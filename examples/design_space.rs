//! Design-space exploration (paper §VI-A, Figs 9–10): enumerate every
//! iso-4TOPS design point, evaluate power/area on the paper's workload,
//! print the pareto frontier and the paper's three design groupings.
//!
//! ```sh
//! cargo run --release --example design_space [-- --csv]
//! ```

use ssta::arch::{space, Design, Tech};
use ssta::cli::Args;
use ssta::models;
use ssta::power;
use ssta::sim::accel::{network_timing, profile_model_repr};
use ssta::util::Parallelism;

fn main() {
    let args = Args::from_env();
    let designs = space::enumerate(space::MACS_4TOPS, Tech::N16);
    let par = Parallelism::auto();
    eprintln!(
        "enumerated {} iso-4TOPS design points ({} sweep threads)",
        designs.len(),
        par.get()
    );

    let m = models::resnet50();
    let profiles = profile_model_repr(&m, 3, 8, 0.5);

    let base = Design::baseline_sa();
    let bt = network_timing(&base, &profiles);
    let bp = power::power(&base, &bt.total).total_mw();
    let ba = power::area(&base).total_mm2();
    let bc = bt.total.cycles as f64;

    // evaluate all points in parallel (one design per task): effective
    // (iso-work) power and area
    let mut rows: Vec<(String, f64, f64)> = space::sweep(&designs, par, |d| {
        let t = network_timing(d, &profiles);
        let slow = t.total.cycles as f64 / bc;
        let p = power::power(d, &t.total).total_mw() * slow / bp;
        let a = power::area(d).total_mm2() * slow / ba;
        (d.label(), p, a)
    });
    rows.sort_by(|x, y| x.1.partial_cmp(&y.1).unwrap());

    if args.flag("csv") {
        println!("design,norm_power,norm_area");
        for (l, p, a) in &rows {
            println!("{l},{p:.4},{a:.4}");
        }
        return;
    }

    // ---- pareto frontier (minimize both axes) ----
    println!("pareto-optimal designs (normalized to {}):", base.label());
    println!("  {:<28} {:>10} {:>10}", "design", "eff power", "eff area");
    let mut best_area = f64::MAX;
    let mut frontier = 0;
    for (l, p, a) in &rows {
        if *a < best_area {
            best_area = *a;
            frontier += 1;
            println!("  {l:<28} {p:>10.3} {a:>10.3}");
        }
    }
    println!("\n{} points on the frontier of {} total", frontier, rows.len());

    // ---- the paper's three groupings (Fig 10's clusters) ----
    let group = |l: &str| {
        if l.contains("VDBB") {
            "VDBB"
        } else if l.contains("DBB") {
            "fixed-DBB"
        } else {
            "dense"
        }
    };
    for g in ["dense", "fixed-DBB", "VDBB"] {
        let pts: Vec<&(String, f64, f64)> = rows.iter().filter(|(l, _, _)| group(l) == g).collect();
        let pmin = pts.iter().map(|(_, p, _)| *p).fold(f64::MAX, f64::min);
        let amin = pts.iter().map(|(_, _, a)| *a).fold(f64::MAX, f64::min);
        println!("group {g:<10} n={:<3} best power {pmin:.3} best area {amin:.3}", pts.len());
    }
    println!("\n(the VDBB+IM2C corner is the paper's Fig 10 pareto group)");
}
