//! Design-space exploration (paper §VI-A, Figs 9–10): enumerate every
//! iso-4TOPS design point, evaluate power/area on the paper's workload,
//! print the pareto frontier and the paper's three design groupings.
//!
//! ```sh
//! cargo run --release --example design_space [-- --csv]
//! ```

use ssta::arch::{space, Design, Tech};
use ssta::cli::Args;
use ssta::models;
use ssta::power;
use ssta::sim::accel::{network_timing, profile_model_repr, LayerProfile};
use ssta::util::Parallelism;

/// Weight-index metadata as a percentage of the stored weight payload.
/// (V)DBB streams one BZ-bit bitmask per block next to its `bound` stored
/// values; BSR streams only the coarse `row_ptr`/`col_idx` arrays next to
/// whole dense blocks — no per-element bitmask at all.
fn index_overhead_pct(profiles: &[LayerProfile], bsr: bool) -> f64 {
    let (mut idx, mut payload) = (0f64, 0f64);
    for p in profiles {
        let s = &p.weights;
        let kb = s.kblocks() as f64;
        if bsr {
            let nbc = (s.n as f64 / s.bz as f64).ceil();
            let keep = ((nbc * s.bound as f64) / s.bz as f64).ceil().clamp(1.0, nbc);
            let stored = kb * keep;
            idx += 4.0 * (kb + 1.0) + 2.0 * stored;
            payload += stored * (s.bz * s.bz) as f64;
        } else {
            idx += kb * s.n as f64 * s.bz as f64 / 8.0;
            payload += kb * s.n as f64 * s.bound as f64;
        }
    }
    100.0 * idx / payload.max(1.0)
}

fn main() {
    let args = Args::from_env();
    let designs = space::enumerate(space::MACS_4TOPS, Tech::N16);
    let par = Parallelism::auto();
    eprintln!(
        "enumerated {} iso-4TOPS design points ({} sweep threads)",
        designs.len(),
        par.get()
    );

    let m = models::resnet50();
    let profiles = profile_model_repr(&m, 3, 8, 0.5);

    let base = Design::baseline_sa();
    let bt = network_timing(&base, &profiles);
    let bp = power::power(&base, &bt.total).total_mw();
    let ba = power::area(&base).total_mm2();
    let bc = bt.total.cycles as f64;

    // evaluate all points in parallel (one design per task): effective
    // (iso-work) power and area
    let mut rows: Vec<(String, f64, f64)> = space::sweep(&designs, par, |d| {
        let t = network_timing(d, &profiles);
        let slow = t.total.cycles as f64 / bc;
        let p = power::power(d, &t.total).total_mw() * slow / bp;
        let a = power::area(d).total_mm2() * slow / ba;
        (d.label(), p, a)
    });
    rows.sort_by(|x, y| x.1.partial_cmp(&y.1).unwrap());

    if args.flag("csv") {
        println!("design,norm_power,norm_area");
        for (l, p, a) in &rows {
            println!("{l},{p:.4},{a:.4}");
        }
        return;
    }

    // ---- pareto frontier (minimize both axes) ----
    println!("pareto-optimal designs (normalized to {}):", base.label());
    println!("  {:<28} {:>10} {:>10}", "design", "eff power", "eff area");
    let mut best_area = f64::MAX;
    let mut frontier = 0;
    for (l, p, a) in &rows {
        if *a < best_area {
            best_area = *a;
            frontier += 1;
            println!("  {l:<28} {p:>10.3} {a:>10.3}");
        }
    }
    println!("\n{} points on the frontier of {} total", frontier, rows.len());

    // ---- the paper's groupings (Fig 10's clusters) + the BSR datapath ----
    let group = |l: &str| {
        if l.contains("BSR") {
            "BSR"
        } else if l.contains("VDBB") {
            "VDBB"
        } else if l.contains("DBB") {
            "fixed-DBB"
        } else {
            "dense"
        }
    };
    for g in ["dense", "fixed-DBB", "VDBB", "BSR"] {
        let pts: Vec<&(String, f64, f64)> = rows.iter().filter(|(l, _, _)| group(l) == g).collect();
        let pmin = pts.iter().map(|(_, p, _)| *p).fold(f64::MAX, f64::min);
        let amin = pts.iter().map(|(_, _, a)| *a).fold(f64::MAX, f64::min);
        println!("group {g:<10} n={:<3} best power {pmin:.3} best area {amin:.3}", pts.len());
    }
    println!("\n(the VDBB+IM2C corner is the paper's Fig 10 pareto group)");

    // ---- weight-format bake-off: DBB vs VDBB vs BSR at matched sparsity ----
    // For each density bound, each format's best iso-throughput design (by
    // effective TOPS/W on the same workload) represents its group; "index %"
    // is the format's weight-index metadata relative to its stored payload.
    println!("\nweight-format bake-off (ResNet-50 repr layers, 50% act, matched density):");
    println!(
        "  {:>4} {:<10} {:<28} {:>10} {:>8}",
        "nnz", "format", "best design", "eff TOPS/W", "index %"
    );
    for nnz in [2usize, 4] {
        let profiles = profile_model_repr(&m, nnz, 8, 0.5);
        for g in ["fixed-DBB", "VDBB", "BSR"] {
            let best = designs
                .iter()
                .filter(|d| group(&d.label()) == g)
                .map(|d| {
                    let t = network_timing(d, &profiles);
                    (power::effective_tops_per_w(d, &t.total, t.dense_macs), d)
                })
                .max_by(|x, y| x.0.partial_cmp(&y.0).unwrap());
            if let Some((tw, d)) = best {
                let ovh = index_overhead_pct(&profiles, g == "BSR");
                println!("  {:>4} {:<10} {:<28} {:>10.1} {:>8.2}", nnz, g, d.label(), tw, ovh);
            }
        }
    }
    println!("\n(BSR trades finer-grained skipping for a bitmask-free index stream)");
}
