//! Quickstart: the core VDBB flow in ~60 lines.
//!
//! 1. magnitude-prune an INT8 weight matrix to a DBB bound and compress it;
//! 2. run the GEMM functionally (golden) and on the cycle-accurate
//!    STA-VDBB simulator — same numbers, plus cycles/events;
//! 3. ask the power model what the paper's optimal 16 nm design would
//!    burn doing it, and how that scales with the density bound.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use ssta::arch::Design;
use ssta::dbb::{prune::prune_i8, DbbMatrix};
use ssta::gemm;
use ssta::power;
use ssta::sim::detailed::simulate_gemm;
use ssta::tensor::TensorI8;
use ssta::util::Rng;

fn main() {
    let mut rng = Rng::new(42);
    let (m, k, n) = (64usize, 128usize, 32usize);
    let (bz, nnz) = (8usize, 3usize);

    // ---- 1. prune + compress (paper Fig. 2) ----
    let dense = TensorI8::rand(&[k, n], &mut rng);
    let pruned = prune_i8(&dense, bz, nnz);
    let w = DbbMatrix::compress_with_bound(&pruned, bz, nnz).expect("satisfies bound");
    println!(
        "weights {k}x{n}: DBB {nnz}/{bz} → {} non-zeros, {:.2}x compression",
        w.total_nnz(),
        w.compression_ratio()
    );

    // ---- 2. golden GEMM vs simulated STA-VDBB ----
    let a = TensorI8::rand_sparse(&[m, k], 0.5, &mut rng); // 50% act sparsity
    let golden = gemm::dense_i8(&a, &pruned);

    let design = Design::paper_optimal(); // 4x8x8_8x8_VDBB_IM2C, 16 nm
    let r = simulate_gemm(&design, &a, &w, 1.0);
    assert_eq!(r.output.data(), golden.data(), "simulator is bit-exact");
    let ev = &r.timing.events;
    println!(
        "simulated on {}: {} cycles, {:.0} effective MACs/cycle, utilization {:.1}%",
        design.label(),
        ev.cycles,
        r.timing.dense_macs as f64 / ev.cycles as f64,
        100.0 * ev.utilization()
    );

    // ---- 3. power/energy, and the VDBB scaling story ----
    let p = power::power(&design, ev);
    println!("power at this operating point: {:.1} mW", p.total_mw());
    println!("\nVDBB scaling (same design, same GEMM, varying density bound):");
    println!("  bound   cycles   eff MACs/cyc   TOPS/W");
    for bound in [8usize, 6, 4, 3, 2, 1] {
        let wp = prune_i8(&dense, bz, bound);
        let wb = DbbMatrix::compress_with_bound(&wp, bz, bound).unwrap();
        let rb = simulate_gemm(&design, &a, &wb, 1.0);
        let tw = power::effective_tops_per_w(&design, &rb.timing.events, rb.timing.dense_macs);
        println!(
            "  {}/8     {:>6}   {:>10.0}   {:>6.1}",
            bound,
            rb.timing.events.cycles,
            rb.timing.dense_macs as f64 / rb.timing.events.cycles as f64,
            tw
        );
    }
    println!("\n(time-unrolled VDBB: cycles scale with the bound, utilization stays flat)");
}
