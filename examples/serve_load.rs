//! Closed-loop SLO load harness for the engine-native serving path.
//!
//! Drives the coordinator's registry-served fused engine (no artifacts, no
//! XLA) with multi-threaded traffic against **two concurrently registered
//! models** and reports, per model, the serving percentiles an SLO review
//! would ask for — p50/p95/p99 latency, throughput — plus the hardware
//! twin's effective TOPS and TOPS/W on exactly the traffic served.
//!
//! Two traffic shapes:
//! * **closed loop** (default, `--rate 0`): `--concurrency` workers each
//!   keep one request in flight — the classic SLO load pattern where
//!   offered load adapts to the server.
//! * **open loop** (`--rate R` > 0): requests are submitted at a fixed
//!   arrival rate regardless of completions, so queueing delay shows up in
//!   the tail percentiles.
//! * **rate sweep** (`--rate-sweep lo:hi:steps`): open-loop runs at
//!   `steps` offered rates between `lo` and `hi` req/s, printing a
//!   latency-vs-offered-rate table (p50/p99 plus peak queue depth per
//!   rate) — the knee of that curve is the design's serving capacity.
//!
//! The run also exercises the two serving features this harness exists to
//! gate:
//! * **persistence** — models are prepared once into `--persist-dir` (a
//!   scratch directory by default) and the coordinator is started twice;
//!   the second start loads the flat binaries and its startup time is
//!   reported next to the cold prepare.
//! * **eviction** — an interleaved phase alternates models per request, so
//!   under a tight `--budget-bytes` the registry thrashes and the eviction
//!   counter moves (the miss path re-loads from the persisted binary).
//!
//! `--smoke` runs a seconds-scale version of all of the above and exits
//! non-zero unless both models served, the percentiles are sane, and
//! eviction actually happened — the CI entry point.
//!
//! ```sh
//! cargo run --release --example serve_load -- --requests 512 --concurrency 8
//! cargo run --release --example serve_load -- --smoke
//! ```

use std::time::{Duration, Instant};

use ssta::arch::Design;
use ssta::cli::Args;
use ssta::coordinator::registry::ModelSpec;
use ssta::coordinator::{Config, Coordinator, Handle};
use ssta::util::error::{Error, Result};
use ssta::util::Rng;

const IMG: usize = 32 * 32 * 3;

/// Closed loop: `concurrency` workers, each keeping one request in flight
/// until `requests` total have completed for `model`. Returns the wall time.
fn run_closed_loop(
    h: &Handle,
    model: &str,
    images: &[Vec<f32>],
    requests: usize,
    concurrency: usize,
) -> Duration {
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for w in 0..concurrency {
            let h = h.clone();
            s.spawn(move || {
                let mut i = w;
                while i < requests {
                    let img = images[i % images.len()].clone();
                    h.infer_to(model, i as u64, img).expect("serving failed under load");
                    i += concurrency;
                }
            });
        }
    });
    t0.elapsed()
}

/// Open loop at `rate` requests/s: submissions are paced by arrival time,
/// not by completions; all responses are drained at the end.
fn run_open_loop(
    h: &Handle,
    model: &str,
    images: &[Vec<f32>],
    requests: usize,
    rate: f64,
) -> Duration {
    let period = Duration::from_secs_f64(1.0 / rate.max(1e-9));
    let t0 = Instant::now();
    let mut pending = Vec::with_capacity(requests);
    for i in 0..requests {
        let due = period * i as u32;
        if let Some(sleep) = due.checked_sub(t0.elapsed()) {
            std::thread::sleep(sleep);
        }
        let img = images[i % images.len()].clone();
        pending.push(h.submit_to(model, i as u64, img).expect("submit failed"));
    }
    for rx in pending {
        rx.recv().expect("serving failed under load");
    }
    t0.elapsed()
}

/// One point of the open-loop rate sweep: arrivals paced at `rate` req/s
/// with one thread per in-flight request (a true open loop — completions
/// never gate submissions), measuring per-request latency client-side.
/// Returns the latency sample in µs and the peak number of requests that
/// were simultaneously in flight (the queue depth the rate built up).
fn run_sweep_point(
    h: &Handle,
    model: &str,
    images: &[Vec<f32>],
    requests: usize,
    rate: f64,
) -> (Vec<u64>, usize) {
    use std::sync::atomic::{AtomicUsize, Ordering};
    let inflight = AtomicUsize::new(0);
    let peak = AtomicUsize::new(0);
    let lat_us: Vec<u64> = std::thread::scope(|s| {
        let period = Duration::from_secs_f64(1.0 / rate.max(1e-9));
        let t0 = Instant::now();
        let mut workers = Vec::with_capacity(requests);
        for i in 0..requests {
            let due = period * i as u32;
            if let Some(sleep) = due.checked_sub(t0.elapsed()) {
                std::thread::sleep(sleep);
            }
            let img = images[i % images.len()].clone();
            let h = h.clone();
            let (inflight, peak) = (&inflight, &peak);
            workers.push(s.spawn(move || {
                let depth = inflight.fetch_add(1, Ordering::SeqCst) + 1;
                peak.fetch_max(depth, Ordering::SeqCst);
                let t = Instant::now();
                h.infer_to(model, i as u64, img).expect("serving failed under load");
                inflight.fetch_sub(1, Ordering::SeqCst);
                t.elapsed().as_micros() as u64
            }));
        }
        workers.into_iter().map(|w| w.join().expect("sweep worker panicked")).collect()
    });
    (lat_us, peak.into_inner())
}

/// Nearest-rank percentile of an ascending-sorted µs sample.
fn pct_us(sorted: &[u64], p: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let rank = ((p / 100.0 * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
    sorted[rank - 1]
}

/// Interleave requests across all models round-robin — the registry-thrash
/// phase that makes a tight byte budget evict on every model switch.
fn run_interleaved(h: &Handle, models: &[String], images: &[Vec<f32>], requests: usize) -> Duration {
    let t0 = Instant::now();
    for i in 0..requests {
        let model = &models[i % models.len()];
        let img = images[i % images.len()].clone();
        h.infer_to(model, i as u64, img).expect("serving failed under load");
    }
    t0.elapsed()
}

fn main() -> Result<()> {
    let args = Args::from_env();
    let smoke = args.flag("smoke");
    let requests = args.opt_as::<usize>("requests", if smoke { 64 } else { 256 });
    let concurrency = args.opt_as::<usize>("concurrency", 4).max(1);
    let rate = args.opt_as::<f64>("rate", 0.0);
    let design = Design::parse(args.opt("design").unwrap_or("4x8x8_8x8_VDBB_IM2C"))
        .map_err(Error::msg)?;
    // smoke forces the thrash regime: a budget of 1 byte can hold only one
    // model, so the interleaved phase evicts on every switch
    let budget = args.opt_as::<usize>("budget-bytes", if smoke { 1 } else { 256 * 1024 * 1024 });
    let scratch;
    let persist_dir = match args.opt("persist-dir") {
        Some(d) => std::path::PathBuf::from(d),
        None => {
            scratch = std::env::temp_dir().join(format!("ssta-serve-load-{}", std::process::id()));
            scratch.clone()
        }
    };
    let cleanup_scratch = args.opt("persist-dir").is_none();

    let cfg = Config {
        design,
        registry: vec![ModelSpec::new("ConvNet", 3, 8), ModelSpec::new("LeNet-5", 2, 8)],
        registry_budget_bytes: budget,
        persist_dir: Some(persist_dir.clone()),
        max_wait: Duration::from_micros(500),
        ..Config::default()
    };

    // ---- persistence: cold start (prepare + save) vs warm start (load) ----
    let t0 = Instant::now();
    let coord = Coordinator::start(cfg.clone())?;
    let cold = t0.elapsed();
    coord.shutdown()?;
    let t1 = Instant::now();
    let coord = Coordinator::start(cfg)?;
    let warm = t1.elapsed();
    println!(
        "startup: cold prepare+persist {cold:.2?} → warm load from flat binaries {warm:.2?} \
         ({:.1}x faster; encode/calibrate skipped)",
        cold.as_secs_f64() / warm.as_secs_f64().max(1e-9)
    );
    let h = coord.handle();
    let models: Vec<String> = h.models().to_vec();

    let mut rng = Rng::new(17);
    let images: Vec<Vec<f32>> =
        (0..64).map(|_| (0..IMG).map(|_| rng.f32()).collect()).collect();

    // ---- open-loop rate sweep: latency vs offered rate, then exit ----
    if let Some(spec) = args.opt("rate-sweep") {
        let parts: Vec<&str> = spec.split(':').collect();
        let bad = || Error::msg(format!("bad --rate-sweep '{spec}' (want lo:hi:steps)"));
        if parts.len() != 3 {
            return Err(bad());
        }
        let lo = parts[0].parse::<f64>().map_err(|_| bad())?;
        let hi = parts[1].parse::<f64>().map_err(|_| bad())?;
        let steps = parts[2].parse::<usize>().map_err(|_| bad())?.max(1);
        let model = &models[0];
        println!("open-loop rate sweep on {model} ({requests} requests per point):");
        println!(
            "  {:>11} {:>9} {:>9} {:>10} {:>12}",
            "offered r/s", "p50 µs", "p99 µs", "peak queue", "achieved r/s"
        );
        for i in 0..steps {
            let rate = if steps == 1 {
                lo
            } else {
                lo + (hi - lo) * i as f64 / (steps - 1) as f64
            };
            let t0 = Instant::now();
            let (mut lat, depth) = run_sweep_point(&h, model, &images, requests, rate);
            let wall = t0.elapsed();
            lat.sort_unstable();
            println!(
                "  {:>11.0} {:>9} {:>9} {:>10} {:>12.0}",
                rate,
                pct_us(&lat, 50.0),
                pct_us(&lat, 99.0),
                depth,
                lat.len() as f64 / wall.as_secs_f64().max(1e-9),
            );
        }
        println!("(the p99 knee marks where the offered rate outruns the engine)");
        coord.shutdown()?;
        if cleanup_scratch {
            let _ = std::fs::remove_dir_all(&persist_dir);
        }
        return Ok(());
    }

    // ---- per-model load phases ----
    for model in &models {
        let wall = if rate > 0.0 {
            run_open_loop(&h, model, &images, requests, rate)
        } else {
            run_closed_loop(&h, model, &images, requests, concurrency)
        };
        println!(
            "{model}: {requests} requests in {wall:.2?} → {:.0} req/s \
             ({} loop, concurrency {concurrency})",
            requests as f64 / wall.as_secs_f64(),
            if rate > 0.0 { "open" } else { "closed" },
        );
    }

    // ---- registry-thrash phase: alternate models per request ----
    let thrash = if smoke { requests.min(16) } else { requests.min(64) };
    let wall = run_interleaved(&h, &models, &images, thrash);
    println!("interleaved: {thrash} alternating requests in {wall:.2?} (eviction pressure)");

    // ---- the SLO report ----
    let m = coord.metrics();
    let f = design.tech.freq_hz();
    println!("aggregate: {}", m.summary());
    println!("per-model SLO report ({}):", design.label());
    for model in &models {
        let Some(mm) = m.model(model) else {
            println!("  {model}: served nothing");
            continue;
        };
        let tops = mm.sim_effective_tops(f);
        let watts = mm.sim_avg_power_w(f);
        println!(
            "  {model}: requests={} p50={}µs p95={}µs p99={}µs occupancy={:.2} \
             twin {:.2} TOPS {:.3} W → {:.1} TOPS/W",
            mm.requests,
            mm.latency_pct(50.0),
            mm.latency_pct(95.0),
            mm.latency_pct(99.0),
            mm.occupancy(),
            tops,
            watts,
            tops / watts.max(1e-12),
        );
    }
    println!("evictions: {}", m.evictions);
    coord.shutdown()?;
    if cleanup_scratch {
        let _ = std::fs::remove_dir_all(&persist_dir);
    }

    // ---- smoke gate: the CI assertions ----
    if smoke {
        let mut failed = false;
        for model in &models {
            match m.model(model) {
                Some(mm) if mm.requests > 0 && mm.latency_pct(99.0) > 0 => {}
                _ => {
                    eprintln!("SMOKE FAIL: model '{model}' served no measurable traffic");
                    failed = true;
                }
            }
        }
        if m.evictions == 0 {
            eprintln!("SMOKE FAIL: byte-budget eviction never triggered");
            failed = true;
        }
        if warm >= cold {
            // loading flat binaries must beat synthesize+encode+calibrate;
            // warn only (CI machines can be noisy), the bit-exactness is
            // test-pinned elsewhere
            eprintln!("note: warm start {warm:.2?} not faster than cold {cold:.2?} on this run");
        }
        if failed {
            std::process::exit(1);
        }
        println!("smoke OK: both models served, eviction exercised, percentiles populated");
    }
    Ok(())
}
