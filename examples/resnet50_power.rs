//! ResNet-50 per-layer power walk (paper Fig. 11): run the whole network
//! through the analytic engine on three designs, with *measured* per-layer
//! activation sparsity from a sampled functional INT8 inference, and print
//! the per-layer normalized power plus the whole-model reduction.
//!
//! ```sh
//! cargo run --release --example resnet50_power [-- --nnz 3 --seed 42]
//! ```

use ssta::arch::Design;
use ssta::cli::Args;
use ssta::models;
use ssta::power;
use ssta::sim::accel::{network_timing, profile_model};

fn main() {
    let args = Args::from_env();
    let nnz = args.opt_as::<usize>("nnz", 3);
    let seed = args.opt_as::<u64>("seed", 42);

    let model = models::resnet50();
    eprintln!(
        "profiling {} ({} layers) with {}/8 DBB weights, measuring act sparsity...",
        model.name,
        model.layers.len(),
        nnz
    );
    let profiles = profile_model(&model, nnz, 8, seed);

    let designs = [
        Design::baseline_sa(),
        Design::parse("4x8x4_4x8_DBB4of8_IM2C").unwrap(),
        Design::paper_optimal(),
    ];
    let timings: Vec<_> = designs.iter().map(|d| network_timing(d, &profiles)).collect();

    println!(
        "{:<22} {:>6}   {:>8} {:>8} {:>8}",
        "layer", "act-sp%", "SA mW", "DBB mW", "VDBB mW"
    );
    for li in 0..profiles.len() {
        let mut cols = Vec::new();
        for (d, t) in designs.iter().zip(&timings) {
            cols.push(power::power(d, &t.layers[li].events).total_mw());
        }
        println!(
            "{:<22} {:>6.1}   {:>8.1} {:>8.1} {:>8.1}",
            profiles[li].name,
            100.0 * profiles[li].act_sparsity,
            cols[0],
            cols[1],
            cols[2]
        );
    }

    println!("\nwhole model:");
    let base_p = power::power(&designs[0], &timings[0].total).total_mw();
    for (d, t) in designs.iter().zip(&timings) {
        let p = power::power(d, &t.total).total_mw();
        println!(
            "  {:<28} {:>8.1} mW  ({:+.1}% vs baseline), {} cycles, {:.1} eff TOPS",
            d.label(),
            p,
            100.0 * (p / base_p - 1.0),
            t.total.cycles,
            t.effective_tops(d)
        );
    }
    println!(
        "\n(paper Fig 11: the VDBB+IM2C design achieves a large whole-model power cut\n \
         while also finishing in ~1/2.4 the cycles — energy/inference drops further)"
    );
}
