//! Full-zoo scenario sweep: prepare every `models::zoo` member — the five
//! Table-I CNNs plus the ViT-class transformer block — through the
//! prepared-model engine, serve each one out of the coordinator's
//! `ModelRegistry`, and report the whole fleet as one scenario table:
//! per-layer `ActPolicy` resolution, measured activation sparsity, twin
//! effective-TOPS and TOPS/W at the paper-optimal design point, and
//! execute-latency p50/p99.
//!
//! Every model additionally round-trips through the flat-binary persistence
//! path, and the table's `exact` column certifies that the *reloaded*
//! model's fused i8→i8 chain reproduces the freshly prepared model's staged
//! chain bit-for-bit — the property CI gates on.
//!
//!   cargo run --release --example scenario_sweep                 # full sweep
//!   cargo run --release --example scenario_sweep -- --smoke      # CI gate
//!   cargo run --release --example scenario_sweep -- --report SCENARIOS.md
//!
//! Flags: `--smoke` (fewer latency iters, exit 1 on any gate failure),
//! `--iters N` (latency samples per model), `--design SPEC` (twin design
//! point, e.g. `4x8x8_8x8_VDBB_IM2C`), `--report PATH` (also write the
//! table + per-layer appendix as markdown — `SCENARIOS.md` is the committed
//! copy).

use ssta::arch::Design;
use ssta::cli::Args;
use ssta::coordinator::registry::{ModelRegistry, ModelSpec};
use ssta::engine::PreparedModel;
use ssta::gemm::ActPolicy;
use ssta::models::{self, LayerKind, Model};
use ssta::power;
use ssta::sim::accel::network_timing_with;
use ssta::tensor::TensorI8;
use ssta::util::error::{Context, Error, Result};
use ssta::util::table::Table;
use ssta::util::{Parallelism, Rng};
use std::time::Instant;

/// Twin seed shared with `coordinator::TWIN_SEED` — one lowering per model.
const SEED: u64 = 42;

/// Per-scenario sweep result (one zoo member at one DBB encoding point).
struct Scenario {
    spec: ModelSpec,
    model: Model,
    prepare_ms: f64,
    persist_bytes: usize,
    bit_exact: bool,
    policies: Vec<ActPolicy>,
    act_sparsity: Vec<f64>,
    eff_tops: f64,
    tops_per_w: f64,
    p50_us: f64,
    p99_us: f64,
}

fn percentile(sorted_us: &[f64], p: f64) -> f64 {
    if sorted_us.is_empty() {
        return 0.0;
    }
    let idx = (p / 100.0 * (sorted_us.len() - 1) as f64).round() as usize;
    sorted_us[idx.min(sorted_us.len() - 1)]
}

fn policy_counts(policies: &[ActPolicy]) -> (usize, usize, usize) {
    let off = policies.iter().filter(|p| matches!(p, ActPolicy::Off)).count();
    let gate = policies.iter().filter(|p| matches!(p, ActPolicy::Gate)).count();
    let enc = policies.iter().filter(|p| matches!(p, ActPolicy::Encode)).count();
    (off, gate, enc)
}

fn kind_label(kind: &LayerKind) -> String {
    match kind {
        LayerKind::Conv(s) => format!("conv{}x{}/s{}", s.kh, s.kw, s.stride),
        LayerKind::DepthwiseConv(s) => format!("dw{}x{}/s{}", s.kh, s.kw, s.stride),
        LayerKind::Fc(i, o) => format!("fc{i}x{o}"),
    }
}

/// Prepare, profile, calibrate, persist, reload, verify, and measure one
/// zoo member; the returned scenario carries everything the table reports.
fn run_scenario(
    spec: &ModelSpec,
    design: &Design,
    par: Parallelism,
    iters: usize,
    persist_dir: &std::path::Path,
    registry: &mut ModelRegistry,
) -> Result<Scenario> {
    let model = models::zoo()
        .into_iter()
        .find(|m| m.name == spec.model)
        .ok_or_else(|| Error::msg(format!("'{}' is not a zoo member", spec.model)))?;

    // ---- one-time lowering: §II-A offline compile ----
    let t0 = Instant::now();
    let mut pm = PreparedModel::prepare(&model, spec.nnz, spec.bz, SEED, par);
    pm.set_fused_epilogue(true);
    pm.profile(par);
    pm.calibrate(par);
    let prepare_ms = t0.elapsed().as_secs_f64() * 1e3;

    // ---- persistence round trip: save, reload, verify bit-exactness of
    // the reloaded fused chain against the fresh staged chain ----
    let path = persist_dir.join(format!("{}_nnz{}_bz{}.ssta", spec.model, spec.nnz, spec.bz));
    pm.save(&path)?;
    let persist_bytes =
        std::fs::metadata(&path).context("stat persisted model")?.len() as usize;
    let reloaded = PreparedModel::load(&path, par)?;
    let mut rng = Rng::new(17);
    let mut bit_exact = reloaded.model_name() == pm.model_name();
    let mut inputs: Vec<TensorI8> = vec![pm.seed_input().clone()];
    inputs.extend((0..2).map(|_| TensorI8::rand_sparse(&[32 * 32 * 8], 0.5, &mut rng)));
    for x in &inputs {
        bit_exact &= pm.execute_staged(x, par).output == reloaded.execute_fused(x, par).output;
    }
    bit_exact &= pm.profiles().is_some() && pm.calibrated_shifts().is_some();

    // ---- twin accounting: full-network timing + power at `design` ----
    let profiles = pm
        .profiles()
        .ok_or_else(|| Error::msg(format!("'{}' has no profile", spec.model)))?;
    let nt = network_timing_with(design, &profiles, par);
    let eff_tops = nt.effective_tops(design);
    let tops_per_w = power::effective_tops_per_w(design, &nt.total, nt.dense_macs);

    // ---- serve out of the registry: policy resolution + latency ----
    registry.insert(spec.model.clone(), reloaded);
    let served = registry
        .get(&spec.model)
        .ok_or_else(|| Error::msg(format!("'{}' missing from registry", spec.model)))?;
    let input = served.seed_input().clone();
    let probe = served.execute_fused(&input, par);
    bit_exact &= !probe.act_policy.iter().any(|p| matches!(p, ActPolicy::Auto));
    let mut lat_us: Vec<f64> = (0..iters.max(1))
        .map(|_| {
            let t = Instant::now();
            let _ = served.execute_fused(&input, par);
            t.elapsed().as_secs_f64() * 1e6
        })
        .collect();
    lat_us.sort_by(|a, b| a.partial_cmp(b).unwrap());

    Ok(Scenario {
        spec: spec.clone(),
        model,
        prepare_ms,
        persist_bytes,
        bit_exact,
        policies: probe.act_policy.clone(),
        act_sparsity: probe.act_sparsity.clone(),
        eff_tops,
        tops_per_w,
        p50_us: percentile(&lat_us, 50.0),
        p99_us: percentile(&lat_us, 99.0),
    })
}

fn scenario_table(scenarios: &[Scenario], design: &Design) -> Table {
    let mut t = Table::new(&format!("Scenario sweep — zoo @ {design}"));
    t.header(&[
        "model", "layers", "GMACs", "dbb", "policy o/g/e", "act%", "effTOPS", "TOPS/W",
        "prep ms", "p50 us", "p99 us", "persist KiB", "exact",
    ]);
    for s in scenarios {
        let (off, gate, enc) = policy_counts(&s.policies);
        let mean_act =
            100.0 * s.act_sparsity.iter().sum::<f64>() / s.act_sparsity.len().max(1) as f64;
        t.row(&[
            s.spec.model.clone(),
            format!("{}", s.model.layers.len()),
            format!("{:.2}", s.model.total_macs() as f64 / 1e9),
            format!("{}/{}", s.spec.nnz, s.spec.bz),
            format!("{off}/{gate}/{enc}"),
            format!("{mean_act:.0}"),
            format!("{:.2}", s.eff_tops),
            format!("{:.2}", s.tops_per_w),
            format!("{:.1}", s.prepare_ms),
            format!("{:.0}", s.p50_us),
            format!("{:.0}", s.p99_us),
            format!("{}", s.persist_bytes / 1024),
            if s.bit_exact { "yes" } else { "NO" }.to_string(),
        ]);
    }
    t
}

/// Render the sweep as the checked-in markdown report (`SCENARIOS.md`).
fn markdown_report(scenarios: &[Scenario], design: &Design) -> String {
    let mut md = String::new();
    md.push_str("# Scenario sweep — the full serving zoo\n\n");
    md.push_str(&format!(
        "Generated by `cargo run --release --example scenario_sweep -- --report \
         SCENARIOS.md` (twin design point: `{design}`, seed {SEED}). Six scenarios: \
         the five Table-I CNNs plus the FC-only ViT-class transformer block, each \
         prepared once (§II-A offline encode), persisted, reloaded, and served \
         through the coordinator's model registry. `exact` certifies the reloaded \
         fused i8→i8 chain matches the fresh staged chain bit-for-bit. Latency \
         columns are host-dependent; twin columns are deterministic for the \
         design point.\n\n"
    ));
    md.push_str(
        "| model | layers | GMACs | dbb | policy o/g/e | act% | effTOPS | TOPS/W | \
         p50 us | p99 us | persist KiB | exact |\n\
         |---|---|---|---|---|---|---|---|---|---|---|---|\n",
    );
    for s in scenarios {
        let (off, gate, enc) = policy_counts(&s.policies);
        let mean_act =
            100.0 * s.act_sparsity.iter().sum::<f64>() / s.act_sparsity.len().max(1) as f64;
        md.push_str(&format!(
            "| {} | {} | {:.2} | {}/{} | {off}/{gate}/{enc} | {mean_act:.0} | {:.2} | \
             {:.2} | {:.0} | {:.0} | {} | {} |\n",
            s.spec.model,
            s.model.layers.len(),
            s.model.total_macs() as f64 / 1e9,
            s.spec.nnz,
            s.spec.bz,
            s.eff_tops,
            s.tops_per_w,
            s.p50_us,
            s.p99_us,
            s.persist_bytes / 1024,
            if s.bit_exact { "yes" } else { "NO" },
        ));
    }
    md.push_str(
        "\n`policy o/g/e` counts layers whose activation operand the engine's \
         `ActPolicy::Auto` resolved to Off / Gate (run-length zero-skip) / Encode \
         (A-side DBB) from the measured profile; `act%` is the mean measured \
         zero fraction of each layer's input operand.\n",
    );
    for s in scenarios {
        md.push_str(&format!(
            "\n## {} ({}, dbb {}/{})\n\n\
             | layer | kind | policy | act sparsity |\n|---|---|---|---|\n",
            s.spec.model, s.model.dataset, s.spec.nnz, s.spec.bz
        ));
        for (i, l) in s.model.layers.iter().enumerate() {
            md.push_str(&format!(
                "| {} | {} | {:?} | {:.2} |\n",
                l.name,
                kind_label(&l.kind),
                s.policies.get(i).copied().unwrap_or(ActPolicy::Off),
                s.act_sparsity.get(i).copied().unwrap_or(0.0),
            ));
        }
    }
    md
}

fn main() -> Result<()> {
    let args = Args::from_env();
    let smoke = args.flag("smoke");
    let iters: usize = args.opt_as("iters", if smoke { 5 } else { 50 });
    let design = match args.opt("design") {
        Some(spec) => Design::parse(spec)
            .map_err(|e| Error::msg(format!("unparseable design spec '{spec}': {e:?}")))?,
        None => Design::paper_optimal(),
    };
    let par = Parallelism::auto();

    // the zoo at its serving encoding points: Table-I-style DBB for the
    // CNNs (first convs / depthwise dense), 4/8 for the transformer block's
    // GELU-sparse MLP GEMMs
    let specs = [
        ModelSpec::new("LeNet-5", 2, 8),
        ModelSpec::new("ConvNet", 3, 8),
        ModelSpec::new("ResNet-50V1", 3, 8),
        ModelSpec::new("VGG-16", 3, 8),
        ModelSpec::new("MobileNetV1", 4, 8),
        ModelSpec::new("TransformerBlock", 4, 8),
    ];

    let persist_dir =
        std::env::temp_dir().join(format!("ssta-scenario-sweep-{}", std::process::id()));
    std::fs::create_dir_all(&persist_dir).context("creating persist dir")?;
    let mut registry = ModelRegistry::new(1 << 30);

    println!(
        "scenario sweep: {} zoo members, twin design {design}, {iters} latency \
         iters{}",
        specs.len(),
        if smoke { " (smoke)" } else { "" }
    );
    let mut scenarios = Vec::new();
    for spec in &specs {
        let t = Instant::now();
        let s = run_scenario(spec, &design, par, iters, &persist_dir, &mut registry)?;
        println!(
            "  {:<16} prepared+persisted+served in {:.1}s ({})",
            spec.model,
            t.elapsed().as_secs_f64(),
            if s.bit_exact { "fused == staged bit-exact" } else { "MISMATCH" },
        );
        scenarios.push(s);
    }
    let _ = std::fs::remove_dir_all(&persist_dir);

    scenario_table(&scenarios, &design).print();
    println!(
        "registry: {} resident models, {:.1} MiB packed operands",
        registry.len(),
        registry.resident_bytes() as f64 / (1024.0 * 1024.0)
    );

    if let Some(path) = args.opt("report") {
        std::fs::write(path, markdown_report(&scenarios, &design))
            .with_context(|| format!("writing report {path}"))?;
        println!("report written to {path}");
    }

    // ---- the gate CI runs under --smoke ----
    let failures: Vec<&str> = scenarios
        .iter()
        .filter(|s| !s.bit_exact)
        .map(|s| s.spec.model.as_str())
        .collect();
    if scenarios.len() != specs.len() || !failures.is_empty() {
        eprintln!("scenario sweep FAILED: {:?}", failures);
        std::process::exit(1);
    }
    println!(
        "scenario sweep: all {} zoo members prepare, persist/reload, and execute \
         fused == staged bit-exact",
        scenarios.len()
    );
    Ok(())
}
