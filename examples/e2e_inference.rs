//! End-to-end driver: the full three-layer stack on a real serving
//! workload, proving all layers compose.
//!
//! * **Layer 1/2** (build time): `make artifacts` lowered the ConvNet-5
//!   forward — Pallas VDBB-GEMM + IM2COL kernels inside a JAX graph — to
//!   HLO text with the DBB-compressed INT8 weights baked in.
//! * **Layer 3** (this binary): the rust coordinator loads the artifacts
//!   via PJRT, serves a batched request stream (open-loop Poisson-ish
//!   arrivals), and runs every batch through the STA-VDBB hardware twin
//!   for simulated cycles/energy.
//!
//! Reports functional correctness (logits vs a golden replay), serving
//! latency/throughput, batch occupancy, and the twin's effective TOPS and
//! TOPS/W — the paper's headline metric, measured on served traffic.
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_inference -- --requests 256
//! ```
//!
//! Before touching the artifacts it also proves, fully offline, the fused
//! streaming-IM2COL conv engine (paper §IV-C in software) on a ConvNet-5
//! layer and the prepare-once/execute-many engine (`ssta::engine`, paper
//! §II-A's offline weight encode) on the whole served model.

use std::time::{Duration, Instant};

use ssta::arch::Design;
use ssta::cli::Args;
use ssta::coordinator::{request::argmax, Config, Coordinator};
use ssta::gemm::conv::{im2col, ConvShape};
use ssta::gemm::{fused, tiled, ActPolicy, ZeroGate};
use ssta::runtime::{HostTensor, Runtime};
use ssta::tensor::TensorI8;
use ssta::util::error::{Error, Result};
use ssta::util::{Parallelism, Rng};

const IMG: usize = 32 * 32 * 3;

/// Materialized-vs-fused conv on ConvNet-5's conv2 (16×16×32, 5×5 → 32):
/// same result bit for bit, without ever allocating the M×K operand.
fn fused_conv_showcase() {
    let s = ConvShape { h: 16, w: 16, c: 32, kh: 5, kw: 5, oc: 32, stride: 1, pad: 2 };
    let mut rng = Rng::new(5);
    let x = TensorI8::rand_sparse(&[s.h, s.w, s.c], 0.5, &mut rng);
    let w = TensorI8::rand(&[s.gemm_k(), s.oc], &mut rng);
    let par = Parallelism::auto();

    let t0 = Instant::now();
    let a = im2col(&x, &s);
    let materialized = tiled::dense_i8(&a, &w, par);
    let t_mat = t0.elapsed();

    let t1 = Instant::now();
    let fused_out = fused::conv2d_i8(&x, &w, &s, par);
    let t_fus = t1.elapsed();

    assert_eq!(materialized.data(), fused_out.data(), "fused != materialized");
    println!(
        "conv2 16×16×32·5×5→32: materialized {t_mat:.2?} ({} operand B) vs \
         fused {t_fus:.2?} ({} peak operand B) — outputs bit-identical",
        s.gemm_m() * s.gemm_k(),
        fused::peak_operand_bytes(&s, par),
    );
}

/// Prepare-once/execute-many on the served model (paper §II-A's
/// offline-encode split, offline-runnable): the first call pays the weight
/// encode + CSC pack, every execute after that streams packed operands.
fn prepared_engine_showcase() {
    let m = ssta::models::convnet5();
    let par = Parallelism::auto();
    let t0 = Instant::now();
    let mut prepared = ssta::engine::PreparedModel::prepare(&m, 3, 8, 42, par);
    let t_prep = t0.elapsed();
    let t1 = Instant::now();
    let first = prepared.execute(prepared.seed_input(), par);
    let t_exec = t1.elapsed();
    let again = prepared.execute(prepared.seed_input(), par);
    assert_eq!(first.output, again.output, "execute must be pure");
    println!(
        "prepared {}: encode+pack once {t_prep:.2?} ({} operand B), \
         then execute {t_exec:.2?}/call with zero encode work",
        prepared.model_name(),
        prepared.operand_bytes(),
    );

    // ---- A-side policy on the measured sparsities (paper §II, S2TA) ----
    // profile once, then let the three-way ActPolicy::Auto pick per layer
    // (off / gate / encode) from the same measured act sparsities the
    // hardware twin prices
    prepared.profile(par);
    let off = prepared.execute_gated(prepared.seed_input(), par, ZeroGate::Off);
    let t2 = Instant::now();
    let auto = prepared.execute_policy(prepared.seed_input(), par, ActPolicy::Auto);
    let t_auto = t2.elapsed();
    assert_eq!(off.output, auto.output, "gating/encoding must be bit-exact");
    let t3 = Instant::now();
    let enc = prepared.execute_policy(prepared.seed_input(), par, ActPolicy::Encode);
    let t_enc = t3.elapsed();
    assert_eq!(off.output, enc.output, "A-DBB encoding must be bit-exact");
    let decisions: Vec<String> = auto
        .act_sparsity
        .iter()
        .zip(&auto.act_policy)
        .map(|(s, p)| format!("{:.0}%{}", 100.0 * s, match p {
            ActPolicy::Encode => "(encode)",
            ActPolicy::Gate => "(gate)",
            _ => "",
        }))
        .collect();
    println!(
        "act-policy Auto: per-layer measured sparsity → decision [{}] — \
         auto execute {t_auto:.2?}, all-encoded execute {t_enc:.2?}, \
         outputs bit-identical",
        decisions.join(" "),
    );
}

fn main() -> Result<()> {
    let args = Args::from_env();
    let n = args.opt_as::<usize>("requests", 256);
    let artifacts = args.opt("artifacts").unwrap_or("artifacts").to_string();
    let design = Design::parse(args.opt("design").unwrap_or("4x8x8_8x8_VDBB_IM2C"))
        .map_err(Error::msg)?;

    // ---- offline: fused streaming conv vs the materializing lowering ----
    fused_conv_showcase();
    // ---- offline: the prepare-once/execute-many engine ----
    prepared_engine_showcase();

    // ---- golden replay path: direct runtime, batch-1 ----
    let mut rng = Rng::new(7);
    let images: Vec<Vec<f32>> =
        (0..n).map(|_| (0..IMG).map(|_| rng.f32()).collect()).collect();
    eprintln!("golden replay of {} images on the raw runtime...", n.min(16));
    let mut rt = Runtime::open(&artifacts)?;
    let golden: Vec<Vec<f32>> = images
        .iter()
        .take(16)
        .map(|im| {
            let outs = rt.execute("convnet5_b1", &[HostTensor::F32(im.clone())]).unwrap();
            outs[0].as_f32().to_vec()
        })
        .collect();
    drop(rt);

    // ---- serve the same stream through the coordinator ----
    let coord = Coordinator::start(Config {
        artifacts_dir: artifacts.into(),
        design,
        act_sparsity: 0.5,
        max_wait: Duration::from_millis(1),
        // this driver is the golden-replay comparison, so it pins the
        // legacy XLA functional path explicitly
        use_xla: true,
        ..Config::default()
    })?;
    let h = coord.handle();

    eprintln!("serving {n} requests (bursty open-loop arrivals)...");
    let t0 = Instant::now();
    let mut pending = Vec::new();
    let mut arrival = Rng::new(99);
    for (i, im) in images.iter().enumerate() {
        pending.push(h.submit(i as u64, im.clone())?);
        // bursty arrivals: occasionally pause so the batcher sees both
        // full-batch and timeout-flush regimes
        if arrival.coin(0.1) {
            std::thread::sleep(Duration::from_micros(500));
        }
    }
    let responses: Vec<_> = pending.into_iter().map(|rx| rx.recv().unwrap()).collect();
    let wall = t0.elapsed();

    // ---- functional check: served logits == golden replay ----
    let mut checked = 0;
    for (i, g) in golden.iter().enumerate() {
        let r = &responses[i];
        assert_eq!(r.id, i as u64);
        for (a, b) in r.logits.iter().zip(g) {
            assert!((a - b).abs() < 1e-4, "req {i}: served {a} vs golden {b}");
        }
        checked += 1;
    }
    println!("functional: {checked}/{checked} served responses match the golden replay exactly");

    // ---- serving metrics ----
    let m = coord.metrics();
    let classes: Vec<usize> = responses.iter().map(|r| argmax(&r.logits)).collect();
    let distinct = {
        let mut c = classes.clone();
        c.sort_unstable();
        c.dedup();
        c.len()
    };
    println!(
        "served {n} requests in {wall:.2?} → {:.0} req/s, {distinct} distinct predicted classes",
        n as f64 / wall.as_secs_f64()
    );
    println!("batching: {}", m.summary());
    println!(
        "latency percentiles ({} of {} samples held in the reservoir): \
         p50={}µs p95={}µs p99={}µs",
        m.latency_us.samples().len(),
        m.latency_us.seen(),
        m.latency_pct(50.0),
        m.latency_pct(95.0),
        m.latency_pct(99.0),
    );

    // ---- the hardware twin's verdict (the paper's metric) ----
    let f = design.tech.freq_hz();
    println!(
        "hardware twin {}: {:.2} effective TOPS, {:.3} W avg → {:.1} effective TOPS/W \
         on served traffic",
        design.label(),
        m.sim_effective_tops(f),
        m.sim_avg_power_w(f),
        m.sim_effective_tops(f) / m.sim_avg_power_w(f).max(1e-12),
    );
    println!(
        "twin totals: {} cycles ({:.3} ms at {:.1} GHz), {:.2} mJ",
        m.sim_cycles,
        m.sim_cycles as f64 / f * 1e3,
        f / 1e9,
        m.sim_energy_mj
    );
    coord.shutdown()?;
    Ok(())
}
