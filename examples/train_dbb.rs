//! Train-to-silicon walk-through (paper §V-A, Table I): train LeNet-5 on
//! the synthetic dataset with the full three-phase recipe — FP32 baseline,
//! progressive DBB-aware magnitude pruning, INT8 fine-tuning — then export
//! the compressed weights and report what the accelerator would do with
//! them.
//!
//! ```sh
//! cargo run --release --example train_dbb -- [--nnz 2 --bz 8 --quick]
//! ```

use ssta::arch::Design;
use ssta::cli::Args;
use ssta::dbb::analyze;
use ssta::power;
use ssta::sim::accel::{layer_timing, LayerProfile};
use ssta::sim::analytic::WeightStats;
use ssta::sim::mcu::McuComplex;
use ssta::train::{self, data, quant, zoo, TrainConfig};
use ssta::util::Rng;

fn main() {
    let args = Args::from_env();
    let bz = args.opt_as::<usize>("bz", 8);
    let nnz = args.opt_as::<usize>("nnz", 2);
    let quick = args.flag("quick");

    let cfg = if quick {
        TrainConfig {
            baseline_epochs: 2,
            prune_epochs: 2,
            finetune_epochs: 1,
            ..TrainConfig::default()
        }
    } else {
        TrainConfig {
            baseline_epochs: 6,
            prune_epochs: 6,
            finetune_epochs: 3,
            ..TrainConfig::default()
        }
    };
    let (n_tr, n_te) = if quick { (600, 200) } else { (2400, 600) };
    let (tr, te) = data::synth_mnist_split(n_tr, n_te, 10);

    eprintln!("phase 1–3: training LeNet-5 with DBB {nnz}/{bz} (quick={quick})...");
    // run the phases manually so we keep the trained model for export
    let mut model = zoo::lenet5(&mut Rng::new(1));
    let mut rng = Rng::new(cfg.seed);
    for e in 0..cfg.baseline_epochs {
        let loss = train::train_epoch(&mut model.net, &tr, &cfg, &mut rng, None);
        eprintln!("  baseline epoch {e}: loss {loss:.4}");
    }
    let baseline_acc = train::evaluate(&mut model.net, &te);

    let mut sched = ssta::train::pruning::DbbPruneSchedule::new(bz, nnz, cfg.prune_epochs);
    for e in 0..cfg.prune_epochs {
        sched.prune_epoch(&mut model.net, &model.prunable, e);
        let loss = train::train_epoch(&mut model.net, &tr, &cfg, &mut rng, Some(&sched));
        eprintln!("  prune epoch {e}: bound {}/{bz}, loss {loss:.4}", sched.nnz_at(e));
    }
    sched.prune_epoch(&mut model.net, &model.prunable, cfg.prune_epochs);

    let mut ft = cfg.clone();
    ft.lr *= 0.2;
    for e in 0..cfg.finetune_epochs {
        quant::quantize_network(&mut model.net);
        sched.enforce(&mut model.net);
        let loss = train::train_epoch(&mut model.net, &tr, &ft, &mut rng, Some(&sched));
        eprintln!("  int8 finetune epoch {e}: loss {loss:.4}");
    }
    quant::quantize_network(&mut model.net);
    sched.enforce(&mut model.net);
    let final_acc = train::evaluate(&mut model.net, &te);

    println!("\nTable-I row (measured):");
    println!(
        "  LeNet-5  synth-MNIST  baseline {:.1}%  DBB+INT8 {:.1}%  sparsity {:.1}% ({nnz}/{bz})",
        100.0 * baseline_acc,
        100.0 * final_acc,
        100.0 * sched.sparsity(&mut model.net, &model.prunable),
    );

    // ---- export + accelerator verdict per layer ----
    println!("\nexported layers on {}:", Design::paper_optimal().label());
    println!(
        "  {:<8} {:>10} {:>8} {:>12} {:>10} {:>8}",
        "layer", "K x N", "nnz/blk", "compression", "cycles", "TOPS/W"
    );
    let design = Design::paper_optimal();
    let mcu = McuComplex::for_tops(design.peak_effective_tops());
    let prunable = model.prunable.clone();
    for ((name, w), p) in model.net.gemm_weights().into_iter().zip(prunable) {
        let (dbb, _) = quant::export_dbb(w, bz);
        let s = analyze::summarize(&dbb);
        let profile = LayerProfile {
            name: name.clone(),
            m: 64, // a served batch of 64 rows
            weights: WeightStats::of(&dbb),
            format: ssta::gemm::WeightFormat::Dbb,
            act_sparsity: 0.5,
            act_encoded: false,
            im2col_magnification: 1.0,
            raw_act_bytes: (64 * dbb.k) as u64,
            out_elems: (64 * dbb.n) as u64,
            relu: true,
            fused_epilogue: false,
        };
        let t = layer_timing(&design, &profile, &mcu);
        let tw = power::effective_tops_per_w(&design, &t.events, t.dense_macs);
        println!(
            "  {:<8} {:>4}x{:<5} {:>5}/{:<2} {:>11.2}x {:>10} {:>8.1}{}",
            name,
            dbb.k,
            dbb.n,
            dbb.max_block_nnz(),
            bz,
            s.compression,
            t.events.cycles,
            tw,
            if p { "" } else { "  (dense)" }
        );
    }
    println!("\n(the hardware streams each layer at its own bound — variable DBB, §III-B)");
}
