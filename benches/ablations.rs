//! Ablation studies for the design choices DESIGN.md calls out
//! (`cargo bench --bench ablations`, filter e.g. `-- im2col`).
//!
//! * `schedule`   — pipelined back-to-back passes vs isolated passes
//! * `im2col`     — the hardware unit's net effect per network (3×3-heavy
//!                  VGG vs 1×1-heavy ResNet/MobileNet)
//! * `act_cg`     — activation clock gating on/off across act sparsity
//! * `acc_reuse`  — wide-DP accumulator sharing vs single-MAC VDBB
//! * `batch`      — coordinator twin: occupancy vs batch size
//! * `vnnz`       — per-layer variable bounds vs the uniform model-wide
//!                  bound at equal global density (paper §II-D extension)

use ssta::arch::Design;
use ssta::dbb::variable::{allocate, allocate_uniform, LayerInfo};
use ssta::models;
use ssta::power;
use ssta::sim::accel::{network_timing, profile_model_fixed_act, LayerProfile};
use ssta::sim::analytic::{cycles_per_pass, gemm_cycles, WeightStats};
use ssta::tensor::TensorF32;
use ssta::util::bench::BenchSet;
use ssta::util::table::Table;
use ssta::util::Rng;

fn schedule_ablation() {
    let d = Design::paper_optimal();
    let mut t = Table::new("ablation: pipelined vs isolated tile passes (VDBB, 3/8)");
    t.header(&["GEMM (MxKxN)", "passes", "isolated cycles", "pipelined cycles", "speedup"]);
    for (m, k, n) in [(3136usize, 576usize, 64usize), (784, 1152, 128), (49, 4608, 512)] {
        let stats = WeightStats::synthetic(k, n, 8, 3);
        let tile_rows = d.dims.a * d.dims.m;
        let tile_cols = d.dims.c * d.dims.n;
        let passes = (m.div_ceil(tile_rows) * n.div_ceil(tile_cols)) as u64;
        let isolated = passes * cycles_per_pass(&d, &stats);
        let pipelined = gemm_cycles(&d, &stats, passes);
        t.row(&[
            format!("{m}x{k}x{n}"),
            passes.to_string(),
            isolated.to_string(),
            pipelined.to_string(),
            format!("{:.2}x", isolated as f64 / pipelined as f64),
        ]);
    }
    println!("{}", t.render());
}

fn im2col_ablation() {
    let mut t = Table::new("ablation: IM2COL unit net power effect per network (3/8 DBB, 50% act)");
    t.header(&["Network", "ASRAM mW (no unit)", "ASRAM mW (unit)", "unit mW", "net total Δ mW"]);
    for model in [models::vgg16(), models::resnet50(), models::mobilenet_v1()] {
        let profiles = profile_model_fixed_act(&model, 3, 8, 0.5);
        let mut with = Design::paper_optimal();
        with.im2col = true;
        let mut without = with;
        without.im2col = false;
        let tw = network_timing(&with, &profiles);
        let to = network_timing(&without, &profiles);
        let pw = power::power(&with, &tw.total);
        let po = power::power(&without, &to.total);
        t.row(&[
            model.name.to_string(),
            format!("{:.1}", po.asram_mw),
            format!("{:.1}", pw.asram_mw),
            format!("{:.1}", pw.im2col_mw),
            format!("{:+.1}", pw.total_mw() - po.total_mw()),
        ]);
    }
    println!("{}", t.render());
    println!("(3×3-heavy VGG benefits most; pointwise-heavy nets see little — §IV-C)");
}

fn act_cg_ablation() {
    let mut t = Table::new("ablation: activation clock gating (VDBB optimal, ResNet-50 3/8)");
    t.header(&["act sparsity %", "power mW (CG)", "power mW (no CG)", "saving %"]);
    let m = models::resnet50();
    for act in [0.0, 0.25, 0.5, 0.8] {
        let profiles = profile_model_fixed_act(&m, 3, 8, act);
        let d = Design::paper_optimal();
        let mut d_no = d;
        d_no.act_cg = false;
        let timing = network_timing(&d, &profiles);
        let p_cg = power::power(&d, &timing.total).total_mw();
        let p_no = power::power(&d_no, &timing.total).total_mw();
        t.row(&[
            format!("{:.0}", act * 100.0),
            format!("{p_cg:.1}"),
            format!("{p_no:.1}"),
            format!("{:.1}", 100.0 * (1.0 - p_cg / p_no)),
        ]);
    }
    println!("{}", t.render());
}

fn acc_reuse_ablation() {
    // Table III's trade: wide DPs amortize accumulators but cannot gate or
    // run variable bounds. Compare iso-MAC dense STA vs VDBB on the same
    // sparse workload.
    let mut t =
        Table::new("ablation: accumulator reuse vs VDBB flexibility (2048 MACs, ResNet-50)");
    t.header(&["design", "ACC regs", "cycles (3/8+50%act)", "power mW", "TOPS/W"]);
    let m = models::resnet50();
    let profiles = profile_model_fixed_act(&m, 3, 8, 0.5);
    for spec in ["4x8x4_4x4", "4x8x4_4x8_DBB4of8", "4x8x8_8x8_VDBB"] {
        let d = Design::parse(spec).unwrap();
        let timing = network_timing(&d, &profiles);
        let p = power::power(&d, &timing.total);
        t.row(&[
            spec.to_string(),
            d.acc_regs().to_string(),
            timing.total.cycles.to_string(),
            format!("{:.1}", p.total_mw()),
            format!("{:.1}", power::effective_tops_per_w(&d, &timing.total, timing.dense_macs)),
        ]);
    }
    println!("{}", t.render());
}

fn batch_ablation() {
    let mut t = Table::new("ablation: batch folding on the serving twin (ConvNet-5, 4/8)");
    t.header(&["batch", "cycles", "cycles/img", "eff TOPS", "energy/img mJ"]);
    let model = models::convnet5();
    let d = Design::paper_optimal();
    for batch in [1usize, 2, 4, 8, 16] {
        let profiles: Vec<LayerProfile> = profile_model_fixed_act(&model, 4, 8, 0.5)
            .into_iter()
            .map(|mut p| {
                p.m *= batch;
                p.out_elems *= batch as u64;
                p
            })
            .collect();
        let timing = network_timing(&d, &profiles);
        let p = power::power(&d, &timing.total);
        let secs = timing.total.cycles as f64 / d.tech.freq_hz();
        t.row(&[
            batch.to_string(),
            timing.total.cycles.to_string(),
            format!("{:.0}", timing.total.cycles as f64 / batch as f64),
            format!("{:.2}", timing.effective_tops(&d)),
            format!("{:.4}", p.total_mw() * secs / batch as f64),
        ]);
    }
    println!("{}", t.render());
    println!("(batch folds into GEMM M: partial-tile waste amortizes away)");
}

fn vnnz_ablation() {
    // per-layer variable bounds (the §II-D extension): measure retained
    // magnitude energy and effective throughput vs the uniform bound
    let mut rng = Rng::new(77);
    let model = models::convnet5();
    // synthesize heterogeneous "trained" weights: later layers sparser
    let infos: Vec<LayerInfo> = model
        .layers
        .iter()
        .enumerate()
        .map(|(i, l)| {
            let (_, k, n) = l.gemm_dims();
            let mut w = TensorF32::randn(&[k, n], 1.0, &mut rng);
            let concentration = 1.0 / (1.0 + i as f32); // later layers peakier
            for (j, v) in w.data_mut().iter_mut().enumerate() {
                if (j / n.max(1)) % 4 != 0 {
                    *v *= concentration;
                }
            }
            LayerInfo::measure(&l.name, &w, 8, l.prunable)
        })
        .collect();

    let mut t =
        Table::new("ablation: per-layer variable NNZ vs uniform (ConvNet-5, equal density)");
    t.header(&[
        "target density",
        "uniform bounds",
        "uniform retained",
        "variable bounds",
        "variable retained",
    ]);
    for target in [0.5f64, 0.375, 0.25] {
        let uni = allocate_uniform(&infos, 8, target);
        let var = allocate(&infos, 8, target);
        t.row(&[
            format!("{target:.3}"),
            format!("{:?}", uni.bounds),
            format!("{:.4}", uni.retained),
            format!("{:?}", var.bounds),
            format!("{:.4}", var.retained),
        ]);
    }
    println!("{}", t.render());
    println!("(VDBB hardware runs any per-layer bound at full utilization — §III-B)");
}

fn main() {
    let mut set = BenchSet::new("ablations");
    set.report("schedule", schedule_ablation);
    set.report("im2col", im2col_ablation);
    set.report("act_cg", act_cg_ablation);
    set.report("acc_reuse", acc_reuse_ablation);
    set.report("batch", batch_ablation);
    set.report("vnnz", vnnz_ablation);
    set.run();
}
