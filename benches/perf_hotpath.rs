//! Hot-path microbenchmarks for the §Perf optimization pass
//! (`cargo bench --bench perf_hotpath`).
//!
//! L3 targets (EXPERIMENTS.md §Perf): the analytic engine is the hot path —
//! a full-design-space Fig-10 sweep must stay interactive; the XLA execute
//! path dominates e2e request latency, with coordinator overhead < 5%.

use ssta::arch::{space, Design, Tech};
use ssta::dbb::{prune::prune_bsr_i8, prune::prune_i8, DbbMatrix};
use ssta::gemm::conv::{im2col, ConvShape};
use ssta::gemm::{ActDbb, ActPolicy, BsrPacked, Epilogue, Requant, WeightFormat, ZeroGate};
use ssta::models;
use ssta::sim::accel::{network_timing, profile_model_fixed_act, profile_model_repr};
use ssta::sim::analytic::{gemm_timing_stats, WeightStats};
use ssta::sim::detailed::simulate_gemm;
use ssta::tensor::TensorI8;
use ssta::util::bench::{bb, BenchSet};
use ssta::util::{Parallelism, Rng};

fn main() {
    let mut set = BenchSet::new("perf_hotpath");

    // ---- L3: analytic engine (the design-space hot path) ----
    let d = Design::paper_optimal();
    let stats = WeightStats::synthetic(2304, 512, 8, 3);
    set.bench("analytic/gemm_timing_stats", move || {
        bb(gemm_timing_stats(&d, 3136, &stats, 0.5, 3.0));
    });

    let d2 = Design::paper_optimal();
    let resnet = models::resnet50();
    let profiles = profile_model_fixed_act(&resnet, 3, 8, 0.5);
    set.bench("analytic/resnet50_network_timing", move || {
        bb(network_timing(&d2, &profiles));
    });

    set.bench("analytic/full_fig10_sweep", || {
        let designs = space::enumerate(space::MACS_4TOPS, Tech::N16);
        let m = models::resnet50();
        let profiles = profile_model_repr(&m, 3, 8, 0.5);
        for d in &designs {
            bb(network_timing(d, &profiles));
        }
    });

    set.bench("analytic/full_fig10_sweep_par", || {
        let designs = space::enumerate(space::MACS_4TOPS, Tech::N16);
        let m = models::resnet50();
        let profiles = profile_model_repr(&m, 3, 8, 0.5);
        bb(space::sweep(&designs, Parallelism::auto(), |d| {
            network_timing(d, &profiles)
        }));
    });

    // ---- model profiling (sampled functional inference) ----
    set.bench("profile/resnet50_measured_act", || {
        let m = models::resnet50();
        bb(ssta::sim::accel::profile_model(&m, 3, 8, 42));
    });

    // ---- prepared-model engine: encode once, execute many (§II-A) ----
    // Amortization triplet on the served convnet5: `prepare` is the
    // first-call price (synthesize + top-k encode + CSC-pack every layer),
    // `execute_prepared` is the steady-state price (zero encode/decode,
    // scratch reused), and `profile_unprepared` is what every call paid
    // before this engine existed (prepare + execute, per call).
    {
        let m = models::convnet5();
        set.bench("engine/convnet5_prepare_first_call", move || {
            bb(ssta::engine::PreparedModel::prepare(&m, 3, 8, 42, Parallelism::auto()));
        });

        let m2 = models::convnet5();
        let prepared = ssta::engine::PreparedModel::prepare(&m2, 3, 8, 42, Parallelism::auto());
        let input = prepared.seed_input().clone();
        set.bench("engine/convnet5_execute_prepared_steady", move || {
            bb(prepared.execute(&input, Parallelism::auto()));
        });

        let m3 = models::convnet5();
        set.bench("engine/convnet5_profile_unprepared", move || {
            bb(ssta::sim::accel::profile_model(&m3, 3, 8, 42));
        });

        // steady-state execute on the BSR weight datapath: same model,
        // seed, and encoding point as the prepared-steady entry, but the
        // prunable layers stream block-scheduler kernels over
        // row_ptr/col_idx operands instead of DBB CSC
        let mb = models::convnet5();
        let bsr_prepared = ssta::engine::PreparedModel::prepare_format(
            &mb,
            3,
            8,
            42,
            Parallelism::auto(),
            WeightFormat::Bsr,
        );
        let binput = bsr_prepared.seed_input().clone();
        set.bench("engine/convnet5_execute_bsr", move || {
            bb(bsr_prepared.execute(&binput, Parallelism::auto()));
        });

        // steady-state execute on a pinned pool: each conv worker pins to a
        // core so its PatchScratch arena stays cache-hot across calls, and
        // every inner loop runs the SIMD microkernels (default dispatch) —
        // the fully-optimized serving configuration the gate must hold
        let m6 = models::convnet5();
        let pinned = Parallelism::auto().with_pin(true);
        let simd_prepared = ssta::engine::PreparedModel::prepare(&m6, 3, 8, 42, pinned);
        let sinput = simd_prepared.seed_input().clone();
        set.bench("engine/convnet5_execute_simd", move || {
            bb(simd_prepared.execute(&sinput, pinned));
        });

        // steady-state execute with the activation zero-gate on Auto: the
        // profile ran once, so Auto consults the measured per-layer act
        // sparsities (the same values the hardware twin prices) and gates
        // only the layers where skipping pays
        let m4 = models::convnet5();
        let mut gated = ssta::engine::PreparedModel::prepare(&m4, 3, 8, 42, Parallelism::auto());
        gated.profile(Parallelism::auto());
        let ginput = gated.seed_input().clone();
        let probe = gated.execute_gated(&ginput, Parallelism::auto(), ZeroGate::Auto);
        set.report("engine/convnet5_gate_decisions", move || {
            let layers: Vec<String> = probe
                .act_sparsity
                .iter()
                .zip(&probe.gate_engaged)
                .map(|(s, g)| format!("{:.0}%{}", 100.0 * s, if *g { "(gated)" } else { "" }))
                .collect();
            println!(
                "convnet5 execute_gated(Auto): per-layer act sparsity = skipped-MAC \
                 fraction on gated layers: {}",
                layers.join(" ")
            );
        });
        set.bench("engine/convnet5_execute_gated", move || {
            bb(gated.execute_gated(&ginput, Parallelism::auto(), ZeroGate::Auto));
        });

        // steady-state execute with the activation operand DBB-*encoded*
        // everywhere (ActPolicy::Encode): the joint A-DBB kernels consume a
        // compressed stream on both sides of the MAC — compare against
        // execute_prepared_steady (Off) and execute_gated (Gate) for the
        // three tiers of the policy ladder
        let m5 = models::convnet5();
        let mut encm = ssta::engine::PreparedModel::prepare(&m5, 3, 8, 42, Parallelism::auto());
        encm.profile(Parallelism::auto());
        let einput = encm.seed_input().clone();
        set.bench("engine/convnet5_execute_encoded", move || {
            bb(encm.execute_policy(&einput, Parallelism::auto(), ActPolicy::Encode));
        });

        // steady-state execute with the layer epilogue (requant + ReLU)
        // fused into each GEMM's output walk: layers chain i8→i8 through
        // the scratch arena's ping-pong pool and no whole-layer i32
        // accumulator tensor is ever allocated — compare against
        // execute_prepared_steady, the staged i32 → requant chain
        let m7 = models::convnet5();
        let mut fusedm = ssta::engine::PreparedModel::prepare(&m7, 3, 8, 42, Parallelism::auto());
        fusedm.calibrate(Parallelism::auto());
        let finput = fusedm.seed_input().clone();
        let i32_bytes: u64 = fusedm
            .layers()
            .iter()
            .map(|l| {
                let rows = match l.sample {
                    ssta::engine::SampleShape::Conv(ss) => ss.oh() * ss.ow(),
                    ssta::engine::SampleShape::Fc { m, .. } => m,
                };
                let cols = match &l.operand {
                    ssta::engine::PackedOperand::Dbb(p) => p.n,
                    ssta::engine::PackedOperand::Dense(w) => w.shape()[1],
                };
                (rows * cols * 4) as u64
            })
            .sum();
        set.report("engine/convnet5_i32_traffic_eliminated", move || {
            println!(
                "convnet5 fused epilogue: {i32_bytes} B of whole-layer i32 \
                 accumulator tensors per execute (written then re-read by the \
                 staged requant pass) never materialize — every worker \
                 requantizes its freshly computed rows to i8 while cache-hot"
            );
        });
        set.bench("engine/convnet5_execute_fused_epilogue", move || {
            bb(fusedm.execute_fused(&finput, Parallelism::auto()));
        });

        // zoo scenarios beyond convnet5, both on the fused serving path:
        // MobileNetV1 runs the depthwise/pointwise ladder (dense-fallback
        // dw sampled at K = kh·kw, stride-2 included) and the transformer
        // block is the FC-only member (per-token M=1 GEMMs, no conv sample
        // at all) — the two geometries examples/scenario_sweep gates
        let mob = models::mobilenet_v1();
        let mut mobm = ssta::engine::PreparedModel::prepare(&mob, 4, 8, 42, Parallelism::auto());
        mobm.calibrate(Parallelism::auto());
        let mobin = mobm.seed_input().clone();
        set.bench("engine/mobilenet_v1_execute_fused", move || {
            bb(mobm.execute_fused(&mobin, Parallelism::auto()));
        });

        let tfb = models::transformer_block();
        let mut tfbm = ssta::engine::PreparedModel::prepare(&tfb, 4, 8, 42, Parallelism::auto());
        tfbm.calibrate(Parallelism::auto());
        let tfbin = tfbm.seed_input().clone();
        set.bench("engine/transformer_block_execute_fused", move || {
            bb(tfbm.execute_fused(&tfbin, Parallelism::auto()));
        });
    }

    // ---- serving substrate: flat-binary load + coordinator round trip ----
    // `load_persisted` is the restart fast path: parse + revalidate the
    // persisted flat binary, *no* synthesize/encode/calibrate — compare
    // against engine/convnet5_prepare_first_call for what a restart skips.
    // `engine_serve_steady_p99` is one steady-state request round trip
    // through the engine-native coordinator (submit → batch-1 flush → fused
    // execute → twin → reply), the latency an SLO p99 is built from.
    {
        let m8 = models::convnet5();
        let mut persisted =
            ssta::engine::PreparedModel::prepare(&m8, 3, 8, 42, Parallelism::auto());
        persisted.profile(Parallelism::auto());
        persisted.calibrate(Parallelism::auto());
        let path = std::env::temp_dir()
            .join(format!("ssta-bench-persist-{}.ssta", std::process::id()));
        persisted.save(&path).expect("persisting prepared model");
        set.bench("engine/convnet5_load_persisted", move || {
            bb(ssta::engine::PreparedModel::load(&path, Parallelism::auto()).expect("load"));
        });

        use ssta::coordinator::{Config, Coordinator};
        let coord = Coordinator::start(Config {
            batch_sizes: vec![1],
            max_wait: std::time::Duration::from_micros(100),
            ..Config::default()
        })
        .expect("engine-native coordinator");
        let h = coord.handle();
        let mut rng = Rng::new(21);
        let img: Vec<f32> = (0..32 * 32 * 3).map(|_| rng.f32()).collect();
        for i in 0..32 {
            h.infer(i, img.clone()).expect("warmup request");
        }
        set.bench("coordinator/engine_serve_steady_p99", move || {
            let _keepalive = &coord;
            bb(h.infer(0, img.clone()).expect("serve"));
        });
    }

    // ---- detailed engine (ground truth; used at small scale) ----
    {
        let mut rng = Rng::new(1);
        let d = Design::parse("2x8x4_2x2_VDBB").unwrap();
        let a = TensorI8::rand_sparse(&[64, 128], 0.5, &mut rng);
        let w = DbbMatrix::compress_with_bound(
            &prune_i8(&TensorI8::rand(&[128, 32], &mut rng), 8, 3),
            8,
            3,
        )
        .unwrap();
        set.bench("detailed/simulate_gemm_64x128x32", move || {
            bb(simulate_gemm(&d, &a, &w, 1.0));
        });
    }

    // ---- golden GEMMs (functional reference path) ----
    {
        let mut rng = Rng::new(2);
        let a = TensorI8::rand_sparse(&[256, 512], 0.5, &mut rng);
        let wd = prune_i8(&TensorI8::rand(&[512, 128], &mut rng), 8, 3);
        let w = DbbMatrix::compress_with_bound(&wd, 8, 3).unwrap();
        let a2 = a.clone();
        set.bench("gemm/dense_i8_256x512x128", move || {
            bb(ssta::gemm::dense_i8(&a, &wd));
        });
        set.bench("gemm/dbb_i8_256x512x128", move || {
            bb(ssta::gemm::dbb_i8(&a2, &w));
        });
    }

    // ---- tiled parallel GEMM engine (the §tentpole hot path) ----
    // Acceptance target: the tiled 512³ dense GEMM shows ≥ 2x over the
    // serial oracle on a ≥ 4-core host (compare the two entries below).
    {
        let mut rng = Rng::new(6);
        let a = TensorI8::rand(&[512, 512], &mut rng);
        let w = TensorI8::rand(&[512, 512], &mut rng);
        let (a2, w2) = (a.clone(), w.clone());
        set.bench("gemm/dense_i8_512x512x512_serial", move || {
            bb(ssta::gemm::dense_i8(&a, &w));
        });
        set.bench("gemm/dense_i8_512x512x512_tiled_auto", move || {
            bb(ssta::gemm::tiled::dense_i8(&a2, &w2, Parallelism::auto()));
        });

        let mut rng = Rng::new(7);
        let a = TensorI8::rand_sparse(&[512, 512], 0.5, &mut rng);
        let wd = prune_i8(&TensorI8::rand(&[512, 512], &mut rng), 8, 3);
        let w = DbbMatrix::compress_with_bound(&wd, 8, 3).unwrap();
        let (a2, w2) = (a.clone(), w.clone());
        set.bench("gemm/dbb_i8_512x512x512_serial", move || {
            bb(ssta::gemm::dbb_i8(&a, &w));
        });
        set.bench("gemm/dbb_i8_512x512x512_tiled_auto", move || {
            bb(ssta::gemm::tiled::dbb_i8(&a2, &w2, Parallelism::auto()));
        });

        // fused output epilogue: same 512³ dense GEMM, but each worker
        // requantizes (+ ReLU) its accumulator rows to i8 while cache-hot —
        // the 1 MiB i32 C matrix is never allocated. Compare against
        // dense_i8_512x512x512_tiled_auto (materialize-then-requant)
        let mut rng = Rng::new(6);
        let ae = TensorI8::rand(&[512, 512], &mut rng);
        let we = TensorI8::rand(&[512, 512], &mut rng);
        let ep = Epilogue::new(Requant::Global(7), true);
        set.bench("gemm/dense_i8_512_epilogue", move || {
            bb(ssta::gemm::tiled::dense_i8_ep(&ae, &we, Parallelism::auto(), ZeroGate::Off, &ep));
        });

        // packed operand: the per-call CSC decode amortized away
        let mut rng = Rng::new(7);
        let a3 = TensorI8::rand_sparse(&[512, 512], 0.5, &mut rng);
        let wd3 = prune_i8(&TensorI8::rand(&[512, 512], &mut rng), 8, 3);
        let packed = DbbMatrix::compress_with_bound(&wd3, 8, 3).unwrap().pack();
        set.bench("gemm/dbb_i8_512x512x512_packed_auto", move || {
            bb(ssta::gemm::tiled::dbb_i8_packed(&a3, &packed, Parallelism::auto()));
        });
    }

    // ---- activation zero-gating (A-side zero-skip, paper §II) ----
    // The gated kernels are bit-exact with the ungated entries above; what
    // the gate buys is the skipped-MAC fraction, reported alongside the
    // timings. 50% is the paper's typical ReLU operating point, 87.5% its
    // high-sparsity regime (Fig. 12's sweep territory).
    {
        let mut rng = Rng::new(11);
        let a50 = TensorI8::rand_sparse(&[512, 512], 0.5, &mut rng);
        let a87 = TensorI8::rand_sparse(&[512, 512], 0.875, &mut rng);
        let w = TensorI8::rand(&[512, 512], &mut rng);
        let wd = prune_i8(&TensorI8::rand(&[512, 512], &mut rng), 8, 3);
        let packed = DbbMatrix::compress_with_bound(&wd, 8, 3).unwrap().pack();

        // dense gated entries skip exactly A's zero fraction of the MACs;
        // the DBB entries skip the zero-activation share of the *stored*
        // entries (dbb_gate_stats counts them exactly)
        let (s50, s87) = (a50.sparsity(), a87.sparsity());
        let (skip50, tot50) = ssta::gemm::dbb_gate_stats(&a50, &packed);
        let (skip87, tot87) = ssta::gemm::dbb_gate_stats(&a87, &packed);
        set.report("gemm/gated_skip_fractions", move || {
            println!(
                "512³ gated entries, skipped-MAC fractions: dense 50pct {s50:.3}, \
                 dense 87pct {s87:.3}; dbb 3/8 50pct {:.3} ({skip50}/{tot50}), \
                 dbb 3/8 87pct {:.3} ({skip87}/{tot87})",
                skip50 as f64 / tot50 as f64,
                skip87 as f64 / tot87 as f64,
            );
        });

        let (w2, a50b) = (w.clone(), a50.clone());
        set.bench("gemm/dense_i8_512_gated_50pct", move || {
            bb(ssta::gemm::tiled::dense_i8_gated(&a50b, &w2, Parallelism::auto(), ZeroGate::On));
        });
        let (w3, a87b) = (w.clone(), a87.clone());
        set.bench("gemm/dense_i8_512_gated_87pct", move || {
            bb(ssta::gemm::tiled::dense_i8_gated(&a87b, &w3, Parallelism::auto(), ZeroGate::On));
        });
        let packed2 = packed.clone();
        set.bench("gemm/dbb_i8_512_gated_50pct", move || {
            bb(ssta::gemm::tiled::dbb_i8_packed_gated(
                &a50,
                &packed2,
                Parallelism::auto(),
                ZeroGate::On,
            ));
        });
        set.bench("gemm/dbb_i8_512_gated_87pct", move || {
            bb(ssta::gemm::tiled::dbb_i8_packed_gated(
                &a87,
                &packed,
                Parallelism::auto(),
                ZeroGate::On,
            ));
        });
    }

    // ---- BSR block-scheduler kernels (the second weight datapath) ----
    // Same 512-cubed shape and activation sparsities as the DBB gated
    // entries, weight blocks pruned at the matched 3/8 density (24 of the
    // 64 blocks of every block row survive); the stream pays coarse
    // row_ptr/col_idx indices instead of per-element bitmasks
    {
        let mut rng = Rng::new(9);
        let a50 = TensorI8::rand_sparse(&[512, 512], 0.5, &mut rng);
        let a87 = TensorI8::rand_sparse(&[512, 512], 0.875, &mut rng);
        let wd = prune_bsr_i8(&TensorI8::rand(&[512, 512], &mut rng), 8, 8, 24);
        let p = BsrPacked::pack(&wd, 8, 8);
        let p2 = p.clone();
        set.bench("gemm/bsr_i8_512_50pct", move || {
            bb(ssta::gemm::tiled::bsr_i8_packed_gated(
                &a50,
                &p,
                Parallelism::auto(),
                ZeroGate::On,
            ));
        });
        set.bench("gemm/bsr_i8_512_87pct", move || {
            bb(ssta::gemm::tiled::bsr_i8_packed_gated(
                &a87,
                &p2,
                Parallelism::auto(),
                ZeroGate::On,
            ));
        });
    }

    // ---- activation-side DBB encoding (A-DBB, S2TA joint sparsity) ----
    // The joint kernels consume an encoded A against the packed 3/8 weight
    // stream: only (non-zero activation, stored weight) pairs reach the
    // multiplier. The encode entry prices the runtime O(M·K) encode pass
    // itself — what ActPolicy::Encode pays before the joint kernels run.
    {
        let mut rng = Rng::new(12);
        let a50 = TensorI8::rand_sparse(&[512, 512], 0.5, &mut rng);
        let a87 = TensorI8::rand_sparse(&[512, 512], 0.875, &mut rng);
        let wd = prune_i8(&TensorI8::rand(&[512, 512], &mut rng), 8, 3);
        let packed = DbbMatrix::compress_with_bound(&wd, 8, 3).unwrap().pack();
        let e50 = ActDbb::encode(&a50, 8);
        let e87 = ActDbb::encode(&a87, 8);

        let (s50b, d50) = (e50.stream_bytes(), e50.dense_bytes());
        let (s87b, d87) = (e87.stream_bytes(), e87.dense_bytes());
        set.report("gemm/adbb_stream_bytes", move || {
            println!(
                "512² A-DBB fixed-rate stream: 50pct {s50b} B vs raw {d50} B \
                 ({:.2}x), 87pct {s87b} B vs raw {d87} B ({:.2}x)",
                d50 as f64 / s50b as f64,
                d87 as f64 / s87b as f64,
            );
        });

        set.bench("gemm/act_dbb_encode_512", move || {
            bb(ActDbb::encode(&a50, 8));
        });
        let packed2 = packed.clone();
        set.bench("gemm/adbb_i8_512_50pct", move || {
            bb(ssta::gemm::tiled::adbb_i8_packed(&e50, &packed2, Parallelism::auto()));
        });
        set.bench("gemm/adbb_i8_512_87pct", move || {
            bb(ssta::gemm::tiled::adbb_i8_packed(&e87, &packed, Parallelism::auto()));
        });
    }

    // ---- SIMD microkernel dispatch (gemm::micro) ----
    // The *_simd entries run the default dispatch (the best ISA the host
    // supports) through the *serial* drivers, so the bench gate holds the
    // microkernel speedups themselves, undiluted by the thread pool. The
    // report then forces each available ISA in turn and prints the measured
    // speedup over the scalar oracle — bit-exact by construction, so only
    // the time moves.
    {
        let mut rng = Rng::new(13);
        let a = TensorI8::rand_sparse(&[512, 512], 0.5, &mut rng);
        let a87 = TensorI8::rand_sparse(&[512, 512], 0.875, &mut rng);
        let w = TensorI8::rand(&[512, 512], &mut rng);
        let wd = prune_i8(&TensorI8::rand(&[512, 512], &mut rng), 8, 3);
        let packed = DbbMatrix::compress_with_bound(&wd, 8, 3).unwrap().pack();

        let (ab, wb) = (a.clone(), w.clone());
        set.bench("gemm/dense_i8_512_simd", move || {
            bb(ssta::gemm::dense_i8(&ab, &wb));
        });
        let (ap, pp) = (a.clone(), packed.clone());
        set.bench("gemm/dbb_i8_512_simd_50pct", move || {
            bb(ssta::gemm::dbb_i8_packed(&ap, &pp));
        });
        let pp87 = packed.clone();
        set.bench("gemm/dbb_i8_512_simd_87pct", move || {
            bb(ssta::gemm::dbb_i8_packed(&a87, &pp87));
        });

        set.report("gemm/simd_speedup", move || {
            use ssta::gemm::micro;
            let time = |f: &dyn Fn()| {
                f(); // warmup
                let mut best = f64::INFINITY;
                for _ in 0..3 {
                    let t0 = std::time::Instant::now();
                    f();
                    best = best.min(t0.elapsed().as_secs_f64());
                }
                best
            };
            let mut lines = Vec::new();
            let mut scalar: Option<(f64, f64)> = None;
            for isa in micro::available_isas() {
                micro::force_isa(Some(isa));
                let td = time(&|| {
                    bb(ssta::gemm::dense_i8(&a, &w));
                });
                let tb = time(&|| {
                    bb(ssta::gemm::dbb_i8_packed(&a, &packed));
                });
                let (sd, sb) = *scalar.get_or_insert((td, tb));
                lines.push(format!(
                    "{isa}: dense 512³ {:.2} ms ({:.2}x), dbb 3/8 50pct {:.2} ms ({:.2}x)",
                    td * 1e3,
                    sd / td,
                    tb * 1e3,
                    sb / tb
                ));
            }
            micro::force_isa(None);
            println!("scalar-vs-SIMD (serial drivers, best of 3): {}", lines.join("; "));
        });
    }

    // ---- fused streaming-IM2COL conv vs materialized IM2COL (§IV-C) ----
    // ResNet blk1-class 3×3: 56×56×64 → 56×56×64 (M=3136, K=576, N=64).
    // The materialized entries allocate the full M×K patch matrix per
    // iteration; the fused entries never do (peak operand O(threads·tile·K),
    // see the conv/operand_bytes report).
    {
        let s = ConvShape { h: 56, w: 56, c: 64, kh: 3, kw: 3, oc: 64, stride: 1, pad: 1 };
        let mut rng = Rng::new(8);
        let x = TensorI8::rand_sparse(&[s.h, s.w, s.c], 0.5, &mut rng);
        let w = TensorI8::rand(&[s.gemm_k(), s.oc], &mut rng);
        let (x2, w2) = (x.clone(), w.clone());
        set.bench("conv/3x3_56x56x64_materialized", move || {
            let a = im2col(&x, &s);
            bb(ssta::gemm::tiled::dense_i8(&a, &w, Parallelism::auto()));
        });
        set.bench("conv/3x3_56x56x64_fused", move || {
            bb(ssta::gemm::fused::conv2d_i8(&x2, &w2, &s, Parallelism::auto()));
        });

        let mut rng = Rng::new(9);
        let x = TensorI8::rand_sparse(&[s.h, s.w, s.c], 0.5, &mut rng);
        let wd = prune_i8(&TensorI8::rand(&[s.gemm_k(), s.oc], &mut rng), 8, 3);
        let wc = DbbMatrix::compress_with_bound(&wd, 8, 3).unwrap();
        let (x2, wc2) = (x.clone(), wc.clone());
        set.bench("conv/3x3_56x56x64_dbb_materialized", move || {
            let a = im2col(&x, &s);
            bb(ssta::gemm::tiled::dbb_i8(&a, &wc, Parallelism::auto()));
        });
        set.bench("conv/3x3_56x56x64_dbb_fused", move || {
            bb(ssta::gemm::fused::conv2d_dbb_i8(&x2, &wc2, &s, Parallelism::auto()));
        });

        set.report("conv/operand_bytes", move || {
            let par = Parallelism::auto();
            let materialized = s.gemm_m() * s.gemm_k();
            let fused = ssta::gemm::fused::peak_operand_bytes(&s, par);
            println!(
                "3x3 56x56x64: materialized IM2COL operand {materialized} B \
                 vs fused peak {fused} B ({} workers × {} rows × K={}) — {:.0}x smaller",
                par.get(),
                ssta::gemm::fused::PATCH_ROWS,
                s.gemm_k(),
                materialized as f64 / fused as f64
            );
        });
    }

    // ---- DBB encode/decode ----
    {
        let mut rng = Rng::new(3);
        let wd = prune_i8(&TensorI8::rand(&[1024, 256], &mut rng), 8, 3);
        let enc = DbbMatrix::compress_with_bound(&wd, 8, 3).unwrap();
        set.bench("dbb/compress_1024x256", move || {
            bb(DbbMatrix::compress_with_bound(&wd, 8, 3).unwrap());
        });
        set.bench("dbb/decompress_1024x256", move || {
            bb(enc.decompress());
        });
    }

    // ---- XLA runtime path (only when artifacts exist) ----
    if std::path::Path::new("artifacts/manifest.json").exists() {
        let mut rt = ssta::runtime::Runtime::open("artifacts").expect("runtime");
        let exe = rt.load("dbb_gemm_m128_k256_n64_nnz4of8").expect("artifact");
        let mut rng = Rng::new(4);
        let a: Vec<i8> = (0..128 * 256).map(|_| rng.i8_sym()).collect();
        let vals: Vec<i8> = (0..32 * 4 * 64).map(|_| rng.i8_sym()).collect();
        let idx: Vec<i32> = (0..32 * 4 * 64).map(|_| (rng.below(8)) as i32).collect();
        use ssta::runtime::HostTensor;
        set.bench("xla/dbb_gemm_execute_128x256x64", move || {
            bb(exe
                .run(&[
                    HostTensor::I8(a.clone()),
                    HostTensor::I8(vals.clone()),
                    HostTensor::I32(idx.clone()),
                ])
                .unwrap());
        });
    } else {
        eprintln!("(artifacts not built — skipping XLA execute bench)");
    }

    set.run();
}
