//! Regenerates the paper's Tables I–V (`cargo bench --bench paper_tables`,
//! optionally filtered: `cargo bench --bench paper_tables -- table5`).
//!
//! Tables are emitted as run-once reports (the deliverable is the table),
//! followed by timed micro-entries for the underlying drivers so the bench
//! also tracks harness performance regressions. Training tables run in
//! quick mode under `cargo bench` (full mode: `ssta run table1`).

use ssta::harness;
use ssta::util::bench::BenchSet;

fn report(name: &'static str, quick: bool) -> impl FnMut() {
    move || {
        for t in harness::run(name, quick).expect("known experiment") {
            println!("{}", t.render());
        }
    }
}

fn main() {
    let mut set = BenchSet::new("paper_tables");
    set.report("table1", report("table1", true));
    set.report("table2", report("table2", true));
    set.report("table3", report("table3", false));
    set.report("table4", report("table4", false));
    set.report("table5", report("table5", false));

    // timed drivers (cheap ones only; training tables are report-only)
    set.bench("driver/table3", || {
        ssta::util::bench::bb(harness::run("table3", true));
    });
    set.bench("driver/table4", || {
        ssta::util::bench::bb(harness::run("table4", true));
    });
    set.bench("driver/table5", || {
        ssta::util::bench::bb(harness::run("table5", true));
    });
    set.run();
}
