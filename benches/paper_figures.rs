//! Regenerates the paper's Figures 9–12 as numeric series
//! (`cargo bench --bench paper_figures`, filter with e.g. `-- fig12`).

use ssta::harness;
use ssta::util::bench::BenchSet;

fn report(name: &'static str, quick: bool) -> impl FnMut() {
    move || {
        for t in harness::run(name, quick).expect("known experiment") {
            println!("{}", t.render());
        }
    }
}

fn main() {
    let mut set = BenchSet::new("paper_figures");
    set.report("fig9", report("fig9", false));
    set.report("fig10", report("fig10", false));
    set.report("fig11", report("fig11", false));
    set.report("fig12", report("fig12", false));

    set.bench("driver/fig9", || {
        ssta::util::bench::bb(harness::run("fig9", true));
    });
    set.bench("driver/fig10", || {
        ssta::util::bench::bb(harness::run("fig10", true));
    });
    set.bench("driver/fig12", || {
        ssta::util::bench::bb(harness::run("fig12", true));
    });
    set.run();
}
