#!/usr/bin/env bash
# Mirror of .github/workflows/ci.yml — run this before pushing and you have
# run exactly what the gate runs (same commands, same flags, same order).
#
#   scripts/ci-local.sh            # full gate
#   scripts/ci-local.sh --fast     # skip the release build (biggest step)
set -euo pipefail
cd "$(dirname "$0")/.."

FAST=0
[[ "${1:-}" == "--fast" ]] && FAST=1

step() {
  echo
  echo "==> $*"
  "$@"
}

if [[ "$FAST" -eq 0 ]]; then
  step cargo build --release
fi
step cargo test -q
# runnable rustdoc examples on the public entry points (PreparedModel,
# ModelRegistry, Epilogue, ActDbb) — compiled and executed, so the docs
# cannot drift from the API (mirrors the CI doc job)
step cargo test -q --doc
# kernel matrix: the SIMD microkernels must stay bit-exact with the scalar
# oracle on every forced dispatch path (mirrors the CI kernel-matrix job;
# unsupported ISAs clamp down by rank, so all three legs run everywhere)
for isa in scalar sse2 avx2; do
  step env SSTA_FORCE_ISA="$isa" cargo test -q --test micro_kernels \
    --test epilogue --test tiled_gemm --test fused_conv --test zero_gate \
    --test act_dbb --test bsr
done
step cargo fmt --check
step cargo clippy --all-targets -- -D warnings
step env RUSTDOCFLAGS="-D warnings" cargo doc --no-deps
step cargo bench --no-run
step cargo bench --bench perf_hotpath -- gemm/ conv/ engine/ coordinator/
echo "(bench results recorded in BENCH_perf_hotpath.json)"
step scripts/bench-check.sh
if [[ "$FAST" -eq 0 ]]; then
  # engine-native serving smoke: two models, forced eviction, persistence
  # across a restart — exits non-zero if any of it breaks
  step cargo run --release --example serve_load -- --smoke
  # full-zoo scenario sweep smoke: every zoo member (5 CNNs + transformer
  # block) prepares, persists/reloads, and executes fused == staged
  # bit-exact — exits non-zero otherwise
  step cargo run --release --example scenario_sweep -- --smoke
fi

echo
echo "ci-local: all gates green"
