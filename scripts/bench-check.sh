#!/usr/bin/env bash
# Bench-regression gate: compare a fresh BENCH_perf_hotpath.json (written by
# `cargo bench --bench perf_hotpath -- gemm/ conv/ engine/ coordinator/`,
# see util::bench) against the committed baseline and fail on a >25% median
# regression in any tracked `gemm/`, `conv/`, `engine/` or `coordinator/`
# entry. Prints a per-entry delta
# table either way. A short REQUIRED list (the SIMD microkernel entries)
# must additionally be *present* in the fresh run — so the SIMD speedups
# cannot silently drop out of the gate by a bench rename.
#
#   scripts/bench-check.sh                       # compare ./BENCH_perf_hotpath.json
#   scripts/bench-check.sh fresh.json            # compare an explicit file
#   scripts/bench-check.sh fresh.json base.json  # explicit baseline too
#   scripts/bench-check.sh --rebaseline f.json   # accept f.json as the new baseline
#
# Re-baselining (after an intentional perf change, or to arm the gate):
# download the `bench-perf-hotpath` artifact from a green CI run of the new
# code, then `scripts/bench-check.sh --rebaseline <artifact.json>` and commit
# `benches/baseline/BENCH_perf_hotpath.json`. The gate only *enforces* when
# BOTH hold, and reports-only otherwise:
#   * the baseline's `provenance` is `ci` (recorded from a CI bench
#     artifact — the initial `bootstrap-estimate` baseline never enforces),
#   * AND this run is on the machine class the baseline was recorded on:
#     the `CI` env var is set (GitHub runners) or BENCH_CHECK_ENFORCE=1.
# Both guards exist for the same reason: absolute medians compared across
# machine classes gate on hardware differences, not regressions — so a
# developer laptop running scripts/ci-local.sh gets the delta table and
# warnings, while the GitHub job goes red.
set -euo pipefail
cd "$(dirname "$0")/.."

# Percent regression that fails the gate. Tightened from the initial 35
# once the SIMD microkernels landed: the kernels are faster AND less noisy
# (fixed-shape register blocks), so shared-runner jitter fits inside 25.
THRESHOLD=25
BASELINE="benches/baseline/BENCH_perf_hotpath.json"
FRESH="BENCH_perf_hotpath.json"

PY=python3
command -v "$PY" >/dev/null || { echo "bench-check: python3 not found" >&2; exit 1; }

if [[ "${1:-}" == "--rebaseline" ]]; then
  SRC="${2:-$FRESH}"
  "$PY" - "$SRC" "$BASELINE" <<'EOF'
import json, os, sys

src, dst = sys.argv[1], sys.argv[2]
with open(src) as f:
    doc = json.load(f)
doc["provenance"] = "ci"
doc["note"] = (
    "bench-regression baseline for scripts/bench-check.sh; recorded from a "
    "CI bench artifact via --rebaseline"
)
os.makedirs(os.path.dirname(dst), exist_ok=True)
with open(dst, "w") as f:
    json.dump(doc, f, indent=1, sort_keys=True)
    f.write("\n")
EOF
  echo "bench-check: baseline updated from $SRC (provenance: ci) — commit $BASELINE"
  exit 0
fi

[[ -n "${1:-}" ]] && FRESH="$1"
[[ -n "${2:-}" ]] && BASELINE="$2"
[[ -f "$FRESH" ]] || { echo "bench-check: fresh results $FRESH not found (run the bench first)" >&2; exit 1; }
[[ -f "$BASELINE" ]] || { echo "bench-check: baseline $BASELINE not found" >&2; exit 1; }

"$PY" - "$FRESH" "$BASELINE" "$THRESHOLD" <<'EOF'
import json, os, sys

fresh_path, base_path, thr = sys.argv[1], sys.argv[2], float(sys.argv[3])
TRACKED = ("gemm/", "conv/", "engine/", "coordinator/")
# Entries that must exist in every fresh run (enforced under the same
# provenance/machine guards as the regression check): the SIMD microkernel
# benches this gate was hardened to hold, the fused-epilogue entries (the
# i8-chained execute path must stay on the gate), and the serving-substrate
# entries (flat-binary restart load + the engine-native coordinator round
# trip), and the BSR-datapath entries (the block-scheduler GEMM kernels and
# the BSR-prepared engine execute).
REQUIRED = (
    "gemm/dense_i8_512_simd",
    "gemm/dbb_i8_512_simd_50pct",
    "gemm/dbb_i8_512_simd_87pct",
    "engine/convnet5_execute_simd",
    "gemm/dense_i8_512_epilogue",
    "engine/convnet5_execute_fused_epilogue",
    "engine/convnet5_load_persisted",
    "coordinator/engine_serve_steady_p99",
    "gemm/bsr_i8_512_50pct",
    "gemm/bsr_i8_512_87pct",
    "engine/convnet5_execute_bsr",
)
on_baseline_machine = (
    bool(os.environ.get("CI")) or os.environ.get("BENCH_CHECK_ENFORCE") == "1"
)


def medians(path):
    with open(path) as f:
        doc = json.load(f)
    meds = {
        r["name"]: float(r["median_ns"])
        for r in doc.get("results", [])
        if str(r.get("name", "")).startswith(TRACKED)
    }
    return doc, meds


def ns(v):
    if v is None:
        return "-"
    if v >= 1e6:
        return f"{v / 1e6:.2f} ms"
    if v >= 1e3:
        return f"{v / 1e3:.2f} us"
    return f"{v:.0f} ns"


fdoc, fresh = medians(fresh_path)
bdoc, base = medians(base_path)
prov = bdoc.get("provenance", "ci")
enforce = prov == "ci" and on_baseline_machine

rows, regressions, missing = [], [], []
for name in sorted(set(base) | set(fresh)):
    if name not in fresh:
        missing.append(name)
        rows.append((name, base[name], None, None, "MISSING"))
    elif name not in base:
        rows.append((name, None, fresh[name], None, "new (no baseline)"))
    else:
        b, f = base[name], fresh[name]
        delta = (f - b) / b * 100.0 if b > 0 else 0.0
        status = "ok"
        if delta > thr:
            status = "REGRESSION"
            regressions.append((name, delta))
        rows.append((name, b, f, delta, status))

w = max([len(r[0]) for r in rows] + [5])
print(
    f"bench-check: {fresh_path} vs {base_path} "
    f"(fail threshold +{thr:.0f}% on medians, baseline provenance: {prov})"
)
print(f"{'entry':<{w}}  {'baseline':>10}  {'fresh':>10}  {'delta':>8}  status")
for name, b, f, d, s in rows:
    ds = "-" if d is None else f"{d:+.1f}%"
    print(f"{name:<{w}}  {ns(b):>10}  {ns(f):>10}  {ds:>8}  {s}")

fail = False
absent = [name for name in REQUIRED if name not in fresh]
if absent:
    print(
        f"\nbench-check: {len(absent)} REQUIRED entries absent from the fresh "
        "run (SIMD bench renamed/removed?): " + ", ".join(absent)
    )
    fail = True
if missing:
    print(
        f"\nbench-check: {len(missing)} tracked baseline entries missing from "
        "the fresh run (bench entry renamed/removed, or the bench-smoke "
        "filter regressed?): " + ", ".join(missing)
    )
    fail = True
if regressions:
    print(f"\nbench-check: {len(regressions)} entries regressed more than {thr:.0f}%:")
    for name, d in regressions:
        print(f"  {name}: {d:+.1f}%")
    fail = True

if not fail:
    print("\nbench-check: all tracked entries within threshold")
    sys.exit(0)
if not enforce:
    if prov != "ci":
        print(
            f"\nbench-check: baseline provenance is '{prov}' (not CI-recorded) "
            "— reporting only, not failing the job. Arm the gate by "
            "re-baselining from a CI bench artifact:\n"
            "  scripts/bench-check.sh --rebaseline <downloaded BENCH_perf_hotpath.json>"
        )
    else:
        print(
            "\nbench-check: not running on the baseline's machine class (no CI "
            "env, BENCH_CHECK_ENFORCE unset) — reporting only; the GitHub job "
            "enforces these numbers."
        )
    sys.exit(0)
print(
    "\nbench-check: FAIL. If the change is an intentional perf trade-off, "
    "re-baseline from this run's CI bench artifact "
    "(scripts/bench-check.sh --rebaseline <artifact.json>) and commit the "
    "updated benches/baseline/BENCH_perf_hotpath.json with the PR."
)
sys.exit(1)
EOF
