"""AOT compile path: lower the L2/L1 computations once to HLO **text**.

This is the only place Python runs in the whole system — `make artifacts`
invokes it, the rust binary then loads `artifacts/*.hlo.txt` through the
PJRT C API (`ssta::runtime`) and never touches Python again.

Interchange is HLO *text*, not a serialized `HloModuleProto`: jax ≥ 0.5
emits protos with 64-bit instruction ids which the image's xla_extension
0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Artifacts produced (plus `manifest.json` describing shapes/dtypes):

* ``convnet5_b{B}.hlo.txt`` — whole ConvNet-5 forward (f32 image in [0,1]
  → f32 logits), weights baked as constants, for batch sizes the
  coordinator's dynamic batcher rounds to.
* ``dbb_gemm_m{M}_k{K}_n{N}_nnz{S}of8.hlo.txt`` — the standalone VDBB GEMM
  with *runtime* weight operands (a: i8[M,K], vals: i8[KB,S,N],
  idx: i32[KB,S,N] → i32[M,N]), one per density bound: the layer-serving
  path and the L3 microbenchmarks use these. One executable per bound is
  the moral equivalent of the hardware's per-layer stream configuration.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as model_mod
from .kernels.dbb_gemm import dbb_gemm

BZ = model_mod.BZ

# Standard microbench GEMM shape (a mid-network ConvNet/ResNet-ish layer).
GEMM_M, GEMM_K, GEMM_N = 128, 256, 64
GEMM_BOUNDS = (2, 4, 8)
MODEL_BATCHES = (1, 8)
MODEL_NNZ = 4  # ConvNet-5's Table I operating point is 2/8; 4/8 is the
# MobileNet-class bound — we bake 4/8 so the e2e demo has both sparse
# speedup and non-trivial accuracy headroom. Override with --nnz.


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the 0.5.1-safe interchange).

    ``as_hlo_text(True)`` = print_large_constants: without it the printer
    elides big literals as ``constant({...})``, which the old text parser
    silently mis-reads — baked weights would round-trip as garbage.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(True)


def lower_convnet5(batch: int, nnz: int, seed: int) -> tuple[str, dict]:
    """Lower the whole-model forward for one batch size."""
    params = model_mod.build_convnet5(nnz=nnz, seed=seed)

    def fwd(x):
        return (model_mod.convnet5_forward(params, x),)

    spec = jax.ShapeDtypeStruct((batch, 32, 32, 3), jnp.float32)
    text = to_hlo_text(jax.jit(fwd).lower(spec))
    meta = {
        "entry": "convnet5",
        "batch": batch,
        "nnz": nnz,
        "inputs": [{"shape": [batch, 32, 32, 3], "dtype": "f32"}],
        "outputs": [{"shape": [batch, 10], "dtype": "f32"}],
        "layers": model_mod.model_weight_stats(params),
    }
    return text, meta


def lower_dbb_gemm(m: int, k: int, n: int, nnz: int) -> tuple[str, dict]:
    """Lower the standalone VDBB GEMM with runtime weight operands."""
    kb = -(-k // BZ)

    def fn(a, vals, idx):
        return (dbb_gemm(a, vals, idx, BZ),)

    specs = (
        jax.ShapeDtypeStruct((m, k), jnp.int8),
        jax.ShapeDtypeStruct((kb, nnz, n), jnp.int8),
        jax.ShapeDtypeStruct((kb, nnz, n), jnp.int32),
    )
    text = to_hlo_text(jax.jit(fn).lower(*specs))
    meta = {
        "entry": "dbb_gemm",
        "m": m,
        "k": k,
        "n": n,
        "bz": BZ,
        "nnz": nnz,
        "inputs": [
            {"shape": [m, k], "dtype": "s8"},
            {"shape": [kb, nnz, n], "dtype": "s8"},
            {"shape": [kb, nnz, n], "dtype": "s32"},
        ],
        "outputs": [{"shape": [m, n], "dtype": "s32"}],
    }
    return text, meta


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--nnz", type=int, default=MODEL_NNZ)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--quick", action="store_true", help="only the smallest artifacts (CI smoke)"
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    manifest: dict[str, dict] = {}

    batches = (1,) if args.quick else MODEL_BATCHES
    for b in batches:
        name = f"convnet5_b{b}"
        text, meta = lower_convnet5(b, args.nnz, args.seed)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest[name] = {**meta, "file": f"{name}.hlo.txt"}
        print(f"wrote {path} ({len(text) / 1e6:.1f} MB)")

    bounds = (4,) if args.quick else GEMM_BOUNDS
    for nnz in bounds:
        name = f"dbb_gemm_m{GEMM_M}_k{GEMM_K}_n{GEMM_N}_nnz{nnz}of8"
        text, meta = lower_dbb_gemm(GEMM_M, GEMM_K, GEMM_N, nnz)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest[name] = {**meta, "file": f"{name}.hlo.txt"}
        print(f"wrote {path} ({len(text) / 1e6:.1f} MB)")

    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {mpath} ({len(manifest)} artifacts)")


if __name__ == "__main__":
    main()
