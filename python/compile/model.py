"""Layer-2 JAX model: INT8 CNN forward pass on the VDBB kernels.

The paper's workload is CNN inference lowered to GEMM (§I): every conv
layer becomes IM2COL (the Layer-1 `im2col` kernel — the hardware unit's
analog) followed by a DBB-sparse GEMM (the Layer-1 `dbb_gemm` kernel — the
time-unrolled STA-VDBB datapath). Requantization + ReLU follow each layer
(the Cortex-M33 ancillary path), with power-of-two scales and an exact
zero point so post-ReLU zeros are exact zeros the hardware clock-gates on.

The network here is the paper's 5-layer **ConvNet** benchmark (Table I:
CIFAR-10, 32×32×3, conv5×5×32 / conv5×5×32 / conv5×5×64 / fc64 / fc10) with
DBB applied to every layer except the first conv and the classifier head
(paper §V-A convention). Weights are synthetic magnitude-pruned INT8 —
the Table I *accuracy* experiments train real models in the rust `train`
substrate; this module is the *serving* model, AOT-lowered once by
`aot.py` and executed from rust via PJRT.

Everything is traceable: `convnet5_forward` contains no Python-side data
dependence, so `jax.jit(...).lower()` produces a single fused HLO with the
weights baked in as constants (they are known in advance — §II-A).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from . import dbbfmt
from .kernels.dbb_gemm import dbb_gemm
from .kernels.im2col import im2col
from .kernels.ref import dbb_gemm_ref, im2col_ref

BZ = 8

# (name, kind, geometry, dbb?) — ConvNet-5 of paper Table I.
# conv geometry: (kh, kw, cin, cout, stride, pad); fc: (in, out)
CONVNET5 = [
    ("conv1", "conv", (5, 5, 3, 32, 1, 2), False),
    ("conv2", "conv", (5, 5, 32, 32, 1, 2), True),
    ("conv3", "conv", (5, 5, 32, 64, 1, 2), True),
    ("fc1", "fc", (1024, 64), True),
    ("fc2", "fc", (64, 10), False),
]


@dataclass
class LayerParams:
    """One layer's compressed weights + static requant shift."""

    name: str
    kind: str
    geom: tuple
    nnz: int  # density bound this layer is encoded with (BZ = dense)
    vals: np.ndarray  # [KB, NNZ, N] int8
    idx: np.ndarray  # [KB, NNZ, N] int32
    shift: int = 0  # calibrated power-of-two requant shift

    @property
    def gemm_k(self) -> int:
        if self.kind == "conv":
            kh, kw, cin, *_ = self.geom
            return kh * kw * cin
        return self.geom[0]

    @property
    def gemm_n(self) -> int:
        return self.geom[3] if self.kind == "conv" else self.geom[1]


@dataclass
class ConvNet5Params:
    """Whole-model parameters (see `build_convnet5`)."""

    nnz: int
    layers: list[LayerParams] = field(default_factory=list)


def _synthesize_weights(rng: np.ndarray, k: int, n: int, nnz: int) -> np.ndarray:
    """Random INT8 weights magnitude-pruned to the DBB bound."""
    w = rng.integers(-64, 65, (k, n)).astype(np.int8)
    w[w == 0] = 7  # keep blocks genuinely at the bound
    if nnz < BZ:
        w = dbbfmt.prune_to_dbb(w, BZ, nnz)
    return w


def _maxpool2x2(x: jnp.ndarray) -> jnp.ndarray:
    """2×2 max pooling on [H, W, C] (MCU ancillary op)."""
    h, w, c = x.shape
    return x.reshape(h // 2, 2, w // 2, 2, c).max(axis=(1, 3))


def _requant_relu(acc: jnp.ndarray, shift: int, relu: bool) -> jnp.ndarray:
    q = jnp.clip(acc >> shift, -127, 127).astype(jnp.int8)
    return jnp.maximum(q, 0) if relu else q


def quantize_input(x: jnp.ndarray) -> jnp.ndarray:
    """FP32 image in [0,1] → symmetric INT8 (the DMA-in conversion)."""
    return jnp.clip(jnp.round(x * 127.0), -127, 127).astype(jnp.int8)


def build_convnet5(nnz: int = 4, seed: int = 0, calib_batch: int = 4) -> ConvNet5Params:
    """Synthesize DBB-pruned weights and calibrate the requant shifts.

    Calibration runs the pure-jnp reference forward on a random batch and
    picks, per layer, the smallest power-of-two shift that keeps the INT32
    accumulator inside INT8 after scaling (the same rule as the rust
    `sim::accel::requant_relu` path).
    """
    rng = np.random.default_rng(seed)
    params = ConvNet5Params(nnz=nnz)
    for name, kind, geom, dbb in CONVNET5:
        bound = nnz if dbb else BZ
        if kind == "conv":
            kh, kw, cin, cout, _, _ = geom
            k, n = kh * kw * cin, cout
        else:
            k, n = geom
        w = _synthesize_weights(rng, k, n, bound)
        vals, idx = dbbfmt.compress(w, BZ, bound)
        params.layers.append(LayerParams(name, kind, geom, bound, vals, idx))

    # ---- shift calibration on the reference path ----
    x = rng.random((calib_batch, 32, 32, 3), dtype=np.float32)
    xq = np.asarray(quantize_input(jnp.asarray(x)))
    act = xq
    for li, lp in enumerate(params.layers):
        relu = li + 1 < len(params.layers)
        if lp.kind == "conv":
            kh, kw, cin, cout, stride, pad = lp.geom
            cols = np.stack(
                [np.asarray(im2col_ref(jnp.asarray(a), kh, kw, stride, pad)) for a in act]
            )  # [B, OH*OW, K]
            m = cols.shape[1]
            a2d = cols.reshape(-1, cols.shape[-1])
        else:
            a2d = act.reshape(act.shape[0], -1)
        acc = np.asarray(
            dbb_gemm_ref(jnp.asarray(a2d), jnp.asarray(lp.vals), jnp.asarray(lp.idx), BZ)
        )
        max_abs = max(int(np.abs(acc).max()), 1)
        shift = 0
        while (max_abs >> shift) > 127:
            shift += 1
        lp.shift = shift
        q = np.clip(acc >> shift, -127, 127).astype(np.int8)
        if relu:
            q = np.maximum(q, 0)
        if lp.kind == "conv":
            _, _, _, cout, stride, pad = lp.geom
            hw = int(np.sqrt(m))
            fmap = q.reshape(calib_batch, hw, hw, cout)
            act = np.stack([np.asarray(_maxpool2x2(jnp.asarray(f))) for f in fmap])
        else:
            act = q
    return params


def convnet5_forward(params: ConvNet5Params, x: jnp.ndarray) -> jnp.ndarray:
    """Forward pass: ``x[B,32,32,3]`` f32 in [0,1] → logits ``[B,10]`` f32.

    Conv layers run IM2COL (Pallas) + VDBB GEMM (Pallas) with the batch
    folded into the GEMM M dimension — exactly how the rust coordinator's
    dynamic batcher shapes work for the array.
    """
    b = x.shape[0]
    act = quantize_input(x)  # [B, 32, 32, 3] int8
    n_layers = len(params.layers)
    for li, lp in enumerate(params.layers):
        relu = li + 1 < n_layers
        vals, idx = jnp.asarray(lp.vals), jnp.asarray(lp.idx)
        if lp.kind == "conv":
            kh, kw, cin, cout, stride, pad = lp.geom
            cols = jax.vmap(lambda a: im2col(a, kh, kw, stride, pad))(act)
            m_per = cols.shape[1]
            a2d = cols.reshape(b * m_per, -1)  # batch folded into M
            acc = dbb_gemm(a2d, vals, idx, BZ)
            q = _requant_relu(acc, lp.shift, relu)
            hw = int(round(m_per**0.5))
            fmap = q.reshape(b, hw, hw, cout)
            act = jax.vmap(_maxpool2x2)(fmap)
        else:
            a2d = act.reshape(b, -1)
            acc = dbb_gemm(a2d, vals, idx, BZ)
            if relu:
                act = _requant_relu(acc, lp.shift, True)
            else:
                return acc.astype(jnp.float32)  # logits
    raise AssertionError("unreachable: last layer returns")


def convnet5_forward_ref(params: ConvNet5Params, x: jnp.ndarray) -> jnp.ndarray:
    """Same forward on the pure-jnp oracles (kernel-free) — the L2 oracle."""
    b = x.shape[0]
    act = quantize_input(x)
    n_layers = len(params.layers)
    for li, lp in enumerate(params.layers):
        relu = li + 1 < n_layers
        vals, idx = jnp.asarray(lp.vals), jnp.asarray(lp.idx)
        if lp.kind == "conv":
            kh, kw, cin, cout, stride, pad = lp.geom
            cols = jnp.stack([im2col_ref(a, kh, kw, stride, pad) for a in act])
            m_per = cols.shape[1]
            a2d = cols.reshape(b * m_per, -1)
            acc = dbb_gemm_ref(a2d, vals, idx, BZ)
            q = _requant_relu(acc, lp.shift, relu)
            hw = int(round(m_per**0.5))
            act = jax.vmap(_maxpool2x2)(q.reshape(b, hw, hw, cout))
        else:
            a2d = act.reshape(b, -1)
            acc = dbb_gemm_ref(a2d, vals, idx, BZ)
            if relu:
                act = _requant_relu(acc, lp.shift, True)
            else:
                return acc.astype(jnp.float32)
    raise AssertionError("unreachable")


def model_weight_stats(params: ConvNet5Params) -> dict:
    """Per-layer (k, n, nnz, storage bits) — consumed by the rust timing
    path via the artifact manifest."""
    out = {}
    for lp in params.layers:
        out[lp.name] = {
            "kind": lp.kind,
            "geom": list(lp.geom),
            "k": lp.gemm_k,
            "n": lp.gemm_n,
            "nnz": lp.nnz,
            "bz": BZ,
            "shift": lp.shift,
            "storage_bits": dbbfmt.storage_bits(lp.gemm_k, lp.gemm_n, BZ, lp.nnz),
        }
    return out
