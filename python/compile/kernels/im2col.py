"""Layer-1 Pallas kernel: the hardware IM2COL bandwidth magnifier (§IV-C).

The hardware unit sits between the activation SRAM and the datapath: it
buffers a few input rows and emits IM2COL-expanded patch rows at 3× the
SRAM read bandwidth (for 3×3 kernels). The Pallas analog reads the (padded)
feature map once per output row and emits the expanded ``[OW, KH*KW*C]``
patch rows — the duplication happens *after* the (modelled) SRAM, in VMEM,
just like the unit's internal buffer register array.

The grid iterates output rows; the static inner loop over ``ow`` plays the
role of the unit's two-outputs-per-cycle register combining.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["im2col", "im2col_magnification"]


def im2col(x: jnp.ndarray, kh: int, kw: int, stride: int = 1, pad: int = 0) -> jnp.ndarray:
    """IM2COL a ``[H, W, C]`` feature map to ``[OH*OW, KH*KW*C]`` patches."""
    h, w, c = x.shape
    xp = jnp.pad(x, ((pad, pad), (pad, pad), (0, 0)))
    hp, wp = h + 2 * pad, w + 2 * pad
    oh = (hp - kh) // stride + 1
    ow = (wp - kw) // stride + 1

    def kernel(x_ref, o_ref):
        # x_ref: [HP, WP, C] (whole padded map — the overlapping KH-row
        # windows of a strided conv don't tile as BlockSpec blocks);
        # o_ref: [OW, KH*KW*C] — the patch rows of output row i.
        i = pl.program_id(0)
        for j in range(ow):  # ← the unit's per-cycle register combining
            patch = pl.load(
                x_ref,
                (pl.dslice(i * stride, kh), pl.dslice(j * stride, kw), slice(None)),
            )
            o_ref[j, :] = patch.reshape(kh * kw * c)

    call = pl.pallas_call(
        kernel,
        grid=(oh,),
        in_specs=[pl.BlockSpec((hp, wp, c), lambda i: (0, 0, 0))],
        out_specs=pl.BlockSpec((ow, kh * kw * c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((oh * ow, kh * kw * c), x.dtype),
        interpret=True,  # CPU-PJRT cannot run Mosaic custom-calls
    )
    return call(xp)


def im2col_magnification(kh: int, stride: int, buf_rows: int = 6) -> float:
    """SRAM-read magnification the hardware unit provides (paper Fig. 8).

    The unit captures the *vertical* reuse of the patch window in its row
    buffers: each SRAM byte serves ``kh/stride`` output rows, capped by the
    buffered-row capacity (``buf_rows − kh + 1`` output rows per refill).
    3× for 3×3 stride-1, 1× for 1×1 pointwise — mirrors
    ``ssta::sim::im2col::Im2colUnit::magnification`` exactly.
    """
    if kh <= 1 or stride >= kh:
        return 1.0
    return max(1.0, min(kh / stride, float(buf_rows - kh + 1)))
