"""Layer-1 Pallas kernel: time-unrolled VDBB sparse GEMM (paper §III-B/§IV).

This is the S8DP1 datapath of the STA-VDBB array expressed as a Pallas
kernel. The compressed weight stream ``vals[KB, NNZ, N]`` is walked one
*slot* at a time — the static inner loop over ``s in range(NNZ)`` is the
paper's time unrolling: the number of executed slots per block equals the
density bound, so effective throughput scales with weight sparsity exactly
as in the hardware. The per-slot gather of activations with ``idx`` *is*
the 8:1 activation mux driven by the bitmask metadata M.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper targets a
16 nm ASIC, not a GPU, so the mapping is about representing the TPE
datapath faithfully: TPE tiles (A×B×C sub-matrices) map to the BlockSpec
tiles ``(bm, bn)``; the output-stationary INT32 accumulator maps to the
kernel's carried accumulator; the HBM↔edge skew schedule is the Pallas
grid. ``interpret=True`` everywhere — the CPU PJRT client cannot execute
Mosaic custom-calls, and our correctness story is vs `ref.py`.

NNZ (the density bound) is a *trace-time constant* — one lowered
executable per bound, exactly like the hardware's per-layer stream
configuration word.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["dbb_gemm", "dbb_gemm_pallas_call"]


def _acc_dtype(dtype) -> jnp.dtype:
    return jnp.int32 if dtype == jnp.int8 else jnp.float32


def _kernel(a_ref, vals_ref, idx_ref, o_ref, *, bz: int):
    """One (bm×bn) output tile: all k-blocks of the reduction, time-unrolled.

    a_ref:    [bm, KB*BZ]   activation tile (k padded to a block multiple)
    vals_ref: [KB, NNZ, bn] compressed weights for this column tile
    idx_ref:  [KB, NNZ, bn] positional metadata (mux selects)
    o_ref:    [bm, bn]      output-stationary accumulators
    """
    kb_total, nnz, bn = vals_ref.shape
    bm = a_ref.shape[0]
    acc_t = _acc_dtype(a_ref.dtype)

    def block_step(kb, acc):
        # the A×B activation tile held at the TPE edge for this block
        a_blk = pl.load(a_ref, (slice(None), pl.dslice(kb * bz, bz)))  # [bm, BZ]
        a_blk = a_blk.astype(acc_t)
        for s in range(nnz):  # ← time unrolling: one slot per cycle
            w_s = pl.load(vals_ref, (kb, s, slice(None))).astype(acc_t)  # [bn]
            i_s = pl.load(idx_ref, (kb, s, slice(None)))  # [bn]
            gathered = jnp.take(a_blk, i_s, axis=1)  # the 8:1 mux  [bm, bn]
            acc = acc + gathered * w_s[None, :]
        return acc

    acc = jnp.zeros((bm, bn), dtype=acc_t)
    acc = jax.lax.fori_loop(0, kb_total, block_step, acc)
    o_ref[...] = acc


def dbb_gemm_pallas_call(
    m: int,
    k: int,
    n: int,
    nnz: int,
    bz: int = 8,
    *,
    bm: int = 32,
    bn: int = 32,
    dtype=jnp.int8,
):
    """Build the pallas_call for an ``M×K×N`` DBB GEMM with bound ``nnz``.

    Returns a function ``(a[M,K], vals[KB,NNZ,N], idx[KB,NNZ,N]) -> [M,N]``.
    ``bm``/``bn`` are the output-tile shape (the VMEM working set is
    ``bm·KB·BZ + 2·KB·NNZ·bn`` operand bytes + ``4·bm·bn`` accumulator
    bytes — see EXPERIMENTS.md §Perf-L1 for the sizing rationale).
    """
    if m % bm:
        bm = next(t for t in (16, 8, 4, 2, 1) if m % t == 0)
    if n % bn:
        bn = next(t for t in (16, 8, 4, 2, 1) if n % t == 0)
    kb = -(-k // bz)
    grid = (m // bm, n // bn)
    acc_t = _acc_dtype(dtype)
    return pl.pallas_call(
        functools.partial(_kernel, bz=bz),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, kb * bz), lambda i, j: (i, 0)),
            pl.BlockSpec((kb, nnz, bn), lambda i, j: (0, 0, j)),
            pl.BlockSpec((kb, nnz, bn), lambda i, j: (0, 0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), acc_t),
        interpret=True,  # CPU-PJRT cannot run Mosaic custom-calls
    )


def dbb_gemm(a: jnp.ndarray, vals: jnp.ndarray, idx: jnp.ndarray, bz: int = 8, **tile) -> jnp.ndarray:
    """Compute ``A[M,K] @ decompress(vals, idx)`` on the VDBB Pallas kernel.

    ``A``'s reduction dim is zero-padded to a block multiple (the hardware's
    ragged last block). Accumulates in INT32 for INT8 operands.
    """
    m, k = a.shape
    kb, nnz, n = vals.shape
    if kb * bz < k:
        raise ValueError(f"weight encoding covers {kb * bz} rows < K={k}")
    if kb * bz > k:
        a = jnp.pad(a, ((0, 0), (0, kb * bz - k)))
    call = dbb_gemm_pallas_call(m, kb * bz, n, nnz, bz, dtype=a.dtype, **tile)
    return call(a, vals, idx)
