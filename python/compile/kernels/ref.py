"""Pure-jnp oracles for the Pallas kernels — the build-time correctness
reference (`pytest python/tests` checks every kernel against these).

These are deliberately the simplest possible formulations: decompress the
DBB operand to dense and call `jnp.matmul`; materialize IM2COL patches with
plain indexing. No Pallas, no tiling.
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["dbb_decompress", "dbb_gemm_ref", "im2col_ref", "requant_relu_ref"]


def dbb_decompress(vals: jnp.ndarray, idx: jnp.ndarray, bz: int, k: int) -> jnp.ndarray:
    """Expand ``(vals[KB,NNZ,N], idx[KB,NNZ,N])`` to the dense ``K×N``."""
    kb, nnz, n = vals.shape
    dense = jnp.zeros((kb, bz, n), dtype=vals.dtype)
    kbi = jnp.arange(kb)[:, None, None]
    ni = jnp.arange(n)[None, None, :]
    # padding slots are (0, idx 0): scatter-add of zero is a no-op
    dense = dense.at[kbi, idx, ni].add(vals)
    return dense.reshape(kb * bz, n)[:k]


def dbb_gemm_ref(a: jnp.ndarray, vals: jnp.ndarray, idx: jnp.ndarray, bz: int) -> jnp.ndarray:
    """Reference ``A[M,K] @ decompress(vals, idx)`` with wide accumulation.

    INT8 operands accumulate in INT32 (the paper's datapath); float operands
    accumulate in float32.
    """
    k = a.shape[1]
    w = dbb_decompress(vals, idx, bz, k)
    acc = jnp.int32 if a.dtype == jnp.int8 else jnp.float32
    return jnp.matmul(a.astype(acc), w.astype(acc), preferred_element_type=acc)


def im2col_ref(x: jnp.ndarray, kh: int, kw: int, stride: int, pad: int) -> jnp.ndarray:
    """Reference IM2COL: ``x[H,W,C]`` → patches ``[OH*OW, KH*KW*C]``.

    Row-major over output pixels; each row is the flattened KH×KW×C patch,
    matching both the Pallas kernel and the hardware unit's output order.
    """
    h, w, c = x.shape
    xp = jnp.pad(x, ((pad, pad), (pad, pad), (0, 0)))
    oh = (h + 2 * pad - kh) // stride + 1
    ow = (w + 2 * pad - kw) // stride + 1
    rows = []
    for i in range(oh):
        for j in range(ow):
            patch = xp[i * stride : i * stride + kh, j * stride : j * stride + kw, :]
            rows.append(patch.reshape(-1))
    return jnp.stack(rows)


def requant_relu_ref(acc: jnp.ndarray, shift: int, relu: bool) -> jnp.ndarray:
    """INT32 → INT8 with a power-of-two scale, then optional ReLU.

    Zero-point is exactly 0 (paper §V-A trains with STE so FP 0 → INT 0),
    which is what makes post-ReLU zeros exact zeros the hardware can gate on.
    """
    q = jnp.clip(acc >> shift, -127, 127).astype(jnp.int8)
    if relu:
        q = jnp.maximum(q, 0)
    return q
