"""Density-Bound Block (DBB) weight format — build-time encode/decode.

Mirrors the rust `ssta::dbb` module (paper §II, Fig. 2): a K×N INT8 weight
matrix is blocked along K (the depth/channel dimension) into blocks of BZ
elements; DBB bounds each block to at most NNZ non-zeros. The compressed
tensor form used by the Pallas kernel stores, per (k-block, slot, column):

* ``vals[KB, NNZ, N]``  int8  — the non-zero values, position-ordered,
  zero-padded when a block has fewer than NNZ non-zeros;
* ``idx[KB, NNZ, N]``   int32 — the position of each value inside its
  expanded block (0..BZ-1). This is the bitmask metadata M of the paper in
  pre-decoded "mux select" form: the hardware drives an 8:1 activation mux
  with it, the kernel drives a gather.

Padding slots carry ``val = 0`` with ``idx = 0`` — a multiply-by-zero, which
is exactly what the hardware's zero-skipping leaves in the schedule.
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "prune_to_dbb",
    "compress",
    "decompress",
    "check_bound",
    "storage_bits",
    "compression_ratio",
]


def prune_to_dbb(w: np.ndarray, bz: int, nnz: int) -> np.ndarray:
    """Magnitude-prune a dense K×N matrix to satisfy an (nnz, bz) DBB bound.

    Within every depthwise block of ``bz`` elements, keep the ``nnz``
    largest-magnitude values and zero the rest (paper §V-A's magnitude-based
    DBB-aware pruning, single shot). The last ragged block is handled by
    zero-padding K up to a block multiple.
    """
    if w.ndim != 2:
        raise ValueError(f"expected K×N matrix, got shape {w.shape}")
    k, n = w.shape
    kb = -(-k // bz)
    pad = kb * bz - k
    wp = np.pad(w, ((0, pad), (0, 0))).reshape(kb, bz, n)
    # rank positions by |value| descending within each block
    order = np.argsort(-np.abs(wp), axis=1, kind="stable")
    keep = np.zeros_like(wp, dtype=bool)
    np.put_along_axis(keep, order[:, :nnz, :], True, axis=1)
    out = np.where(keep, wp, 0).reshape(kb * bz, n)[:k]
    return out.astype(w.dtype)


def check_bound(w: np.ndarray, bz: int, nnz: int) -> bool:
    """True iff every depthwise block of ``w`` has ≤ ``nnz`` non-zeros."""
    k, n = w.shape
    kb = -(-k // bz)
    wp = np.pad(w, ((0, kb * bz - k), (0, 0))).reshape(kb, bz, n)
    return bool(((wp != 0).sum(axis=1) <= nnz).all())


def compress(w: np.ndarray, bz: int, nnz: int) -> tuple[np.ndarray, np.ndarray]:
    """Encode a DBB-satisfying dense K×N matrix to ``(vals, idx)``.

    Returns ``vals[KB, NNZ, N]`` (same dtype as ``w``) and
    ``idx[KB, NNZ, N]`` int32. Raises if any block violates the bound —
    the hardware would have to fall back to dense (paper §III).
    """
    if not check_bound(w, bz, nnz):
        raise ValueError(f"matrix violates DBB bound {nnz}/{bz}")
    k, n = w.shape
    kb = -(-k // bz)
    wp = np.pad(w, ((0, kb * bz - k), (0, 0))).reshape(kb, bz, n)
    nonzero = wp != 0
    # stable order: non-zeros first (by block position), zeros after
    rank = np.argsort(~nonzero, axis=1, kind="stable")  # [KB, BZ, N]
    sel = rank[:, :nnz, :]  # positions of the (up to) nnz non-zeros
    vals = np.take_along_axis(wp, sel, axis=1)
    taken_nonzero = np.take_along_axis(nonzero, sel, axis=1)
    vals = np.where(taken_nonzero, vals, 0)
    idx = np.where(taken_nonzero, sel, 0).astype(np.int32)
    return vals.astype(w.dtype), idx


def decompress(vals: np.ndarray, idx: np.ndarray, bz: int, k: int) -> np.ndarray:
    """Decode ``(vals, idx)`` back to the dense K×N matrix."""
    kb, nnz, n = vals.shape
    out = np.zeros((kb, bz, n), dtype=vals.dtype)
    kbi = np.arange(kb)[:, None, None]
    ni = np.arange(n)[None, None, :]
    # padding slots are (val 0, idx 0): adding zero is a no-op, so a plain
    # scatter-add is safe even when idx collides with a real slot 0
    np.add.at(out, (kbi, idx, ni), vals)
    return out.reshape(kb * bz, n)[:k]


def storage_bits(k: int, n: int, bz: int, nnz: int, wordbits: int = 8) -> int:
    """Compressed bits: per block ``wordbits·NNZ + BZ`` (paper §II-A)."""
    kb = -(-k // bz)
    return kb * n * (wordbits * nnz + bz)


def compression_ratio(bz: int, nnz: int, wordbits: int = 8) -> float:
    """``wordbits·BZ / (wordbits·NNZ + BZ)`` — paper §II-A."""
    return wordbits * bz / (wordbits * nnz + bz)
