"""AOT path: HLO text generation and executability on the CPU PJRT client.

These tests lower the small artifacts only (the full `make artifacts` set
takes minutes); they verify the HLO text parses back and executes with the
same numbers as the jax-side computation — i.e. the exact interchange the
rust runtime consumes.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, dbbfmt, model
from compile.kernels.dbb_gemm import dbb_gemm


def test_dbb_gemm_hlo_text_shape():
    text, meta = aot.lower_dbb_gemm(8, 16, 4, 2)
    assert "ENTRY" in text
    assert meta["inputs"][0] == {"shape": [8, 16], "dtype": "s8"}
    assert meta["outputs"] == [{"shape": [8, 4], "dtype": "s32"}]
    # HLO text must mention the integer gemm types
    assert "s32" in text and "s8" in text


def test_convnet5_hlo_text_small():
    text, meta = aot.lower_convnet5(1, 4, 0)
    assert "ENTRY" in text
    assert meta["outputs"] == [{"shape": [1, 10], "dtype": "f32"}]
    assert "layers" in meta and "conv2" in meta["layers"]


def test_hlo_text_parses_back():
    """The emitted HLO text must re-parse with the correct program shape.

    (The execute half of the round-trip — text → parse → compile → run —
    is exercised with real numbers by the rust runtime integration tests;
    xla_extension 0.5.1's text parser is the consumer that matters.)
    """
    from jax._src.lib import xla_client as xc

    m, k, n, nnz = 8, 16, 4, 2

    def fn(a, vals, idx):
        return (dbb_gemm(a, vals, idx, 8),)

    kb = -(-k // 8)
    lowered = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((m, k), jnp.int8),
        jax.ShapeDtypeStruct((kb, nnz, n), jnp.int8),
        jax.ShapeDtypeStruct((kb, nnz, n), jnp.int32),
    )
    text = aot.to_hlo_text(lowered)
    hlo_module = xc._xla.hlo_module_from_text(text)
    comp = xc.XlaComputation(hlo_module.as_serialized_hlo_module_proto())
    shape = comp.program_shape()
    params = [str(p).split("{")[0] for p in shape.parameter_shapes()]
    assert params == ["s8[8,16]", "s8[2,2,4]", "s32[2,2,4]"]
    assert "s32[8,4]" in str(shape.result_shape())


def test_manifest_written(tmp_path):
    import subprocess
    import sys

    out = tmp_path / "artifacts"
    r = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out), "--quick"],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        capture_output=True,
        text=True,
        timeout=600,
    )
    assert r.returncode == 0, r.stderr
    manifest = json.loads((out / "manifest.json").read_text())
    assert "convnet5_b1" in manifest
    for name, meta in manifest.items():
        assert (out / meta["file"]).exists(), name


def test_artifacts_dir_manifest_consistent():
    """If `make artifacts` has run, every manifest entry must exist."""
    art = os.path.join(os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__)))), "artifacts")
    mpath = os.path.join(art, "manifest.json")
    if not os.path.exists(mpath):
        pytest.skip("artifacts not built")
    manifest = json.load(open(mpath))
    for name, meta in manifest.items():
        path = os.path.join(art, meta["file"])
        assert os.path.exists(path), name
        head = open(path).read(4096)
        assert "ENTRY" in head or "HloModule" in head
