"""Pallas kernels vs pure-jnp oracles — the CORE L1 correctness signal.

Hypothesis sweeps shapes, dtypes and density bounds; exact integer
equality is demanded for the INT8 path (the hardware datapath is exact),
allclose for the float path.
"""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import dbbfmt
from compile.kernels import ref
from compile.kernels.dbb_gemm import dbb_gemm
from compile.kernels.im2col import im2col, im2col_magnification


def make_dbb(rng, k, n, bz, nnz, dtype=np.int8):
    if dtype == np.int8:
        w = rng.integers(-127, 128, (k, n)).astype(np.int8)
    else:
        w = rng.standard_normal((k, n)).astype(np.float32)
    w = dbbfmt.prune_to_dbb(w, bz, nnz)
    return dbbfmt.compress(w, bz, nnz)


# ---------------------------------------------------------------- dbb_gemm


@given(
    m=st.integers(1, 48),
    k=st.integers(1, 64),
    n=st.integers(1, 48),
    bz=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**31),
    data=st.data(),
)
@settings(max_examples=40, deadline=None)
def test_dbb_gemm_int8_exact(m, k, n, bz, seed, data):
    nnz = data.draw(st.integers(1, bz))
    rng = np.random.default_rng(seed)
    a = rng.integers(-127, 128, (m, k)).astype(np.int8)
    vals, idx = make_dbb(rng, k, n, bz, nnz)
    got = dbb_gemm(jnp.asarray(a), jnp.asarray(vals), jnp.asarray(idx), bz)
    want = ref.dbb_gemm_ref(jnp.asarray(a), jnp.asarray(vals), jnp.asarray(idx), bz)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert got.dtype == jnp.int32


@given(
    m=st.integers(1, 24),
    k=st.integers(1, 40),
    n=st.integers(1, 24),
    nnz=st.integers(1, 8),
    seed=st.integers(0, 2**31),
)
@settings(max_examples=20, deadline=None)
def test_dbb_gemm_f32_allclose(m, k, n, nnz, seed):
    bz = 8
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((m, k)).astype(np.float32)
    vals, idx = make_dbb(rng, k, n, bz, nnz, dtype=np.float32)
    got = dbb_gemm(jnp.asarray(a), jnp.asarray(vals), jnp.asarray(idx), bz)
    want = ref.dbb_gemm_ref(jnp.asarray(a), jnp.asarray(vals), jnp.asarray(idx), bz)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_dbb_gemm_matches_dense_matmul():
    # end-to-end: compressed kernel == dense numpy GEMM on the pruned weights
    rng = np.random.default_rng(42)
    a = rng.integers(-127, 128, (32, 64)).astype(np.int8)
    w = dbbfmt.prune_to_dbb(rng.integers(-127, 128, (64, 16)).astype(np.int8), 8, 3)
    vals, idx = dbbfmt.compress(w, 8, 3)
    got = np.asarray(dbb_gemm(jnp.asarray(a), jnp.asarray(vals), jnp.asarray(idx), 8))
    want = a.astype(np.int32) @ w.astype(np.int32)
    np.testing.assert_array_equal(got, want)


def test_dbb_gemm_dense_bound_is_dense_gemm():
    # NNZ == BZ: the VDBB kernel runs the fully dense 8/8 case (paper Fig 4a)
    rng = np.random.default_rng(9)
    a = rng.integers(-127, 128, (8, 24)).astype(np.int8)
    w = rng.integers(-127, 128, (24, 8)).astype(np.int8)
    vals, idx = dbbfmt.compress(w, 8, 8)
    got = np.asarray(dbb_gemm(jnp.asarray(a), jnp.asarray(vals), jnp.asarray(idx), 8))
    np.testing.assert_array_equal(got, a.astype(np.int32) @ w.astype(np.int32))


def test_dbb_gemm_int8_saturation_range():
    # worst-case accumulation stays in INT32: K*127*127 < 2^31 for K<=128k
    a = np.full((1, 128), 127, dtype=np.int8)
    w = np.full((128, 1), 127, dtype=np.int8)
    vals, idx = dbbfmt.compress(w, 8, 8)
    got = np.asarray(dbb_gemm(jnp.asarray(a), jnp.asarray(vals), jnp.asarray(idx), 8))
    assert got[0, 0] == 128 * 127 * 127


@given(tile=st.sampled_from([(8, 8), (16, 4), (32, 32), (4, 16)]))
@settings(max_examples=4, deadline=None)
def test_dbb_gemm_tile_shape_invariance(tile):
    # the BlockSpec tiling must not change the numbers
    rng = np.random.default_rng(5)
    a = rng.integers(-127, 128, (32, 32)).astype(np.int8)
    vals, idx = make_dbb(rng, 32, 32, 8, 3)
    bm, bn = tile
    got = dbb_gemm(jnp.asarray(a), jnp.asarray(vals), jnp.asarray(idx), 8, bm=bm, bn=bn)
    want = ref.dbb_gemm_ref(jnp.asarray(a), jnp.asarray(vals), jnp.asarray(idx), 8)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------- im2col


@given(
    h=st.integers(3, 14),
    w=st.integers(3, 14),
    c=st.integers(1, 8),
    cfg=st.sampled_from([(3, 3, 1, 1), (3, 3, 2, 1), (5, 5, 1, 2), (1, 1, 1, 0), (3, 3, 1, 0)]),
    seed=st.integers(0, 2**31),
)
@settings(max_examples=30, deadline=None)
def test_im2col_matches_ref(h, w, c, cfg, seed):
    kh, kw, stride, pad = cfg
    if h + 2 * pad < kh or w + 2 * pad < kw:
        return
    rng = np.random.default_rng(seed)
    x = rng.integers(-127, 128, (h, w, c)).astype(np.int8)
    got = im2col(jnp.asarray(x), kh, kw, stride, pad)
    want = ref.im2col_ref(jnp.asarray(x), kh, kw, stride, pad)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_im2col_magnification_3x3_is_3x():
    # paper §IV-C: 3× SRAM-read reduction for 3×3 stride-1
    assert im2col_magnification(3, 1) == 3.0


def test_im2col_magnification_1x1_is_1x():
    assert im2col_magnification(1, 1) == 1.0


def test_im2col_magnification_5x5_buffer_capped():
    # 5×5 s1: vertical reuse 5, but the 6-row buffer serves 2 rows/refill
    assert im2col_magnification(5, 1) == 2.0
    assert im2col_magnification(3, 2) == 1.5  # stride-2 halves the reuse


def test_im2col_then_gemm_equals_conv():
    # the full lowering: conv == im2col + GEMM (paper §I)
    import jax

    rng = np.random.default_rng(11)
    x = rng.integers(-10, 11, (8, 8, 4)).astype(np.int8)
    w = rng.integers(-10, 11, (3, 3, 4, 6)).astype(np.int8)
    cols = im2col(jnp.asarray(x), 3, 3, 1, 1)  # [64, 36]
    gemm = np.asarray(cols).astype(np.int32) @ w.reshape(36, 6).astype(np.int32)
    conv = jax.lax.conv_general_dilated(
        jnp.asarray(x).astype(jnp.int32)[None],
        jnp.asarray(w).astype(jnp.int32),
        (1, 1),
        ((1, 1), (1, 1)),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )[0]
    np.testing.assert_array_equal(gemm.reshape(8, 8, 6), np.asarray(conv))
