"""L2 model: Pallas forward vs oracle forward, quantization invariants."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import dbbfmt, model


@pytest.fixture(scope="module")
def params():
    return model.build_convnet5(nnz=4, seed=0, calib_batch=2)


def test_forward_matches_ref(params):
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.random((2, 32, 32, 3), dtype=np.float32))
    got = model.convnet5_forward(params, x)
    want = model.convnet5_forward_ref(params, x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert got.shape == (2, 10)
    assert got.dtype == jnp.float32


def test_forward_batch1(params):
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.random((1, 32, 32, 3), dtype=np.float32))
    out = model.convnet5_forward(params, x)
    assert out.shape == (1, 10)


def test_batch_rows_independent(params):
    # batch folding into GEMM M must not mix rows (coordinator invariant)
    rng = np.random.default_rng(3)
    x2 = jnp.asarray(rng.random((2, 32, 32, 3), dtype=np.float32))
    both = np.asarray(model.convnet5_forward(params, x2))
    one = np.asarray(model.convnet5_forward(params, x2[:1]))
    np.testing.assert_array_equal(both[0], one[0])


def test_dbb_layers_satisfy_bound(params):
    for lp in params.layers:
        w = dbbfmt.decompress(lp.vals, lp.idx, model.BZ, lp.gemm_k)
        assert dbbfmt.check_bound(w, model.BZ, lp.nnz), lp.name


def test_first_and_last_layers_dense(params):
    # paper §V-A: first conv + classifier head are left unpruned
    assert params.layers[0].nnz == model.BZ
    assert params.layers[-1].nnz == model.BZ
    for lp in params.layers[1:-1]:
        assert lp.nnz == 4


def test_quantize_input_exact_zero():
    # STE-style quantization: FP 0 → INT 0 exactly (gating correctness)
    x = jnp.zeros((1, 4), jnp.float32)
    assert (np.asarray(model.quantize_input(x)) == 0).all()


def test_quantize_input_range():
    x = jnp.asarray([[0.0, 1.0, 0.5, 2.0]], jnp.float32)
    q = np.asarray(model.quantize_input(x))
    assert q[0, 0] == 0 and q[0, 1] == 127 and q[0, 3] == 127  # clamped


def test_calibrated_shifts_keep_int8(params):
    # logits are the raw INT32 accumulators of the head (no requant on the
    # last layer); intermediate activations are INT8 by construction, so the
    # head's accumulator magnitude is bounded by K·127·|w|max
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.random((2, 32, 32, 3), dtype=np.float32))
    out = np.asarray(model.convnet5_forward_ref(params, x))
    assert np.isfinite(out).all()
    assert np.abs(out).max() <= 64 * 127 * 64  # K=64, |w|<=64
    assert out.std() > 0


def test_weight_stats_consistent(params):
    stats = model.model_weight_stats(params)
    assert set(stats) == {l.name for l in params.layers}
    assert stats["conv2"]["k"] == 5 * 5 * 32
    assert stats["conv2"]["nnz"] == 4
    assert stats["fc1"]["k"] == 1024
    # §II-A storage: conv2 = KB*N*(8*NNZ+BZ) bits
    assert stats["conv2"]["storage_bits"] == 100 * 32 * (8 * 4 + 8)


def test_different_nnz_changes_model():
    p2 = model.build_convnet5(nnz=2, seed=0, calib_batch=1)
    assert p2.layers[1].vals.shape[1] == 2
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.random((1, 32, 32, 3), dtype=np.float32))
    out = model.convnet5_forward_ref(p2, x)
    assert np.isfinite(np.asarray(out)).all()
