"""DBB format (numpy side): prune / compress / decompress invariants."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import dbbfmt


def rand_w(rng, k, n):
    return rng.integers(-127, 128, (k, n)).astype(np.int8)


@given(
    k=st.integers(1, 64),
    n=st.integers(1, 16),
    bz=st.sampled_from([2, 4, 8, 16]),
    seed=st.integers(0, 2**31),
    data=st.data(),
)
@settings(max_examples=60, deadline=None)
def test_prune_compress_roundtrip(k, n, bz, seed, data):
    nnz = data.draw(st.integers(1, bz))
    rng = np.random.default_rng(seed)
    w = dbbfmt.prune_to_dbb(rand_w(rng, k, n), bz, nnz)
    assert dbbfmt.check_bound(w, bz, nnz)
    vals, idx = dbbfmt.compress(w, bz, nnz)
    assert vals.shape == idx.shape == (-(-k // bz), nnz, n)
    back = dbbfmt.decompress(vals, idx, bz, k)
    np.testing.assert_array_equal(back, w)


@given(
    k=st.integers(1, 48),
    n=st.integers(1, 12),
    seed=st.integers(0, 2**31),
    nnz=st.integers(1, 8),
)
@settings(max_examples=40, deadline=None)
def test_prune_keeps_largest_magnitudes(k, n, seed, nnz):
    bz = 8
    rng = np.random.default_rng(seed)
    w = rand_w(rng, k, n)
    p = dbbfmt.prune_to_dbb(w, bz, nnz)
    kb = -(-k // bz)
    wp = np.pad(w, ((0, kb * bz - k), (0, 0))).reshape(kb, bz, n)
    pp = np.pad(p, ((0, kb * bz - k), (0, 0))).reshape(kb, bz, n)
    # every kept value must be >= every dropped value in magnitude
    for b in range(kb):
        for c in range(n):
            kept = np.abs(wp[b, pp[b, :, c] != 0, c])
            dropped = np.abs(wp[b, (pp[b, :, c] == 0) & (wp[b, :, c] != 0), c])
            if kept.size and dropped.size:
                assert kept.min() >= dropped.max()


def test_compress_rejects_bound_violation():
    w = np.full((8, 2), 3, dtype=np.int8)  # fully dense
    with pytest.raises(ValueError):
        dbbfmt.compress(w, 8, 2)


def test_padding_slots_are_zero():
    # a block with fewer non-zeros than the bound pads with (0, idx 0)
    w = np.zeros((8, 1), dtype=np.int8)
    w[5, 0] = 9
    vals, idx = dbbfmt.compress(w, 8, 3)
    assert vals[0, 0, 0] == 9 and idx[0, 0, 0] == 5
    assert (vals[0, 1:, 0] == 0).all() and (idx[0, 1:, 0] == 0).all()


def test_ragged_k_roundtrip():
    rng = np.random.default_rng(7)
    w = dbbfmt.prune_to_dbb(rand_w(rng, 13, 3), 8, 2)
    vals, idx = dbbfmt.compress(w, 8, 2)
    np.testing.assert_array_equal(dbbfmt.decompress(vals, idx, 8, 13), w)


def test_storage_and_compression_formulas():
    # paper §II-A: block of BZ=8 at NNZ=2 → 8*8/(8*2+8) ≈ 2.67×
    assert dbbfmt.storage_bits(64, 16, 8, 2) == 8 * 16 * (8 * 2 + 8)
    assert abs(dbbfmt.compression_ratio(8, 2) - 64 / 24) < 1e-12


def test_dense_bound_is_identity():
    rng = np.random.default_rng(3)
    w = rand_w(rng, 24, 5)
    vals, idx = dbbfmt.compress(w, 8, 8)
    np.testing.assert_array_equal(dbbfmt.decompress(vals, idx, 8, 24), w)
