//! Minimal dense n-d tensor used by the golden GEMMs, the simulator's
//! functional path and the training substrate.
//!
//! Row-major, owned storage, no views/strides beyond what the substrate
//! needs — the hot paths in this repo operate on raw slices obtained via
//! [`Tensor::data`] and do their own indexing.

use std::fmt;

/// Dense row-major tensor.
#[derive(Clone, PartialEq)]
pub struct Tensor<T> {
    shape: Vec<usize>,
    data: Vec<T>,
}

/// INT8 tensor (CNN operands).
pub type TensorI8 = Tensor<i8>;
/// INT32 tensor (accumulators).
pub type TensorI32 = Tensor<i32>;
/// f32 tensor (training substrate).
pub type TensorF32 = Tensor<f32>;

impl<T: Copy + Default> Tensor<T> {
    /// All-default (zero) tensor of the given shape.
    pub fn zeros(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: vec![T::default(); n],
        }
    }

    /// Build from existing data; panics if the element count mismatches.
    pub fn from_vec(shape: &[usize], data: Vec<T>) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(n, data.len(), "shape {shape:?} needs {n} elems, got {}", data.len());
        Tensor {
            shape: shape.to_vec(),
            data,
        }
    }

    /// Shape slice.
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True when there are no elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Immutable raw storage.
    pub fn data(&self) -> &[T] {
        &self.data
    }

    /// Mutable raw storage.
    pub fn data_mut(&mut self) -> &mut [T] {
        &mut self.data
    }

    /// Consume into raw storage.
    pub fn into_vec(self) -> Vec<T> {
        self.data
    }

    /// Flat offset of a multi-index (row-major).
    #[inline]
    pub fn offset(&self, idx: &[usize]) -> usize {
        debug_assert_eq!(idx.len(), self.shape.len());
        let mut off = 0usize;
        for (i, &d) in idx.iter().enumerate() {
            debug_assert!(d < self.shape[i], "index {idx:?} out of shape {:?}", self.shape);
            off = off * self.shape[i] + d;
        }
        off
    }

    /// Element read by multi-index.
    #[inline]
    pub fn at(&self, idx: &[usize]) -> T {
        self.data[self.offset(idx)]
    }

    /// Element write by multi-index.
    #[inline]
    pub fn set(&mut self, idx: &[usize], v: T) {
        let off = self.offset(idx);
        self.data[off] = v;
    }

    /// Reinterpret with a new shape of equal element count.
    pub fn reshape(&self, shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(n, self.data.len(), "reshape {:?} -> {shape:?}", self.shape);
        Tensor {
            shape: shape.to_vec(),
            data: self.data.clone(),
        }
    }

    /// Elementwise map into a (possibly different-typed) tensor.
    pub fn map<U: Copy + Default, F: Fn(T) -> U>(&self, f: F) -> Tensor<U> {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    /// Materialized transpose of a 2-D tensor.
    pub fn transpose2d(&self) -> Self {
        assert_eq!(self.shape.len(), 2, "transpose2d needs a matrix");
        let (m, n) = (self.shape[0], self.shape[1]);
        let mut data = vec![T::default(); m * n];
        for i in 0..m {
            for j in 0..n {
                data[j * m + i] = self.data[i * n + j];
            }
        }
        Tensor { shape: vec![n, m], data }
    }
}

impl TensorF32 {
    /// Gaussian-initialized tensor (He-style scale under `std`).
    pub fn randn(shape: &[usize], std: f32, rng: &mut crate::util::Rng) -> Self {
        let n: usize = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: (0..n).map(|_| rng.normal() * std).collect(),
        }
    }

    /// Fraction of exactly-zero elements.
    pub fn sparsity(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().filter(|&&x| x == 0.0).count() as f64 / self.data.len() as f64
    }
}

impl TensorI8 {
    /// Uniform random INT8 in [-127, 127].
    pub fn rand(shape: &[usize], rng: &mut crate::util::Rng) -> Self {
        let n: usize = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: (0..n).map(|_| rng.i8_sym()).collect(),
        }
    }

    /// Random with a given probability of zero per element (random sparsity).
    pub fn rand_sparse(shape: &[usize], p_zero: f32, rng: &mut crate::util::Rng) -> Self {
        let n: usize = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: (0..n)
                .map(|_| if rng.coin(p_zero) { 0 } else { rng.i8_sym() })
                .collect(),
        }
    }

    /// Fraction of exactly-zero elements.
    pub fn sparsity(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().filter(|&&x| x == 0).count() as f64 / self.data.len() as f64
    }
}

impl<T: fmt::Debug> fmt::Debug for Tensor<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}(n={})", self.shape, self.data.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn zeros_and_shape() {
        let t = TensorI32::zeros(&[2, 3, 4]);
        assert_eq!(t.shape(), &[2, 3, 4]);
        assert_eq!(t.len(), 24);
        assert!(t.data().iter().all(|&x| x == 0));
    }

    #[test]
    fn offset_row_major() {
        let t = TensorI32::zeros(&[2, 3, 4]);
        assert_eq!(t.offset(&[0, 0, 0]), 0);
        assert_eq!(t.offset(&[0, 0, 3]), 3);
        assert_eq!(t.offset(&[0, 1, 0]), 4);
        assert_eq!(t.offset(&[1, 0, 0]), 12);
        assert_eq!(t.offset(&[1, 2, 3]), 23);
    }

    #[test]
    fn set_at_roundtrip() {
        let mut t = TensorF32::zeros(&[3, 3]);
        t.set(&[1, 2], 7.5);
        assert_eq!(t.at(&[1, 2]), 7.5);
        assert_eq!(t.at(&[2, 1]), 0.0);
    }

    #[test]
    #[should_panic]
    fn from_vec_bad_len_panics() {
        let _ = TensorI8::from_vec(&[2, 2], vec![1, 2, 3]);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = TensorI32::from_vec(&[2, 3], vec![1, 2, 3, 4, 5, 6]);
        let r = t.reshape(&[3, 2]);
        assert_eq!(r.at(&[0, 1]), 2);
        assert_eq!(r.at(&[2, 1]), 6);
    }

    #[test]
    fn map_changes_type() {
        let t = TensorI8::from_vec(&[2], vec![-1, 2]);
        let f = t.map(|x| x as f32 * 2.0);
        assert_eq!(f.data(), &[-2.0, 4.0]);
    }

    #[test]
    fn rand_sparse_hits_target() {
        let mut rng = Rng::new(9);
        let t = TensorI8::rand_sparse(&[100, 100], 0.5, &mut rng);
        let s = t.sparsity();
        assert!((s - 0.5).abs() < 0.03, "sparsity={s}");
    }

    #[test]
    fn randn_scale() {
        let mut rng = Rng::new(10);
        let t = TensorF32::randn(&[10_000], 0.1, &mut rng);
        let var =
            t.data().iter().map(|x| (x * x) as f64).sum::<f64>() / t.len() as f64;
        assert!((var.sqrt() - 0.1).abs() < 0.01);
    }
}
