//! `ssta` — the leader binary: experiment drivers + the serving demo.
//!
//! ```text
//! ssta list                          # available experiments
//! ssta run <name>... [--quick|--csv] # regenerate paper tables/figures
//! ssta all [--quick]                 # every experiment in paper order
//! ssta serve [--requests N] [--design STR] [--xla [--artifacts DIR]]
//! ssta design <STR> [--nnz N --act S]   # inspect one design point
//! ```

use std::time::Instant;

use ssta::arch::Design;
use ssta::cli::Args;
use ssta::coordinator::{Config, Coordinator};
use ssta::harness;
use ssta::models;
use ssta::power;
use ssta::sim::accel::{network_timing, profile_model_fixed_act};
use ssta::util::Rng;

fn main() {
    let args = Args::from_env();
    let code = match args.command.as_deref() {
        Some("list") => {
            for e in harness::EXPERIMENTS {
                println!("{e}");
            }
            0
        }
        Some("run") => run_experiments(&args.positional, &args),
        Some("all") => {
            let names: Vec<String> =
                harness::EXPERIMENTS.iter().map(|s| s.to_string()).collect();
            run_experiments(&names, &args)
        }
        Some("serve") => serve(&args),
        Some("design") => inspect_design(&args),
        _ => {
            eprintln!(
                "usage: ssta <list|run|all|serve|design> [...]\n\
                 try: ssta run table5    ssta all --quick    ssta serve --requests 64"
            );
            2
        }
    };
    std::process::exit(code);
}

fn run_experiments(names: &[String], args: &Args) -> i32 {
    if names.is_empty() {
        eprintln!("no experiments named; try `ssta list`");
        return 2;
    }
    let quick = args.flag("quick");
    for name in names {
        let t0 = Instant::now();
        match harness::run(name, quick) {
            Some(tables) => {
                for t in &tables {
                    if args.flag("csv") {
                        println!("{}", t.to_csv());
                    } else {
                        println!("{}", t.render());
                    }
                }
                eprintln!("[{name}] done in {:.2?}", t0.elapsed());
            }
            None => {
                eprintln!("unknown experiment `{name}` — try `ssta list`");
                return 2;
            }
        }
    }
    0
}

fn serve(args: &Args) -> i32 {
    let n = args.opt_as::<usize>("requests", 64);
    let design = match Design::parse(args.opt("design").unwrap_or("4x8x8_8x8_VDBB_IM2C")) {
        Ok(d) => d,
        Err(e) => {
            eprintln!("bad --design: {e}");
            return 2;
        }
    };
    // default is the engine-native registry path (no artifacts needed);
    // --xla serves through the legacy PJRT artifact path instead
    let cfg = Config {
        artifacts_dir: args.opt("artifacts").unwrap_or("artifacts").into(),
        design,
        use_xla: args.flag("xla"),
        ..Config::default()
    };
    let coord = match Coordinator::start(cfg) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("coordinator failed to start: {e:#}");
            return 1;
        }
    };
    let h = coord.handle();
    let mut rng = Rng::new(42);
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..n)
        .map(|i| {
            let img: Vec<f32> = (0..32 * 32 * 3).map(|_| rng.f32()).collect();
            h.submit(i as u64, img).expect("submit")
        })
        .collect();
    let mut ok = 0;
    for rx in rxs {
        if rx.recv().is_ok() {
            ok += 1;
        }
    }
    let wall = t0.elapsed();
    let m = coord.metrics();
    println!(
        "served {ok}/{n} requests in {wall:.2?} ({:.1} req/s)",
        ok as f64 / wall.as_secs_f64()
    );
    println!("{}", m.summary());
    println!(
        "hardware twin ({}): {:.2} effective TOPS, {:.1} mW avg",
        design.label(),
        m.sim_effective_tops(design.tech.freq_hz()),
        m.sim_avg_power_w(design.tech.freq_hz()) * 1e3,
    );
    if coord.shutdown().is_err() {
        return 1;
    }
    0
}

fn inspect_design(args: &Args) -> i32 {
    let Some(spec) = args.positional.first() else {
        eprintln!("usage: ssta design <AxBxC_MxN[_VDBB][_IM2C]> [--nnz N --act S]");
        return 2;
    };
    let d = match Design::parse(spec) {
        Ok(d) => d,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let nnz = args.opt_as::<usize>("nnz", 3);
    let act = args.opt_as::<f64>("act", 0.5);
    let m = models::resnet50();
    let profiles = profile_model_fixed_act(&m, nnz, 8, act);
    let t = network_timing(&d, &profiles);
    let p = power::power(&d, &t.total);
    let a = power::area(&d);
    println!("design        {}", d.label());
    println!("MACs          {}", d.physical_macs());
    println!("nominal TOPS  {:.2}", d.nominal_tops());
    println!("workload      ResNet-50, {nnz}/8 DBB, {:.0}% act sparsity", act * 100.0);
    println!("cycles        {}", t.total.cycles);
    println!("effective TOPS {:.2}", t.effective_tops(&d));
    println!(
        "power mW      sta {:.1} + wsram {:.1} + asram {:.1} + mcu {:.1} + im2c {:.1} = {:.1}",
        p.sta_mw, p.wsram_mw, p.asram_mw, p.mcu_mw, p.im2col_mw, p.total_mw()
    );
    println!("area mm2      {:.3}", a.total_mm2());
    println!(
        "TOPS/W        {:.1}    TOPS/mm2 {:.2}",
        power::effective_tops_per_w(&d, &t.total, t.dense_macs),
        power::effective_tops_per_mm2(&d, &t.total, t.dense_macs)
    );
    0
}
