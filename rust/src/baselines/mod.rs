//! Comparison baselines for Table V.
//!
//! * [`smt_sa`] — our re-implementation of SMT-SA (Shomron et al., the only
//!   other sparse systolic array), as the paper also did ("we implemented
//!   the same design ourselves … with INT8 operands in 16nm").
//! * [`published`] — the published numbers for the remaining comparison
//!   rows (Laconic, SCNN, Kang, Eyeriss v2), clearly marked as constants
//!   from the literature, exactly as the paper cites them.

pub mod published;
pub mod smt_sa;
