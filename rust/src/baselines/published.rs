//! Published comparison-point constants for Table V — numbers reported in
//! the cited papers, reproduced verbatim (marked `published = true` in the
//! harness output). Our own rows and the SMT-SA re-implementation are
//! *measured* from the simulator + power model instead.

/// One comparison row.
#[derive(Debug, Clone)]
pub struct ComparisonRow {
    /// System name as cited.
    pub name: &'static str,
    /// Technology node label.
    pub tech: &'static str,
    /// SRAM description (activation / weight).
    pub sram: &'static str,
    /// Clock in GHz.
    pub freq_ghz: f64,
    /// Peak/nominal throughput in TOPS (None where unreported).
    pub tops: Option<f64>,
    /// Energy efficiency in effective TOPS/W (None where unreported).
    pub tops_per_w: Option<f64>,
    /// Area efficiency in TOPS/mm² (None where unreported).
    pub tops_per_mm2: Option<f64>,
    /// Weight-sparsity scheme.
    pub weight_sparsity: &'static str,
    /// Activation-sparsity scheme.
    pub act_sparsity: &'static str,
    /// True when the numbers are quoted from the publication rather than
    /// measured by this repo.
    pub published: bool,
}

/// The prior-work rows of Table V, 16 nm/15 nm group.
pub fn rows_16nm() -> Vec<ComparisonRow> {
    vec![
        ComparisonRow {
            name: "Laconic",
            tech: "15nm",
            sram: "2MB / 512KB",
            freq_ghz: 1.0,
            tops: None,
            tops_per_w: Some(1.997),
            tops_per_mm2: None,
            weight_sparsity: "Bit-wise",
            act_sparsity: "Bit-wise",
            published: true,
        },
        ComparisonRow {
            name: "SCNN",
            tech: "16nm",
            sram: "1.2MB / -",
            freq_ghz: 1.0,
            tops: Some(2.0),
            tops_per_w: Some(0.79),
            tops_per_mm2: Some(0.7),
            weight_sparsity: "Random",
            act_sparsity: "-",
            published: true,
        },
    ]
}

/// The prior-work rows of Table V, 65 nm group.
pub fn rows_65nm() -> Vec<ComparisonRow> {
    vec![
        ComparisonRow {
            name: "Kang et al.",
            tech: "65nm",
            sram: "58KB",
            freq_ghz: 1.0,
            tops: Some(0.5),
            tops_per_w: Some(1.65),
            tops_per_mm2: Some(1.01),
            weight_sparsity: "75% DBB (fixed)",
            act_sparsity: "-",
            published: true,
        },
        ComparisonRow {
            name: "Laconic",
            tech: "65nm",
            sram: "2MB / 512KB",
            freq_ghz: 1.0,
            tops: None,
            tops_per_w: Some(0.81),
            tops_per_mm2: None,
            weight_sparsity: "Bit-wise",
            act_sparsity: "Bit-wise",
            published: true,
        },
        ComparisonRow {
            name: "Eyeriss v2",
            tech: "65nm",
            sram: "246KB",
            freq_ghz: 0.2,
            tops: Some(0.40),
            tops_per_w: Some(0.96),
            tops_per_mm2: None, // "0.07/2.7M gates" — not mm²-comparable
            weight_sparsity: "Random",
            act_sparsity: "Random",
            published: true,
        },
    ]
}

/// Prior block-sparse (BSR-style) accelerator points — the comparison
/// group the BSR datapath rows are measured against. SPOTS prunes whole
/// weight tiles and schedules the surviving blocks through a systolic
/// GEMM after im2col, the same coarse-index scheme as our
/// [`crate::gemm::BsrPacked`] pipeline; its report quotes speedups over
/// dense/Eyeriss baselines rather than absolute TOPS/W, so the efficiency
/// columns stay unreported here and the measured comparison comes from our
/// own BSR rows in Table V.
pub fn rows_block_sparse() -> Vec<ComparisonRow> {
    vec![ComparisonRow {
        name: "SPOTS",
        tech: "45nm",
        sram: "-",
        freq_ghz: 1.0,
        tops: None,
        tops_per_w: None,
        tops_per_mm2: None,
        weight_sparsity: "Block (BSR)",
        act_sparsity: "im2col reuse",
        published: true,
    }]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_match_paper_table_v() {
        let r16 = rows_16nm();
        assert_eq!(r16.len(), 2);
        assert!((r16[0].tops_per_w.unwrap() - 1.997).abs() < 1e-9);
        let r65 = rows_65nm();
        assert_eq!(r65.len(), 3);
        assert!((r65[0].tops_per_w.unwrap() - 1.65).abs() < 1e-9);
        let rbsr = rows_block_sparse();
        assert_eq!(rbsr.len(), 1);
        assert_eq!(rbsr[0].weight_sparsity, "Block (BSR)");
        assert!(rbsr[0].tops_per_w.is_none(), "no invented numbers");
        assert!(r16.iter().chain(r65.iter()).chain(rbsr.iter()).all(|r| r.published));
    }
}
