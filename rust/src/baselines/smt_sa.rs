//! SMT-SA re-implementation (Shomron, Horowitz & Weiser, IEEE CAL 2019) —
//! a *random*-sparsity systolic array: each PE multiplexes T threads and
//! skips MACs whose operand pair contains a zero, buffering the incoming
//! operand streams in per-PE FIFOs. This is the paper's only
//! sparse-systolic-array comparison point (Table V row "SMT-SA²").
//!
//! Key contrasts with STA-VDBB that the model captures (paper §VII):
//! * speedup is *data dependent* and capped by the thread count T — random
//!   sparsity gives `min(T, 1/p_nz)` where `p_nz` is the probability both
//!   operands are non-zero, with load imbalance eroding the ideal;
//! * the per-PE FIFOs add area and energy that DBB's fixed-rate streams
//!   don't need ("largely due to the cost of the FIFOs required in the
//!   array").

use crate::sim::analytic::WeightStats;
use crate::sim::{EventCounts, GemmTiming};

/// SMT-SA configuration.
#[derive(Debug, Clone, Copy)]
pub struct SmtSa {
    /// Physical MAC count (iso-budget with our designs: 2048 at 4 TOPS).
    pub macs: usize,
    /// Threads per PE (the published design evaluates T = 2 and 4; 2 is
    /// the area-efficient point we compare at).
    pub threads: usize,
    /// FIFO depth per thread (area/energy overhead scales with this).
    pub fifo_depth: usize,
    /// Clock (Hz).
    pub freq_hz: f64,
}

impl Default for SmtSa {
    fn default() -> Self {
        SmtSa {
            macs: 2048,
            threads: 2,
            fifo_depth: 4,
            freq_hz: 1e9,
        }
    }
}

impl SmtSa {
    /// Probability a MAC can be skipped: either operand zero, for *random*
    /// (element-level) weight sparsity `ws` and activation sparsity `as_`.
    pub fn skip_probability(&self, ws: f64, as_: f64) -> f64 {
        1.0 - (1.0 - ws) * (1.0 - as_)
    }

    /// Effective speedup over the dense SA. Ideal is `1/p_nz` capped at the
    /// thread count; finite FIFOs lose some of that to load imbalance —
    /// modelled with the published ≈90% efficiency at depth 4.
    pub fn speedup(&self, ws: f64, as_: f64) -> f64 {
        let p_nz = (1.0 - self.skip_probability(ws, as_)).max(1e-9);
        let ideal = (1.0 / p_nz).min(self.threads as f64);
        let fifo_eff = 1.0 - 0.4 / self.fifo_depth as f64; // 0.9 at depth 4
        1.0 + (ideal - 1.0) * fifo_eff
    }

    /// Nominal TOPS (dense).
    pub fn nominal_tops(&self) -> f64 {
        2.0 * self.macs as f64 * self.freq_hz / 1e12
    }

    /// Effective TOPS at the given random sparsities.
    pub fn effective_tops(&self, ws: f64, as_: f64) -> f64 {
        self.nominal_tops() * self.speedup(ws, as_)
    }

    /// Timing of an `mg×k×n` GEMM with random weight sparsity `ws` and
    /// activation sparsity `as_` (API-compatible with the sim engines so
    /// the Table V harness can treat it uniformly).
    pub fn gemm_timing(&self, mg: usize, stats: &WeightStats, as_: f64) -> GemmTiming {
        // element-level weight sparsity for a DBB-pruned matrix
        let kn = (stats.k * stats.n) as f64;
        let ws = 1.0 - stats.total_nnz as f64 / kn;
        let dense_macs = mg as u64 * stats.k as u64 * stats.n as u64;
        let speed = self.speedup(ws, as_);
        let cycles = (dense_macs as f64 / (self.macs as f64 * speed)).ceil() as u64;
        let active = (dense_macs as f64 * (1.0 - self.skip_probability(ws, as_))) as u64;
        let slots = self.macs as u64 * cycles;
        GemmTiming {
            events: EventCounts {
                cycles,
                macs_active: active,
                macs_gated: dense_macs.saturating_sub(active),
                macs_idle: slots.saturating_sub(dense_macs),
                // random sparsity cannot compress the SRAM streams without
                // per-element indices: full dense traffic + index overhead
                weight_sram_bytes: (stats.k as u64 * stats.n as u64) * 9 / 8,
                act_sram_bytes: (mg * stats.k) as u64,
                act_index_bytes: 0,
                act_edge_bytes: (mg * stats.k) as u64,
                out_sram_bytes: 4 * (mg * stats.n) as u64,
                mux_selects: 0,
                mcu_cycles: 0,
                epilogue_cycles: 0,
            },
            dense_macs,
        }
    }

    /// FIFO storage bits across the array (two INT8 operand streams per
    /// thread per PE).
    pub fn fifo_bits(&self) -> usize {
        self.macs * self.threads * self.fifo_depth * 2 * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn speedup_capped_by_threads() {
        let s = SmtSa::default();
        // very sparse: ideal >> 2, capped at 2 (minus fifo loss)
        let sp = s.speedup(0.9, 0.9);
        assert!(sp <= 2.0 && sp > 1.85, "sp={sp}");
    }

    #[test]
    fn dense_data_no_speedup() {
        let s = SmtSa::default();
        assert!((s.speedup(0.0, 0.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn paper_operating_point_speedup() {
        // 62.5% random weight, 50% act: p_nz = 0.1875 -> ideal capped at 2
        let s = SmtSa::default();
        let sp = s.speedup(0.625, 0.5);
        assert!(sp > 1.85 && sp <= 2.0, "sp={sp}");
        // effective ≈ 8 TOPS from 4 nominal
        let eff = s.effective_tops(0.625, 0.5);
        assert!((7.5..8.3).contains(&eff), "eff={eff}");
    }

    #[test]
    fn gemm_timing_matches_speedup() {
        let s = SmtSa::default();
        let stats = WeightStats::synthetic(1024, 512, 8, 3);
        let t = s.gemm_timing(1024, &stats, 0.5);
        let macs_per_cycle = t.dense_macs as f64 / t.events.cycles as f64;
        let ws = 1.0 - 3.0 / 8.0 * 1.0; // element sparsity of 3/8-pruned
        let expect = s.macs as f64 * s.speedup(ws, 0.5);
        assert!((macs_per_cycle / expect - 1.0).abs() < 0.01);
    }

    #[test]
    fn fifo_bits_scale() {
        let s = SmtSa::default();
        assert_eq!(s.fifo_bits(), 2048 * 2 * 4 * 16);
    }

    #[test]
    fn no_weight_compression_in_sram() {
        let s = SmtSa::default();
        let sparse = WeightStats::synthetic(1024, 512, 8, 2);
        let dense = WeightStats::synthetic(1024, 512, 8, 8);
        let ts = s.gemm_timing(256, &sparse, 0.5);
        let td = s.gemm_timing(256, &dense, 0.5);
        // random-sparse SRAM traffic identical (indices, no compression)
        assert_eq!(ts.events.weight_sram_bytes, td.events.weight_sram_bytes);
    }
}
