//! Minimal command-line argument parser (offline substrate for `clap`).
//!
//! Supports the subcommand + flags shape the `ssta` binary and the bench
//! harnesses need: `ssta <command> [--flag] [--key value] [positional...]`.

use std::collections::BTreeMap;

/// Parsed arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First positional token (the subcommand), if any.
    pub command: Option<String>,
    /// Remaining positional tokens.
    pub positional: Vec<String>,
    /// `--key value` options.
    pub options: BTreeMap<String, String>,
    /// `--flag` booleans.
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of tokens (excluding argv[0]).
    ///
    /// A `--name` token followed by a token that does not start with `--`
    /// is an option; otherwise it is a flag. Everything else is positional.
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Args {
        let mut out = Args::default();
        let toks: Vec<String> = tokens.into_iter().collect();
        let mut i = 0;
        while i < toks.len() {
            let t = &toks[i];
            if let Some(name) = t.strip_prefix("--") {
                if i + 1 < toks.len() && !toks[i + 1].starts_with("--") {
                    out.options.insert(name.to_string(), toks[i + 1].clone());
                    i += 2;
                    continue;
                }
                out.flags.push(name.to_string());
            } else if out.command.is_none() {
                out.command = Some(t.clone());
            } else {
                out.positional.push(t.clone());
            }
            i += 1;
        }
        out
    }

    /// Parse the process arguments.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// Boolean flag present?
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Option value.
    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }

    /// Option parsed to a type, with default.
    pub fn opt_as<T: std::str::FromStr>(&self, name: &str, default: T) -> T {
        self.opt(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("table5 --csv --quick");
        assert_eq!(a.command.as_deref(), Some("table5"));
        assert!(a.flag("csv") && a.flag("quick"));
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn options_with_values() {
        let a = parse("serve --design 4x8x8_8x8_VDBB_IM2C --requests 100");
        assert_eq!(a.opt("design"), Some("4x8x8_8x8_VDBB_IM2C"));
        assert_eq!(a.opt_as::<usize>("requests", 0), 100);
        assert_eq!(a.opt_as::<usize>("missing", 7), 7);
    }

    #[test]
    fn positional_after_command() {
        let a = parse("run fig9 fig10");
        assert_eq!(a.command.as_deref(), Some("run"));
        assert_eq!(a.positional, vec!["fig9", "fig10"]);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("x --a --b v");
        assert!(a.flag("a"));
        assert_eq!(a.opt("b"), Some("v"));
    }

    #[test]
    fn empty_args() {
        let a = parse("");
        assert!(a.command.is_none());
        assert!(a.positional.is_empty());
    }
}
