//! Minimal property-based-testing harness (offline stand-in for `proptest`).
//!
//! Usage:
//! ```
//! use ssta::util::prop::{check, Config};
//! check(Config::default().cases(64), |rng| {
//!     let n = rng.below(100) + 1;
//!     assert!(n >= 1);
//! });
//! ```
//!
//! Each case gets a child RNG derived from a master seed; on panic the
//! harness reports the failing case seed so the exact input can be replayed
//! with [`replay`]. `SSTA_PROP_CASES` / `SSTA_PROP_SEED` environment
//! variables override the defaults, so CI can crank coverage up without code
//! changes.

use super::rng::Rng;
use std::panic::{catch_unwind, AssertUnwindSafe};

/// Harness configuration.
#[derive(Debug, Clone, Copy)]
pub struct Config {
    /// Number of generated cases.
    pub cases: u32,
    /// Master seed; every case seed derives from it.
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        let cases = std::env::var("SSTA_PROP_CASES")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(64);
        let seed = std::env::var("SSTA_PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0x5513_A001);
        Config { cases, seed }
    }
}

impl Config {
    /// Override the number of cases.
    pub fn cases(mut self, n: u32) -> Self {
        self.cases = n;
        self
    }

    /// Override the master seed.
    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }
}

/// Run `f` against `cfg.cases` seeded RNGs; panic with the failing case seed
/// on the first failure.
pub fn check<F>(cfg: Config, f: F)
where
    F: Fn(&mut Rng),
{
    let mut master = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        let case_seed = master.next_u64();
        let mut rng = Rng::new(case_seed);
        let result = catch_unwind(AssertUnwindSafe(|| f(&mut rng)));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property failed at case {case}/{} (replay with seed {case_seed:#x}): {msg}",
                cfg.cases
            );
        }
    }
}

/// Re-run a single failing case by its reported seed.
pub fn replay<F>(case_seed: u64, f: F)
where
    F: Fn(&mut Rng),
{
    let mut rng = Rng::new(case_seed);
    f(&mut rng);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0u32;
        // count via a cell: closure is Fn, use std::cell
        let count = std::cell::Cell::new(0u32);
        check(Config::default().cases(10).seed(1), |_| {
            count.set(count.get() + 1);
        });
        n += count.get();
        assert_eq!(n, 10);
    }

    #[test]
    fn failing_property_reports_seed() {
        let result = catch_unwind(AssertUnwindSafe(|| {
            check(Config::default().cases(10).seed(2), |rng| {
                // fails on ~half the cases
                assert!(rng.coin(0.5), "boom");
            });
        }));
        let err = result.expect_err("property should fail");
        let msg = err.downcast_ref::<String>().expect("string panic");
        assert!(msg.contains("replay with seed"), "msg={msg}");
    }

    #[test]
    fn replay_reproduces_case() {
        // find the failing seed, then replay must also fail
        let mut failing_seed = None;
        let mut master = Rng::new(3);
        for _ in 0..100 {
            let s = master.next_u64();
            let mut r = Rng::new(s);
            if !r.coin(0.5) {
                failing_seed = Some(s);
                break;
            }
        }
        let s = failing_seed.expect("found a failing seed");
        let result = catch_unwind(AssertUnwindSafe(|| {
            replay(s, |rng| assert!(rng.coin(0.5)));
        }));
        assert!(result.is_err());
    }
}
