//! Timing harness for `benches/*` (offline stand-in for `criterion`).
//!
//! Benches are `harness = false`: each bench binary builds a [`BenchSet`],
//! registers closures, and calls [`BenchSet::run`], which handles CLI filter
//! arguments (so `cargo bench -- fig9` runs only matching entries), warmup,
//! adaptive repetition and robust statistics.
//!
//! Besides the human-readable lines, [`BenchSet::run`] writes every timed
//! result to `BENCH_<set>.json` in the working directory (name,
//! median/mean/stddev in ns, sample counts) so the perf trajectory is
//! machine-readable — CI uploads the file as an artifact.

use super::json::Json;
use super::stats;
use std::collections::BTreeMap;
use std::hint::black_box;
use std::time::{Duration, Instant};

/// One timing measurement summary.
#[derive(Debug, Clone)]
pub struct Measurement {
    /// Benchmark id.
    pub name: String,
    /// Median wall time per iteration.
    pub median: Duration,
    /// Mean wall time per iteration.
    pub mean: Duration,
    /// Std-dev across samples.
    pub stddev: Duration,
    /// Number of timed samples.
    pub samples: usize,
    /// Iterations per sample.
    pub iters_per_sample: u64,
}

impl Measurement {
    /// Machine-readable view (ns-denominated; integers exact in f64 far
    /// beyond any realistic duration).
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("name".to_string(), Json::Str(self.name.clone()));
        m.insert("median_ns".to_string(), Json::Num(self.median.as_nanos() as f64));
        m.insert("mean_ns".to_string(), Json::Num(self.mean.as_nanos() as f64));
        m.insert("stddev_ns".to_string(), Json::Num(self.stddev.as_nanos() as f64));
        m.insert("samples".to_string(), Json::Num(self.samples as f64));
        m.insert("iters_per_sample".to_string(), Json::Num(self.iters_per_sample as f64));
        Json::Obj(m)
    }

    /// criterion-like one-line rendering.
    pub fn render(&self) -> String {
        format!(
            "{:<44} time: [{:>12} ± {:>10}] (median {:>12}, {} samples × {} iters)",
            self.name,
            fmt_dur(self.mean),
            fmt_dur(self.stddev),
            fmt_dur(self.median),
            self.samples,
            self.iters_per_sample
        )
    }
}

fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1_000.0 {
        format!("{ns:.1} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1_000_000.0)
    } else {
        format!("{:.3} s", ns / 1_000_000_000.0)
    }
}

/// Time a single closure: warm up for `warmup`, then take `samples` samples,
/// auto-scaling iterations so each sample lasts ≥ `min_sample`.
pub fn time_fn<F: FnMut()>(
    name: &str,
    warmup: Duration,
    min_sample: Duration,
    samples: usize,
    mut f: F,
) -> Measurement {
    // Warmup & calibration: figure out iterations per sample.
    let mut iters: u64 = 1;
    let warm_start = Instant::now();
    loop {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        let dt = t0.elapsed();
        if warm_start.elapsed() >= warmup && dt >= min_sample {
            break;
        }
        if dt < min_sample {
            // grow multiplicatively but avoid overshooting wildly
            let factor = (min_sample.as_nanos() as f64 / dt.as_nanos().max(1) as f64).min(10.0);
            iters = ((iters as f64 * factor).ceil() as u64).max(iters + 1);
        }
        if warm_start.elapsed() > warmup * 20 {
            break; // very slow body: give up growing, take what we have
        }
    }

    let mut per_iter: Vec<f64> = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        per_iter.push(t0.elapsed().as_secs_f64() / iters as f64);
    }
    Measurement {
        name: name.to_string(),
        median: Duration::from_secs_f64(stats::median(&per_iter)),
        mean: Duration::from_secs_f64(stats::mean(&per_iter)),
        stddev: Duration::from_secs_f64(stats::stddev(&per_iter)),
        samples,
        iters_per_sample: iters,
    }
}

/// Re-export of `std::hint::black_box` so benches only import this module.
pub fn bb<T>(x: T) -> T {
    black_box(x)
}

type BenchFn = Box<dyn FnMut()>;

/// A named set of benchmarks with CLI filtering — the bench-binary entry
/// point.
pub struct BenchSet {
    name: String,
    entries: Vec<(String, BenchFn)>,
    /// Report-only entries: run once, print their own output (used for the
    /// paper-table harness where the deliverable is the table itself).
    reports: Vec<(String, Box<dyn FnMut()>)>,
}

impl BenchSet {
    /// New bench set (name is informational).
    pub fn new(name: &str) -> Self {
        BenchSet {
            name: name.to_string(),
            entries: Vec::new(),
            reports: Vec::new(),
        }
    }

    /// Register a timed benchmark.
    pub fn bench<F: FnMut() + 'static>(&mut self, name: &str, f: F) -> &mut Self {
        self.entries.push((name.to_string(), Box::new(f)));
        self
    }

    /// Register a run-once report (prints a paper table/figure).
    pub fn report<F: FnMut() + 'static>(&mut self, name: &str, f: F) -> &mut Self {
        self.reports.push((name.to_string(), Box::new(f)));
        self
    }

    /// Parse CLI args (`cargo bench -- <filter>`), run matching entries,
    /// and write the timed results to `BENCH_<set>.json`.
    pub fn run(&mut self) {
        let args: Vec<String> = std::env::args().skip(1).collect();
        // cargo passes --bench; ignore flags, keep free-form filters
        let filters: Vec<&String> = args.iter().filter(|a| !a.starts_with('-')).collect();
        let matches = |name: &str| filters.is_empty() || filters.iter().any(|f| name.contains(*f));

        println!("== bench set: {} ==", self.name);
        for (name, f) in self.reports.iter_mut() {
            if matches(name) {
                println!("\n-- report: {name} --");
                f();
            }
        }
        let mut measured: Vec<Measurement> = Vec::new();
        for (name, f) in self.entries.iter_mut() {
            if matches(name) {
                let m = time_fn(
                    name,
                    Duration::from_millis(200),
                    Duration::from_millis(50),
                    10,
                    f,
                );
                println!("{}", m.render());
                measured.push(m);
            }
        }
        if !measured.is_empty() {
            let path = format!("BENCH_{}.json", self.name);
            let mut obj = BTreeMap::new();
            obj.insert("bench".to_string(), Json::Str(self.name.clone()));
            obj.insert(
                "results".to_string(),
                Json::Arr(measured.iter().map(Measurement::to_json).collect()),
            );
            match std::fs::write(&path, format!("{}\n", Json::Obj(obj))) {
                Ok(()) => println!("(machine-readable results → {path})"),
                Err(e) => eprintln!("(could not write {path}: {e})"),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_fn_measures_something() {
        let m = time_fn(
            "noop-ish",
            Duration::from_millis(5),
            Duration::from_millis(1),
            3,
            || {
                let n = bb(100u64);
                bb((0..n).sum::<u64>());
            },
        );
        assert_eq!(m.samples, 3);
        assert!(m.iters_per_sample >= 1);
        assert!(m.mean.as_nanos() > 0);
    }

    #[test]
    fn measurement_json_roundtrips() {
        let m = Measurement {
            name: "gemm/x".into(),
            median: Duration::from_micros(12),
            mean: Duration::from_micros(13),
            stddev: Duration::from_nanos(500),
            samples: 10,
            iters_per_sample: 4,
        };
        let j = m.to_json();
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("name").unwrap().as_str(), Some("gemm/x"));
        assert_eq!(parsed.get("median_ns").unwrap().as_f64(), Some(12_000.0));
        assert_eq!(parsed.get("mean_ns").unwrap().as_f64(), Some(13_000.0));
        assert_eq!(parsed.get("stddev_ns").unwrap().as_f64(), Some(500.0));
        assert_eq!(parsed.get("samples").unwrap().as_usize(), Some(10));
        assert_eq!(parsed.get("iters_per_sample").unwrap().as_usize(), Some(4));
    }

    #[test]
    fn fmt_dur_units() {
        assert!(fmt_dur(Duration::from_nanos(500)).contains("ns"));
        assert!(fmt_dur(Duration::from_micros(500)).contains("µs"));
        assert!(fmt_dur(Duration::from_millis(500)).contains("ms"));
        assert!(fmt_dur(Duration::from_secs(2)).contains(" s"));
    }
}
