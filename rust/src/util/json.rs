//! Minimal JSON parser/serializer (offline substrate for `serde_json`).
//!
//! The artifact manifest (`artifacts/manifest.json`, written by
//! `python/compile/aot.py`) and the harness's machine-readable outputs are
//! the only JSON consumers/producers in the system, so the supported
//! surface is the full JSON grammar but with f64 numbers only (ints are
//! exact up to 2⁵³ — artifact shapes are far below that).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any number (f64; integers exact to 2⁵³).
    Num(f64),
    /// String (escapes decoded).
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object (sorted keys — deterministic serialization).
    Obj(BTreeMap<String, Json>),
}

/// Parse errors with byte offsets.
#[derive(Debug, PartialEq, Eq)]
pub enum JsonError {
    /// Unexpected byte or EOF.
    Unexpected(usize),
    /// Trailing non-whitespace after the top-level value.
    Trailing(usize),
    /// Bad \u escape or number.
    Malformed(usize),
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            JsonError::Unexpected(p) => write!(f, "unexpected input at byte {p}"),
            JsonError::Trailing(p) => write!(f, "trailing garbage at byte {p}"),
            JsonError::Malformed(p) => write!(f, "malformed literal at byte {p}"),
        }
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// Parse a complete JSON document.
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let b = s.as_bytes();
        let mut p = 0usize;
        let v = parse_value(b, &mut p)?;
        skip_ws(b, &mut p);
        if p != b.len() {
            return Err(JsonError::Trailing(p));
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// String value.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Number value.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Integer value (rejects non-integral floats).
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if n.fract() == 0.0 && *n >= 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    /// Array items.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Object map.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
}

fn skip_ws(b: &[u8], p: &mut usize) {
    while *p < b.len() && matches!(b[*p], b' ' | b'\t' | b'\n' | b'\r') {
        *p += 1;
    }
}

fn parse_value(b: &[u8], p: &mut usize) -> Result<Json, JsonError> {
    skip_ws(b, p);
    match b.get(*p) {
        Some(b'{') => parse_obj(b, p),
        Some(b'[') => parse_arr(b, p),
        Some(b'"') => Ok(Json::Str(parse_str(b, p)?)),
        Some(b't') => parse_lit(b, p, b"true", Json::Bool(true)),
        Some(b'f') => parse_lit(b, p, b"false", Json::Bool(false)),
        Some(b'n') => parse_lit(b, p, b"null", Json::Null),
        Some(c) if c.is_ascii_digit() || *c == b'-' => parse_num(b, p),
        _ => Err(JsonError::Unexpected(*p)),
    }
}

fn parse_lit(b: &[u8], p: &mut usize, lit: &[u8], v: Json) -> Result<Json, JsonError> {
    if b.len() >= *p + lit.len() && &b[*p..*p + lit.len()] == lit {
        *p += lit.len();
        Ok(v)
    } else {
        Err(JsonError::Malformed(*p))
    }
}

fn parse_num(b: &[u8], p: &mut usize) -> Result<Json, JsonError> {
    let start = *p;
    if b.get(*p) == Some(&b'-') {
        *p += 1;
    }
    while *p < b.len()
        && (b[*p].is_ascii_digit() || matches!(b[*p], b'.' | b'e' | b'E' | b'+' | b'-'))
    {
        *p += 1;
    }
    std::str::from_utf8(&b[start..*p])
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .map(Json::Num)
        .ok_or(JsonError::Malformed(start))
}

fn parse_str(b: &[u8], p: &mut usize) -> Result<String, JsonError> {
    debug_assert_eq!(b[*p], b'"');
    *p += 1;
    let mut out = String::new();
    loop {
        match b.get(*p) {
            None => return Err(JsonError::Unexpected(*p)),
            Some(b'"') => {
                *p += 1;
                return Ok(out);
            }
            Some(b'\\') => {
                *p += 1;
                match b.get(*p) {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let hex = b
                            .get(*p + 1..*p + 5)
                            .and_then(|h| std::str::from_utf8(h).ok())
                            .and_then(|h| u32::from_str_radix(h, 16).ok())
                            .ok_or(JsonError::Malformed(*p))?;
                        out.push(char::from_u32(hex).unwrap_or('\u{fffd}'));
                        *p += 4;
                    }
                    _ => return Err(JsonError::Malformed(*p)),
                }
                *p += 1;
            }
            Some(_) => {
                // copy a full UTF-8 scalar
                let s = std::str::from_utf8(&b[*p..]).map_err(|_| JsonError::Malformed(*p))?;
                let c = s.chars().next().unwrap();
                out.push(c);
                *p += c.len_utf8();
            }
        }
    }
}

fn parse_arr(b: &[u8], p: &mut usize) -> Result<Json, JsonError> {
    *p += 1; // [
    let mut items = Vec::new();
    skip_ws(b, p);
    if b.get(*p) == Some(&b']') {
        *p += 1;
        return Ok(Json::Arr(items));
    }
    loop {
        items.push(parse_value(b, p)?);
        skip_ws(b, p);
        match b.get(*p) {
            Some(b',') => *p += 1,
            Some(b']') => {
                *p += 1;
                return Ok(Json::Arr(items));
            }
            _ => return Err(JsonError::Unexpected(*p)),
        }
    }
}

fn parse_obj(b: &[u8], p: &mut usize) -> Result<Json, JsonError> {
    *p += 1; // {
    let mut map = BTreeMap::new();
    skip_ws(b, p);
    if b.get(*p) == Some(&b'}') {
        *p += 1;
        return Ok(Json::Obj(map));
    }
    loop {
        skip_ws(b, p);
        if b.get(*p) != Some(&b'"') {
            return Err(JsonError::Unexpected(*p));
        }
        let key = parse_str(b, p)?;
        skip_ws(b, p);
        if b.get(*p) != Some(&b':') {
            return Err(JsonError::Unexpected(*p));
        }
        *p += 1;
        map.insert(key, parse_value(b, p)?);
        skip_ws(b, p);
        match b.get(*p) {
            Some(b',') => *p += 1,
            Some(b'}') => {
                *p += 1;
                return Ok(Json::Obj(map));
            }
            _ => return Err(JsonError::Unexpected(*p)),
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\r' => write!(f, "\\r")?,
                        '\t' => write!(f, "\\t")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Config};

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("x")
        );
        assert_eq!(j.get("c"), Some(&Json::Null));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("truefalse").is_err());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(Json::parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
        assert_eq!(Json::parse("\"π\"").unwrap(), Json::Str("π".into()));
    }

    #[test]
    fn display_roundtrip_prop() {
        // random value trees serialize then re-parse identically
        check(Config::default().cases(100), |rng| {
            fn gen(rng: &mut crate::util::Rng, depth: usize) -> Json {
                match if depth > 3 { rng.below(4) } else { rng.below(6) } {
                    0 => Json::Null,
                    1 => Json::Bool(rng.below(2) == 0),
                    2 => Json::Num((rng.below(2_000_001) as f64) - 1_000_000.0),
                    3 => Json::Str(format!("s{}\"\\\n{}", rng.below(100), rng.below(10))),
                    4 => Json::Arr((0..rng.below(4)).map(|_| gen(rng, depth + 1)).collect()),
                    _ => Json::Obj(
                        (0..rng.below(4))
                            .map(|i| (format!("k{i}"), gen(rng, depth + 1)))
                            .collect(),
                    ),
                }
            }
            let v = gen(rng, 0);
            let s = v.to_string();
            assert_eq!(Json::parse(&s).unwrap(), v, "serialized: {s}");
        });
    }

    #[test]
    fn real_manifest_shape() {
        let doc = r#"{
          "convnet5_b1": {"entry": "convnet5", "batch": 1,
            "inputs": [{"shape": [1,32,32,3], "dtype": "f32"}],
            "file": "convnet5_b1.hlo.txt"}
        }"#;
        let j = Json::parse(doc).unwrap();
        let m = j.get("convnet5_b1").unwrap();
        assert_eq!(m.get("batch").unwrap().as_usize(), Some(1));
        let shape = m.get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap();
        let dims: Vec<usize> =
            shape.as_arr().unwrap().iter().map(|d| d.as_usize().unwrap()).collect();
        assert_eq!(dims, vec![1, 32, 32, 3]);
    }
}
