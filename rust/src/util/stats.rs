//! Small numeric-statistics helpers shared by the bench harness and the
//! experiment drivers.

/// Arithmetic mean. Returns 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// p-th percentile (0..=100) by linear interpolation on a sorted copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (s.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        let frac = rank - lo as f64;
        s[lo] * (1.0 - frac) + s[hi] * frac
    }
}

/// Median (50th percentile).
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Geometric mean of strictly positive values.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_median_basic() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(mean(&xs), 3.0);
        assert_eq!(median(&xs), 3.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert_eq!(percentile(&xs, 50.0), 5.0);
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 100.0), 10.0);
    }

    #[test]
    fn stddev_constant_is_zero() {
        let xs = [4.0, 4.0, 4.0];
        assert_eq!(stddev(&xs), 0.0);
    }

    #[test]
    fn geomean_powers_of_two() {
        let xs = [1.0, 4.0];
        assert!((geomean(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_inputs_are_zero() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(median(&[]), 0.0);
        assert_eq!(stddev(&[]), 0.0);
    }
}
