//! Deterministic SplitMix64 PRNG.
//!
//! Every synthetic tensor, dataset, and workload in this repo is produced
//! through [`Rng`], so all experiments are reproducible from a single seed.
//! SplitMix64 (Steele, Lea & Flood 2014) passes BigCrush, needs no warmup,
//! and is a few instructions per draw — good enough for data generation (we
//! make no cryptographic claims).

/// SplitMix64 pseudo-random generator.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create a generator from a seed. Equal seeds give equal streams.
    pub fn new(seed: u64) -> Self {
        Rng { state: seed }
    }

    /// Next raw 64-bit draw.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Next 32-bit draw (upper half of a 64-bit draw).
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, n)`. Uses Lemire's multiply-shift rejection-free
    /// mapping (bias < 2^-32 for the `n` we use; fine for data generation).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform in `[lo, hi]` inclusive.
    pub fn range_i32(&mut self, lo: i32, hi: i32) -> i32 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as usize) as i32
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.f32() * (hi - lo)
    }

    /// Approximately standard-normal f32 (sum of 12 uniforms, Irwin-Hall).
    /// Cheap, deterministic, and plenty for weight init / noisy datasets.
    pub fn normal(&mut self) -> f32 {
        let mut s = 0.0f32;
        for _ in 0..12 {
            s += self.f32();
        }
        s - 6.0
    }

    /// Bernoulli draw with probability `p` of `true`.
    pub fn coin(&mut self, p: f32) -> bool {
        self.f32() < p
    }

    /// Random INT8 value in `[-127, 127]` (symmetric; -128 excluded to match
    /// symmetric-quantized CNN weights).
    pub fn i8_sym(&mut self) -> i8 {
        self.range_i32(-127, 127) as i8
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Choose `k` distinct indices out of `n` (sorted). Used to place
    /// non-zeros inside a DBB block.
    pub fn choose_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        debug_assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        let mut picked = idx[..k].to_vec();
        picked.sort_unstable();
        picked
    }

    /// Split off an independent child generator (for parallel streams).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_stream() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.below(13);
            assert!(v < 13);
        }
    }

    #[test]
    fn f32_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let v = r.f32();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn uniform_mean_close_to_half() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mean: f32 = (0..n).map(|_| r.f32()).sum::<f32>() / n as f32;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 100_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn choose_indices_distinct_sorted() {
        let mut r = Rng::new(5);
        for _ in 0..100 {
            let k = r.below(8) + 1;
            let idx = r.choose_indices(8, k);
            assert_eq!(idx.len(), k);
            for w in idx.windows(2) {
                assert!(w[0] < w[1]);
            }
            assert!(idx.iter().all(|&i| i < 8));
        }
    }

    #[test]
    fn coin_probability() {
        let mut r = Rng::new(17);
        let n = 100_000;
        let heads = (0..n).filter(|_| r.coin(0.3)).count();
        let p = heads as f32 / n as f32;
        assert!((p - 0.3).abs() < 0.01, "p={p}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(23);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<u32>>());
    }
}
