//! Minimal little-endian flat-binary reader/writer (offline substrate for
//! `byteorder`/`bincode`), used by the prepared-model persistence format
//! (`engine::PreparedModel::{save, load}`).
//!
//! Design constraints, in order:
//!
//! * **Untrusted input never panics.** Every [`BinReader`] accessor is
//!   bounds-checked and returns a [`Result`]; length prefixes are validated
//!   against the bytes actually remaining *before* any allocation, so a
//!   corrupted or truncated header cannot trigger an out-of-bounds slice or
//!   a multi-gigabyte `Vec::with_capacity`.
//! * **Byte-stable.** All integers are little-endian, `f64` is its IEEE-754
//!   bit pattern, `usize` travels as `u64` — the on-disk form is identical
//!   across hosts, so a prepared model saved on one machine loads on
//!   another.
//! * **No dependencies.** Plain `Vec<u8>` in, `&[u8]` out.

use crate::util::error::{bail, Result};

/// Append-only little-endian byte-stream writer.
#[derive(Debug, Default)]
pub struct BinWriter {
    buf: Vec<u8>,
}

impl BinWriter {
    /// Empty writer.
    pub fn new() -> Self {
        BinWriter { buf: Vec::new() }
    }

    /// Bytes written so far.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// Whether nothing has been written.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// The accumulated bytes.
    pub fn into_vec(self) -> Vec<u8> {
        self.buf
    }

    /// Raw bytes, unprefixed (fixed-size fields like the magic).
    pub fn bytes(&mut self, b: &[u8]) {
        self.buf.extend_from_slice(b);
    }

    /// One byte.
    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    /// Little-endian `u32`.
    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Little-endian `u64`.
    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// `usize` as little-endian `u64` (byte-stable across hosts).
    pub fn usize(&mut self, v: usize) {
        self.u64(v as u64);
    }

    /// IEEE-754 bit pattern of an `f64` (round-trips NaN payloads too).
    pub fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }

    /// Length-prefixed UTF-8 string (`u64` byte length + bytes).
    pub fn str(&mut self, s: &str) {
        self.usize(s.len());
        self.bytes(s.as_bytes());
    }

    /// Length-prefixed `i8` slice.
    pub fn i8_slice(&mut self, v: &[i8]) {
        self.usize(v.len());
        // i8 → u8 is a bit-preserving cast element-wise
        self.buf.extend(v.iter().map(|&b| b as u8));
    }
}

/// Bounds-checked little-endian reader over a borrowed byte slice.
#[derive(Debug)]
pub struct BinReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> BinReader<'a> {
    /// Reader over `buf`, positioned at the start.
    pub fn new(buf: &'a [u8]) -> Self {
        BinReader { buf, pos: 0 }
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Current read offset.
    pub fn pos(&self) -> usize {
        self.pos
    }

    /// Take `n` raw bytes.
    pub fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        if n > self.remaining() {
            bail!(
                "truncated stream: need {n} bytes at offset {}, only {} remain",
                self.pos,
                self.remaining()
            );
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    /// One byte.
    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.bytes(1)?[0])
    }

    /// Little-endian `u32`.
    pub fn u32(&mut self) -> Result<u32> {
        let b = self.bytes(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    /// Little-endian `u64`.
    pub fn u64(&mut self) -> Result<u64> {
        let b = self.bytes(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    /// `u64` narrowed to `usize` (fails on 32-bit overflow rather than
    /// truncating).
    pub fn usize(&mut self) -> Result<usize> {
        let v = self.u64()?;
        usize::try_from(v).map_err(|_| crate::anyhow!("length {v} overflows usize"))
    }

    /// IEEE-754 `f64` from its bit pattern.
    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    /// A length prefix for elements of `elem_bytes` each, validated against
    /// the remaining input so a corrupted count cannot drive a huge
    /// allocation or a later out-of-bounds read.
    pub fn len_prefix(&mut self, elem_bytes: usize) -> Result<usize> {
        let n = self.usize()?;
        let need = n.checked_mul(elem_bytes.max(1)).unwrap_or(usize::MAX);
        if need > self.remaining() {
            bail!(
                "corrupt length prefix: {n} elements x {elem_bytes} B exceed the {} bytes \
                 remaining at offset {}",
                self.remaining(),
                self.pos
            );
        }
        Ok(n)
    }

    /// Length-prefixed UTF-8 string.
    pub fn str(&mut self) -> Result<String> {
        let n = self.len_prefix(1)?;
        let b = self.bytes(n)?;
        String::from_utf8(b.to_vec()).map_err(|_| crate::anyhow!("invalid UTF-8 in string field"))
    }

    /// Length-prefixed `i8` vector.
    pub fn i8_vec(&mut self) -> Result<Vec<i8>> {
        let n = self.len_prefix(1)?;
        Ok(self.bytes(n)?.iter().map(|&b| b as i8).collect())
    }

    /// Length-prefixed `u32` vector.
    pub fn u32_vec(&mut self) -> Result<Vec<u32>> {
        let n = self.len_prefix(4)?;
        (0..n).map(|_| self.u32()).collect()
    }

    /// Length-prefixed `u64`-encoded `usize` vector.
    pub fn usize_vec(&mut self) -> Result<Vec<usize>> {
        let n = self.len_prefix(8)?;
        (0..n).map(|_| self.usize()).collect()
    }

    /// Length-prefixed `f64` vector.
    pub fn f64_vec(&mut self) -> Result<Vec<f64>> {
        let n = self.len_prefix(8)?;
        (0..n).map(|_| self.f64()).collect()
    }
}

/// FNV-1a 64-bit hash — the persistence format's whole-file integrity
/// checksum (corruption detection, not cryptographic).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_every_field_kind() {
        let mut w = BinWriter::new();
        w.bytes(b"MAGIC");
        w.u8(7);
        w.u32(0xdead_beef);
        w.u64(u64::MAX - 1);
        w.usize(12345);
        w.f64(-0.125);
        w.str("hello ∞");
        w.i8_slice(&[-128, -1, 0, 1, 127]);
        let bytes = w.into_vec();

        let mut r = BinReader::new(&bytes);
        assert_eq!(r.bytes(5).unwrap(), b"MAGIC");
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u32().unwrap(), 0xdead_beef);
        assert_eq!(r.u64().unwrap(), u64::MAX - 1);
        assert_eq!(r.usize().unwrap(), 12345);
        assert_eq!(r.f64().unwrap(), -0.125);
        assert_eq!(r.str().unwrap(), "hello ∞");
        assert_eq!(r.i8_vec().unwrap(), vec![-128, -1, 0, 1, 127]);
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn vectors_roundtrip() {
        let mut w = BinWriter::new();
        let u32s = vec![0u32, 7, u32::MAX];
        let usizes = vec![0usize, 1, 1 << 40];
        let f64s = vec![0.0, -1.5, f64::INFINITY];
        w.usize(u32s.len());
        for &v in &u32s {
            w.u32(v);
        }
        w.usize(usizes.len());
        for &v in &usizes {
            w.usize(v);
        }
        w.usize(f64s.len());
        for &v in &f64s {
            w.f64(v);
        }
        let bytes = w.into_vec();
        let mut r = BinReader::new(&bytes);
        assert_eq!(r.u32_vec().unwrap(), u32s);
        assert_eq!(r.usize_vec().unwrap(), usizes);
        assert_eq!(r.f64_vec().unwrap(), f64s);
    }

    #[test]
    fn truncated_reads_error_cleanly() {
        let mut w = BinWriter::new();
        w.u64(42);
        let bytes = w.into_vec();
        // every strict prefix must fail with an Err, never panic
        for cut in 0..bytes.len() {
            let mut r = BinReader::new(&bytes[..cut]);
            assert!(r.u64().is_err(), "cut={cut}");
        }
    }

    #[test]
    fn corrupt_length_prefix_rejected_before_allocation() {
        let mut w = BinWriter::new();
        w.usize(usize::MAX / 2); // claims ~9e18 elements
        w.u32(1);
        let bytes = w.into_vec();
        let mut r = BinReader::new(&bytes);
        let e = r.u32_vec().err().expect("absurd length must be rejected");
        assert!(e.to_string().contains("length"), "{e}");
        // a huge count whose byte product overflows is also caught
        let mut w = BinWriter::new();
        w.u64(u64::MAX);
        let bytes = w.into_vec();
        let mut r = BinReader::new(&bytes);
        assert!(r.usize_vec().is_err());
    }

    #[test]
    fn fnv1a64_is_stable() {
        // pinned reference values (RFC draft test vectors)
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_ne!(fnv1a64(b"abc"), fnv1a64(b"acb"));
    }
}
