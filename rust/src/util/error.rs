//! Minimal error type + context helpers (offline substrate for `anyhow`).
//!
//! The runtime and coordinator layers need ad-hoc, message-carrying errors
//! with context chaining; `anyhow` is unavailable offline, so this module
//! provides the surface those layers use: an opaque [`Error`], the
//! [`Result`] alias with a defaulted error type, the [`anyhow!`]/[`bail!`]
//! macros and a [`Context`] extension trait for `Result`.
//!
//! Context is flattened into a single `outer: inner` message string rather
//! than a source chain — every consumer in this crate only ever formats the
//! error, so the chain structure would be dead weight.

use std::fmt;

/// An opaque, message-carrying error.
#[derive(Clone, PartialEq, Eq)]
pub struct Error(String);

impl Error {
    /// Build from any displayable message.
    pub fn msg(m: impl fmt::Display) -> Error {
        Error(m.to_string())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

/// `Result` with [`Error`] as the default error type (mirrors
/// `anyhow::Result`).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to an error, `anyhow::Context`-style: the context message
/// is prepended (`"context: cause"`).
pub trait Context<T> {
    /// Wrap the error with a fixed context message.
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    /// Wrap the error with a lazily built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, ctx: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error(format!("{ctx}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, ctx: F) -> Result<T> {
        self.map_err(|e| Error(format!("{}: {e}", ctx())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error(ctx.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, ctx: F) -> Result<T> {
        self.ok_or_else(|| Error(ctx().to_string()))
    }
}

/// Build an [`Error`] from a format string (mirrors `anyhow::anyhow!`).
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(::core::format_args!($($arg)*))
    };
}

/// Early-return an [`Error`] built from a format string (mirrors
/// `anyhow::bail!`).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

pub use crate::{anyhow, bail};

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<u32> {
        bail!("broke with code {}", 7)
    }

    #[test]
    fn bail_and_display() {
        let e = fails().unwrap_err();
        assert_eq!(e.to_string(), "broke with code 7");
        assert_eq!(format!("{e:?}"), "broke with code 7");
        assert_eq!(format!("{e:#}"), "broke with code 7");
    }

    #[test]
    fn context_prepends() {
        let r: Result<()> = Err(anyhow!("root cause")).context("opening manifest");
        assert_eq!(r.unwrap_err().to_string(), "opening manifest: root cause");
        let r2: Result<()> = Err(anyhow!("inner")).with_context(|| format!("step {}", 3));
        assert_eq!(r2.unwrap_err().to_string(), "step 3: inner");
    }

    #[test]
    fn context_on_foreign_error_types() {
        let io: std::result::Result<(), std::io::Error> = Err(std::io::Error::new(
            std::io::ErrorKind::NotFound,
            "no such file",
        ));
        let e = io.context("reading config").unwrap_err();
        assert!(e.to_string().starts_with("reading config: "));
        assert!(e.to_string().contains("no such file"));
    }

    #[test]
    fn context_on_option() {
        let v: Option<u8> = None;
        assert_eq!(v.context("missing field").unwrap_err().to_string(), "missing field");
        assert_eq!(Some(5u8).context("unused").unwrap(), 5);
    }

    #[test]
    fn question_mark_propagates() {
        fn outer() -> Result<u32> {
            let v = fails()?;
            Ok(v)
        }
        assert!(outer().is_err());
    }
}
