//! Dependency-free data-parallel helpers built on `std::thread::scope`
//! (offline substrate for `rayon`).
//!
//! Two primitives cover every hot path in the crate:
//!
//! * [`Parallelism`] — the thread-count knob. Defaults to
//!   `std::thread::available_parallelism()`; `Parallelism::serial()` (1
//!   thread) is the exact-fallback that bypasses thread spawning entirely,
//!   so serial results stay byte-for-byte reproducible and debuggable.
//!   [`Parallelism::with_pin`] adds opt-in worker→core affinity pinning
//!   (Linux `sched_setaffinity`, best-effort, scheduling-only — never
//!   affects results): worker `i` of every pool pins to core `i % cores`,
//!   so per-worker scratch arenas (the fused conv engine's `PatchScratch`)
//!   stay hot in the same core's cache across steady-state calls. Pinning
//!   pairs with *first-touch* arena allocation: the conv/tiled workers
//!   size their scratch (`resize`/`vec!`) **inside** the spawned closure,
//!   after `pin_worker`, so the first write — and hence the backing pages
//!   on first-touch NUMA policies — lands on the worker's own node rather
//!   than the node of the thread that built the scratch.
//! * [`map_indexed`] — evaluate `f(0..n)` across a scoped worker pool with a
//!   shared atomic work queue (one index per task — good load balance when
//!   task costs vary, e.g. design points with different occupancies), and
//!   return the results in index order.
//!
//! `crate::gemm::tiled` adds the third pattern (disjoint `&mut` output
//! tiles via `chunks_mut`) directly where the output buffer lives.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Worker-pool size configuration, plus the core-affinity knob.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Parallelism {
    threads: usize,
    pin: bool,
}

impl Parallelism {
    /// Use the host's available parallelism (≥ 1).
    pub fn auto() -> Parallelism {
        let threads = std::thread::available_parallelism()
            .map(std::num::NonZeroUsize::get)
            .unwrap_or(1);
        Parallelism { threads, pin: false }
    }

    /// Serial execution: no worker threads are spawned at all.
    pub fn serial() -> Parallelism {
        Parallelism { threads: 1, pin: false }
    }

    /// Exactly `n` worker threads (clamped to ≥ 1).
    pub fn threads(n: usize) -> Parallelism {
        Parallelism { threads: n.max(1), pin: false }
    }

    /// Configured thread count.
    pub fn get(&self) -> usize {
        self.threads
    }

    /// Enable/disable worker→core affinity pinning (default off). When on,
    /// worker `i` of every pool built from this knob pins itself to core
    /// `i % cores` before touching its tile — so a steady-state executor's
    /// per-worker scratch (the fused conv's `PatchScratch` row buffers)
    /// keeps meeting the same L1/L2 across calls. Pinning never affects
    /// results (it is scheduling only) and is best-effort: hosts where
    /// affinity syscalls are unavailable or denied run unpinned.
    pub fn with_pin(mut self, pin: bool) -> Parallelism {
        self.pin = pin;
        self
    }

    /// Whether worker→core pinning is enabled.
    pub fn pin(&self) -> bool {
        self.pin
    }

    /// Pin the calling worker thread (index `idx` of its pool) to a core,
    /// if pinning is enabled. Called by every pool scaffold right after
    /// spawn; a no-op when disabled, best-effort when enabled.
    pub(crate) fn pin_worker(&self, idx: usize) {
        if self.pin {
            pin_current_to(idx);
        }
    }
}

/// Best-effort: pin the calling thread to core `worker % cores` (Linux
/// `sched_setaffinity`; other platforms — and miri, which cannot shim the
/// raw syscall — are a no-op). Returns whether the pin took effect.
/// Failure is fine — e.g. a cgroup/sandbox that restricts the affinity
/// mask — the thread just stays under the default scheduler.
pub fn pin_current_to(worker: usize) -> bool {
    #[cfg(all(target_os = "linux", not(miri)))]
    {
        let cores = std::thread::available_parallelism()
            .map(std::num::NonZeroUsize::get)
            .unwrap_or(1);
        let cpu = worker % cores;
        // glibc cpu_set_t: a 1024-bit (128-byte) mask; pid 0 = this thread.
        let mut mask = [0u8; 128];
        mask[cpu / 8] |= 1 << (cpu % 8);
        extern "C" {
            fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u8) -> i32;
        }
        // SAFETY: the mask pointer is valid for `cpusetsize` bytes for the
        // duration of the call; the syscall only reads it.
        unsafe { sched_setaffinity(0, mask.len(), mask.as_ptr()) == 0 }
    }
    #[cfg(not(all(target_os = "linux", not(miri))))]
    {
        let _ = worker;
        false
    }
}

impl Default for Parallelism {
    fn default() -> Self {
        Parallelism::auto()
    }
}

/// Evaluate `f(i)` for every `i in 0..n` on the worker pool and collect the
/// results in index order. Work is distributed through a shared atomic
/// counter, one index per claim, so uneven task costs balance naturally.
///
/// With `par` serial (or `n <= 1`) this runs inline with no threads — the
/// exact serial fallback.
///
/// Panics in `f` are propagated (the pool joins every worker first).
pub fn map_indexed<T, F>(n: usize, par: Parallelism, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = par.get().min(n);
    if threads <= 1 {
        return (0..n).map(f).collect();
    }

    let next = AtomicUsize::new(0);
    let fref = &f;
    let nextref = &next;
    let parts: Vec<Vec<(usize, T)>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..threads)
            .map(|wi| {
                s.spawn(move || {
                    par.pin_worker(wi);
                    let mut local = Vec::new();
                    loop {
                        let i = nextref.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        local.push((i, fref(i)));
                    }
                    local
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| match h.join() {
                Ok(local) => local,
                // re-raise with the original payload so the caller sees the
                // real assertion message, not a generic pool error
                Err(payload) => std::panic::resume_unwind(payload),
            })
            .collect()
    });

    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    for (i, v) in parts.into_iter().flatten() {
        out[i] = Some(v);
    }
    out.into_iter()
        .map(|v| v.expect("work queue covered every index"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn auto_is_at_least_one() {
        assert!(Parallelism::auto().get() >= 1);
        assert_eq!(Parallelism::serial().get(), 1);
        assert_eq!(Parallelism::threads(0).get(), 1);
        assert_eq!(Parallelism::threads(6).get(), 6);
    }

    #[test]
    fn pin_knob_defaults_off_and_round_trips() {
        assert!(!Parallelism::auto().pin());
        assert!(!Parallelism::serial().pin());
        assert!(Parallelism::threads(4).with_pin(true).pin());
        assert!(!Parallelism::threads(4).with_pin(true).with_pin(false).pin());
        // thread count survives the pin toggle
        assert_eq!(Parallelism::threads(4).with_pin(true).get(), 4);
    }

    #[test]
    fn pinned_pool_results_are_identical() {
        // pinning is scheduling-only: same values, same order, and a
        // best-effort no-op on hosts that deny the affinity syscall
        let want: Vec<usize> = (0..53).map(|i| i * 3 + 1).collect();
        for t in [1usize, 2, 4] {
            let got = map_indexed(53, Parallelism::threads(t).with_pin(true), |i| i * 3 + 1);
            assert_eq!(got, want, "threads={t}");
        }
    }

    #[test]
    fn pin_current_is_best_effort() {
        // must never panic, whatever the host allows; on non-Linux it is
        // always false
        let _ = pin_current_to(0);
        let _ = pin_current_to(usize::MAX - 3);
    }

    #[test]
    fn map_preserves_index_order() {
        for t in [1usize, 2, 3, 8] {
            let got = map_indexed(37, Parallelism::threads(t), |i| i * i);
            let want: Vec<usize> = (0..37).map(|i| i * i).collect();
            assert_eq!(got, want, "threads={t}");
        }
    }

    #[test]
    fn fewer_items_than_threads() {
        let got = map_indexed(2, Parallelism::threads(8), |i| i + 10);
        assert_eq!(got, vec![10, 11]);
        let empty = map_indexed(0, Parallelism::threads(4), |i| i);
        assert!(empty.is_empty());
    }

    #[test]
    fn every_index_claimed_exactly_once() {
        use std::sync::atomic::AtomicU32;
        let calls = AtomicU32::new(0);
        let n = 101;
        let got = map_indexed(n, Parallelism::threads(4), |i| {
            calls.fetch_add(1, Ordering::Relaxed);
            i
        });
        assert_eq!(calls.load(Ordering::Relaxed), n as u32);
        assert_eq!(got, (0..n).collect::<Vec<_>>());
    }

    #[test]
    fn uneven_task_costs_still_complete() {
        // tasks with wildly different costs (the design-space sweep shape)
        let got = map_indexed(16, Parallelism::threads(4), |i| {
            let mut acc = 0u64;
            for k in 0..(i * 10_000) {
                acc = acc.wrapping_add(k as u64);
            }
            (i, acc)
        });
        for (i, (gi, _)) in got.iter().enumerate() {
            assert_eq!(i, *gi);
        }
    }
}
