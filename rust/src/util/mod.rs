//! Cross-cutting utilities.
//!
//! The build environment is fully offline, so the usual ecosystem crates
//! (`rand`, `proptest`, `criterion`, `prettytable`) are unavailable. This
//! module provides the minimal, well-tested in-tree replacements the rest of
//! the crate relies on:
//!
//! * [`rng`] — deterministic SplitMix64 PRNG (seedable, serializable state),
//!   used for every piece of synthetic data in the repo so experiments are
//!   reproducible bit-for-bit.
//! * [`prop`] — a small property-based-testing harness (seeded case
//!   generation, failure-seed reporting) standing in for `proptest`.
//! * [`bench`] — a timing harness with warmup, repetition and robust
//!   statistics standing in for `criterion`; used by `benches/*` which are
//!   `harness = false`.
//! * [`table`] — fixed-width ASCII table rendering for the experiment
//!   harness output (the "same rows the paper reports").
//! * [`stats`] — mean/median/percentile helpers.
//! * [`json`] — minimal JSON parse/serialize for the artifact manifest
//!   (standing in for `serde_json`).
//! * [`error`] — message-carrying error + context chaining (standing in for
//!   `anyhow`), used by the runtime and coordinator layers.
//! * [`bin`] — bounds-checked little-endian flat-binary reader/writer
//!   (standing in for `byteorder`/`bincode`), used by the prepared-model
//!   persistence format.
//! * [`par`] — scoped-thread worker pool and the [`par::Parallelism`] knob
//!   (standing in for `rayon`), used by the tiled GEMMs, the layer profiler
//!   and the design-space sweep.

pub mod bench;
pub mod bin;
pub mod error;
pub mod json;
pub mod par;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;

pub use par::Parallelism;
pub use rng::Rng;
