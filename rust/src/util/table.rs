//! Fixed-width ASCII table rendering for the experiment harness.
//!
//! The harness prints "the same rows the paper reports"; this keeps that
//! output aligned and greppable, and can also emit CSV for plotting.

/// A simple column-aligned table.
#[derive(Debug, Default, Clone)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with a title line.
    pub fn new(title: &str) -> Self {
        Table {
            title: title.to_string(),
            ..Default::default()
        }
    }

    /// Set the header row.
    pub fn header<S: ToString>(&mut self, cols: &[S]) -> &mut Self {
        self.header = cols.iter().map(|c| c.to_string()).collect();
        self
    }

    /// Append a data row.
    pub fn row<S: ToString>(&mut self, cols: &[S]) -> &mut Self {
        self.rows.push(cols.iter().map(|c| c.to_string()).collect());
        self
    }

    /// Number of data rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True when there are no data rows.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Data rows (for assertions in tests and downstream processing).
    pub fn rows(&self) -> &[Vec<String>] {
        &self.rows
    }

    /// Table title.
    pub fn title(&self) -> &str {
        &self.title
    }

    fn widths(&self) -> Vec<usize> {
        let ncols = self
            .rows
            .iter()
            .map(|r| r.len())
            .chain(std::iter::once(self.header.len()))
            .max()
            .unwrap_or(0);
        let mut w = vec![0usize; ncols];
        for (i, h) in self.header.iter().enumerate() {
            w[i] = w[i].max(h.chars().count());
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.chars().count());
            }
        }
        w
    }

    /// Render as an aligned ASCII table.
    pub fn render(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("### {}\n", self.title));
        }
        let fmt_row = |cols: &[String]| -> String {
            let cells: Vec<String> = cols
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<width$}", c, width = w.get(i).copied().unwrap_or(0)))
                .collect();
            format!("| {} |", cells.join(" | "))
        };
        if !self.header.is_empty() {
            out.push_str(&fmt_row(&self.header));
            out.push('\n');
            let sep: Vec<String> = w.iter().map(|n| "-".repeat(*n)).collect();
            out.push_str(&format!("|-{}-|\n", sep.join("-|-")));
        }
        for r in &self.rows {
            out.push_str(&fmt_row(r));
            out.push('\n');
        }
        out
    }

    /// Render as CSV (header then rows).
    pub fn to_csv(&self) -> String {
        let esc = |s: &String| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.clone()
            }
        };
        let mut out = String::new();
        if !self.header.is_empty() {
            out.push_str(&self.header.iter().map(esc).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        for r in &self.rows {
            out.push_str(&r.iter().map(esc).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    /// Print to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo");
        t.header(&["name", "value"]);
        t.row(&["a", "1"]);
        t.row(&["longer-name", "22"]);
        let s = t.render();
        assert!(s.contains("### demo"));
        assert!(s.contains("| name        | value |"));
        assert!(s.contains("| longer-name | 22    |"));
    }

    #[test]
    fn csv_escapes_commas() {
        let mut t = Table::new("");
        t.header(&["a", "b"]);
        t.row(&["x,y", "z"]);
        assert_eq!(t.to_csv(), "a,b\n\"x,y\",z\n");
    }

    #[test]
    fn len_tracks_rows() {
        let mut t = Table::new("");
        assert!(t.is_empty());
        t.row(&["1"]);
        assert_eq!(t.len(), 1);
    }
}
