//! Convolution ↔ GEMM lowering (IM2COL) and a direct convolution oracle.
//!
//! [`im2col`] here is the **materializing** lowering: it allocates the full
//! `[M×K]` patch matrix. Since the fused engine landed it serves as the test
//! oracle's lowering (and as the operand-footprint baseline the benches
//! compare against); production conv call sites run on
//! [`crate::gemm::fused`], which generates the same rows on the fly and
//! never stores the expansion — the software mirror of the paper's §IV-C
//! hardware IM2COL unit.
//!
//! Layout conventions (match `python/compile/kernels/ref.py`):
//! * activations NHWC (`[n, h, w, c]`), INT8;
//! * weights HWCO (`[kh, kw, c, oc]`), INT8 — so the flattened GEMM `K`
//!   dimension is `(kh, kw, c)` with the **channel innermost**. That is the
//!   paper's depthwise blocking (Fig. 2): a DBB block of BZ consecutive K
//!   elements covers BZ channels of one spatial tap, so the elements of a
//!   single 3×3 kernel never fall into the same block (for C ≥ BZ).

use crate::tensor::{TensorI32, TensorI8};

/// Convolution shape parameters (single layer, square-friendly but fully
/// general in H/W).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConvShape {
    /// Input height.
    pub h: usize,
    /// Input width.
    pub w: usize,
    /// Input channels.
    pub c: usize,
    /// Kernel height.
    pub kh: usize,
    /// Kernel width.
    pub kw: usize,
    /// Output channels.
    pub oc: usize,
    /// Stride (both dims).
    pub stride: usize,
    /// Symmetric zero padding (both dims).
    pub pad: usize,
}

impl ConvShape {
    /// Output height.
    pub fn oh(&self) -> usize {
        (self.h + 2 * self.pad - self.kh) / self.stride + 1
    }

    /// Output width.
    pub fn ow(&self) -> usize {
        (self.w + 2 * self.pad - self.kw) / self.stride + 1
    }

    /// GEMM M dimension per image: output pixels.
    pub fn gemm_m(&self) -> usize {
        self.oh() * self.ow()
    }

    /// GEMM K dimension: kh·kw·c.
    pub fn gemm_k(&self) -> usize {
        self.kh * self.kw * self.c
    }

    /// GEMM N dimension: output channels.
    pub fn gemm_n(&self) -> usize {
        self.oc
    }

    /// MAC count per image.
    pub fn macs(&self) -> u64 {
        self.gemm_m() as u64 * self.gemm_k() as u64 * self.gemm_n() as u64
    }
}

/// IM2COL: lower an NHWC activation tensor (one image, `[h, w, c]`) to the
/// GEMM left operand `[oh·ow, kh·kw·c]` (channel-innermost K).
pub fn im2col(x: &TensorI8, s: &ConvShape) -> TensorI8 {
    assert_eq!(x.shape(), &[s.h, s.w, s.c], "im2col input shape");
    let (oh, ow) = (s.oh(), s.ow());
    let mut out = TensorI8::zeros(&[oh * ow, s.gemm_k()]);
    for oy in 0..oh {
        for ox in 0..ow {
            let row = oy * ow + ox;
            for ky in 0..s.kh {
                for kx in 0..s.kw {
                    let iy = (oy * s.stride + ky) as isize - s.pad as isize;
                    let ix = (ox * s.stride + kx) as isize - s.pad as isize;
                    if iy < 0 || ix < 0 || iy >= s.h as isize || ix >= s.w as isize {
                        continue; // zero padding
                    }
                    for cc in 0..s.c {
                        let v = x.at(&[iy as usize, ix as usize, cc]);
                        out.set(&[row, (ky * s.kw + kx) * s.c + cc], v);
                    }
                }
            }
        }
    }
    out
}

/// Flatten HWCO weights `[kh, kw, c, oc]` to the GEMM right operand
/// `[kh·kw·c, oc]` (same K ordering as [`im2col`]).
pub fn weights_to_gemm(w: &TensorI8, s: &ConvShape) -> TensorI8 {
    assert_eq!(w.shape(), &[s.kh, s.kw, s.c, s.oc], "weight shape");
    w.reshape(&[s.gemm_k(), s.oc])
}

/// Direct convolution oracle (no IM2COL): output `[oh, ow, oc]` INT32.
pub fn conv2d_direct(x: &TensorI8, w: &TensorI8, s: &ConvShape) -> TensorI32 {
    assert_eq!(x.shape(), &[s.h, s.w, s.c]);
    assert_eq!(w.shape(), &[s.kh, s.kw, s.c, s.oc]);
    let (oh, ow) = (s.oh(), s.ow());
    let mut out = TensorI32::zeros(&[oh, ow, s.oc]);
    for oy in 0..oh {
        for ox in 0..ow {
            for ky in 0..s.kh {
                for kx in 0..s.kw {
                    let iy = (oy * s.stride + ky) as isize - s.pad as isize;
                    let ix = (ox * s.stride + kx) as isize - s.pad as isize;
                    if iy < 0 || ix < 0 || iy >= s.h as isize || ix >= s.w as isize {
                        continue;
                    }
                    for cc in 0..s.c {
                        let a = x.at(&[iy as usize, ix as usize, cc]) as i32;
                        if a == 0 {
                            continue;
                        }
                        for oc in 0..s.oc {
                            let wv = w.at(&[ky, kx, cc, oc]) as i32;
                            let cur = out.at(&[oy, ox, oc]);
                            out.set(&[oy, ox, oc], cur + a * wv);
                        }
                    }
                }
            }
        }
    }
    out
}

/// IM2COL duplication factor: how many GEMM-operand bytes each SRAM byte of
/// the feature map expands into — the bandwidth the hardware IM2COL unit
/// saves (≈`kh·kw/stride²`; exactly 9/1 = up to 3× *average read* reduction
/// for 3×3 s=1 per paper Fig. 8 which streams 2 of 6 buffered rows).
///
/// This counts the duplication actually present in the finite operand (edge
/// and padding effects included), so it upper-bounds the buffered unit's
/// achievable read magnification:
/// `im2col_expansion(s).max(1.0) ≥ Im2colUnit::magnification(s)` for every
/// shape — [`crate::sim::im2col::Im2colUnit::magnification`] clamps against
/// this value, and the invariant is property-tested in
/// `rust/tests/fused_conv.rs`. (For subsampling convs with `stride > kh`
/// the "expansion" is a contraction, `< 1`, while the unit is simply
/// bypassed at 1×, hence the clamp at 1.)
pub fn im2col_expansion(s: &ConvShape) -> f64 {
    let gemm_bytes = (s.gemm_m() * s.gemm_k()) as f64;
    let fmap_bytes = (s.h * s.w * s.c) as f64;
    gemm_bytes / fmap_bytes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::dense_i8;
    use crate::util::prop::{check, Config};
    use crate::util::Rng;

    fn rand_shape(rng: &mut crate::util::Rng) -> ConvShape {
        let kh = [1usize, 3, 5][rng.below(3)];
        let stride = rng.below(2) + 1;
        let pad = rng.below(kh.div_ceil(2));
        let h = kh + rng.below(6) + stride;
        ConvShape {
            h,
            w: kh + rng.below(6) + stride,
            c: rng.below(8) + 1,
            kh,
            kw: kh,
            oc: rng.below(8) + 1,
            stride,
            pad,
        }
    }

    #[test]
    fn im2col_gemm_matches_direct_conv() {
        check(Config::default().cases(48), |rng| {
            let s = rand_shape(rng);
            let x = TensorI8::rand(&[s.h, s.w, s.c], rng);
            let w = TensorI8::rand(&[s.kh, s.kw, s.c, s.oc], rng);
            let direct = conv2d_direct(&x, &w, &s);
            let a = im2col(&x, &s);
            let wg = weights_to_gemm(&w, &s);
            let gemm = dense_i8(&a, &wg);
            assert_eq!(
                gemm.data(),
                direct.data(),
                "shape={s:?}" // same row-major order: [oh*ow, oc] vs [oh, ow, oc]
            );
        });
    }

    #[test]
    fn output_dims_textbook() {
        let s = ConvShape {
            h: 224,
            w: 224,
            c: 3,
            kh: 7,
            kw: 7,
            oc: 64,
            stride: 2,
            pad: 3,
        };
        assert_eq!(s.oh(), 112);
        assert_eq!(s.ow(), 112);
        assert_eq!(s.gemm_k(), 147);
    }

    #[test]
    fn pointwise_conv_is_plain_gemm() {
        // 1x1 conv: im2col is the identity on [h*w, c]
        let mut rng = Rng::new(11);
        let s = ConvShape {
            h: 4,
            w: 4,
            c: 8,
            kh: 1,
            kw: 1,
            oc: 16,
            stride: 1,
            pad: 0,
        };
        let x = TensorI8::rand(&[4, 4, 8], &mut rng);
        let a = im2col(&x, &s);
        assert_eq!(a.data(), x.data());
        assert!((im2col_expansion(&s) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn expansion_3x3_stride1_near_9x_interior() {
        let s = ConvShape {
            h: 56,
            w: 56,
            c: 64,
            kh: 3,
            kw: 3,
            oc: 64,
            stride: 1,
            pad: 1,
        };
        let e = im2col_expansion(&s);
        assert!(e > 8.0 && e <= 9.0, "e={e}");
    }

    #[test]
    fn padding_zeros_visible_in_im2col() {
        let s = ConvShape {
            h: 2,
            w: 2,
            c: 1,
            kh: 3,
            kw: 3,
            oc: 1,
            stride: 1,
            pad: 1,
        };
        let x = TensorI8::from_vec(&[2, 2, 1], vec![1, 2, 3, 4]);
        let a = im2col(&x, &s);
        // first output pixel (0,0): top-left 3x3 window has 5 padding zeros
        let row0: Vec<i8> = a.data()[..9].to_vec();
        assert_eq!(row0, vec![0, 0, 0, 0, 1, 2, 0, 3, 4]);
    }
}
