//! Pluggable GEMM output epilogues: requantize + ReLU + 2×2 max-pool
//! applied inside the output row walk — SNIPPETS Snippet 1 (INT32→INT8
//! requantization with per-channel scaling right at the accumulator) and
//! Snippet 2 (MAC→ReLU→Max-pool fused on chip so intermediates never touch
//! SRAM) in software.
//!
//! Every i8 GEMM driver in the crate drains its freshly computed INT32
//! accumulator rows through an [`Epilogue`] while they are still cache-hot:
//! the tiled drivers ([`crate::gemm::tiled::dense_i8_ep`] and friends) and
//! the fused-conv workers ([`crate::gemm::fused::conv2d_i8_ep`] family)
//! requantize each `PATCH_ROWS`-sized chunk to INT8 — and optionally
//! max-fold it into a 2×2/stride-2 pooled output — immediately after the
//! inner kernel produces it, so a conv+ReLU+pool block is one streaming
//! pass and **no whole-layer i32 tensor is ever allocated**.
//!
//! ## Exactness contract
//!
//! The requantize rounding is pinned, bit-identical to the historical
//! [`requant_relu`] (which lived in `sim::accel` and survives here as the
//! staged oracle): arithmetic right shift by a power-of-two scale, clamp to
//! `[-127, 127]` (never −128 — the symmetric range the paper's STE-trained
//! quantizer produces), then ReLU. ReLU folds into the clamp lower bound
//! (`max(0, clamp(x, -127, 127)) == clamp(x, 0, 127)`), which is what the
//! SIMD epilogue kernels in [`crate::gemm::micro`] exploit; the scalar
//! row kernels in [`crate::gemm`] remain the bit-exactness oracle.
//!
//! ## Why the pool can stream
//!
//! `x ↦ clamp(x >> s, lo, 127)` is monotonic non-decreasing, so requantize
//! and max-pool **commute**: `max(requant(x)) == requant(max(x))` bit-for-
//! bit. The epilogue therefore requantizes each output row the moment it
//! exists and max-folds the INT8 values into the pooled cell (`i8::MIN`
//! initialized), which needs no 2-row window buffering — each pooled cell
//! simply receives its 4 (or fewer, at dropped odd edges) contributions as
//! the row walk passes them. The only structural requirement is that a
//! pooled row pair never straddles two workers' tiles, which
//! [`Epilogue::row_quantum`] encodes for the drivers' tile partition.
//!
//! The staged references — [`requant_relu`], [`requant_with_shift`],
//! [`max_pool_2x2`] — are kept as the property-test oracles
//! (`rust/tests/epilogue.rs` pins fused == staged across ISAs × activation
//! policies × operand encodings).

use crate::tensor::{TensorI32, TensorI8};

/// The requantization scale of an [`Epilogue`]: a power-of-two right shift,
/// either one global shift for the whole output (the historical
/// [`requant_relu`] behavior) or one shift per output channel (GEMM
/// column) — Snippet 1's per-channel scaling.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Requant {
    /// One arithmetic right shift applied to every output element.
    Global(u32),
    /// One shift per output column (`shifts.len() == n`).
    PerChannel(Vec<u32>),
}

/// Geometry of the 2×2/stride-2 max-pool an [`Epilogue`] optionally folds
/// into the output row walk: the *pre-pool* output grid (`oh × ow` pixels
/// per image). Odd trailing rows/columns are dropped (floor semantics).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolGeom {
    /// Pre-pool output rows per image.
    pub oh: usize,
    /// Pre-pool output columns per image.
    pub ow: usize,
}

impl PoolGeom {
    /// Pooled output rows per image (`oh / 2`, floor).
    pub fn ph(&self) -> usize {
        self.oh / 2
    }

    /// Pooled output columns per image (`ow / 2`, floor).
    pub fn pw(&self) -> usize {
        self.ow / 2
    }
}

/// A pluggable output epilogue: requantize (global or per-channel shift),
/// optional ReLU, optional 2×2/stride-2 max-pool folded into the row walk.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Epilogue {
    requant: Requant,
    relu: bool,
    pool: Option<PoolGeom>,
}

impl Epilogue {
    /// Requantize-only epilogue (plus ReLU when `relu`).
    ///
    /// # Example
    ///
    /// ```
    /// use ssta::gemm::{Epilogue, Requant, ZeroGate};
    /// use ssta::tensor::TensorI8;
    /// use ssta::util::{Parallelism, Rng};
    ///
    /// let mut rng = Rng::new(1);
    /// let a = TensorI8::rand(&[8, 16], &mut rng);
    /// let w = TensorI8::rand(&[16, 4], &mut rng);
    /// // requantize accumulators by >>6 and ReLU, inside the output walk —
    /// // the whole-layer i32 accumulator tensor never materializes
    /// let ep = Epilogue::new(Requant::Global(6), true);
    /// let y = ssta::gemm::tiled::dense_i8_ep(&a, &w, Parallelism::serial(), ZeroGate::Off, &ep);
    /// assert_eq!(y.shape(), &[8, 4]);
    /// assert!(y.data().iter().all(|&v| v >= 0), "ReLU clamps negatives");
    /// ```
    pub fn new(requant: Requant, relu: bool) -> Self {
        Epilogue {
            requant,
            relu,
            pool: None,
        }
    }

    /// Fold a 2×2/stride-2 max-pool over `pool`'s output grid into the
    /// epilogue. The GEMM's `M` must then be a whole number of
    /// `oh·ow`-pixel images.
    pub fn with_pool(mut self, pool: PoolGeom) -> Self {
        self.pool = Some(pool);
        self
    }

    /// The requantization scale.
    pub fn requant(&self) -> &Requant {
        &self.requant
    }

    /// Whether ReLU is applied after requantization.
    pub fn relu(&self) -> bool {
        self.relu
    }

    /// The folded pool geometry, if any.
    pub fn pool(&self) -> Option<PoolGeom> {
        self.pool
    }

    /// Tile-partition alignment quantum: worker tiles must cover whole
    /// multiples of this many *input* (pre-pool) rows so a pooled row pair
    /// never straddles two workers. `1` without a pool; `2·ow` (one pooled
    /// output row's worth of input pixels) with a pool; a whole image
    /// (`oh·ow`) when `oh` is odd, so the dropped last row cannot
    /// misalign the image that follows it.
    pub fn row_quantum(&self) -> usize {
        match self.pool {
            None => 1,
            Some(pg) => {
                if pg.oh % 2 == 0 {
                    2 * pg.ow
                } else {
                    (pg.oh * pg.ow).max(1)
                }
            }
        }
    }

    /// Output rows produced for `rows` input rows. `rows` must be a
    /// multiple of [`Self::row_quantum`]; under that alignment the mapping
    /// is additive (`out_rows(a + b) == out_rows(a) + out_rows(b)`), which
    /// is what lets the drivers hand each worker a disjoint output tile.
    pub fn out_rows(&self, rows: usize) -> usize {
        match self.pool {
            None => rows,
            Some(pg) => {
                debug_assert_eq!(rows % self.row_quantum(), 0, "unaligned tile rows");
                let img = pg.oh * pg.ow;
                let full = rows / img.max(1);
                let rem = rows % img.max(1);
                full * pg.ph() * pg.pw() + (rem / (2 * pg.ow.max(1))) * pg.pw()
            }
        }
    }

    /// Assert `m` is compatible with this epilogue (pooled epilogues need a
    /// whole number of images).
    pub fn check_rows(&self, m: usize) {
        if let Some(pg) = self.pool {
            assert_eq!(
                m % (pg.oh * pg.ow).max(1),
                0,
                "pooled epilogue needs M to be whole {}x{} images, got M={m}",
                pg.oh,
                pg.ow
            );
        }
    }

    /// Requantize `acc` (whole rows of width `n`) into `out` through this
    /// epilogue's scale + ReLU, dispatching to the SIMD epilogue kernels.
    fn requant_rows_into(&self, acc: &[i32], n: usize, out: &mut [i8]) {
        requant_rows(acc, n, &self.requant, self.relu, out);
    }

    /// Drain one freshly computed accumulator chunk into the worker's
    /// output tile: `acc` holds `acc.len()/n` whole output rows starting at
    /// absolute (global) row `grow0`; `tile` is the worker's i8 output tile
    /// whose first row corresponds to absolute input row `tile_grow0`
    /// (a [`Self::row_quantum`] multiple). `q8` is per-worker i8 staging of
    /// at least `acc.len()` bytes, used only when pooling. Pooled tiles
    /// must be pre-filled with `i8::MIN` before the first chunk.
    pub(crate) fn apply_chunk(
        &self,
        acc: &[i32],
        grow0: usize,
        n: usize,
        q8: &mut [i8],
        tile: &mut [i8],
        tile_grow0: usize,
    ) {
        let rows = acc.len() / n.max(1);
        match self.pool {
            None => {
                let dst = (grow0 - tile_grow0) * n;
                self.requant_rows_into(acc, n, &mut tile[dst..dst + rows * n]);
            }
            Some(pg) => {
                let (ph, pw) = (pg.ph(), pg.pw());
                let (oh, ow) = (pg.oh, pg.ow);
                self.requant_rows_into(acc, n, &mut q8[..rows * n]);
                let tile_prow0 = self.out_rows(tile_grow0);
                for r in 0..rows {
                    let gr = grow0 + r;
                    let (bi, pix) = (gr / (oh * ow), gr % (oh * ow));
                    let (oy, ox) = (pix / ow, pix % ow);
                    if oy >= 2 * ph || ox >= 2 * pw {
                        continue; // dropped odd edge
                    }
                    let prow = bi * ph * pw + (oy / 2) * pw + ox / 2;
                    let dst = (prow - tile_prow0) * n;
                    for (d, &s8) in tile[dst..dst + n].iter_mut().zip(&q8[r * n..(r + 1) * n]) {
                        if s8 > *d {
                            *d = s8;
                        }
                    }
                }
            }
        }
    }
}

/// Requantize whole rows of width `n` from `acc` into `out` (same length)
/// under the given scale + ReLU, through the ISA-dispatched epilogue
/// kernels of [`crate::gemm::micro`]. Public so the property suite can
/// exercise the SIMD requant kernels directly.
pub fn requant_rows(acc: &[i32], n: usize, rq: &Requant, relu: bool, out: &mut [i8]) {
    assert_eq!(acc.len(), out.len(), "requant in/out length");
    match rq {
        Requant::Global(shift) => crate::gemm::micro::requant_i8(acc, out, *shift, relu),
        Requant::PerChannel(shifts) => {
            assert_eq!(shifts.len(), n, "per-channel shifts are one per output column");
            assert_eq!(acc.len() % n.max(1), 0, "requant takes whole rows");
            crate::gemm::micro::requant_i8_perch(acc, out, shifts, relu)
        }
    }
}

/// The smallest power-of-two right shift that brings `max_abs` into
/// `[0, 127]` — the one shift derivation shared by the global and
/// per-channel calibrations (it is monotone non-decreasing in `max_abs`,
/// which is what makes the global shift exactly the max of the per-column
/// shifts).
fn shift_for(max_abs: u32) -> u32 {
    let mut shift = 0u32;
    while (max_abs >> shift) > 127 {
        shift += 1;
    }
    shift
}

/// The data-dependent global shift the historical [`requant_relu`]
/// derives: the smallest power-of-two right shift that brings the largest
/// accumulator magnitude into `[0, 127]`.
pub fn requant_shift(acc: &[i32]) -> u32 {
    shift_for(acc.iter().map(|v| v.unsigned_abs()).max().unwrap_or(1).max(1))
}

/// Per-column shifts of an accumulator of whole rows of width `n`: record
/// each output column's i32 magnitude maximum and derive its own
/// power-of-two shift — the [`Requant::PerChannel`] scale the engine's
/// calibration pass freezes (Snippet 1's per-channel requantization,
/// derivable from one seed pass). Because [`requant_shift`]'s derivation
/// is monotone in the maximum magnitude,
/// `max(requant_col_shifts(acc, n)) == requant_shift(acc)` bit-for-bit —
/// the global calibration is exactly the per-channel one collapsed.
pub fn requant_col_shifts(acc: &[i32], n: usize) -> Vec<u32> {
    assert!(n > 0, "per-channel shifts need at least one column");
    assert_eq!(acc.len() % n, 0, "per-channel shifts take whole rows");
    // the empty-accumulator max defaults to 1, mirroring requant_shift
    let mut maxima = vec![1u32; n];
    for row in acc.chunks_exact(n) {
        for (m, &v) in maxima.iter_mut().zip(row) {
            *m = (*m).max(v.unsigned_abs());
        }
    }
    maxima.into_iter().map(shift_for).collect()
}

/// INT32 accumulators → INT8 under a *given* global shift, then ReLU —
/// the frozen-scale form of [`requant_relu`] (the engine's calibrated
/// fused path and its staged oracle both use this, with the shift recorded
/// once at calibration).
pub fn requant_with_shift(acc: &TensorI32, shift: u32, relu: bool) -> TensorI8 {
    acc.map(|v| {
        let q = (v >> shift).clamp(-127, 127) as i8;
        if relu && q < 0 {
            0
        } else {
            q
        }
    })
}

/// INT32 accumulators → INT8 with a per-tensor power-of-two scale, then
/// ReLU. The zero point is exactly 0 (paper §V-A trains with STE so FP 0 →
/// INT 0), which is what makes post-ReLU zeros exact zeros the hardware can
/// gate on. Relocated from `sim::accel` (a re-export remains there): this
/// is the engine's functional op and the epilogue's staged oracle, so it
/// lives next to the kernels that pin it.
pub fn requant_relu(acc: &TensorI32, relu: bool) -> TensorI8 {
    requant_with_shift(acc, requant_shift(acc.data()), relu)
}

/// Staged 2×2/stride-2 max-pool oracle: `x` is `[b·oh·ow, n]` row-major
/// (any actual tensor shape with that element layout), pooled to
/// `[b·(oh/2)·(ow/2), n]`; odd trailing rows/columns are dropped. The fused
/// epilogue's pool fold is property-tested bit-exact against
/// `requant → this`.
pub fn max_pool_2x2(x: &TensorI8, oh: usize, ow: usize, n: usize) -> TensorI8 {
    let img = (oh * ow).max(1);
    let m = if n == 0 { 0 } else { x.len() / n };
    assert_eq!(m % img, 0, "pool input must be whole {oh}x{ow} images");
    let b = m / img;
    let (ph, pw) = (oh / 2, ow / 2);
    let mut out = vec![i8::MIN; b * ph * pw * n];
    let xd = x.data();
    for bi in 0..b {
        for oy in 0..2 * ph {
            for ox in 0..2 * pw {
                let src = (bi * oh * ow + oy * ow + ox) * n;
                let dst = (bi * ph * pw + (oy / 2) * pw + ox / 2) * n;
                for ci in 0..n {
                    let v = xd[src + ci];
                    if v > out[dst + ci] {
                        out[dst + ci] = v;
                    }
                }
            }
        }
    }
    TensorI8::from_vec(&[b * ph * pw, n], out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn requant_relu_matches_historical_contract() {
        // shift derivation + rounding pinned: clamp at ±127, arithmetic
        // shift, ReLU zeroes negatives
        let acc = TensorI32::from_vec(&[4], vec![0, 100_000, -100_000, 127]);
        let out = requant_relu(&acc, false);
        assert_eq!(out.data()[0], 0);
        assert!(out.data()[1] > 0);
        assert!(out.data()[2] < 0);
        let relu = requant_relu(&acc, true);
        assert_eq!(relu.data()[2], 0);
        // frozen-shift decomposition is the identical function
        let s = requant_shift(acc.data());
        assert_eq!(requant_with_shift(&acc, s, true).data(), relu.data());
        // small accumulators need no shift and clamp symmetric
        let small = TensorI32::from_vec(&[3], vec![127, -127, -128]);
        assert_eq!(requant_shift(small.data()), 1);
        assert_eq!(requant_with_shift(&small, 0, false).data(), &[127, -127, -127]);
    }

    #[test]
    fn relu_folds_into_clamp_lower_bound() {
        // the SIMD kernels' identity: max(0, clamp(x, -127, 127)) ==
        // clamp(x, 0, 127) for every i32 after any shift
        let mut rng = Rng::new(11);
        for _ in 0..2000 {
            let v = rng.next_u64() as i32;
            for s in [0u32, 1, 7, 24] {
                let q = (v >> s).clamp(-127, 127);
                let a = if q < 0 { 0 } else { q };
                let b = (v >> s).clamp(0, 127);
                assert_eq!(a, b, "v={v} s={s}");
            }
        }
    }

    #[test]
    fn requant_commutes_with_max() {
        // monotonicity: requant(max(xs)) == max(requant(xs)) — the property
        // that lets the pool fold stream on i8 values
        let mut rng = Rng::new(12);
        for _ in 0..500 {
            let xs: Vec<i32> = (0..4).map(|_| rng.next_u64() as i32).collect();
            for s in [0u32, 3, 17] {
                for relu in [false, true] {
                    let q = |v: i32| {
                        let lo = if relu { 0 } else { -127 };
                        (v >> s).clamp(lo, 127) as i8
                    };
                    let qmax = q(*xs.iter().max().unwrap());
                    let maxq = xs.iter().map(|&v| q(v)).max().unwrap();
                    assert_eq!(qmax, maxq, "xs={xs:?} s={s} relu={relu}");
                }
            }
        }
    }

    #[test]
    fn out_rows_is_additive_over_quanta() {
        for (oh, ow) in [(4usize, 3usize), (3, 3), (6, 5), (2, 2), (5, 1), (1, 4)] {
            let ep = Epilogue::new(Requant::Global(0), false).with_pool(PoolGeom { oh, ow });
            let q = ep.row_quantum();
            assert_eq!((oh * ow) % q, 0, "quantum must divide an image");
            let total = 3 * oh * ow; // 3 images
            let mut sum = 0;
            let mut at = 0;
            while at < total {
                let take = q.min(total - at);
                sum += ep.out_rows(take);
                at += take;
            }
            assert_eq!(sum, ep.out_rows(total), "oh={oh} ow={ow}");
            assert_eq!(ep.out_rows(total), 3 * (oh / 2) * (ow / 2), "oh={oh} ow={ow}");
        }
        // no pool: identity
        let ep = Epilogue::new(Requant::Global(2), true);
        assert_eq!(ep.out_rows(17), 17);
        assert_eq!(ep.row_quantum(), 1);
    }

    #[test]
    fn col_shifts_max_is_the_global_shift() {
        // monotonicity of the shift derivation: the column attaining the
        // global magnitude maximum gets the global shift, every other
        // column gets at most it
        let mut rng = Rng::new(13);
        for n in [1usize, 3, 10] {
            for _ in 0..200 {
                let acc: Vec<i32> = (0..4 * n).map(|_| rng.next_u64() as i32 >> 8).collect();
                let cols = requant_col_shifts(&acc, n);
                assert_eq!(cols.len(), n);
                let global = requant_shift(&acc);
                assert_eq!(*cols.iter().max().unwrap(), global, "n={n} acc={acc:?}");
            }
        }
        // all-zero accumulator: per-column max defaults to 1, shift 0
        assert_eq!(requant_col_shifts(&[0; 6], 3), vec![0, 0, 0]);
    }

    #[test]
    fn per_channel_at_uniform_maxima_reproduces_global() {
        // per-channel ⊇ global: when every column attains the same
        // magnitude maximum, the per-channel shifts are all the global
        // shift and requant_rows produces identical bytes either way
        let mut rng = Rng::new(14);
        let n = 8usize;
        let mut acc: Vec<i32> = (0..16 * n).map(|_| (rng.next_u64() as i32) >> 12).collect();
        let cap = 1 << 20;
        for v in acc.iter_mut() {
            *v = (*v).clamp(-(cap - 1), cap - 1);
        }
        // force the shared maximum onto every column via the last row
        let last = acc.len() - n;
        for ci in 0..n {
            acc[last + ci] = if ci % 2 == 0 { cap } else { -cap };
        }
        let cols = requant_col_shifts(&acc, n);
        let global = requant_shift(&acc);
        assert!(cols.iter().all(|&s| s == global), "cols={cols:?} global={global}");
        for relu in [false, true] {
            let mut a = vec![0i8; acc.len()];
            let mut b = vec![0i8; acc.len()];
            requant_rows(&acc, n, &Requant::Global(global), relu, &mut a);
            requant_rows(&acc, n, &Requant::PerChannel(cols.clone()), relu, &mut b);
            assert_eq!(a, b, "relu={relu}");
        }
    }

    #[test]
    fn pool_oracle_drops_odd_edges() {
        // 3x3 grid, n=1, values = row*3+col: pooled single cell is
        // max of the 2x2 top-left block = 4; row 2 / col 2 dropped
        let x = TensorI8::from_vec(&[9, 1], (0..9).map(|v| v as i8).collect());
        let p = max_pool_2x2(&x, 3, 3, 1);
        assert_eq!(p.shape(), &[1, 1]);
        assert_eq!(p.data(), &[4]);
    }
}
