//! BSR (block-sparse-row) weight operand + block-scheduler kernels — the
//! second compressed weight datapath (SPOTS, and SNIPPETS Snippet 1's
//! hardware BSR scheduler: `row_ptr` + `col_idx` metadata over dense INT8
//! blocks, whole zero blocks never loaded).
//!
//! Where the DBB/VDBB format ([`crate::gemm::DbbPacked`]) compresses
//! *within* a block (bitmask + packed non-zeros, every block present), BSR
//! compresses *across* blocks: the `[K×N]` weight is cut into `bz_r × bz_c`
//! tiles, tiles that are entirely zero are skipped by the scheduler walk,
//! and surviving tiles stay **dense** — branch-free MACs inside, no
//! per-element index metadata at all. The index overhead is per *block*
//! (one `col_idx` entry per surviving block, one `row_ptr` entry per block
//! row), which is why the format wins at coarse structured sparsity and
//! loses the fine-grained b-of-B regime to DBB — the exact trade
//! `examples/design_space` puts on one axis.
//!
//! Bit-exactness is by construction: a skipped block contributes exactly 0
//! to every INT32 accumulator it would have touched, and the surviving
//! terms accumulate in ascending-k order per output column — the same
//! per-column term order as the dense oracle — so
//! [`bsr_i8_packed`] == [`crate::gemm::dense_i8`] on the decompressed
//! matrix to the bit (property-pinned in `rust/tests/bsr.rs`). Like the
//! merge-join A-DBB kernel, the block scheduler stays scalar on every ISA.

use crate::tensor::{TensorI32, TensorI8};
use crate::util::error::Result;

/// Widest supported block edge (either dimension). Generous next to the
/// DBB `BZ ≤ 16` bound — BSR hardware uses tiles as large as the array
/// (Snippet 1 schedules 14×14).
pub const BSR_MAX_BZ: usize = 64;

/// A `[K×N]` INT8 weight in block-sparse-row form: per block-row offsets
/// (`row_ptr`), per-block column indices (`col_idx`), and the surviving
/// blocks as dense `bz_r × bz_c` tiles (row-major within the tile,
/// zero-padded at the K/N edges). Mirrors [`crate::gemm::DbbPacked`]'s
/// prepare-once/execute-many contract: pack once, every GEMM/conv that
/// takes a `BsrPacked` runs with zero per-call decode work.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BsrPacked {
    /// Reduction dim of the dense matrix.
    pub k: usize,
    /// Output channels.
    pub n: usize,
    /// Block rows (tile height along K).
    pub bz_r: usize,
    /// Block columns (tile width along N).
    pub bz_c: usize,
    row_ptr: Vec<usize>,
    col_idx: Vec<u32>,
    blocks: Vec<i8>,
}

impl BsrPacked {
    /// Pack a dense `[K, N]` matrix: every `bz_r × bz_c` tile with at least
    /// one non-zero is stored dense (edge tiles zero-padded); all-zero
    /// tiles are dropped. Within a block row, stored tiles keep ascending
    /// column order — the canonical form [`Self::from_raw_parts`] enforces.
    pub fn pack(w: &TensorI8, bz_r: usize, bz_c: usize) -> BsrPacked {
        let (k, n) = (w.shape()[0], w.shape()[1]);
        assert!(
            (1..=BSR_MAX_BZ).contains(&bz_r) && (1..=BSR_MAX_BZ).contains(&bz_c),
            "BSR block {bz_r}x{bz_c} out of 1..={BSR_MAX_BZ}"
        );
        let (nbr, nbc) = (k.div_ceil(bz_r), n.div_ceil(bz_c));
        let wd = w.data();
        let mut row_ptr = Vec::with_capacity(nbr + 1);
        let mut col_idx = Vec::new();
        let mut blocks = Vec::new();
        row_ptr.push(0usize);
        let mut tile = vec![0i8; bz_r * bz_c];
        for br in 0..nbr {
            let k0 = br * bz_r;
            let rlen = bz_r.min(k - k0);
            for bc in 0..nbc {
                let n0 = bc * bz_c;
                let clen = bz_c.min(n - n0);
                tile.fill(0);
                let mut any = false;
                for r in 0..rlen {
                    let src = &wd[(k0 + r) * n + n0..(k0 + r) * n + n0 + clen];
                    any |= src.iter().any(|&v| v != 0);
                    tile[r * bz_c..r * bz_c + clen].copy_from_slice(src);
                }
                if any {
                    col_idx.push(bc as u32);
                    blocks.extend_from_slice(&tile);
                }
            }
            row_ptr.push(col_idx.len());
        }
        BsrPacked { k, n, bz_r, bz_c, row_ptr, col_idx, blocks }
    }

    /// Rebuild a packed operand from its flattened parts — the
    /// deserialization entry of the prepared-model persistence format. The
    /// parts are *validated*, not trusted (mirrors
    /// [`crate::gemm::DbbPacked::from_raw_parts`]): `row_ptr` must be a
    /// monotone `block_rows + 1` offset table covering `col_idx` exactly,
    /// column indices must be strictly ascending within each block row and
    /// in range, and `blocks` must hold exactly `bz_r · bz_c` bytes per
    /// stored block — so a corrupted file yields a clean `Err`, never a
    /// kernel out-of-bounds.
    pub fn from_raw_parts(
        k: usize,
        n: usize,
        bz_r: usize,
        bz_c: usize,
        row_ptr: Vec<usize>,
        col_idx: Vec<u32>,
        blocks: Vec<i8>,
    ) -> Result<BsrPacked> {
        if !(1..=BSR_MAX_BZ).contains(&bz_r) || !(1..=BSR_MAX_BZ).contains(&bz_c) {
            crate::bail!("BsrPacked stream: invalid block {bz_r}x{bz_c}");
        }
        if k == 0 || n == 0 {
            crate::bail!("BsrPacked stream: empty matrix {k}x{n}");
        }
        let (nbr, nbc) = (k.div_ceil(bz_r), n.div_ceil(bz_c));
        if row_ptr.len() != nbr + 1 || row_ptr.first() != Some(&0) {
            crate::bail!(
                "BsrPacked stream: row_ptr must hold block_rows+1={} offsets starting at 0, got {}",
                nbr + 1,
                row_ptr.len()
            );
        }
        if row_ptr.windows(2).any(|w| w[0] > w[1]) || row_ptr[nbr] != col_idx.len() {
            crate::bail!(
                "BsrPacked stream: row_ptr must rise monotonically to col_idx.len()={}",
                col_idx.len()
            );
        }
        for br in 0..nbr {
            let row = &col_idx[row_ptr[br]..row_ptr[br + 1]];
            if row.iter().any(|&c| c as usize >= nbc) {
                crate::bail!("BsrPacked stream: col_idx out of range (block_cols={nbc})");
            }
            if row.windows(2).any(|w| w[0] >= w[1]) {
                crate::bail!("BsrPacked stream: col_idx must ascend within a block row");
            }
        }
        if blocks.len() != col_idx.len() * bz_r * bz_c {
            crate::bail!(
                "BsrPacked stream: blocks must hold {} x {}x{} values, got {}",
                col_idx.len(),
                bz_r,
                bz_c,
                blocks.len()
            );
        }
        Ok(BsrPacked { k, n, bz_r, bz_c, row_ptr, col_idx, blocks })
    }

    /// Per-block-row offsets into [`Self::col_idx`] (`block_rows + 1`
    /// values).
    pub fn row_ptr(&self) -> &[usize] {
        &self.row_ptr
    }

    /// Block-column index of each stored block, block-row-major.
    pub fn col_idx(&self) -> &[u32] {
        &self.col_idx
    }

    /// The stored tiles, `bz_r · bz_c` dense INT8 values each.
    pub fn blocks(&self) -> &[i8] {
        &self.blocks
    }

    /// Block rows (`ceil(K / bz_r)`).
    pub fn block_rows(&self) -> usize {
        self.k.div_ceil(self.bz_r)
    }

    /// Block columns (`ceil(N / bz_c)`).
    pub fn block_cols(&self) -> usize {
        self.n.div_ceil(self.bz_c)
    }

    /// Stored (surviving) blocks.
    pub fn stored_blocks(&self) -> usize {
        self.col_idx.len()
    }

    /// Fraction of the block grid that survives — the quantity the
    /// analytic twin prices as the BSR datapath's occupancy.
    pub fn block_density(&self) -> f64 {
        let total = self.block_rows() * self.block_cols();
        if total == 0 {
            return 0.0;
        }
        self.stored_blocks() as f64 / total as f64
    }

    /// Stored non-zero values (zeros padded/embedded inside surviving
    /// blocks do not count — this is the *model* sparsity, not the stream
    /// length; the stream length is `stored_blocks() · bz_r · bz_c`).
    pub fn total_nnz(&self) -> usize {
        self.blocks.iter().filter(|&&v| v != 0).count()
    }

    /// Wire bytes of the scheduler metadata, priced at the weight-SRAM
    /// rate by the analytic twin: one u32 offset per `row_ptr` entry plus
    /// one u16 column index per stored block — **no per-element bitmask**,
    /// the defining contrast with the DBB stream's `BZ` bits per block.
    pub fn index_bytes(&self) -> usize {
        4 * self.row_ptr.len() + 2 * self.col_idx.len()
    }

    /// Host bytes the packed operand occupies (the steady-state footprint
    /// an executor holds per layer; mirrors
    /// [`crate::gemm::DbbPacked::operand_bytes`]).
    pub fn operand_bytes(&self) -> usize {
        self.row_ptr.len() * std::mem::size_of::<usize>()
            + self.col_idx.len() * std::mem::size_of::<u32>()
            + self.blocks.len()
    }

    /// Decompress to the dense `[K, N]` matrix (test oracle convenience).
    pub fn decompress(&self) -> TensorI8 {
        let mut out = TensorI8::zeros(&[self.k, self.n]);
        let od = out.data_mut();
        let (bz_r, bz_c) = (self.bz_r, self.bz_c);
        for br in 0..self.block_rows() {
            let k0 = br * bz_r;
            let rlen = bz_r.min(self.k - k0);
            for bi in self.row_ptr[br]..self.row_ptr[br + 1] {
                let n0 = self.col_idx[bi] as usize * bz_c;
                let clen = bz_c.min(self.n - n0);
                let blk = &self.blocks[bi * bz_r * bz_c..(bi + 1) * bz_r * bz_c];
                for r in 0..rlen {
                    od[(k0 + r) * self.n + n0..(k0 + r) * self.n + n0 + clen]
                        .copy_from_slice(&blk[r * bz_c..r * bz_c + clen]);
                }
            }
        }
        out
    }
}

/// Block-scheduler inner kernel shared by the serial, tiled and fused-conv
/// BSR GEMMs: accumulate output rows `row0..row0 + out.len()/n` from the
/// packed operand. Absent blocks are skipped by the `row_ptr` walk; inside
/// a surviving block the MACs are branch-free and dense. Per output
/// column the surviving terms accumulate in ascending-k order — the dense
/// oracle's per-column order — so every caller is bit-exact under tiling.
/// Scalar on every ISA (block-skip control flow, like the merge-join).
pub(crate) fn bsr_rows_i8(
    ad: &[i8],
    w: &BsrPacked,
    out: &mut [i32],
    row0: usize,
    k: usize,
    n: usize,
) {
    if n == 0 {
        return;
    }
    debug_assert_eq!(k, w.k);
    debug_assert_eq!(n, w.n);
    let (bz_r, bz_c) = (w.bz_r, w.bz_c);
    let (rp, ci, bl) = (&w.row_ptr[..], &w.col_idx[..], &w.blocks[..]);
    for (i, crow) in out.chunks_mut(n).enumerate() {
        let row = row0 + i;
        let arow = &ad[row * k..row * k + k];
        for br in 0..rp.len() - 1 {
            let k0 = br * bz_r;
            let rlen = bz_r.min(k - k0);
            for bi in rp[br]..rp[br + 1] {
                let n0 = ci[bi] as usize * bz_c;
                let clen = bz_c.min(n - n0);
                let blk = &bl[bi * bz_r * bz_c..(bi + 1) * bz_r * bz_c];
                let cw = &mut crow[n0..n0 + clen];
                for r in 0..rlen {
                    let av = arow[k0 + r] as i32;
                    let wrow = &blk[r * bz_c..r * bz_c + clen];
                    for (cv, &wv) in cw.iter_mut().zip(wrow) {
                        *cv += av * wv as i32;
                    }
                }
            }
        }
    }
}

/// Zero-gated variant of [`bsr_rows_i8`]: the per-row occupancy scan of
/// the other gated kernels (O(K), amortized across all N columns)
/// classifies each A row once — all-zero rows skip every surviving block
/// outright, dense rows take the branch-free walk, mixed rows arm the
/// per-element gate so a zero activation suppresses its MAC row across
/// the block. Bit-exact with [`bsr_rows_i8`]: skipped terms are exactly 0
/// and survivors keep their order.
pub(crate) fn bsr_rows_i8_gated(
    ad: &[i8],
    w: &BsrPacked,
    out: &mut [i32],
    row0: usize,
    k: usize,
    n: usize,
) {
    if n == 0 {
        return;
    }
    let (bz_r, bz_c) = (w.bz_r, w.bz_c);
    let (rp, ci, bl) = (&w.row_ptr[..], &w.col_idx[..], &w.blocks[..]);
    for (i, crow) in out.chunks_mut(n).enumerate() {
        let row = row0 + i;
        let arow = &ad[row * k..row * k + k];
        let nnz = k - arow.iter().filter(|&&a| a == 0).count();
        if nnz == 0 {
            continue; // accumulate semantics: contributes exactly 0
        }
        let gate = nnz < k;
        for br in 0..rp.len() - 1 {
            let k0 = br * bz_r;
            let rlen = bz_r.min(k - k0);
            for bi in rp[br]..rp[br + 1] {
                let n0 = ci[bi] as usize * bz_c;
                let clen = bz_c.min(n - n0);
                let blk = &bl[bi * bz_r * bz_c..(bi + 1) * bz_r * bz_c];
                let cw = &mut crow[n0..n0 + clen];
                for r in 0..rlen {
                    let av = arow[k0 + r] as i32;
                    // the gate: a zero activation suppresses the MAC row
                    if gate && av == 0 {
                        continue;
                    }
                    let wrow = &blk[r * bz_c..r * bz_c + clen];
                    for (cv, &wv) in cw.iter_mut().zip(wrow) {
                        *cv += av * wv as i32;
                    }
                }
            }
        }
    }
}

/// Serial BSR GEMM: `C[M×N] = A[M×K] · decompress(W)`, computed directly
/// on the packed form. Bit-exact with [`crate::gemm::dense_i8`] on
/// [`BsrPacked::decompress`].
pub fn bsr_i8_packed(a: &TensorI8, w: &BsrPacked) -> TensorI32 {
    let (m, k) = (a.shape()[0], a.shape()[1]);
    assert_eq!(k, w.k, "GEMM inner dims: A[{m}x{k}] Wbsr[{}x{}]", w.k, w.n);
    let mut c = TensorI32::zeros(&[m, w.n]);
    bsr_rows_i8(a.data(), w, c.data_mut(), 0, k, w.n);
    c
}

/// [`bsr_i8_packed`] under a [`crate::gemm::ZeroGate`] policy: `Auto`
/// measures `A`'s zero fraction once and gates when it clears the
/// threshold. Bit-exact with [`bsr_i8_packed`] under every policy.
pub fn bsr_i8_packed_gated(
    a: &TensorI8,
    w: &BsrPacked,
    gate: crate::gemm::ZeroGate,
) -> TensorI32 {
    let (m, k) = (a.shape()[0], a.shape()[1]);
    assert_eq!(k, w.k, "GEMM inner dims: A[{m}x{k}] Wbsr[{}x{}]", w.k, w.n);
    let mut c = TensorI32::zeros(&[m, w.n]);
    if gate.resolve_with(|| a.sparsity()) {
        bsr_rows_i8_gated(a.data(), w, c.data_mut(), 0, k, w.n);
    } else {
        bsr_rows_i8(a.data(), w, c.data_mut(), 0, k, w.n);
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dbb::prune::prune_bsr_i8;
    use crate::gemm::{dense_i8, ZeroGate};
    use crate::util::prop::{check, Config};
    use crate::util::Rng;

    #[test]
    fn pack_equals_dense_on_decompressed_prop() {
        check(Config::default().cases(96), |rng| {
            let m = rng.below(12) + 1;
            let k = rng.below(48) + 1;
            let n = rng.below(24) + 1;
            let bz_r = [4usize, 8, 14, 16][rng.below(4)];
            let bz_c = [4usize, 8, 14, 16][rng.below(4)];
            let a = TensorI8::rand(&[m, k], rng);
            let keep = rng.below(4); // 0..=3 blocks per block row
            let wd = prune_bsr_i8(&TensorI8::rand(&[k, n], rng), bz_r, bz_c, keep);
            let w = BsrPacked::pack(&wd, bz_r, bz_c);
            assert_eq!(w.decompress().data(), wd.data(), "decompress roundtrip");
            assert_eq!(
                bsr_i8_packed(&a, &w).data(),
                dense_i8(&a, &wd).data(),
                "m={m} k={k} n={n} bz={bz_r}x{bz_c} keep={keep}"
            );
        });
    }

    #[test]
    fn gated_bit_exact_prop() {
        check(Config::default().cases(64), |rng| {
            let m = rng.below(12) + 1;
            let k = rng.below(48) + 1;
            let n = rng.below(20) + 1;
            let p_zero = [0.0f32, 0.5, 1.0][rng.below(3)];
            let a = TensorI8::rand_sparse(&[m, k], p_zero, rng);
            let wd = prune_bsr_i8(&TensorI8::rand(&[k, n], rng), 8, 8, rng.below(3) + 1);
            let w = BsrPacked::pack(&wd, 8, 8);
            let gate = [ZeroGate::Off, ZeroGate::Auto, ZeroGate::On][rng.below(3)];
            assert_eq!(
                bsr_i8_packed_gated(&a, &w, gate).data(),
                bsr_i8_packed(&a, &w).data(),
                "m={m} k={k} n={n} p={p_zero} gate={gate:?}"
            );
        });
    }

    #[test]
    fn all_zero_weight_packs_empty() {
        let w = BsrPacked::pack(&TensorI8::zeros(&[32, 16]), 8, 8);
        assert_eq!(w.stored_blocks(), 0);
        assert_eq!(w.block_density(), 0.0);
        assert_eq!(w.index_bytes(), 4 * 5); // row_ptr only
        let a = TensorI8::from_vec(&[2, 32], vec![1i8; 64]);
        assert!(bsr_i8_packed(&a, &w).data().iter().all(|&v| v == 0));
    }

    #[test]
    fn fully_dense_weight_stores_every_block() {
        let mut rng = Rng::new(7);
        // no zeros at all → every block survives
        let wd = TensorI8::from_vec(
            &[16, 12],
            (0..16 * 12).map(|i| (i % 251 + 1) as u8 as i8).collect(),
        );
        let w = BsrPacked::pack(&wd, 8, 8);
        assert_eq!(w.stored_blocks(), 2 * 2);
        assert_eq!(w.block_density(), 1.0);
        let a = TensorI8::rand(&[3, 16], &mut rng);
        assert_eq!(bsr_i8_packed(&a, &w).data(), dense_i8(&a, &wd).data());
    }

    #[test]
    fn partial_edge_blocks_are_exact() {
        // K=13, N=11 with 8x8 blocks: both edges partial
        let mut rng = Rng::new(9);
        let wd = TensorI8::rand(&[13, 11], &mut rng);
        let w = BsrPacked::pack(&wd, 8, 8);
        let a = TensorI8::rand(&[5, 13], &mut rng);
        assert_eq!(bsr_i8_packed(&a, &w).data(), dense_i8(&a, &wd).data());
    }

    #[test]
    fn raw_parts_roundtrip_and_rejection() {
        let mut rng = Rng::new(11);
        let wd = prune_bsr_i8(&TensorI8::rand(&[24, 16], &mut rng), 8, 8, 1);
        let w = BsrPacked::pack(&wd, 8, 8);
        let rt = BsrPacked::from_raw_parts(
            w.k,
            w.n,
            w.bz_r,
            w.bz_c,
            w.row_ptr().to_vec(),
            w.col_idx().to_vec(),
            w.blocks().to_vec(),
        )
        .unwrap();
        assert_eq!(rt, w);
        // corrupted row_ptr length
        assert!(BsrPacked::from_raw_parts(
            w.k,
            w.n,
            8,
            8,
            w.row_ptr()[1..].to_vec(),
            w.col_idx().to_vec(),
            w.blocks().to_vec()
        )
        .is_err());
        // col_idx out of range
        let mut bad_ci = w.col_idx().to_vec();
        if let Some(c) = bad_ci.first_mut() {
            *c = 99;
        }
        assert!(BsrPacked::from_raw_parts(
            w.k,
            w.n,
            8,
            8,
            w.row_ptr().to_vec(),
            bad_ci,
            w.blocks().to_vec()
        )
        .is_err());
        // truncated block payload
        assert!(BsrPacked::from_raw_parts(
            w.k,
            w.n,
            8,
            8,
            w.row_ptr().to_vec(),
            w.col_idx().to_vec(),
            w.blocks()[..w.blocks().len() - 1].to_vec()
        )
        .is_err());
        // zero-sized block geometry
        assert!(BsrPacked::from_raw_parts(8, 8, 0, 8, vec![0], vec![], vec![]).is_err());
    }

    #[test]
    fn index_bytes_have_no_per_element_bitmask() {
        let mut rng = Rng::new(13);
        let wd = TensorI8::rand(&[64, 64], &mut rng);
        let w = BsrPacked::pack(&wd, 8, 8);
        // 9 row_ptr entries * 4B + 64 blocks * 2B
        assert_eq!(w.index_bytes(), 9 * 4 + 64 * 2);
        // dense stream bytes: every block dense
        assert_eq!(w.blocks().len(), 64 * 64);
    }
}
