//! Activation-side DBB encoding — the A operand of the paper's fixed-rate
//! compressed stream, in software.
//!
//! The paper's datapath consumes a *compressed* stream on both sides of the
//! MAC: weights are DBB-encoded offline (§II-A, [`crate::dbb::DbbMatrix`] →
//! [`crate::gemm::DbbPacked`]), and the STA (Liu et al., 2020) stream format
//! carries per-block bitmasks + packed non-zero values at a fixed rate.
//! S2TA (Liu et al., 2021) extends the same formulation to the *activation*
//! operand — the joint weight×activation DBB datapath — because the big
//! energy wins are in never *fetching* a zero operand, not merely skipping
//! its multiply. [`ActDbb`] is that A-side stream: the time-unrolled VDBB
//! block format [`crate::gemm::DbbPacked`] uses, but **row-major for the
//! left operand** — each row of `A[M×K]` is blocked along `K` into
//! `ceil(K/bz)` blocks, each block storing its non-zero values plus a
//! `bz`-bit positional bitmask.
//!
//! Two differences from the weight side, both forced by *when* the encoding
//! happens:
//!
//! * **Runtime, not offline.** Activations only exist at inference time, so
//!   [`ActDbb::encode`] is a single `O(M·K)` pass the executor runs per
//!   operand (or per generated patch-row chunk in the fused conv engine —
//!   see `gemm::fused`'s `*_encoded` entry points).
//! * **Lossless, not pruned.** Weights are top-k pruned *to* a bound;
//!   activations must be reproduced exactly (bit-exactness is the
//!   codebase's contract), so every non-zero is kept and the block bound is
//!   *measured* (`bound = max` block occupancy, the VDBB time-unrolling
//!   depth the hardware would run at).
//!
//! In memory the blocks are flattened to the per-row `(row_ptr, entries)`
//! CSR stream the joint kernels walk — the exact mirror of `DbbPacked`'s
//! per-column CSC flattening. [`ActDbb::stream_bytes`] reports the
//! fixed-rate *wire* form of this exact operand (`bound` value bytes +
//! `bz/8` bitmask bytes per block — pessimistic, since one dense block
//! pads every block to its occupancy); the hardware twin's analytic model
//! instead prices the *average-rate* compressed stream from the measured
//! sparsity statistic (`crate::sim::analytic::gemm_timing_stats_enc`),
//! because it works from layer statistics, not a concrete operand.
//!
//! The joint kernels (`adbb_rows_i8` behind [`adbb_i8_packed`], consuming
//! a [`crate::gemm::DbbPacked`] weight stream; `adbb_dense_rows_i8` behind
//! [`adbb_dense_i8`], consuming a dense `[K,N]` weight) are **bit-exact**
//! with the ungated oracles: a term they skip has a zero activation and
//! contributes exactly 0 to the INT32 accumulator, and the surviving terms
//! accumulate in the identical ascending-`k` order (property-tested in
//! `rust/tests/act_dbb.rs`).
//!
//! Dispatch note: the dense-W joint kernel runs through the
//! [`crate::gemm::micro`] SIMD dispatch (each stored activation entry
//! streams a register-blocked axpy); the merge-join kernel
//! (`adbb_rows_i8`) stays scalar on every ISA — its control flow is
//! data-dependent on two compressed index streams, and the encoding has
//! already removed the multiplies SIMD would amortize.

use crate::gemm::DbbPacked;
use crate::tensor::{TensorI32, TensorI8};

/// A DBB-encoded activation operand `A[M×K]`: per-block (bitmask + packed
/// non-zeros) along `K`, flattened to the per-row `(row_ptr, entries)` CSR
/// stream the joint row kernels consume. Encoding is **lossless** — every
/// non-zero survives with its position — so every GEMM/conv that takes an
/// `ActDbb` is bit-exact with its dense-A counterpart.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ActDbb {
    /// GEMM rows of the encoded operand.
    pub m: usize,
    /// Reduction dim of the encoded operand.
    pub k: usize,
    /// Block size along `K` the stream is blocked with.
    pub bz: usize,
    /// Measured density bound: max non-zeros observed in any block (≥ 1 —
    /// the hardware streams at least one slot per block, mirroring
    /// [`crate::dbb::DbbMatrix`]). This is the VDBB time-unrolling depth of
    /// the fixed-rate stream.
    pub bound: usize,
    row_ptr: Vec<usize>,
    entries: Vec<(u32, i32)>,
}

impl ActDbb {
    /// Encode a dense `[M, K]` INT8 activation operand, once, at runtime:
    /// one `O(M·K)` pass recording every non-zero as a `(k-index, value)`
    /// entry and measuring the per-block density bound. `bz` must be
    /// `1..=16` (the [`crate::dbb::DbbMatrix`] block-size range).
    ///
    /// # Example
    ///
    /// ```
    /// use ssta::gemm::{adbb_dense_i8, dense_i8, ActDbb};
    /// use ssta::tensor::TensorI8;
    /// use ssta::util::Rng;
    ///
    /// // ReLU-style activations: at most 2 non-zeros in any 8-wide block,
    /// // so the measured VDBB bound is 2 and the fixed-rate stream is
    /// // (2 value + 1 mask) bytes per block instead of 8 raw bytes
    /// let data: Vec<i8> =
    ///     (0..16 * 32).map(|i| if i % 8 < 2 { 1 + (i % 8) as i8 } else { 0 }).collect();
    /// let a = TensorI8::from_vec(&[16, 32], data);
    /// let enc = ActDbb::encode(&a, 8);
    /// assert!(enc.stream_bytes() < enc.dense_bytes());
    /// // ...and the joint kernels consuming it stay bit-exact
    /// let mut rng = Rng::new(2);
    /// let w = TensorI8::rand(&[32, 8], &mut rng);
    /// assert_eq!(adbb_dense_i8(&enc, &w), dense_i8(&a, &w));
    /// ```
    pub fn encode(a: &TensorI8, bz: usize) -> ActDbb {
        let mut enc = ActDbb::empty();
        enc.encode_reuse(a, bz);
        enc
    }

    /// An empty stream for [`Self::encode_reuse`] to fill — the seed of the
    /// reusable-buffer encode path steady-state executors hold in their
    /// scratch arena.
    pub fn empty() -> ActDbb {
        ActDbb {
            m: 0,
            k: 0,
            bz: 1,
            bound: 1,
            row_ptr: Vec::new(),
            entries: Vec::new(),
        }
    }

    /// [`Self::encode`] into this existing stream: previous contents are
    /// discarded but the buffers' capacity is retained, so a hot loop that
    /// re-encodes per call allocates nothing in steady state (the
    /// [`crate::engine`] executor's FC `Encode` path draws one of these
    /// from its scratch arena). Every field is rewritten — equivalent to
    /// `*self = ActDbb::encode(a, bz)` to the last bit.
    pub fn encode_reuse(&mut self, a: &TensorI8, bz: usize) {
        assert!(
            a.shape().len() == 2,
            "ActDbb encodes a [M, K] matrix, got shape {:?}",
            a.shape()
        );
        assert!((1..=16).contains(&bz), "block size {bz} not supported (must be 1..=16)");
        let (m, k) = (a.shape()[0], a.shape()[1]);
        let ad = a.data();
        self.row_ptr.clear();
        self.entries.clear();
        self.row_ptr.reserve(m + 1);
        self.row_ptr.push(0usize);
        let mut bound = 0usize;
        for row in 0..m {
            let arow = &ad[row * k..(row + 1) * k];
            let mut block_nnz = 0usize;
            for (kk, &v) in arow.iter().enumerate() {
                if kk % bz == 0 {
                    bound = bound.max(block_nnz);
                    block_nnz = 0;
                }
                if v != 0 {
                    self.entries.push((kk as u32, v as i32));
                    block_nnz += 1;
                }
            }
            bound = bound.max(block_nnz);
            self.row_ptr.push(self.entries.len());
        }
        self.m = m;
        self.k = k;
        self.bz = bz;
        self.bound = bound.max(1);
    }

    /// Rebuild an encoded operand from its flattened parts — the mirror of
    /// [`crate::gemm::DbbPacked::from_raw_parts`] for the A-side stream
    /// (the prepared-model persistence format). Validated, not trusted:
    /// `row_ptr` must be a monotone `m + 1`-length offset table covering
    /// `entries` exactly, with every k-index in `0..k`, so a corrupted file
    /// yields a clean `Err` instead of a kernel out-of-bounds.
    pub fn from_raw_parts(
        m: usize,
        k: usize,
        bz: usize,
        bound: usize,
        row_ptr: Vec<usize>,
        entries: Vec<(u32, i32)>,
    ) -> crate::util::error::Result<ActDbb> {
        if !(1..=16).contains(&bz) || bound == 0 {
            crate::bail!("ActDbb stream: invalid encoding bz={bz} bound={bound}");
        }
        if row_ptr.len() != m + 1 || row_ptr.first() != Some(&0) {
            crate::bail!(
                "ActDbb stream: row_ptr must hold m+1={} offsets starting at 0, got {}",
                m + 1,
                row_ptr.len()
            );
        }
        if row_ptr.windows(2).any(|w| w[0] > w[1]) || row_ptr[m] != entries.len() {
            crate::bail!(
                "ActDbb stream: row_ptr must rise monotonically to entries.len()={}",
                entries.len()
            );
        }
        if entries.iter().any(|&(kk, _)| kk as usize >= k) {
            crate::bail!("ActDbb stream: entry k-index out of range (k={k})");
        }
        Ok(ActDbb {
            m,
            k,
            bz,
            bound,
            row_ptr,
            entries,
        })
    }

    /// Per-row offsets into [`Self::entries`] (`m + 1` values).
    pub fn row_ptr(&self) -> &[usize] {
        &self.row_ptr
    }

    /// The `(k-index, value)` stream, row-major, ascending `k` within a row.
    pub fn entries(&self) -> &[(u32, i32)] {
        &self.entries
    }

    /// Stored non-zeros.
    pub fn total_nnz(&self) -> usize {
        self.entries.len()
    }

    /// Zero fraction of the encoded operand (identical to the source
    /// tensor's [`TensorI8::sparsity`]).
    pub fn sparsity(&self) -> f64 {
        let total = self.m * self.k;
        if total == 0 {
            return 0.0;
        }
        1.0 - self.entries.len() as f64 / total as f64
    }

    /// K-blocks per row (`ceil(K/bz)`; the last block is zero-padded).
    pub fn kblocks(&self) -> usize {
        self.k.div_ceil(self.bz)
    }

    /// Bytes of the fixed-rate compressed *wire* form of this operand: per
    /// block, `bound` value bytes (slots padded to the measured bound so
    /// the stream rate is fixed, paper §II-A) plus `bz/8` bitmask bytes.
    /// A reporting/analysis view (the bench reports print it); note the
    /// analytic twin prices A-traffic from the sparsity *statistic*
    /// instead (average-rate, `gemm_timing_stats_enc`), which undercuts
    /// this bound-padded figure whenever block occupancy is skewed.
    pub fn stream_bytes(&self) -> usize {
        self.m * self.kblocks() * (self.bound + self.bz.div_ceil(8))
    }

    /// Bytes the raw (uncompressed) operand would stream.
    pub fn dense_bytes(&self) -> usize {
        self.m * self.k
    }

    /// Host bytes the packed CSR form occupies.
    pub fn operand_bytes(&self) -> usize {
        self.row_ptr.len() * std::mem::size_of::<usize>()
            + self.entries.len() * std::mem::size_of::<(u32, i32)>()
    }
}

/// Joint-sparse inner kernel: encoded-A rows × the decoded per-column CSC
/// weight stream of a [`DbbPacked`]. For each `(row, col)` the two sorted
/// index lists (A row ascending `k`, W column ascending `k`) are
/// merge-intersected, so only `(non-zero activation, stored weight)` pairs
/// ever reach the multiplier — the S2TA joint-DBB datapath in software.
///
/// Bit-exact with [`crate::gemm::dbb_rows_i8`] on the dense form of A:
/// every skipped term has a zero activation (contributes exactly 0 to the
/// INT32 accumulator) and the surviving terms keep the ascending-`k`
/// accumulation order of the weight stream.
pub(crate) fn adbb_rows_i8(
    a_row_ptr: &[usize],
    a_entries: &[(u32, i32)],
    col_ptr: &[usize],
    entries: &[(u32, i32)],
    out: &mut [i32],
    row0: usize,
    n: usize,
) {
    if n == 0 {
        return;
    }
    for (i, crow) in out.chunks_mut(n).enumerate() {
        let row = row0 + i;
        let arow = &a_entries[a_row_ptr[row]..a_row_ptr[row + 1]];
        if arow.is_empty() {
            crow.fill(0);
            continue;
        }
        for (col, cv) in crow.iter_mut().enumerate() {
            let wcol = &entries[col_ptr[col]..col_ptr[col + 1]];
            let mut acc = 0i32;
            let (mut ai, mut wi) = (0usize, 0usize);
            while ai < arow.len() && wi < wcol.len() {
                let (ak, av) = arow[ai];
                let (wk, wv) = wcol[wi];
                match ak.cmp(&wk) {
                    std::cmp::Ordering::Less => ai += 1,
                    std::cmp::Ordering::Greater => wi += 1,
                    std::cmp::Ordering::Equal => {
                        acc += av * wv;
                        ai += 1;
                        wi += 1;
                    }
                }
            }
            *cv = acc;
        }
    }
}

/// Joint kernel for dense-fallback weights: encoded-A rows × a dense
/// `[K, N]` weight. Each stored activation entry streams one axpy over the
/// weight row its `k`-index selects — the exact non-zero terms
/// [`crate::gemm::dense_rows_i8`] accumulates (it skips zero activations
/// too), in the exact ascending-`k` order, so the two are bit-exact.
pub(crate) fn adbb_dense_rows_i8(
    a_row_ptr: &[usize],
    a_entries: &[(u32, i32)],
    wd: &[i8],
    out: &mut [i32],
    row0: usize,
    n: usize,
) {
    if n == 0 {
        return;
    }
    for (i, crow) in out.chunks_mut(n).enumerate() {
        let row = row0 + i;
        for &(kk, av) in &a_entries[a_row_ptr[row]..a_row_ptr[row + 1]] {
            let wrow = &wd[kk as usize * n..kk as usize * n + n];
            for (cv, &wv) in crow.iter_mut().zip(wrow) {
                *cv += av * wv as i32;
            }
        }
    }
}

/// Joint-sparse GEMM on a pre-encoded A and a pre-packed W: zero per-call
/// encode/decode work on *either* operand. Bit-exact with
/// [`crate::gemm::dbb_i8_packed`] on the dense form of `a`.
pub fn adbb_i8_packed(a: &ActDbb, w: &DbbPacked) -> TensorI32 {
    assert_eq!(a.k, w.k, "GEMM inner dims: Adbb[{}x{}] Wdbb[{}x{}]", a.m, a.k, w.k, w.n);
    let mut c = TensorI32::zeros(&[a.m, w.n]);
    adbb_rows_i8(a.row_ptr(), a.entries(), w.col_ptr(), w.entries(), c.data_mut(), 0, w.n);
    c
}

/// Joint GEMM for dense-fallback weights: encoded A × dense `[K, N]` W.
/// Bit-exact with [`crate::gemm::dense_i8`] on the dense form of `a`.
pub fn adbb_dense_i8(a: &ActDbb, w: &TensorI8) -> TensorI32 {
    let (k2, n) = (w.shape()[0], w.shape()[1]);
    assert_eq!(a.k, k2, "GEMM inner dims: Adbb[{}x{}] W[{k2}x{n}]", a.m, a.k);
    let mut c = TensorI32::zeros(&[a.m, n]);
    crate::gemm::micro::adbb_dense_rows_i8(a.row_ptr(), a.entries(), w.data(), c.data_mut(), 0, n);
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dbb::DbbMatrix;
    use crate::gemm;
    use crate::util::prop::{check, Config};

    #[test]
    fn encode_roundtrips_every_nonzero() {
        check(Config::default().cases(64), |rng| {
            let m = rng.below(16) + 1;
            let k = rng.below(48) + 1;
            let bz = [4usize, 8, 16][rng.below(3)];
            let p = [0.0f32, 0.5, 1.0][rng.below(3)];
            let a = TensorI8::rand_sparse(&[m, k], p, rng);
            let enc = ActDbb::encode(&a, bz);
            let mut back = TensorI8::zeros(&[m, k]);
            for row in 0..m {
                for &(kk, v) in &enc.entries()[enc.row_ptr()[row]..enc.row_ptr()[row + 1]] {
                    back.set(&[row, kk as usize], v as i8);
                }
            }
            assert_eq!(back.data(), a.data(), "m={m} k={k} bz={bz} p={p}");
            assert_eq!(
                enc.total_nnz(),
                a.data().iter().filter(|&&v| v != 0).count()
            );
            assert!(enc.bound >= 1 && enc.bound <= bz, "bound={}", enc.bound);
            assert!((enc.sparsity() - a.sparsity()).abs() < 1e-12);
        });
    }

    #[test]
    fn stream_bytes_follow_fixed_rate_formula() {
        // 8 rows × 2 blocks of bz=8, max 3/block → 8·2·(3+1) bytes
        let mut a = TensorI8::zeros(&[8, 16]);
        for row in 0..8 {
            for j in 0..3 {
                a.set(&[row, j], 1 + j as i8);
            }
        }
        let enc = ActDbb::encode(&a, 8);
        assert_eq!(enc.bound, 3);
        assert_eq!(enc.stream_bytes(), 8 * 2 * (3 + 1));
        assert!(enc.stream_bytes() < enc.dense_bytes());
        // an all-zero operand still streams one slot per block
        let z = ActDbb::encode(&TensorI8::zeros(&[4, 8]), 8);
        assert_eq!(z.bound, 1);
        assert_eq!(z.total_nnz(), 0);
    }

    #[test]
    fn encode_reuse_matches_fresh_encode() {
        // one reused stream across wildly varying shapes/blocks must be
        // indistinguishable from a fresh encode, field for field
        let scratch = std::cell::RefCell::new(ActDbb::empty());
        check(Config::default().cases(48), |rng| {
            let m = rng.below(16) + 1;
            let k = rng.below(48) + 1;
            let bz = [4usize, 8, 16][rng.below(3)];
            let p = [0.0f32, 0.5, 1.0][rng.below(3)];
            let a = TensorI8::rand_sparse(&[m, k], p, rng);
            let mut reused = scratch.borrow_mut();
            reused.encode_reuse(&a, bz);
            assert_eq!(*reused, ActDbb::encode(&a, bz), "m={m} k={k} bz={bz} p={p}");
        });
    }

    #[test]
    fn joint_kernels_match_oracles_prop() {
        check(Config::default().cases(64), |rng| {
            let m = rng.below(12) + 1;
            let k = rng.below(48) + 1;
            let n = rng.below(16) + 1;
            let bz = [4usize, 8, 16][rng.below(3)];
            let nnz = rng.below(bz) + 1;
            let p = [0.0f32, 0.5, 1.0][rng.below(3)];
            let a = TensorI8::rand_sparse(&[m, k], p, rng);
            let wd = TensorI8::rand(&[k, n], rng);
            let enc = ActDbb::encode(&a, bz);
            assert_eq!(
                adbb_dense_i8(&enc, &wd).data(),
                gemm::dense_i8(&a, &wd).data(),
                "dense m={m} k={k} n={n} bz={bz} p={p}"
            );
            let w = DbbMatrix::compress_topk(&wd, bz, nnz).unwrap();
            let packed = DbbPacked::pack(&w);
            assert_eq!(
                adbb_i8_packed(&enc, &packed).data(),
                gemm::dbb_i8_packed(&a, &packed).data(),
                "dbb m={m} k={k} n={n} bz={bz} nnz={nnz} p={p}"
            );
        });
    }
}
