//! Register-blocked, cache-tiled SIMD microkernels for the shared i8 inner
//! loops — the software twin of the paper's §IV MAC-dense PE.
//!
//! Every datapath in the crate (serial, [`crate::gemm::tiled`], fused
//! IM2COL, gated, joint A×W DBB) funnels into the row kernels of
//! [`crate::gemm`]. This module re-implements those kernels as
//! register-blocked microkernels and puts a one-decision dispatch layer in
//! front: AVX2 and SSE2 on x86_64 (picked once per process via
//! `is_x86_feature_detected!`), NEON on aarch64, and the untouched scalar
//! kernels everywhere else — the scalar kernels remain the bit-exactness
//! oracle and the universal fallback.
//!
//! ## Why this is the paper's multi-MAC PE
//!
//! S2TA's core argument (PAPERS.md) is that a PE amortizes its operand
//! fetches by keeping one operand *resident* while many MACs consume it.
//! The dense microkernel is exactly that in registers: one broadcast
//! activation (`set1`) is reused across an [`NR`]-wide column block held in
//! accumulator registers — [`NR`] MACs per A-operand fetch, the in-register
//! form of Snippet 2's cyclic cached-weight dataflow (one cached operand,
//! cycled against a stream). The K×N cache tiling ([`KC`]×[`NR`]) keeps the
//! streamed W panel L1/L2-resident across all M rows, which is the SPOTS
//! blocked-systolic-GEMM observation applied to a host CPU.
//!
//! ## Exact-accumulation contract
//!
//! Every kernel here is **bit-exact** with its scalar oracle, for every
//! shape, sparsity and ISA:
//!
//! * Products are exact: `|i8 × i8| ≤ 127² = 16129 < 2^15`, so the widened
//!   i16 product lanes (`mullo_epi16` / `vmull_s8`) never wrap, and each
//!   product is widened to a full i32 lane before any addition.
//! * Accumulation is i32 two's-complement addition, which is associative
//!   *and* commutative — unlike float, **any** reassociation (K-tiling,
//!   lane-parallel partial sums) produces the identical bit pattern. The
//!   SIMD kernels therefore do not need to replay the scalar term order;
//!   the property suite (`rust/tests/micro_kernels.rs`) pins value-equality
//!   against the scalar oracle for every shape × sparsity × ISA path.
//! * The contract assumes the accumulation itself stays inside i32, same as
//!   the scalar kernels (which panic on overflow in debug builds): with i8
//!   operands that holds for any `K ≤ 2^31 / 127² ≈ 133k`, far above every
//!   shape in the repo.
//!
//! ## Dispatch rules
//!
//! * The default ISA is resolved **once per process** ([`active_isa`]):
//!   best detected ISA, unless the `SSTA_FORCE_ISA` env var
//!   (`scalar|sse2|avx2|neon`, case-insensitive) overrides it. An unknown
//!   name panics (a misconfigured CI matrix must be loud); a *known but
//!   unsupported* name clamps down to the best supported ISA of no higher
//!   rank and warns on stderr.
//! * [`force_isa`] installs a process-global programmatic override (tests
//!   and the bench speedup report use it); `force_isa(None)` restores the
//!   default. Forcing an unsupported ISA panics.
//! * Gated variants: under a SIMD ISA the *ungated* microkernels already
//!   skip zero activations (the dense kernel tests each broadcast operand,
//!   the DBB kernel skips all-zero 8-row lane groups and all-zero row
//!   blocks), so `dense_rows_i8_gated` / `dbb_rows_i8_gated` route to the
//!   same microkernels; only the scalar ISA keeps the dedicated scalar
//!   gated kernels. Bit-exactness makes the two routes indistinguishable.
//! * The DBB microkernel packs an [`MR`]-row activation block into a
//!   column-major stack transpose buffer; `K > `[`DBB_PACK_MAX_K`] falls
//!   back to the scalar kernel (no shape in the repo comes close).
//! * The merge-join joint kernel (`adbb_rows_i8`, encoded A × packed W)
//!   stays scalar on every ISA: its control flow is data-dependent on two
//!   compressed index streams and the encoding has already removed the
//!   multiplies SIMD would amortize. Its dense-W sibling
//!   (`adbb_dense_rows_i8`) does vectorize (dense W row axpy per stored
//!   activation entry).
//! * Epilogue requantize (`requant_i8` / `requant_i8_perch`): the fused
//!   output epilogues ([`crate::gemm::epilogue`]) drain i32 accumulator
//!   chunks through a vectorized shift→clamp→narrow (ReLU folded into the
//!   clamp lower bound; lanes clamped to ±127 *before* the saturating
//!   packs so narrowing is exact). Per-channel shifts vectorize on AVX2
//!   (`srav`) and NEON (per-lane `vshlq`); SSE2 has no per-lane variable
//!   shift, so its per-channel path stays on the scalar oracle.
//!
//! Safety: the `unsafe` here is raw-pointer loads/stores inside the
//! per-ISA kernels, each dispatched only when its target feature is
//! detected (or is a baseline feature of the target). The scheduled
//! `cargo miri` CI job interprets the property suite over this module per
//! forced ISA, so the pointer arithmetic is checked, not just reviewed.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

/// Instruction-set paths the dispatch layer can select.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Isa {
    /// The scalar oracle kernels of [`crate::gemm`] — always available.
    Scalar = 0,
    /// 128-bit SSE2 (baseline on every x86_64).
    Sse2 = 1,
    /// 256-bit AVX2 (runtime-detected on x86_64).
    Avx2 = 2,
    /// 128-bit NEON (baseline on every aarch64).
    Neon = 3,
}

impl Isa {
    /// The `SSTA_FORCE_ISA` vocabulary name of this path.
    pub fn name(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Sse2 => "sse2",
            Isa::Avx2 => "avx2",
            Isa::Neon => "neon",
        }
    }

    /// Parse an `SSTA_FORCE_ISA` value (case-insensitive).
    pub fn from_name(s: &str) -> Option<Isa> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scalar" => Some(Isa::Scalar),
            "sse2" => Some(Isa::Sse2),
            "avx2" => Some(Isa::Avx2),
            "neon" => Some(Isa::Neon),
            _ => None,
        }
    }
}

impl std::fmt::Display for Isa {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Columns per register block of the dense/adbb-dense microkernels: one
/// broadcast activation is reused across this many resident accumulator
/// lanes (the in-register multi-MAC PE).
pub const NR: usize = 16;

/// K-tile of the dense microkernel: the `KC × NR` W panel streamed per
/// (column-block, k-tile) stays cache-resident across all M rows.
pub const KC: usize = 256;

/// Activation rows per packed block of the DBB microkernel — one stored
/// weight entry is broadcast against this many rows at once (and `MR == 8`
/// makes the all-zero lane-group test a single u64 compare).
pub const MR: usize = 8;

/// Largest reduction dim the DBB microkernel packs on the stack
/// (`MR × DBB_PACK_MAX_K` = 64 KiB transpose buffer); larger `K` falls back
/// to the scalar kernel.
pub const DBB_PACK_MAX_K: usize = 8192;

/// `true` when `isa` can be dispatched on this host.
pub fn supported(isa: Isa) -> bool {
    match isa {
        Isa::Scalar => true,
        #[cfg(target_arch = "x86_64")]
        Isa::Sse2 => true,
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => std::arch::is_x86_feature_detected!("avx2"),
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => true,
        _ => false,
    }
}

/// Every ISA [`supported`] on this host, scalar first — the sweep axis of
/// the property suite and the bench speedup report.
pub fn available_isas() -> Vec<Isa> {
    [Isa::Scalar, Isa::Sse2, Isa::Avx2, Isa::Neon]
        .into_iter()
        .filter(|&i| supported(i))
        .collect()
}

/// Width rank for the env-override clamp: scalar < {sse2, neon} < avx2.
fn rank(isa: Isa) -> u8 {
    match isa {
        Isa::Scalar => 0,
        Isa::Sse2 | Isa::Neon => 1,
        Isa::Avx2 => 2,
    }
}

/// Best supported ISA of rank no higher than the requested one (scalar at
/// worst) — how a known-but-unsupported `SSTA_FORCE_ISA` degrades.
fn clamp_to_supported(req: Isa) -> Isa {
    let mut best = Isa::Scalar;
    for isa in [Isa::Sse2, Isa::Neon, Isa::Avx2] {
        if rank(isa) <= rank(req) && rank(isa) >= rank(best) && supported(isa) {
            best = isa;
        }
    }
    best
}

#[cfg(target_arch = "x86_64")]
fn detected_best() -> Isa {
    if std::arch::is_x86_feature_detected!("avx2") {
        Isa::Avx2
    } else {
        Isa::Sse2
    }
}

#[cfg(target_arch = "aarch64")]
fn detected_best() -> Isa {
    Isa::Neon
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
fn detected_best() -> Isa {
    Isa::Scalar
}

/// Process default: `SSTA_FORCE_ISA` if set (unknown name = panic, known
/// but unsupported = clamp + stderr warning), else the best detected ISA.
fn default_isa() -> Isa {
    match std::env::var("SSTA_FORCE_ISA") {
        Ok(s) if !s.trim().is_empty() => {
            let req = Isa::from_name(&s).unwrap_or_else(|| {
                panic!("SSTA_FORCE_ISA={s:?}: unknown ISA (expected scalar|sse2|avx2|neon)")
            });
            if supported(req) {
                req
            } else {
                let got = clamp_to_supported(req);
                eprintln!(
                    "ssta: SSTA_FORCE_ISA={} not supported on this host; dispatching {}",
                    req.name(),
                    got.name()
                );
                got
            }
        }
        _ => detected_best(),
    }
}

static DEFAULT: OnceLock<Isa> = OnceLock::new();
// 0 = no override; otherwise discriminant + 1.
static OVERRIDE: AtomicU8 = AtomicU8::new(0);

fn from_u8(v: u8) -> Isa {
    match v {
        0 => Isa::Scalar,
        1 => Isa::Sse2,
        2 => Isa::Avx2,
        _ => Isa::Neon,
    }
}

/// Install (`Some`) or clear (`None`) the process-global ISA override.
/// Panics if the requested ISA is not [`supported`] on this host — the
/// dispatch layer must never be able to select an undetected feature.
pub fn force_isa(isa: Option<Isa>) {
    if let Some(i) = isa {
        assert!(
            supported(i),
            "ISA {} is not supported on this host (available: {:?})",
            i.name(),
            available_isas()
        );
    }
    let v = match isa {
        None => 0,
        Some(i) => i as u8 + 1,
    };
    OVERRIDE.store(v, Ordering::Relaxed);
}

/// The ISA every micro dispatch call resolves to right now: the
/// [`force_isa`] override if installed, else the once-per-process default
/// (`SSTA_FORCE_ISA` env var or best detected). Always [`supported`].
pub fn active_isa() -> Isa {
    match OVERRIDE.load(Ordering::Relaxed) {
        0 => *DEFAULT.get_or_init(default_isa),
        v => from_u8(v - 1),
    }
}

// ---------------------------------------------------------------------------
// Dispatch wrappers — signature-compatible with the scalar row kernels.
// ---------------------------------------------------------------------------

/// [`crate::gemm::dense_rows_i8`] behind the ISA dispatch. `out.len()` must
/// be a multiple of `n` (every caller tiles in whole rows).
pub(crate) fn dense_rows_i8(
    ad: &[i8],
    wd: &[i8],
    out: &mut [i32],
    row0: usize,
    k: usize,
    n: usize,
) {
    if n == 0 {
        return;
    }
    debug_assert_eq!(out.len() % n, 0, "row kernels take whole output rows");
    match active_isa() {
        // SAFETY (all arms): `active_isa` only returns a `supported()` ISA
        // — detection, the env clamp, and the `force_isa` assert all
        // guarantee it — so the required target features are present.
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { x86::dense_rows_i8_avx2(ad, wd, out, row0, k, n) },
        #[cfg(target_arch = "x86_64")]
        Isa::Sse2 => unsafe { x86::dense_rows_i8_sse2(ad, wd, out, row0, k, n) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { neon::dense_rows_i8_neon(ad, wd, out, row0, k, n) },
        _ => crate::gemm::dense_rows_i8(ad, wd, out, row0, k, n),
    }
}

/// Gated dense rows: the SIMD microkernels already skip zero activations
/// (one test per broadcast operand, amortized over the `NR` lanes), so
/// every SIMD ISA routes to [`dense_rows_i8`]; scalar keeps the dedicated
/// run-length kernel. Bit-exact either way.
pub(crate) fn dense_rows_i8_gated(
    ad: &[i8],
    wd: &[i8],
    out: &mut [i32],
    row0: usize,
    k: usize,
    n: usize,
) {
    if active_isa() == Isa::Scalar {
        crate::gemm::dense_rows_i8_gated(ad, wd, out, row0, k, n)
    } else {
        dense_rows_i8(ad, wd, out, row0, k, n)
    }
}

/// [`crate::gemm::dbb_rows_i8`] behind the ISA dispatch. Falls back to the
/// scalar kernel when `k` exceeds [`DBB_PACK_MAX_K`] (or is 0). Every
/// entry's k-index must be `< k` — upheld by [`crate::gemm::DbbPacked`]
/// construction.
pub(crate) fn dbb_rows_i8(
    ad: &[i8],
    col_ptr: &[usize],
    entries: &[(u32, i32)],
    out: &mut [i32],
    row0: usize,
    k: usize,
    n: usize,
) {
    if n == 0 {
        return;
    }
    debug_assert_eq!(out.len() % n, 0, "row kernels take whole output rows");
    if k == 0 || k > DBB_PACK_MAX_K {
        return crate::gemm::dbb_rows_i8(ad, col_ptr, entries, out, row0, k, n);
    }
    match active_isa() {
        // SAFETY: see `dense_rows_i8` — the active ISA is always supported.
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { x86::dbb_rows_i8_avx2(ad, col_ptr, entries, out, row0, k, n) },
        #[cfg(target_arch = "x86_64")]
        Isa::Sse2 => unsafe { x86::dbb_rows_i8_sse2(ad, col_ptr, entries, out, row0, k, n) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { neon::dbb_rows_i8_neon(ad, col_ptr, entries, out, row0, k, n) },
        _ => crate::gemm::dbb_rows_i8(ad, col_ptr, entries, out, row0, k, n),
    }
}

/// Gated DBB rows: the SIMD microkernel already skips all-zero activation
/// row blocks (pack-time occupancy) and all-zero 8-row lane groups (one
/// u64 compare per stored entry), so every SIMD ISA routes to
/// [`dbb_rows_i8`]; scalar keeps the dedicated occupancy-scan kernel.
pub(crate) fn dbb_rows_i8_gated(
    ad: &[i8],
    col_ptr: &[usize],
    entries: &[(u32, i32)],
    out: &mut [i32],
    row0: usize,
    k: usize,
    n: usize,
) {
    if active_isa() == Isa::Scalar {
        crate::gemm::dbb_rows_i8_gated(ad, col_ptr, entries, out, row0, k, n)
    } else {
        dbb_rows_i8(ad, col_ptr, entries, out, row0, k, n)
    }
}

/// [`crate::gemm::act::adbb_dense_rows_i8`] behind the ISA dispatch: each
/// stored activation entry streams one `NR`-blocked axpy over the dense W
/// row its k-index selects. Every entry's k-index must be `< wd.len() / n`
/// — upheld by [`crate::gemm::ActDbb`] construction.
pub(crate) fn adbb_dense_rows_i8(
    a_row_ptr: &[usize],
    a_entries: &[(u32, i32)],
    wd: &[i8],
    out: &mut [i32],
    row0: usize,
    n: usize,
) {
    if n == 0 {
        return;
    }
    debug_assert_eq!(out.len() % n, 0, "row kernels take whole output rows");
    match active_isa() {
        // SAFETY: see `dense_rows_i8` — the active ISA is always supported.
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe {
            x86::adbb_dense_rows_i8_avx2(a_row_ptr, a_entries, wd, out, row0, n)
        },
        #[cfg(target_arch = "x86_64")]
        Isa::Sse2 => unsafe {
            x86::adbb_dense_rows_i8_sse2(a_row_ptr, a_entries, wd, out, row0, n)
        },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe {
            neon::adbb_dense_rows_i8_neon(a_row_ptr, a_entries, wd, out, row0, n)
        },
        _ => crate::gemm::act::adbb_dense_rows_i8(a_row_ptr, a_entries, wd, out, row0, n),
    }
}

/// Vectorized epilogue requantize (`crate::gemm::requant_rows_i8` behind
/// the ISA dispatch): `out[i] = clamp(acc[i] >> shift, lo, 127)` with
/// `lo = 0` when `relu` — ReLU folded into the clamp lower bound, which is
/// bit-identical to clamp-then-zero. The lanes are clamped to `[-127, 127]`
/// **before** the saturating narrowing packs, so the packs can never round
/// differently from the scalar oracle (saturation is the identity on
/// already-clamped lanes).
pub(crate) fn requant_i8(acc: &[i32], out: &mut [i8], shift: u32, relu: bool) {
    debug_assert_eq!(acc.len(), out.len(), "requant in/out length");
    match active_isa() {
        // SAFETY: see `dense_rows_i8` — the active ISA is always supported.
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { x86::requant_i8_avx2(acc, out, shift, relu) },
        #[cfg(target_arch = "x86_64")]
        Isa::Sse2 => unsafe { x86::requant_i8_sse2(acc, out, shift, relu) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { neon::requant_i8_neon(acc, out, shift, relu) },
        _ => crate::gemm::requant_rows_i8(acc, out, shift, relu),
    }
}

/// Per-channel epilogue requantize (`crate::gemm::requant_rows_i8_perch`
/// behind the ISA dispatch): `shifts` is one shift per output column,
/// cycling per row. AVX2 uses the per-lane variable shift (`srav`); NEON
/// shifts per lane natively (`vshlq` with negated counts); **SSE2 has no
/// per-lane variable shift**, so it stays on the scalar oracle — per-row
/// global requant ([`requant_i8`]) is the vectorized path on SSE2 hosts.
pub(crate) fn requant_i8_perch(acc: &[i32], out: &mut [i8], shifts: &[u32], relu: bool) {
    debug_assert_eq!(acc.len(), out.len(), "requant in/out length");
    debug_assert!(!shifts.is_empty(), "per-channel requant needs >= 1 column");
    debug_assert_eq!(acc.len() % shifts.len(), 0, "requant takes whole rows");
    match active_isa() {
        // SAFETY: see `dense_rows_i8` — the active ISA is always supported.
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { x86::requant_i8_perch_avx2(acc, out, shifts, relu) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { neon::requant_i8_perch_neon(acc, out, shifts, relu) },
        _ => crate::gemm::requant_rows_i8_perch(acc, out, shifts, relu),
    }
}

// ---------------------------------------------------------------------------
// Shared (intrinsic-free) pieces of the per-ISA kernels.
// ---------------------------------------------------------------------------

/// Scalar remainder for the dense microkernels: columns `j0..n` (the
/// `n % NR` tail the register blocks cannot cover), accumulate semantics.
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
fn dense_tail_cols(
    ad: &[i8],
    wd: &[i8],
    out: &mut [i32],
    row0: usize,
    k: usize,
    n: usize,
    j0: usize,
) {
    for (i, crow) in out.chunks_mut(n).enumerate() {
        let row = row0 + i;
        let arow = &ad[row * k..row * k + k];
        for (kk, &a) in arow.iter().enumerate() {
            let av = a as i32;
            if av == 0 {
                continue;
            }
            let wrow = &wd[kk * n + j0..kk * n + n];
            for (cv, &wv) in crow[j0..].iter_mut().zip(wrow) {
                *cv += av * wv as i32;
            }
        }
    }
}

/// Scalar remainder for the adbb-dense microkernels: columns `j0..n`.
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
fn adbb_tail_cols(
    a_row_ptr: &[usize],
    a_entries: &[(u32, i32)],
    wd: &[i8],
    out: &mut [i32],
    row0: usize,
    n: usize,
    j0: usize,
) {
    for (i, crow) in out.chunks_mut(n).enumerate() {
        let row = row0 + i;
        for &(kk, av) in &a_entries[a_row_ptr[row]..a_row_ptr[row + 1]] {
            let wrow = &wd[kk as usize * n + j0..kk as usize * n + n];
            for (cv, &wv) in crow[j0..].iter_mut().zip(wrow) {
                *cv += av * wv as i32;
            }
        }
    }
}

/// Pack one [`MR`]-row activation block into the column-major transpose
/// buffer (`tb[kk*MR + r] = A[base_row + r, kk]`; lanes `r >= mr` zeroed so
/// partial blocks and the u64 lane-group test stay exact). Returns whether
/// any packed value is non-zero — `false` lets the caller write the
/// all-zero block's outputs directly (the block-granular activation gate).
///
/// # Safety
/// `tb` must be valid for writes of `MR * k` bytes.
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
unsafe fn pack_block(ad: &[i8], tb: *mut i8, base_row: usize, mr: usize, k: usize) -> bool {
    let mut any = false;
    for r in 0..MR {
        if r < mr {
            let arow = &ad[(base_row + r) * k..(base_row + r) * k + k];
            for (kk, &v) in arow.iter().enumerate() {
                tb.add(kk * MR + r).write(v);
                any |= v != 0;
            }
        } else {
            for kk in 0..k {
                tb.add(kk * MR + r).write(0);
            }
        }
    }
    any
}

// ---------------------------------------------------------------------------
// x86_64: AVX2 + SSE2
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod x86 {
    use core::arch::x86_64::*;
    use core::mem::MaybeUninit;

    use super::{adbb_tail_cols, dense_tail_cols, pack_block, DBB_PACK_MAX_K, KC, MR, NR};

    /// Sign-extend 16 i8 lanes to two i16 octets (SSE2 has no `cvtepi8`).
    #[inline(always)]
    unsafe fn widen16_sse2(v: __m128i) -> (__m128i, __m128i) {
        let sign = _mm_cmpgt_epi8(_mm_setzero_si128(), v);
        (_mm_unpacklo_epi8(v, sign), _mm_unpackhi_epi8(v, sign))
    }

    /// Exact i32 products of 8 i16 lanes × a broadcast i16 via the
    /// lo/hi-half multiply pair (`a*b = lo | hi << 16`), split into the two
    /// i32 quads in lane order.
    #[inline(always)]
    unsafe fn mul_i16_to_i32_sse2(a: __m128i, b: __m128i) -> (__m128i, __m128i) {
        let lo = _mm_mullo_epi16(a, b);
        let hi = _mm_mulhi_epi16(a, b);
        (_mm_unpacklo_epi16(lo, hi), _mm_unpackhi_epi16(lo, hi))
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn dense_rows_i8_avx2(
        ad: &[i8],
        wd: &[i8],
        out: &mut [i32],
        row0: usize,
        k: usize,
        n: usize,
    ) {
        let rows = out.len() / n;
        let nb = n - n % NR;
        let op = out.as_mut_ptr();
        let wp = wd.as_ptr();
        for j0 in (0..nb).step_by(NR) {
            let mut kt = 0usize;
            while kt < k {
                let kend = (kt + KC).min(k);
                for i in 0..rows {
                    let arow = &ad[(row0 + i) * k..(row0 + i) * k + k];
                    let cp = op.add(i * n + j0);
                    let mut acc0 = _mm256_loadu_si256(cp as *const __m256i);
                    let mut acc1 = _mm256_loadu_si256(cp.add(8) as *const __m256i);
                    for (off, &a) in arow[kt..kend].iter().enumerate() {
                        if a == 0 {
                            continue;
                        }
                        let kk = kt + off;
                        let a16 = _mm256_set1_epi16(a as i16);
                        let w8 = _mm_loadu_si128(wp.add(kk * n + j0) as *const __m128i);
                        let w16 = _mm256_cvtepi8_epi16(w8);
                        // exact: |i8·i8| ≤ 2^14 < i16::MAX
                        let p = _mm256_mullo_epi16(w16, a16);
                        let p_lo = _mm256_cvtepi16_epi32(_mm256_castsi256_si128(p));
                        let p_hi = _mm256_cvtepi16_epi32(_mm256_extracti128_si256::<1>(p));
                        acc0 = _mm256_add_epi32(acc0, p_lo);
                        acc1 = _mm256_add_epi32(acc1, p_hi);
                    }
                    _mm256_storeu_si256(cp as *mut __m256i, acc0);
                    _mm256_storeu_si256(cp.add(8) as *mut __m256i, acc1);
                }
                kt = kend;
            }
        }
        if nb < n {
            dense_tail_cols(ad, wd, out, row0, k, n, nb);
        }
    }

    #[target_feature(enable = "sse2")]
    pub(super) unsafe fn dense_rows_i8_sse2(
        ad: &[i8],
        wd: &[i8],
        out: &mut [i32],
        row0: usize,
        k: usize,
        n: usize,
    ) {
        let rows = out.len() / n;
        let nb = n - n % NR;
        let op = out.as_mut_ptr();
        let wp = wd.as_ptr();
        for j0 in (0..nb).step_by(NR) {
            let mut kt = 0usize;
            while kt < k {
                let kend = (kt + KC).min(k);
                for i in 0..rows {
                    let arow = &ad[(row0 + i) * k..(row0 + i) * k + k];
                    let cp = op.add(i * n + j0);
                    let mut acc0 = _mm_loadu_si128(cp as *const __m128i);
                    let mut acc1 = _mm_loadu_si128(cp.add(4) as *const __m128i);
                    let mut acc2 = _mm_loadu_si128(cp.add(8) as *const __m128i);
                    let mut acc3 = _mm_loadu_si128(cp.add(12) as *const __m128i);
                    for (off, &a) in arow[kt..kend].iter().enumerate() {
                        if a == 0 {
                            continue;
                        }
                        let kk = kt + off;
                        let a16 = _mm_set1_epi16(a as i16);
                        let w8 = _mm_loadu_si128(wp.add(kk * n + j0) as *const __m128i);
                        let (wlo, whi) = widen16_sse2(w8);
                        let (p0, p1) = mul_i16_to_i32_sse2(wlo, a16);
                        let (p2, p3) = mul_i16_to_i32_sse2(whi, a16);
                        acc0 = _mm_add_epi32(acc0, p0);
                        acc1 = _mm_add_epi32(acc1, p1);
                        acc2 = _mm_add_epi32(acc2, p2);
                        acc3 = _mm_add_epi32(acc3, p3);
                    }
                    _mm_storeu_si128(cp as *mut __m128i, acc0);
                    _mm_storeu_si128(cp.add(4) as *mut __m128i, acc1);
                    _mm_storeu_si128(cp.add(8) as *mut __m128i, acc2);
                    _mm_storeu_si128(cp.add(12) as *mut __m128i, acc3);
                }
                kt = kend;
            }
        }
        if nb < n {
            dense_tail_cols(ad, wd, out, row0, k, n, nb);
        }
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn dbb_rows_i8_avx2(
        ad: &[i8],
        col_ptr: &[usize],
        entries: &[(u32, i32)],
        out: &mut [i32],
        row0: usize,
        k: usize,
        n: usize,
    ) {
        let rows = out.len() / n;
        let mut tbuf = MaybeUninit::<[i8; MR * DBB_PACK_MAX_K]>::uninit();
        let tb = tbuf.as_mut_ptr() as *mut i8;
        let mut rb = 0usize;
        while rb < rows {
            let mr = MR.min(rows - rb);
            // SAFETY: tb holds MR * DBB_PACK_MAX_K bytes and k <= DBB_PACK_MAX_K.
            if !pack_block(ad, tb, row0 + rb, mr, k) {
                // all-zero activation block: every output is an exact 0
                // (the kernel assigns, not accumulates)
                for r in 0..mr {
                    out[(rb + r) * n..(rb + r) * n + n].fill(0);
                }
                rb += MR;
                continue;
            }
            let mut tmp = [0i32; MR];
            for col in 0..n {
                let mut acc = _mm256_setzero_si256();
                for &(kk, wv) in &entries[col_ptr[col]..col_ptr[col + 1]] {
                    debug_assert!((kk as usize) < k, "DBB entry k-index out of range");
                    let lane = (tb.add(kk as usize * MR) as *const u64).read_unaligned();
                    if lane == 0 {
                        continue; // all 8 muxed activations are zero
                    }
                    let a32 = _mm256_cvtepi8_epi32(_mm_cvtsi64_si128(lane as i64));
                    acc = _mm256_add_epi32(acc, _mm256_mullo_epi32(a32, _mm256_set1_epi32(wv)));
                }
                _mm256_storeu_si256(tmp.as_mut_ptr() as *mut __m256i, acc);
                for r in 0..mr {
                    out[(rb + r) * n + col] = tmp[r];
                }
            }
            rb += MR;
        }
    }

    #[target_feature(enable = "sse2")]
    pub(super) unsafe fn dbb_rows_i8_sse2(
        ad: &[i8],
        col_ptr: &[usize],
        entries: &[(u32, i32)],
        out: &mut [i32],
        row0: usize,
        k: usize,
        n: usize,
    ) {
        let rows = out.len() / n;
        let mut tbuf = MaybeUninit::<[i8; MR * DBB_PACK_MAX_K]>::uninit();
        let tb = tbuf.as_mut_ptr() as *mut i8;
        let mut rb = 0usize;
        while rb < rows {
            let mr = MR.min(rows - rb);
            // SAFETY: tb holds MR * DBB_PACK_MAX_K bytes and k <= DBB_PACK_MAX_K.
            if !pack_block(ad, tb, row0 + rb, mr, k) {
                for r in 0..mr {
                    out[(rb + r) * n..(rb + r) * n + n].fill(0);
                }
                rb += MR;
                continue;
            }
            let mut tmp = [0i32; MR];
            for col in 0..n {
                let mut acc_lo = _mm_setzero_si128();
                let mut acc_hi = _mm_setzero_si128();
                for &(kk, wv) in &entries[col_ptr[col]..col_ptr[col + 1]] {
                    debug_assert!((kk as usize) < k, "DBB entry k-index out of range");
                    let lane = (tb.add(kk as usize * MR) as *const u64).read_unaligned();
                    if lane == 0 {
                        continue;
                    }
                    let v = _mm_cvtsi64_si128(lane as i64);
                    let sign = _mm_cmpgt_epi8(_mm_setzero_si128(), v);
                    let a16 = _mm_unpacklo_epi8(v, sign);
                    // |wv| <= 127 (DBB values are i8-sourced), so i16 holds it
                    let (p0, p1) = mul_i16_to_i32_sse2(a16, _mm_set1_epi16(wv as i16));
                    acc_lo = _mm_add_epi32(acc_lo, p0);
                    acc_hi = _mm_add_epi32(acc_hi, p1);
                }
                _mm_storeu_si128(tmp.as_mut_ptr() as *mut __m128i, acc_lo);
                _mm_storeu_si128(tmp.as_mut_ptr().add(4) as *mut __m128i, acc_hi);
                for r in 0..mr {
                    out[(rb + r) * n + col] = tmp[r];
                }
            }
            rb += MR;
        }
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn adbb_dense_rows_i8_avx2(
        a_row_ptr: &[usize],
        a_entries: &[(u32, i32)],
        wd: &[i8],
        out: &mut [i32],
        row0: usize,
        n: usize,
    ) {
        let rows = out.len() / n;
        let nb = n - n % NR;
        let op = out.as_mut_ptr();
        let wp = wd.as_ptr();
        for i in 0..rows {
            let ents = &a_entries[a_row_ptr[row0 + i]..a_row_ptr[row0 + i + 1]];
            for j0 in (0..nb).step_by(NR) {
                let cp = op.add(i * n + j0);
                let mut acc0 = _mm256_loadu_si256(cp as *const __m256i);
                let mut acc1 = _mm256_loadu_si256(cp.add(8) as *const __m256i);
                for &(kk, av) in ents {
                    // |av| <= 127 (encoded from i8), so i16 holds it
                    let a16 = _mm256_set1_epi16(av as i16);
                    let w8 = _mm_loadu_si128(wp.add(kk as usize * n + j0) as *const __m128i);
                    let w16 = _mm256_cvtepi8_epi16(w8);
                    let p = _mm256_mullo_epi16(w16, a16);
                    let p_lo = _mm256_cvtepi16_epi32(_mm256_castsi256_si128(p));
                    let p_hi = _mm256_cvtepi16_epi32(_mm256_extracti128_si256::<1>(p));
                    acc0 = _mm256_add_epi32(acc0, p_lo);
                    acc1 = _mm256_add_epi32(acc1, p_hi);
                }
                _mm256_storeu_si256(cp as *mut __m256i, acc0);
                _mm256_storeu_si256(cp.add(8) as *mut __m256i, acc1);
            }
        }
        if nb < n {
            adbb_tail_cols(a_row_ptr, a_entries, wd, out, row0, n, nb);
        }
    }

    #[target_feature(enable = "sse2")]
    pub(super) unsafe fn adbb_dense_rows_i8_sse2(
        a_row_ptr: &[usize],
        a_entries: &[(u32, i32)],
        wd: &[i8],
        out: &mut [i32],
        row0: usize,
        n: usize,
    ) {
        let rows = out.len() / n;
        let nb = n - n % NR;
        let op = out.as_mut_ptr();
        let wp = wd.as_ptr();
        for i in 0..rows {
            let ents = &a_entries[a_row_ptr[row0 + i]..a_row_ptr[row0 + i + 1]];
            for j0 in (0..nb).step_by(NR) {
                let cp = op.add(i * n + j0);
                let mut acc0 = _mm_loadu_si128(cp as *const __m128i);
                let mut acc1 = _mm_loadu_si128(cp.add(4) as *const __m128i);
                let mut acc2 = _mm_loadu_si128(cp.add(8) as *const __m128i);
                let mut acc3 = _mm_loadu_si128(cp.add(12) as *const __m128i);
                for &(kk, av) in ents {
                    let a16 = _mm_set1_epi16(av as i16);
                    let w8 = _mm_loadu_si128(wp.add(kk as usize * n + j0) as *const __m128i);
                    let (wlo, whi) = widen16_sse2(w8);
                    let (p0, p1) = mul_i16_to_i32_sse2(wlo, a16);
                    let (p2, p3) = mul_i16_to_i32_sse2(whi, a16);
                    acc0 = _mm_add_epi32(acc0, p0);
                    acc1 = _mm_add_epi32(acc1, p1);
                    acc2 = _mm_add_epi32(acc2, p2);
                    acc3 = _mm_add_epi32(acc3, p3);
                }
                _mm_storeu_si128(cp as *mut __m128i, acc0);
                _mm_storeu_si128(cp.add(4) as *mut __m128i, acc1);
                _mm_storeu_si128(cp.add(8) as *mut __m128i, acc2);
                _mm_storeu_si128(cp.add(12) as *mut __m128i, acc3);
            }
        }
        if nb < n {
            adbb_tail_cols(a_row_ptr, a_entries, wd, out, row0, n, nb);
        }
    }

    /// SSE2 lacks `min/max_epi32` (SSE4.1); build them from `cmpgt` blends.
    #[inline(always)]
    unsafe fn min_epi32_sse2(a: __m128i, b: __m128i) -> __m128i {
        let gt = _mm_cmpgt_epi32(a, b);
        _mm_or_si128(_mm_and_si128(gt, b), _mm_andnot_si128(gt, a))
    }

    #[inline(always)]
    unsafe fn max_epi32_sse2(a: __m128i, b: __m128i) -> __m128i {
        let gt = _mm_cmpgt_epi32(a, b);
        _mm_or_si128(_mm_and_si128(gt, a), _mm_andnot_si128(gt, b))
    }

    /// Narrow 8 already-clamped i32 lanes (two AVX2 128-bit halves of one
    /// 256-bit vector) to 8 i8 bytes. Exact because every lane is in
    /// `[-127, 127]` before the saturating packs.
    #[inline(always)]
    unsafe fn narrow8_avx2(c: __m256i) -> __m128i {
        let p16 = _mm256_packs_epi32(c, c); // [c0..3,c0..3 | c4..7,c4..7] i16
        let lo = _mm256_castsi256_si128(p16);
        let hi = _mm256_extracti128_si256::<1>(p16);
        let merged = _mm_unpacklo_epi64(lo, hi); // c0..c7 i16
        _mm_packs_epi16(merged, merged)
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn requant_i8_avx2(acc: &[i32], out: &mut [i8], shift: u32, relu: bool) {
        let n = acc.len();
        let nb = n - n % 8;
        let lo = if relu { 0 } else { -127 };
        let lov = _mm256_set1_epi32(lo);
        let hiv = _mm256_set1_epi32(127);
        let cnt = _mm_cvtsi32_si128(shift as i32);
        let ap = acc.as_ptr();
        let op = out.as_mut_ptr();
        for i in (0..nb).step_by(8) {
            let v = _mm256_loadu_si256(ap.add(i) as *const __m256i);
            let s = _mm256_sra_epi32(v, cnt);
            let c = _mm256_min_epi32(_mm256_max_epi32(s, lov), hiv);
            _mm_storel_epi64(op.add(i) as *mut __m128i, narrow8_avx2(c));
        }
        for i in nb..n {
            out[i] = (acc[i] >> shift).clamp(lo, 127) as i8;
        }
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn requant_i8_perch_avx2(
        acc: &[i32],
        out: &mut [i8],
        shifts: &[u32],
        relu: bool,
    ) {
        let n = shifts.len();
        let nb = n - n % 8;
        let lo = if relu { 0 } else { -127 };
        let lov = _mm256_set1_epi32(lo);
        let hiv = _mm256_set1_epi32(127);
        let rows = acc.len() / n;
        let ap = acc.as_ptr();
        let op = out.as_mut_ptr();
        let sp = shifts.as_ptr();
        for r in 0..rows {
            for j in (0..nb).step_by(8) {
                let v = _mm256_loadu_si256(ap.add(r * n + j) as *const __m256i);
                // shifts are < 32, so the u32 bits are valid srav counts
                let cnt = _mm256_loadu_si256(sp.add(j) as *const __m256i);
                let s = _mm256_srav_epi32(v, cnt);
                let c = _mm256_min_epi32(_mm256_max_epi32(s, lov), hiv);
                _mm_storel_epi64(op.add(r * n + j) as *mut __m128i, narrow8_avx2(c));
            }
            for j in nb..n {
                out[r * n + j] = (acc[r * n + j] >> shifts[j]).clamp(lo, 127) as i8;
            }
        }
    }

    #[target_feature(enable = "sse2")]
    pub(super) unsafe fn requant_i8_sse2(acc: &[i32], out: &mut [i8], shift: u32, relu: bool) {
        let n = acc.len();
        let nb = n - n % 4;
        let lo = if relu { 0 } else { -127 };
        let lov = _mm_set1_epi32(lo);
        let hiv = _mm_set1_epi32(127);
        let cnt = _mm_cvtsi32_si128(shift as i32);
        let ap = acc.as_ptr();
        let op = out.as_mut_ptr();
        for i in (0..nb).step_by(4) {
            let v = _mm_loadu_si128(ap.add(i) as *const __m128i);
            let s = _mm_sra_epi32(v, cnt);
            let c = min_epi32_sse2(max_epi32_sse2(s, lov), hiv);
            // exact: lanes already in [-127, 127] before the packs
            let p8 = _mm_packs_epi16(_mm_packs_epi32(c, c), _mm_setzero_si128());
            (op.add(i) as *mut i32).write_unaligned(_mm_cvtsi128_si32(p8));
        }
        for i in nb..n {
            out[i] = (acc[i] >> shift).clamp(lo, 127) as i8;
        }
    }
}

#[cfg(target_arch = "aarch64")]
mod neon {
    use core::arch::aarch64::*;
    use core::mem::MaybeUninit;

    use super::{adbb_tail_cols, dense_tail_cols, pack_block, DBB_PACK_MAX_K, KC, MR, NR};

    #[target_feature(enable = "neon")]
    pub(super) unsafe fn dense_rows_i8_neon(
        ad: &[i8],
        wd: &[i8],
        out: &mut [i32],
        row0: usize,
        k: usize,
        n: usize,
    ) {
        let rows = out.len() / n;
        let nb = n - n % NR;
        let op = out.as_mut_ptr();
        let wp = wd.as_ptr();
        for j0 in (0..nb).step_by(NR) {
            let mut kt = 0usize;
            while kt < k {
                let kend = (kt + KC).min(k);
                for i in 0..rows {
                    let arow = &ad[(row0 + i) * k..(row0 + i) * k + k];
                    let cp = op.add(i * n + j0);
                    let mut acc0 = vld1q_s32(cp);
                    let mut acc1 = vld1q_s32(cp.add(4));
                    let mut acc2 = vld1q_s32(cp.add(8));
                    let mut acc3 = vld1q_s32(cp.add(12));
                    for (off, &a) in arow[kt..kend].iter().enumerate() {
                        if a == 0 {
                            continue;
                        }
                        let kk = kt + off;
                        let a8 = vdup_n_s8(a);
                        let w = vld1q_s8(wp.add(kk * n + j0));
                        // exact i16 products: |i8·i8| ≤ 2^14
                        let p_lo = vmull_s8(vget_low_s8(w), a8);
                        let p_hi = vmull_s8(vget_high_s8(w), a8);
                        acc0 = vaddw_s16(acc0, vget_low_s16(p_lo));
                        acc1 = vaddw_s16(acc1, vget_high_s16(p_lo));
                        acc2 = vaddw_s16(acc2, vget_low_s16(p_hi));
                        acc3 = vaddw_s16(acc3, vget_high_s16(p_hi));
                    }
                    vst1q_s32(cp, acc0);
                    vst1q_s32(cp.add(4), acc1);
                    vst1q_s32(cp.add(8), acc2);
                    vst1q_s32(cp.add(12), acc3);
                }
                kt = kend;
            }
        }
        if nb < n {
            dense_tail_cols(ad, wd, out, row0, k, n, nb);
        }
    }

    #[target_feature(enable = "neon")]
    pub(super) unsafe fn dbb_rows_i8_neon(
        ad: &[i8],
        col_ptr: &[usize],
        entries: &[(u32, i32)],
        out: &mut [i32],
        row0: usize,
        k: usize,
        n: usize,
    ) {
        let rows = out.len() / n;
        let mut tbuf = MaybeUninit::<[i8; MR * DBB_PACK_MAX_K]>::uninit();
        let tb = tbuf.as_mut_ptr() as *mut i8;
        let mut rb = 0usize;
        while rb < rows {
            let mr = MR.min(rows - rb);
            // SAFETY: tb holds MR * DBB_PACK_MAX_K bytes and k <= DBB_PACK_MAX_K.
            if !pack_block(ad, tb, row0 + rb, mr, k) {
                for r in 0..mr {
                    out[(rb + r) * n..(rb + r) * n + n].fill(0);
                }
                rb += MR;
                continue;
            }
            let mut tmp = [0i32; MR];
            for col in 0..n {
                let mut acc_lo = vdupq_n_s32(0);
                let mut acc_hi = vdupq_n_s32(0);
                for &(kk, wv) in &entries[col_ptr[col]..col_ptr[col + 1]] {
                    debug_assert!((kk as usize) < k, "DBB entry k-index out of range");
                    let lane = (tb.add(kk as usize * MR) as *const u64).read_unaligned();
                    if lane == 0 {
                        continue; // all 8 muxed activations are zero
                    }
                    let v = vcreate_s8(lane);
                    // |wv| <= 127 (DBB values are i8-sourced)
                    let p = vmull_s8(v, vdup_n_s8(wv as i8));
                    acc_lo = vaddw_s16(acc_lo, vget_low_s16(p));
                    acc_hi = vaddw_s16(acc_hi, vget_high_s16(p));
                }
                vst1q_s32(tmp.as_mut_ptr(), acc_lo);
                vst1q_s32(tmp.as_mut_ptr().add(4), acc_hi);
                for r in 0..mr {
                    out[(rb + r) * n + col] = tmp[r];
                }
            }
            rb += MR;
        }
    }

    #[target_feature(enable = "neon")]
    pub(super) unsafe fn adbb_dense_rows_i8_neon(
        a_row_ptr: &[usize],
        a_entries: &[(u32, i32)],
        wd: &[i8],
        out: &mut [i32],
        row0: usize,
        n: usize,
    ) {
        let rows = out.len() / n;
        let nb = n - n % NR;
        let op = out.as_mut_ptr();
        let wp = wd.as_ptr();
        for i in 0..rows {
            let ents = &a_entries[a_row_ptr[row0 + i]..a_row_ptr[row0 + i + 1]];
            for j0 in (0..nb).step_by(NR) {
                let cp = op.add(i * n + j0);
                let mut acc0 = vld1q_s32(cp);
                let mut acc1 = vld1q_s32(cp.add(4));
                let mut acc2 = vld1q_s32(cp.add(8));
                let mut acc3 = vld1q_s32(cp.add(12));
                for &(kk, av) in ents {
                    // |av| <= 127 (encoded from i8)
                    let a8 = vdup_n_s8(av as i8);
                    let w = vld1q_s8(wp.add(kk as usize * n + j0));
                    let p_lo = vmull_s8(vget_low_s8(w), a8);
                    let p_hi = vmull_s8(vget_high_s8(w), a8);
                    acc0 = vaddw_s16(acc0, vget_low_s16(p_lo));
                    acc1 = vaddw_s16(acc1, vget_high_s16(p_lo));
                    acc2 = vaddw_s16(acc2, vget_low_s16(p_hi));
                    acc3 = vaddw_s16(acc3, vget_high_s16(p_hi));
                }
                vst1q_s32(cp, acc0);
                vst1q_s32(cp.add(4), acc1);
                vst1q_s32(cp.add(8), acc2);
                vst1q_s32(cp.add(12), acc3);
            }
        }
        if nb < n {
            adbb_tail_cols(a_row_ptr, a_entries, wd, out, row0, n, nb);
        }
    }

    /// Narrow 8 already-clamped i32 lanes to 8 i8 bytes and store. Exact
    /// because every lane is in `[-127, 127]` before the narrowing.
    #[inline(always)]
    unsafe fn narrow_store8_neon(dst: *mut i8, c0: int32x4_t, c1: int32x4_t) {
        let m16 = vcombine_s16(vmovn_s32(c0), vmovn_s32(c1));
        vst1_s8(dst, vmovn_s16(m16));
    }

    #[target_feature(enable = "neon")]
    pub(super) unsafe fn requant_i8_neon(acc: &[i32], out: &mut [i8], shift: u32, relu: bool) {
        let n = acc.len();
        let nb = n - n % 8;
        let lo = if relu { 0 } else { -127 };
        let lov = vdupq_n_s32(lo);
        let hiv = vdupq_n_s32(127);
        // vshlq with a negative count is an arithmetic right shift —
        // identical semantics to Rust's `>>` on i32
        let sh = vdupq_n_s32(-(shift as i32));
        let ap = acc.as_ptr();
        let op = out.as_mut_ptr();
        for i in (0..nb).step_by(8) {
            let s0 = vshlq_s32(vld1q_s32(ap.add(i)), sh);
            let s1 = vshlq_s32(vld1q_s32(ap.add(i + 4)), sh);
            let c0 = vminq_s32(vmaxq_s32(s0, lov), hiv);
            let c1 = vminq_s32(vmaxq_s32(s1, lov), hiv);
            narrow_store8_neon(op.add(i), c0, c1);
        }
        for i in nb..n {
            out[i] = (acc[i] >> shift).clamp(lo, 127) as i8;
        }
    }

    #[target_feature(enable = "neon")]
    pub(super) unsafe fn requant_i8_perch_neon(
        acc: &[i32],
        out: &mut [i8],
        shifts: &[u32],
        relu: bool,
    ) {
        let n = shifts.len();
        let nb = n - n % 8;
        let lo = if relu { 0 } else { -127 };
        let lov = vdupq_n_s32(lo);
        let hiv = vdupq_n_s32(127);
        let rows = acc.len() / n;
        let ap = acc.as_ptr();
        let op = out.as_mut_ptr();
        let sp = shifts.as_ptr();
        for r in 0..rows {
            for j in (0..nb).step_by(8) {
                let sh0 = vnegq_s32(vreinterpretq_s32_u32(vld1q_u32(sp.add(j))));
                let sh1 = vnegq_s32(vreinterpretq_s32_u32(vld1q_u32(sp.add(j + 4))));
                let s0 = vshlq_s32(vld1q_s32(ap.add(r * n + j)), sh0);
                let s1 = vshlq_s32(vld1q_s32(ap.add(r * n + j + 4)), sh1);
                let c0 = vminq_s32(vmaxq_s32(s0, lov), hiv);
                let c1 = vminq_s32(vmaxq_s32(s1, lov), hiv);
                narrow_store8_neon(op.add(r * n + j), c0, c1);
            }
            for j in nb..n {
                out[r * n + j] = (acc[r * n + j] >> shifts[j]).clamp(lo, 127) as i8;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::TensorI8;
    use crate::util::Rng;
    use std::sync::Mutex;

    // The override is process-global; every lib test that forces an ISA
    // serializes on this lock and restores the default on drop. (Other lib
    // tests running concurrently only ever compare dispatch-vs-dispatch or
    // dispatch-vs-scalar values, and every ISA is bit-exact, so a transient
    // override cannot change any of their outcomes.)
    static ISA_LOCK: Mutex<()> = Mutex::new(());

    struct RestoreIsa;
    impl Drop for RestoreIsa {
        fn drop(&mut self) {
            force_isa(None);
        }
    }

    #[test]
    fn isa_names_roundtrip() {
        for isa in [Isa::Scalar, Isa::Sse2, Isa::Avx2, Isa::Neon] {
            assert_eq!(Isa::from_name(isa.name()), Some(isa));
            assert_eq!(Isa::from_name(&isa.name().to_uppercase()), Some(isa));
            assert_eq!(format!("{isa}"), isa.name());
        }
        assert_eq!(Isa::from_name("avx512"), None);
        assert_eq!(Isa::from_name(""), None);
    }

    #[test]
    fn scalar_always_available_and_active_supported() {
        let isas = available_isas();
        assert_eq!(isas.first(), Some(&Isa::Scalar));
        assert!(supported(active_isa()));
        #[cfg(target_arch = "x86_64")]
        assert!(isas.contains(&Isa::Sse2), "SSE2 is x86_64 baseline");
        #[cfg(target_arch = "aarch64")]
        assert!(isas.contains(&Isa::Neon), "NEON is aarch64 baseline");
    }

    #[test]
    fn clamp_respects_rank_and_support() {
        for req in [Isa::Scalar, Isa::Sse2, Isa::Avx2, Isa::Neon] {
            let got = clamp_to_supported(req);
            assert!(supported(got), "clamp({req:?}) -> {got:?}");
            assert!(rank(got) <= rank(req), "clamp({req:?}) -> {got:?}");
        }
        assert_eq!(clamp_to_supported(Isa::Scalar), Isa::Scalar);
    }

    #[test]
    fn requant_kernels_bit_exact_per_isa() {
        let _g = ISA_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let _restore = RestoreIsa;
        let mut rng = Rng::new(0x77);
        for &(rows, n) in &[(1usize, 1usize), (3, 7), (5, 16), (2, 33), (4, 8)] {
            // accumulators spanning tiny to huge magnitudes, plus exact
            // clamp-edge values
            let mut acc: Vec<i32> = (0..rows * n).map(|_| rng.next_u64() as i32).collect();
            for (i, v) in [0i32, 127, -127, -128, 128, i32::MAX, i32::MIN]
                .into_iter()
                .enumerate()
            {
                if i < acc.len() {
                    acc[i] = v;
                }
            }
            let shifts: Vec<u32> = (0..n).map(|_| rng.below(25) as u32).collect();
            for relu in [false, true] {
                for shift in [0u32, 5, 24] {
                    let mut want = vec![0i8; acc.len()];
                    crate::gemm::requant_rows_i8(&acc, &mut want, shift, relu);
                    for isa in available_isas() {
                        force_isa(Some(isa));
                        let mut got = vec![0i8; acc.len()];
                        requant_i8(&acc, &mut got, shift, relu);
                        assert_eq!(got, want, "global isa={isa} shift={shift} relu={relu}");
                    }
                }
                let mut want = vec![0i8; acc.len()];
                crate::gemm::requant_rows_i8_perch(&acc, &mut want, &shifts, relu);
                for isa in available_isas() {
                    force_isa(Some(isa));
                    let mut got = vec![0i8; acc.len()];
                    requant_i8_perch(&acc, &mut got, &shifts, relu);
                    assert_eq!(got, want, "perch isa={isa} relu={relu} rows={rows} n={n}");
                }
            }
        }
        force_isa(None);
    }

    #[test]
    fn forced_isa_is_active_and_kernels_stay_exact() {
        let _g = ISA_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let _restore = RestoreIsa;
        let mut rng = Rng::new(0x51);
        let a = TensorI8::rand_sparse(&[5, 70], 0.4, &mut rng);
        let w = TensorI8::rand(&[70, 19], &mut rng);
        force_isa(Some(Isa::Scalar));
        let want = crate::gemm::dense_i8(&a, &w);
        for isa in available_isas() {
            force_isa(Some(isa));
            assert_eq!(active_isa(), isa);
            assert_eq!(crate::gemm::dense_i8(&a, &w).data(), want.data(), "isa={isa}");
        }
    }
}
