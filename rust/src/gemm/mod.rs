//! Golden (reference) compute: dense INT8 GEMM, DBB-sparse GEMM, and the
//! IM2COL lowering of convolution to GEMM (paper §I: convolutions are
//! lowered to GEMM by linearizing feature maps with IM2COL).
//!
//! Everything in this module is bit-exact integer arithmetic
//! (INT8 × INT8 → INT32 accumulate) and serves as the functional oracle for
//! the datapath simulators and for the XLA/Pallas artifacts.
//!
//! ## Parallelism
//!
//! [`dense_i8`] and [`dbb_i8`] are the single-threaded oracles. The
//! [`tiled`] submodule provides row-tiled multi-threaded versions
//! ([`tiled::dense_i8`] / [`tiled::dbb_i8`]) built on a dependency-free
//! `std::thread::scope` worker pool: the `M` dimension is partitioned into
//! per-thread output tiles, each accumulated in INT32 with the *same* inner
//! kernels as the serial path, so the parallel results are bit-exact with
//! the oracles for every thread count. The knob is
//! [`crate::util::Parallelism`]: `Parallelism::auto()` (the default) uses
//! `std::thread::available_parallelism()`, `Parallelism::serial()` falls
//! back to the exact single-threaded path with no threads spawned.
//!
//! ## Convolution: fused vs materialized
//!
//! Convolutions have two lowerings onto these kernels. The *materializing*
//! path ([`conv::im2col`] + a GEMM) builds the full `[M×K]` patch matrix
//! first — it is the test oracle's lowering, kept because its output is the
//! literal GEMM operand the hardware models reason about. The *production*
//! path is [`fused`]: [`fused::conv2d_i8`] / [`fused::conv2d_dbb_i8`]
//! generate patch rows on the fly inside the tiled worker pool (paper
//! §IV-C's hardware IM2COL unit, in software), never allocating the `M×K`
//! operand — peak extra memory is `O(threads · PATCH_ROWS · K)` — and are
//! bit-exact with [`conv::conv2d_direct`] and with the materializing path.

pub mod conv;
pub mod fused;
pub mod tiled;

use crate::dbb::DbbMatrix;
use crate::tensor::{TensorI32, TensorI8};

/// Inner kernel shared by the serial and tiled dense GEMMs: accumulate the
/// output rows `row0..row0 + out.len()/n` into `out` (a row-contiguous
/// `&mut` window of C). Iteration order is identical for every caller, so
/// tiling cannot change a single bit of the result.
pub(crate) fn dense_rows_i8(
    ad: &[i8],
    wd: &[i8],
    out: &mut [i32],
    row0: usize,
    k: usize,
    n: usize,
) {
    if n == 0 {
        return;
    }
    for (i, crow) in out.chunks_mut(n).enumerate() {
        let row = row0 + i;
        let arow = &ad[row * k..row * k + k];
        for (kk, &a) in arow.iter().enumerate() {
            let av = a as i32;
            if av == 0 {
                continue;
            }
            let wrow = &wd[kk * n..kk * n + n];
            for (cv, &wv) in crow.iter_mut().zip(wrow) {
                *cv += av * wv as i32;
            }
        }
    }
}

/// Dense GEMM: `C[M×N] = A[M×K] · W[K×N]`, INT8 operands, INT32 accumulate.
pub fn dense_i8(a: &TensorI8, w: &TensorI8) -> TensorI32 {
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (w.shape()[0], w.shape()[1]);
    assert_eq!(k, k2, "GEMM inner dims: A[{m}x{k}] W[{k2}x{n}]");
    let mut c = TensorI32::zeros(&[m, n]);
    dense_rows_i8(a.data(), w.data(), c.data_mut(), 0, k, n);
    c
}

/// DBB-sparse GEMM: `C = A · decompress(W)`, computed directly on the
/// compressed form — the functional model of the time-unrolled S8DP1
/// datapath: for each block, each stored non-zero selects (muxes) the
/// activation at its bitmask position.
///
/// Decodes the CSC stream per call; hot loops that reuse one weight matrix
/// should pack once ([`DbbPacked::pack`]) and call [`dbb_i8_packed`] — the
/// prepare-once/execute-many split of [`crate::engine`].
pub fn dbb_i8(a: &TensorI8, w: &DbbMatrix) -> TensorI32 {
    dbb_i8_packed(a, &DbbPacked::pack(w))
}

/// [`dbb_i8`] on a pre-decoded operand: zero per-call decode work. Bit-exact
/// with [`dbb_i8`] on the matrix the operand was packed from (both run the
/// identical `dbb_rows_i8` inner kernel on the identical stream).
pub fn dbb_i8_packed(a: &TensorI8, w: &DbbPacked) -> TensorI32 {
    let (m, k) = (a.shape()[0], a.shape()[1]);
    assert_eq!(k, w.k, "GEMM inner dims: A[{m}x{k}] Wdbb[{}x{}]", w.k, w.n);
    let mut c = TensorI32::zeros(&[m, w.n]);
    dbb_rows_i8(a.data(), w.col_ptr(), w.entries(), c.data_mut(), 0, k, w.n);
    c
}

/// A DBB weight operand decoded once into the flattened per-column
/// `(col_ptr, entries)` CSC stream the row kernels consume — the software
/// form of the paper's §II-A offline-encoded weight stream. Packing is the
/// one-time "compile" step; every GEMM/conv that takes a `DbbPacked`
/// ([`dbb_i8_packed`], [`tiled::dbb_i8_packed`],
/// [`fused::conv2d_dbb_i8_packed`]) runs with zero per-call decode work and
/// is bit-exact with its per-call-decoding counterpart, because both feed
/// the identical stream to the shared `dbb_rows_i8` inner kernel.
#[derive(Debug, Clone)]
pub struct DbbPacked {
    /// Reduction dim of the dense matrix.
    pub k: usize,
    /// Output channels.
    pub n: usize,
    /// Block size the source matrix was encoded with.
    pub bz: usize,
    /// Density bound (max NNZ/block) of the source encoding.
    pub bound: usize,
    col_ptr: Vec<usize>,
    entries: Vec<(u32, i32)>,
}

impl DbbPacked {
    /// Decode a compressed matrix into the flattened CSC stream, once.
    pub fn pack(w: &DbbMatrix) -> DbbPacked {
        let (col_ptr, entries) = dbb_decode_csc(w);
        DbbPacked {
            k: w.k,
            n: w.n,
            bz: w.bz,
            bound: w.bound,
            col_ptr,
            entries,
        }
    }

    /// Per-column offsets into [`Self::entries`] (`n + 1` values).
    pub fn col_ptr(&self) -> &[usize] {
        &self.col_ptr
    }

    /// The `(k-index, value)` stream, column-major.
    pub fn entries(&self) -> &[(u32, i32)] {
        &self.entries
    }

    /// Stored non-zeros.
    pub fn total_nnz(&self) -> usize {
        self.entries.len()
    }

    /// Host bytes the packed stream occupies (the steady-state operand
    /// footprint an executor holds per layer).
    pub fn operand_bytes(&self) -> usize {
        self.col_ptr.len() * std::mem::size_of::<usize>()
            + self.entries.len() * std::mem::size_of::<(u32, i32)>()
    }
}

/// Decode a compressed operand once into a per-column (k-index, value)
/// stream — the CSC view. The per-row pass then walks each output row with
/// the A row hot in L1 and the weight stream sequential, which is ~5x
/// faster than scattering down the columns (§Perf, EXPERIMENTS). Shared by
/// the serial and tiled DBB GEMMs (the tiled workers all read one decode).
pub(crate) fn dbb_decode_csc(w: &DbbMatrix) -> (Vec<usize>, Vec<(u32, i32)>) {
    let kblocks = w.kblocks();
    let n = w.n;
    let mut col_ptr = Vec::with_capacity(n + 1);
    let mut entries: Vec<(u32, i32)> = Vec::with_capacity(w.total_nnz());
    col_ptr.push(0usize);
    for col in 0..n {
        for kb in 0..kblocks {
            let blk = w.block(col, kb);
            for (val, pos) in blk.vals.iter().zip(blk.positions()) {
                let kk = kb * w.bz + pos;
                debug_assert!(kk < w.k, "non-zero in padding region");
                entries.push((kk as u32, *val as i32));
            }
        }
        col_ptr.push(entries.len());
    }
    (col_ptr, entries)
}

/// Inner kernel shared by the serial and tiled DBB GEMMs: accumulate output
/// rows `row0..row0 + out.len()/n` from the decoded CSC stream. Per-element
/// accumulation order is column-stream order for every caller — bit-exact
/// under tiling.
pub(crate) fn dbb_rows_i8(
    ad: &[i8],
    col_ptr: &[usize],
    entries: &[(u32, i32)],
    out: &mut [i32],
    row0: usize,
    k: usize,
    n: usize,
) {
    if n == 0 {
        return;
    }
    for (i, crow) in out.chunks_mut(n).enumerate() {
        let row = row0 + i;
        let arow = &ad[row * k..(row + 1) * k];
        for (col, cv) in crow.iter_mut().enumerate() {
            let mut acc = 0i32;
            for &(kk, wv) in &entries[col_ptr[col]..col_ptr[col + 1]] {
                // the mux: activation A[i, kk] selected by the index
                acc += arow[kk as usize] as i32 * wv;
            }
            *cv = acc;
        }
    }
}

/// Count of effective MAC operations for a DBB GEMM (per paper Table V
/// footnote: "effective operations" = 2 × dense MAC count, independent of
/// how many the hardware actually executed).
pub fn effective_ops(m: usize, k: usize, n: usize) -> u64 {
    2 * m as u64 * k as u64 * n as u64
}

/// MACs the DBB datapath actually executes: `M × kblocks × bound × N`.
pub fn dbb_executed_macs(m: usize, w: &DbbMatrix) -> u64 {
    m as u64 * w.kblocks() as u64 * w.bound as u64 * w.n as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dbb::prune::prune_i8;
    use crate::util::prop::{check, Config};
    use crate::util::Rng;

    #[test]
    fn dense_matches_naive_small() {
        let a = TensorI8::from_vec(&[2, 3], vec![1, 2, 3, 4, 5, 6]);
        let w = TensorI8::from_vec(&[3, 2], vec![7, 8, 9, 10, 11, 12]);
        let c = dense_i8(&a, &w);
        // [[1*7+2*9+3*11, 1*8+2*10+3*12], [4*7+5*9+6*11, 4*8+5*10+6*12]]
        assert_eq!(c.data(), &[58, 64, 139, 154]);
    }

    #[test]
    fn dbb_equals_dense_on_decompressed() {
        check(Config::default().cases(96), |rng| {
            let m = rng.below(12) + 1;
            let k = rng.below(48) + 1;
            let n = rng.below(16) + 1;
            let bz = [4usize, 8, 16][rng.below(3)];
            let nnz = rng.below(bz) + 1;
            let a = TensorI8::rand(&[m, k], rng);
            let wd = prune_i8(&TensorI8::rand(&[k, n], rng), bz, nnz);
            let w = DbbMatrix::compress(&wd, bz).unwrap();
            assert_eq!(
                dbb_i8(&a, &w).data(),
                dense_i8(&a, &wd).data(),
                "m={m} k={k} n={n} bz={bz} nnz={nnz}"
            );
        });
    }

    #[test]
    fn dbb_fully_dense_weights_still_correct() {
        let mut rng = Rng::new(7);
        let a = TensorI8::rand(&[4, 16], &mut rng);
        let wd = TensorI8::rand(&[16, 8], &mut rng);
        let w = DbbMatrix::compress(&wd, 8).unwrap();
        assert_eq!(dbb_i8(&a, &w).data(), dense_i8(&a, &wd).data());
    }

    #[test]
    fn executed_macs_scale_with_bound() {
        let mut rng = Rng::new(8);
        let wd = prune_i8(&TensorI8::rand(&[64, 32], &mut rng), 8, 2);
        let w = DbbMatrix::compress_with_bound(&wd, 8, 2).unwrap();
        // 2/8 bound: executed = M * (64/8) * 2 * 32 = dense/4
        assert_eq!(dbb_executed_macs(16, &w), 16 * 8 * 2 * 32);
        assert_eq!(effective_ops(16, 64, 32), 2 * 16 * 64 * 32);
    }

    #[test]
    fn packed_equals_per_call_decode_prop() {
        check(Config::default().cases(64), |rng| {
            let m = rng.below(12) + 1;
            let k = rng.below(48) + 1;
            let n = rng.below(16) + 1;
            let bz = [4usize, 8, 16][rng.below(3)];
            let nnz = rng.below(bz) + 1;
            let a = TensorI8::rand(&[m, k], rng);
            let w = DbbMatrix::compress_topk(&TensorI8::rand(&[k, n], rng), bz, nnz).unwrap();
            let packed = DbbPacked::pack(&w);
            assert_eq!(packed.total_nnz(), w.total_nnz());
            assert_eq!(
                dbb_i8_packed(&a, &packed).data(),
                dbb_i8(&a, &w).data(),
                "m={m} k={k} n={n} bz={bz} nnz={nnz}"
            );
        });
    }

    #[test]
    fn zero_activation_rows_give_zero_output() {
        let a = TensorI8::zeros(&[3, 8]);
        let mut rng = Rng::new(9);
        let wd = TensorI8::rand(&[8, 4], &mut rng);
        let w = DbbMatrix::compress(&wd, 8).unwrap();
        assert!(dbb_i8(&a, &w).data().iter().all(|&x| x == 0));
    }
}
