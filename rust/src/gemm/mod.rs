//! Golden (reference) compute: dense INT8 GEMM, DBB-sparse GEMM, and the
//! IM2COL lowering of convolution to GEMM (paper §I: convolutions are
//! lowered to GEMM by linearizing feature maps with IM2COL).
//!
//! Everything in this module is bit-exact integer arithmetic
//! (INT8 × INT8 → INT32 accumulate) and serves as the functional oracle for
//! the datapath simulators and for the XLA/Pallas artifacts.
//!
//! ## SIMD microkernels
//!
//! The scalar row kernels in this file (`dense_rows_i8` /
//! `dbb_rows_i8` and friends) are the **bit-exactness oracles**; the hot
//! paths dispatch through [`micro`], which re-implements them as
//! register-blocked, cache-tiled SIMD microkernels (AVX2/SSE2 on x86_64,
//! NEON on aarch64, runtime-detected once per process, `SSTA_FORCE_ISA`
//! overridable) and falls back to the scalar kernels everywhere else.
//! Integer i32 accumulation is exactly associative, so every ISA path is
//! bit-exact with the oracles — property-pinned per shape × sparsity × ISA
//! in `rust/tests/micro_kernels.rs`.
//!
//! ## Parallelism
//!
//! [`dense_i8`] and [`dbb_i8`] are the single-threaded oracles. The
//! [`tiled`] submodule provides row-tiled multi-threaded versions
//! ([`tiled::dense_i8`] / [`tiled::dbb_i8`]) built on a dependency-free
//! `std::thread::scope` worker pool: the `M` dimension is partitioned into
//! per-thread output tiles, each accumulated in INT32 with the *same* inner
//! kernels as the serial path, so the parallel results are bit-exact with
//! the oracles for every thread count. The knob is
//! [`crate::util::Parallelism`]: `Parallelism::auto()` (the default) uses
//! `std::thread::available_parallelism()`, `Parallelism::serial()` falls
//! back to the exact single-threaded path with no threads spawned.
//!
//! ## Convolution: fused vs materialized
//!
//! Convolutions have two lowerings onto these kernels. The *materializing*
//! path ([`conv::im2col`] + a GEMM) builds the full `[M×K]` patch matrix
//! first — it is the test oracle's lowering, kept because its output is the
//! literal GEMM operand the hardware models reason about. The *production*
//! path is [`fused`]: [`fused::conv2d_i8`] / [`fused::conv2d_dbb_i8`]
//! generate patch rows on the fly inside the tiled worker pool (paper
//! §IV-C's hardware IM2COL unit, in software), never allocating the `M×K`
//! operand — peak extra memory is `O(threads · PATCH_ROWS · K)` — and are
//! bit-exact with [`conv::conv2d_direct`] and with the materializing path.
//!
//! ## Activation-side zero-gating
//!
//! The paper's datapath exploits *both* operand sparsities: weight zeros
//! are compressed away offline by the DBB encoding, while activation zeros
//! are **gated in the datapath** — a zero activation suppresses the MAC's
//! switching at runtime (§II, and the Fig. 12 sweeps at 50%/80% activation
//! sparsity). The software analogue is the [`ZeroGate`] policy: the gated
//! kernel variants (`dense_rows_i8_gated` / `dbb_rows_i8_gated`, reached
//! through the `*_gated` entry points of this module, [`tiled`] and
//! [`fused`]) run a cheap per-row occupancy scan over the A operand — O(K),
//! amortized across all `N` output columns — and skip the multiply for every
//! zero activation entry. Skipping is **bit-exact**: a zero activation
//! contributes exactly 0 to the INT32 accumulator and the surviving terms
//! accumulate in the unchanged order, so gated and ungated results are
//! identical to the bit (property-tested in `rust/tests/zero_gate.rs`).
//! `ZeroGate::Auto` engages the gate only when the measured A-side zero
//! fraction clears [`ZeroGate::AUTO_THRESHOLD`]; the end-to-end consumer is
//! [`crate::engine::PreparedModel::execute`], which resolves `Auto` per
//! layer from the activation sparsities its own profile pass measured.
//!
//! ## Activation-side DBB encoding
//!
//! Gating skips the *multiply* but still fetches the operand. The paper's
//! datapath goes further: it consumes a fixed-rate **compressed** stream on
//! both sides of the MAC, and S2TA (Liu et al., 2021) shows the joint
//! weight×activation DBB formulation is where the big energy wins live.
//! The [`act`] submodule is that A-side: [`ActDbb`] encodes the left
//! operand at runtime into the same time-unrolled VDBB block format
//! [`DbbPacked`] uses (bitmask + packed non-zeros per `bz`-block), but
//! row-major and **lossless** (the bound is measured, not pruned to), and
//! the joint kernels ([`adbb_i8_packed`], [`adbb_dense_i8`], their [`tiled`]
//! drivers and the [`fused`] `*_encoded` conv entry points, which encode
//! each generated patch-row chunk right after streaming IM2COL) multiply
//! only `(non-zero activation, stored weight)` pairs — bit-exact with the
//! dense-A oracles. [`ActPolicy`] is the three-way per-operand decision
//! (off / gate / encode); [`crate::engine::PreparedModel::execute`]
//! resolves it per layer from the same recorded profile that drives
//! `ZeroGate::Auto` and that the hardware twin prices.
//!
//! ## Fused output epilogues
//!
//! The output side mirrors the paper's on-chip post-processing (SNIPPETS
//! Snippet 1/2: requantize + ReLU + max-pool right at the accumulator so
//! INT32 intermediates never hit SRAM): the [`epilogue`] submodule defines
//! a pluggable [`Epilogue`] (global or per-channel power-of-two requantize,
//! optional ReLU, optional 2×2/stride-2 max-pool folded into the output row
//! walk). The `*_ep` drivers in [`tiled`] and [`fused`] drain each freshly
//! computed accumulator chunk through it while cache-hot, producing the
//! next layer's INT8 operand directly — no whole-layer i32 tensor is ever
//! allocated. The scalar row kernels `requant_rows_i8` /
//! `requant_rows_i8_perch` below are the rounding oracles (bit-identical
//! to the historical [`requant_relu`]); [`micro`] vectorizes them per ISA.

pub mod act;
pub mod bsr;
pub mod conv;
pub mod epilogue;
pub mod fused;
pub mod micro;
pub mod tiled;

pub use act::{adbb_dense_i8, adbb_i8_packed, ActDbb};
pub use bsr::{bsr_i8_packed, bsr_i8_packed_gated, BsrPacked};
pub use epilogue::{requant_relu, Epilogue, PoolGeom, Requant};

/// Which compressed weight datapath a model (or layer) runs on — the
/// format-polymorphism knob threaded from the pruner
/// ([`crate::dbb::prune`]) through [`crate::engine::PreparedModel`] down
/// to the analytic twin's pricing ([`crate::arch::Datapath`]).
///
/// * `Dbb` — the paper's (V)DBB stream: per-`BZ`-block bitmask + packed
///   non-zeros, fine-grained `NNZ`-of-`BZ` sparsity ([`DbbPacked`]).
/// * `Bsr` — block-sparse-row: whole `bz×bz` zero blocks skipped by a
///   `row_ptr`/`col_idx` scheduler walk, surviving blocks dense
///   ([`BsrPacked`]; SPOTS / SNIPPETS Snippet 1).
/// * `Dense` — no compression; the dense oracle end to end.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum WeightFormat {
    #[default]
    Dbb,
    Bsr,
    Dense,
}

impl WeightFormat {
    /// Stable one-byte tag used by the prepared-model flat binary (v2+).
    pub fn tag(self) -> u8 {
        match self {
            WeightFormat::Dbb => 0,
            WeightFormat::Bsr => 1,
            WeightFormat::Dense => 2,
        }
    }

    /// Inverse of [`Self::tag`] for deserialization.
    pub fn from_tag(tag: u8) -> Option<WeightFormat> {
        match tag {
            0 => Some(WeightFormat::Dbb),
            1 => Some(WeightFormat::Bsr),
            2 => Some(WeightFormat::Dense),
            _ => None,
        }
    }

    /// Human label (CLI parsing / report tables).
    pub fn label(self) -> &'static str {
        match self {
            WeightFormat::Dbb => "dbb",
            WeightFormat::Bsr => "bsr",
            WeightFormat::Dense => "dense",
        }
    }

    /// Parse a CLI token (`dbb` / `bsr` / `dense`, case-insensitive).
    pub fn parse(s: &str) -> Option<WeightFormat> {
        match s.to_ascii_lowercase().as_str() {
            "dbb" => Some(WeightFormat::Dbb),
            "bsr" => Some(WeightFormat::Bsr),
            "dense" => Some(WeightFormat::Dense),
            _ => None,
        }
    }
}

use crate::dbb::DbbMatrix;
use crate::tensor::{TensorI32, TensorI8};

/// Activation-side zero-gating policy — the software form of the paper's
/// A-operand MAC gating (§II: a zero activation suppresses the multiply in
/// the datapath; the DBB encoding only ever compresses the weight side).
///
/// Gating never changes a result bit (`dense_rows_i8_gated` /
/// `dbb_rows_i8_gated` skip terms that are exactly 0 in the INT32
/// accumulation and keep the surviving order), so the policy is purely a
/// performance knob:
///
/// * [`ZeroGate::Off`] — the ungated inner kernels, branch-free per DBB
///   entry. Right when the A operand is dense.
/// * [`ZeroGate::On`] — always run the per-row occupancy scan and skip
///   zero-activation multiplies.
/// * [`ZeroGate::Auto`] (default) — measure (or be told) the A-side zero
///   fraction and gate only when it clears [`ZeroGate::AUTO_THRESHOLD`].
///   At the GEMM/conv driver level the measurement is one `O(M·K)` /
///   `O(H·W·C)` scan of the operand the caller already holds;
///   [`crate::engine::PreparedModel::execute`] resolves `Auto` per layer
///   from its profiled activation sparsities and passes the drivers a
///   pre-resolved `On`/`Off`, so no operand is ever scanned twice.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ZeroGate {
    /// Never gate: the exact pre-gating code path.
    Off,
    /// Gate when the measured A-side zero fraction clears
    /// [`ZeroGate::AUTO_THRESHOLD`].
    #[default]
    Auto,
    /// Always gate.
    On,
}

impl ZeroGate {
    /// A-side zero fraction above which `Auto` engages the gate. Below it
    /// the per-row occupancy scan and the per-entry zero test cost more
    /// than the multiplies they would skip; well above it the DBB walk
    /// drops a proportional fraction of its MACs.
    pub const AUTO_THRESHOLD: f64 = 0.25;

    /// Resolve the policy against a measured A-side zero fraction.
    pub fn engaged(self, act_sparsity: f64) -> bool {
        match self {
            ZeroGate::Off => false,
            ZeroGate::On => true,
            ZeroGate::Auto => act_sparsity >= Self::AUTO_THRESHOLD,
        }
    }

    /// [`Self::engaged`] with the measurement deferred, so `Off`/`On` never
    /// pay the operand scan.
    pub(crate) fn resolve_with<F: FnOnce() -> f64>(self, measure: F) -> bool {
        match self {
            ZeroGate::Off => false,
            ZeroGate::On => true,
            ZeroGate::Auto => measure() >= Self::AUTO_THRESHOLD,
        }
    }

    /// The policy collapsed to a pre-resolved `On`/`Off` (what the engine
    /// hands the kernel drivers after consulting its measured profile).
    pub fn resolved(engage: bool) -> ZeroGate {
        if engage {
            ZeroGate::On
        } else {
            ZeroGate::Off
        }
    }
}

/// Three-way activation-operand policy — the full A-side decision the
/// engine makes per layer, superseding the two-way [`ZeroGate`]:
///
/// * [`ActPolicy::Off`] — stream the operand raw through the ungated
///   kernels. Right for dense activations, where both the occupancy scan
///   and the encode pass cost more than they save.
/// * [`ActPolicy::Gate`] — the [`ZeroGate`] zero-skip kernels: the operand
///   is still fetched in full, but zero activations skip their multiplies
///   ("skipped the multiply").
/// * [`ActPolicy::Encode`] — DBB-encode the operand ([`ActDbb`]) and run
///   the joint kernels: zeros are never stored, streamed, or multiplied
///   ("never fetched the operand"). Costs one `O(M·K)` encode pass plus
///   1 bit/element of index metadata, so it only pays above a higher
///   sparsity than gating.
/// * [`ActPolicy::Auto`] (default) — resolve per operand from the measured
///   A-side zero fraction: `Encode` at ≥ [`ActPolicy::ENCODE_THRESHOLD`],
///   else `Gate` at ≥ [`ActPolicy::GATE_THRESHOLD`], else `Off`.
///
/// Every policy is **bit-exact** with every other (gating skips exact
/// zeros; encoding is lossless), so — like [`ZeroGate`] — this is purely a
/// performance/traffic knob. `Auto`'s thresholds are the **modeled
/// datapath's** break-evens, and the hardware twin prices the identical
/// decision (an encoded layer's A-side SRAM traffic is the compressed
/// stream — values + index bytes — instead of the raw fetch,
/// `crate::sim::analytic::gemm_timing_stats_enc`): one policy source for
/// the executor and the twin, which is the point. On the *software* side
/// the `Encode` tier trades an `O(M·K)` encode pass and a merge-join walk
/// for the skipped fetches, so its wall-clock win over `Gate` is workload-
/// and host-dependent — measure with the `gemm/adbb_*` /
/// `engine/convnet5_execute_encoded` bench entries, and pin
/// [`ActPolicy::Gate`] via the model-level setter where raw execute
/// latency is all that matters.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ActPolicy {
    /// Raw operand, ungated kernels.
    Off,
    /// Zero-gated kernels (fetch everything, skip zero multiplies).
    Gate,
    /// DBB-encode the operand and run the joint A-DBB kernels.
    Encode,
    /// Resolve per operand from the measured A-side zero fraction.
    #[default]
    Auto,
}

impl ActPolicy {
    /// A-side zero fraction at which `Auto` starts gating (the
    /// [`ZeroGate::AUTO_THRESHOLD`] — one threshold, two policies).
    pub const GATE_THRESHOLD: f64 = ZeroGate::AUTO_THRESHOLD;

    /// A-side zero fraction at which `Auto` upgrades gating to encoding —
    /// the **modeled datapath's** traffic break-even: the compressed
    /// stream (surviving values + 1 bit/element of bitmask) undercuts the
    /// raw fetch once more than half the operand is zeros, with margin for
    /// the runtime encode pass. This is an operand-*traffic* threshold,
    /// shared with the twin's pricing — not a measured software-latency
    /// optimum (see the type-level docs).
    pub const ENCODE_THRESHOLD: f64 = 0.5;

    /// Resolve the policy against a measured A-side zero fraction. Fixed
    /// policies return themselves; `Auto` picks the tier the sparsity pays
    /// for. Never returns `Auto`.
    pub fn resolved(self, act_sparsity: f64) -> ActPolicy {
        match self {
            ActPolicy::Auto => {
                if act_sparsity >= Self::ENCODE_THRESHOLD {
                    ActPolicy::Encode
                } else if act_sparsity >= Self::GATE_THRESHOLD {
                    ActPolicy::Gate
                } else {
                    ActPolicy::Off
                }
            }
            p => p,
        }
    }

    /// The [`ZeroGate`] this (resolved) policy hands the gated kernel
    /// drivers when it does not encode: `Gate` arms them, `Off` (and
    /// `Encode`, which never reaches them) leaves them branch-free.
    pub(crate) fn gate(self) -> ZeroGate {
        ZeroGate::resolved(matches!(self, ActPolicy::Gate))
    }
}

/// Inner kernel shared by the serial and tiled dense GEMMs: accumulate the
/// output rows `row0..row0 + out.len()/n` into `out` (a row-contiguous
/// `&mut` window of C). Iteration order is identical for every caller, so
/// tiling cannot change a single bit of the result.
pub(crate) fn dense_rows_i8(
    ad: &[i8],
    wd: &[i8],
    out: &mut [i32],
    row0: usize,
    k: usize,
    n: usize,
) {
    if n == 0 {
        return;
    }
    for (i, crow) in out.chunks_mut(n).enumerate() {
        let row = row0 + i;
        let arow = &ad[row * k..row * k + k];
        for (kk, &a) in arow.iter().enumerate() {
            let av = a as i32;
            if av == 0 {
                continue;
            }
            let wrow = &wd[kk * n..kk * n + n];
            for (cv, &wv) in crow.iter_mut().zip(wrow) {
                *cv += av * wv as i32;
            }
        }
    }
}

/// Zero-gated variant of [`dense_rows_i8`]: a run-length zero-skip pass
/// over each A row — zero runs are consumed at one compare per element
/// *outside* the `N`-wide MAC loop (the occupancy scan, O(K), amortized
/// across all `N` columns) and only the non-zero runs stream through the
/// multiplies, branch-free within a run. An all-zero row skips its `K·N`
/// MACs outright; no scratch is allocated. Bit-exact with the ungated
/// kernel: the surviving terms are the exact terms it accumulates, in the
/// same order.
pub(crate) fn dense_rows_i8_gated(
    ad: &[i8],
    wd: &[i8],
    out: &mut [i32],
    row0: usize,
    k: usize,
    n: usize,
) {
    if n == 0 {
        return;
    }
    for (i, crow) in out.chunks_mut(n).enumerate() {
        let row = row0 + i;
        let arow = &ad[row * k..row * k + k];
        let mut kk = 0usize;
        while kk < k {
            if arow[kk] == 0 {
                kk += 1;
                continue;
            }
            let start = kk;
            while kk < k && arow[kk] != 0 {
                kk += 1;
            }
            for kidx in start..kk {
                let av = arow[kidx] as i32;
                let wrow = &wd[kidx * n..kidx * n + n];
                for (cv, &wv) in crow.iter_mut().zip(wrow) {
                    *cv += av * wv as i32;
                }
            }
        }
    }
}

/// Scalar epilogue requantize row kernel — the rounding **oracle** the SIMD
/// variants in [`micro`] are property-pinned against:
/// `out[i] = clamp(acc[i] >> shift, lo, 127)` with `lo = 0` when `relu`.
/// Folding ReLU into the clamp lower bound is bit-identical to the
/// historical clamp-then-zero (`max(0, clamp(x, -127, 127)) ==
/// clamp(x, 0, 127)` — both operands of the outer `max` are monotonic in
/// `x`), and the clamp is symmetric at ±127, never −128.
pub(crate) fn requant_rows_i8(acc: &[i32], out: &mut [i8], shift: u32, relu: bool) {
    let lo = if relu { 0 } else { -127 };
    for (o, &v) in out.iter_mut().zip(acc) {
        *o = (v >> shift).clamp(lo, 127) as i8;
    }
}

/// Per-channel variant of [`requant_rows_i8`] (Snippet 1's per-channel
/// scale): `shifts` holds one power-of-two shift per output column and
/// cycles per row (`acc` is whole rows of width `shifts.len()`).
pub(crate) fn requant_rows_i8_perch(acc: &[i32], out: &mut [i8], shifts: &[u32], relu: bool) {
    let n = shifts.len();
    if n == 0 {
        return;
    }
    let lo = if relu { 0 } else { -127 };
    for (orow, arow) in out.chunks_mut(n).zip(acc.chunks(n)) {
        for ((o, &v), &s) in orow.iter_mut().zip(arow).zip(shifts) {
            *o = (v >> s).clamp(lo, 127) as i8;
        }
    }
}

/// Dense GEMM: `C[M×N] = A[M×K] · W[K×N]`, INT8 operands, INT32 accumulate.
pub fn dense_i8(a: &TensorI8, w: &TensorI8) -> TensorI32 {
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (w.shape()[0], w.shape()[1]);
    assert_eq!(k, k2, "GEMM inner dims: A[{m}x{k}] W[{k2}x{n}]");
    let mut c = TensorI32::zeros(&[m, n]);
    micro::dense_rows_i8(a.data(), w.data(), c.data_mut(), 0, k, n);
    c
}

/// [`dense_i8`] under a [`ZeroGate`] policy: `Auto` measures `A`'s zero
/// fraction once (O(M·K), a ~`1/N` fraction of the MAC work) and gates when
/// it clears the threshold. Bit-exact with [`dense_i8`] under every policy.
pub fn dense_i8_gated(a: &TensorI8, w: &TensorI8, gate: ZeroGate) -> TensorI32 {
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (w.shape()[0], w.shape()[1]);
    assert_eq!(k, k2, "GEMM inner dims: A[{m}x{k}] W[{k2}x{n}]");
    let mut c = TensorI32::zeros(&[m, n]);
    if gate.resolve_with(|| a.sparsity()) {
        micro::dense_rows_i8_gated(a.data(), w.data(), c.data_mut(), 0, k, n);
    } else {
        micro::dense_rows_i8(a.data(), w.data(), c.data_mut(), 0, k, n);
    }
    c
}

/// DBB-sparse GEMM: `C = A · decompress(W)`, computed directly on the
/// compressed form — the functional model of the time-unrolled S8DP1
/// datapath: for each block, each stored non-zero selects (muxes) the
/// activation at its bitmask position.
///
/// Decodes the CSC stream per call; hot loops that reuse one weight matrix
/// should pack once ([`DbbPacked::pack`]) and call [`dbb_i8_packed`] — the
/// prepare-once/execute-many split of [`crate::engine`].
pub fn dbb_i8(a: &TensorI8, w: &DbbMatrix) -> TensorI32 {
    dbb_i8_packed(a, &DbbPacked::pack(w))
}

/// [`dbb_i8`] on a pre-decoded operand: zero per-call decode work. Bit-exact
/// with [`dbb_i8`] on the matrix the operand was packed from (both run the
/// identical `dbb_rows_i8` inner kernel on the identical stream).
pub fn dbb_i8_packed(a: &TensorI8, w: &DbbPacked) -> TensorI32 {
    let (m, k) = (a.shape()[0], a.shape()[1]);
    assert_eq!(k, w.k, "GEMM inner dims: A[{m}x{k}] Wdbb[{}x{}]", w.k, w.n);
    let mut c = TensorI32::zeros(&[m, w.n]);
    micro::dbb_rows_i8(a.data(), w.col_ptr(), w.entries(), c.data_mut(), 0, k, w.n);
    c
}

/// A DBB weight operand decoded once into the flattened per-column
/// `(col_ptr, entries)` CSC stream the row kernels consume — the software
/// form of the paper's §II-A offline-encoded weight stream. Packing is the
/// one-time "compile" step; every GEMM/conv that takes a `DbbPacked`
/// ([`dbb_i8_packed`], [`tiled::dbb_i8_packed`],
/// [`fused::conv2d_dbb_i8_packed`]) runs with zero per-call decode work and
/// is bit-exact with its per-call-decoding counterpart, because both feed
/// the identical stream to the shared `dbb_rows_i8` inner kernel.
#[derive(Debug, Clone)]
pub struct DbbPacked {
    /// Reduction dim of the dense matrix.
    pub k: usize,
    /// Output channels.
    pub n: usize,
    /// Block size the source matrix was encoded with.
    pub bz: usize,
    /// Density bound (max NNZ/block) of the source encoding.
    pub bound: usize,
    col_ptr: Vec<usize>,
    entries: Vec<(u32, i32)>,
}

impl DbbPacked {
    /// Decode a compressed matrix into the flattened CSC stream, once.
    pub fn pack(w: &DbbMatrix) -> DbbPacked {
        let (col_ptr, entries) = dbb_decode_csc(w);
        DbbPacked {
            k: w.k,
            n: w.n,
            bz: w.bz,
            bound: w.bound,
            col_ptr,
            entries,
        }
    }

    /// Rebuild a packed operand from its flattened parts — the
    /// deserialization entry of the prepared-model persistence format
    /// (`engine::PreparedModel::load`). The parts are *validated*, not
    /// trusted: `col_ptr` must be a monotone `n + 1`-length offset table
    /// covering `entries` exactly, and every entry's k-index must lie in
    /// `0..k` — so a corrupted file yields a clean `Err`, never a kernel
    /// out-of-bounds. A stream that came from [`Self::pack`] round-trips
    /// bit-identically (the kernels read only these fields).
    pub fn from_raw_parts(
        k: usize,
        n: usize,
        bz: usize,
        bound: usize,
        col_ptr: Vec<usize>,
        entries: Vec<(u32, i32)>,
    ) -> crate::util::error::Result<DbbPacked> {
        if !(1..=16).contains(&bz) || bound == 0 || bound > bz {
            crate::bail!("DbbPacked stream: invalid encoding bz={bz} bound={bound}");
        }
        if col_ptr.len() != n + 1 || col_ptr.first() != Some(&0) {
            crate::bail!(
                "DbbPacked stream: col_ptr must hold n+1={} offsets starting at 0, got {}",
                n + 1,
                col_ptr.len()
            );
        }
        if col_ptr.windows(2).any(|w| w[0] > w[1]) || col_ptr[n] != entries.len() {
            crate::bail!(
                "DbbPacked stream: col_ptr must rise monotonically to entries.len()={}",
                entries.len()
            );
        }
        if entries.iter().any(|&(kk, _)| kk as usize >= k) {
            crate::bail!("DbbPacked stream: entry k-index out of range (k={k})");
        }
        Ok(DbbPacked {
            k,
            n,
            bz,
            bound,
            col_ptr,
            entries,
        })
    }

    /// Per-column offsets into [`Self::entries`] (`n + 1` values).
    pub fn col_ptr(&self) -> &[usize] {
        &self.col_ptr
    }

    /// The `(k-index, value)` stream, column-major.
    pub fn entries(&self) -> &[(u32, i32)] {
        &self.entries
    }

    /// Stored non-zeros.
    pub fn total_nnz(&self) -> usize {
        self.entries.len()
    }

    /// Host bytes the packed stream occupies (the steady-state operand
    /// footprint an executor holds per layer).
    pub fn operand_bytes(&self) -> usize {
        self.col_ptr.len() * std::mem::size_of::<usize>()
            + self.entries.len() * std::mem::size_of::<(u32, i32)>()
    }
}

/// Decode a compressed operand once into a per-column (k-index, value)
/// stream — the CSC view. The per-row pass then walks each output row with
/// the A row hot in L1 and the weight stream sequential, which is ~5x
/// faster than scattering down the columns (§Perf, EXPERIMENTS). Shared by
/// the serial and tiled DBB GEMMs (the tiled workers all read one decode).
pub(crate) fn dbb_decode_csc(w: &DbbMatrix) -> (Vec<usize>, Vec<(u32, i32)>) {
    let kblocks = w.kblocks();
    let n = w.n;
    let mut col_ptr = Vec::with_capacity(n + 1);
    let mut entries: Vec<(u32, i32)> = Vec::with_capacity(w.total_nnz());
    col_ptr.push(0usize);
    for col in 0..n {
        for kb in 0..kblocks {
            let blk = w.block(col, kb);
            for (val, pos) in blk.vals.iter().zip(blk.positions()) {
                let kk = kb * w.bz + pos;
                debug_assert!(kk < w.k, "non-zero in padding region");
                entries.push((kk as u32, *val as i32));
            }
        }
        col_ptr.push(entries.len());
    }
    (col_ptr, entries)
}

/// Inner kernel shared by the serial and tiled DBB GEMMs: accumulate output
/// rows `row0..row0 + out.len()/n` from the decoded CSC stream. Per-element
/// accumulation order is column-stream order for every caller — bit-exact
/// under tiling.
pub(crate) fn dbb_rows_i8(
    ad: &[i8],
    col_ptr: &[usize],
    entries: &[(u32, i32)],
    out: &mut [i32],
    row0: usize,
    k: usize,
    n: usize,
) {
    if n == 0 {
        return;
    }
    for (i, crow) in out.chunks_mut(n).enumerate() {
        let row = row0 + i;
        let arow = &ad[row * k..(row + 1) * k];
        for (col, cv) in crow.iter_mut().enumerate() {
            let mut acc = 0i32;
            for &(kk, wv) in &entries[col_ptr[col]..col_ptr[col + 1]] {
                // the mux: activation A[i, kk] selected by the index
                acc += arow[kk as usize] as i32 * wv;
            }
            *cv = acc;
        }
    }
}

/// Zero-gated variant of [`dbb_rows_i8`]: a per-row occupancy scan (O(K),
/// amortized across all `N` columns) classifies each A row once —
///
/// * **all-zero** rows write zeros and skip every one of their
///   `N · entries-per-column` MACs;
/// * **fully dense** rows take the ungated branch-free walk (the gate has
///   nothing to skip, so it must not pay the per-entry test);
/// * **mixed** rows walk the weight stream with the gate armed: each stored
///   entry muxes its activation, and a zero activation skips the multiply.
///
/// Bit-exact with [`dbb_rows_i8`]: a skipped term contributes exactly 0 to
/// the INT32 accumulator and the surviving terms keep their stream order.
pub(crate) fn dbb_rows_i8_gated(
    ad: &[i8],
    col_ptr: &[usize],
    entries: &[(u32, i32)],
    out: &mut [i32],
    row0: usize,
    k: usize,
    n: usize,
) {
    if n == 0 {
        return;
    }
    for (i, crow) in out.chunks_mut(n).enumerate() {
        let row = row0 + i;
        let arow = &ad[row * k..(row + 1) * k];
        let nnz = k - arow.iter().filter(|&&a| a == 0).count();
        if nnz == 0 {
            crow.fill(0);
            continue;
        }
        if nnz == k {
            for (col, cv) in crow.iter_mut().enumerate() {
                let mut acc = 0i32;
                for &(kk, wv) in &entries[col_ptr[col]..col_ptr[col + 1]] {
                    acc += arow[kk as usize] as i32 * wv;
                }
                *cv = acc;
            }
            continue;
        }
        for (col, cv) in crow.iter_mut().enumerate() {
            let mut acc = 0i32;
            for &(kk, wv) in &entries[col_ptr[col]..col_ptr[col + 1]] {
                let av = arow[kk as usize] as i32;
                // the gate: a zero activation suppresses the MAC
                if av != 0 {
                    acc += av * wv;
                }
            }
            *cv = acc;
        }
    }
}

/// [`dbb_i8_packed`] under a [`ZeroGate`] policy: `Auto` measures `A`'s
/// zero fraction once and gates when it clears the threshold. Bit-exact
/// with [`dbb_i8_packed`] under every policy.
pub fn dbb_i8_packed_gated(a: &TensorI8, w: &DbbPacked, gate: ZeroGate) -> TensorI32 {
    let (m, k) = (a.shape()[0], a.shape()[1]);
    assert_eq!(k, w.k, "GEMM inner dims: A[{m}x{k}] Wdbb[{}x{}]", w.k, w.n);
    let mut c = TensorI32::zeros(&[m, w.n]);
    if gate.resolve_with(|| a.sparsity()) {
        micro::dbb_rows_i8_gated(a.data(), w.col_ptr(), w.entries(), c.data_mut(), 0, k, w.n);
    } else {
        micro::dbb_rows_i8(a.data(), w.col_ptr(), w.entries(), c.data_mut(), 0, k, w.n);
    }
    c
}

/// MACs the activation gate skips for a DBB GEMM `A · decompress(W)`:
/// every `(row, stored-entry)` pair whose muxed activation `A[row, kk]` is
/// exactly zero. Returns `(skipped, executed_total)` where `executed_total
/// = M · total_nnz` is what the ungated DBB walk multiplies — the
/// skipped-MAC fraction the gated benches report alongside their timings.
pub fn dbb_gate_stats(a: &TensorI8, w: &DbbPacked) -> (u64, u64) {
    let (m, k) = (a.shape()[0], a.shape()[1]);
    assert_eq!(k, w.k, "gate stats inner dims: A[{m}x{k}] Wdbb[{}x{}]", w.k, w.n);
    let ad = a.data();
    // zero-row counts per k index: zc[kk] = rows whose A[row, kk] == 0
    let mut zc = vec![0u64; k];
    for row in 0..m {
        for (kk, &v) in ad[row * k..(row + 1) * k].iter().enumerate() {
            if v == 0 {
                zc[kk] += 1;
            }
        }
    }
    let skipped = w.entries().iter().map(|&(kk, _)| zc[kk as usize]).sum();
    (skipped, m as u64 * w.entries().len() as u64)
}

/// Count of effective MAC operations for a DBB GEMM (per paper Table V
/// footnote: "effective operations" = 2 × dense MAC count, independent of
/// how many the hardware actually executed).
pub fn effective_ops(m: usize, k: usize, n: usize) -> u64 {
    2 * m as u64 * k as u64 * n as u64
}

/// MACs the DBB datapath actually executes: `M × kblocks × bound × N`.
pub fn dbb_executed_macs(m: usize, w: &DbbMatrix) -> u64 {
    m as u64 * w.kblocks() as u64 * w.bound as u64 * w.n as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dbb::prune::prune_i8;
    use crate::util::prop::{check, Config};
    use crate::util::Rng;

    #[test]
    fn dense_matches_naive_small() {
        let a = TensorI8::from_vec(&[2, 3], vec![1, 2, 3, 4, 5, 6]);
        let w = TensorI8::from_vec(&[3, 2], vec![7, 8, 9, 10, 11, 12]);
        let c = dense_i8(&a, &w);
        // [[1*7+2*9+3*11, 1*8+2*10+3*12], [4*7+5*9+6*11, 4*8+5*10+6*12]]
        assert_eq!(c.data(), &[58, 64, 139, 154]);
    }

    #[test]
    fn dbb_equals_dense_on_decompressed() {
        check(Config::default().cases(96), |rng| {
            let m = rng.below(12) + 1;
            let k = rng.below(48) + 1;
            let n = rng.below(16) + 1;
            let bz = [4usize, 8, 16][rng.below(3)];
            let nnz = rng.below(bz) + 1;
            let a = TensorI8::rand(&[m, k], rng);
            let wd = prune_i8(&TensorI8::rand(&[k, n], rng), bz, nnz);
            let w = DbbMatrix::compress(&wd, bz).unwrap();
            assert_eq!(
                dbb_i8(&a, &w).data(),
                dense_i8(&a, &wd).data(),
                "m={m} k={k} n={n} bz={bz} nnz={nnz}"
            );
        });
    }

    #[test]
    fn dbb_fully_dense_weights_still_correct() {
        let mut rng = Rng::new(7);
        let a = TensorI8::rand(&[4, 16], &mut rng);
        let wd = TensorI8::rand(&[16, 8], &mut rng);
        let w = DbbMatrix::compress(&wd, 8).unwrap();
        assert_eq!(dbb_i8(&a, &w).data(), dense_i8(&a, &wd).data());
    }

    #[test]
    fn executed_macs_scale_with_bound() {
        let mut rng = Rng::new(8);
        let wd = prune_i8(&TensorI8::rand(&[64, 32], &mut rng), 8, 2);
        let w = DbbMatrix::compress_with_bound(&wd, 8, 2).unwrap();
        // 2/8 bound: executed = M * (64/8) * 2 * 32 = dense/4
        assert_eq!(dbb_executed_macs(16, &w), 16 * 8 * 2 * 32);
        assert_eq!(effective_ops(16, 64, 32), 2 * 16 * 64 * 32);
    }

    #[test]
    fn packed_equals_per_call_decode_prop() {
        check(Config::default().cases(64), |rng| {
            let m = rng.below(12) + 1;
            let k = rng.below(48) + 1;
            let n = rng.below(16) + 1;
            let bz = [4usize, 8, 16][rng.below(3)];
            let nnz = rng.below(bz) + 1;
            let a = TensorI8::rand(&[m, k], rng);
            let w = DbbMatrix::compress_topk(&TensorI8::rand(&[k, n], rng), bz, nnz).unwrap();
            let packed = DbbPacked::pack(&w);
            assert_eq!(packed.total_nnz(), w.total_nnz());
            assert_eq!(
                dbb_i8_packed(&a, &packed).data(),
                dbb_i8(&a, &w).data(),
                "m={m} k={k} n={n} bz={bz} nnz={nnz}"
            );
        });
    }

    #[test]
    fn zero_activation_rows_give_zero_output() {
        let a = TensorI8::zeros(&[3, 8]);
        let mut rng = Rng::new(9);
        let wd = TensorI8::rand(&[8, 4], &mut rng);
        let w = DbbMatrix::compress(&wd, 8).unwrap();
        assert!(dbb_i8(&a, &w).data().iter().all(|&x| x == 0));
    }

    #[test]
    fn gated_serial_kernels_bit_exact_prop() {
        check(Config::default().cases(64), |rng| {
            let m = rng.below(12) + 1;
            let k = rng.below(48) + 1;
            let n = rng.below(16) + 1;
            let p_zero = [0.0f32, 0.5, 1.0][rng.below(3)];
            let a = TensorI8::rand_sparse(&[m, k], p_zero, rng);
            let wd = TensorI8::rand(&[k, n], rng);
            let gate = [ZeroGate::Off, ZeroGate::Auto, ZeroGate::On][rng.below(3)];
            assert_eq!(
                dense_i8_gated(&a, &wd, gate).data(),
                dense_i8(&a, &wd).data(),
                "dense m={m} k={k} n={n} p={p_zero} gate={gate:?}"
            );
            let w = DbbMatrix::compress_topk(&wd, 8, rng.below(8) + 1).unwrap();
            let packed = DbbPacked::pack(&w);
            assert_eq!(
                dbb_i8_packed_gated(&a, &packed, gate).data(),
                dbb_i8_packed(&a, &packed).data(),
                "dbb m={m} k={k} n={n} p={p_zero} gate={gate:?}"
            );
        });
    }

    #[test]
    fn auto_threshold_engages_on_measured_sparsity() {
        assert!(!ZeroGate::Off.engaged(1.0));
        assert!(ZeroGate::On.engaged(0.0));
        assert!(!ZeroGate::Auto.engaged(ZeroGate::AUTO_THRESHOLD - 0.01));
        assert!(ZeroGate::Auto.engaged(ZeroGate::AUTO_THRESHOLD));
        assert!(ZeroGate::Auto.engaged(0.8));
        assert_eq!(ZeroGate::resolved(true), ZeroGate::On);
        assert_eq!(ZeroGate::resolved(false), ZeroGate::Off);
    }

    #[test]
    fn act_policy_auto_resolves_three_tiers() {
        assert_eq!(ActPolicy::Auto.resolved(0.0), ActPolicy::Off);
        assert_eq!(
            ActPolicy::Auto.resolved(ActPolicy::GATE_THRESHOLD - 0.01),
            ActPolicy::Off
        );
        assert_eq!(ActPolicy::Auto.resolved(ActPolicy::GATE_THRESHOLD), ActPolicy::Gate);
        assert_eq!(
            ActPolicy::Auto.resolved(ActPolicy::ENCODE_THRESHOLD - 0.01),
            ActPolicy::Gate
        );
        assert_eq!(ActPolicy::Auto.resolved(ActPolicy::ENCODE_THRESHOLD), ActPolicy::Encode);
        assert_eq!(ActPolicy::Auto.resolved(1.0), ActPolicy::Encode);
        // fixed policies ignore the measurement
        for s in [0.0, 0.5, 1.0] {
            assert_eq!(ActPolicy::Off.resolved(s), ActPolicy::Off);
            assert_eq!(ActPolicy::Gate.resolved(s), ActPolicy::Gate);
            assert_eq!(ActPolicy::Encode.resolved(s), ActPolicy::Encode);
        }
        assert_eq!(ActPolicy::Gate.gate(), ZeroGate::On);
        assert_eq!(ActPolicy::Off.gate(), ZeroGate::Off);
    }

    #[test]
    fn dbb_gate_stats_counts_skippable_macs() {
        // A: row 0 all-zero, row 1 dense → exactly half the entry-row
        // pairs are skippable
        let a = TensorI8::from_vec(&[2, 8], vec![0, 0, 0, 0, 0, 0, 0, 0, 1, 2, 3, 4, 5, 6, 7, 8]);
        let mut rng = Rng::new(11);
        let w = DbbMatrix::compress_topk(&TensorI8::rand(&[8, 4], &mut rng), 8, 3).unwrap();
        let packed = DbbPacked::pack(&w);
        let (skipped, total) = dbb_gate_stats(&a, &packed);
        assert_eq!(total, 2 * packed.total_nnz() as u64);
        assert_eq!(skipped, packed.total_nnz() as u64);
    }
}
