//! Parallel row-tiled GEMM engine — the multi-PE analogue in software.
//!
//! The paper's array reaches throughput by spreading the `M` (output-row)
//! dimension across physical PE rows; this module does the same across host
//! cores: the output matrix is split into row-contiguous tiles, one scoped
//! worker (`std::thread::scope`, no external crates) accumulates each tile
//! in INT32 using the *same* inner kernels as the serial oracles
//! ([`crate::gemm::dense_i8`] / [`crate::gemm::dbb_i8`]), so results are
//! bit-exact for every thread count — property-tested in this module and in
//! `rust/tests/tiled_gemm.rs`.
//!
//! The thread-count knob is [`Parallelism`] (re-exported from
//! [`crate::util::par`]): `auto()` = `available_parallelism()` (the
//! default), `serial()` = the exact single-threaded fallback with no thread
//! spawned, `with_pin(true)` = opt-in worker→core affinity pinning (worker
//! `i` → core `i % cores`, best-effort, scheduling-only).
//!
//! Each worker's inner loop dispatches through the
//! [`crate::gemm::micro`] SIMD microkernels — same kernels as the serial
//! drivers, so tiled results stay bit-exact with the oracles on every ISA
//! path.

pub use crate::util::par::Parallelism;

use crate::dbb::DbbMatrix;
use crate::gemm::{ActDbb, DbbPacked, ZeroGate};
use crate::tensor::{TensorI32, TensorI8};

/// Shared row-tiling scaffold of every GEMM driver in this module:
/// partition the `m × n` output into row-contiguous per-worker tiles (the
/// one tile split, so every driver is bit-exact under the same partition)
/// and run `kernel(tile, row0)` on each from the scoped pool. Callers have
/// already taken the serial fallback, so `par.get() > 1`, `m > 1`, `n > 0`.
fn row_tiled<K: Fn(&mut [i32], usize) + Sync>(
    m: usize,
    n: usize,
    par: Parallelism,
    kernel: K,
) -> TensorI32 {
    let mut c = TensorI32::zeros(&[m, n]);
    let rows_per_tile = m.div_ceil(par.get().min(m));
    let kref = &kernel;
    std::thread::scope(|s| {
        for (ti, tile) in c.data_mut().chunks_mut(rows_per_tile * n).enumerate() {
            let row0 = ti * rows_per_tile;
            s.spawn(move || {
                par.pin_worker(ti);
                kref(tile, row0)
            });
        }
    });
    c
}

/// Parallel dense GEMM: `C[M×N] = A[M×K] · W[K×N]`, INT8 operands, INT32
/// accumulate. Bit-exact with [`crate::gemm::dense_i8`].
pub fn dense_i8(a: &TensorI8, w: &TensorI8, par: Parallelism) -> TensorI32 {
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (w.shape()[0], w.shape()[1]);
    assert_eq!(k, k2, "GEMM inner dims: A[{m}x{k}] W[{k2}x{n}]");
    if par.get() <= 1 || m <= 1 || n == 0 {
        return crate::gemm::dense_i8(a, w);
    }
    let (ad, wd) = (a.data(), w.data());
    row_tiled(m, n, par, |tile, row0| {
        crate::gemm::micro::dense_rows_i8(ad, wd, tile, row0, k, n)
    })
}

/// [`dense_i8`] under a [`ZeroGate`] policy: each worker runs the
/// zero-gated row kernel when the gate engages (`Auto` measures `A`'s zero
/// fraction once, before the pool spawns). Bit-exact with [`dense_i8`] for
/// every policy and thread count.
pub fn dense_i8_gated(a: &TensorI8, w: &TensorI8, par: Parallelism, gate: ZeroGate) -> TensorI32 {
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (w.shape()[0], w.shape()[1]);
    assert_eq!(k, k2, "GEMM inner dims: A[{m}x{k}] W[{k2}x{n}]");
    let engaged = gate.resolve_with(|| a.sparsity());
    if par.get() <= 1 || m <= 1 || n == 0 {
        return crate::gemm::dense_i8_gated(a, w, ZeroGate::resolved(engaged));
    }
    let (ad, wd) = (a.data(), w.data());
    if engaged {
        row_tiled(m, n, par, |tile, row0| {
            crate::gemm::micro::dense_rows_i8_gated(ad, wd, tile, row0, k, n)
        })
    } else {
        row_tiled(m, n, par, |tile, row0| {
            crate::gemm::micro::dense_rows_i8(ad, wd, tile, row0, k, n)
        })
    }
}

/// Parallel DBB-sparse GEMM: `C = A · decompress(W)` on the compressed
/// form. The CSC decode happens once per call; all workers read it.
/// Bit-exact with [`crate::gemm::dbb_i8`]. Hot loops that reuse one weight
/// matrix should pack it once ([`DbbPacked::pack`]) and call
/// [`dbb_i8_packed`] instead.
pub fn dbb_i8(a: &TensorI8, w: &DbbMatrix, par: Parallelism) -> TensorI32 {
    dbb_i8_packed(a, &DbbPacked::pack(w), par)
}

/// [`dbb_i8`] on a pre-decoded operand: zero per-call decode work, same
/// row-tiling, same `dbb_rows_i8` inner kernel — bit-exact with the
/// per-call-decoding path for every thread count.
pub fn dbb_i8_packed(a: &TensorI8, w: &DbbPacked, par: Parallelism) -> TensorI32 {
    let (m, k) = (a.shape()[0], a.shape()[1]);
    assert_eq!(k, w.k, "GEMM inner dims: A[{m}x{k}] Wdbb[{}x{}]", w.k, w.n);
    if par.get() <= 1 || m <= 1 || w.n == 0 {
        return crate::gemm::dbb_i8_packed(a, w);
    }
    let ad = a.data();
    let (cp, en) = (w.col_ptr(), w.entries());
    row_tiled(m, w.n, par, |tile, row0| {
        crate::gemm::micro::dbb_rows_i8(ad, cp, en, tile, row0, k, w.n)
    })
}

/// [`dbb_i8_packed`] under a [`ZeroGate`] policy: each worker runs the
/// zero-gated CSC row kernel when the gate engages (`Auto` measures `A`'s
/// zero fraction once, before the pool spawns). Bit-exact with
/// [`dbb_i8_packed`] for every policy and thread count.
pub fn dbb_i8_packed_gated(
    a: &TensorI8,
    w: &DbbPacked,
    par: Parallelism,
    gate: ZeroGate,
) -> TensorI32 {
    let (m, k) = (a.shape()[0], a.shape()[1]);
    assert_eq!(k, w.k, "GEMM inner dims: A[{m}x{k}] Wdbb[{}x{}]", w.k, w.n);
    let engaged = gate.resolve_with(|| a.sparsity());
    if par.get() <= 1 || m <= 1 || w.n == 0 {
        return crate::gemm::dbb_i8_packed_gated(a, w, ZeroGate::resolved(engaged));
    }
    let ad = a.data();
    let (cp, en) = (w.col_ptr(), w.entries());
    if engaged {
        row_tiled(m, w.n, par, |tile, row0| {
            crate::gemm::micro::dbb_rows_i8_gated(ad, cp, en, tile, row0, k, w.n)
        })
    } else {
        row_tiled(m, w.n, par, |tile, row0| {
            crate::gemm::micro::dbb_rows_i8(ad, cp, en, tile, row0, k, w.n)
        })
    }
}

/// Parallel joint-sparse GEMM: pre-encoded A ([`ActDbb`]) × pre-packed W,
/// row-tiled across the pool. Zero per-call encode/decode work on either
/// operand; bit-exact with [`crate::gemm::adbb_i8_packed`] (and so with the
/// dense-A oracles) for every thread count.
pub fn adbb_i8_packed(a: &ActDbb, w: &DbbPacked, par: Parallelism) -> TensorI32 {
    assert_eq!(a.k, w.k, "GEMM inner dims: Adbb[{}x{}] Wdbb[{}x{}]", a.m, a.k, w.k, w.n);
    if par.get() <= 1 || a.m <= 1 || w.n == 0 {
        return crate::gemm::adbb_i8_packed(a, w);
    }
    let (arp, aen) = (a.row_ptr(), a.entries());
    let (cp, en) = (w.col_ptr(), w.entries());
    row_tiled(a.m, w.n, par, |tile, row0| {
        crate::gemm::act::adbb_rows_i8(arp, aen, cp, en, tile, row0, w.n)
    })
}

/// Parallel joint GEMM for dense-fallback weights: pre-encoded A × dense
/// `[K, N]` W. Bit-exact with [`crate::gemm::adbb_dense_i8`] (and so with
/// [`dense_i8`]) for every thread count.
pub fn adbb_dense_i8(a: &ActDbb, w: &TensorI8, par: Parallelism) -> TensorI32 {
    let (k2, n) = (w.shape()[0], w.shape()[1]);
    assert_eq!(a.k, k2, "GEMM inner dims: Adbb[{}x{}] W[{k2}x{n}]", a.m, a.k);
    if par.get() <= 1 || a.m <= 1 || n == 0 {
        return crate::gemm::adbb_dense_i8(a, w);
    }
    let (arp, aen) = (a.row_ptr(), a.entries());
    let wd = w.data();
    row_tiled(a.m, n, par, |tile, row0| {
        crate::gemm::micro::adbb_dense_rows_i8(arp, aen, wd, tile, row0, n)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dbb::prune::prune_i8;
    use crate::gemm;
    use crate::util::prop::{check, Config};
    use crate::util::Rng;

    #[test]
    fn dense_tiled_equals_serial_prop() {
        // random M/K/N and thread counts 1–8, including M < threads
        check(Config::default().cases(96), |rng| {
            let m = rng.below(40) + 1;
            let k = rng.below(64) + 1;
            let n = rng.below(24) + 1;
            let threads = rng.below(8) + 1;
            let a = TensorI8::rand_sparse(&[m, k], 0.3, rng);
            let w = TensorI8::rand(&[k, n], rng);
            let serial = gemm::dense_i8(&a, &w);
            let tiled = dense_i8(&a, &w, Parallelism::threads(threads));
            assert_eq!(
                serial.data(),
                tiled.data(),
                "m={m} k={k} n={n} threads={threads}"
            );
        });
    }

    #[test]
    fn dbb_tiled_equals_serial_prop() {
        // random M/K/N/bz/nnz and thread counts 1–8
        check(Config::default().cases(96), |rng| {
            let m = rng.below(32) + 1;
            let k = rng.below(64) + 1;
            let n = rng.below(20) + 1;
            let bz = [4usize, 8, 16][rng.below(3)];
            let nnz = rng.below(bz) + 1;
            let threads = rng.below(8) + 1;
            let a = TensorI8::rand_sparse(&[m, k], 0.4, rng);
            let wd = prune_i8(&TensorI8::rand(&[k, n], rng), bz, nnz);
            let w = DbbMatrix::compress(&wd, bz).unwrap();
            let serial = gemm::dbb_i8(&a, &w);
            let tiled = dbb_i8(&a, &w, Parallelism::threads(threads));
            assert_eq!(
                serial.data(),
                tiled.data(),
                "m={m} k={k} n={n} bz={bz} nnz={nnz} threads={threads}"
            );
        });
    }

    #[test]
    fn single_row_more_threads_than_rows() {
        // M < threads: the tile split must degenerate gracefully
        let mut rng = Rng::new(3);
        let a = TensorI8::rand(&[1, 33], &mut rng);
        let w = TensorI8::rand(&[33, 7], &mut rng);
        assert_eq!(
            dense_i8(&a, &w, Parallelism::threads(8)).data(),
            gemm::dense_i8(&a, &w).data()
        );
        let a3 = TensorI8::rand(&[3, 16], &mut rng);
        let wd = prune_i8(&TensorI8::rand(&[16, 5], &mut rng), 8, 3);
        let wc = DbbMatrix::compress(&wd, 8).unwrap();
        assert_eq!(
            dbb_i8(&a3, &wc, Parallelism::threads(16)).data(),
            gemm::dbb_i8(&a3, &wc).data()
        );
    }

    #[test]
    fn serial_fallback_is_exact_path() {
        let mut rng = Rng::new(4);
        let a = TensorI8::rand(&[9, 24], &mut rng);
        let w = TensorI8::rand(&[24, 6], &mut rng);
        assert_eq!(
            dense_i8(&a, &w, Parallelism::serial()).data(),
            gemm::dense_i8(&a, &w).data()
        );
    }

    #[test]
    fn dbb_packed_equals_per_call_decode_prop() {
        check(Config::default().cases(64), |rng| {
            let m = rng.below(32) + 1;
            let k = rng.below(64) + 1;
            let n = rng.below(20) + 1;
            let bz = [4usize, 8, 16][rng.below(3)];
            let nnz = rng.below(bz) + 1;
            let threads = rng.below(8) + 1;
            let a = TensorI8::rand_sparse(&[m, k], 0.4, rng);
            let wd = prune_i8(&TensorI8::rand(&[k, n], rng), bz, nnz);
            let w = DbbMatrix::compress(&wd, bz).unwrap();
            let packed = DbbPacked::pack(&w);
            assert_eq!(
                dbb_i8_packed(&a, &packed, Parallelism::threads(threads)).data(),
                gemm::dbb_i8(&a, &w).data(),
                "m={m} k={k} n={n} bz={bz} nnz={nnz} threads={threads}"
            );
        });
    }

    #[test]
    fn gated_tiled_bit_exact_prop() {
        // every policy × random sparsity × thread counts incl. M < threads
        check(Config::default().cases(64), |rng| {
            let m = rng.below(24) + 1;
            let k = rng.below(48) + 1;
            let n = rng.below(16) + 1;
            let threads = rng.below(8) + 1;
            let p_zero = [0.0f32, 0.5, 1.0][rng.below(3)];
            let gate = [ZeroGate::Off, ZeroGate::Auto, ZeroGate::On][rng.below(3)];
            let a = TensorI8::rand_sparse(&[m, k], p_zero, rng);
            let w = TensorI8::rand(&[k, n], rng);
            assert_eq!(
                dense_i8_gated(&a, &w, Parallelism::threads(threads), gate).data(),
                gemm::dense_i8(&a, &w).data(),
                "dense m={m} k={k} n={n} threads={threads} p={p_zero} gate={gate:?}"
            );
            let enc = DbbMatrix::compress_topk(&w, 8, rng.below(8) + 1).unwrap();
            let packed = DbbPacked::pack(&enc);
            assert_eq!(
                dbb_i8_packed_gated(&a, &packed, Parallelism::threads(threads), gate).data(),
                gemm::dbb_i8(&a, &enc).data(),
                "dbb m={m} k={k} n={n} threads={threads} p={p_zero} gate={gate:?}"
            );
        });
    }

    #[test]
    fn adbb_tiled_bit_exact_prop() {
        // encoded-A joint kernels vs the dense-A oracles, every thread count
        check(Config::default().cases(64), |rng| {
            let m = rng.below(24) + 1;
            let k = rng.below(48) + 1;
            let n = rng.below(16) + 1;
            let bz = [4usize, 8, 16][rng.below(3)];
            let nnz = rng.below(bz) + 1;
            let threads = rng.below(8) + 1;
            let p_zero = [0.0f32, 0.5, 1.0][rng.below(3)];
            let a = TensorI8::rand_sparse(&[m, k], p_zero, rng);
            let w = TensorI8::rand(&[k, n], rng);
            let enc = ActDbb::encode(&a, bz);
            let par = Parallelism::threads(threads);
            assert_eq!(
                adbb_dense_i8(&enc, &w, par).data(),
                gemm::dense_i8(&a, &w).data(),
                "dense m={m} k={k} n={n} bz={bz} threads={threads} p={p_zero}"
            );
            let wc = DbbMatrix::compress_topk(&w, bz, nnz).unwrap();
            let packed = DbbPacked::pack(&wc);
            assert_eq!(
                adbb_i8_packed(&enc, &packed, par).data(),
                gemm::dbb_i8_packed(&a, &packed).data(),
                "dbb m={m} k={k} n={n} bz={bz} nnz={nnz} threads={threads} p={p_zero}"
            );
        });
    }

    #[test]
    fn dbb_tiled_matches_dense_on_decompressed() {
        let mut rng = Rng::new(5);
        let a = TensorI8::rand_sparse(&[40, 48], 0.5, &mut rng);
        let wd = prune_i8(&TensorI8::rand(&[48, 24], &mut rng), 8, 3);
        let w = DbbMatrix::compress(&wd, 8).unwrap();
        assert_eq!(
            dbb_i8(&a, &w, Parallelism::threads(4)).data(),
            gemm::dense_i8(&a, &wd).data()
        );
    }
}
