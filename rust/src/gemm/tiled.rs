//! Parallel row-tiled GEMM engine — the multi-PE analogue in software.
//!
//! The paper's array reaches throughput by spreading the `M` (output-row)
//! dimension across physical PE rows; this module does the same across host
//! cores: the output matrix is split into row-contiguous tiles, one scoped
//! worker (`std::thread::scope`, no external crates) accumulates each tile
//! in INT32 using the *same* inner kernels as the serial oracles
//! ([`crate::gemm::dense_i8`] / [`crate::gemm::dbb_i8`]), so results are
//! bit-exact for every thread count — property-tested in this module and in
//! `rust/tests/tiled_gemm.rs`.
//!
//! The thread-count knob is [`Parallelism`] (re-exported from
//! [`crate::util::par`]): `auto()` = `available_parallelism()` (the
//! default), `serial()` = the exact single-threaded fallback with no thread
//! spawned, `with_pin(true)` = opt-in worker→core affinity pinning (worker
//! `i` → core `i % cores`, best-effort, scheduling-only).
//!
//! Each worker's inner loop dispatches through the
//! [`crate::gemm::micro`] SIMD microkernels — same kernels as the serial
//! drivers, so tiled results stay bit-exact with the oracles on every ISA
//! path.

pub use crate::util::par::Parallelism;

use crate::dbb::DbbMatrix;
use crate::gemm::{ActDbb, BsrPacked, DbbPacked, Epilogue, ZeroGate};
use crate::tensor::{TensorI32, TensorI8};

/// Accumulator rows a fused-epilogue worker computes per inner-kernel call
/// before draining them through the epilogue — small enough that the i32
/// chunk stays L1-resident while it is requantized (mirrors
/// `fused::PATCH_ROWS`).
const EP_CHUNK: usize = 8;

/// Shared row-tiling scaffold of every GEMM driver in this module:
/// partition the `m × n` output into row-contiguous per-worker tiles (the
/// one tile split, so every driver is bit-exact under the same partition)
/// and run `kernel(tile, row0)` on each from the scoped pool. Callers have
/// already taken the serial fallback, so `par.get() > 1`, `m > 1`, `n > 0`.
fn row_tiled<K: Fn(&mut [i32], usize) + Sync>(
    m: usize,
    n: usize,
    par: Parallelism,
    kernel: K,
) -> TensorI32 {
    let mut c = TensorI32::zeros(&[m, n]);
    let rows_per_tile = m.div_ceil(par.get().min(m));
    let kref = &kernel;
    std::thread::scope(|s| {
        for (ti, tile) in c.data_mut().chunks_mut(rows_per_tile * n).enumerate() {
            let row0 = ti * rows_per_tile;
            s.spawn(move || {
                par.pin_worker(ti);
                kref(tile, row0)
            });
        }
    });
    c
}

/// Parallel dense GEMM: `C[M×N] = A[M×K] · W[K×N]`, INT8 operands, INT32
/// accumulate. Bit-exact with [`crate::gemm::dense_i8`].
pub fn dense_i8(a: &TensorI8, w: &TensorI8, par: Parallelism) -> TensorI32 {
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (w.shape()[0], w.shape()[1]);
    assert_eq!(k, k2, "GEMM inner dims: A[{m}x{k}] W[{k2}x{n}]");
    if par.get() <= 1 || m <= 1 || n == 0 {
        return crate::gemm::dense_i8(a, w);
    }
    let (ad, wd) = (a.data(), w.data());
    row_tiled(m, n, par, |tile, row0| {
        crate::gemm::micro::dense_rows_i8(ad, wd, tile, row0, k, n)
    })
}

/// [`dense_i8`] under a [`ZeroGate`] policy: each worker runs the
/// zero-gated row kernel when the gate engages (`Auto` measures `A`'s zero
/// fraction once, before the pool spawns). Bit-exact with [`dense_i8`] for
/// every policy and thread count.
pub fn dense_i8_gated(a: &TensorI8, w: &TensorI8, par: Parallelism, gate: ZeroGate) -> TensorI32 {
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (w.shape()[0], w.shape()[1]);
    assert_eq!(k, k2, "GEMM inner dims: A[{m}x{k}] W[{k2}x{n}]");
    let engaged = gate.resolve_with(|| a.sparsity());
    if par.get() <= 1 || m <= 1 || n == 0 {
        return crate::gemm::dense_i8_gated(a, w, ZeroGate::resolved(engaged));
    }
    let (ad, wd) = (a.data(), w.data());
    if engaged {
        row_tiled(m, n, par, |tile, row0| {
            crate::gemm::micro::dense_rows_i8_gated(ad, wd, tile, row0, k, n)
        })
    } else {
        row_tiled(m, n, par, |tile, row0| {
            crate::gemm::micro::dense_rows_i8(ad, wd, tile, row0, k, n)
        })
    }
}

/// Parallel DBB-sparse GEMM: `C = A · decompress(W)` on the compressed
/// form. The CSC decode happens once per call; all workers read it.
/// Bit-exact with [`crate::gemm::dbb_i8`]. Hot loops that reuse one weight
/// matrix should pack it once ([`DbbPacked::pack`]) and call
/// [`dbb_i8_packed`] instead.
pub fn dbb_i8(a: &TensorI8, w: &DbbMatrix, par: Parallelism) -> TensorI32 {
    dbb_i8_packed(a, &DbbPacked::pack(w), par)
}

/// [`dbb_i8`] on a pre-decoded operand: zero per-call decode work, same
/// row-tiling, same `dbb_rows_i8` inner kernel — bit-exact with the
/// per-call-decoding path for every thread count.
pub fn dbb_i8_packed(a: &TensorI8, w: &DbbPacked, par: Parallelism) -> TensorI32 {
    let (m, k) = (a.shape()[0], a.shape()[1]);
    assert_eq!(k, w.k, "GEMM inner dims: A[{m}x{k}] Wdbb[{}x{}]", w.k, w.n);
    if par.get() <= 1 || m <= 1 || w.n == 0 {
        return crate::gemm::dbb_i8_packed(a, w);
    }
    let ad = a.data();
    let (cp, en) = (w.col_ptr(), w.entries());
    row_tiled(m, w.n, par, |tile, row0| {
        crate::gemm::micro::dbb_rows_i8(ad, cp, en, tile, row0, k, w.n)
    })
}

/// [`dbb_i8_packed`] under a [`ZeroGate`] policy: each worker runs the
/// zero-gated CSC row kernel when the gate engages (`Auto` measures `A`'s
/// zero fraction once, before the pool spawns). Bit-exact with
/// [`dbb_i8_packed`] for every policy and thread count.
pub fn dbb_i8_packed_gated(
    a: &TensorI8,
    w: &DbbPacked,
    par: Parallelism,
    gate: ZeroGate,
) -> TensorI32 {
    let (m, k) = (a.shape()[0], a.shape()[1]);
    assert_eq!(k, w.k, "GEMM inner dims: A[{m}x{k}] Wdbb[{}x{}]", w.k, w.n);
    let engaged = gate.resolve_with(|| a.sparsity());
    if par.get() <= 1 || m <= 1 || w.n == 0 {
        return crate::gemm::dbb_i8_packed_gated(a, w, ZeroGate::resolved(engaged));
    }
    let ad = a.data();
    let (cp, en) = (w.col_ptr(), w.entries());
    if engaged {
        row_tiled(m, w.n, par, |tile, row0| {
            crate::gemm::micro::dbb_rows_i8_gated(ad, cp, en, tile, row0, k, w.n)
        })
    } else {
        row_tiled(m, w.n, par, |tile, row0| {
            crate::gemm::micro::dbb_rows_i8(ad, cp, en, tile, row0, k, w.n)
        })
    }
}

/// Parallel BSR GEMM on a pre-packed operand: the block-scheduler kernel
/// ([`crate::gemm::bsr`]) walks each worker's row tile, skipping absent
/// blocks. Zero per-call decode work; bit-exact with
/// [`crate::gemm::bsr_i8_packed`] — and with the dense oracle on the
/// decompressed weights — for every thread count.
pub fn bsr_i8_packed(a: &TensorI8, w: &BsrPacked, par: Parallelism) -> TensorI32 {
    let (m, k) = (a.shape()[0], a.shape()[1]);
    assert_eq!(k, w.k, "GEMM inner dims: A[{m}x{k}] Wbsr[{}x{}]", w.k, w.n);
    if par.get() <= 1 || m <= 1 || w.n == 0 {
        return crate::gemm::bsr_i8_packed(a, w);
    }
    let ad = a.data();
    row_tiled(m, w.n, par, |tile, row0| {
        crate::gemm::bsr::bsr_rows_i8(ad, w, tile, row0, k, w.n)
    })
}

/// [`bsr_i8_packed`] under a [`ZeroGate`] policy: workers run the
/// zero-gated block scheduler when the gate engages (`Auto` measures `A`'s
/// zero fraction once, before the pool spawns). Bit-exact with
/// [`bsr_i8_packed`] for every policy and thread count.
pub fn bsr_i8_packed_gated(
    a: &TensorI8,
    w: &BsrPacked,
    par: Parallelism,
    gate: ZeroGate,
) -> TensorI32 {
    let (m, k) = (a.shape()[0], a.shape()[1]);
    assert_eq!(k, w.k, "GEMM inner dims: A[{m}x{k}] Wbsr[{}x{}]", w.k, w.n);
    let engaged = gate.resolve_with(|| a.sparsity());
    if par.get() <= 1 || m <= 1 || w.n == 0 {
        return crate::gemm::bsr_i8_packed_gated(a, w, ZeroGate::resolved(engaged));
    }
    let ad = a.data();
    if engaged {
        row_tiled(m, w.n, par, |tile, row0| {
            crate::gemm::bsr::bsr_rows_i8_gated(ad, w, tile, row0, k, w.n)
        })
    } else {
        row_tiled(m, w.n, par, |tile, row0| {
            crate::gemm::bsr::bsr_rows_i8(ad, w, tile, row0, k, w.n)
        })
    }
}

/// Parallel joint-sparse GEMM: pre-encoded A ([`ActDbb`]) × pre-packed W,
/// row-tiled across the pool. Zero per-call encode/decode work on either
/// operand; bit-exact with [`crate::gemm::adbb_i8_packed`] (and so with the
/// dense-A oracles) for every thread count.
pub fn adbb_i8_packed(a: &ActDbb, w: &DbbPacked, par: Parallelism) -> TensorI32 {
    assert_eq!(a.k, w.k, "GEMM inner dims: Adbb[{}x{}] Wdbb[{}x{}]", a.m, a.k, w.k, w.n);
    if par.get() <= 1 || a.m <= 1 || w.n == 0 {
        return crate::gemm::adbb_i8_packed(a, w);
    }
    let (arp, aen) = (a.row_ptr(), a.entries());
    let (cp, en) = (w.col_ptr(), w.entries());
    row_tiled(a.m, w.n, par, |tile, row0| {
        crate::gemm::act::adbb_rows_i8(arp, aen, cp, en, tile, row0, w.n)
    })
}

/// Parallel joint GEMM for dense-fallback weights: pre-encoded A × dense
/// `[K, N]` W. Bit-exact with [`crate::gemm::adbb_dense_i8`] (and so with
/// [`dense_i8`]) for every thread count.
pub fn adbb_dense_i8(a: &ActDbb, w: &TensorI8, par: Parallelism) -> TensorI32 {
    let (k2, n) = (w.shape()[0], w.shape()[1]);
    assert_eq!(a.k, k2, "GEMM inner dims: Adbb[{}x{}] W[{k2}x{n}]", a.m, a.k);
    if par.get() <= 1 || a.m <= 1 || n == 0 {
        return crate::gemm::adbb_dense_i8(a, w);
    }
    let (arp, aen) = (a.row_ptr(), a.entries());
    let wd = w.data();
    row_tiled(a.m, n, par, |tile, row0| {
        crate::gemm::micro::adbb_dense_rows_i8(arp, aen, wd, tile, row0, n)
    })
}

/// Fused-epilogue row-tiling scaffold: like [`row_tiled`], but the kernel
/// computes one [`EP_CHUNK`]-row *chunk* of i32 accumulator rows at a time
/// into a small per-worker scratch (zeroed before every call, so assign-
/// and accumulate-semantics kernels both work), and the [`Epilogue`]
/// immediately requantizes — and optionally max-pools — the chunk into the
/// worker's INT8 output tile while it is L1-hot. The tile partition is
/// aligned to [`Epilogue::row_quantum`] so a pooled row pair never
/// straddles two workers, and [`Epilogue::out_rows`]' additivity over
/// quantum multiples keeps the per-worker output tiles disjoint and exact.
/// The per-worker acc/q8 arenas are allocated inside the spawned worker
/// *after* `pin_worker`, so their pages are first-touched on the worker's
/// own NUMA node; `buf` recycles the output backing across calls (the
/// engine's ping-pong).
fn row_tiled_ep<K: Fn(&mut [i32], usize) + Sync>(
    m: usize,
    n: usize,
    par: Parallelism,
    ep: &Epilogue,
    buf: Vec<i8>,
    kernel: K,
) -> TensorI8 {
    ep.check_rows(m);
    let out_rows = ep.out_rows(m);
    let len = out_rows * n;
    let mut buf = buf;
    if buf.len() != len {
        buf.clear();
        buf.resize(len, 0);
    }
    let mut c = TensorI8::from_vec(&[out_rows, n], buf);
    if m == 0 || n == 0 || len == 0 {
        return c;
    }
    let threads = par.get().min(m).max(1);
    let run_tile = |tile: &mut [i8], row0: usize, rows: usize| {
        // per-worker arena: first write happens on the worker itself
        let mut acc = vec![0i32; EP_CHUNK * n];
        let mut q8 = vec![0i8; EP_CHUNK * n];
        if ep.pool().is_some() {
            tile.fill(i8::MIN);
        }
        let mut done = 0usize;
        while done < rows {
            let take = EP_CHUNK.min(rows - done);
            let acc_c = &mut acc[..take * n];
            acc_c.fill(0);
            kernel(acc_c, row0 + done);
            ep.apply_chunk(acc_c, row0 + done, n, &mut q8, tile, row0);
            done += take;
        }
    };
    if threads <= 1 {
        run_tile(c.data_mut(), 0, m);
        return c;
    }
    let q = ep.row_quantum();
    let rows_per_tile = m.div_ceil(threads).div_ceil(q) * q;
    let out_per_tile = ep.out_rows(rows_per_tile);
    if out_per_tile == 0 {
        return c; // unreachable when len > 0; guards chunks_mut(0)
    }
    let rt = &run_tile;
    std::thread::scope(|s| {
        for (ti, tile) in c.data_mut().chunks_mut(out_per_tile * n).enumerate() {
            let row0 = ti * rows_per_tile;
            let rows = rows_per_tile.min(m - row0);
            s.spawn(move || {
                par.pin_worker(ti);
                rt(tile, row0, rows)
            });
        }
    });
    c
}

/// [`dense_i8_gated`] with a fused output [`Epilogue`]: each worker
/// requantizes (and optionally pools) its accumulator chunks to INT8 while
/// cache-hot — the whole-matrix i32 C is never allocated. Bit-exact with
/// `epilogue-oracle(dense_i8(a, w))` for every gate policy, ISA, and
/// thread count (pinned in `rust/tests/epilogue.rs`).
pub fn dense_i8_ep(
    a: &TensorI8,
    w: &TensorI8,
    par: Parallelism,
    gate: ZeroGate,
    ep: &Epilogue,
) -> TensorI8 {
    dense_i8_ep_into(a, w, par, gate, ep, Vec::new())
}

/// [`dense_i8_ep`] recycling `buf` as the output backing (the engine's
/// layer-chain ping-pong; pass `Vec::new()` when there is nothing to
/// recycle).
pub fn dense_i8_ep_into(
    a: &TensorI8,
    w: &TensorI8,
    par: Parallelism,
    gate: ZeroGate,
    ep: &Epilogue,
    buf: Vec<i8>,
) -> TensorI8 {
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (w.shape()[0], w.shape()[1]);
    assert_eq!(k, k2, "GEMM inner dims: A[{m}x{k}] W[{k2}x{n}]");
    let engaged = gate.resolve_with(|| a.sparsity());
    let (ad, wd) = (a.data(), w.data());
    if engaged {
        row_tiled_ep(m, n, par, ep, buf, |acc, row0| {
            crate::gemm::micro::dense_rows_i8_gated(ad, wd, acc, row0, k, n)
        })
    } else {
        row_tiled_ep(m, n, par, ep, buf, |acc, row0| {
            crate::gemm::micro::dense_rows_i8(ad, wd, acc, row0, k, n)
        })
    }
}

/// [`dbb_i8_packed_gated`] with a fused output [`Epilogue`].
pub fn dbb_i8_packed_ep(
    a: &TensorI8,
    w: &DbbPacked,
    par: Parallelism,
    gate: ZeroGate,
    ep: &Epilogue,
) -> TensorI8 {
    dbb_i8_packed_ep_into(a, w, par, gate, ep, Vec::new())
}

/// [`dbb_i8_packed_ep`] recycling `buf` as the output backing.
pub fn dbb_i8_packed_ep_into(
    a: &TensorI8,
    w: &DbbPacked,
    par: Parallelism,
    gate: ZeroGate,
    ep: &Epilogue,
    buf: Vec<i8>,
) -> TensorI8 {
    let (m, k) = (a.shape()[0], a.shape()[1]);
    assert_eq!(k, w.k, "GEMM inner dims: A[{m}x{k}] Wdbb[{}x{}]", w.k, w.n);
    let engaged = gate.resolve_with(|| a.sparsity());
    let ad = a.data();
    let (cp, en) = (w.col_ptr(), w.entries());
    if engaged {
        row_tiled_ep(m, w.n, par, ep, buf, |acc, row0| {
            crate::gemm::micro::dbb_rows_i8_gated(ad, cp, en, acc, row0, k, w.n)
        })
    } else {
        row_tiled_ep(m, w.n, par, ep, buf, |acc, row0| {
            crate::gemm::micro::dbb_rows_i8(ad, cp, en, acc, row0, k, w.n)
        })
    }
}

/// [`bsr_i8_packed_gated`] with a fused output [`Epilogue`].
pub fn bsr_i8_packed_ep(
    a: &TensorI8,
    w: &BsrPacked,
    par: Parallelism,
    gate: ZeroGate,
    ep: &Epilogue,
) -> TensorI8 {
    bsr_i8_packed_ep_into(a, w, par, gate, ep, Vec::new())
}

/// [`bsr_i8_packed_ep`] recycling `buf` as the output backing.
pub fn bsr_i8_packed_ep_into(
    a: &TensorI8,
    w: &BsrPacked,
    par: Parallelism,
    gate: ZeroGate,
    ep: &Epilogue,
    buf: Vec<i8>,
) -> TensorI8 {
    let (m, k) = (a.shape()[0], a.shape()[1]);
    assert_eq!(k, w.k, "GEMM inner dims: A[{m}x{k}] Wbsr[{}x{}]", w.k, w.n);
    let engaged = gate.resolve_with(|| a.sparsity());
    let ad = a.data();
    if engaged {
        row_tiled_ep(m, w.n, par, ep, buf, |acc, row0| {
            crate::gemm::bsr::bsr_rows_i8_gated(ad, w, acc, row0, k, w.n)
        })
    } else {
        row_tiled_ep(m, w.n, par, ep, buf, |acc, row0| {
            crate::gemm::bsr::bsr_rows_i8(ad, w, acc, row0, k, w.n)
        })
    }
}

/// [`adbb_i8_packed`] with a fused output [`Epilogue`].
pub fn adbb_i8_packed_ep(a: &ActDbb, w: &DbbPacked, par: Parallelism, ep: &Epilogue) -> TensorI8 {
    adbb_i8_packed_ep_into(a, w, par, ep, Vec::new())
}

/// [`adbb_i8_packed_ep`] recycling `buf` as the output backing.
pub fn adbb_i8_packed_ep_into(
    a: &ActDbb,
    w: &DbbPacked,
    par: Parallelism,
    ep: &Epilogue,
    buf: Vec<i8>,
) -> TensorI8 {
    assert_eq!(a.k, w.k, "GEMM inner dims: Adbb[{}x{}] Wdbb[{}x{}]", a.m, a.k, w.k, w.n);
    let (arp, aen) = (a.row_ptr(), a.entries());
    let (cp, en) = (w.col_ptr(), w.entries());
    row_tiled_ep(a.m, w.n, par, ep, buf, |acc, row0| {
        crate::gemm::act::adbb_rows_i8(arp, aen, cp, en, acc, row0, w.n)
    })
}

/// [`adbb_dense_i8`] with a fused output [`Epilogue`].
pub fn adbb_dense_i8_ep(a: &ActDbb, w: &TensorI8, par: Parallelism, ep: &Epilogue) -> TensorI8 {
    adbb_dense_i8_ep_into(a, w, par, ep, Vec::new())
}

/// [`adbb_dense_i8_ep`] recycling `buf` as the output backing.
pub fn adbb_dense_i8_ep_into(
    a: &ActDbb,
    w: &TensorI8,
    par: Parallelism,
    ep: &Epilogue,
    buf: Vec<i8>,
) -> TensorI8 {
    let (k2, n) = (w.shape()[0], w.shape()[1]);
    assert_eq!(a.k, k2, "GEMM inner dims: Adbb[{}x{}] W[{k2}x{n}]", a.m, a.k);
    let (arp, aen) = (a.row_ptr(), a.entries());
    let wd = w.data();
    row_tiled_ep(a.m, n, par, ep, buf, |acc, row0| {
        crate::gemm::micro::adbb_dense_rows_i8(arp, aen, wd, acc, row0, n)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dbb::prune::prune_i8;
    use crate::gemm;
    use crate::util::prop::{check, Config};
    use crate::util::Rng;

    #[test]
    fn dense_tiled_equals_serial_prop() {
        // random M/K/N and thread counts 1–8, including M < threads
        check(Config::default().cases(96), |rng| {
            let m = rng.below(40) + 1;
            let k = rng.below(64) + 1;
            let n = rng.below(24) + 1;
            let threads = rng.below(8) + 1;
            let a = TensorI8::rand_sparse(&[m, k], 0.3, rng);
            let w = TensorI8::rand(&[k, n], rng);
            let serial = gemm::dense_i8(&a, &w);
            let tiled = dense_i8(&a, &w, Parallelism::threads(threads));
            assert_eq!(
                serial.data(),
                tiled.data(),
                "m={m} k={k} n={n} threads={threads}"
            );
        });
    }

    #[test]
    fn dbb_tiled_equals_serial_prop() {
        // random M/K/N/bz/nnz and thread counts 1–8
        check(Config::default().cases(96), |rng| {
            let m = rng.below(32) + 1;
            let k = rng.below(64) + 1;
            let n = rng.below(20) + 1;
            let bz = [4usize, 8, 16][rng.below(3)];
            let nnz = rng.below(bz) + 1;
            let threads = rng.below(8) + 1;
            let a = TensorI8::rand_sparse(&[m, k], 0.4, rng);
            let wd = prune_i8(&TensorI8::rand(&[k, n], rng), bz, nnz);
            let w = DbbMatrix::compress(&wd, bz).unwrap();
            let serial = gemm::dbb_i8(&a, &w);
            let tiled = dbb_i8(&a, &w, Parallelism::threads(threads));
            assert_eq!(
                serial.data(),
                tiled.data(),
                "m={m} k={k} n={n} bz={bz} nnz={nnz} threads={threads}"
            );
        });
    }

    #[test]
    fn single_row_more_threads_than_rows() {
        // M < threads: the tile split must degenerate gracefully
        let mut rng = Rng::new(3);
        let a = TensorI8::rand(&[1, 33], &mut rng);
        let w = TensorI8::rand(&[33, 7], &mut rng);
        assert_eq!(
            dense_i8(&a, &w, Parallelism::threads(8)).data(),
            gemm::dense_i8(&a, &w).data()
        );
        let a3 = TensorI8::rand(&[3, 16], &mut rng);
        let wd = prune_i8(&TensorI8::rand(&[16, 5], &mut rng), 8, 3);
        let wc = DbbMatrix::compress(&wd, 8).unwrap();
        assert_eq!(
            dbb_i8(&a3, &wc, Parallelism::threads(16)).data(),
            gemm::dbb_i8(&a3, &wc).data()
        );
    }

    #[test]
    fn serial_fallback_is_exact_path() {
        let mut rng = Rng::new(4);
        let a = TensorI8::rand(&[9, 24], &mut rng);
        let w = TensorI8::rand(&[24, 6], &mut rng);
        assert_eq!(
            dense_i8(&a, &w, Parallelism::serial()).data(),
            gemm::dense_i8(&a, &w).data()
        );
    }

    #[test]
    fn dbb_packed_equals_per_call_decode_prop() {
        check(Config::default().cases(64), |rng| {
            let m = rng.below(32) + 1;
            let k = rng.below(64) + 1;
            let n = rng.below(20) + 1;
            let bz = [4usize, 8, 16][rng.below(3)];
            let nnz = rng.below(bz) + 1;
            let threads = rng.below(8) + 1;
            let a = TensorI8::rand_sparse(&[m, k], 0.4, rng);
            let wd = prune_i8(&TensorI8::rand(&[k, n], rng), bz, nnz);
            let w = DbbMatrix::compress(&wd, bz).unwrap();
            let packed = DbbPacked::pack(&w);
            assert_eq!(
                dbb_i8_packed(&a, &packed, Parallelism::threads(threads)).data(),
                gemm::dbb_i8(&a, &w).data(),
                "m={m} k={k} n={n} bz={bz} nnz={nnz} threads={threads}"
            );
        });
    }

    #[test]
    fn gated_tiled_bit_exact_prop() {
        // every policy × random sparsity × thread counts incl. M < threads
        check(Config::default().cases(64), |rng| {
            let m = rng.below(24) + 1;
            let k = rng.below(48) + 1;
            let n = rng.below(16) + 1;
            let threads = rng.below(8) + 1;
            let p_zero = [0.0f32, 0.5, 1.0][rng.below(3)];
            let gate = [ZeroGate::Off, ZeroGate::Auto, ZeroGate::On][rng.below(3)];
            let a = TensorI8::rand_sparse(&[m, k], p_zero, rng);
            let w = TensorI8::rand(&[k, n], rng);
            assert_eq!(
                dense_i8_gated(&a, &w, Parallelism::threads(threads), gate).data(),
                gemm::dense_i8(&a, &w).data(),
                "dense m={m} k={k} n={n} threads={threads} p={p_zero} gate={gate:?}"
            );
            let enc = DbbMatrix::compress_topk(&w, 8, rng.below(8) + 1).unwrap();
            let packed = DbbPacked::pack(&enc);
            assert_eq!(
                dbb_i8_packed_gated(&a, &packed, Parallelism::threads(threads), gate).data(),
                gemm::dbb_i8(&a, &enc).data(),
                "dbb m={m} k={k} n={n} threads={threads} p={p_zero} gate={gate:?}"
            );
        });
    }

    #[test]
    fn adbb_tiled_bit_exact_prop() {
        // encoded-A joint kernels vs the dense-A oracles, every thread count
        check(Config::default().cases(64), |rng| {
            let m = rng.below(24) + 1;
            let k = rng.below(48) + 1;
            let n = rng.below(16) + 1;
            let bz = [4usize, 8, 16][rng.below(3)];
            let nnz = rng.below(bz) + 1;
            let threads = rng.below(8) + 1;
            let p_zero = [0.0f32, 0.5, 1.0][rng.below(3)];
            let a = TensorI8::rand_sparse(&[m, k], p_zero, rng);
            let w = TensorI8::rand(&[k, n], rng);
            let enc = ActDbb::encode(&a, bz);
            let par = Parallelism::threads(threads);
            assert_eq!(
                adbb_dense_i8(&enc, &w, par).data(),
                gemm::dense_i8(&a, &w).data(),
                "dense m={m} k={k} n={n} bz={bz} threads={threads} p={p_zero}"
            );
            let wc = DbbMatrix::compress_topk(&w, bz, nnz).unwrap();
            let packed = DbbPacked::pack(&wc);
            assert_eq!(
                adbb_i8_packed(&enc, &packed, par).data(),
                gemm::dbb_i8_packed(&a, &packed).data(),
                "dbb m={m} k={k} n={n} bz={bz} nnz={nnz} threads={threads} p={p_zero}"
            );
        });
    }

    #[test]
    fn epilogue_tiled_equals_staged_oracle_prop() {
        use crate::gemm::epilogue::{self, PoolGeom, Requant};
        check(Config::default().cases(48), |rng| {
            let oh = rng.below(6) + 1;
            let ow = rng.below(6) + 1;
            let b = rng.below(3) + 1;
            let m = b * oh * ow;
            let k = rng.below(32) + 1;
            let n = rng.below(20) + 1;
            let threads = rng.below(8) + 1;
            let relu = rng.below(2) == 0;
            let a = TensorI8::rand_sparse(&[m, k], 0.4, rng);
            let w = TensorI8::rand(&[k, n], rng);
            let par = Parallelism::threads(threads);
            let acc = gemm::dense_i8(&a, &w);
            let shift = epilogue::requant_shift(acc.data());
            let staged = epilogue::requant_with_shift(&acc, shift, relu);
            let ep = Epilogue::new(Requant::Global(shift), relu);
            let fused = dense_i8_ep(&a, &w, par, ZeroGate::Auto, &ep);
            assert_eq!(
                fused.data(),
                staged.data(),
                "requant m={m} k={k} n={n} threads={threads} relu={relu}"
            );
            let epp = ep.with_pool(PoolGeom { oh, ow });
            let pooled = epilogue::max_pool_2x2(&staged, oh, ow, n);
            let fusedp = dense_i8_ep(&a, &w, par, ZeroGate::Auto, &epp);
            assert_eq!(fusedp.shape(), pooled.shape());
            assert_eq!(
                fusedp.data(),
                pooled.data(),
                "pool b={b} oh={oh} ow={ow} k={k} n={n} threads={threads} relu={relu}"
            );
        });
    }

    #[test]
    fn bsr_tiled_bit_exact_prop() {
        use crate::dbb::prune::prune_bsr_i8;
        // tiled + gated BSR vs the dense oracle, threads incl. M < threads
        check(Config::default().cases(64), |rng| {
            let m = rng.below(24) + 1;
            let k = rng.below(48) + 1;
            let n = rng.below(20) + 1;
            let bz_r = [4usize, 8, 16][rng.below(3)];
            let bz_c = [4usize, 8, 16][rng.below(3)];
            let threads = rng.below(8) + 1;
            let p_zero = [0.0f32, 0.5, 1.0][rng.below(3)];
            let gate = [ZeroGate::Off, ZeroGate::Auto, ZeroGate::On][rng.below(3)];
            let a = TensorI8::rand_sparse(&[m, k], p_zero, rng);
            let wd = prune_bsr_i8(&TensorI8::rand(&[k, n], rng), bz_r, bz_c, rng.below(3) + 1);
            let w = BsrPacked::pack(&wd, bz_r, bz_c);
            let par = Parallelism::threads(threads);
            assert_eq!(
                bsr_i8_packed(&a, &w, par).data(),
                gemm::dense_i8(&a, &wd).data(),
                "bsr m={m} k={k} n={n} bz={bz_r}x{bz_c} threads={threads}"
            );
            assert_eq!(
                bsr_i8_packed_gated(&a, &w, par, gate).data(),
                gemm::dense_i8(&a, &wd).data(),
                "bsr gated m={m} k={k} n={n} p={p_zero} gate={gate:?}"
            );
        });
    }

    #[test]
    fn dbb_tiled_matches_dense_on_decompressed() {
        let mut rng = Rng::new(5);
        let a = TensorI8::rand_sparse(&[40, 48], 0.5, &mut rng);
        let wd = prune_i8(&TensorI8::rand(&[48, 24], &mut rng), 8, 3);
        let w = DbbMatrix::compress(&wd, 8).unwrap();
        assert_eq!(
            dbb_i8(&a, &w, Parallelism::threads(4)).data(),
            gemm::dense_i8(&a, &wd).data()
        );
    }
}
