//! Fused streaming-IM2COL convolution engine — paper §IV-C (Fig. 8) in
//! software.
//!
//! The paper's hardware IM2COL unit moves the patch expansion *into the
//! datapath*: the SRAM holds only the raw NHWC feature map and a small row
//! buffer regenerates the duplicated IM2COL pixels just before the MACs, so
//! the ~`kh·kw/stride²` operand blowup never exists as a stored matrix.
//! This module is the same design decision applied to the functional stack:
//! instead of materializing the `[M×K]` IM2COL operand
//! ([`crate::gemm::conv::im2col`] — now the test oracle's lowering), each
//! worker of the tiled pool generates a small chunk of patch rows on the fly
//! from the feature map and streams it straight into the shared inner
//! kernels (`dense_rows_i8` / `dbb_rows_i8`, the same row kernels behind
//! [`crate::gemm::dense_i8`] and [`crate::gemm::dbb_i8`]), accumulating its
//! disjoint output tile in INT32.
//!
//! Peak extra memory is `O(threads · PATCH_ROWS · K)` — the software
//! analogue of the unit's 6×4-pixel buffer registers
//! ([`crate::sim::im2col::Im2colUnit`], whose functional row-generation path
//! is this module's [`patch_row_into`]) — versus `O(M·K)` for the
//! materializing path. Batch folds into `M` exactly like the coordinator
//! folds it: row `r` of the virtual operand is output pixel
//! `(r / ow) % oh, r % ow` of image `r / (oh·ow)`.
//!
//! Results are bit-exact with [`crate::gemm::conv::conv2d_direct`] (INT8 is
//! order-independent) and, for the f32 training variant, bit-exact with
//! `im2col_f32` + `matmul` (the per-row accumulation order is preserved).
//! Property-tested here and in `rust/tests/fused_conv.rs`.
//!
//! The `*_gated` INT8 entry points additionally take a
//! [`crate::gemm::ZeroGate`] policy: when the gate engages, the generated
//! patch rows stream through the zero-gated row kernels, so zero
//! activations — including the IM2COL padding zeros the row generator
//! writes — skip their multiplies entirely, still bit-exact
//! (`rust/tests/zero_gate.rs`).
//!
//! The `*_encoded` entry points go one step further down the
//! [`crate::gemm::ActPolicy`] ladder: each worker DBB-encodes its generated
//! patch-row chunk **right after streaming IM2COL** — the point where the
//! ~`kh·kw/stride²` bandwidth expansion happens, so the padding zeros and
//! the duplicated zero pixels are compressed away the moment they are
//! produced — and streams the per-chunk `(row_ptr, entries)` CSR through
//! the joint A-DBB kernels (`crate::gemm::act`). Still bit-exact: the
//! encoding is lossless (`rust/tests/act_dbb.rs`).
//!
//! Every dense-weight and packed-DBB inner call dispatches through the
//! [`crate::gemm::micro`] SIMD microkernels (bit-exact with the scalar
//! oracles; see that module for the dispatch rules). Only the merge-join
//! `adbb_rows_i8` path stays scalar by design. With
//! [`Parallelism::with_pin`]`(true)`, each conv worker pins itself to core
//! `ti % cores` before touching its tile, keeping its [`PatchScratch`]
//! arena hot in the same core's cache across steady-state `*_with` calls.
//! Worker scratch (patch rows, epilogue arenas) is *sized inside* the
//! pinned workers, so on a first-touch NUMA policy the pages land on each
//! worker's own node.
//!
//! The `*_ep` entry points fuse the layer **epilogue**
//! ([`crate::gemm::Epilogue`]: requantize + optional ReLU + optional
//! 2×2/stride-2 max-pool) into the same output walk: each worker converts
//! its freshly accumulated `PATCH_ROWS × N` i32 chunk to i8 — and
//! max-folds it into the pooled output tile — while the chunk is still
//! cache-hot, so conv + ReLU + pool becomes one streaming pass and no
//! whole-layer i32 tensor is ever allocated. Bit-exact with the staged
//! `conv → requant_relu → max_pool_2x2` pipeline (`rust/tests/epilogue.rs`);
//! when pooling, worker tiles are partitioned on the epilogue's row quantum
//! so each pool window is owned by exactly one worker.

pub use crate::util::par::Parallelism;

use crate::dbb::DbbMatrix;
use crate::gemm::conv::ConvShape;
use crate::gemm::{BsrPacked, DbbPacked, Epilogue, ZeroGate};
use crate::tensor::{Tensor, TensorF32, TensorI32, TensorI8};

/// Patch rows generated per inner-kernel call — the software row buffer.
/// Small enough to stay L1-resident next to the weight stream, large enough
/// to amortize the generation loop.
pub const PATCH_ROWS: usize = 8;

/// Reusable per-worker patch-row buffers — the preallocated form of the
/// software row buffer ([`PATCH_ROWS`]` × K` i8 per worker). The `*_with`
/// conv entry points draw their buffers from a `PatchScratch` instead of
/// allocating per call, so a caller that executes many convolutions (the
/// [`crate::engine`] prepared-model executor) pays the allocation once;
/// buffers grow on demand and every patch row is fully rewritten before it
/// is read, so reuse across layers of different `K` is safe.
#[derive(Debug, Default)]
pub struct PatchScratch {
    bufs: Vec<Vec<i8>>,
    /// Per-worker chunk-encode buffers for the `*_encoded` paths: the CSR
    /// `row_ptr` / `(k, value)` entry stream of one `PATCH_ROWS` chunk.
    /// Cleared and fully rewritten before every read, like `bufs`.
    enc_ptr: Vec<Vec<usize>>,
    enc_ent: Vec<Vec<(u32, i32)>>,
    /// Per-worker fused-epilogue arenas: the `PATCH_ROWS × N` i32
    /// accumulator chunk and its i8 requantize staging. Sized inside the
    /// pinned workers, like `bufs`.
    acc: Vec<Vec<i32>>,
    q8: Vec<Vec<i8>>,
    /// Recycled whole-layer INT8 output backings for the engine's
    /// fused-epilogue layer chain (the ping-pong: a layer's output buffer
    /// is reclaimed once the next layer has consumed it).
    out_bufs: Vec<Vec<i8>>,
    /// Reusable whole-operand A-DBB stream for FC-layer `Encode` passes —
    /// the non-chunked counterpart of `enc_ptr`/`enc_ent` (the engine
    /// encodes one FC operand at a time, between conv layers, so a single
    /// slot suffices). Fully rewritten by every [`Self::act_encode`].
    act_enc: Option<crate::gemm::ActDbb>,
}

impl PatchScratch {
    /// Empty scratch; buffers materialize on first use.
    pub fn new() -> Self {
        PatchScratch::default()
    }

    /// Scratch with `workers` buffer slots ready. The buffers themselves
    /// grow lazily **inside the pinned workers** (see [`Self::reserve`]),
    /// so this only sets up the outer slots; `k` documents the expected
    /// chunk width and keeps the signature stable.
    pub fn preallocate(workers: usize, k: usize) -> Self {
        let mut s = PatchScratch::new();
        s.reserve(workers, k);
        s
    }

    /// Ensure at least `workers` per-worker buffer *slots*. The inner
    /// buffers are deliberately **not** sized here: each worker grows its
    /// own buffer to `PATCH_ROWS · k` on first use, *after*
    /// `Parallelism::pin_worker`, so the pages are first-touched — and on a
    /// first-touch NUMA policy, physically placed — on the worker's own
    /// node instead of the prepare thread's (the capacity is retained
    /// across calls, so the steady state still allocates nothing).
    pub fn reserve(&mut self, workers: usize, _k: usize) {
        if self.bufs.len() < workers {
            self.bufs.resize_with(workers, Vec::new);
        }
    }

    fn take(&mut self, workers: usize, k: usize) -> &mut [Vec<i8>] {
        self.reserve(workers, k);
        &mut self.bufs[..workers]
    }

    /// Like [`Self::take`], plus the per-worker chunk-encode buffers the
    /// `*_encoded` conv paths rewrite per chunk (entry capacity grows on
    /// demand and is retained across calls, so the steady state allocates
    /// nothing).
    fn take_encoded(
        &mut self,
        workers: usize,
        k: usize,
    ) -> (&mut [Vec<i8>], &mut [Vec<usize>], &mut [Vec<(u32, i32)>]) {
        self.reserve(workers, k);
        if self.enc_ptr.len() < workers {
            self.enc_ptr.resize_with(workers, Vec::new);
        }
        if self.enc_ent.len() < workers {
            self.enc_ent.resize_with(workers, Vec::new);
        }
        (
            &mut self.bufs[..workers],
            &mut self.enc_ptr[..workers],
            &mut self.enc_ent[..workers],
        )
    }

    /// [`Self::take`] plus the per-worker fused-epilogue arenas (i32
    /// accumulator chunk + i8 requantize staging), slots only — each worker
    /// sizes its own arena after pinning (first-touch).
    fn take_ep(
        &mut self,
        workers: usize,
        k: usize,
    ) -> (&mut [Vec<i8>], &mut [Vec<i32>], &mut [Vec<i8>]) {
        self.reserve(workers, k);
        if self.acc.len() < workers {
            self.acc.resize_with(workers, Vec::new);
        }
        if self.q8.len() < workers {
            self.q8.resize_with(workers, Vec::new);
        }
        (
            &mut self.bufs[..workers],
            &mut self.acc[..workers],
            &mut self.q8[..workers],
        )
    }

    /// [`Self::take_encoded`] plus the fused-epilogue arenas — the
    /// joint-sparse fused-epilogue conv path needs all five per-worker
    /// buffer families.
    #[allow(clippy::type_complexity)]
    fn take_encoded_ep(
        &mut self,
        workers: usize,
        k: usize,
    ) -> (
        &mut [Vec<i8>],
        &mut [Vec<usize>],
        &mut [Vec<(u32, i32)>],
        &mut [Vec<i32>],
        &mut [Vec<i8>],
    ) {
        self.reserve(workers, k);
        if self.enc_ptr.len() < workers {
            self.enc_ptr.resize_with(workers, Vec::new);
        }
        if self.enc_ent.len() < workers {
            self.enc_ent.resize_with(workers, Vec::new);
        }
        if self.acc.len() < workers {
            self.acc.resize_with(workers, Vec::new);
        }
        if self.q8.len() < workers {
            self.q8.resize_with(workers, Vec::new);
        }
        (
            &mut self.bufs[..workers],
            &mut self.enc_ptr[..workers],
            &mut self.enc_ent[..workers],
            &mut self.acc[..workers],
            &mut self.q8[..workers],
        )
    }

    /// Pop a recycled whole-layer output backing (empty `Vec` when none) —
    /// the take side of the engine's fused-epilogue ping-pong.
    pub fn take_out_buf(&mut self) -> Vec<i8> {
        self.out_bufs.pop().unwrap_or_default()
    }

    /// Return a consumed layer output's backing for reuse (bounded pool, so
    /// an over-returning caller cannot hoard memory).
    pub fn put_out_buf(&mut self, buf: Vec<i8>) {
        if self.out_bufs.len() < 4 {
            self.out_bufs.push(buf);
        }
    }

    /// DBB-encode a whole `[M, K]` activation operand into the
    /// scratch-owned reusable stream ([`crate::gemm::ActDbb::encode_reuse`])
    /// and return it — zero steady-state allocation, the FC analogue of the
    /// per-worker chunk encoding the `*_encoded` conv paths do.
    pub fn act_encode(&mut self, a: &TensorI8, bz: usize) -> &crate::gemm::ActDbb {
        let enc = self.act_enc.get_or_insert_with(crate::gemm::ActDbb::empty);
        enc.encode_reuse(a, bz);
        enc
    }
}

/// Write the IM2COL operand row of output pixel `(oy, ox)` (one image,
/// layout `[h, w, c]`, channel-innermost K) into `row`
/// (length [`ConvShape::gemm_k`]). Out-of-bounds taps stay zero (padding).
///
/// This is the row generator shared by the fused engine and the hardware
/// [`crate::sim::im2col::Im2colUnit`] functional path — the two are
/// cross-tested against [`crate::gemm::conv::im2col`] in
/// `rust/tests/fused_conv.rs`.
pub fn patch_row_into<T: Copy + Default>(
    xd: &[T],
    s: &ConvShape,
    oy: usize,
    ox: usize,
    row: &mut [T],
) {
    debug_assert_eq!(xd.len(), s.h * s.w * s.c);
    debug_assert_eq!(row.len(), s.gemm_k());
    row.fill(T::default());
    let (h, w, c) = (s.h, s.w, s.c);
    for ky in 0..s.kh {
        let iy = (oy * s.stride + ky) as isize - s.pad as isize;
        if iy < 0 || iy >= h as isize {
            continue;
        }
        for kx in 0..s.kw {
            let ix = (ox * s.stride + kx) as isize - s.pad as isize;
            if ix < 0 || ix >= w as isize {
                continue;
            }
            let src = (iy as usize * w + ix as usize) * c;
            let dst = (ky * s.kw + kx) * c;
            row[dst..dst + c].copy_from_slice(&xd[src..src + c]);
        }
    }
}

/// Peak operand bytes the fused engine holds at once (all workers' row
/// buffers, with the same worker/row clamps the engine applies) — compare
/// with the `gemm_m() · gemm_k()` bytes the materializing path allocates.
/// This is the §IV-C memory claim, measured (batch-1 view).
pub fn peak_operand_bytes(s: &ConvShape, par: Parallelism) -> usize {
    let m = s.gemm_m().max(1);
    let workers = par.get().clamp(1, m);
    let rows_per_tile = m.div_ceil(workers);
    workers * PATCH_ROWS.min(rows_per_tile) * s.gemm_k()
}

/// Batch size of an activation tensor: `[h, w, c]` (one image) or
/// `[b, h, w, c]` (batch folded into GEMM M). Panics on a shape mismatch.
fn batch_of<T: Copy + Default>(x: &Tensor<T>, s: &ConvShape) -> usize {
    match x.shape() {
        &[h, w, c] => {
            assert_eq!([h, w, c], [s.h, s.w, s.c], "conv input shape");
            1
        }
        &[b, h, w, c] => {
            assert_eq!([h, w, c], [s.h, s.w, s.c], "conv input shape");
            b
        }
        other => panic!("conv input must be [h,w,c] or [b,h,w,c], got {other:?}"),
    }
}

/// Weights may come as HWCO `[kh, kw, c, oc]` (the direct-conv layout) or
/// already flattened to the GEMM right operand `[kh·kw·c, oc]` — identical
/// bytes either way (see [`crate::gemm::conv::weights_to_gemm`]).
fn check_weights<T: Copy + Default>(w: &Tensor<T>, s: &ConvShape) {
    let ok = w.shape() == [s.kh, s.kw, s.c, s.oc] || w.shape() == [s.gemm_k(), s.oc];
    assert!(
        ok,
        "conv weights must be [kh,kw,c,oc] or [K,oc] for {s:?}, got {:?}",
        w.shape()
    );
}

/// Generate-and-accumulate worker: compute output rows
/// `row0..row0 + out.len()/n` of the virtual `[M×N]` result, generating
/// IM2COL rows in `PATCH_ROWS` chunks and handing each chunk (patch slice +
/// matching output window) to the inner row `kernel` — the dense or
/// decoded-CSC GEMM row kernel.
///
/// NOTE: [`conv_rows_encoded`] mirrors this chunk loop (same
/// `gr → (batch, pixel)` mapping, same `PATCH_ROWS` chunking) with a
/// per-chunk encode step; the two cannot share one scaffold because the
/// encoded path needs per-*worker* mutable CSR buffers the shared `Fn`
/// kernel cannot own. Keep any change to the row mapping or chunking in
/// lockstep — the encoded-vs-plain bit-exactness property tests
/// (`encoded_conv_bit_exact_prop`, `rust/tests/act_dbb.rs`) catch drift.
fn conv_rows<K: Fn(&[i8], &mut [i32])>(
    xd: &[i8],
    s: &ConvShape,
    out: &mut [i32],
    row0: usize,
    k: usize,
    n: usize,
    patch: &mut Vec<i8>,
    kernel: &K,
) {
    // Sized here, on the worker, so the pages are first-touched on the
    // worker's own NUMA node (no-op once warm).
    if patch.len() < PATCH_ROWS * k {
        patch.resize(PATCH_ROWS * k, 0);
    }
    let (oh, ow) = (s.oh(), s.ow());
    let img = s.h * s.w * s.c;
    let rows = out.len() / n;
    let mut done = 0usize;
    while done < rows {
        let take = PATCH_ROWS.min(rows - done);
        for r in 0..take {
            let gr = row0 + done + r;
            let (bi, pix) = (gr / (oh * ow), gr % (oh * ow));
            patch_row_into(
                &xd[bi * img..(bi + 1) * img],
                s,
                pix / ow,
                pix % ow,
                &mut patch[r * k..(r + 1) * k],
            );
        }
        kernel(&patch[..take * k], &mut out[done * n..(done + take) * n]);
        done += take;
    }
}

/// Row-tile `out` across the worker pool (same partition as
/// [`crate::gemm::tiled`]) and run [`conv_rows`] on each tile, each worker
/// on its own scratch buffer. Serial parallelism runs inline with no thread
/// spawned.
fn conv_tiled<K: Fn(&[i8], &mut [i32]) + Sync>(
    xd: &[i8],
    s: &ConvShape,
    out: &mut [i32],
    m: usize,
    k: usize,
    n: usize,
    par: Parallelism,
    scratch: &mut PatchScratch,
    kernel: K,
) {
    let threads = par.get().min(m);
    let patches = scratch.take(threads.max(1), k);
    if threads <= 1 {
        conv_rows(xd, s, out, 0, k, n, &mut patches[0], &kernel);
        return;
    }
    let rows_per_tile = m.div_ceil(threads);
    let kref = &kernel;
    std::thread::scope(|sc| {
        for ((ti, tile), buf) in
            out.chunks_mut(rows_per_tile * n).enumerate().zip(patches.iter_mut())
        {
            let row0 = ti * rows_per_tile;
            sc.spawn(move || {
                par.pin_worker(ti);
                conv_rows(xd, s, tile, row0, k, n, buf, kref)
            });
        }
    });
}

/// Generate-encode-accumulate worker for the `*_encoded` paths: like
/// [`conv_rows`], but every `PATCH_ROWS` chunk of generated IM2COL rows is
/// DBB-encoded in place — one pass over the chunk recording its non-zeros
/// as a `(row_ptr, entries)` CSR — before the joint A-DBB row `kernel`
/// consumes it. The encode happens at the exact point of the IM2COL
/// bandwidth expansion, so padding zeros and duplicated zero pixels never
/// reach the multiplier *or* the weight-stream walk.
///
/// NOTE: keep the chunk loop and `gr → (batch, pixel)` mapping in lockstep
/// with [`conv_rows`] (see the note there for why the scaffold is
/// duplicated).
fn conv_rows_encoded<K: Fn(&[usize], &[(u32, i32)], &mut [i32])>(
    xd: &[i8],
    s: &ConvShape,
    out: &mut [i32],
    row0: usize,
    k: usize,
    n: usize,
    patch: &mut Vec<i8>,
    arp: &mut Vec<usize>,
    aen: &mut Vec<(u32, i32)>,
    kernel: &K,
) {
    // Worker-side sizing for first-touch placement (see `conv_rows`).
    if patch.len() < PATCH_ROWS * k {
        patch.resize(PATCH_ROWS * k, 0);
    }
    let (oh, ow) = (s.oh(), s.ow());
    let img = s.h * s.w * s.c;
    let rows = out.len() / n;
    let mut done = 0usize;
    while done < rows {
        let take = PATCH_ROWS.min(rows - done);
        arp.clear();
        aen.clear();
        arp.push(0);
        for r in 0..take {
            let gr = row0 + done + r;
            let (bi, pix) = (gr / (oh * ow), gr % (oh * ow));
            patch_row_into(
                &xd[bi * img..(bi + 1) * img],
                s,
                pix / ow,
                pix % ow,
                &mut patch[r * k..(r + 1) * k],
            );
            for (kk, &v) in patch[r * k..(r + 1) * k].iter().enumerate() {
                if v != 0 {
                    aen.push((kk as u32, v as i32));
                }
            }
            arp.push(aen.len());
        }
        kernel(arp, aen, &mut out[done * n..(done + take) * n]);
        done += take;
    }
}

/// Row-tile `out` across the worker pool and run [`conv_rows_encoded`] on
/// each tile, each worker on its own patch + encode buffers. Same partition
/// as [`conv_tiled`]; serial parallelism runs inline.
fn conv_tiled_encoded<K: Fn(&[usize], &[(u32, i32)], &mut [i32]) + Sync>(
    xd: &[i8],
    s: &ConvShape,
    out: &mut [i32],
    m: usize,
    k: usize,
    n: usize,
    par: Parallelism,
    scratch: &mut PatchScratch,
    kernel: K,
) {
    let threads = par.get().min(m);
    let (patches, ptrs, ents) = scratch.take_encoded(threads.max(1), k);
    if threads <= 1 {
        conv_rows_encoded(
            xd,
            s,
            out,
            0,
            k,
            n,
            &mut patches[0],
            &mut ptrs[0],
            &mut ents[0],
            &kernel,
        );
        return;
    }
    let rows_per_tile = m.div_ceil(threads);
    let kref = &kernel;
    std::thread::scope(|sc| {
        for ((((ti, tile), buf), arp), aen) in out
            .chunks_mut(rows_per_tile * n)
            .enumerate()
            .zip(patches.iter_mut())
            .zip(ptrs.iter_mut())
            .zip(ents.iter_mut())
        {
            let row0 = ti * rows_per_tile;
            sc.spawn(move || {
                par.pin_worker(ti);
                conv_rows_encoded(xd, s, tile, row0, k, n, buf, arp, aen, kref)
            });
        }
    });
}

/// Output tensor for a conv: batched input keeps the batch axis.
fn conv_output(batched: bool, batch: usize, s: &ConvShape) -> TensorI32 {
    if batched {
        TensorI32::zeros(&[batch, s.oh(), s.ow(), s.oc])
    } else {
        TensorI32::zeros(&[s.oh(), s.ow(), s.oc])
    }
}

/// INT8 output tensor for a fused-epilogue conv, recycling `buf` as the
/// backing store when it already has the right length (the engine's
/// ping-pong). Pooling halves the spatial grid (floor: odd edge rows/cols
/// are dropped, matching [`crate::gemm::epilogue::max_pool_2x2`]).
fn conv_output_ep(
    batched: bool,
    batch: usize,
    s: &ConvShape,
    ep: &Epilogue,
    mut buf: Vec<i8>,
) -> TensorI8 {
    let (oh, ow) = if ep.pool().is_some() {
        (s.oh() / 2, s.ow() / 2)
    } else {
        (s.oh(), s.ow())
    };
    let len = batch * oh * ow * s.oc;
    if buf.len() != len {
        buf.clear();
        buf.resize(len, 0);
    }
    if batched {
        TensorI8::from_vec(&[batch, oh, ow, s.oc], buf)
    } else {
        TensorI8::from_vec(&[oh, ow, s.oc], buf)
    }
}

/// A pooled epilogue handed to a conv entry must describe that conv's own
/// output grid — the pool fold reads its `(oh, ow)` to map accumulator rows
/// to pool windows.
fn check_pool(ep: &Epilogue, s: &ConvShape) {
    if let Some(pg) = ep.pool() {
        assert_eq!(
            (pg.oh, pg.ow),
            (s.oh(), s.ow()),
            "epilogue pool geometry must match the conv output grid"
        );
    }
}

/// Fused-epilogue counterpart of [`conv_rows`]: generate IM2COL rows in
/// `PATCH_ROWS` chunks, accumulate each chunk into the worker's i32 arena,
/// then immediately requantize (+ ReLU) it to i8 — max-folding into the
/// pooled tile when the epilogue pools — while the chunk is cache-hot.
/// `tile` is the worker's i8 *output* tile covering epilogue output rows
/// `ep.out_rows(row0)..`; `rows` is the count of virtual GEMM rows this
/// worker owns (a multiple of the epilogue row quantum except possibly the
/// last tile, which `Epilogue::out_rows` additivity still covers).
///
/// NOTE: keep the chunk loop and `gr → (batch, pixel)` mapping in lockstep
/// with [`conv_rows`] (see the note there).
#[allow(clippy::too_many_arguments)]
fn conv_rows_ep<K: Fn(&[i8], &mut [i32])>(
    xd: &[i8],
    s: &ConvShape,
    tile: &mut [i8],
    row0: usize,
    rows: usize,
    k: usize,
    n: usize,
    ep: &Epilogue,
    patch: &mut Vec<i8>,
    acc: &mut Vec<i32>,
    q8: &mut Vec<i8>,
    kernel: &K,
) {
    // Worker-side sizing for first-touch placement (see `conv_rows`).
    if patch.len() < PATCH_ROWS * k {
        patch.resize(PATCH_ROWS * k, 0);
    }
    if acc.len() < PATCH_ROWS * n {
        acc.resize(PATCH_ROWS * n, 0);
    }
    if q8.len() < PATCH_ROWS * n {
        q8.resize(PATCH_ROWS * n, 0);
    }
    if ep.pool().is_some() {
        tile.fill(i8::MIN);
    }
    let (oh, ow) = (s.oh(), s.ow());
    let img = s.h * s.w * s.c;
    let tile_row0 = row0;
    let mut done = 0usize;
    while done < rows {
        let take = PATCH_ROWS.min(rows - done);
        for r in 0..take {
            let gr = row0 + done + r;
            let (bi, pix) = (gr / (oh * ow), gr % (oh * ow));
            patch_row_into(
                &xd[bi * img..(bi + 1) * img],
                s,
                pix / ow,
                pix % ow,
                &mut patch[r * k..(r + 1) * k],
            );
        }
        let acc_c = &mut acc[..take * n];
        acc_c.fill(0);
        kernel(&patch[..take * k], acc_c);
        ep.apply_chunk(acc_c, row0 + done, n, q8, tile, tile_row0);
        done += take;
    }
}

/// Row-tile the fused-epilogue conv across the worker pool: same partition
/// idea as [`conv_tiled`], but tiles are aligned to the epilogue's row
/// quantum so every pool window is owned by exactly one worker, and each
/// worker writes a disjoint i8 output tile. `out` is the whole
/// `[ep.out_rows(m) × n]` i8 output slice.
#[allow(clippy::too_many_arguments)]
fn conv_tiled_ep<K: Fn(&[i8], &mut [i32]) + Sync>(
    xd: &[i8],
    s: &ConvShape,
    out: &mut [i8],
    m: usize,
    k: usize,
    n: usize,
    par: Parallelism,
    ep: &Epilogue,
    scratch: &mut PatchScratch,
    kernel: K,
) {
    let threads = par.get().min(m);
    let (patches, accs, q8s) = scratch.take_ep(threads.max(1), k);
    if threads <= 1 {
        conv_rows_ep(
            xd,
            s,
            out,
            0,
            m,
            k,
            n,
            ep,
            &mut patches[0],
            &mut accs[0],
            &mut q8s[0],
            &kernel,
        );
        return;
    }
    let q = ep.row_quantum();
    let rows_per_tile = m.div_ceil(threads).div_ceil(q) * q;
    let out_per_tile = ep.out_rows(rows_per_tile);
    if out_per_tile == 0 {
        return;
    }
    let kref = &kernel;
    std::thread::scope(|sc| {
        for ((((ti, tile), buf), acc), q8) in out
            .chunks_mut(out_per_tile * n)
            .enumerate()
            .zip(patches.iter_mut())
            .zip(accs.iter_mut())
            .zip(q8s.iter_mut())
        {
            let row0 = ti * rows_per_tile;
            let rows = rows_per_tile.min(m - row0);
            sc.spawn(move || {
                par.pin_worker(ti);
                conv_rows_ep(xd, s, tile, row0, rows, k, n, ep, buf, acc, q8, kref)
            });
        }
    });
}

/// Fused-epilogue counterpart of [`conv_rows_encoded`]: generate + DBB-encode
/// each `PATCH_ROWS` chunk, accumulate through the joint A-DBB kernel into
/// the worker's i32 arena, then requantize/pool it to i8 in place.
///
/// NOTE: keep the chunk loop, encode step, and `gr → (batch, pixel)` mapping
/// in lockstep with [`conv_rows_encoded`].
#[allow(clippy::too_many_arguments)]
fn conv_rows_encoded_ep<K: Fn(&[usize], &[(u32, i32)], &mut [i32])>(
    xd: &[i8],
    s: &ConvShape,
    tile: &mut [i8],
    row0: usize,
    rows: usize,
    k: usize,
    n: usize,
    ep: &Epilogue,
    patch: &mut Vec<i8>,
    arp: &mut Vec<usize>,
    aen: &mut Vec<(u32, i32)>,
    acc: &mut Vec<i32>,
    q8: &mut Vec<i8>,
    kernel: &K,
) {
    // Worker-side sizing for first-touch placement (see `conv_rows`).
    if patch.len() < PATCH_ROWS * k {
        patch.resize(PATCH_ROWS * k, 0);
    }
    if acc.len() < PATCH_ROWS * n {
        acc.resize(PATCH_ROWS * n, 0);
    }
    if q8.len() < PATCH_ROWS * n {
        q8.resize(PATCH_ROWS * n, 0);
    }
    if ep.pool().is_some() {
        tile.fill(i8::MIN);
    }
    let (oh, ow) = (s.oh(), s.ow());
    let img = s.h * s.w * s.c;
    let tile_row0 = row0;
    let mut done = 0usize;
    while done < rows {
        let take = PATCH_ROWS.min(rows - done);
        arp.clear();
        aen.clear();
        arp.push(0);
        for r in 0..take {
            let gr = row0 + done + r;
            let (bi, pix) = (gr / (oh * ow), gr % (oh * ow));
            patch_row_into(
                &xd[bi * img..(bi + 1) * img],
                s,
                pix / ow,
                pix % ow,
                &mut patch[r * k..(r + 1) * k],
            );
            for (kk, &v) in patch[r * k..(r + 1) * k].iter().enumerate() {
                if v != 0 {
                    aen.push((kk as u32, v as i32));
                }
            }
            arp.push(aen.len());
        }
        let acc_c = &mut acc[..take * n];
        acc_c.fill(0);
        kernel(arp, aen, acc_c);
        ep.apply_chunk(acc_c, row0 + done, n, q8, tile, tile_row0);
        done += take;
    }
}

/// Row-tile the fused-epilogue encoded conv across the worker pool — the
/// [`conv_tiled_encoded`] partition with the quantum-aligned i8 output
/// tiling of [`conv_tiled_ep`].
#[allow(clippy::too_many_arguments)]
fn conv_tiled_encoded_ep<K: Fn(&[usize], &[(u32, i32)], &mut [i32]) + Sync>(
    xd: &[i8],
    s: &ConvShape,
    out: &mut [i8],
    m: usize,
    k: usize,
    n: usize,
    par: Parallelism,
    ep: &Epilogue,
    scratch: &mut PatchScratch,
    kernel: K,
) {
    let threads = par.get().min(m);
    let (patches, ptrs, ents, accs, q8s) = scratch.take_encoded_ep(threads.max(1), k);
    if threads <= 1 {
        conv_rows_encoded_ep(
            xd,
            s,
            out,
            0,
            m,
            k,
            n,
            ep,
            &mut patches[0],
            &mut ptrs[0],
            &mut ents[0],
            &mut accs[0],
            &mut q8s[0],
            &kernel,
        );
        return;
    }
    let q = ep.row_quantum();
    let rows_per_tile = m.div_ceil(threads).div_ceil(q) * q;
    let out_per_tile = ep.out_rows(rows_per_tile);
    if out_per_tile == 0 {
        return;
    }
    let kref = &kernel;
    std::thread::scope(|sc| {
        for ((((((ti, tile), buf), arp), aen), acc), q8) in out
            .chunks_mut(out_per_tile * n)
            .enumerate()
            .zip(patches.iter_mut())
            .zip(ptrs.iter_mut())
            .zip(ents.iter_mut())
            .zip(accs.iter_mut())
            .zip(q8s.iter_mut())
        {
            let row0 = ti * rows_per_tile;
            let rows = rows_per_tile.min(m - row0);
            sc.spawn(move || {
                par.pin_worker(ti);
                conv_rows_encoded_ep(
                    xd, s, tile, row0, rows, k, n, ep, buf, arp, aen, acc, q8, kref,
                )
            });
        }
    });
}

/// Fused streaming convolution, dense INT8 weights: output
/// `[([b,] oh, ow, oc)]` INT32, bit-exact with
/// [`crate::gemm::conv::conv2d_direct`] per image, computed without ever
/// materializing the `[M×K]` IM2COL operand. `x` is `[h, w, c]` or
/// `[b, h, w, c]` NHWC; `w` is `[kh, kw, c, oc]` or `[K, oc]`.
pub fn conv2d_i8(x: &TensorI8, w: &TensorI8, s: &ConvShape, par: Parallelism) -> TensorI32 {
    conv2d_i8_with(x, w, s, par, &mut PatchScratch::new())
}

/// [`conv2d_i8`] drawing its per-worker row buffers from a caller-owned
/// [`PatchScratch`] (zero per-call buffer allocation in steady state).
pub fn conv2d_i8_with(
    x: &TensorI8,
    w: &TensorI8,
    s: &ConvShape,
    par: Parallelism,
    scratch: &mut PatchScratch,
) -> TensorI32 {
    conv2d_i8_gated_with(x, w, s, par, ZeroGate::Off, scratch)
}

/// [`conv2d_i8`] under a [`ZeroGate`] policy (transient scratch).
pub fn conv2d_i8_gated(
    x: &TensorI8,
    w: &TensorI8,
    s: &ConvShape,
    par: Parallelism,
    gate: ZeroGate,
) -> TensorI32 {
    conv2d_i8_gated_with(x, w, s, par, gate, &mut PatchScratch::new())
}

/// [`conv2d_i8_with`] under a [`ZeroGate`] policy: when the gate engages,
/// each generated patch-row chunk streams through the zero-gated row kernel
/// instead — zero activations (including every IM2COL padding zero the row
/// generator writes) skip their multiplies. `Auto` measures the *raw
/// feature map* once (O(H·W·C), far below the conv work); the IM2COL
/// operand's zero fraction is at least that (padding only adds zeros), so
/// `Auto` under-engages, never over-engages. Bit-exact with
/// [`conv2d_i8_with`] under every policy.
pub fn conv2d_i8_gated_with(
    x: &TensorI8,
    w: &TensorI8,
    s: &ConvShape,
    par: Parallelism,
    gate: ZeroGate,
    scratch: &mut PatchScratch,
) -> TensorI32 {
    let batch = batch_of(x, s);
    check_weights(w, s);
    let (k, n) = (s.gemm_k(), s.oc);
    let m = batch * s.gemm_m();
    let mut c = conv_output(x.shape().len() == 4, batch, s);
    if m == 0 || n == 0 {
        return c;
    }
    let (xd, wd) = (x.data(), w.data());
    if gate.resolve_with(|| x.sparsity()) {
        conv_tiled(xd, s, c.data_mut(), m, k, n, par, scratch, |patch, out| {
            crate::gemm::micro::dense_rows_i8_gated(patch, wd, out, 0, k, n)
        });
    } else {
        conv_tiled(xd, s, c.data_mut(), m, k, n, par, scratch, |patch, out| {
            crate::gemm::micro::dense_rows_i8(patch, wd, out, 0, k, n)
        });
    }
    c
}

/// [`conv2d_i8`] with the activation stream DBB-encoded ([`crate::gemm::ActPolicy::Encode`];
/// transient scratch): each worker encodes its generated patch-row chunks
/// right after streaming IM2COL and runs the joint A-DBB kernel against the
/// dense weight. Bit-exact with [`conv2d_i8`] — the chunk encoding is
/// lossless, padding zeros included.
pub fn conv2d_i8_encoded(x: &TensorI8, w: &TensorI8, s: &ConvShape, par: Parallelism) -> TensorI32 {
    conv2d_i8_encoded_with(x, w, s, par, &mut PatchScratch::new())
}

/// [`conv2d_i8_encoded`] drawing its per-worker patch and encode buffers
/// from a caller-owned [`PatchScratch`].
pub fn conv2d_i8_encoded_with(
    x: &TensorI8,
    w: &TensorI8,
    s: &ConvShape,
    par: Parallelism,
    scratch: &mut PatchScratch,
) -> TensorI32 {
    let batch = batch_of(x, s);
    check_weights(w, s);
    let (k, n) = (s.gemm_k(), s.oc);
    let m = batch * s.gemm_m();
    let mut c = conv_output(x.shape().len() == 4, batch, s);
    if m == 0 || n == 0 {
        return c;
    }
    let (xd, wd) = (x.data(), w.data());
    conv_tiled_encoded(xd, s, c.data_mut(), m, k, n, par, scratch, |arp, aen, out| {
        crate::gemm::micro::adbb_dense_rows_i8(arp, aen, wd, out, 0, n)
    });
    c
}

/// Fused streaming convolution over DBB-compressed weights (`w` encodes the
/// `[K, oc]` GEMM operand): the CSC decode happens once per call, every
/// worker reads it and generates its own patch rows. Bit-exact with
/// [`conv2d_i8`] on `w.decompress()`. Hot loops that reuse one weight
/// matrix should pack it once ([`DbbPacked::pack`]) and call
/// [`conv2d_dbb_i8_packed`] instead.
pub fn conv2d_dbb_i8(x: &TensorI8, w: &DbbMatrix, s: &ConvShape, par: Parallelism) -> TensorI32 {
    conv2d_dbb_i8_packed(x, &DbbPacked::pack(w), s, par)
}

/// [`conv2d_dbb_i8`] on a pre-decoded operand: zero per-call decode work,
/// bit-exact with the per-call-decoding path (identical stream into the
/// identical inner kernel).
pub fn conv2d_dbb_i8_packed(
    x: &TensorI8,
    w: &DbbPacked,
    s: &ConvShape,
    par: Parallelism,
) -> TensorI32 {
    conv2d_dbb_i8_packed_with(x, w, s, par, &mut PatchScratch::new())
}

/// [`conv2d_dbb_i8_packed`] drawing its per-worker row buffers from a
/// caller-owned [`PatchScratch`] — the fully prepared hot path: no encode,
/// no decode, no buffer allocation per call ([`crate::engine`] runs every
/// prepared conv layer through this entry point).
pub fn conv2d_dbb_i8_packed_with(
    x: &TensorI8,
    w: &DbbPacked,
    s: &ConvShape,
    par: Parallelism,
    scratch: &mut PatchScratch,
) -> TensorI32 {
    conv2d_dbb_i8_packed_gated_with(x, w, s, par, ZeroGate::Off, scratch)
}

/// [`conv2d_dbb_i8_packed`] under a [`ZeroGate`] policy (transient
/// scratch).
pub fn conv2d_dbb_i8_packed_gated(
    x: &TensorI8,
    w: &DbbPacked,
    s: &ConvShape,
    par: Parallelism,
    gate: ZeroGate,
) -> TensorI32 {
    conv2d_dbb_i8_packed_gated_with(x, w, s, par, gate, &mut PatchScratch::new())
}

/// [`conv2d_dbb_i8_packed_with`] under a [`ZeroGate`] policy — the fully
/// prepared *and* gated hot path: no encode, no decode, no per-call buffer
/// allocation, and zero activations skip their MACs (both operand
/// sparsities exploited at once, the paper's joint-sparsity claim in
/// software). `Auto` measures the raw feature map once; see
/// [`conv2d_i8_gated_with`] for why that is a safe under-estimate of the
/// IM2COL operand's zero fraction. Bit-exact with
/// [`conv2d_dbb_i8_packed_with`] under every policy.
pub fn conv2d_dbb_i8_packed_gated_with(
    x: &TensorI8,
    w: &DbbPacked,
    s: &ConvShape,
    par: Parallelism,
    gate: ZeroGate,
    scratch: &mut PatchScratch,
) -> TensorI32 {
    let batch = batch_of(x, s);
    assert_eq!(w.k, s.gemm_k(), "DBB weight K vs conv {s:?}");
    assert_eq!(w.n, s.oc, "DBB weight N vs conv oc");
    let (k, n) = (s.gemm_k(), s.oc);
    let m = batch * s.gemm_m();
    let mut c = conv_output(x.shape().len() == 4, batch, s);
    if m == 0 || n == 0 {
        return c;
    }
    let (cp, en) = (w.col_ptr(), w.entries());
    let xd = x.data();
    if gate.resolve_with(|| x.sparsity()) {
        conv_tiled(xd, s, c.data_mut(), m, k, n, par, scratch, |patch, out| {
            crate::gemm::micro::dbb_rows_i8_gated(patch, cp, en, out, 0, k, n)
        });
    } else {
        conv_tiled(xd, s, c.data_mut(), m, k, n, par, scratch, |patch, out| {
            crate::gemm::micro::dbb_rows_i8(patch, cp, en, out, 0, k, n)
        });
    }
    c
}

/// [`conv2d_dbb_i8_packed`] with the activation stream DBB-encoded as well
/// (transient scratch) — the **joint-sparse** fused conv: compressed
/// operands on both sides of the MAC, the S2TA formulation in software.
pub fn conv2d_dbb_i8_packed_encoded(
    x: &TensorI8,
    w: &DbbPacked,
    s: &ConvShape,
    par: Parallelism,
) -> TensorI32 {
    conv2d_dbb_i8_packed_encoded_with(x, w, s, par, &mut PatchScratch::new())
}

/// [`conv2d_dbb_i8_packed_encoded`] on a caller-owned [`PatchScratch`] —
/// the fully prepared joint-sparse hot path ([`crate::engine`] runs every
/// `Encode`-policy conv layer through this entry point): weights packed
/// once at prepare, activations encoded chunk-by-chunk at the IM2COL
/// expansion point, zeros on *either* side never reach the multiplier.
/// Bit-exact with [`conv2d_dbb_i8_packed_with`].
pub fn conv2d_dbb_i8_packed_encoded_with(
    x: &TensorI8,
    w: &DbbPacked,
    s: &ConvShape,
    par: Parallelism,
    scratch: &mut PatchScratch,
) -> TensorI32 {
    let batch = batch_of(x, s);
    assert_eq!(w.k, s.gemm_k(), "DBB weight K vs conv {s:?}");
    assert_eq!(w.n, s.oc, "DBB weight N vs conv oc");
    let (k, n) = (s.gemm_k(), s.oc);
    let m = batch * s.gemm_m();
    let mut c = conv_output(x.shape().len() == 4, batch, s);
    if m == 0 || n == 0 {
        return c;
    }
    let (cp, en) = (w.col_ptr(), w.entries());
    let xd = x.data();
    conv_tiled_encoded(xd, s, c.data_mut(), m, k, n, par, scratch, |arp, aen, out| {
        crate::gemm::act::adbb_rows_i8(arp, aen, cp, en, out, 0, n)
    });
    c
}

/// Fused BSR convolution on a pre-packed operand (transient scratch):
/// streaming IM2COL feeds the block-scheduler kernel
/// ([`crate::gemm::bsr`]) — absent weight blocks are skipped for every
/// generated patch row, surviving blocks run dense. Bit-exact with
/// [`conv2d_i8`] on the decompressed weights.
pub fn conv2d_bsr_i8_packed(
    x: &TensorI8,
    w: &BsrPacked,
    s: &ConvShape,
    par: Parallelism,
) -> TensorI32 {
    conv2d_bsr_i8_packed_with(x, w, s, par, &mut PatchScratch::new())
}

/// [`conv2d_bsr_i8_packed`] drawing its per-worker row buffers from a
/// caller-owned [`PatchScratch`] — the fully prepared BSR conv hot path
/// ([`crate::engine`] runs every BSR-format conv layer through here).
pub fn conv2d_bsr_i8_packed_with(
    x: &TensorI8,
    w: &BsrPacked,
    s: &ConvShape,
    par: Parallelism,
    scratch: &mut PatchScratch,
) -> TensorI32 {
    conv2d_bsr_i8_packed_gated_with(x, w, s, par, ZeroGate::Off, scratch)
}

/// [`conv2d_bsr_i8_packed`] under a [`ZeroGate`] policy (transient
/// scratch).
pub fn conv2d_bsr_i8_packed_gated(
    x: &TensorI8,
    w: &BsrPacked,
    s: &ConvShape,
    par: Parallelism,
    gate: ZeroGate,
) -> TensorI32 {
    conv2d_bsr_i8_packed_gated_with(x, w, s, par, gate, &mut PatchScratch::new())
}

/// [`conv2d_bsr_i8_packed_with`] under a [`ZeroGate`] policy: weight
/// zeros vanish at *block* granularity in the scheduler walk, activation
/// zeros at element granularity in the gated kernel. `Auto` measures the
/// raw feature map once (same safe under-estimate as
/// [`conv2d_i8_gated_with`]). Bit-exact with
/// [`conv2d_bsr_i8_packed_with`] under every policy.
pub fn conv2d_bsr_i8_packed_gated_with(
    x: &TensorI8,
    w: &BsrPacked,
    s: &ConvShape,
    par: Parallelism,
    gate: ZeroGate,
    scratch: &mut PatchScratch,
) -> TensorI32 {
    let batch = batch_of(x, s);
    assert_eq!(w.k, s.gemm_k(), "BSR weight K vs conv {s:?}");
    assert_eq!(w.n, s.oc, "BSR weight N vs conv oc");
    let (k, n) = (s.gemm_k(), s.oc);
    let m = batch * s.gemm_m();
    let mut c = conv_output(x.shape().len() == 4, batch, s);
    if m == 0 || n == 0 {
        return c;
    }
    let xd = x.data();
    if gate.resolve_with(|| x.sparsity()) {
        conv_tiled(xd, s, c.data_mut(), m, k, n, par, scratch, |patch, out| {
            crate::gemm::bsr::bsr_rows_i8_gated(patch, w, out, 0, k, n)
        });
    } else {
        conv_tiled(xd, s, c.data_mut(), m, k, n, par, scratch, |patch, out| {
            crate::gemm::bsr::bsr_rows_i8(patch, w, out, 0, k, n)
        });
    }
    c
}

/// [`conv2d_i8_gated`] with the layer epilogue fused into the output walk
/// (transient scratch, fresh output allocation): each worker requantizes
/// (+ ReLU, + 2×2/stride-2 max-pool when the epilogue pools) its freshly
/// accumulated chunk to i8 while cache-hot, so no whole-layer i32 tensor is
/// ever allocated. Output is `[([b,] oh, ow, oc)]` i8 — halved spatial grid
/// when pooling. Bit-exact with
/// `requant_relu`/`max_pool_2x2` staged on [`conv2d_i8`]'s i32 result when
/// the epilogue's shift matches (`rust/tests/epilogue.rs`).
pub fn conv2d_i8_ep(
    x: &TensorI8,
    w: &TensorI8,
    s: &ConvShape,
    par: Parallelism,
    gate: ZeroGate,
    ep: &Epilogue,
) -> TensorI8 {
    conv2d_i8_ep_with(x, w, s, par, gate, ep, &mut PatchScratch::new(), Vec::new())
}

/// [`conv2d_i8_ep`] on caller-owned [`PatchScratch`] and a recyclable
/// output backing `buf` (reused as the result's storage when its length
/// already matches — the engine's layer ping-pong).
#[allow(clippy::too_many_arguments)]
pub fn conv2d_i8_ep_with(
    x: &TensorI8,
    w: &TensorI8,
    s: &ConvShape,
    par: Parallelism,
    gate: ZeroGate,
    ep: &Epilogue,
    scratch: &mut PatchScratch,
    buf: Vec<i8>,
) -> TensorI8 {
    let batch = batch_of(x, s);
    check_weights(w, s);
    check_pool(ep, s);
    let (k, n) = (s.gemm_k(), s.oc);
    let m = batch * s.gemm_m();
    let mut c = conv_output_ep(x.shape().len() == 4, batch, s, ep, buf);
    if m == 0 || n == 0 || ep.out_rows(m) == 0 {
        return c;
    }
    let (xd, wd) = (x.data(), w.data());
    if gate.resolve_with(|| x.sparsity()) {
        conv_tiled_ep(xd, s, c.data_mut(), m, k, n, par, ep, scratch, |patch, out| {
            crate::gemm::micro::dense_rows_i8_gated(patch, wd, out, 0, k, n)
        });
    } else {
        conv_tiled_ep(xd, s, c.data_mut(), m, k, n, par, ep, scratch, |patch, out| {
            crate::gemm::micro::dense_rows_i8(patch, wd, out, 0, k, n)
        });
    }
    c
}

/// [`conv2d_i8_encoded`] with the layer epilogue fused into the output walk
/// (transient scratch).
pub fn conv2d_i8_encoded_ep(
    x: &TensorI8,
    w: &TensorI8,
    s: &ConvShape,
    par: Parallelism,
    ep: &Epilogue,
) -> TensorI8 {
    conv2d_i8_encoded_ep_with(x, w, s, par, ep, &mut PatchScratch::new(), Vec::new())
}

/// [`conv2d_i8_encoded_ep`] on caller-owned scratch + recyclable output
/// backing.
pub fn conv2d_i8_encoded_ep_with(
    x: &TensorI8,
    w: &TensorI8,
    s: &ConvShape,
    par: Parallelism,
    ep: &Epilogue,
    scratch: &mut PatchScratch,
    buf: Vec<i8>,
) -> TensorI8 {
    let batch = batch_of(x, s);
    check_weights(w, s);
    check_pool(ep, s);
    let (k, n) = (s.gemm_k(), s.oc);
    let m = batch * s.gemm_m();
    let mut c = conv_output_ep(x.shape().len() == 4, batch, s, ep, buf);
    if m == 0 || n == 0 || ep.out_rows(m) == 0 {
        return c;
    }
    let (xd, wd) = (x.data(), w.data());
    conv_tiled_encoded_ep(xd, s, c.data_mut(), m, k, n, par, ep, scratch, |arp, aen, out| {
        crate::gemm::micro::adbb_dense_rows_i8(arp, aen, wd, out, 0, n)
    });
    c
}

/// [`conv2d_dbb_i8_packed_gated`] with the layer epilogue fused into the
/// output walk (transient scratch).
pub fn conv2d_dbb_i8_packed_ep(
    x: &TensorI8,
    w: &DbbPacked,
    s: &ConvShape,
    par: Parallelism,
    gate: ZeroGate,
    ep: &Epilogue,
) -> TensorI8 {
    conv2d_dbb_i8_packed_ep_with(x, w, s, par, gate, ep, &mut PatchScratch::new(), Vec::new())
}

/// [`conv2d_dbb_i8_packed_ep`] on caller-owned scratch + recyclable output
/// backing — the engine's fused-epilogue hot path for DBB conv layers.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_dbb_i8_packed_ep_with(
    x: &TensorI8,
    w: &DbbPacked,
    s: &ConvShape,
    par: Parallelism,
    gate: ZeroGate,
    ep: &Epilogue,
    scratch: &mut PatchScratch,
    buf: Vec<i8>,
) -> TensorI8 {
    let batch = batch_of(x, s);
    assert_eq!(w.k, s.gemm_k(), "DBB weight K vs conv {s:?}");
    assert_eq!(w.n, s.oc, "DBB weight N vs conv oc");
    check_pool(ep, s);
    let (k, n) = (s.gemm_k(), s.oc);
    let m = batch * s.gemm_m();
    let mut c = conv_output_ep(x.shape().len() == 4, batch, s, ep, buf);
    if m == 0 || n == 0 || ep.out_rows(m) == 0 {
        return c;
    }
    let (cp, en) = (w.col_ptr(), w.entries());
    let xd = x.data();
    if gate.resolve_with(|| x.sparsity()) {
        conv_tiled_ep(xd, s, c.data_mut(), m, k, n, par, ep, scratch, |patch, out| {
            crate::gemm::micro::dbb_rows_i8_gated(patch, cp, en, out, 0, k, n)
        });
    } else {
        conv_tiled_ep(xd, s, c.data_mut(), m, k, n, par, ep, scratch, |patch, out| {
            crate::gemm::micro::dbb_rows_i8(patch, cp, en, out, 0, k, n)
        });
    }
    c
}

/// [`conv2d_dbb_i8_packed_encoded`] with the layer epilogue fused into the
/// output walk (transient scratch) — joint-sparse conv + requantize + ReLU
/// + pool in one streaming pass.
pub fn conv2d_dbb_i8_packed_encoded_ep(
    x: &TensorI8,
    w: &DbbPacked,
    s: &ConvShape,
    par: Parallelism,
    ep: &Epilogue,
) -> TensorI8 {
    conv2d_dbb_i8_packed_encoded_ep_with(x, w, s, par, ep, &mut PatchScratch::new(), Vec::new())
}

/// [`conv2d_dbb_i8_packed_encoded_ep`] on caller-owned scratch + recyclable
/// output backing — the engine's fused-epilogue hot path for
/// `Encode`-policy conv layers.
pub fn conv2d_dbb_i8_packed_encoded_ep_with(
    x: &TensorI8,
    w: &DbbPacked,
    s: &ConvShape,
    par: Parallelism,
    ep: &Epilogue,
    scratch: &mut PatchScratch,
    buf: Vec<i8>,
) -> TensorI8 {
    let batch = batch_of(x, s);
    assert_eq!(w.k, s.gemm_k(), "DBB weight K vs conv {s:?}");
    assert_eq!(w.n, s.oc, "DBB weight N vs conv oc");
    check_pool(ep, s);
    let (k, n) = (s.gemm_k(), s.oc);
    let m = batch * s.gemm_m();
    let mut c = conv_output_ep(x.shape().len() == 4, batch, s, ep, buf);
    if m == 0 || n == 0 || ep.out_rows(m) == 0 {
        return c;
    }
    let (cp, en) = (w.col_ptr(), w.entries());
    let xd = x.data();
    conv_tiled_encoded_ep(xd, s, c.data_mut(), m, k, n, par, ep, scratch, |arp, aen, out| {
        crate::gemm::act::adbb_rows_i8(arp, aen, cp, en, out, 0, n)
    });
    c
}

/// [`conv2d_bsr_i8_packed_gated`] with the layer epilogue fused into the
/// output walk (transient scratch).
pub fn conv2d_bsr_i8_packed_ep(
    x: &TensorI8,
    w: &BsrPacked,
    s: &ConvShape,
    par: Parallelism,
    gate: ZeroGate,
    ep: &Epilogue,
) -> TensorI8 {
    conv2d_bsr_i8_packed_ep_with(x, w, s, par, gate, ep, &mut PatchScratch::new(), Vec::new())
}

/// [`conv2d_bsr_i8_packed_ep`] on caller-owned scratch + recyclable output
/// backing — the engine's fused-epilogue hot path for BSR conv layers.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_bsr_i8_packed_ep_with(
    x: &TensorI8,
    w: &BsrPacked,
    s: &ConvShape,
    par: Parallelism,
    gate: ZeroGate,
    ep: &Epilogue,
    scratch: &mut PatchScratch,
    buf: Vec<i8>,
) -> TensorI8 {
    let batch = batch_of(x, s);
    assert_eq!(w.k, s.gemm_k(), "BSR weight K vs conv {s:?}");
    assert_eq!(w.n, s.oc, "BSR weight N vs conv oc");
    check_pool(ep, s);
    let (k, n) = (s.gemm_k(), s.oc);
    let m = batch * s.gemm_m();
    let mut c = conv_output_ep(x.shape().len() == 4, batch, s, ep, buf);
    if m == 0 || n == 0 || ep.out_rows(m) == 0 {
        return c;
    }
    let xd = x.data();
    if gate.resolve_with(|| x.sparsity()) {
        conv_tiled_ep(xd, s, c.data_mut(), m, k, n, par, ep, scratch, |patch, out| {
            crate::gemm::bsr::bsr_rows_i8_gated(patch, w, out, 0, k, n)
        });
    } else {
        conv_tiled_ep(xd, s, c.data_mut(), m, k, n, par, ep, scratch, |patch, out| {
            crate::gemm::bsr::bsr_rows_i8(patch, w, out, 0, k, n)
        });
    }
    c
}

/// Fused f32 convolution forward for the training substrate: returns the
/// GEMM-layout result `[b·oh·ow, oc]`, **bit-exact** with
/// `matmul(im2col_f32(x), w)` — each generated row runs the identical
/// zero-skipping `ikj` inner loop, so the f32 accumulation order is
/// unchanged — while the `[M×K]` patch matrix is never stored. `w` is the
/// train-layout `[K, oc]` weight.
pub fn conv2d_f32(x: &TensorF32, w: &TensorF32, s: &ConvShape) -> TensorF32 {
    let batch = batch_of(x, s);
    let (k, n) = (s.gemm_k(), s.oc);
    assert_eq!(w.shape(), [k, n], "train conv weight is [K, oc]");
    let (oh, ow) = (s.oh(), s.ow());
    let m = batch * oh * ow;
    let mut c = vec![0f32; m * n];
    let (xd, wd) = (x.data(), w.data());
    let img = s.h * s.w * s.c;
    let mut row = vec![0f32; k];
    for gr in 0..m {
        let (bi, pix) = (gr / (oh * ow), gr % (oh * ow));
        patch_row_into(&xd[bi * img..(bi + 1) * img], s, pix / ow, pix % ow, &mut row);
        let crow = &mut c[gr * n..(gr + 1) * n];
        for (kk, &av) in row.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &wd[kk * n..(kk + 1) * n];
            for (cv, bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
    TensorF32::from_vec(&[m, n], c)
}

/// Streaming weight gradient for the f32 train path:
/// `dW[K, oc] = Σ_pixels patch_row ⊗ dy_row`, **bit-exact** with
/// `matmul_tn(im2col_f32(x), dy)` (same pixel-major accumulation order),
/// regenerating each patch row instead of reading a stored `[M×K]` matrix —
/// which is why [`crate::train::layers::Conv2d`] only has to retain the raw
/// input between forward and backward.
pub fn conv2d_dw_f32(x: &TensorF32, dy: &TensorF32, s: &ConvShape) -> TensorF32 {
    let batch = batch_of(x, s);
    let (k, n) = (s.gemm_k(), s.oc);
    let (oh, ow) = (s.oh(), s.ow());
    let m = batch * oh * ow;
    assert_eq!(dy.shape(), [m, n], "dy is [b·oh·ow, oc]");
    let mut c = vec![0f32; k * n];
    let (xd, dyd) = (x.data(), dy.data());
    let img = s.h * s.w * s.c;
    let mut row = vec![0f32; k];
    for gr in 0..m {
        let (bi, pix) = (gr / (oh * ow), gr % (oh * ow));
        patch_row_into(&xd[bi * img..(bi + 1) * img], s, pix / ow, pix % ow, &mut row);
        let brow = &dyd[gr * n..(gr + 1) * n];
        for (i, &av) in row.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let crow = &mut c[i * n..(i + 1) * n];
            for (cv, bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
    TensorF32::from_vec(&[k, n], c)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dbb::prune::prune_i8;
    use crate::gemm;
    use crate::gemm::conv::{conv2d_direct, im2col, weights_to_gemm};
    use crate::train::linalg::{im2col_f32, matmul, matmul_tn, Conv2dShape};
    use crate::util::prop::{check, Config};
    use crate::util::Rng;

    fn rand_shape(rng: &mut Rng) -> ConvShape {
        let kh = [1usize, 3, 5][rng.below(3)];
        let stride = rng.below(2) + 1;
        let pad = rng.below(kh.div_ceil(2));
        ConvShape {
            h: kh + rng.below(6) + stride,
            w: kh + rng.below(6) + stride,
            c: rng.below(8) + 1,
            kh,
            kw: kh,
            oc: rng.below(8) + 1,
            stride,
            pad,
        }
    }

    #[test]
    fn fused_matches_direct_prop() {
        check(Config::default().cases(64), |rng| {
            let s = rand_shape(rng);
            let threads = rng.below(8) + 1;
            let x = TensorI8::rand(&[s.h, s.w, s.c], rng);
            let w = TensorI8::rand(&[s.kh, s.kw, s.c, s.oc], rng);
            let direct = conv2d_direct(&x, &w, &s);
            let fused = conv2d_i8(&x, &w, &s, Parallelism::threads(threads));
            assert_eq!(fused.shape(), direct.shape());
            assert_eq!(fused.data(), direct.data(), "shape={s:?} threads={threads}");
        });
    }

    #[test]
    fn batch_folds_into_m() {
        // [b,h,w,c] input == per-image direct conv, concatenated
        let mut rng = Rng::new(5);
        let s = ConvShape { h: 6, w: 5, c: 3, kh: 3, kw: 3, oc: 4, stride: 1, pad: 1 };
        let b = 3usize;
        let x = TensorI8::rand(&[b, s.h, s.w, s.c], &mut rng);
        let w = TensorI8::rand(&[s.kh, s.kw, s.c, s.oc], &mut rng);
        let fused = conv2d_i8(&x, &w, &s, Parallelism::threads(4));
        assert_eq!(fused.shape(), &[b, s.oh(), s.ow(), s.oc]);
        let img = s.h * s.w * s.c;
        let out = s.oh() * s.ow() * s.oc;
        for bi in 0..b {
            let xi = TensorI8::from_vec(
                &[s.h, s.w, s.c],
                x.data()[bi * img..(bi + 1) * img].to_vec(),
            );
            let di = conv2d_direct(&xi, &w, &s);
            assert_eq!(&fused.data()[bi * out..(bi + 1) * out], di.data(), "image {bi}");
        }
    }

    #[test]
    fn fused_dbb_matches_materialized_dbb_prop() {
        check(Config::default().cases(48), |rng| {
            let s = rand_shape(rng);
            let bz = 8usize;
            let nnz = rng.below(bz) + 1; // DBB bounds 1..=BZ
            let threads = rng.below(8) + 1;
            let x = TensorI8::rand_sparse(&[s.h, s.w, s.c], 0.3, rng);
            let wd = prune_i8(&TensorI8::rand(&[s.gemm_k(), s.oc], rng), bz, nnz);
            let enc = crate::dbb::DbbMatrix::compress(&wd, bz).unwrap();
            let a = im2col(&x, &s);
            let want = gemm::dbb_i8(&a, &enc);
            let got = conv2d_dbb_i8(&x, &enc, &s, Parallelism::threads(threads));
            assert_eq!(got.data(), want.data(), "shape={s:?} nnz={nnz} threads={threads}");
        });
    }

    #[test]
    fn packed_conv_equals_per_call_decode_prop() {
        // one shared scratch across every case: buffer reuse over varying
        // shapes/K must never change a bit
        let scratch = std::cell::RefCell::new(PatchScratch::new());
        check(Config::default().cases(48), |rng| {
            let s = rand_shape(rng);
            let bz = 8usize;
            let nnz = rng.below(bz) + 1;
            let threads = rng.below(8) + 1;
            let x = TensorI8::rand_sparse(&[s.h, s.w, s.c], 0.3, rng);
            let w = crate::dbb::DbbMatrix::compress_topk(
                &TensorI8::rand(&[s.gemm_k(), s.oc], rng),
                bz,
                nnz,
            )
            .unwrap();
            let packed = DbbPacked::pack(&w);
            let want = conv2d_dbb_i8(&x, &w, &s, Parallelism::threads(threads));
            let got = conv2d_dbb_i8_packed_with(
                &x,
                &packed,
                &s,
                Parallelism::threads(threads),
                &mut scratch.borrow_mut(),
            );
            assert_eq!(got.data(), want.data(), "shape={s:?} nnz={nnz} threads={threads}");
        });
    }

    #[test]
    fn gated_conv_bit_exact_prop() {
        check(Config::default().cases(48), |rng| {
            let s = rand_shape(rng);
            let threads = rng.below(8) + 1;
            let p_zero = [0.0f32, 0.5, 1.0][rng.below(3)];
            let gate = [ZeroGate::Off, ZeroGate::Auto, ZeroGate::On][rng.below(3)];
            let x = TensorI8::rand_sparse(&[s.h, s.w, s.c], p_zero, rng);
            let w = TensorI8::rand(&[s.kh, s.kw, s.c, s.oc], rng);
            let par = Parallelism::threads(threads);
            assert_eq!(
                conv2d_i8_gated(&x, &w, &s, par, gate).data(),
                conv2d_i8(&x, &w, &s, par).data(),
                "shape={s:?} threads={threads} p={p_zero} gate={gate:?}"
            );
            let wg = crate::dbb::DbbMatrix::compress_topk(
                &TensorI8::rand(&[s.gemm_k(), s.oc], rng),
                8,
                rng.below(8) + 1,
            )
            .unwrap();
            let packed = DbbPacked::pack(&wg);
            assert_eq!(
                conv2d_dbb_i8_packed_gated(&x, &packed, &s, par, gate).data(),
                conv2d_dbb_i8_packed(&x, &packed, &s, par).data(),
                "dbb shape={s:?} threads={threads} p={p_zero} gate={gate:?}"
            );
        });
    }

    #[test]
    fn encoded_conv_bit_exact_prop() {
        // chunk-encoded A (incl. the IM2COL padding zeros) vs the plain
        // fused path, dense and DBB weights, one shared scratch throughout
        let scratch = std::cell::RefCell::new(PatchScratch::new());
        check(Config::default().cases(48), |rng| {
            let s = rand_shape(rng);
            let threads = rng.below(8) + 1;
            let p_zero = [0.0f32, 0.5, 1.0][rng.below(3)];
            let par = Parallelism::threads(threads);
            let x = TensorI8::rand_sparse(&[s.h, s.w, s.c], p_zero, rng);
            let w = TensorI8::rand(&[s.kh, s.kw, s.c, s.oc], rng);
            assert_eq!(
                conv2d_i8_encoded_with(&x, &w, &s, par, &mut scratch.borrow_mut()).data(),
                conv2d_i8(&x, &w, &s, par).data(),
                "dense shape={s:?} threads={threads} p={p_zero}"
            );
            let wc = crate::dbb::DbbMatrix::compress_topk(
                &TensorI8::rand(&[s.gemm_k(), s.oc], rng),
                8,
                rng.below(8) + 1,
            )
            .unwrap();
            let packed = DbbPacked::pack(&wc);
            assert_eq!(
                conv2d_dbb_i8_packed_encoded_with(&x, &packed, &s, par, &mut scratch.borrow_mut())
                    .data(),
                conv2d_dbb_i8_packed(&x, &packed, &s, par).data(),
                "dbb shape={s:?} threads={threads} p={p_zero}"
            );
        });
    }

    #[test]
    fn fused_epilogue_conv_equals_staged_oracle_prop() {
        use crate::gemm::epilogue::{max_pool_2x2, requant_shift, requant_with_shift};
        use crate::gemm::{PoolGeom, Requant};
        let scratch = std::cell::RefCell::new(PatchScratch::new());
        check(Config::default().cases(48), |rng| {
            let s = rand_shape(rng);
            let b = rng.below(3) + 1;
            let threads = rng.below(8) + 1;
            let par = Parallelism::threads(threads);
            let relu = rng.below(2) == 1;
            let x = TensorI8::rand_sparse(&[b, s.h, s.w, s.c], 0.5, rng);
            let w = TensorI8::rand(&[s.kh, s.kw, s.c, s.oc], rng);
            let acc = conv2d_i8(&x, &w, &s, par);
            let shift = requant_shift(acc.data());
            let staged = requant_with_shift(&acc, shift, relu);
            let ep = Epilogue::new(Requant::Global(shift), relu);
            assert_eq!(
                conv2d_i8_ep(&x, &w, &s, par, ZeroGate::Auto, &ep).data(),
                staged.data(),
                "dense shape={s:?} b={b} threads={threads} relu={relu}"
            );
            assert_eq!(
                conv2d_i8_encoded_ep_with(
                    &x,
                    &w,
                    &s,
                    par,
                    &ep,
                    &mut scratch.borrow_mut(),
                    Vec::new()
                )
                .data(),
                staged.data(),
                "encoded shape={s:?} b={b} threads={threads} relu={relu}"
            );
            if s.oh() >= 2 && s.ow() >= 2 {
                let epp = Epilogue::new(Requant::Global(shift), relu)
                    .with_pool(PoolGeom { oh: s.oh(), ow: s.ow() });
                let pooled = max_pool_2x2(&staged, s.oh(), s.ow(), s.oc);
                let got = conv2d_i8_ep(&x, &w, &s, par, ZeroGate::Off, &epp);
                assert_eq!(got.shape(), [b, s.oh() / 2, s.ow() / 2, s.oc]);
                assert_eq!(
                    got.data(),
                    pooled.data(),
                    "pooled shape={s:?} b={b} threads={threads} relu={relu}"
                );
            }
            let wc = crate::dbb::DbbMatrix::compress_topk(
                &TensorI8::rand(&[s.gemm_k(), s.oc], rng),
                8,
                rng.below(8) + 1,
            )
            .unwrap();
            let packed = DbbPacked::pack(&wc);
            let dacc = conv2d_dbb_i8_packed(&x, &packed, &s, par);
            let dshift = requant_shift(dacc.data());
            let dstaged = requant_with_shift(&dacc, dshift, relu);
            let dep = Epilogue::new(Requant::Global(dshift), relu);
            assert_eq!(
                conv2d_dbb_i8_packed_ep(&x, &packed, &s, par, ZeroGate::Auto, &dep).data(),
                dstaged.data(),
                "dbb shape={s:?} b={b} threads={threads} relu={relu}"
            );
            assert_eq!(
                conv2d_dbb_i8_packed_encoded_ep_with(
                    &x,
                    &packed,
                    &s,
                    par,
                    &dep,
                    &mut scratch.borrow_mut(),
                    Vec::new()
                )
                .data(),
                dstaged.data(),
                "dbb-encoded shape={s:?} b={b} threads={threads} relu={relu}"
            );
        });
    }

    #[test]
    fn encoded_conv_batch_folds_into_m() {
        let mut rng = Rng::new(13);
        let s = ConvShape { h: 6, w: 5, c: 3, kh: 3, kw: 3, oc: 4, stride: 1, pad: 1 };
        let x = TensorI8::rand_sparse(&[3, s.h, s.w, s.c], 0.6, &mut rng);
        let w = TensorI8::rand(&[s.kh, s.kw, s.c, s.oc], &mut rng);
        assert_eq!(
            conv2d_i8_encoded(&x, &w, &s, Parallelism::threads(4)).data(),
            conv2d_i8(&x, &w, &s, Parallelism::threads(4)).data()
        );
    }

    #[test]
    fn serial_and_parallel_identical() {
        let mut rng = Rng::new(9);
        let s = ConvShape { h: 9, w: 9, c: 4, kh: 3, kw: 3, oc: 5, stride: 2, pad: 1 };
        let x = TensorI8::rand(&[s.h, s.w, s.c], &mut rng);
        let w = TensorI8::rand(&[s.kh, s.kw, s.c, s.oc], &mut rng);
        assert_eq!(
            conv2d_i8(&x, &w, &s, Parallelism::serial()).data(),
            conv2d_i8(&x, &w, &s, Parallelism::threads(7)).data()
        );
    }

    #[test]
    fn gemm_layout_weights_accepted() {
        let mut rng = Rng::new(10);
        let s = ConvShape { h: 5, w: 5, c: 2, kh: 3, kw: 3, oc: 3, stride: 1, pad: 0 };
        let w = TensorI8::rand(&[s.kh, s.kw, s.c, s.oc], &mut rng);
        let x = TensorI8::rand(&[s.h, s.w, s.c], &mut rng);
        let wg = weights_to_gemm(&w, &s);
        assert_eq!(
            conv2d_i8(&x, &w, &s, Parallelism::serial()).data(),
            conv2d_i8(&x, &wg, &s, Parallelism::serial()).data()
        );
    }

    #[test]
    fn f32_forward_bit_exact_with_materialized_path() {
        check(Config::default().cases(32), |rng| {
            let s = rand_shape(rng);
            let b = rng.below(3) + 1;
            let mut frng = Rng::new(rng.next_u64());
            let x = TensorF32::randn(&[b, s.h, s.w, s.c], 1.0, &mut frng);
            let w = TensorF32::randn(&[s.gemm_k(), s.oc], 0.5, &mut frng);
            let cs = Conv2dShape {
                h: s.h,
                w: s.w,
                c: s.c,
                k: s.kh,
                oc: s.oc,
                stride: s.stride,
                pad: s.pad,
            };
            let want = matmul(&im2col_f32(&x, &cs), &w);
            let got = conv2d_f32(&x, &w, &s);
            assert_eq!(got.shape(), want.shape());
            for (g, t) in got.data().iter().zip(want.data()) {
                assert_eq!(g.to_bits(), t.to_bits(), "shape={s:?}");
            }
        });
    }

    #[test]
    fn f32_weight_grad_bit_exact_with_materialized_path() {
        check(Config::default().cases(32), |rng| {
            let s = rand_shape(rng);
            let b = rng.below(2) + 1;
            let mut frng = Rng::new(rng.next_u64());
            let x = TensorF32::randn(&[b, s.h, s.w, s.c], 1.0, &mut frng);
            let m = b * s.gemm_m();
            let dy = TensorF32::randn(&[m, s.oc], 1.0, &mut frng);
            let cs = Conv2dShape {
                h: s.h,
                w: s.w,
                c: s.c,
                k: s.kh,
                oc: s.oc,
                stride: s.stride,
                pad: s.pad,
            };
            let want = matmul_tn(&im2col_f32(&x, &cs), &dy);
            let got = conv2d_dw_f32(&x, &dy, &s);
            for (g, t) in got.data().iter().zip(want.data()) {
                assert_eq!(g.to_bits(), t.to_bits(), "shape={s:?}");
            }
        });
    }

    #[test]
    fn peak_operand_is_tile_not_matrix() {
        let s = ConvShape { h: 56, w: 56, c: 64, kh: 3, kw: 3, oc: 64, stride: 1, pad: 1 };
        let fused = peak_operand_bytes(&s, Parallelism::threads(8));
        let materialized = s.gemm_m() * s.gemm_k();
        assert_eq!(fused, 8 * PATCH_ROWS * s.gemm_k());
        assert!(fused * 10 < materialized, "{fused} vs {materialized}");
    }
}
