//! f32 GEMM + im2col primitives for the training substrate.
//!
//! Scalar `ikj`-ordered matmul (cache-friendly, autovectorizes well) — the
//! training workloads here are small synthetic-dataset models (Tables I–II),
//! not production training.

use crate::tensor::TensorF32;

/// `C[M,N] = A[M,K] · B[K,N]`.
pub fn matmul(a: &TensorF32, b: &TensorF32) -> TensorF32 {
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, k2, "GEMM inner dim: {k} vs {k2}");
    let mut c = vec![0f32; m * n];
    let ad = a.data();
    let bd = b.data();
    for i in 0..m {
        let crow = &mut c[i * n..(i + 1) * n];
        for kk in 0..k {
            let av = ad[i * k + kk];
            if av == 0.0 {
                continue;
            }
            let brow = &bd[kk * n..(kk + 1) * n];
            for (cv, bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
    TensorF32::from_vec(&[m, n], c)
}

/// `C[M,N] = Aᵀ[M,K]ᵀ… ` — precisely: `C = Aᵀ·B` with `A[K,M]`, `B[K,N]`.
pub fn matmul_tn(a: &TensorF32, b: &TensorF32) -> TensorF32 {
    let (k, m) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, k2);
    let mut c = vec![0f32; m * n];
    let ad = a.data();
    let bd = b.data();
    for kk in 0..k {
        let arow = &ad[kk * m..(kk + 1) * m];
        let brow = &bd[kk * n..(kk + 1) * n];
        for (i, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let crow = &mut c[i * n..(i + 1) * n];
            for (cv, bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
    TensorF32::from_vec(&[m, n], c)
}

/// `C[M,N] = A[M,K] · Bᵀ` with `B[N,K]`.
pub fn matmul_nt(a: &TensorF32, b: &TensorF32) -> TensorF32 {
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (n, k2) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, k2);
    let mut c = vec![0f32; m * n];
    let ad = a.data();
    let bd = b.data();
    for i in 0..m {
        let arow = &ad[i * k..(i + 1) * k];
        for j in 0..n {
            let brow = &bd[j * k..(j + 1) * k];
            let mut acc = 0f32;
            for (av, bv) in arow.iter().zip(brow) {
                acc += av * bv;
            }
            c[i * n + j] = acc;
        }
    }
    TensorF32::from_vec(&[m, n], c)
}

/// Conv geometry for the training layers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Conv2dShape {
    /// Input height/width/channels.
    pub h: usize,
    /// Input width.
    pub w: usize,
    /// Input channels.
    pub c: usize,
    /// Kernel size (square).
    pub k: usize,
    /// Output channels.
    pub oc: usize,
    /// Stride.
    pub stride: usize,
    /// Zero padding.
    pub pad: usize,
}

impl Conv2dShape {
    /// Output height.
    pub fn oh(&self) -> usize {
        (self.h + 2 * self.pad - self.k) / self.stride + 1
    }

    /// Output width.
    pub fn ow(&self) -> usize {
        (self.w + 2 * self.pad - self.k) / self.stride + 1
    }

    /// GEMM reduction dim (patch size), channel-fastest layout `(kh, kw, c)`
    /// so DBB blocks along K group channels at one tap — the paper's
    /// depthwise blocking (Fig. 2).
    pub fn gemm_k(&self) -> usize {
        self.k * self.k * self.c
    }

    /// The crate-wide [`crate::gemm::conv::ConvShape`] view of this
    /// geometry (square kernel) — what the fused streaming engine consumes.
    pub fn as_conv(&self) -> crate::gemm::conv::ConvShape {
        crate::gemm::conv::ConvShape {
            h: self.h,
            w: self.w,
            c: self.c,
            kh: self.k,
            kw: self.k,
            oc: self.oc,
            stride: self.stride,
            pad: self.pad,
        }
    }
}

/// IM2COL for a batched `[B, H, W, C]` f32 tensor → `[B·OH·OW, K·K·C]`.
///
/// Since the fused engine landed this materializing lowering is the *test
/// oracle* for the train path — [`crate::train::layers::Conv2d`] runs on
/// [`crate::gemm::fused::conv2d_f32`], which is bit-exact with
/// `matmul(im2col_f32(x), w)` without ever storing the patch matrix.
pub fn im2col_f32(x: &TensorF32, s: &Conv2dShape) -> TensorF32 {
    let b = x.shape()[0];
    let (oh, ow, kk) = (s.oh(), s.ow(), s.gemm_k());
    let mut out = vec![0f32; b * oh * ow * kk];
    let xd = x.data();
    let (h, w, c) = (s.h, s.w, s.c);
    for bi in 0..b {
        let xoff = bi * h * w * c;
        for oy in 0..oh {
            for ox in 0..ow {
                let row = ((bi * oh + oy) * ow + ox) * kk;
                for ky in 0..s.k {
                    let iy = (oy * s.stride + ky) as isize - s.pad as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kx in 0..s.k {
                        let ix = (ox * s.stride + kx) as isize - s.pad as isize;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        let src = xoff + ((iy as usize) * w + ix as usize) * c;
                        let dst = row + (ky * s.k + kx) * c;
                        out[dst..dst + c].copy_from_slice(&xd[src..src + c]);
                    }
                }
            }
        }
    }
    TensorF32::from_vec(&[b * oh * ow, kk], out)
}

/// COL2IM: scatter-add patch-space gradients back to `[B, H, W, C]`.
pub fn col2im_f32(cols: &TensorF32, s: &Conv2dShape, b: usize) -> TensorF32 {
    let (oh, ow, kk) = (s.oh(), s.ow(), s.gemm_k());
    assert_eq!(cols.shape(), &[b * oh * ow, kk]);
    let (h, w, c) = (s.h, s.w, s.c);
    let mut out = vec![0f32; b * h * w * c];
    let cd = cols.data();
    for bi in 0..b {
        let xoff = bi * h * w * c;
        for oy in 0..oh {
            for ox in 0..ow {
                let row = ((bi * oh + oy) * ow + ox) * kk;
                for ky in 0..s.k {
                    let iy = (oy * s.stride + ky) as isize - s.pad as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kx in 0..s.k {
                        let ix = (ox * s.stride + kx) as isize - s.pad as isize;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        let dst = xoff + ((iy as usize) * w + ix as usize) * c;
                        let src = row + (ky * s.k + kx) * c;
                        for ci in 0..c {
                            out[dst + ci] += cd[src + ci];
                        }
                    }
                }
            }
        }
    }
    TensorF32::from_vec(&[b, h, w, c], out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn matmul_small_golden() {
        let a = TensorF32::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let b = TensorF32::from_vec(&[3, 2], vec![7., 8., 9., 10., 11., 12.]);
        let c = matmul(&a, &b);
        assert_eq!(c.data(), &[58., 64., 139., 154.]);
    }

    #[test]
    fn transposed_variants_agree() {
        let mut rng = Rng::new(9);
        let a = TensorF32::randn(&[7, 5], 1.0, &mut rng);
        let b = TensorF32::randn(&[5, 6], 1.0, &mut rng);
        let c = matmul(&a, &b);
        // A = (Aᵀ)ᵀ: matmul_tn(Aᵀ, B) == A·B
        let mut at = TensorF32::zeros(&[5, 7]);
        for i in 0..7 {
            for j in 0..5 {
                at.set(&[j, i], a.at(&[i, j]));
            }
        }
        let c2 = matmul_tn(&at, &b);
        for (x, y) in c.data().iter().zip(c2.data()) {
            assert!((x - y).abs() < 1e-4);
        }
        // matmul_nt(A, Bᵀ) == A·B
        let mut bt = TensorF32::zeros(&[6, 5]);
        for i in 0..5 {
            for j in 0..6 {
                bt.set(&[j, i], b.at(&[i, j]));
            }
        }
        let c3 = matmul_nt(&a, &bt);
        for (x, y) in c.data().iter().zip(c3.data()) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn im2col_col2im_adjoint() {
        // <im2col(x), y> == <x, col2im(y)> — the operators are adjoint,
        // which is exactly what correct conv backprop requires.
        let mut rng = Rng::new(3);
        let s = Conv2dShape { h: 6, w: 5, c: 2, k: 3, oc: 4, stride: 1, pad: 1 };
        let x = TensorF32::randn(&[2, 6, 5, 2], 1.0, &mut rng);
        let y = TensorF32::randn(&[2 * s.oh() * s.ow(), s.gemm_k()], 1.0, &mut rng);
        let ax = im2col_f32(&x, &s);
        let aty = col2im_f32(&y, &s, 2);
        let lhs: f32 = ax.data().iter().zip(y.data()).map(|(a, b)| a * b).sum();
        let rhs: f32 = x.data().iter().zip(aty.data()).map(|(a, b)| a * b).sum();
        assert!((lhs - rhs).abs() < 1e-2 * lhs.abs().max(1.0), "{lhs} vs {rhs}");
    }

    #[test]
    fn im2col_matches_python_layout() {
        // channel-fastest (kh, kw, c) — the same layout as the Pallas kernel
        let s = Conv2dShape { h: 2, w: 2, c: 2, k: 1, oc: 1, stride: 1, pad: 0 };
        let x = TensorF32::from_vec(&[1, 2, 2, 2], vec![1., 2., 3., 4., 5., 6., 7., 8.]);
        let cols = im2col_f32(&x, &s);
        assert_eq!(cols.shape(), &[4, 2]);
        assert_eq!(cols.data(), x.data());
    }

    #[test]
    fn stride_2_shapes() {
        let s = Conv2dShape { h: 8, w: 8, c: 1, k: 3, oc: 1, stride: 2, pad: 1 };
        assert_eq!(s.oh(), 4);
        let x = TensorF32::zeros(&[1, 8, 8, 1]);
        assert_eq!(im2col_f32(&x, &s).shape(), &[16, 9]);
    }
}
