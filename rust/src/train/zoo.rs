//! Trainable builds of the paper's small benchmark CNNs (Table I rows that
//! are trained end-to-end: LeNet-5 and the 5-layer ConvNet).

use crate::util::Rng;

use super::layers::{Conv2d, Flatten, Linear, MaxPool2, Relu};
use super::linalg::Conv2dShape;
use super::net::Network;

/// A trainable model plus its pruning annotation.
pub struct TrainableModel {
    /// The network.
    pub net: Network,
    /// Which GEMM weights (conv+fc, in order) are DBB-prunable. The first
    /// conv and the classifier head stay dense (paper §V-A).
    pub prunable: Vec<bool>,
    /// Model name.
    pub name: &'static str,
}

/// LeNet-5 for 28×28×1 inputs: conv5×5×6(p2) → pool → conv5×5×16 → pool →
/// fc120 → fc84 → fc10.
pub fn lenet5(rng: &mut Rng) -> TrainableModel {
    let c1 = Conv2dShape { h: 28, w: 28, c: 1, k: 5, oc: 6, stride: 1, pad: 2 };
    let c2 = Conv2dShape { h: 14, w: 14, c: 6, k: 5, oc: 16, stride: 1, pad: 0 };
    let net = Network::new(vec![
        Box::new(Conv2d::new("conv1", c1, rng)),
        Box::new(Relu::new()),
        Box::new(MaxPool2::new()),
        Box::new(Conv2d::new("conv2", c2, rng)),
        Box::new(Relu::new()),
        Box::new(MaxPool2::new()),
        Box::new(Flatten::new()),
        Box::new(Linear::new("fc1", 5 * 5 * 16, 120, rng)),
        Box::new(Relu::new()),
        Box::new(Linear::new("fc2", 120, 84, rng)),
        Box::new(Relu::new()),
        Box::new(Linear::new("fc3", 84, 10, rng)),
    ]);
    TrainableModel {
        net,
        prunable: vec![false, true, true, true, false],
        name: "LeNet-5",
    }
}

/// The paper's 5-layer ConvNet for 32×32×3 inputs.
pub fn convnet5(rng: &mut Rng) -> TrainableModel {
    let c1 = Conv2dShape { h: 32, w: 32, c: 3, k: 5, oc: 32, stride: 1, pad: 2 };
    let c2 = Conv2dShape { h: 16, w: 16, c: 32, k: 5, oc: 32, stride: 1, pad: 2 };
    let c3 = Conv2dShape { h: 8, w: 8, c: 32, k: 5, oc: 64, stride: 1, pad: 2 };
    let net = Network::new(vec![
        Box::new(Conv2d::new("conv1", c1, rng)),
        Box::new(Relu::new()),
        Box::new(MaxPool2::new()),
        Box::new(Conv2d::new("conv2", c2, rng)),
        Box::new(Relu::new()),
        Box::new(MaxPool2::new()),
        Box::new(Conv2d::new("conv3", c3, rng)),
        Box::new(Relu::new()),
        Box::new(MaxPool2::new()),
        Box::new(Flatten::new()),
        Box::new(Linear::new("fc1", 4 * 4 * 64, 64, rng)),
        Box::new(Relu::new()),
        Box::new(Linear::new("fc2", 64, 10, rng)),
    ]);
    TrainableModel {
        net,
        prunable: vec![false, true, true, true, false],
        name: "ConvNet",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::TensorF32;

    #[test]
    fn lenet_shapes() {
        let mut rng = Rng::new(1);
        let mut m = lenet5(&mut rng);
        let x = TensorF32::zeros(&[2, 28, 28, 1]);
        let y = m.net.forward(&x, false);
        assert_eq!(y.shape(), &[2, 10]);
        assert_eq!(m.net.gemm_weights().len(), m.prunable.len());
    }

    #[test]
    fn convnet_shapes() {
        let mut rng = Rng::new(2);
        let mut m = convnet5(&mut rng);
        let x = TensorF32::zeros(&[1, 32, 32, 3]);
        let y = m.net.forward(&x, false);
        assert_eq!(y.shape(), &[1, 10]);
    }

    #[test]
    fn weight_counts_match_layer_tables() {
        // the trainable builds must agree with `crate::models` layer tables
        let mut rng = Rng::new(3);
        let mut m = lenet5(&mut rng);
        let total: usize = m.net.gemm_weights().iter().map(|(_, w)| w.len()).sum();
        let table: usize = crate::models::lenet5().layers.iter().map(|l| l.weights()).sum();
        assert_eq!(total, table);
    }
}
