//! Training substrate for the DBB pruning experiments (paper Tables I–II).
//!
//! A small, dependency-free CNN training stack: f32 conv/fc/pool layers
//! with exact backprop ([`layers`], gradient-checked), SGD + momentum
//! ([`net`]), synthetic learnable datasets ([`data`] — the offline
//! substitute for MNIST/CIFAR), the paper's three-phase recipe
//! ([`three_phase`]): baseline training → progressive DBB-aware magnitude
//! pruning ([`pruning`]) → INT8 fine-tuning/quantization ([`quant`]).
//!
//! What Tables I–II claim — and what these modules reproduce — is the
//! *relative* behaviour: (a) DBB pruning to 50–75% sparsity costs ≲1%
//! accuracy after fine-tuning, and (b) at equal compression ratio, larger
//! block sizes lose less accuracy. Absolute ImageNet numbers are out of
//! scope (no data, one CPU core); the big-model rows of Table I reuse the
//! weight-count columns from `crate::models` layer tables.

pub mod data;
pub mod layers;
pub mod linalg;
pub mod net;
pub mod pruning;
pub mod quant;
pub mod zoo;

use crate::util::Rng;
use data::Dataset;
use net::{accuracy, softmax_ce, Network};
use pruning::DbbPruneSchedule;
use zoo::TrainableModel;

/// Hyper-parameters for the three-phase recipe.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Baseline training epochs.
    pub baseline_epochs: usize,
    /// Progressive-pruning epochs (the NNZ ramp length).
    pub prune_epochs: usize,
    /// Quantized fine-tuning epochs.
    pub finetune_epochs: usize,
    /// Minibatch size.
    pub batch: usize,
    /// Learning rate.
    pub lr: f32,
    /// SGD momentum.
    pub momentum: f32,
    /// RNG seed (shuffling).
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            baseline_epochs: 4,
            prune_epochs: 4,
            finetune_epochs: 2,
            batch: 32,
            lr: 0.01,
            momentum: 0.9,
            seed: 1234,
        }
    }
}

/// Result of a full three-phase run.
#[derive(Debug, Clone)]
pub struct TrainReport {
    /// Model name.
    pub model: &'static str,
    /// FP32 baseline test accuracy.
    pub baseline_acc: f64,
    /// Accuracy after DBB pruning + INT8 quantization + fine-tuning.
    pub dbb_int8_acc: f64,
    /// Total non-zero weights in the prunable matrices after pruning.
    pub total_nnz: usize,
    /// Non-zero weights in *convolution* layers only (paper Table I
    /// footnote: "Convolution layers only" — conv nnz incl. dense convs).
    pub conv_nnz: usize,
    /// Measured sparsity over prunable matrices.
    pub sparsity: f64,
    /// DBB parameters used.
    pub bz: usize,
    /// Density bound.
    pub nnz: usize,
}

/// One training epoch; returns mean loss.
pub fn train_epoch(
    net: &mut Network,
    ds: &Dataset,
    cfg: &TrainConfig,
    rng: &mut Rng,
    schedule: Option<&DbbPruneSchedule>,
) -> f32 {
    let n = ds.len();
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    let mut total = 0f32;
    let mut batches = 0;
    for chunk in order.chunks(cfg.batch) {
        let (x, y) = ds.batch(chunk);
        let logits = net.forward(&x, true);
        let (loss, d) = softmax_ce(&logits, &y);
        net.backward(&d);
        net.sgd_step(cfg.lr, cfg.momentum);
        if let Some(s) = schedule {
            s.enforce(net); // pruned weights stay zero through the update
        }
        total += loss;
        batches += 1;
    }
    total / batches.max(1) as f32
}

/// Test accuracy over a dataset.
pub fn evaluate(net: &mut Network, ds: &Dataset) -> f64 {
    let mut correct = 0f64;
    let mut count = 0usize;
    for chunk in (0..ds.len()).collect::<Vec<_>>().chunks(64) {
        let (x, y) = ds.batch(chunk);
        let logits = net.forward(&x, false);
        correct += accuracy(&logits, &y) * y.len() as f64;
        count += y.len();
    }
    correct / count.max(1) as f64
}

/// The paper's full three-phase recipe (§V-A): train FP32 baseline,
/// progressively DBB-prune with fine-tuning, then quantize to INT8 and
/// fine-tune with the masks enforced. Returns the Table-I style report.
pub fn three_phase(
    mut model: TrainableModel,
    train: &Dataset,
    test: &Dataset,
    bz: usize,
    nnz: usize,
    cfg: &TrainConfig,
) -> TrainReport {
    let mut rng = Rng::new(cfg.seed);

    // phase 1: baseline
    for _ in 0..cfg.baseline_epochs {
        train_epoch(&mut model.net, train, cfg, &mut rng, None);
    }
    let baseline_acc = evaluate(&mut model.net, test);

    // phase 2: progressive DBB pruning with fine-tuning between steps
    let mut sched = DbbPruneSchedule::new(bz, nnz, cfg.prune_epochs);
    for e in 0..cfg.prune_epochs {
        sched.prune_epoch(&mut model.net, &model.prunable, e);
        train_epoch(&mut model.net, train, cfg, &mut rng, Some(&sched));
    }
    // make sure the final bound is in force
    sched.prune_epoch(&mut model.net, &model.prunable, cfg.prune_epochs);

    // phase 3: INT8 quantization + fine-tune (STE: quantize, train f32
    // with masks, re-quantize)
    let mut ft_cfg = cfg.clone();
    ft_cfg.lr = cfg.lr * 0.2;
    for _ in 0..cfg.finetune_epochs {
        quant::quantize_network(&mut model.net);
        sched.enforce(&mut model.net);
        train_epoch(&mut model.net, train, &ft_cfg, &mut rng, Some(&sched));
    }
    quant::quantize_network(&mut model.net);
    sched.enforce(&mut model.net);

    let dbb_int8_acc = evaluate(&mut model.net, test);
    let sparsity = sched.sparsity(&mut model.net, &model.prunable);
    let total_nnz: usize = model
        .net
        .gemm_weights()
        .into_iter()
        .zip(&model.prunable)
        .filter(|(_, &p)| p)
        .map(|((_, w), _)| w.data().iter().filter(|&&v| v != 0.0).count())
        .sum();
    let conv_nnz: usize = model
        .net
        .gemm_weights()
        .into_iter()
        .filter(|(n, _)| n.starts_with("conv"))
        .map(|(_, w)| w.data().iter().filter(|&&v| v != 0.0).count())
        .sum();

    TrainReport {
        model: model.name,
        baseline_acc,
        dbb_int8_acc,
        total_nnz,
        conv_nnz,
        sparsity,
        bz,
        nnz,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg() -> TrainConfig {
        TrainConfig {
            baseline_epochs: 2,
            prune_epochs: 2,
            finetune_epochs: 1,
            batch: 32,
            lr: 0.01,
            momentum: 0.9,
            seed: 7,
        }
    }

    #[test]
    fn lenet_three_phase_learns_and_prunes() {
        let mut rng = Rng::new(1);
        let (train, test) = data::synth_mnist_split(600, 200, 10);
        let model = zoo::lenet5(&mut rng);
        let r = three_phase(model, &train, &test, 8, 2, &quick_cfg());
        // learnable: well above 10% chance
        assert!(r.baseline_acc > 0.5, "baseline {}", r.baseline_acc);
        // pruning hit the 2/8 target = 75% sparsity
        assert!((r.sparsity - 0.75).abs() < 0.02, "sparsity {}", r.sparsity);
        // the paper's claim: small accuracy cost (allow generous slack on
        // tiny synthetic data)
        assert!(
            r.dbb_int8_acc > r.baseline_acc - 0.15,
            "acc {} -> {}",
            r.baseline_acc,
            r.dbb_int8_acc
        );
    }

    #[test]
    fn pruned_network_exports_valid_dbb() {
        // after three_phase, every prunable weight must encode under the
        // bound — the exact artifact the accelerator consumes
        let mut rng = Rng::new(2);
        let (train, test) = data::synth_mnist_split(300, 100, 20);
        let mut cfg = quick_cfg();
        cfg.baseline_epochs = 1;
        let (bz, nnz) = (8usize, 3usize);

        // re-run the phases manually to keep the model afterwards
        let mut model = zoo::lenet5(&mut rng);
        let mut train_rng = Rng::new(cfg.seed);
        for _ in 0..cfg.baseline_epochs {
            train_epoch(&mut model.net, &train, &cfg, &mut train_rng, None);
        }
        let mut sched = DbbPruneSchedule::new(bz, nnz, cfg.prune_epochs);
        for e in 0..cfg.prune_epochs {
            sched.prune_epoch(&mut model.net, &model.prunable, e);
            train_epoch(&mut model.net, &train, &cfg, &mut train_rng, Some(&sched));
        }
        sched.prune_epoch(&mut model.net, &model.prunable, cfg.prune_epochs);
        quant::quantize_network(&mut model.net);
        sched.enforce(&mut model.net);

        let prunable = model.prunable.clone();
        for ((_, w), p) in model.net.gemm_weights().into_iter().zip(prunable) {
            let (dbb, _) = quant::export_dbb(w, bz);
            if p {
                assert!(dbb.max_block_nnz() <= nnz, "bound violated");
            }
        }
        let _ = evaluate(&mut model.net, &test);
    }
}
