//! Trainable layers with forward/backward passes.
//!
//! The layer set is exactly what the paper's five benchmark CNNs need:
//! conv (streaming IM2COL fused into the GEMM — the same §IV-C design the
//! accelerator uses in hardware), fully-connected, ReLU, 2×2 max-pool and
//! flatten. Weights live in GEMM layout (`[K, N]`, K = kh·kw·cin
//! channel-fastest) so the DBB pruning masks apply to the same blocks the
//! hardware sees.

use crate::gemm::fused;
use crate::tensor::TensorF32;
use crate::util::Rng;

use super::linalg::{col2im_f32, matmul, Conv2dShape};

/// A trainable layer.
pub trait Layer {
    /// Forward pass; `x` layout is layer-specific (documented per layer).
    fn forward(&mut self, x: &TensorF32, train: bool) -> TensorF32;
    /// Backward pass: gradient w.r.t. input; accumulates weight grads.
    fn backward(&mut self, dy: &TensorF32) -> TensorF32;
    /// (weights, grads, momentum) triples for the optimizer; empty for
    /// stateless layers.
    fn params(&mut self) -> Vec<(&mut TensorF32, &mut TensorF32, &mut TensorF32)> {
        Vec::new()
    }
    /// Prunable GEMM weight matrix (K×N), if this layer carries one.
    fn gemm_weight(&mut self) -> Option<&mut TensorF32> {
        None
    }
    /// Layer name for reporting.
    fn name(&self) -> &str;
}

/// Convolution via the fused streaming-IM2COL GEMM
/// ([`crate::gemm::fused::conv2d_f32`]): the `[M×K]` patch matrix is never
/// materialized — forward generates rows on the fly, and backward retains
/// only the raw input (`O(B·H·W·C)`) and regenerates patches for the
/// streaming weight-gradient ([`crate::gemm::fused::conv2d_dw_f32`]).
/// Input `[B, H, W, C]`, output `[B, OH, OW, OC]`. Weight `[K, OC]` with
/// `K = k·k·c` (GEMM layout). Bit-exact with the materializing
/// `im2col_f32` + `matmul` lowering, which survives as the test oracle.
pub struct Conv2d {
    /// Geometry.
    pub shape: Conv2dShape,
    /// GEMM-layout weights.
    pub w: TensorF32,
    /// Bias per output channel.
    pub b: TensorF32,
    dw: TensorF32,
    db: TensorF32,
    mw: TensorF32,
    mb: TensorF32,
    x: Option<TensorF32>,
    batch: usize,
    label: String,
}

impl Conv2d {
    /// He-initialized conv layer.
    pub fn new(label: &str, shape: Conv2dShape, rng: &mut Rng) -> Self {
        let k = shape.gemm_k();
        let std = (2.0 / k as f32).sqrt();
        Conv2d {
            shape,
            w: TensorF32::randn(&[k, shape.oc], std, rng),
            b: TensorF32::zeros(&[shape.oc]),
            dw: TensorF32::zeros(&[k, shape.oc]),
            db: TensorF32::zeros(&[shape.oc]),
            mw: TensorF32::zeros(&[k, shape.oc]),
            mb: TensorF32::zeros(&[shape.oc]),
            x: None,
            batch: 0,
            label: label.to_string(),
        }
    }
}

impl Layer for Conv2d {
    fn forward(&mut self, x: &TensorF32, train: bool) -> TensorF32 {
        let b = x.shape()[0];
        self.batch = b;
        let s = self.shape;
        let mut y = fused::conv2d_f32(x, &self.w, &s.as_conv());
        let oc = s.oc;
        for row in y.data_mut().chunks_mut(oc) {
            for (v, bias) in row.iter_mut().zip(self.b.data()) {
                *v += bias;
            }
        }
        if train {
            self.x = Some(x.clone());
        }
        y.reshape(&[b, s.oh(), s.ow(), oc])
    }

    fn backward(&mut self, dy: &TensorF32) -> TensorF32 {
        let s = self.shape;
        let m = self.batch * s.oh() * s.ow();
        let dy2 = dy.reshape(&[m, s.oc]);
        let x = self.x.take().expect("forward(train=true) first");
        // dW = colsᵀ · dy, patches regenerated on the fly
        self.dw = fused::conv2d_dw_f32(&x, &dy2, &s.as_conv());
        // db = Σ rows
        let mut db = vec![0f32; s.oc];
        for row in dy2.data().chunks(s.oc) {
            for (d, v) in db.iter_mut().zip(row) {
                *d += v;
            }
        }
        self.db = TensorF32::from_vec(&[s.oc], db);
        // dX = col2im(dy · Wᵀ) — the adjoint stays materialized: its operand
        // is dy·Wᵀ (gradients, not duplicated activations)
        let wt = self.w.transpose2d(); // [N, K]
        let dcols = matmul(&dy2, &wt);
        col2im_f32(&dcols, &s, self.batch)
    }

    fn params(&mut self) -> Vec<(&mut TensorF32, &mut TensorF32, &mut TensorF32)> {
        vec![(&mut self.w, &mut self.dw, &mut self.mw), (&mut self.b, &mut self.db, &mut self.mb)]
    }

    fn gemm_weight(&mut self) -> Option<&mut TensorF32> {
        Some(&mut self.w)
    }

    fn name(&self) -> &str {
        &self.label
    }
}

/// Fully connected: input `[B, K]`, weight `[K, N]`, output `[B, N]`.
pub struct Linear {
    /// GEMM-layout weights.
    pub w: TensorF32,
    /// Bias.
    pub b: TensorF32,
    dw: TensorF32,
    db: TensorF32,
    mw: TensorF32,
    mb: TensorF32,
    x: Option<TensorF32>,
    label: String,
}

impl Linear {
    /// He-initialized FC layer.
    pub fn new(label: &str, k: usize, n: usize, rng: &mut Rng) -> Self {
        let std = (2.0 / k as f32).sqrt();
        Linear {
            w: TensorF32::randn(&[k, n], std, rng),
            b: TensorF32::zeros(&[n]),
            dw: TensorF32::zeros(&[k, n]),
            db: TensorF32::zeros(&[n]),
            mw: TensorF32::zeros(&[k, n]),
            mb: TensorF32::zeros(&[n]),
            x: None,
            label: label.to_string(),
        }
    }
}

impl Layer for Linear {
    fn forward(&mut self, x: &TensorF32, train: bool) -> TensorF32 {
        let b = x.shape()[0];
        let k = self.w.shape()[0];
        let x2 = x.reshape(&[b, k]);
        let mut y = matmul(&x2, &self.w);
        let n = self.w.shape()[1];
        for row in y.data_mut().chunks_mut(n) {
            for (v, bias) in row.iter_mut().zip(self.b.data()) {
                *v += bias;
            }
        }
        if train {
            self.x = Some(x2);
        }
        y
    }

    fn backward(&mut self, dy: &TensorF32) -> TensorF32 {
        let x = self.x.take().expect("forward(train=true) first");
        self.dw = matmul_tn(&x, dy);
        let n = self.w.shape()[1];
        let mut db = vec![0f32; n];
        for row in dy.data().chunks(n) {
            for (d, v) in db.iter_mut().zip(row) {
                *d += v;
            }
        }
        self.db = TensorF32::from_vec(&[n], db);
        matmul(dy, &self.w.transpose2d())
    }

    fn params(&mut self) -> Vec<(&mut TensorF32, &mut TensorF32, &mut TensorF32)> {
        vec![(&mut self.w, &mut self.dw, &mut self.mw), (&mut self.b, &mut self.db, &mut self.mb)]
    }

    fn gemm_weight(&mut self) -> Option<&mut TensorF32> {
        Some(&mut self.w)
    }

    fn name(&self) -> &str {
        &self.label
    }
}

/// ReLU (any shape).
pub struct Relu {
    mask: Vec<bool>,
}

impl Relu {
    /// New ReLU.
    pub fn new() -> Self {
        Relu { mask: Vec::new() }
    }
}

impl Default for Relu {
    fn default() -> Self {
        Self::new()
    }
}

impl Layer for Relu {
    fn forward(&mut self, x: &TensorF32, train: bool) -> TensorF32 {
        let mut y = x.clone();
        if train {
            self.mask = x.data().iter().map(|&v| v > 0.0).collect();
        }
        for v in y.data_mut() {
            if *v < 0.0 {
                *v = 0.0;
            }
        }
        y
    }

    fn backward(&mut self, dy: &TensorF32) -> TensorF32 {
        let mut dx = dy.clone();
        for (d, &keep) in dx.data_mut().iter_mut().zip(&self.mask) {
            if !keep {
                *d = 0.0;
            }
        }
        dx
    }

    fn name(&self) -> &str {
        "relu"
    }
}

/// 2×2 max pool, stride 2. Input `[B, H, W, C]` (H, W even).
pub struct MaxPool2 {
    argmax: Vec<usize>,
    in_shape: Vec<usize>,
}

impl MaxPool2 {
    /// New pool layer.
    pub fn new() -> Self {
        MaxPool2 { argmax: Vec::new(), in_shape: Vec::new() }
    }
}

impl Default for MaxPool2 {
    fn default() -> Self {
        Self::new()
    }
}

impl Layer for MaxPool2 {
    fn forward(&mut self, x: &TensorF32, train: bool) -> TensorF32 {
        let (b, h, w, c) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
        let (oh, ow) = (h / 2, w / 2);
        let mut y = vec![f32::NEG_INFINITY; b * oh * ow * c];
        let mut arg = vec![0usize; b * oh * ow * c];
        let xd = x.data();
        for bi in 0..b {
            for oy in 0..oh {
                for ox in 0..ow {
                    for ci in 0..c {
                        let o = ((bi * oh + oy) * ow + ox) * c + ci;
                        for dy_ in 0..2 {
                            for dx in 0..2 {
                                let ii = ((bi * h + oy * 2 + dy_) * w + ox * 2 + dx) * c + ci;
                                if xd[ii] > y[o] {
                                    y[o] = xd[ii];
                                    arg[o] = ii;
                                }
                            }
                        }
                    }
                }
            }
        }
        if train {
            self.argmax = arg;
            self.in_shape = x.shape().to_vec();
        }
        TensorF32::from_vec(&[b, oh, ow, c], y)
    }

    fn backward(&mut self, dy: &TensorF32) -> TensorF32 {
        let mut dx = TensorF32::zeros(&self.in_shape);
        let dxd = dx.data_mut();
        for (g, &src) in dy.data().iter().zip(&self.argmax) {
            dxd[src] += g;
        }
        dx
    }

    fn name(&self) -> &str {
        "maxpool2"
    }
}

/// Flatten `[B, ...]` → `[B, prod]`.
pub struct Flatten {
    in_shape: Vec<usize>,
}

impl Flatten {
    /// New flatten layer.
    pub fn new() -> Self {
        Flatten { in_shape: Vec::new() }
    }
}

impl Default for Flatten {
    fn default() -> Self {
        Self::new()
    }
}

impl Layer for Flatten {
    fn forward(&mut self, x: &TensorF32, train: bool) -> TensorF32 {
        if train {
            self.in_shape = x.shape().to_vec();
        }
        let b = x.shape()[0];
        x.reshape(&[b, x.len() / b])
    }

    fn backward(&mut self, dy: &TensorF32) -> TensorF32 {
        dy.reshape(&self.in_shape)
    }

    fn name(&self) -> &str {
        "flatten"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Finite-difference gradient check for a layer's input gradient.
    fn grad_check<L: Layer>(layer: &mut L, x: &TensorF32, eps: f32, tol: f32) {
        let y = layer.forward(x, true);
        // loss = Σ y²/2 → dy = y
        let dx = layer.backward(&y);
        let mut rng = Rng::new(11);
        for _ in 0..10 {
            let i = rng.below(x.len());
            let mut xp = x.clone();
            xp.data_mut()[i] += eps;
            let yp = layer.forward(&xp, false);
            let mut xm = x.clone();
            xm.data_mut()[i] -= eps;
            let ym = layer.forward(&xm, false);
            let lp: f32 = yp.data().iter().map(|v| v * v / 2.0).sum();
            let lm: f32 = ym.data().iter().map(|v| v * v / 2.0).sum();
            let fd = (lp - lm) / (2.0 * eps);
            let an = dx.data()[i];
            assert!(
                (fd - an).abs() < tol * (1.0 + fd.abs().max(an.abs())),
                "elem {i}: fd={fd} analytic={an}"
            );
        }
    }

    #[test]
    fn conv_forward_bit_exact_with_materialized_oracle() {
        // the fused layer must reproduce the old im2col+matmul lowering to
        // the last bit (same per-row f32 accumulation order)
        use super::super::linalg::{im2col_f32, matmul};
        use crate::util::prop::{check, Config};
        check(Config::default().cases(24), |rng| {
            let k = [1usize, 3, 5][rng.below(3)];
            let s = Conv2dShape {
                h: k + rng.below(5) + 1,
                w: k + rng.below(5) + 1,
                c: rng.below(4) + 1,
                k,
                oc: rng.below(4) + 1,
                stride: rng.below(2) + 1,
                pad: rng.below(k.div_ceil(2)),
            };
            let b = rng.below(3) + 1;
            let mut frng = Rng::new(rng.next_u64());
            let mut conv = Conv2d::new("c", s, &mut frng);
            let x = TensorF32::randn(&[b, s.h, s.w, s.c], 1.0, &mut frng);
            let got = conv.forward(&x, false);
            let mut want = matmul(&im2col_f32(&x, &s), &conv.w);
            for row in want.data_mut().chunks_mut(s.oc) {
                for (v, bias) in row.iter_mut().zip(conv.b.data()) {
                    *v += bias;
                }
            }
            assert_eq!(got.shape(), &[b, s.oh(), s.ow(), s.oc]);
            for (g, t) in got.data().iter().zip(want.data()) {
                assert_eq!(g.to_bits(), t.to_bits(), "shape={s:?}");
            }
        });
    }

    #[test]
    fn conv_grad_check() {
        let mut rng = Rng::new(1);
        let s = Conv2dShape { h: 5, w: 5, c: 2, k: 3, oc: 3, stride: 1, pad: 1 };
        let mut conv = Conv2d::new("c", s, &mut rng);
        let x = TensorF32::randn(&[2, 5, 5, 2], 1.0, &mut rng);
        grad_check(&mut conv, &x, 1e-2, 2e-2);
    }

    #[test]
    fn conv_weight_grad_check() {
        let mut rng = Rng::new(2);
        let s = Conv2dShape { h: 4, w: 4, c: 1, k: 3, oc: 2, stride: 1, pad: 0 };
        let mut conv = Conv2d::new("c", s, &mut rng);
        let x = TensorF32::randn(&[1, 4, 4, 1], 1.0, &mut rng);
        let y = conv.forward(&x, true);
        conv.backward(&y);
        let eps = 1e-2f32;
        for i in [0usize, 3, 7] {
            let orig = conv.w.data()[i];
            conv.w.data_mut()[i] = orig + eps;
            let lp: f32 = conv.forward(&x, false).data().iter().map(|v| v * v / 2.0).sum();
            conv.w.data_mut()[i] = orig - eps;
            let lm: f32 = conv.forward(&x, false).data().iter().map(|v| v * v / 2.0).sum();
            conv.w.data_mut()[i] = orig;
            let fd = (lp - lm) / (2.0 * eps);
            let y2 = conv.forward(&x, true);
            conv.backward(&y2);
            let an = conv.dw.data()[i];
            assert!((fd - an).abs() < 2e-2 * (1.0 + fd.abs()), "w[{i}]: fd={fd} an={an}");
        }
    }

    #[test]
    fn linear_grad_check() {
        let mut rng = Rng::new(3);
        let mut fc = Linear::new("fc", 6, 4, &mut rng);
        let x = TensorF32::randn(&[3, 6], 1.0, &mut rng);
        grad_check(&mut fc, &x, 1e-2, 1e-2);
    }

    #[test]
    fn relu_grad_check() {
        let mut rng = Rng::new(4);
        let mut r = Relu::new();
        let x = TensorF32::randn(&[4, 5], 1.0, &mut rng);
        grad_check(&mut r, &x, 1e-3, 1e-2);
    }

    #[test]
    fn maxpool_forward_and_grad_routing() {
        let x = TensorF32::from_vec(
            &[1, 2, 2, 1],
            vec![1.0, 5.0, 3.0, 2.0],
        );
        let mut p = MaxPool2::new();
        let y = p.forward(&x, true);
        assert_eq!(y.data(), &[5.0]);
        let dx = p.backward(&TensorF32::from_vec(&[1, 1, 1, 1], vec![7.0]));
        assert_eq!(dx.data(), &[0.0, 7.0, 0.0, 0.0]); // all grad to argmax
    }

    #[test]
    fn flatten_roundtrip() {
        let mut f = Flatten::new();
        let x = TensorF32::zeros(&[2, 3, 4, 5]);
        let y = f.forward(&x, true);
        assert_eq!(y.shape(), &[2, 60]);
        assert_eq!(f.backward(&y).shape(), &[2, 3, 4, 5]);
    }
}
