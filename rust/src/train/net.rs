//! Sequential network container, softmax cross-entropy and SGD+momentum.

use crate::tensor::TensorF32;

use super::layers::Layer;

/// A sequential network.
pub struct Network {
    /// Layers in order.
    pub layers: Vec<Box<dyn Layer>>,
}

impl Network {
    /// Build from a layer list.
    pub fn new(layers: Vec<Box<dyn Layer>>) -> Self {
        Network { layers }
    }

    /// Forward pass through all layers.
    pub fn forward(&mut self, x: &TensorF32, train: bool) -> TensorF32 {
        let mut h = x.clone();
        for l in &mut self.layers {
            h = l.forward(&h, train);
        }
        h
    }

    /// Backward pass (after `forward(train=true)`).
    pub fn backward(&mut self, dloss: &TensorF32) {
        let mut g = dloss.clone();
        for l in self.layers.iter_mut().rev() {
            g = l.backward(&g);
        }
    }

    /// SGD with momentum over all parameters.
    pub fn sgd_step(&mut self, lr: f32, momentum: f32) {
        for l in &mut self.layers {
            for (w, g, m) in l.params() {
                for ((wv, gv), mv) in
                    w.data_mut().iter_mut().zip(g.data()).zip(m.data_mut())
                {
                    *mv = momentum * *mv + gv;
                    *wv -= lr * *mv;
                }
            }
        }
    }

    /// Prunable GEMM weight matrices (conv + fc), with layer names.
    pub fn gemm_weights(&mut self) -> Vec<(String, &mut TensorF32)> {
        let mut out = Vec::new();
        for l in &mut self.layers {
            let name = l.name().to_string();
            if let Some(w) = l.gemm_weight() {
                out.push((name, w));
            }
        }
        out
    }
}

/// Softmax cross-entropy: returns (mean loss, dlogits).
pub fn softmax_ce(logits: &TensorF32, labels: &[usize]) -> (f32, TensorF32) {
    let b = logits.shape()[0];
    let n = logits.shape()[1];
    assert_eq!(b, labels.len());
    let mut dl = TensorF32::zeros(&[b, n]);
    let mut loss = 0f32;
    for (i, &y) in labels.iter().enumerate() {
        let row = &logits.data()[i * n..(i + 1) * n];
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let exps: Vec<f32> = row.iter().map(|v| (v - mx).exp()).collect();
        let z: f32 = exps.iter().sum();
        loss += -(exps[y] / z).ln();
        for j in 0..n {
            let p = exps[j] / z;
            dl.set(&[i, j], (p - if j == y { 1.0 } else { 0.0 }) / b as f32);
        }
    }
    (loss / b as f32, dl)
}

/// Classification accuracy of logits vs labels.
pub fn accuracy(logits: &TensorF32, labels: &[usize]) -> f64 {
    let b = logits.shape()[0];
    let n = logits.shape()[1];
    let mut correct = 0usize;
    for (i, &y) in labels.iter().enumerate() {
        let row = &logits.data()[i * n..(i + 1) * n];
        let pred = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        if pred == y {
            correct += 1;
        }
    }
    correct as f64 / b as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::train::layers::{Linear, Relu};
    use crate::util::Rng;

    #[test]
    fn softmax_ce_gradient_sums_to_zero_per_row() {
        let mut rng = Rng::new(1);
        let logits = TensorF32::randn(&[4, 10], 1.0, &mut rng);
        let (_, d) = softmax_ce(&logits, &[0, 3, 9, 5]);
        for i in 0..4 {
            let s: f32 = d.data()[i * 10..(i + 1) * 10].iter().sum();
            assert!(s.abs() < 1e-6);
        }
    }

    #[test]
    fn softmax_ce_loss_of_perfect_prediction_is_small() {
        let mut logits = TensorF32::zeros(&[1, 3]);
        logits.set(&[0, 1], 100.0);
        let (loss, _) = softmax_ce(&logits, &[1]);
        assert!(loss < 1e-6);
    }

    #[test]
    fn tiny_net_learns_xor_like_task() {
        // 2-layer MLP on a linearly-inseparable toy task: loss must drop
        let mut rng = Rng::new(7);
        let mut net = Network::new(vec![
            Box::new(Linear::new("fc1", 2, 16, &mut rng)),
            Box::new(Relu::new()),
            Box::new(Linear::new("fc2", 16, 2, &mut rng)),
        ]);
        let xs: Vec<[f32; 2]> = vec![[0., 0.], [0., 1.], [1., 0.], [1., 1.]];
        let ys = [0usize, 1, 1, 0];
        let x = TensorF32::from_vec(&[4, 2], xs.iter().flatten().cloned().collect());
        let mut first = None;
        let mut last = 0.0;
        for _ in 0..300 {
            let logits = net.forward(&x, true);
            let (loss, d) = softmax_ce(&logits, &ys);
            net.backward(&d);
            net.sgd_step(0.1, 0.9);
            first.get_or_insert(loss);
            last = loss;
        }
        assert!(last < 0.1 * first.unwrap(), "loss {first:?} -> {last}");
        let logits = net.forward(&x, false);
        assert_eq!(accuracy(&logits, &ys), 1.0);
    }

    #[test]
    fn accuracy_counts() {
        let logits = TensorF32::from_vec(&[2, 3], vec![1., 5., 2., 9., 0., 1.]);
        assert_eq!(accuracy(&logits, &[1, 0]), 1.0);
        assert_eq!(accuracy(&logits, &[0, 0]), 0.5);
    }
}
