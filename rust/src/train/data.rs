//! Synthetic image-classification datasets.
//!
//! The paper trains on MNIST / CIFAR-10 / ImageNet; the builder has no
//! network access, so we substitute *genuinely learnable* synthetic
//! datasets (DESIGN.md §Paper-resources substitutions): each class gets a
//! smooth random template (low-frequency blobs), and samples are the
//! template plus pixel noise, random shifts and amplitude jitter. This
//! preserves what Tables I–II actually measure — the *relative* accuracy
//! cost of DBB pruning and quantization on a trained CNN — without the
//! datasets themselves.

use crate::tensor::TensorF32;
use crate::util::Rng;

/// A labeled image dataset, `[N, H, W, C]` in `[0, 1]`.
pub struct Dataset {
    /// Images.
    pub images: TensorF32,
    /// Labels `0..classes`.
    pub labels: Vec<usize>,
    /// Class count.
    pub classes: usize,
}

impl Dataset {
    /// Number of samples.
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// Per-sample image size (H·W·C).
    pub fn sample_elems(&self) -> usize {
        self.images.len() / self.len().max(1)
    }

    /// Copy a batch `[indices.len(), H, W, C]`.
    pub fn batch(&self, indices: &[usize]) -> (TensorF32, Vec<usize>) {
        let e = self.sample_elems();
        let mut shape = self.images.shape().to_vec();
        shape[0] = indices.len();
        let mut data = Vec::with_capacity(indices.len() * e);
        for &i in indices {
            data.extend_from_slice(&self.images.data()[i * e..(i + 1) * e]);
        }
        (
            TensorF32::from_vec(&shape, data),
            indices.iter().map(|&i| self.labels[i]).collect(),
        )
    }
}

/// Smooth per-class template: sum of a few random 2-D Gaussian blobs.
fn template(h: usize, w: usize, c: usize, rng: &mut Rng) -> Vec<f32> {
    let mut t = vec![0f32; h * w * c];
    let blobs = 3 + rng.below(3);
    for _ in 0..blobs {
        let cy = rng.f32() * h as f32;
        let cx = rng.f32() * w as f32;
        let sig = 1.5 + rng.f32() * (h as f32 / 4.0);
        let amp = 0.4 + rng.f32() * 0.6;
        let chan = rng.below(c);
        for y in 0..h {
            for x in 0..w {
                let d2 = (y as f32 - cy).powi(2) + (x as f32 - cx).powi(2);
                t[(y * w + x) * c + chan] += amp * (-d2 / (2.0 * sig * sig)).exp();
            }
        }
    }
    t
}

/// Generate a synthetic dataset of `n` samples.
pub fn synth(
    n: usize,
    h: usize,
    w: usize,
    c: usize,
    classes: usize,
    noise: f32,
    seed: u64,
) -> Dataset {
    let mut rng = Rng::new(seed);
    let templates: Vec<Vec<f32>> = (0..classes).map(|_| template(h, w, c, &mut rng)).collect();
    let e = h * w * c;
    let mut images = vec![0f32; n * e];
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let y = rng.below(classes);
        labels.push(y);
        let amp = 0.6 + rng.f32() * 0.6;
        // random translation (±3 px)
        let dy = rng.below(7) as isize - 3;
        let dx = rng.below(7) as isize - 3;
        let t = &templates[y];
        for yy in 0..h {
            for xx in 0..w {
                let sy = yy as isize - dy;
                let sx = xx as isize - dx;
                for cc in 0..c {
                    let base = if sy >= 0 && sy < h as isize && sx >= 0 && sx < w as isize {
                        t[((sy as usize) * w + sx as usize) * c + cc]
                    } else {
                        0.0
                    };
                    let v = amp * base + noise * rng.normal();
                    images[(i * h * w + yy * w + xx) * c + cc] = v.clamp(0.0, 1.0);
                }
            }
        }
    }
    Dataset {
        images: TensorF32::from_vec(&[n, h, w, c], images),
        labels,
        classes,
    }
}

/// Generate a train/test pair drawn from the *same* class templates
/// (the split a real dataset provides).
pub fn synth_split(
    n_train: usize,
    n_test: usize,
    h: usize,
    w: usize,
    c: usize,
    classes: usize,
    noise: f32,
    seed: u64,
) -> (Dataset, Dataset) {
    let all = synth(n_train + n_test, h, w, c, classes, noise, seed);
    let e = all.sample_elems();
    let cut = n_train * e;
    let train = Dataset {
        images: {
            let mut shape = all.images.shape().to_vec();
            shape[0] = n_train;
            TensorF32::from_vec(&shape, all.images.data()[..cut].to_vec())
        },
        labels: all.labels[..n_train].to_vec(),
        classes,
    };
    let test = Dataset {
        images: {
            let mut shape = all.images.shape().to_vec();
            shape[0] = n_test;
            TensorF32::from_vec(&shape, all.images.data()[cut..].to_vec())
        },
        labels: all.labels[n_train..].to_vec(),
        classes,
    };
    (train, test)
}

/// Noise level of the standard datasets: tuned so a converged LeNet-5
/// lands in the high-90s (headroom for pruning damage to show, like the
/// real MNIST/CIFAR columns of Table I) while a nearest-mean classifier
/// still clears 60%.
pub const NOISE: f32 = 0.22;

/// MNIST-like: 28×28×1, 10 classes.
pub fn synth_mnist(n: usize, seed: u64) -> Dataset {
    synth(n, 28, 28, 1, 10, NOISE, seed)
}

/// MNIST-like train/test split sharing templates.
pub fn synth_mnist_split(n_train: usize, n_test: usize, seed: u64) -> (Dataset, Dataset) {
    synth_split(n_train, n_test, 28, 28, 1, 10, NOISE, seed)
}

/// CIFAR-like: 32×32×3, 10 classes.
pub fn synth_cifar(n: usize, seed: u64) -> Dataset {
    synth(n, 32, 32, 3, 10, NOISE, seed)
}

/// CIFAR-like train/test split sharing templates.
pub fn synth_cifar_split(n_train: usize, n_test: usize, seed: u64) -> (Dataset, Dataset) {
    synth_split(n_train, n_test, 32, 32, 3, 10, NOISE, seed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_ranges() {
        let d = synth_mnist(64, 1);
        assert_eq!(d.images.shape(), &[64, 28, 28, 1]);
        assert_eq!(d.len(), 64);
        assert!(d.images.data().iter().all(|&v| (0.0..=1.0).contains(&v)));
        assert!(d.labels.iter().all(|&y| y < 10));
    }

    #[test]
    fn all_classes_present() {
        let d = synth_mnist(400, 2);
        for cls in 0..10 {
            assert!(d.labels.contains(&cls), "class {cls} missing");
        }
    }

    #[test]
    fn batch_extraction() {
        let d = synth_cifar(16, 3);
        let (x, y) = d.batch(&[3, 7, 11]);
        assert_eq!(x.shape(), &[3, 32, 32, 3]);
        assert_eq!(y.len(), 3);
        // rows are the right samples
        let e = d.sample_elems();
        assert_eq!(&x.data()[..e], &d.images.data()[3 * e..4 * e]);
    }

    #[test]
    fn classes_are_distinguishable() {
        // a nearest-template classifier should beat chance easily —
        // the dataset is genuinely learnable
        let d = synth_mnist(200, 4);
        let e = d.sample_elems();
        // build per-class means from the first half
        let mut means = vec![vec![0f32; e]; 10];
        let mut counts = vec![0usize; 10];
        for i in 0..100 {
            let y = d.labels[i];
            counts[y] += 1;
            for (m, v) in means[y].iter_mut().zip(&d.images.data()[i * e..(i + 1) * e]) {
                *m += v;
            }
        }
        for (m, &c) in means.iter_mut().zip(&counts) {
            for v in m.iter_mut() {
                *v /= c.max(1) as f32;
            }
        }
        // classify the second half by nearest mean
        let mut correct = 0;
        for i in 100..200 {
            let img = &d.images.data()[i * e..(i + 1) * e];
            let best = (0..10)
                .min_by(|&a, &b| {
                    let da: f32 = means[a].iter().zip(img).map(|(m, v)| (m - v).powi(2)).sum();
                    let db: f32 = means[b].iter().zip(img).map(|(m, v)| (m - v).powi(2)).sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if best == d.labels[i] {
                correct += 1;
            }
        }
        assert!(correct > 60, "nearest-mean accuracy only {correct}/100");
    }
}
