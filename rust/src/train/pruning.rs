//! Progressive DBB-aware magnitude pruning (paper §V-A).
//!
//! "This step progressively prunes small-magnitude weights within each DBB
//! block for about 20 epochs, until the desired block sparsity constraint
//! is met." We implement the schedule as a per-epoch NNZ ramp from BZ down
//! to the target, recomputing the keep-mask each step and re-applying it
//! after every optimizer update so pruned weights stay zero.
//!
//! The same schedule learns **block** masks under [`WeightFormat::Bsr`]
//! ([`DbbPruneSchedule::new_format`]): instead of the top-`nnz` elements of
//! each DBB block, whole `bz×bz` tiles survive by Frobenius magnitude — the
//! matched-density rule the inference engine applies at
//! `PreparedModel::prepare_format`, so a network trained here exports
//! directly into the BSR datapath.

use crate::dbb::prune::{apply_mask_f32, bsr_mask_f32, dbb_mask_f32};
use crate::gemm::WeightFormat;
use crate::tensor::TensorF32;

use super::net::Network;

/// Pruning schedule state.
#[derive(Debug, Clone)]
pub struct DbbPruneSchedule {
    /// Block size.
    pub bz: usize,
    /// Final NNZ target.
    pub target_nnz: usize,
    /// Epochs over which NNZ ramps from BZ to the target.
    pub ramp_epochs: usize,
    /// Mask structure the schedule learns: per-element within DBB blocks
    /// ([`WeightFormat::Dbb`], the historical default), whole surviving
    /// `bz×bz` tiles ([`WeightFormat::Bsr`]), or no pruning at all
    /// ([`WeightFormat::Dense`]).
    pub format: WeightFormat,
    masks: Vec<Vec<bool>>, // one per prunable weight matrix
}

impl DbbPruneSchedule {
    /// New schedule (the historical DBB element-mask mode).
    pub fn new(bz: usize, target_nnz: usize, ramp_epochs: usize) -> Self {
        Self::new_format(bz, target_nnz, ramp_epochs, WeightFormat::Dbb)
    }

    /// New schedule learning `format`-structured masks. The NNZ ramp is
    /// shared: at an epoch bound of `nnz`, DBB keeps the top `nnz` elements
    /// of every `bz` block while BSR keeps the top `nnz/bz` **fraction of
    /// blocks** per block row — identical weight density, different
    /// granularity.
    pub fn new_format(
        bz: usize,
        target_nnz: usize,
        ramp_epochs: usize,
        format: WeightFormat,
    ) -> Self {
        assert!(target_nnz >= 1 && target_nnz <= bz);
        DbbPruneSchedule {
            bz,
            target_nnz,
            ramp_epochs: ramp_epochs.max(1),
            format,
            masks: Vec::new(),
        }
    }

    /// NNZ bound in force at `epoch` (0-based): linear ramp BZ → target.
    pub fn nnz_at(&self, epoch: usize) -> usize {
        if epoch + 1 >= self.ramp_epochs {
            return self.target_nnz;
        }
        let span = (self.bz - self.target_nnz) as f64;
        let frac = (epoch + 1) as f64 / self.ramp_epochs as f64;
        (self.bz as f64 - span * frac).round() as usize
    }

    /// Recompute masks for the epoch's bound and prune the network.
    /// `prunable` marks which GEMM weights participate (same order as
    /// [`Network::gemm_weights`]).
    pub fn prune_epoch(&mut self, net: &mut Network, prunable: &[bool], epoch: usize) {
        let nnz = self.nnz_at(epoch);
        let weights = net.gemm_weights();
        self.masks = weights
            .into_iter()
            .zip(prunable)
            .map(|((_, w), &p)| {
                if !p || nnz >= self.bz || matches!(self.format, WeightFormat::Dense) {
                    vec![true; w.len()]
                } else {
                    let m = match self.format {
                        WeightFormat::Dbb => dbb_mask_f32(w, self.bz, nnz),
                        WeightFormat::Bsr => {
                            let nbc = w.shape()[1].div_ceil(self.bz);
                            let keep = (nbc * nnz).div_ceil(self.bz).clamp(1, nbc);
                            bsr_mask_f32(w, self.bz, self.bz, keep)
                        }
                        WeightFormat::Dense => unreachable!("handled above"),
                    };
                    apply_mask_f32(w, &m);
                    m
                }
            })
            .collect();
    }

    /// Re-apply the current masks (call after every optimizer step).
    pub fn enforce(&self, net: &mut Network) {
        if self.masks.is_empty() {
            return;
        }
        for ((_, w), mask) in net.gemm_weights().into_iter().zip(&self.masks) {
            apply_mask_f32(w, mask);
        }
    }

    /// Measured sparsity over the prunable matrices.
    pub fn sparsity(&self, net: &mut Network, prunable: &[bool]) -> f64 {
        let mut zeros = 0usize;
        let mut total = 0usize;
        for ((_, w), &p) in net.gemm_weights().into_iter().zip(prunable) {
            if !p {
                continue;
            }
            zeros += w.data().iter().filter(|&&v| v == 0.0).count();
            total += w.len();
        }
        if total == 0 {
            0.0
        } else {
            zeros as f64 / total as f64
        }
    }
}

/// Verify every prunable matrix satisfies the (nnz, bz) bound.
pub fn check_dbb_bound(w: &TensorF32, bz: usize, nnz: usize) -> bool {
    let (k, n) = (w.shape()[0], w.shape()[1]);
    for col in 0..n {
        for kb in 0..k.div_ceil(bz) {
            let lo = kb * bz;
            let hi = (lo + bz).min(k);
            let cnt = (lo..hi).filter(|&kk| w.at(&[kk, col]) != 0.0).count();
            if cnt > nnz {
                return false;
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::train::layers::Linear;
    use crate::util::Rng;

    fn net2(rng: &mut Rng) -> Network {
        Network::new(vec![
            Box::new(Linear::new("fc1", 32, 16, rng)),
            Box::new(Linear::new("fc2", 16, 8, rng)),
        ])
    }

    #[test]
    fn ramp_is_monotone_and_hits_target() {
        let s = DbbPruneSchedule::new(8, 2, 6);
        let mut prev = 8;
        for e in 0..10 {
            let n = s.nnz_at(e);
            assert!(n <= prev, "epoch {e}: {n} > {prev}");
            prev = n;
        }
        assert_eq!(s.nnz_at(5), 2);
        assert_eq!(s.nnz_at(9), 2);
    }

    #[test]
    fn prune_epoch_enforces_bound() {
        let mut rng = Rng::new(1);
        let mut net = net2(&mut rng);
        let mut s = DbbPruneSchedule::new(8, 2, 1);
        s.prune_epoch(&mut net, &[true, true], 0);
        for (_, w) in net.gemm_weights() {
            assert!(check_dbb_bound(w, 8, 2));
        }
    }

    #[test]
    fn non_prunable_layers_untouched() {
        let mut rng = Rng::new(2);
        let mut net = net2(&mut rng);
        let before = net.gemm_weights()[1].1.data().to_vec();
        let mut s = DbbPruneSchedule::new(8, 1, 1);
        s.prune_epoch(&mut net, &[true, false], 0);
        assert_eq!(net.gemm_weights()[1].1.data(), &before[..]);
        assert!(check_dbb_bound(net.gemm_weights()[0].1, 8, 1));
    }

    #[test]
    fn enforce_keeps_weights_pruned_after_updates() {
        let mut rng = Rng::new(3);
        let mut net = net2(&mut rng);
        let mut s = DbbPruneSchedule::new(8, 2, 1);
        s.prune_epoch(&mut net, &[true, true], 0);
        // simulate an optimizer update perturbing everything
        for (_, w) in net.gemm_weights() {
            for v in w.data_mut() {
                *v += 0.5;
            }
        }
        s.enforce(&mut net);
        for (_, w) in net.gemm_weights() {
            assert!(check_dbb_bound(w, 8, 2));
        }
    }

    #[test]
    fn sparsity_reporting() {
        let mut rng = Rng::new(4);
        let mut net = net2(&mut rng);
        let mut s = DbbPruneSchedule::new(8, 2, 1);
        s.prune_epoch(&mut net, &[true, true], 0);
        let sp = s.sparsity(&mut net, &[true, true]);
        assert!((sp - 0.75).abs() < 0.02, "sparsity {sp}"); // 2/8 = 75%
    }

    #[test]
    fn bsr_mode_learns_block_structured_masks_at_matched_density() {
        let mut rng = Rng::new(5);
        let mut net = net2(&mut rng);
        let mut s = DbbPruneSchedule::new_format(8, 2, 1, WeightFormat::Bsr);
        assert_eq!(s.format, WeightFormat::Bsr);
        s.prune_epoch(&mut net, &[true, true], 0);
        for (_, w) in net.gemm_weights() {
            let (k, n) = (w.shape()[0], w.shape()[1]);
            let (nbr, nbc) = (k.div_ceil(8), n.div_ceil(8));
            let keep = (nbc * 2).div_ceil(8).max(1);
            for br in 0..nbr {
                let mut survivors = 0;
                for bc in 0..nbc {
                    // every 8x8 tile is uniformly kept or uniformly zero
                    let mut any = false;
                    let mut all = true;
                    for r in br * 8..((br + 1) * 8).min(k) {
                        for c in bc * 8..((bc + 1) * 8).min(n) {
                            let nz = w.at(&[r, c]) != 0.0;
                            any |= nz;
                            all &= nz;
                        }
                    }
                    assert!(any == all || !any, "ragged block ({br},{bc})");
                    survivors += any as usize;
                }
                assert!(survivors <= keep, "block row {br}: {survivors} > {keep}");
            }
        }
        // the matched-density rule: 2/8 bound -> 1/4 of the blocks survive,
        // so element sparsity lands on the same 75% the DBB mode reports
        let sp = s.sparsity(&mut net, &[true, true]);
        assert!((sp - 0.75).abs() < 0.02, "sparsity {sp}");
        // enforce keeps the block structure after optimizer perturbation
        for (_, w) in net.gemm_weights() {
            for v in w.data_mut() {
                *v += 0.25;
            }
        }
        s.enforce(&mut net);
        let sp = s.sparsity(&mut net, &[true, true]);
        assert!((sp - 0.75).abs() < 0.02, "post-enforce sparsity {sp}");
    }
}
