//! Progressive DBB-aware magnitude pruning (paper §V-A).
//!
//! "This step progressively prunes small-magnitude weights within each DBB
//! block for about 20 epochs, until the desired block sparsity constraint
//! is met." We implement the schedule as a per-epoch NNZ ramp from BZ down
//! to the target, recomputing the keep-mask each step and re-applying it
//! after every optimizer update so pruned weights stay zero.

use crate::dbb::prune::{apply_mask_f32, dbb_mask_f32};
use crate::tensor::TensorF32;

use super::net::Network;

/// Pruning schedule state.
#[derive(Debug, Clone)]
pub struct DbbPruneSchedule {
    /// Block size.
    pub bz: usize,
    /// Final NNZ target.
    pub target_nnz: usize,
    /// Epochs over which NNZ ramps from BZ to the target.
    pub ramp_epochs: usize,
    masks: Vec<Vec<bool>>, // one per prunable weight matrix
}

impl DbbPruneSchedule {
    /// New schedule.
    pub fn new(bz: usize, target_nnz: usize, ramp_epochs: usize) -> Self {
        assert!(target_nnz >= 1 && target_nnz <= bz);
        DbbPruneSchedule {
            bz,
            target_nnz,
            ramp_epochs: ramp_epochs.max(1),
            masks: Vec::new(),
        }
    }

    /// NNZ bound in force at `epoch` (0-based): linear ramp BZ → target.
    pub fn nnz_at(&self, epoch: usize) -> usize {
        if epoch + 1 >= self.ramp_epochs {
            return self.target_nnz;
        }
        let span = (self.bz - self.target_nnz) as f64;
        let frac = (epoch + 1) as f64 / self.ramp_epochs as f64;
        (self.bz as f64 - span * frac).round() as usize
    }

    /// Recompute masks for the epoch's bound and prune the network.
    /// `prunable` marks which GEMM weights participate (same order as
    /// [`Network::gemm_weights`]).
    pub fn prune_epoch(&mut self, net: &mut Network, prunable: &[bool], epoch: usize) {
        let nnz = self.nnz_at(epoch);
        let weights = net.gemm_weights();
        self.masks = weights
            .into_iter()
            .zip(prunable)
            .map(|((_, w), &p)| {
                if !p || nnz >= self.bz {
                    vec![true; w.len()]
                } else {
                    let m = dbb_mask_f32(w, self.bz, nnz);
                    apply_mask_f32(w, &m);
                    m
                }
            })
            .collect();
    }

    /// Re-apply the current masks (call after every optimizer step).
    pub fn enforce(&self, net: &mut Network) {
        if self.masks.is_empty() {
            return;
        }
        for ((_, w), mask) in net.gemm_weights().into_iter().zip(&self.masks) {
            apply_mask_f32(w, mask);
        }
    }

    /// Measured sparsity over the prunable matrices.
    pub fn sparsity(&self, net: &mut Network, prunable: &[bool]) -> f64 {
        let mut zeros = 0usize;
        let mut total = 0usize;
        for ((_, w), &p) in net.gemm_weights().into_iter().zip(prunable) {
            if !p {
                continue;
            }
            zeros += w.data().iter().filter(|&&v| v == 0.0).count();
            total += w.len();
        }
        if total == 0 {
            0.0
        } else {
            zeros as f64 / total as f64
        }
    }
}

/// Verify every prunable matrix satisfies the (nnz, bz) bound.
pub fn check_dbb_bound(w: &TensorF32, bz: usize, nnz: usize) -> bool {
    let (k, n) = (w.shape()[0], w.shape()[1]);
    for col in 0..n {
        for kb in 0..k.div_ceil(bz) {
            let lo = kb * bz;
            let hi = (lo + bz).min(k);
            let cnt = (lo..hi).filter(|&kk| w.at(&[kk, col]) != 0.0).count();
            if cnt > nnz {
                return false;
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::train::layers::Linear;
    use crate::util::Rng;

    fn net2(rng: &mut Rng) -> Network {
        Network::new(vec![
            Box::new(Linear::new("fc1", 32, 16, rng)),
            Box::new(Linear::new("fc2", 16, 8, rng)),
        ])
    }

    #[test]
    fn ramp_is_monotone_and_hits_target() {
        let s = DbbPruneSchedule::new(8, 2, 6);
        let mut prev = 8;
        for e in 0..10 {
            let n = s.nnz_at(e);
            assert!(n <= prev, "epoch {e}: {n} > {prev}");
            prev = n;
        }
        assert_eq!(s.nnz_at(5), 2);
        assert_eq!(s.nnz_at(9), 2);
    }

    #[test]
    fn prune_epoch_enforces_bound() {
        let mut rng = Rng::new(1);
        let mut net = net2(&mut rng);
        let mut s = DbbPruneSchedule::new(8, 2, 1);
        s.prune_epoch(&mut net, &[true, true], 0);
        for (_, w) in net.gemm_weights() {
            assert!(check_dbb_bound(w, 8, 2));
        }
    }

    #[test]
    fn non_prunable_layers_untouched() {
        let mut rng = Rng::new(2);
        let mut net = net2(&mut rng);
        let before = net.gemm_weights()[1].1.data().to_vec();
        let mut s = DbbPruneSchedule::new(8, 1, 1);
        s.prune_epoch(&mut net, &[true, false], 0);
        assert_eq!(net.gemm_weights()[1].1.data(), &before[..]);
        assert!(check_dbb_bound(net.gemm_weights()[0].1, 8, 1));
    }

    #[test]
    fn enforce_keeps_weights_pruned_after_updates() {
        let mut rng = Rng::new(3);
        let mut net = net2(&mut rng);
        let mut s = DbbPruneSchedule::new(8, 2, 1);
        s.prune_epoch(&mut net, &[true, true], 0);
        // simulate an optimizer update perturbing everything
        for (_, w) in net.gemm_weights() {
            for v in w.data_mut() {
                *v += 0.5;
            }
        }
        s.enforce(&mut net);
        for (_, w) in net.gemm_weights() {
            assert!(check_dbb_bound(w, 8, 2));
        }
    }

    #[test]
    fn sparsity_reporting() {
        let mut rng = Rng::new(4);
        let mut net = net2(&mut rng);
        let mut s = DbbPruneSchedule::new(8, 2, 1);
        s.prune_epoch(&mut net, &[true, true], 0);
        let sp = s.sparsity(&mut net, &[true, true]);
        assert!((sp - 0.75).abs() < 0.02, "sparsity {sp}"); // 2/8 = 75%
    }
}
