//! INT8 post-training quantization with STE-style exact zero (paper §V-A).
//!
//! Symmetric per-tensor quantization: `q = clamp(round(w / s), ±127)` with
//! `s = max|w| / 127`. FP 0 maps to INT 0 exactly — the property the
//! clock-gating power model depends on. The quantized *evaluation* path
//! runs fake-quant (quantize → dequantize) through the f32 layers, which is
//! numerically identical to the INT8 datapath up to the accumulator (exact
//! for weights/activations, and the INT32 accumulator never saturates for
//! these layer sizes).

use crate::dbb::DbbMatrix;
use crate::tensor::{TensorF32, TensorI8};

use super::net::Network;

/// Symmetric quantization scale for a tensor.
pub fn scale_for(w: &TensorF32) -> f32 {
    let mx = w.data().iter().fold(0f32, |a, &v| a.max(v.abs()));
    if mx == 0.0 {
        1.0
    } else {
        mx / 127.0
    }
}

/// Quantize to INT8 with the given scale (exact zero preserved).
pub fn quantize(w: &TensorF32, scale: f32) -> TensorI8 {
    w.map(|v| (v / scale).round().clamp(-127.0, 127.0) as i8)
}

/// Dequantize back to f32.
pub fn dequantize(q: &TensorI8, scale: f32) -> TensorF32 {
    q.map(|v| v as f32 * scale)
}

/// Fake-quantize in place: `w ← dequant(quant(w))`.
pub fn fake_quant(w: &mut TensorF32) -> f32 {
    let s = scale_for(w);
    let q = quantize(w, s);
    *w = dequantize(&q, s);
    s
}

/// Quantize every GEMM weight of a network in place (fake-quant), so the
/// f32 evaluation measures INT8 accuracy. Returns per-layer scales.
pub fn quantize_network(net: &mut Network) -> Vec<(String, f32)> {
    net.gemm_weights()
        .into_iter()
        .map(|(name, w)| {
            let s = fake_quant(w);
            (name, s)
        })
        .collect()
}

/// Export a (pruned, fake-quantized) GEMM weight as a DBB-compressed INT8
/// matrix — the artifact the accelerator consumes.
pub fn export_dbb(w: &TensorF32, bz: usize) -> (DbbMatrix, f32) {
    let s = scale_for(w);
    let q = quantize(w, s);
    (DbbMatrix::compress(&q, bz).expect("valid block size"), s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Config};
    use crate::util::Rng;

    #[test]
    fn zero_maps_to_zero_exactly() {
        check(Config::default().cases(64), |rng| {
            let w = TensorF32::randn(&[16, 4], 1.0, rng);
            let s = scale_for(&w);
            let q = quantize(&w, s);
            for (orig, qq) in w.data().iter().zip(q.data()) {
                if *orig == 0.0 {
                    assert_eq!(*qq, 0);
                }
            }
        });
    }

    #[test]
    fn quant_roundtrip_error_bounded() {
        let mut rng = Rng::new(1);
        let w = TensorF32::randn(&[64, 8], 1.0, &mut rng);
        let s = scale_for(&w);
        let back = dequantize(&quantize(&w, s), s);
        for (a, b) in w.data().iter().zip(back.data()) {
            assert!((a - b).abs() <= s * 0.5 + 1e-7, "{a} vs {b} (s={s})");
        }
    }

    #[test]
    fn pruned_zeros_survive_quantization() {
        let mut rng = Rng::new(2);
        let w0 = TensorF32::randn(&[32, 8], 1.0, &mut rng);
        let w = crate::dbb::prune::prune_f32(&w0, 8, 3);
        let mut wq = w.clone();
        fake_quant(&mut wq);
        // every pruned zero is still zero → DBB bound still satisfied
        for (orig, q) in w.data().iter().zip(wq.data()) {
            if *orig == 0.0 {
                assert_eq!(*q, 0.0);
            }
        }
        let (dbb, _) = export_dbb(&wq, 8);
        assert!(dbb.max_block_nnz() <= 3);
    }

    #[test]
    fn export_scale_consistency() {
        let mut rng = Rng::new(3);
        let w = crate::dbb::prune::prune_f32(&TensorF32::randn(&[24, 4], 1.0, &mut rng), 8, 2);
        let (dbb, s) = export_dbb(&w, 8);
        let dense = dbb.decompress();
        // dequantized export approximates the original
        for (a, b) in w.data().iter().zip(dense.data()) {
            assert!((a - *b as f32 * s).abs() <= s * 0.5 + 1e-7);
        }
    }
}
