//! Drivers for the paper's figures (9–12) — each emits the numeric series
//! behind the figure as a table (one row per bar/point).

use crate::arch::{space, Design, Tech};
use crate::models;
use crate::power;
use crate::sim::accel::{
    network_timing, profile_model, profile_model_repr, LayerProfile, NetworkTiming,
};
use crate::util::table::Table;
use crate::util::Parallelism;

/// Shared evaluation: run the paper's power-analysis workload (§V-C:
/// representative 3×3 ResNet-50 layers) at (nnz/8 DBB, fixed act sparsity)
/// on a design; returns (timing, power mW, area mm²).
fn eval_design(d: &Design, nnz: usize, act: f64) -> (NetworkTiming, f64, f64) {
    let m = models::resnet50();
    let profiles = profile_model_repr(&m, nnz, 8, act);
    eval_design_on(d, &profiles)
}

/// [`eval_design`] against an already-built layer profile — the sweep form:
/// the profile is design-independent, so fig9/fig10 build it once and share
/// it across every sweep task.
fn eval_design_on(d: &Design, profiles: &[LayerProfile]) -> (NetworkTiming, f64, f64) {
    let t = network_timing(d, profiles);
    let p = power::power(d, &t.total).total_mw();
    let a = power::area(d).total_mm2();
    (t, p, a)
}

/// Iso-work ("effective") view shared by fig9/fig10: raw power/area plus
/// the same scaled by the time this design needs for the workload relative
/// to `base_cycles` (energy per inference ∝ power × time; effective area ∝
/// area × time). Returns `(timing, power, area, eff_power, eff_area)`.
fn effective_on(
    d: &Design,
    profiles: &[LayerProfile],
    base_cycles: u64,
) -> (NetworkTiming, f64, f64, f64, f64) {
    let (t, p, a) = eval_design_on(d, profiles);
    let slowdown = t.total.cycles as f64 / base_cycles as f64;
    (t, p, a, p * slowdown, a * slowdown)
}

/// Fig. 9 — normalized power and area breakdown of the 12 representative
/// iso-peak-throughput designs at 3/8 DBB + 50% activation sparsity.
pub fn fig9() -> Vec<Table> {
    let designs = space::representative_12(Tech::N16);
    let base = &designs[0];
    let m = models::resnet50();
    let profiles = profile_model_repr(&m, 3, 8, 0.5);
    let (bt, bp, ba) = eval_design_on(base, &profiles);
    let base_cycles = bt.total.cycles;

    let mut t =
        Table::new("Fig 9: iso-throughput designs @ 3/8 DBB, 50% act (normalized to 1x1x1_32x64)");
    t.header(&[
        "Design", "Power mW", "Area mm2", "Cycles (ResNet50)", "Norm. eff. power",
        "Norm. eff. area",
    ]);
    let rows = space::sweep(&designs, Parallelism::auto(), |d| {
        let (ti, p, a, ep, ea) = effective_on(d, &profiles, base_cycles);
        (d.label(), p, a, ti.total.cycles, ep, ea)
    });
    for (label, p, a, cycles, ep, ea) in rows {
        t.row(&[
            label,
            format!("{p:.1}"),
            format!("{a:.2}"),
            format!("{cycles}"),
            format!("{:.3}", ep / bp),
            format!("{:.3}", ea / ba),
        ]);
    }
    vec![t]
}

/// Fig. 10 — the full enumerated design space: effective power vs area,
/// normalized to the baseline (the paper's scatter plot, as rows).
pub fn fig10() -> Vec<Table> {
    let designs = space::enumerate(space::MACS_4TOPS, Tech::N16);
    let base = Design::baseline_sa();
    let m = models::resnet50();
    let profiles = profile_model_repr(&m, 3, 8, 0.5);
    let (bt, bp, ba) = eval_design_on(&base, &profiles);
    let base_cycles = bt.total.cycles;

    let mut t = Table::new("Fig 10: design space (effective power vs area, normalized)");
    t.header(&["Design", "Norm. power", "Norm. area", "Group"]);
    // the whole-space sweep is the repo's hot loop — one design per task,
    // all tasks sharing the one design-independent layer profile
    let mut rows: Vec<(String, f64, f64, &'static str)> =
        space::sweep(&designs, Parallelism::auto(), |d| {
            let (_ti, _p, _a, ep, ea) = effective_on(d, &profiles, base_cycles);
            let group = match (&d.datapath, d.im2col) {
                (crate::arch::Datapath::Dense, _) => "dense",
                (crate::arch::Datapath::FixedDbb { .. }, _) => "fixed-DBB",
                (crate::arch::Datapath::Vdbb, true) => "VDBB+IM2C",
                (crate::arch::Datapath::Vdbb, false) => "VDBB",
                (crate::arch::Datapath::Bsr, _) => "BSR",
            };
            (d.label(), ep / bp, ea / ba, group)
        });
    rows.sort_by(|a, b| (a.1 * a.2).partial_cmp(&(b.1 * b.2)).unwrap());
    for (label, p, a, g) in rows {
        t.row(&[label, format!("{p:.3}"), format!("{a:.3}"), g.to_string()]);
    }
    vec![t]
}

/// Fig. 11 — per-layer power of INT8 DBB ResNet-50 on the representative
/// designs, normalized to the baseline, with *measured* per-layer
/// activation sparsity from a sampled functional inference.
pub fn fig11(quick: bool) -> Vec<Table> {
    let designs = if quick {
        vec![
            Design::baseline_sa(),
            Design::parse("4x8x4_4x8_DBB4of8_IM2C").unwrap(),
            Design::paper_optimal(),
        ]
    } else {
        space::representative_12(Tech::N16)
    };
    let m = models::resnet50();
    let profiles = profile_model(&m, 3, 8, 42); // measured act sparsity

    let base = Design::baseline_sa();
    let bt = network_timing(&base, &profiles);
    let bp = power::power(&base, &bt.total).total_mw();

    // whole-model row + a sample of named layers (the paper highlights
    // blk1/unit3/conv3 as the ~50%-sparsity layer). Power is per unit
    // time; the energy column (power × cycles, normalized) is the
    // per-inference view — the paper's "44.6% power reduction over the
    // baseline" matches the energy interpretation, since the sparse
    // designs also finish in a fraction of the cycles.
    let sample_layers =
        ["blk1/unit1/conv2", "blk1/unit3/conv3", "blk3/unit2/conv2", "blk4/unit3/conv3"];

    let mut t = Table::new(
        "Fig 11: ResNet-50 power/energy (normalized to baseline, measured act sparsity)",
    );
    let mut hdr = vec!["Design".to_string(), "whole power".into(), "whole energy".into()];
    hdr.extend(sample_layers.iter().map(|s| s.to_string()));
    t.header(&hdr);

    let rows = space::sweep(&designs, Parallelism::auto(), |d| {
        let ti = network_timing(d, &profiles);
        let p = power::power(d, &ti.total).total_mw();
        let energy = p * ti.total.cycles as f64 / (bp * bt.total.cycles as f64);
        let mut row = vec![d.label(), format!("{:.3}", p / bp), format!("{:.3}", energy)];
        for name in sample_layers {
            let li = ti.layers.iter().position(|l| l.name == name).expect("layer exists");
            let lp = power::power(d, &ti.layers[li].events).total_mw();
            let blp = power::power(&base, &bt.layers[li].events).total_mw();
            row.push(format!("{:.3}", lp / blp));
        }
        row
    });
    for row in rows {
        t.row(&row);
    }

    let mut spars = Table::new("Fig 11 (annotation): measured per-layer activation sparsity");
    spars.header(&["Layer", "Act sparsity %"]);
    for p in profiles.iter().take(12) {
        spars.row(&[p.name.clone(), format!("{:.1}", 100.0 * p.act_sparsity)]);
    }
    vec![t, spars]
}

/// Fig. 12 — effective throughput and energy efficiency vs weight sparsity
/// for the three designs (baseline SA + CG, fixed 4/8 DBB, VDBB), at 50%
/// and 80% activation sparsity.
pub fn fig12() -> Vec<Table> {
    let designs = vec![
        ("SA+CG (1x1x1_32x64_IM2C)", {
            let mut d = Design::baseline_sa();
            d.im2col = true;
            d
        }),
        ("DBB 4/8 (4x8x4_4x8_IM2C)", {
            let mut d = Design::paper_fixed_dbb();
            d.im2col = true;
            d
        }),
        ("VDBB (4x8x8_8x8_VDBB_IM2C)", Design::paper_optimal()),
    ];

    let mut thr = Table::new("Fig 12a: effective throughput (TOPS) vs weight sparsity");
    let mut hdr = vec!["Design / sparsity %".to_string()];
    for nnz in (1..=8).rev() {
        hdr.push(format!("{:.1}", 100.0 * (1.0 - nnz as f64 / 8.0)));
    }
    thr.header(&hdr);

    let mut eff50 = Table::new("Fig 12b: TOPS/W vs weight sparsity @ 50% act");
    eff50.header(&hdr);
    let mut eff80 = Table::new("Fig 12b: TOPS/W vs weight sparsity @ 80% act");
    eff80.header(&hdr);

    for (name, d) in &designs {
        let mut thr_row = vec![name.to_string()];
        let mut e50_row = vec![name.to_string()];
        let mut e80_row = vec![name.to_string()];
        for nnz in (1..=8usize).rev() {
            let (t, _, _) = eval_design(d, nnz, 0.5);
            thr_row.push(format!("{:.1}", t.effective_tops(d)));
            let tw50 = power::effective_tops_per_w(d, &t.total, t.dense_macs);
            e50_row.push(format!("{tw50:.1}"));
            let (t80, _, _) = eval_design(d, nnz, 0.8);
            let tw80 = power::effective_tops_per_w(d, &t80.total, t80.dense_macs);
            e80_row.push(format!("{tw80:.1}"));
        }
        thr.row(&thr_row);
        eff50.row(&e50_row);
        eff80.row(&e80_row);
    }
    vec![thr, eff50, eff80]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig9_vdbb_im2c_is_best() {
        let t = &fig9()[0];
        // last column is normalized effective area; find the optimal design
        // row and check it beats the baseline by >2x on both axes (paper:
        // ">2.5x area, >2x power" for the pareto group)
        let rows = t.rows();
        let opt = rows.iter().find(|r| r[0] == "4x8x8_8x8_VDBB_IM2C").expect("optimal in fig9");
        let p: f64 = opt[4].parse().unwrap();
        let a: f64 = opt[5].parse().unwrap();
        assert!(p < 0.5, "normalized effective power {p}");
        assert!(a < 0.4, "normalized effective area {a}");
    }

    #[test]
    fn fig10_pareto_corner_is_vdbb_im2c() {
        let t = &fig10()[0];
        // rows are sorted by power×area: the best corner must be VDBB+IM2C
        let first = &t.rows()[0];
        assert_eq!(first[3], "VDBB+IM2C", "pareto corner: {first:?}");
    }

    #[test]
    fn fig12_baseline_flat_dbb_steps_vdbb_scales() {
        let ts = fig12();
        let thr = &ts[0];
        let rows = thr.rows();
        let parse_row = |i: usize| -> Vec<f64> {
            rows[i][1..].iter().map(|s| s.parse().unwrap()).collect()
        };
        let sa = parse_row(0);
        let dbb = parse_row(1);
        let vdbb = parse_row(2);
        // baseline flat (within a few %)
        let sa_min = sa.iter().cloned().fold(f64::MAX, f64::min);
        let sa_max = sa.iter().cloned().fold(0.0, f64::max);
        assert!(sa_max / sa_min < 1.05, "SA should be flat: {sa:?}");
        // columns ascend in sparsity: [0]=0.0% ... [7]=87.5%
        // fixed DBB steps at 50% sparsity (col 4) and gains nothing above
        assert!(dbb[4] > 1.8 * dbb[0], "DBB 2x at 50%: {dbb:?}");
        assert!((dbb[7] / dbb[4] - 1.0).abs() < 0.05, "no further gain above 50%: {dbb:?}");
        // VDBB scales ~8x from dense to 87.5%
        let ratio = vdbb[7] / vdbb[0];
        assert!(ratio > 6.0, "VDBB should scale ~8x: {vdbb:?}");
        // and the 87.5% point approaches the paper's ~30 TOPS
        assert!(vdbb[7] > 25.0, "VDBB @87.5% = {} TOPS", vdbb[7]);
    }

    #[test]
    fn fig12_energy_scales_with_act_sparsity() {
        let ts = fig12();
        let e50 = &ts[1];
        let e80 = &ts[2];
        // VDBB row, 87.5% sparsity column (last): 80% act must beat 50% act
        let v50: f64 = e50.rows()[2][8].parse().unwrap();
        let v80: f64 = e80.rows()[2][8].parse().unwrap();
        assert!(v80 > v50, "80% act {v80} should beat 50% act {v50}");
        // and the headline: ~55.7 TOPS/W at 87.5% (50% act) — same order
        assert!(v50 > 30.0, "headline TOPS/W at 87.5%: {v50}");
    }
}
