//! Drivers for the paper's tables (I–V).

use crate::arch::{reuse, ArrayDims, Datapath, Design, Tech};
use crate::baselines::published;
use crate::baselines::smt_sa::SmtSa;
use crate::models;
use crate::power;
use crate::sim::accel::{network_timing, profile_model_repr};
use crate::train::{self, data, zoo, TrainConfig};
use crate::util::table::Table;
use crate::util::Rng;

fn pct(x: f64) -> String {
    format!("{:.1}", 100.0 * x)
}

/// Table I — CNNs trained with INT8 DBB weights (block size 8).
///
/// LeNet-5 and ConvNet are trained end-to-end on the synthetic datasets
/// (the offline substitute for MNIST/CIFAR — DESIGN.md); the ImageNet-scale
/// rows reproduce the weight-count/sparsity columns from the published
/// architectures, with the paper's accuracy figures quoted as `published`.
pub fn table1(quick: bool) -> Vec<Table> {
    let mut t = Table::new("Table I: CNNs trained with INT8 DBB weights (BZ=8)");
    t.header(&[
        "Model", "Dataset", "Baseline Acc.(%)", "DBB Acc.(%)", "Total NNZ", "Sparsity(%)",
        "Source",
    ]);

    let cfg = if quick {
        TrainConfig {
            baseline_epochs: 2,
            prune_epochs: 2,
            finetune_epochs: 1,
            ..TrainConfig::default()
        }
    } else {
        TrainConfig {
            baseline_epochs: 6,
            prune_epochs: 6,
            finetune_epochs: 3,
            ..TrainConfig::default()
        }
    };
    let (n_train, n_test) = if quick { (600, 200) } else { (2400, 600) };

    // ---- trained rows ----
    let (tr, te) = data::synth_mnist_split(n_train, n_test, 10);
    let r = train::three_phase(zoo::lenet5(&mut Rng::new(1)), &tr, &te, 8, 2, &cfg);
    t.row(&[
        r.model.to_string(),
        "synth-MNIST".into(),
        pct(r.baseline_acc),
        pct(r.dbb_int8_acc),
        format!("{:.2}K", r.conv_nnz as f64 / 1e3),
        format!("{} (2/8)", pct(r.sparsity)),
        "measured".into(),
    ]);

    let (tr, te) = data::synth_cifar_split(n_train.min(1200), n_test.min(300), 20);
    let r = train::three_phase(zoo::convnet5(&mut Rng::new(2)), &tr, &te, 8, 2, &cfg);
    t.row(&[
        r.model.to_string(),
        "synth-CIFAR".into(),
        pct(r.baseline_acc),
        pct(r.dbb_int8_acc),
        format!("{:.1}K", r.conv_nnz as f64 / 1e3),
        format!("{} (2/8)", pct(r.sparsity)),
        "measured".into(),
    ]);

    // ---- ImageNet-scale rows: weight structure from the layer tables,
    //      accuracy quoted from the paper (training is out of scope) ----
    for (model, nnz, base_acc, dbb_acc) in [
        (models::resnet50(), 3usize, 75.2, 74.2),
        (models::vgg16(), 3, 71.5, 71.4),
        (models::mobilenet_v1(), 4, 70.9, 69.8),
    ] {
        // paper Table I footnote: "Convolution layers only"
        let conv_prunable = model
            .layers
            .iter()
            .filter(|l| l.prunable && l.conv_shape().is_some())
            .map(|l| l.weights())
            .sum::<usize>() as f64;
        let conv_dense = model
            .layers
            .iter()
            .filter(|l| !l.prunable && l.conv_shape().is_some())
            .map(|l| l.weights())
            .sum::<usize>() as f64;
        let nnz_total = conv_prunable * nnz as f64 / 8.0 + conv_dense;
        let sparsity = 1.0 - nnz as f64 / 8.0;
        t.row(&[
            model.name.to_string(),
            "ImageNet".into(),
            format!("{base_acc:.1}"),
            format!("{dbb_acc:.1}"),
            format!("{:.2}M", nnz_total / 1e6),
            format!("{} ({}/8)", pct(sparsity), nnz),
            "published acc. / measured structure".into(),
        ]);
    }
    vec![t]
}

/// Table II — accuracy sensitivity to block size (BZ) and NNZ for LeNet-5.
/// At equal compression ratio, larger blocks should lose less accuracy.
pub fn table2(quick: bool) -> Vec<Table> {
    let mut t = Table::new("Table II: accuracy vs DBB block size (LeNet-5, INT8)");
    t.header(&["NNZ \\ BZ", "2", "4", "8", "16"]);
    let cfg = if quick {
        TrainConfig {
            baseline_epochs: 2,
            prune_epochs: 2,
            finetune_epochs: 1,
            ..TrainConfig::default()
        }
    } else {
        TrainConfig {
            baseline_epochs: 5,
            prune_epochs: 5,
            finetune_epochs: 2,
            ..TrainConfig::default()
        }
    };
    // a deliberately harder dataset than Table I's (less data, more
    // noise): the paper's BZ-sensitivity is only visible when the model is
    // under pressure — at saturation every cell reads the same
    let (n_train, n_test) = if quick { (500, 150) } else { (900, 400) };
    let (tr, te) = data::synth_split(n_train, n_test, 28, 28, 1, 10, 0.4, 30);

    // the paper's equal-ratio effect is a few tenths of a point — average
    // over several seeds (init + shuffle) so it isn't drowned by run noise
    let seeds: &[u64] = if quick { &[5] } else { &[5, 6, 7] };
    for nnz in [1usize, 2, 4] {
        let mut cells = vec![format!("{nnz}")];
        for bz in [2usize, 4, 8, 16] {
            if nnz >= bz {
                cells.push("-".into());
                continue;
            }
            let mean: f64 = seeds
                .iter()
                .map(|&seed| {
                    let mut c = cfg.clone();
                    c.seed = 1000 + seed;
                    train::three_phase(zoo::lenet5(&mut Rng::new(seed)), &tr, &te, bz, nnz, &c)
                        .dbb_int8_acc
                })
                .sum::<f64>()
                / seeds.len() as f64;
            cells.push(pct(mean));
        }
        t.row(&cells);
    }
    vec![t]
}

/// Table III — array design trade-offs (the reuse algebra), evaluated on
/// the four datapath variants at the paper's example geometries.
pub fn table3() -> Vec<Table> {
    let mut t = Table::new("Table III: array design trade-offs");
    t.header(&[
        "Variant", "Design", "MACs/TPE", "ACCs/TPE", "OPRs/TPE", "Inter-TPE reuse",
        "Intra-TPE reuse", "ACC reuse", "Act CG", "W sparsity",
    ]);
    let mk = |a, b, c, m, n, dp| Design {
        dims: ArrayDims { a, b, c, m, n },
        datapath: dp,
        im2col: false,
        act_cg: true,
        tech: Tech::N16,
    };
    let rows: Vec<(&str, Design, &str)> = vec![
        ("SA", mk(1, 1, 1, 32, 64, Datapath::Dense), "none"),
        ("STA", mk(4, 8, 8, 2, 4, Datapath::Dense), "none"),
        ("STA-DBB", mk(4, 8, 4, 4, 8, Datapath::FixedDbb { b: 4 }), "fixed DBB"),
        ("STA-VDBB", mk(4, 8, 8, 8, 8, Datapath::Vdbb), "variable DBB"),
    ];
    for (name, d, wsp) in rows {
        t.row(&[
            name.to_string(),
            d.label(),
            format!("{}", d.physical_macs() / d.dims.tpes()),
            format!("{}", d.acc_regs() / d.dims.tpes()),
            format!("{}", d.opr_regs_per_tpe()),
            format!("{:.1}", reuse::inter_tpe_reuse(&d)),
            format!("{:.2}", reuse::intra_tpe_reuse(&d)),
            format!("{}", reuse::acc_reuse(&d)),
            if reuse::act_cg_effective(&d) { "yes" } else { "no" }.into(),
            wsp.into(),
        ]);
    }
    vec![t]
}

/// Table IV — the pareto-optimal design's power/area breakdown at the
/// paper's operating point (ResNet-50, 3/8 DBB weights, 50% activations).
pub fn table4() -> Vec<Table> {
    let d = Design::paper_optimal();
    let m = models::resnet50();
    // §V-C: power analysis uses representative (3×3) ResNet-50 layers
    let profiles = profile_model_repr(&m, 3, 8, 0.5);
    let timing = network_timing(&d, &profiles);
    let p = power::power(&d, &timing.total);
    let a = power::area(&d);

    let mut t = Table::new(&format!(
        "Table IV: optimal design {} (nominal {:.1} TOPS)",
        d.label(),
        d.nominal_tops()
    ));
    t.header(&[
        "Component",
        "Power mW (model)",
        "Power mW (paper)",
        "Area mm2 (model)",
        "Area mm2 (paper)",
    ]);
    let rows: Vec<(&str, f64, f64, f64, f64)> = vec![
        ("Systolic Tensor Array", p.sta_mw, 318.0, a.sta_mm2, 0.732),
        ("Weight SRAM (512KB)", p.wsram_mw, 78.5, a.wsram_mm2, 0.54),
        ("Activation SRAM (2MB)", p.asram_mw, 31.0, a.asram_mm2, 2.16),
        ("Cortex-M33 MCUs", p.mcu_mw, 50.5, a.mcu_mm2, 0.30),
        ("IM2COL Unit", p.im2col_mw, 10.0, a.im2col_mm2, 0.01),
        ("Total", p.total_mw(), 487.5, a.total_mm2(), 3.74),
    ];
    for (name, pm, pp, am, ap) in rows {
        t.row(&[
            name.to_string(),
            format!("{pm:.1}"),
            format!("{pp:.1}"),
            format!("{am:.3}"),
            format!("{ap:.3}"),
        ]);
    }

    let mut eff = Table::new("Table IV (cont.): efficiency at 62.5% DBB / 50% act");
    eff.header(&["Metric", "Model", "Paper"]);
    let tw = power::effective_tops_per_w(&d, &timing.total, timing.dense_macs);
    let tm = power::effective_tops_per_mm2(&d, &timing.total, timing.dense_macs);
    eff.row(&["TOPS/W".to_string(), format!("{tw:.1}"), "21.9".into()]);
    eff.row(&["TOPS/mm2".to_string(), format!("{tm:.2}"), "2.85".into()]);
    vec![t, eff]
}

/// Our Table V rows: the optimal design at several model sparsities.
/// The 65 nm comparison design is the paper's half-size array (nominal
/// 1 TOPS at 500 MHz — Table V's 65 nm "Ours" rows).
fn ours_row(t: &mut Table, tech: Tech, nnz: usize) {
    let mut d = Design::paper_optimal();
    d.tech = tech;
    if tech == Tech::N65 {
        d.dims.m = 4; // 1024 MACs → 2·1024·0.5 GHz ≈ 1 TOPS nominal
    }
    let m = models::resnet50();
    let profiles = profile_model_repr(&m, nnz, 8, 0.5);
    let timing = network_timing(&d, &profiles);
    let tw = power::effective_tops_per_w(&d, &timing.total, timing.dense_macs);
    let tm = power::effective_tops_per_mm2(&d, &timing.total, timing.dense_macs);
    let sparsity = 100.0 * (1.0 - nnz as f64 / 8.0);
    t.row(&[
        "Ours (measured)".to_string(),
        if tech == Tech::N16 { "16nm" } else { "65nm" }.into(),
        "2MB / 512KB".into(),
        format!("{:.1}", tech.freq_hz() / 1e9),
        format!("{:.1}", d.nominal_tops()),
        format!("{tw:.1}"),
        format!("{tm:.2}"),
        format!("{sparsity:.1}% VDBB"),
        "50% CG".into(),
    ]);
}

/// Our measured BSR-datapath row: the iso-2048-MAC block-sparse design
/// (`4x8x8_2x4_BSR_IM2C` — dense TPEs, no operand muxes, coarse
/// `row_ptr`/`col_idx` weight indices) on the same ResNet-50 workload at
/// the matched block density `nnz/8`.
fn ours_bsr_row(t: &mut Table, nnz: usize) {
    let d = Design::parse("4x8x8_2x4_BSR_IM2C").expect("valid BSR label");
    let m = models::resnet50();
    let profiles = profile_model_repr(&m, nnz, 8, 0.5);
    let timing = network_timing(&d, &profiles);
    let tw = power::effective_tops_per_w(&d, &timing.total, timing.dense_macs);
    let tm = power::effective_tops_per_mm2(&d, &timing.total, timing.dense_macs);
    let sparsity = 100.0 * (1.0 - nnz as f64 / 8.0);
    t.row(&[
        "Ours BSR (measured)".to_string(),
        "16nm".into(),
        "2MB / 512KB".into(),
        format!("{:.1}", d.tech.freq_hz() / 1e9),
        format!("{:.1}", d.nominal_tops()),
        format!("{tw:.1}"),
        format!("{tm:.2}"),
        format!("{sparsity:.1}% BSR"),
        "50% CG".into(),
    ]);
}

/// Table V — comparison with published sparse INT8 CNN accelerators.
pub fn table5() -> Vec<Table> {
    let mut t = Table::new("Table V: comparison with sparse INT8 CNN accelerators");
    t.header(&[
        "System", "Tech", "SRAM A/W", "Freq GHz", "TOPS", "TOPS/W", "TOPS/mm2", "W sparsity",
        "A sparsity",
    ]);

    // ---- ours, 16 nm, at the paper's four sparsity points ----
    for nnz in [1usize, 2, 3, 4] {
        ours_row(&mut t, Tech::N16, nnz);
    }

    // ---- ours on the BSR datapath, same workload, matched densities ----
    for nnz in [2usize, 4] {
        ours_bsr_row(&mut t, nnz);
    }

    // ---- SMT-SA re-implementation (measured on the same workload) ----
    let smt = SmtSa::default();
    let (tw, tm) = smt_sa_efficiency(&smt);
    t.row(&[
        "SMT-SA (re-impl, measured)".to_string(),
        "16nm".into(),
        "2MB / 512KB".into(),
        "1.0".into(),
        format!("{:.1}", smt.nominal_tops()),
        format!("{tw:.1}"),
        format!("{tm:.2}"),
        "62.5% random".into(),
        "50% CG".into(),
    ]);

    // ---- published rows ----
    for r in published::rows_16nm() {
        t.row(&[
            format!("{} (published)", r.name),
            r.tech.into(),
            r.sram.into(),
            format!("{:.1}", r.freq_ghz),
            r.tops.map(|v| format!("{v:.1}")).unwrap_or_else(|| "-".into()),
            r.tops_per_w.map(|v| format!("{v:.2}")).unwrap_or_else(|| "-".into()),
            r.tops_per_mm2.map(|v| format!("{v:.2}")).unwrap_or_else(|| "-".into()),
            r.weight_sparsity.into(),
            r.act_sparsity.into(),
        ]);
    }

    // ---- prior block-sparse accelerators (qualitative comparison) ----
    for r in published::rows_block_sparse() {
        t.row(&[
            format!("{} (published)", r.name),
            r.tech.into(),
            r.sram.into(),
            format!("{:.1}", r.freq_ghz),
            r.tops.map(|v| format!("{v:.1}")).unwrap_or_else(|| "-".into()),
            r.tops_per_w.map(|v| format!("{v:.2}")).unwrap_or_else(|| "-".into()),
            r.tops_per_mm2.map(|v| format!("{v:.2}")).unwrap_or_else(|| "-".into()),
            r.weight_sparsity.into(),
            r.act_sparsity.into(),
        ]);
    }

    // ---- 65 nm group ----
    for nnz in [2usize, 3] {
        ours_row(&mut t, Tech::N65, nnz);
    }
    for r in published::rows_65nm() {
        t.row(&[
            format!("{} (published)", r.name),
            r.tech.into(),
            r.sram.into(),
            format!("{:.1}", r.freq_ghz),
            r.tops.map(|v| format!("{v:.1}")).unwrap_or_else(|| "-".into()),
            r.tops_per_w.map(|v| format!("{v:.2}")).unwrap_or_else(|| "-".into()),
            r.tops_per_mm2.map(|v| format!("{v:.2}")).unwrap_or_else(|| "-".into()),
            r.weight_sparsity.into(),
            r.act_sparsity.into(),
        ]);
    }
    vec![t]
}

/// SMT-SA efficiency on the Table V workload (ResNet-50, 62.5% weight
/// sparsity, 50% activations): timing from its thread-skipping model,
/// power/area from the shared 16 nm component library plus the per-PE
/// FIFOs the design needs.
pub fn smt_sa_efficiency(smt: &SmtSa) -> (f64, f64) {
    let lib = power::TechLib::for_tech(Tech::N16);
    let m = models::resnet50();
    let profiles = profile_model_repr(&m, 3, 8, 0.5);

    let mut cycles = 0u64;
    let mut active = 0u64;
    let mut gated = 0u64;
    let mut idle = 0u64;
    let mut wbytes = 0u64;
    let mut abytes = 0u64;
    let mut obytes = 0u64;
    let mut dense_macs = 0u64;
    for p in &profiles {
        let t = smt.gemm_timing(p.m, &p.weights, p.act_sparsity);
        cycles += t.events.cycles;
        active += t.events.macs_active;
        gated += t.events.macs_gated;
        idle += t.events.macs_idle;
        wbytes += t.events.weight_sram_bytes;
        abytes += t.events.act_sram_bytes;
        obytes += t.events.out_sram_bytes;
        dense_macs += t.dense_macs;
    }
    let secs = cycles as f64 / smt.freq_hz;

    // datapath + FIFO energy: every retired MAC pops two INT8 operands
    // from depth-4 FIFOs — write + read with full/empty bookkeeping and
    // depth muxing ≈ 10 register-byte equivalents per slot. The factor is
    // calibrated once against the paper's own re-implementation figure
    // (7.4 TOPS/W at 62.5% random / 50% act), the same methodology as the
    // Table IV anchor; the paper itself attributes SMT-SA's deficit
    // "largely to the cost of the FIFOs required in the array".
    let fifo_pj = (active + gated) as f64 * 10.0 * lib.e_opr_reg_byte_pj;
    let sta_pj = (active as f64 * lib.e_mac_active_pj
        + gated as f64 * lib.e_mac_clock_gated_pj
        + idle as f64 * lib.e_mac_idle_pj
        + fifo_pj)
        * (1.0 + lib.clock_overhead);
    let sram_pj =
        wbytes as f64 * lib.e_wsram_byte_pj + (abytes + obytes) as f64 * lib.e_asram_byte_pj;
    let mcu_mw = 4.0 * lib.mcu_mw_per_core;
    let mw = (sta_pj + sram_pj) * 1e-12 / secs * 1e3 + mcu_mw;

    let area = smt.macs as f64 * lib.a_mac_um2 / 1e6
        + smt.fifo_bits() as f64 * lib.a_reg_bit_um2 / 1e6
        + (smt.macs * 2 * 8 + smt.macs * 32) as f64 * lib.a_reg_bit_um2 / 1e6
        + 2.5 * lib.a_sram_mm2_per_mb
        + 4.0 * lib.a_mcu_mm2_per_core;

    let eff_tops = 2.0 * dense_macs as f64 / secs / 1e12;
    (eff_tops / (mw / 1e3), eff_tops / area)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_prints_four_variants() {
        let t = &table3()[0];
        assert_eq!(t.len(), 4);
    }

    #[test]
    fn table4_matches_anchor_within_tolerance() {
        let ts = table4();
        assert_eq!(ts.len(), 2);
        assert_eq!(ts[0].len(), 6);
    }

    #[test]
    fn table5_ours_beats_smt_sa_and_laconic_shape() {
        // the paper's headline comparison shape: ours @50% ≫ SMT-SA ≫ Laconic
        let smt = SmtSa::default();
        let (smt_tw, _) = smt_sa_efficiency(&smt);
        let d = Design::paper_optimal();
        let m = models::resnet50();
        let profiles = profile_model_repr(&m, 4, 8, 0.5);
        let timing = network_timing(&d, &profiles);
        let ours_50 = power::effective_tops_per_w(&d, &timing.total, timing.dense_macs);
        assert!(
            ours_50 > 1.5 * smt_tw,
            "ours@50% {ours_50:.1} should be well above SMT-SA {smt_tw:.1}"
        );
        assert!(smt_tw > 2.0, "SMT-SA should land in the >2 TOPS/W range, got {smt_tw:.1}");
        // paper: 16.8 TOPS/W = "more than 8x" Laconic's ~2; our model lands
        // at ~7.8x — the residual is recorded in EXPERIMENTS.md
        assert!(ours_50 > 7.5 * 2.0, "paper: ~8x Laconic's ~2 TOPS/W, got {ours_50:.1}");
    }

    #[test]
    fn smt_sa_within_factor_2_of_paper_figure() {
        // paper reports 7.4 TOPS/W for their INT8 SMT-SA re-implementation
        let (tw, tm) = smt_sa_efficiency(&SmtSa::default());
        assert!((3.7..14.8).contains(&tw), "TOPS/W={tw}");
        assert!(tm > 0.3, "TOPS/mm2={tm}");
    }

    #[test]
    fn ours_65nm_lands_near_paper() {
        // paper: 2.80 TOPS/W at 75% VDBB in 65 nm
        let mut d = Design::paper_optimal();
        d.tech = Tech::N65;
        let m = models::resnet50();
        let profiles = profile_model_repr(&m, 2, 8, 0.5);
        let timing = network_timing(&d, &profiles);
        let tw = power::effective_tops_per_w(&d, &timing.total, timing.dense_macs);
        assert!((1.4..5.6).contains(&tw), "65nm TOPS/W={tw}");
    }
}
