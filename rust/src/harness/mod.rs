//! Experiment harness: one driver per table and figure of the paper's
//! evaluation, each regenerating the same rows/series the paper reports
//! (DESIGN.md §Per-experiment index).
//!
//! Every driver returns [`crate::util::table::Table`]s so the CLI, the
//! examples and the bench targets share one implementation; `quick` mode
//! shrinks the training workloads (Tables I–II) for CI.

pub mod figures;
pub mod tables;

use crate::util::table::Table;

/// All experiment names, in paper order.
pub const EXPERIMENTS: &[&str] = &[
    "table1", "table2", "table3", "table4", "table5", "fig9", "fig10", "fig11", "fig12",
];

/// Run one experiment by name.
pub fn run(name: &str, quick: bool) -> Option<Vec<Table>> {
    Some(match name {
        "table1" => tables::table1(quick),
        "table2" => tables::table2(quick),
        "table3" => tables::table3(),
        "table4" => tables::table4(),
        "table5" => tables::table5(),
        "fig9" => figures::fig9(),
        "fig10" => figures::fig10(),
        "fig11" => figures::fig11(quick),
        "fig12" => figures::fig12(),
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_cheap_experiment_runs() {
        // smoke: the cheap drivers (everything but the training tables and
        // the full per-layer sweep) produce non-empty tables
        for name in ["table3", "table4", "table5", "fig9", "fig12"] {
            let ts = run(name, true).unwrap_or_else(|| panic!("unknown {name}"));
            assert!(!ts.is_empty(), "{name} returned no tables");
            for t in &ts {
                assert!(!t.is_empty(), "{name} empty table");
            }
        }
    }

    #[test]
    fn unknown_experiment_is_none() {
        assert!(run("table99", true).is_none());
    }
}
