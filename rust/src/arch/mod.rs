//! Accelerator architecture configuration — the `A×B×C_M×N` design-point
//! algebra of paper §IV (Fig. 6) plus flags (DBB / VDBB / IM2C / CG) and
//! technology node.
//!
//! Notation (paper Fig. 6): an `A×B×C_M×N` STA is an `M×N` 2-D systolic
//! array of tensor PEs; each TPE performs an `(A×B)·(B×C)` sub-matrix
//! multiply per step. The classic SA is the special case `1×1×1_M×N`.
//! Datapath variants change the per-TPE MAC provisioning (Table III):
//!
//! | variant   | MACs/TPE | note |
//! |-----------|----------|------|
//! | dense STA | A·B·C    | B-way dot products |
//! | STA-DBB   | A·b·C    | fixed b-of-B sparse dot products (S‹B›DP‹b›) |
//! | STA-VDBB  | A·C      | time-unrolled single-MAC S‹B›DP1 units |
//!
//! ### Nominal-TOPS convention (see DESIGN.md §Key modelling decisions)
//!
//! The paper quotes every design at "nominal 4 TOPS" and scales *effective*
//! throughput as nominal/density. For the time-unrolled VDBB array that
//! semantics requires the physical MAC count to equal the dense-equivalent
//! rate (a dense 8/8 block takes 8 cycles on one MAC — the same 1 MAC/elem
//! as the dense baseline). The paper's own labels (e.g. `4×8×8_4×8_VDBB`,
//! which has A·C·M·N = 1024 MACs by its own Table III) are internally
//! inconsistent with that 4-TOPS claim, so we size `M×N` to reach the
//! target MAC budget (the canonical optimal design here is
//! `4×8×8_8×8_VDBB_IM2C` = 2048 MACs) and keep the paper's throughput
//! semantics exactly. All reproduced *shapes* are unaffected.

pub mod reuse;
pub mod space;

use std::fmt;

/// Datapath variant.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Datapath {
    /// Dense (SA when 1×1×1, otherwise dense STA with B-way dot products).
    Dense,
    /// Fixed DBB: sparse dot products with `b` MACs per B-element block
    /// (supports only models with density ≤ b/B at full rate).
    FixedDbb {
        /// MACs per sparse dot product (the supported NNZ).
        b: usize,
    },
    /// Variable DBB: time-unrolled single-MAC units, any density 1/B..=B/B.
    Vdbb,
    /// Block-sparse-row: a `row_ptr`/`col_idx` scheduler walk skips whole
    /// `B×B` zero blocks; surviving blocks run **dense** on the full
    /// `A·B·C` MAC complement (SPOTS; SNIPPETS Snippet 1's BSR DMA/FSM).
    /// For this datapath the model `density` everywhere below is the
    /// *block* density — the fraction of the block grid that survives
    /// pruning — not the element density.
    Bsr,
}

/// Technology node for the physical model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tech {
    /// TSMC 16 nm FinFET, 1 GHz (paper's primary node).
    N16,
    /// TSMC 65 nm LP, 500 MHz (paper's comparison node).
    N65,
}

impl Tech {
    /// Clock frequency in Hz.
    pub fn freq_hz(&self) -> f64 {
        match self {
            Tech::N16 => 1.0e9,
            Tech::N65 => 0.5e9,
        }
    }
}

/// TPE dimensions and array shape.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ArrayDims {
    /// TPE activation rows.
    pub a: usize,
    /// TPE inner (block) dimension = DBB block size BZ for sparse variants.
    pub b: usize,
    /// TPE weight columns.
    pub c: usize,
    /// Array rows of TPEs.
    pub m: usize,
    /// Array columns of TPEs.
    pub n: usize,
}

impl ArrayDims {
    /// Total TPE count.
    pub fn tpes(&self) -> usize {
        self.m * self.n
    }
}

/// Config validation errors.
#[derive(Debug, PartialEq, Eq)]
pub enum ArchError {
    /// Any zero dimension.
    ZeroDim(ArrayDims),
    /// Fixed-DBB NNZ out of range.
    BadFixedNnz {
        /// Requested SDP width.
        b: usize,
        /// Block size.
        bz: usize,
    },
    /// Sparse datapaths need a real block dimension.
    SparseNeedsBlock(usize),
    /// Unparseable design string.
    Parse(String),
}

impl fmt::Display for ArchError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ArchError::ZeroDim(d) => write!(f, "dimensions must be non-zero: {d:?}"),
            ArchError::BadFixedNnz { b, bz } => {
                write!(f, "fixed-DBB b={b} must be in 1..B={bz}")
            }
            ArchError::SparseNeedsBlock(b) => {
                write!(f, "sparse datapath requires B>1 (got B={b})")
            }
            ArchError::Parse(s) => write!(f, "cannot parse design string `{s}`"),
        }
    }
}

impl std::error::Error for ArchError {}

/// A complete design point.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Design {
    /// Array geometry.
    pub dims: ArrayDims,
    /// Datapath variant.
    pub datapath: Datapath,
    /// Hardware IM2COL unit present (paper §IV-C).
    pub im2col: bool,
    /// Activation-zero clock gating. Per Table III this is only *effective*
    /// for single-MAC datapaths (SA and STA-VDBB); the power model applies
    /// full gating there and data-gating (reduced switching only) elsewhere.
    pub act_cg: bool,
    /// Technology node.
    pub tech: Tech,
}

impl Design {
    /// Validate dimensional constraints.
    pub fn validate(&self) -> Result<(), ArchError> {
        let d = self.dims;
        if d.a == 0 || d.b == 0 || d.c == 0 || d.m == 0 || d.n == 0 {
            return Err(ArchError::ZeroDim(d));
        }
        match self.datapath {
            Datapath::FixedDbb { b } => {
                if d.b < 2 {
                    return Err(ArchError::SparseNeedsBlock(d.b));
                }
                if b == 0 || b >= d.b {
                    return Err(ArchError::BadFixedNnz { b, bz: d.b });
                }
            }
            Datapath::Vdbb | Datapath::Bsr => {
                if d.b < 2 {
                    return Err(ArchError::SparseNeedsBlock(d.b));
                }
            }
            Datapath::Dense => {}
        }
        Ok(())
    }

    /// Physical MAC count of the whole array (Table III "MACs per TPE" ×
    /// M·N).
    pub fn physical_macs(&self) -> usize {
        let d = self.dims;
        let per_tpe = match self.datapath {
            // BSR blocks run dense, so the MAC provisioning is the dense
            // complement — the win is scheduler cycles, not silicon.
            Datapath::Dense | Datapath::Bsr => d.a * d.b * d.c,
            Datapath::FixedDbb { b } => d.a * b * d.c,
            Datapath::Vdbb => d.a * d.c,
        };
        per_tpe * d.tpes()
    }

    /// INT32 accumulator registers (Table III: A·C per TPE for every STA
    /// variant; 1 for the scalar SA).
    pub fn acc_regs(&self) -> usize {
        self.dims.a * self.dims.c * self.dims.tpes()
    }

    /// INT8 operand pipeline registers per TPE (Table III).
    pub fn opr_regs_per_tpe(&self) -> usize {
        let d = self.dims;
        match self.datapath {
            // BSR operand staging is the dense TPE's: surviving blocks
            // are dense A×B / B×C tiles.
            Datapath::Dense | Datapath::Bsr => d.b * (d.a + d.c),
            Datapath::FixedDbb { b } => d.a * d.b + b * d.c,
            // VDBB holds the A×B activation tile while streaming one
            // compressed weight per column (n=1 slot in flight).
            Datapath::Vdbb => d.a * d.b + d.c,
        }
    }

    /// Total operand registers.
    pub fn opr_regs(&self) -> usize {
        self.opr_regs_per_tpe() * self.dims.tpes()
    }

    /// B:1 activation multiplexers (one per physical MAC on sparse
    /// datapaths; none on dense).
    pub fn muxes(&self) -> usize {
        match self.datapath {
            // BSR has no per-element operand selection either: skipping
            // happens in the block scheduler, the datapath stays dense.
            Datapath::Dense | Datapath::Bsr => 0,
            _ => self.physical_macs(),
        }
    }

    /// Dense-equivalent MACs/cycle when running a model of weight `density`
    /// (= NNZ/BZ ∈ (0,1]). This is the paper's *effective throughput* core:
    ///
    /// * dense: physical rate, no benefit from sparsity;
    /// * fixed DBB b/B: blocks stream at 1/cycle when density ≤ b/B
    ///   (rate = physical × B/b); a denser model falls back to multi-pass
    ///   dense execution at the physical MAC rate;
    /// * VDBB: a block of B·density non-zeros occupies the unit for
    ///   B·density cycles while retiring B dense-equivalent elements —
    ///   rate = physical / density, for *any* density.
    pub fn dense_equiv_macs_per_cycle(&self, density: f64) -> f64 {
        let phys = self.physical_macs() as f64;
        match self.datapath {
            Datapath::Dense => phys,
            Datapath::FixedDbb { b } => {
                let design_density = b as f64 / self.dims.b as f64;
                if density <= design_density + 1e-12 {
                    phys / design_density
                } else {
                    phys // dense fallback
                }
            }
            Datapath::Vdbb => phys / density.max(1e-9),
            // BSR skips whole blocks: the array only ever sees surviving
            // blocks, so the dense-equivalent rate scales 1/block-density
            // (`density` is the block density here, see [`Datapath::Bsr`]).
            Datapath::Bsr => phys / density.max(1e-9),
        }
    }

    /// Nominal (dense-model) TOPS: 2 ops/MAC × physical rate × f.
    pub fn nominal_tops(&self) -> f64 {
        2.0 * self.physical_macs() as f64 * self.tech.freq_hz() / 1e12
    }

    /// Effective TOPS at a weight density (paper Table V "effective
    /// operations").
    pub fn effective_tops(&self, density: f64) -> f64 {
        2.0 * self.dense_equiv_macs_per_cycle(density) * self.tech.freq_hz() / 1e12
    }

    /// Peak effective TOPS — the highest effective rate the datapath can
    /// sustain at its sparsest supported density (1/B for VDBB, b/B for
    /// fixed DBB, dense otherwise). Used to provision the MCU complex
    /// (§IV-D quotes "8 MCUs for 16 TOPS", an effective figure).
    pub fn peak_effective_tops(&self) -> f64 {
        let min_density = match self.datapath {
            Datapath::Dense => 1.0,
            Datapath::FixedDbb { b } => b as f64 / self.dims.b as f64,
            Datapath::Vdbb => 1.0 / self.dims.b as f64,
            // the scheduler retires at most one block descriptor per block
            // slot, bounding the sustained speedup at B — symmetric with
            // VDBB's 1/B floor, just one granularity up.
            Datapath::Bsr => 1.0 / self.dims.b as f64,
        };
        self.effective_tops(min_density)
    }

    /// Weight operands entering the array per cycle (SRAM→edge bandwidth,
    /// bytes ≈ values for INT8). Per top-edge TPE and cycle: dense B·C
    /// values; fixed-DBB b·C compressed values; VDBB C compressed values.
    pub fn weight_edge_bytes_per_cycle(&self) -> f64 {
        let d = self.dims;
        let per_tpe = match self.datapath {
            // surviving BSR blocks stream dense values at the dense rate;
            // the (small) index stream is priced by the SRAM model
            Datapath::Dense | Datapath::Bsr => d.b * d.c,
            Datapath::FixedDbb { b } => b * d.c,
            Datapath::Vdbb => d.c,
        };
        (per_tpe * d.n) as f64
    }

    /// Activation operands entering per cycle. Dense/fixed-DBB left-edge
    /// TPEs consume an A×B tile per cycle; VDBB holds the tile for the
    /// block occupancy (`B·density` cycles on average).
    pub fn act_edge_bytes_per_cycle(&self, density: f64) -> f64 {
        let d = self.dims;
        let per_tpe = (d.a * d.b) as f64;
        match self.datapath {
            Datapath::Dense | Datapath::FixedDbb { .. } | Datapath::Bsr => per_tpe * d.m as f64,
            Datapath::Vdbb => per_tpe * d.m as f64 / (d.b as f64 * density).max(1.0),
        }
    }

    /// Render the paper-style design string, e.g. `4x8x8_8x8_VDBB_IM2C`.
    pub fn label(&self) -> String {
        let d = self.dims;
        let mut s = format!("{}x{}x{}_{}x{}", d.a, d.b, d.c, d.m, d.n);
        match self.datapath {
            Datapath::Dense => {}
            Datapath::FixedDbb { b } => s.push_str(&format!("_DBB{}of{}", b, d.b)),
            Datapath::Vdbb => s.push_str("_VDBB"),
            Datapath::Bsr => s.push_str("_BSR"),
        }
        if self.im2col {
            s.push_str("_IM2C");
        }
        if self.tech == Tech::N65 {
            s.push_str("_65nm");
        }
        s
    }

    /// Parse a design string (inverse of [`Design::label`]; also accepts the
    /// paper's bare `_DBB` for 4-of-B). `act_cg` defaults to on.
    pub fn parse(s: &str) -> Result<Design, ArchError> {
        let err = || ArchError::Parse(s.to_string());
        let mut parts = s.split('_');
        let dims_abc = parts.next().ok_or_else(err)?;
        let dims_mn = parts.next().ok_or_else(err)?;
        let abc: Vec<usize> = dims_abc
            .split('x')
            .map(|t| t.parse().map_err(|_| err()))
            .collect::<Result<_, _>>()?;
        let mn: Vec<usize> = dims_mn
            .split('x')
            .map(|t| t.parse().map_err(|_| err()))
            .collect::<Result<_, _>>()?;
        if abc.len() != 3 || mn.len() != 2 {
            return Err(err());
        }
        let dims = ArrayDims {
            a: abc[0],
            b: abc[1],
            c: abc[2],
            m: mn[0],
            n: mn[1],
        };
        let mut datapath = Datapath::Dense;
        let mut im2col = false;
        let mut tech = Tech::N16;
        for p in parts {
            if p == "VDBB" {
                datapath = Datapath::Vdbb;
            } else if p == "BSR" {
                datapath = Datapath::Bsr;
            } else if p == "IM2C" {
                im2col = true;
            } else if p == "65nm" {
                tech = Tech::N65;
            } else if let Some(rest) = p.strip_prefix("DBB") {
                let b = if rest.is_empty() {
                    dims.b / 2 // paper's bare "DBB" = half-density design
                } else {
                    rest.split("of").next().unwrap_or("").parse().map_err(|_| err())?
                };
                datapath = Datapath::FixedDbb { b };
            } else {
                return Err(err());
            }
        }
        let d = Design {
            dims,
            datapath,
            im2col,
            act_cg: true,
            tech,
        };
        d.validate()?;
        Ok(d)
    }

    /// The paper's pareto-optimal design (Table IV), in our sizing
    /// convention: `4×8×8_8×8_VDBB_IM2C` at 16 nm, 2048 MACs, nominal 4 TOPS.
    pub fn paper_optimal() -> Design {
        Design {
            dims: ArrayDims { a: 4, b: 8, c: 8, m: 8, n: 8 },
            datapath: Datapath::Vdbb,
            im2col: true,
            act_cg: true,
            tech: Tech::N16,
        }
    }

    /// The TPU-like baseline the paper normalizes to: `1×1×1_32×64`.
    pub fn baseline_sa() -> Design {
        Design {
            dims: ArrayDims { a: 1, b: 1, c: 1, m: 32, n: 64 },
            datapath: Datapath::Dense,
            im2col: false,
            act_cg: true,
            tech: Tech::N16,
        }
    }

    /// The fixed-DBB comparison design (4/8 density, paper Fig. 12).
    pub fn paper_fixed_dbb() -> Design {
        Design {
            dims: ArrayDims { a: 4, b: 8, c: 4, m: 4, n: 8 },
            datapath: Datapath::FixedDbb { b: 4 },
            im2col: true,
            act_cg: true,
            tech: Tech::N16,
        }
    }
}

impl fmt::Display for Design {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.label())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn baseline_is_4tops() {
        let d = Design::baseline_sa();
        assert_eq!(d.physical_macs(), 2048);
        assert!((d.nominal_tops() - 4.096).abs() < 1e-9);
    }

    #[test]
    fn optimal_vdbb_is_4tops_nominal() {
        let d = Design::paper_optimal();
        assert_eq!(d.physical_macs(), 2048);
        // effective scales 1/density: 3/8 density -> 4.096/0.375 ≈ 10.92
        let eff = d.effective_tops(3.0 / 8.0);
        assert!((eff - 4.096 / 0.375).abs() < 1e-9, "eff={eff}");
        // 1/8 density -> 8x nominal ≈ 32.8 TOPS (paper: "as much as 30")
        assert!((d.effective_tops(1.0 / 8.0) - 8.0 * 4.096).abs() < 1e-9);
    }

    #[test]
    fn fixed_dbb_steps_at_design_density() {
        let d = Design::paper_fixed_dbb();
        assert_eq!(d.physical_macs(), 4 * 4 * 4 * 32); // 2048
        // dense model: fallback at physical rate
        assert!((d.effective_tops(1.0) - 4.096).abs() < 1e-9);
        // at 4/8 and sparser: 2x
        assert!((d.effective_tops(0.5) - 8.192).abs() < 1e-9);
        assert!((d.effective_tops(0.25) - 8.192).abs() < 1e-9); // no further gain
    }

    #[test]
    fn vdbb_continuous_scaling() {
        let d = Design::paper_optimal();
        for nnz in 1..=8usize {
            let density = nnz as f64 / 8.0;
            let eff = d.effective_tops(density);
            assert!((eff - 4.096 / density).abs() < 1e-9, "nnz={nnz}");
        }
    }

    #[test]
    fn bsr_datapath_semantics() {
        // dense MAC provisioning (A·B·C per TPE), so the iso-4-TOPS grid
        // is 2x4 TPEs — same silicon budget as the dense STA
        let d = Design::parse("4x8x8_2x4_BSR_IM2C").unwrap();
        assert_eq!(d.physical_macs(), 2048);
        assert_eq!(d.muxes(), 0);
        assert_eq!(d.opr_regs_per_tpe(), 96);
        // effective rate scales 1/block-density, VDBB-style
        assert!((d.effective_tops(0.5) - 2.0 * 4.096).abs() < 1e-9);
        assert!((d.effective_tops(0.125) - 8.0 * 4.096).abs() < 1e-9);
        // weight edge streams dense block values: B·C per TPE × N=4
        assert_eq!(d.weight_edge_bytes_per_cycle(), 8.0 * 8.0 * 4.0);
        // BSR needs a real block dimension
        assert!(Design::parse("4x1x8_8x8_BSR").is_err());
    }

    #[test]
    fn label_parse_roundtrip() {
        for s in [
            "1x1x1_32x64",
            "4x8x8_8x8_VDBB_IM2C",
            "4x8x4_4x8_DBB4of8_IM2C",
            "2x8x2_8x8_VDBB",
            "4x8x8_8x8_VDBB_IM2C_65nm",
            "4x8x8_2x4_BSR_IM2C",
        ] {
            let d = Design::parse(s).unwrap();
            assert_eq!(d.label(), s, "roundtrip {s}");
        }
    }

    #[test]
    fn bare_dbb_suffix_means_half_density() {
        let d = Design::parse("4x8x4_4x8_DBB").unwrap();
        assert_eq!(d.datapath, Datapath::FixedDbb { b: 4 });
    }

    #[test]
    fn invalid_configs_rejected() {
        assert!(Design::parse("0x8x8_8x8_VDBB").is_err());
        assert!(Design::parse("4x1x8_8x8_VDBB").is_err()); // VDBB needs B>1
        assert!(Design::parse("4x8x8_8x8_DBB9of8").is_err()); // b >= B
        assert!(Design::parse("garbage").is_err());
    }

    #[test]
    fn table3_register_counts() {
        // dense STA 4x8x8: OPR = B(A+C) = 8*12 = 96/TPE
        let dense = Design::parse("4x8x8_2x4").unwrap();
        assert_eq!(dense.opr_regs_per_tpe(), 96);
        assert_eq!(dense.muxes(), 0);
        // DBB 4-of-8, 4x8x4: OPR = AB + bC = 32+16 = 48/TPE
        let dbb = Design::paper_fixed_dbb();
        assert_eq!(dbb.opr_regs_per_tpe(), 48);
        assert_eq!(dbb.muxes(), dbb.physical_macs());
        // VDBB 4x8x8: OPR = AB + C = 32+8 = 40/TPE
        let vdbb = Design::paper_optimal();
        assert_eq!(vdbb.opr_regs_per_tpe(), 40);
        assert_eq!(vdbb.acc_regs(), 4 * 8 * 64);
    }

    #[test]
    fn edge_bandwidth_vdbb_weight_side_is_compressed() {
        let v = Design::paper_optimal();
        // weight side: C per TPE column × N = 8*8 = 64 B/cyc regardless of density
        assert_eq!(v.weight_edge_bytes_per_cycle(), 64.0);
        // act side at 3/8: A*B*M / (B*density) = 4*8*8/3
        let act = v.act_edge_bytes_per_cycle(3.0 / 8.0);
        assert!((act - (4.0 * 8.0 * 8.0) / 3.0).abs() < 1e-9, "act={act}");
    }
}
