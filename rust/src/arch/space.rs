//! Design-space enumeration (paper §VI-A): generate the family of
//! iso-peak-throughput design points — every combination of TPE geometry,
//! datapath variant and IM2COL option, sized to the same nominal MAC budget
//! (4 TOPS ⇒ 2048 MACs at 1 GHz) — plus the curated 12-design subset used
//! in Figs 9 and 11.

use super::{ArrayDims, Datapath, Design, Tech};
use crate::util::par::{map_indexed, Parallelism};

/// MAC budget for a nominal 4 TOPS array at 1 GHz.
pub const MACS_4TOPS: usize = 2048;

/// Evaluate `eval` over every design point on the worker pool — one design
/// per task, pulled from a shared queue so expensive points (dense
/// fallbacks, deep occupancies) balance across threads — and return the
/// results in design order. This is the engine behind the Fig-9/10/11
/// sweeps and the `design_space` example; `Parallelism::serial()` gives the
/// original sequential sweep.
pub fn sweep<T, F>(designs: &[Design], par: Parallelism, eval: F) -> Vec<T>
where
    T: Send,
    F: Fn(&Design) -> T + Sync,
{
    map_indexed(designs.len(), par, |i| eval(&designs[i]))
}

/// Factor `total` into an (m, n) grid as near-square as possible with n ≥ m
/// (paper arrays are wider than tall, e.g. 32×64).
pub fn near_square_grid(total: usize) -> Option<(usize, usize)> {
    let mut best: Option<(usize, usize)> = None;
    for m in 1..=total {
        if m * m > total {
            break;
        }
        if total % m == 0 {
            best = Some((m, total / m));
        }
    }
    best
}

/// Enumerate the full iso-throughput design space at a MAC budget.
///
/// Candidate TPE geometries follow the paper: A, C ∈ {1, 2, 4, 8} with
/// B = 8 (the DBB block size) for tensor PEs, plus the scalar 1×1×1
/// baseline. For each geometry we emit the valid datapath variants
/// (dense; fixed-DBB 2/8 and 4/8; VDBB; BSR) × IM2COL on/off, keeping
/// only configurations whose per-TPE MAC count divides the budget.
pub fn enumerate(mac_budget: usize, tech: Tech) -> Vec<Design> {
    let mut out = Vec::new();
    let mut push = |dims: ArrayDims, dp: Datapath, im2c: bool| {
        let d = Design {
            dims,
            datapath: dp,
            im2col: im2c,
            act_cg: true,
            tech,
        };
        if d.validate().is_ok() {
            out.push(d);
        }
    };

    // scalar SA baseline (1x1x1)
    if let Some((m, n)) = near_square_grid(mac_budget / 2).map(|(m, n)| (m, n * 2)) {
        // prefer the paper's 32x64 aspect for 2048
        let dims = ArrayDims { a: 1, b: 1, c: 1, m, n };
        push(dims, Datapath::Dense, false);
        push(dims, Datapath::Dense, true);
    }

    let geoms: &[(usize, usize)] = &[(1, 8), (2, 2), (2, 4), (2, 8), (4, 4), (4, 8), (8, 8)];
    for &(a, c) in geoms {
        let b = 8usize;
        for dp in [
            Datapath::Dense,
            Datapath::FixedDbb { b: 2 },
            Datapath::FixedDbb { b: 4 },
            Datapath::Vdbb,
            Datapath::Bsr,
        ] {
            let per_tpe = match dp {
                Datapath::Dense | Datapath::Bsr => a * b * c,
                Datapath::FixedDbb { b: nnz } => a * nnz * c,
                Datapath::Vdbb => a * c,
            };
            if per_tpe == 0 || mac_budget % per_tpe != 0 {
                continue;
            }
            let tpes = mac_budget / per_tpe;
            let Some((m, n)) = near_square_grid(tpes) else {
                continue;
            };
            let dims = ArrayDims { a, b, c, m, n };
            for im2c in [false, true] {
                push(dims, dp, im2c);
            }
        }
    }
    out
}

/// The curated 12-design subset used for the per-layer power figure
/// (paper Fig. 11) and the breakdown bars (Fig. 9): baseline SA, dense
/// STAs, fixed-DBB and VDBB variants, with and without IM2COL.
pub fn representative_12(tech: Tech) -> Vec<Design> {
    let parse = |s: &str| {
        let mut d = Design::parse(s).expect("representative design parses");
        d.tech = tech;
        d
    };
    vec![
        parse("1x1x1_32x64"),            // TPU-like baseline (normalization point)
        parse("1x1x1_32x64_IM2C"),       // baseline + IM2COL
        parse("2x8x2_8x8"),              // dense STA, small TPE
        parse("4x8x4_4x4"),              // dense STA, large TPE (2048 MACs)
        parse("4x8x4_4x4_IM2C"),         // dense STA + IM2COL
        parse("2x8x2_8x16_DBB4of8"),     // fixed DBB, small TPE
        parse("4x8x4_4x8_DBB4of8"),      // fixed DBB (paper's DBB design)
        parse("4x8x4_4x8_DBB4of8_IM2C"), // fixed DBB + IM2COL
        parse("2x8x2_16x32_VDBB"),       // VDBB, small TPE
        parse("4x8x4_8x16_VDBB"),        // VDBB, mid TPE
        parse("4x8x8_8x8_VDBB"),         // VDBB, large TPE
        parse("4x8x8_8x8_VDBB_IM2C"),    // the pareto-optimal design (Table IV)
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn near_square_prefers_wide() {
        assert_eq!(near_square_grid(2048), Some((32, 64)));
        assert_eq!(near_square_grid(64), Some((8, 8)));
        assert_eq!(near_square_grid(1), Some((1, 1)));
        assert_eq!(near_square_grid(13), Some((1, 13)));
    }

    #[test]
    fn all_enumerated_designs_hit_budget() {
        let space = enumerate(MACS_4TOPS, Tech::N16);
        assert!(space.len() >= 30, "space too small: {}", space.len());
        for d in &space {
            assert_eq!(d.physical_macs(), MACS_4TOPS, "{}", d.label());
            d.validate().unwrap();
        }
    }

    #[test]
    fn space_contains_paper_families() {
        let space = enumerate(MACS_4TOPS, Tech::N16);
        let labels: Vec<String> = space.iter().map(|d| d.label()).collect();
        assert!(labels.iter().any(|l| l.starts_with("1x1x1")), "{labels:?}");
        assert!(labels.iter().any(|l| l.contains("VDBB")));
        assert!(labels.iter().any(|l| l.contains("DBB4of8")));
        assert!(labels.iter().any(|l| l.contains("IM2C")));
        assert!(labels.iter().any(|l| l.contains("BSR")), "{labels:?}");
    }

    #[test]
    fn representative_12_are_iso_throughput() {
        let reps = representative_12(Tech::N16);
        assert_eq!(reps.len(), 12);
        for d in &reps {
            assert_eq!(d.physical_macs(), MACS_4TOPS, "{}", d.label());
        }
        // normalization point first
        assert_eq!(reps[0].label(), "1x1x1_32x64");
        // the optimal design is present
        assert!(reps.iter().any(|d| d.label() == "4x8x8_8x8_VDBB_IM2C"));
    }

    #[test]
    fn sweep_preserves_design_order_and_matches_serial() {
        let space = enumerate(MACS_4TOPS, Tech::N16);
        let serial = sweep(&space, Parallelism::serial(), |d| d.physical_macs());
        let parallel = sweep(&space, Parallelism::threads(4), |d| d.physical_macs());
        assert_eq!(serial, parallel);
        let labels = sweep(&space, Parallelism::threads(8), |d| d.label());
        for (d, l) in space.iter().zip(&labels) {
            assert_eq!(&d.label(), l);
        }
    }

    #[test]
    fn no_duplicate_labels_in_space() {
        let space = enumerate(MACS_4TOPS, Tech::N16);
        let mut labels: Vec<String> = space.iter().map(|d| d.label()).collect();
        let n = labels.len();
        labels.sort();
        labels.dedup();
        assert_eq!(labels.len(), n);
    }
}
