//! Table III reuse algebra: inter-TPE, intra-TPE and accumulator reuse for
//! each datapath variant, as closed-form functions of the design point.
//!
//! These are verified two ways: unit tests against the paper's formulas, and
//! an integration test (`tests/table3_events.rs`) that checks the formulas
//! against *counted* MACs/operands in the detailed simulator.

use super::{Datapath, Design};

/// Inter-TPE operand reuse = array MACs / array input operands per cycle
/// (Table III row 4).
pub fn inter_tpe_reuse(d: &Design) -> f64 {
    let (a, b, c, m, n) = dims(d);
    match d.datapath {
        // AMCN / (AM + CN) with the SA special case A=B=C=1: MN/(M+N).
        // BSR surviving blocks are dense tiles, so the reuse algebra is
        // the dense one for the blocks that actually flow.
        Datapath::Dense | Datapath::Bsr => {
            (a * c * m * n) as f64 * b as f64 / ((a * b * m + c * b * n) as f64)
        }
        Datapath::FixedDbb { b: nnz } => {
            (a * nnz * c * m * n) as f64 / ((a * b * m + c * nnz * n) as f64)
        }
        // streaming one compressed weight per column: n=1 in Table III
        Datapath::Vdbb => (a * c * m * n) as f64 / ((a * b * m + c * n) as f64),
    }
}

/// Intra-TPE operand reuse = TPE MACs / TPE input operands (Table III row 5).
pub fn intra_tpe_reuse(d: &Design) -> f64 {
    let (a, b, c, _, _) = dims(d);
    match d.datapath {
        Datapath::Dense | Datapath::Bsr => (a * b * c) as f64 / (b * (a + c)) as f64,
        Datapath::FixedDbb { b: nnz } => (a * nnz * c) as f64 / (a * b + nnz * c) as f64,
        Datapath::Vdbb => (a * c) as f64 / (a * b + c) as f64,
    }
}

/// Accumulator reuse = MACs per accumulator register (Table III row 6):
/// B for a dense B-way dot product, b for the fixed-DBB SDP, 1 for the
/// single-MAC VDBB unit.
pub fn acc_reuse(d: &Design) -> usize {
    match d.datapath {
        Datapath::Dense | Datapath::Bsr => d.dims.b,
        Datapath::FixedDbb { b } => b,
        Datapath::Vdbb => 1,
    }
}

/// Whether activation-zero clock gating is effective (Table III row 7):
/// only single-MAC datapaths (classic SA, or VDBB) can gate on one zero
/// operand; a B-way dot product would need all B activations zero.
pub fn act_cg_effective(d: &Design) -> bool {
    match d.datapath {
        // BSR keeps B-way dot products inside surviving blocks, so it
        // inherits the dense rule (never single-MAC at B ≥ 2)
        Datapath::Dense | Datapath::Bsr => d.dims.b == 1,
        Datapath::FixedDbb { .. } => false,
        Datapath::Vdbb => true,
    }
}

/// Inter-TPE reuse at a concrete model bound `nnz` (Table III's symbolic
/// `n`): the VDBB block occupies the unit for `nnz` cycles while the A×B
/// activation tile stays resident, so reuse improves with the bound —
/// `AnCMN/(ABM + CnN)`. Dense/fixed-DBB are bound-independent.
pub fn inter_tpe_reuse_at(d: &Design, nnz: usize) -> f64 {
    match d.datapath {
        Datapath::Vdbb => {
            let (a, b, c, m, n) = dims(d);
            (a * nnz * c * m * n) as f64 / ((a * b * m + c * nnz * n) as f64)
        }
        _ => inter_tpe_reuse(d),
    }
}

/// Intra-TPE reuse at a concrete bound (Table III: `AnC/(AB + nC)`).
pub fn intra_tpe_reuse_at(d: &Design, nnz: usize) -> f64 {
    match d.datapath {
        Datapath::Vdbb => {
            let (a, b, c, _, _) = dims(d);
            (a * nnz * c) as f64 / (a * b + nnz * c) as f64
        }
        _ => intra_tpe_reuse(d),
    }
}

fn dims(d: &Design) -> (usize, usize, usize, usize, usize) {
    (d.dims.a, d.dims.b, d.dims.c, d.dims.m, d.dims.n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{ArrayDims, Design, Tech};

    fn mk(a: usize, b: usize, c: usize, m: usize, n: usize, dp: Datapath) -> Design {
        Design {
            dims: ArrayDims { a, b, c, m, n },
            datapath: dp,
            im2col: false,
            act_cg: true,
            tech: Tech::N16,
        }
    }

    #[test]
    fn sa_special_case_mn_over_m_plus_n() {
        // Table III col 1: SA reuse = MN/(M+N)
        let d = mk(1, 1, 1, 32, 64, Datapath::Dense);
        let expect = (32.0 * 64.0) / (32.0 + 64.0);
        assert!((inter_tpe_reuse(&d) - expect).abs() < 1e-12);
        assert!((intra_tpe_reuse(&d) - 0.5).abs() < 1e-12); // 1/2
        assert_eq!(acc_reuse(&d), 1);
        assert!(act_cg_effective(&d));
    }

    #[test]
    fn dense_sta_matches_table() {
        // STA: inter = AMCN/(AM+CN), intra = AC/(A+C)
        let d = mk(4, 8, 8, 2, 4, Datapath::Dense);
        let inter = (4.0 * 2.0 * 8.0 * 4.0) / (4.0 * 2.0 + 8.0 * 4.0);
        assert!((inter_tpe_reuse(&d) - inter).abs() < 1e-12);
        let intra = (4.0 * 8.0) / (4.0 + 8.0);
        assert!((intra_tpe_reuse(&d) - intra).abs() < 1e-12);
        assert_eq!(acc_reuse(&d), 8);
        assert!(!act_cg_effective(&d));
    }

    #[test]
    fn dbb_sta_matches_table() {
        // STA-DBB: inter = AbCMN/(ABM+CbN), intra = AbC/(AB+bC)
        let d = mk(4, 8, 4, 4, 8, Datapath::FixedDbb { b: 4 });
        let (a, b, c, m, n, nnz) = (4.0, 8.0, 4.0, 4.0, 8.0, 4.0);
        let inter = (a * nnz * c * m * n) / (a * b * m + c * nnz * n);
        assert!((inter_tpe_reuse(&d) - inter).abs() < 1e-12);
        let intra = (a * nnz * c) / (a * b + nnz * c);
        assert!((intra_tpe_reuse(&d) - intra).abs() < 1e-12);
        assert_eq!(acc_reuse(&d), 4);
        assert!(!act_cg_effective(&d));
    }

    #[test]
    fn vdbb_sta_matches_table() {
        // STA-VDBB: inter = AnCMN/(ABM+CnN) with n=1, intra = AnC/(AB+nC)
        let d = mk(4, 8, 8, 8, 8, Datapath::Vdbb);
        let (a, b, c, m, n) = (4.0, 8.0, 8.0, 8.0, 8.0);
        let inter = (a * c * m * n) / (a * b * m + c * n);
        assert!((inter_tpe_reuse(&d) - inter).abs() < 1e-12);
        let intra = (a * c) / (a * b + c);
        assert!((intra_tpe_reuse(&d) - intra).abs() < 1e-12);
        assert_eq!(acc_reuse(&d), 1);
        assert!(act_cg_effective(&d));
    }

    #[test]
    fn sta_beats_sa_on_reuse() {
        // the whole point of the STA (paper §IV-A): more reuse per operand
        let sa = Design::baseline_sa();
        let sta = mk(4, 8, 8, 2, 4, Datapath::Dense);
        assert!(intra_tpe_reuse(&sta) > intra_tpe_reuse(&sa));
    }

    #[test]
    fn vdbb_weight_stream_raises_inter_reuse() {
        // compressed weight stream (1 value/col/cycle) means higher
        // MACs-per-operand than the dense STA at the same dims
        let dense = mk(4, 8, 8, 8, 8, Datapath::Dense);
        let vdbb = mk(4, 8, 8, 8, 8, Datapath::Vdbb);
        let per_op_dense = inter_tpe_reuse(&dense) / (4.0 * 8.0 * 8.0); // per dense MAC
        let per_op_vdbb = inter_tpe_reuse(&vdbb) / (4.0 * 8.0);
        assert!(per_op_vdbb > per_op_dense);
    }
}
