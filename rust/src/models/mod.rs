//! Model zoo: the layer tables of the five benchmark networks the paper
//! evaluates (Table I) — LeNet-5, a 5-layer ConvNet, ResNet-50V1, VGG-16 and
//! MobileNetV1 — expressed as sequences of conv / FC layers with exact
//! shapes, so per-layer GEMM dimensions, MAC counts and weight counts are
//! reproduced from the published architectures. [`zoo`] extends the Table-I
//! set with [`transformer_block`], a ViT-Base-class encoder block whose
//! attention and MLP projections are plain [`LayerKind::Fc`] GEMMs — S2TA's
//! joint-sparsity argument (PAPERS.md) applies verbatim to its ReLU/GELU-
//! sparse MLP activations.
//!
//! The architecture experiments (Figs 9–12, Table V) run these layer tables
//! through the simulator; the training experiments (Tables I–II) train the
//! two small models end-to-end on synthetic datasets (see `crate::train`).

use crate::gemm::conv::ConvShape;

/// Layer kind.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerKind {
    /// Standard convolution.
    Conv(ConvShape),
    /// Depthwise convolution (MobileNet) — the paper runs these **dense**
    /// (DBB applies to pointwise layers only, §II-B).
    DepthwiseConv(ConvShape),
    /// Fully connected, `in_features → out_features` (GEMM with M = batch).
    Fc(usize, usize),
}

/// One network layer.
#[derive(Debug, Clone)]
pub struct Layer {
    /// Layer name (ResNet names follow the paper's `blkB/unitU/convC`).
    pub name: String,
    /// Shape information.
    pub kind: LayerKind,
    /// Whether DBB pruning applies (first conv layers are conventionally
    /// left dense, paper §V-A; depthwise convs fall back to dense).
    pub prunable: bool,
}

impl Layer {
    /// GEMM dimensions `(M, K, N)` for this layer at batch 1 (conv M is
    /// output pixels).
    ///
    /// Depthwise convs reduce each output channel over the `kh·kw` window of
    /// a *single* input channel, so their GEMM-equivalent K is `kh·kw` — not
    /// `kh·kw·c`, which would overcount the sampled profile and
    /// `WeightStats` by a factor of `c`. With this accounting
    /// `M·K·N == macs()` and `K·N == weights()` hold for every layer kind.
    pub fn gemm_dims(&self) -> (usize, usize, usize) {
        match self.kind {
            LayerKind::Conv(s) => (s.gemm_m(), s.gemm_k(), s.gemm_n()),
            LayerKind::DepthwiseConv(s) => (s.gemm_m(), s.kh * s.kw, s.oc),
            LayerKind::Fc(i, o) => (1, i, o),
        }
    }

    /// Weight parameter count.
    pub fn weights(&self) -> usize {
        match self.kind {
            LayerKind::Conv(s) => s.kh * s.kw * s.c * s.oc,
            // depthwise: one filter per channel
            LayerKind::DepthwiseConv(s) => s.kh * s.kw * s.c,
            LayerKind::Fc(i, o) => i * o,
        }
    }

    /// MACs at batch 1.
    pub fn macs(&self) -> u64 {
        match self.kind {
            LayerKind::Conv(s) => s.macs(),
            LayerKind::DepthwiseConv(s) => {
                (s.oh() * s.ow() * s.kh * s.kw * s.c) as u64
            }
            LayerKind::Fc(i, o) => (i * o) as u64,
        }
    }

    /// DBB density bound this layer runs at under a model-wide target
    /// `nnz` (paper Table I): prunable layers are bounded at `nnz`,
    /// non-prunable layers (first convs, depthwise) fall back to dense
    /// (`bound == bz`). Shared by the layer profiler and the prepared-model
    /// engine so both lower a model to identical per-layer encodings.
    pub fn dbb_bound(&self, nnz: usize, bz: usize) -> usize {
        if self.prunable {
            nnz.min(bz)
        } else {
            bz
        }
    }

    /// Convolution shape if this is a conv layer.
    pub fn conv_shape(&self) -> Option<ConvShape> {
        match self.kind {
            LayerKind::Conv(s) | LayerKind::DepthwiseConv(s) => Some(s),
            LayerKind::Fc(..) => None,
        }
    }
}

/// A whole network.
#[derive(Debug, Clone)]
pub struct Model {
    /// Model name.
    pub name: &'static str,
    /// Dataset it is associated with (informational).
    pub dataset: &'static str,
    /// Layers in execution order.
    pub layers: Vec<Layer>,
}

impl Model {
    /// Total weights over conv layers only (paper Table I footnote).
    pub fn conv_weights(&self) -> usize {
        self.layers
            .iter()
            .filter(|l| matches!(l.kind, LayerKind::Conv(_) | LayerKind::DepthwiseConv(_)))
            .map(|l| l.weights())
            .sum()
    }

    /// Total weights over prunable layers.
    pub fn prunable_weights(&self) -> usize {
        self.layers
            .iter()
            .filter(|l| l.prunable)
            .map(|l| l.weights())
            .sum()
    }

    /// Total MACs at batch 1.
    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs()).sum()
    }

    /// Layers that run on the GEMM datapath (everything; FC is GEMM too).
    pub fn gemm_layers(&self) -> impl Iterator<Item = &Layer> {
        self.layers.iter()
    }
}

fn conv(
    name: &str,
    h: usize,
    w: usize,
    c: usize,
    k: usize,
    oc: usize,
    stride: usize,
    pad: usize,
    prunable: bool,
) -> Layer {
    Layer {
        name: name.to_string(),
        kind: LayerKind::Conv(ConvShape {
            h,
            w,
            c,
            kh: k,
            kw: k,
            oc,
            stride,
            pad,
        }),
        prunable,
    }
}

/// LeNet-5 (MNIST, 28×28×1). Classic shape: conv 5×5×1×6 (pad 2), pool,
/// conv 5×5×6×16, pool, FC 400-120-84-10.
pub fn lenet5() -> Model {
    Model {
        name: "LeNet-5",
        dataset: "MNIST",
        layers: vec![
            conv("conv1", 28, 28, 1, 5, 6, 1, 2, false),
            conv("conv2", 14, 14, 6, 5, 16, 1, 0, true),
            Layer { name: "fc1".into(), kind: LayerKind::Fc(400, 120), prunable: true },
            Layer { name: "fc2".into(), kind: LayerKind::Fc(120, 84), prunable: true },
            Layer { name: "fc3".into(), kind: LayerKind::Fc(84, 10), prunable: false },
        ],
    }
}

/// 5-layer ConvNet (CIFAR-10, 32×32×3): 3 conv + 2 FC.
pub fn convnet5() -> Model {
    Model {
        name: "ConvNet",
        dataset: "CIFAR10",
        layers: vec![
            conv("conv1", 32, 32, 3, 5, 32, 1, 2, false),
            conv("conv2", 16, 16, 32, 5, 32, 1, 2, true),
            conv("conv3", 8, 8, 32, 5, 64, 1, 2, true),
            Layer { name: "fc1".into(), kind: LayerKind::Fc(1024, 64), prunable: true },
            Layer { name: "fc2".into(), kind: LayerKind::Fc(64, 10), prunable: false },
        ],
    }
}

/// VGG-16 (ImageNet, 224×224×3): the 13 conv layers (+3 FC).
pub fn vgg16() -> Model {
    let cfg: &[(usize, usize, usize)] = &[
        // (input hw, in c, out c); all 3x3 s1 p1, maxpool between groups
        (224, 3, 64),
        (224, 64, 64),
        (112, 64, 128),
        (112, 128, 128),
        (56, 128, 256),
        (56, 256, 256),
        (56, 256, 256),
        (28, 256, 512),
        (28, 512, 512),
        (28, 512, 512),
        (14, 512, 512),
        (14, 512, 512),
        (14, 512, 512),
    ];
    let mut layers: Vec<Layer> = cfg
        .iter()
        .enumerate()
        .map(|(i, &(hw, ci, co))| {
            conv(&format!("conv{}", i + 1), hw, hw, ci, 3, co, 1, 1, i > 0)
        })
        .collect();
    layers.push(Layer { name: "fc6".into(), kind: LayerKind::Fc(25088, 4096), prunable: true });
    layers.push(Layer { name: "fc7".into(), kind: LayerKind::Fc(4096, 4096), prunable: true });
    layers.push(Layer { name: "fc8".into(), kind: LayerKind::Fc(4096, 1000), prunable: false });
    Model {
        name: "VGG-16",
        dataset: "ImageNet",
        layers,
    }
}

/// ResNet-50 V1 (ImageNet): conv1 + 4 stages of bottleneck units. Layer
/// names follow the paper's Fig. 11 convention `blkB/unitU/convC`.
pub fn resnet50() -> Model {
    let mut layers = vec![conv("conv1", 224, 224, 3, 7, 64, 2, 3, false)];
    // (blocks, in hw after stage entry, bottleneck width, out channels)
    let stages: &[(usize, usize, usize, usize)] =
        &[(3, 56, 64, 256), (4, 28, 128, 512), (6, 14, 256, 1024), (3, 7, 512, 2048)];
    let mut in_c = 64; // after conv1 + maxpool
    for (bi, &(units, hw, width, out_c)) in stages.iter().enumerate() {
        for u in 0..units {
            let blk = bi + 1;
            let unit = u + 1;
            // stride-2 happens in the first unit of stages 2..4 (on conv2 in V1.5;
            // V1 puts it on conv1 of the unit — we follow V1: 1x1/2)
            let s = if u == 0 && bi > 0 { 2 } else { 1 };
            let hw_in = if u == 0 && bi > 0 { hw * 2 } else { hw };
            let p = |n: usize| format!("blk{blk}/unit{unit}/conv{n}");
            layers.push(conv(&p(1), hw_in, hw_in, in_c, 1, width, s, 0, true));
            layers.push(conv(&p(2), hw, hw, width, 3, width, 1, 1, true));
            layers.push(conv(&p(3), hw, hw, width, 1, out_c, 1, 0, true));
            if u == 0 {
                layers.push(conv(
                    &format!("blk{blk}/unit{unit}/shortcut"),
                    hw_in,
                    hw_in,
                    in_c,
                    1,
                    out_c,
                    s,
                    0,
                    true,
                ));
            }
            in_c = out_c;
        }
    }
    layers.push(Layer { name: "fc".into(), kind: LayerKind::Fc(2048, 1000), prunable: false });
    Model {
        name: "ResNet-50V1",
        dataset: "ImageNet",
        layers,
    }
}

/// MobileNetV1 1.0/224 (ImageNet): conv1 then 13 depthwise-separable pairs.
/// DBB applies to the pointwise (1×1) layers only (paper §II-B).
pub fn mobilenet_v1() -> Model {
    let mut layers = vec![conv("conv1", 224, 224, 3, 3, 32, 2, 1, false)];
    // (hw in, c in, c out, stride of dw)
    let cfg: &[(usize, usize, usize, usize)] = &[
        (112, 32, 64, 1),
        (112, 64, 128, 2),
        (56, 128, 128, 1),
        (56, 128, 256, 2),
        (28, 256, 256, 1),
        (28, 256, 512, 2),
        (14, 512, 512, 1),
        (14, 512, 512, 1),
        (14, 512, 512, 1),
        (14, 512, 512, 1),
        (14, 512, 512, 1),
        (14, 512, 1024, 2),
        (7, 1024, 1024, 1),
    ];
    for (i, &(hw, ci, co, s)) in cfg.iter().enumerate() {
        let n = i + 2;
        layers.push(Layer {
            name: format!("conv{n}/dw"),
            kind: LayerKind::DepthwiseConv(ConvShape {
                h: hw,
                w: hw,
                c: ci,
                kh: 3,
                kw: 3,
                oc: ci,
                stride: s,
                pad: 1,
            }),
            prunable: false, // dense fallback
        });
        let hw_pw = hw / s;
        layers.push(Layer {
            name: format!("conv{n}/pw"),
            kind: LayerKind::Conv(ConvShape {
                h: hw_pw,
                w: hw_pw,
                c: ci,
                kh: 1,
                kw: 1,
                oc: co,
                stride: 1,
                pad: 0,
            }),
            prunable: true,
        });
    }
    layers.push(Layer { name: "fc".into(), kind: LayerKind::Fc(1024, 1000), prunable: false });
    Model {
        name: "MobileNetV1",
        dataset: "ImageNet",
        layers,
    }
}

/// One ViT-Base-class transformer encoder block (d=768, MLP 4×), expressed
/// as the four GEMMs the datapath actually sees: fused QKV projection,
/// attention output projection, and the two MLP projections. All per-token
/// (GEMM M = 1, like batch-1 CNN accounting); serving folds the sequence
/// dimension into GEMM M via `execute_fused_batch`, exactly as image batches
/// fold for the CNNs. The MLP tail is left dense (the residual-stream output
/// projection is the conventionally unpruned layer), so the FC-only model
/// exercises both packed-DBB and dense-fallback operands.
pub fn transformer_block() -> Model {
    const D: usize = 768;
    Model {
        name: "TransformerBlock",
        dataset: "Seq",
        layers: vec![
            Layer { name: "attn/qkv".into(), kind: LayerKind::Fc(D, 3 * D), prunable: true },
            Layer { name: "attn/proj".into(), kind: LayerKind::Fc(D, D), prunable: true },
            Layer { name: "mlp/fc1".into(), kind: LayerKind::Fc(D, 4 * D), prunable: true },
            Layer { name: "mlp/fc2".into(), kind: LayerKind::Fc(4 * D, D), prunable: false },
        ],
    }
}

/// All five benchmark models (Table I rows).
pub fn all_models() -> Vec<Model> {
    vec![lenet5(), convnet5(), resnet50(), vgg16(), mobilenet_v1()]
}

/// The full serving zoo: the five Table-I CNNs plus [`transformer_block`].
/// This is the set the prepared-model engine, the coordinator's model
/// registry and `examples/scenario_sweep` resolve names against; Table-I
/// reproductions keep using [`all_models`].
pub fn zoo() -> Vec<Model> {
    let mut v = all_models();
    v.push(transformer_block());
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lenet_weights() {
        let m = lenet5();
        // conv1 150, conv2 2400, fc 48000+10080+840
        assert_eq!(m.conv_weights(), 150 + 2400);
        let total: usize = m.layers.iter().map(|l| l.weights()).sum();
        assert_eq!(total, 150 + 2400 + 48_000 + 10_080 + 840);
    }

    #[test]
    fn vgg16_conv_weights_published() {
        let m = vgg16();
        // published VGG-16 conv parameter count ≈ 14.71M
        let w = m.conv_weights();
        assert!((14_600_000..14_800_000).contains(&w), "w={w}");
    }

    #[test]
    fn resnet50_totals_published() {
        let m = resnet50();
        let w = m.conv_weights();
        // ResNet-50 conv weights ≈ 23.45M (total 25.5M incl. fc+bn)
        assert!((23_000_000..24_000_000).contains(&w), "w={w}");
        let macs = m.total_macs();
        // ≈ 3.8 GMACs on 224x224 input (V1, conv s=2 in unit conv1)
        assert!((3_300_000_000..4_300_000_000).contains(&macs), "macs={macs}");
    }

    #[test]
    fn mobilenet_totals_published() {
        let m = mobilenet_v1();
        let macs = m.total_macs();
        // MobileNetV1 ≈ 569 MMACs
        assert!((520_000_000..620_000_000).contains(&macs), "macs={macs}");
        // pointwise layers dominate and are prunable
        let pw: usize = m.prunable_weights();
        let total = m.conv_weights();
        assert!(pw as f64 / total as f64 > 0.9, "pw={pw} total={total}");
    }

    #[test]
    fn resnet_names_match_paper_convention() {
        let m = resnet50();
        assert!(m.layers.iter().any(|l| l.name == "blk1/unit3/conv3"));
        assert!(m.layers.iter().any(|l| l.name == "blk4/unit3/conv3"));
    }

    #[test]
    fn gemm_dims_consistent_with_macs() {
        // every layer kind, depthwise included (regression: DepthwiseConv
        // used to report K = kh·kw·c, overcounting by a factor of c)
        for m in all_models() {
            for l in &m.layers {
                let (mm, k, n) = l.gemm_dims();
                assert_eq!((mm * k * n) as u64, l.macs(), "{}/{}", m.name, l.name);
            }
        }
    }

    #[test]
    fn depthwise_gemm_dims_match_weights_and_macs() {
        let m = mobilenet_v1();
        let dw = m
            .layers
            .iter()
            .find(|l| matches!(l.kind, LayerKind::DepthwiseConv(_)))
            .unwrap();
        let s = dw.conv_shape().unwrap();
        let (mm, k, n) = dw.gemm_dims();
        assert_eq!(k, s.kh * s.kw, "depthwise K is one window, not kh·kw·c");
        assert_eq!(n, s.oc);
        assert_eq!(mm, s.oh() * s.ow());
        assert_eq!(k * n, dw.weights(), "{}", dw.name);
        assert_eq!((mm * k * n) as u64, dw.macs(), "{}", dw.name);
    }

    #[test]
    fn dbb_bound_dense_fallback() {
        let m = mobilenet_v1();
        let dw = m
            .layers
            .iter()
            .find(|l| matches!(l.kind, LayerKind::DepthwiseConv(_)))
            .unwrap();
        assert_eq!(dw.dbb_bound(3, 8), 8, "non-prunable layers run dense");
        let pw = m.layers.iter().find(|l| l.name.ends_with("/pw")).unwrap();
        assert_eq!(pw.dbb_bound(3, 8), 3);
        assert_eq!(pw.dbb_bound(12, 8), 8, "bound clamps at bz");
    }

    #[test]
    fn transformer_block_gemm_totals() {
        let m = transformer_block();
        // ViT-Base block: qkv 768·2304 + proj 768² + mlp 768·3072·2 ≈ 7.08M
        // weights, and at M=1 every FC layer's MACs equal its weights
        let w: usize = m.layers.iter().map(|l| l.weights()).sum();
        assert_eq!(w, 768 * 2304 + 768 * 768 + 2 * 768 * 3072);
        assert_eq!(m.total_macs(), w as u64);
        for l in &m.layers {
            let (mm, k, n) = l.gemm_dims();
            assert_eq!(mm, 1, "{} is a per-token FC GEMM", l.name);
            assert_eq!(k * n, l.weights(), "{}", l.name);
        }
        // the unpruned residual-stream tail runs dense
        assert!(m.layers.last().unwrap().dbb_bound(3, 8) == 8);
        assert_eq!(m.prunable_weights(), 768 * 2304 + 768 * 768 + 768 * 3072);
    }

    #[test]
    fn zoo_is_table_one_plus_transformer() {
        let zoo = zoo();
        assert_eq!(zoo.len(), all_models().len() + 1);
        assert_eq!(zoo.last().unwrap().name, "TransformerBlock");
        let mut names: Vec<&str> = zoo.iter().map(|m| m.name).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), zoo.len(), "zoo names must be unique keys");
    }

    #[test]
    fn vgg_gemm_m_is_pixel_count() {
        let m = vgg16();
        let (mm, k, n) = m.layers[0].gemm_dims();
        assert_eq!(mm, 224 * 224);
        assert_eq!(k, 27);
        assert_eq!(n, 64);
    }
}
