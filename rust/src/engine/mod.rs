//! Prepared-model inference engine: pack operands once, execute many.
//!
//! The paper's whole deployment story (§II-A) is an offline/online split:
//! DBB weights are *encoded offline* and the accelerator *streams* the
//! fixed-rate compressed operand at runtime — encoding cost is paid once
//! per model, never per inference. This module is that split in software.
//! [`PreparedModel::prepare`] lowers each layer of a [`Model`] exactly once
//! into a [`PreparedLayer`]:
//!
//! * a **packed weight operand** ([`PackedOperand`]) — either the flattened
//!   `(col_ptr, entries)` CSC stream ([`crate::gemm::DbbPacked`]) that the
//!   DBB row kernels consume, decoded here and never again, or a dense
//!   `[K, N]` INT8 matrix for layers that run unpruned;
//! * a **fused-conv descriptor** ([`SampleShape`]) — the sampled window
//!   geometry (same kernel/stride/pad as the full layer) the functional
//!   pass convolves, plus the static profile facts (GEMM `M`, IM2COL
//!   magnification, raw activation bytes) the timing model needs;
//! * a share of the model's **preallocated per-worker scratch arena**
//!   ([`crate::gemm::fused::PatchScratch`]) — the streaming-IM2COL row
//!   buffers every conv layer draws from.
//!
//! [`PreparedModel::execute`] then runs the whole network through the
//! existing [`crate::gemm::fused`] / [`crate::gemm::tiled`] kernels with
//! **zero encode/decode work and zero per-call weight-operand allocation**,
//! bit-exact with the per-call-encoding path it replaced (the shared
//! `dbb_rows_i8`-family inner kernels guarantee it).
//! [`PreparedModel::profile`] replays the seeded sampled inference of
//! `sim::accel::profile_model` — same seed, same RNG draw order, same
//! per-layer activation sparsities to the last bit — and records the
//! measured sparsities *into* the prepared model, where the serving
//! coordinator's hardware twin reads them.
//!
//! ## Activation-side zero-gating
//!
//! The measured per-layer sparsities are not just reported — they are *fed
//! back into the kernels*. Every execute resolves a
//! [`crate::gemm::ZeroGate`] policy per layer (the model-level default is
//! [`ZeroGate::Auto`]; see [`PreparedModel::set_zero_gate`] /
//! [`PreparedModel::execute_gated`]): `Auto` consults the layer's
//! *measured* activation sparsity from the recorded profile (falling back
//! to the zero fraction of the current input operand, which the execute
//! loop measures anyway) and engages the zero-gated row kernels only where
//! gating pays. The same measured values price the A-side gating in the
//! hardware twin's timing model (the `act_sparsity` field of
//! [`crate::sim::accel::LayerProfile`]) — one sparsity source for the
//! priced datapath gate and the software gate. Gating is bit-exact, so
//! [`Execution::output`] is identical under every policy
//! (`rust/tests/zero_gate.rs`); the per-layer decisions are reported in
//! [`Execution::gate_engaged`].

use crate::dbb::DbbMatrix;
use crate::gemm::conv::ConvShape;
use crate::gemm::fused::{self, PatchScratch};
use crate::gemm::tiled;
use crate::gemm::{DbbPacked, ZeroGate};
use crate::models::{LayerKind, Model};
use crate::sim::accel::{requant_relu, LayerProfile};
use crate::sim::analytic::WeightStats;
use crate::sim::im2col::Im2colUnit;
use crate::tensor::TensorI8;
use crate::util::par::map_indexed;
use crate::util::{Parallelism, Rng};
use std::sync::Mutex;

/// Cap on sampled GEMM rows/cols for the functional sparsity measurement
/// (keeps ResNet/VGG preparation fast; sparsity is a statistical mean over
/// ≥64k requantized outputs per layer at these caps — §Perf).
const SAMPLE_ROWS: usize = 256;
const SAMPLE_COLS: usize = 256;
/// Width (in output pixels) of the sampled conv window; the height is then
/// chosen so the window holds at most [`SAMPLE_ROWS`] output pixels.
const SAMPLE_WIN_COLS: usize = 16;

/// Zero fraction of the synthetic input image fed to the first layer:
/// natural images are dense (≈0% zeros after normalization).
const SEED_ACT_SPARSITY: f32 = 0.02;

/// Conv geometry of the sampled sub-window: same kernel/stride/pad as the
/// full layer, input cropped so the output window has ≤ [`SAMPLE_ROWS`]
/// pixels. `c`/`ns` override channels (depthwise samples one channel).
fn sample_shape(s: &ConvShape, c: usize, ns: usize) -> ConvShape {
    let ow_s = s.ow().min(SAMPLE_WIN_COLS).max(1);
    let oh_s = s.oh().min((SAMPLE_ROWS / ow_s).max(1));
    ConvShape {
        h: ((oh_s - 1) * s.stride + s.kh).saturating_sub(2 * s.pad).max(1),
        w: ((ow_s - 1) * s.stride + s.kw).saturating_sub(2 * s.pad).max(1),
        c,
        kh: s.kh,
        kw: s.kw,
        oc: ns,
        stride: s.stride,
        pad: s.pad,
    }
}

/// Fit a propagated feature map to a layer's sampled input shape by
/// wrap-around tiling (spatial dims and channels), preserving the measured
/// value/zero structure. An exact-shape match is an identity copy, which is
/// what keeps [`PreparedModel::profile`] bit-exact: the stored seed input
/// passes through unchanged.
fn fit_fmap_from(p: &TensorI8, h: usize, w: usize, c: usize) -> TensorI8 {
    if p.shape().len() != 3 {
        // non-spatial input (matrix / flat vector): wrap the raw data
        let pd = p.data();
        let data = (0..h * w * c).map(|i| pd[i % pd.len()]).collect();
        return TensorI8::from_vec(&[h, w, c], data);
    }
    let (ph, pw, pc) = (p.shape()[0], p.shape()[1], p.shape()[2]);
    let mut out = TensorI8::zeros(&[h, w, c]);
    for y in 0..h {
        for x in 0..w {
            for ci in 0..c {
                out.set(&[y, x, ci], p.at(&[y % ph, x % pw, ci % pc]));
            }
        }
    }
    out
}

/// FC analogue of [`fit_fmap_from`]: wrap the flattened feature map into an
/// `[m, k]` operand sample.
fn fit_matrix_from(p: &TensorI8, m: usize, k: usize) -> TensorI8 {
    let pd = p.data();
    TensorI8::from_vec(&[m, k], (0..m * k).map(|i| pd[i % pd.len()]).collect())
}

/// The fused-conv descriptor of a prepared layer: what geometry the
/// functional pass runs (the sampled window keeps the full layer's
/// kernel/stride/pad; FC layers sample GEMM rows).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SampleShape {
    /// Sampled conv window (standard or depthwise; depthwise samples one
    /// channel).
    Conv(ConvShape),
    /// Sampled FC GEMM: `m` rows over the layer's full `k`.
    Fc {
        /// Sampled GEMM rows (`min(M, SAMPLE_ROWS)`).
        m: usize,
        /// Reduction dim (the layer's full input features).
        k: usize,
    },
}

/// A weight operand lowered exactly once at prepare time.
#[derive(Debug, Clone)]
pub enum PackedOperand {
    /// DBB-bounded layer: the flattened CSC stream, decoded at prepare.
    Dbb(DbbPacked),
    /// Dense-fallback layer (non-prunable / bound == bz): the `[K, N]`
    /// GEMM right operand.
    Dense(TensorI8),
}

impl PackedOperand {
    /// Host bytes of the packed operand held in steady state.
    pub fn operand_bytes(&self) -> usize {
        match self {
            PackedOperand::Dbb(p) => p.operand_bytes(),
            PackedOperand::Dense(w) => w.len(),
        }
    }
}

/// One layer, lowered once: packed operand + sampled geometry + the static
/// profile facts the timing/power models consume.
#[derive(Debug, Clone)]
pub struct PreparedLayer {
    /// Layer name.
    pub name: String,
    /// Full-layer GEMM rows (output pixels × batch 1).
    pub m: usize,
    /// Weight statistics (synthetic-exact for magnitude-pruned weights).
    pub weights: WeightStats,
    /// Sampled execution geometry.
    pub sample: SampleShape,
    /// The weight operand, encoded/decoded exactly once.
    pub operand: PackedOperand,
    /// IM2COL duplication this layer offers (1.0 for FC/1×1).
    pub im2col_magnification: f64,
    /// Raw input bytes (feature map / FC input vector).
    pub raw_act_bytes: u64,
    /// Output elements (for MCU post-processing).
    pub out_elems: u64,
    /// Followed by ReLU?
    pub relu: bool,
}

/// Result of one [`PreparedModel::execute`] pass.
#[derive(Debug, Clone)]
pub struct Execution {
    /// Final layer's requantized INT8 output.
    pub output: TensorI8,
    /// Measured zero fraction of each layer's fitted *input* operand (the
    /// raw feature map / FC matrix as fed to the layer, before any IM2COL
    /// expansion — the same convention as
    /// [`crate::sim::accel::LayerProfile::act_sparsity`]).
    pub act_sparsity: Vec<f64>,
    /// Whether the activation zero-gate engaged for each layer (always all
    /// `false` under [`ZeroGate::Off`], all `true` under [`ZeroGate::On`];
    /// under [`ZeroGate::Auto`] the per-layer threshold decision).
    pub gate_engaged: Vec<bool>,
}

/// A model lowered once, executable many times: the software twin of the
/// paper's offline-encode / runtime-stream split (§II-A).
#[derive(Debug)]
pub struct PreparedModel {
    name: &'static str,
    nnz: usize,
    bz: usize,
    seed: u64,
    layers: Vec<PreparedLayer>,
    seed_input: TensorI8,
    /// Recorded by [`Self::profile`]; empty until a functional profile ran.
    measured_act: Vec<f64>,
    /// Model-level default gating policy [`Self::execute`] applies
    /// (default [`ZeroGate::Auto`]).
    zero_gate: ZeroGate,
    /// Per-worker streaming-IM2COL row buffers, preallocated at prepare and
    /// reused by every [`Self::execute`] (concurrent executes fall back to
    /// a transient arena rather than blocking).
    scratch: Mutex<PatchScratch>,
}

impl PreparedModel {
    /// Lower every layer of `model` exactly once: draw the synthetic
    /// DBB-pruned INT8 weights from `seed` (identical RNG draw order to the
    /// historical per-call path, so measured sparsities reproduce
    /// bit-for-bit), encode + pack each prunable layer's operand on the
    /// `par` worker pool, and preallocate the per-worker scratch arena.
    ///
    /// `nnz` is the model-wide DBB target (paper Table I, e.g. 3/8 for
    /// ResNet-50); non-prunable layers fall back to dense.
    pub fn prepare(model: &Model, nnz: usize, bz: usize, seed: u64, par: Parallelism) -> Self {
        let mut rng = Rng::new(seed);
        let nlayers = model.layers.len();

        // Pass 1 (serial): draw the synthetic weights — and, right after the
        // first layer's weights, the seed input — in the exact RNG order the
        // per-call profiler used, so seeded results are unchanged.
        let mut dense = Vec::with_capacity(nlayers);
        let mut samples = Vec::with_capacity(nlayers);
        let mut seed_input: Option<TensorI8> = None;
        for l in &model.layers {
            let (m, k, n) = l.gemm_dims();
            let ns = n.min(SAMPLE_COLS);
            let w_dense = TensorI8::rand(&[k, ns], &mut rng);
            let sample = match l.kind {
                LayerKind::Conv(s) | LayerKind::DepthwiseConv(s) => {
                    let chans = if matches!(l.kind, LayerKind::Conv(_)) { s.c } else { 1 };
                    SampleShape::Conv(sample_shape(&s, chans, ns))
                }
                LayerKind::Fc(..) => SampleShape::Fc { m: m.min(SAMPLE_ROWS), k },
            };
            if seed_input.is_none() {
                seed_input = Some(match sample {
                    SampleShape::Conv(ss) => {
                        TensorI8::rand_sparse(&[ss.h, ss.w, ss.c], SEED_ACT_SPARSITY, &mut rng)
                    }
                    SampleShape::Fc { m, k } => {
                        TensorI8::rand_sparse(&[m, k], SEED_ACT_SPARSITY, &mut rng)
                    }
                });
            }
            dense.push(w_dense);
            samples.push(sample);
        }

        // Pass 2 (worker pool): the one-time encode — fused top-k prune +
        // DBB compress + CSC pack per prunable layer. This is the *only*
        // place the engine ever encodes or decodes a weight operand.
        let operands: Vec<PackedOperand> = map_indexed(nlayers, par, |li| {
            let l = &model.layers[li];
            let bound = l.dbb_bound(nnz, bz);
            if bound < bz {
                let enc =
                    DbbMatrix::compress_topk(&dense[li], bz, bound).expect("valid block size");
                PackedOperand::Dbb(enc.pack())
            } else {
                PackedOperand::Dense(dense[li].clone())
            }
        });

        let layers: Vec<PreparedLayer> = model
            .layers
            .iter()
            .zip(samples)
            .zip(operands)
            .enumerate()
            .map(|(li, ((l, sample), operand))| {
                let (m, k, n) = l.gemm_dims();
                let bound = l.dbb_bound(nnz, bz);
                let (im2c, raw) = match l.kind {
                    LayerKind::Conv(s) | LayerKind::DepthwiseConv(s) => (
                        Im2colUnit::default().magnification(&s),
                        (s.h * s.w * s.c) as u64,
                    ),
                    LayerKind::Fc(i, _) => (1.0, i as u64),
                };
                PreparedLayer {
                    name: l.name.clone(),
                    m,
                    weights: WeightStats::synthetic(k, n, bz, bound),
                    sample,
                    operand,
                    im2col_magnification: im2c,
                    raw_act_bytes: raw,
                    out_elems: (m * n) as u64,
                    relu: li + 1 < nlayers,
                }
            })
            .collect();

        let max_k = layers
            .iter()
            .filter_map(|l| match l.sample {
                SampleShape::Conv(ss) => Some(ss.gemm_k()),
                SampleShape::Fc { .. } => None,
            })
            .max()
            .unwrap_or(0);
        PreparedModel {
            name: model.name,
            nnz,
            bz,
            seed,
            layers,
            seed_input: seed_input.unwrap_or_else(|| TensorI8::zeros(&[1, 1, 1])),
            measured_act: Vec::new(),
            zero_gate: ZeroGate::default(),
            scratch: Mutex::new(PatchScratch::preallocate(par.get(), max_k)),
        }
    }

    /// The model-level default [`ZeroGate`] policy.
    pub fn zero_gate(&self) -> ZeroGate {
        self.zero_gate
    }

    /// Override the default gating policy [`Self::execute`] applies.
    /// Gating never changes a result bit; this is a performance knob.
    pub fn set_zero_gate(&mut self, gate: ZeroGate) {
        self.zero_gate = gate;
    }

    /// The measured per-layer activation sparsities — `Some` once
    /// [`Self::profile`] ran. This is the **one sparsity source** shared by
    /// the software gate (`Auto` consults it per layer) and the hardware
    /// twin's priced A-side gating ([`Self::profiles`] copies the same
    /// values into [`LayerProfile::act_sparsity`]).
    pub fn measured_act_sparsity(&self) -> Option<&[f64]> {
        if self.measured_act.len() != self.layers.len() {
            return None;
        }
        Some(&self.measured_act)
    }

    /// Run the whole network on `input` (any non-empty feature map /
    /// matrix; it is wrap-fitted to the first layer's sampled shape) with
    /// zero encode/decode work: every layer streams its prepared operand
    /// through the fused/tiled kernels, under the model-level default
    /// [`ZeroGate`] policy ([`ZeroGate::Auto`] unless
    /// [`Self::set_zero_gate`] changed it). Repeated calls with the same
    /// input return identical results — the engine holds no mutable state
    /// beyond the scratch buffers, which are fully rewritten before every
    /// read, and gating never changes a bit.
    pub fn execute(&self, input: &TensorI8, par: Parallelism) -> Execution {
        self.execute_gated(input, par, self.zero_gate)
    }

    /// [`Self::execute`] under an explicit [`ZeroGate`] policy. `Auto`
    /// resolves per layer against the *measured* activation sparsity the
    /// recorded profile holds for that layer (the same value the hardware
    /// twin prices), falling back to the zero fraction of the layer's
    /// current input operand — which the execute loop measures anyway — on
    /// an unprofiled model. The drivers receive a pre-resolved `On`/`Off`,
    /// so no operand is scanned twice.
    pub fn execute_gated(&self, input: &TensorI8, par: Parallelism, gate: ZeroGate) -> Execution {
        match self.scratch.try_lock() {
            Ok(mut guard) => self.execute_gated_with(input, par, gate, &mut guard),
            // a panicked execute poisoned the arena: the buffers are fully
            // rewritten before every read, so reclaiming them is safe
            Err(std::sync::TryLockError::Poisoned(p)) => {
                self.execute_gated_with(input, par, gate, &mut p.into_inner())
            }
            // another execute holds the arena: run on a transient one
            Err(std::sync::TryLockError::WouldBlock) => {
                self.execute_gated_with(input, par, gate, &mut PatchScratch::new())
            }
        }
    }

    /// [`Self::execute`] on a caller-owned scratch arena (model-level
    /// default gating policy).
    pub fn execute_with(
        &self,
        input: &TensorI8,
        par: Parallelism,
        scratch: &mut PatchScratch,
    ) -> Execution {
        self.execute_gated_with(input, par, self.zero_gate, scratch)
    }

    /// [`Self::execute_gated`] on a caller-owned scratch arena.
    pub fn execute_gated_with(
        &self,
        input: &TensorI8,
        par: Parallelism,
        gate: ZeroGate,
        scratch: &mut PatchScratch,
    ) -> Execution {
        assert!(!input.is_empty(), "execute input must be non-empty");
        let mut act_sparsity = Vec::with_capacity(self.layers.len());
        let mut gate_engaged = Vec::with_capacity(self.layers.len());
        let mut fmap: Option<TensorI8> = None;
        for (li, l) in self.layers.iter().enumerate() {
            let prev = fmap.as_ref().unwrap_or(input);
            let (acc, in_s, engaged) = match l.sample {
                SampleShape::Conv(ss) => {
                    let x = fit_fmap_from(prev, ss.h, ss.w, ss.c);
                    let in_s = x.sparsity();
                    let engaged = gate.engaged(self.measured_act.get(li).copied().unwrap_or(in_s));
                    let g = ZeroGate::resolved(engaged);
                    let acc = match &l.operand {
                        PackedOperand::Dbb(p) => {
                            fused::conv2d_dbb_i8_packed_gated_with(&x, p, &ss, par, g, scratch)
                        }
                        PackedOperand::Dense(w) => {
                            fused::conv2d_i8_gated_with(&x, w, &ss, par, g, scratch)
                        }
                    };
                    (acc, in_s, engaged)
                }
                SampleShape::Fc { m, k } => {
                    let a = fit_matrix_from(prev, m, k);
                    let in_s = a.sparsity();
                    let engaged = gate.engaged(self.measured_act.get(li).copied().unwrap_or(in_s));
                    let g = ZeroGate::resolved(engaged);
                    let acc = match &l.operand {
                        PackedOperand::Dbb(p) => tiled::dbb_i8_packed_gated(&a, p, par, g),
                        PackedOperand::Dense(w) => tiled::dense_i8_gated(&a, w, par, g),
                    };
                    (acc, in_s, engaged)
                }
            };
            act_sparsity.push(in_s);
            gate_engaged.push(engaged);
            let out = requant_relu(&acc, l.relu);
            // propagate: conv outputs keep spatial form, FC outputs become
            // a 1×m×n map
            fmap = Some(if out.shape().len() == 3 {
                out
            } else {
                let (om, on) = (out.shape()[0], out.shape()[1]);
                out.reshape(&[1, om, on])
            });
        }
        Execution {
            output: fmap.unwrap_or_else(|| input.clone()),
            act_sparsity,
            gate_engaged,
        }
    }

    /// Replay the seeded sampled functional inference (the historical
    /// `profile_model` pass), record the measured per-layer activation
    /// sparsities into the model, and return the layer profiles the
    /// timing/power models consume. Bit-exact with the per-call-encoding
    /// path for the same `(model, nnz, bz, seed)` at any worker-pool width
    /// and under any [`ZeroGate`] policy (gating never changes a bit, so
    /// the recorded sparsities are gating-invariant).
    pub fn profile(&mut self, par: Parallelism) -> Vec<LayerProfile> {
        let rec = self.execute(&self.seed_input, par);
        self.measured_act = rec.act_sparsity;
        self.profiles().expect("profile just ran")
    }

    /// Layer profiles with *measured* activation sparsity — available once
    /// [`Self::profile`] has run, `None` before (the serving twin falls
    /// back to an assumed scalar in that case).
    pub fn profiles(&self) -> Option<Vec<LayerProfile>> {
        if self.measured_act.len() != self.layers.len() {
            return None;
        }
        Some(
            self.layers
                .iter()
                .zip(&self.measured_act)
                .map(|(l, &act)| LayerProfile {
                    name: l.name.clone(),
                    m: l.m,
                    weights: l.weights,
                    act_sparsity: act,
                    im2col_magnification: l.im2col_magnification,
                    raw_act_bytes: l.raw_act_bytes,
                    out_elems: l.out_elems,
                    relu: l.relu,
                })
                .collect(),
        )
    }

    /// The prepared layers, in execution order.
    pub fn layers(&self) -> &[PreparedLayer] {
        &self.layers
    }

    /// The seeded input sample the profile pass feeds to the first layer.
    pub fn seed_input(&self) -> &TensorI8 {
        &self.seed_input
    }

    /// Model name this was prepared from.
    pub fn model_name(&self) -> &'static str {
        self.name
    }

    /// `(nnz, bz, seed)` the model was prepared with.
    pub fn encoding(&self) -> (usize, usize, u64) {
        (self.nnz, self.bz, self.seed)
    }

    /// Total host bytes of all packed weight operands (steady-state
    /// weight-memory footprint of the executor).
    pub fn operand_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.operand.operand_bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;

    #[test]
    fn prepare_lowers_every_layer_once() {
        let m = models::convnet5();
        let pm = PreparedModel::prepare(&m, 3, 8, 42, Parallelism::serial());
        assert_eq!(pm.layers().len(), m.layers.len());
        assert_eq!(pm.model_name(), m.name);
        assert_eq!(pm.encoding(), (3, 8, 42));
        // prunable layers carry a packed DBB stream, the rest dense
        for (pl, l) in pm.layers().iter().zip(&m.layers) {
            match (&pl.operand, l.prunable) {
                (PackedOperand::Dbb(p), true) => assert!(p.total_nnz() > 0),
                (PackedOperand::Dense(w), false) => assert!(!w.is_empty()),
                (op, prunable) => {
                    panic!("{}: operand {op:?} vs prunable={prunable}", pl.name)
                }
            }
        }
        assert!(pm.operand_bytes() > 0);
        assert!(pm.profiles().is_none(), "no functional profile ran yet");
    }

    #[test]
    fn repeated_execute_is_pure() {
        let m = models::lenet5();
        let pm = PreparedModel::prepare(&m, 2, 8, 9, Parallelism::threads(3));
        let a = pm.execute(pm.seed_input(), Parallelism::threads(3));
        let b = pm.execute(pm.seed_input(), Parallelism::threads(3));
        assert_eq!(a.output, b.output);
        assert_eq!(a.act_sparsity, b.act_sparsity);
    }

    #[test]
    fn execute_accepts_non_spatial_input() {
        // the documented contract: any non-empty input is wrap-fitted,
        // including a 2-D matrix fed to a conv-first model
        let m = models::convnet5();
        let pm = PreparedModel::prepare(&m, 3, 8, 1, Parallelism::serial());
        let mut rng = Rng::new(2);
        let flat = TensorI8::rand(&[10, 27], &mut rng);
        let rec = pm.execute(&flat, Parallelism::serial());
        assert_eq!(rec.act_sparsity.len(), m.layers.len());
        assert!(!rec.output.is_empty());
    }

    #[test]
    fn gate_policies_share_one_output_and_report_decisions() {
        let m = models::lenet5();
        let pm = PreparedModel::prepare(&m, 2, 8, 9, Parallelism::serial());
        let par = Parallelism::serial();
        let off = pm.execute_gated(pm.seed_input(), par, ZeroGate::Off);
        let on = pm.execute_gated(pm.seed_input(), par, ZeroGate::On);
        let auto = pm.execute_gated(pm.seed_input(), par, ZeroGate::Auto);
        assert_eq!(off.output, on.output, "gating must be bit-exact");
        assert_eq!(off.output, auto.output);
        assert_eq!(off.act_sparsity, on.act_sparsity);
        assert!(off.gate_engaged.iter().all(|&g| !g));
        assert!(on.gate_engaged.iter().all(|&g| g));
        // Auto mirrors the per-layer threshold on the measured input
        // sparsities (unprofiled model → current-operand fallback)
        for (li, (&s, &g)) in auto.act_sparsity.iter().zip(&auto.gate_engaged).enumerate() {
            assert_eq!(g, ZeroGate::Auto.engaged(s), "layer {li}: s={s}");
        }
    }

    #[test]
    fn auto_consults_recorded_profile_after_profiling() {
        let m = models::convnet5();
        let mut pm = PreparedModel::prepare(&m, 3, 8, 42, Parallelism::serial());
        assert_eq!(pm.zero_gate(), ZeroGate::Auto, "default policy");
        assert!(pm.measured_act_sparsity().is_none());
        pm.profile(Parallelism::serial());
        let measured = pm.measured_act_sparsity().expect("profile ran").to_vec();
        // same sparsity source as the twin's priced profiles
        let profiles = pm.profiles().unwrap();
        for (p, &s) in profiles.iter().zip(&measured) {
            assert_eq!(p.act_sparsity.to_bits(), s.to_bits(), "{}", p.name);
        }
        // Auto decisions on the seed input now follow the recorded values
        let auto = pm.execute_gated(pm.seed_input(), Parallelism::serial(), ZeroGate::Auto);
        for (li, (&s, &g)) in measured.iter().zip(&auto.gate_engaged).enumerate() {
            assert_eq!(g, ZeroGate::Auto.engaged(s), "layer {li}: measured={s}");
        }
        // the seed input is near-dense (2% zeros): layer 0 must not gate
        assert!(!auto.gate_engaged[0], "near-dense first layer must not gate");
    }

    #[test]
    fn profile_records_measured_sparsity() {
        let m = models::convnet5();
        let mut pm = PreparedModel::prepare(&m, 3, 8, 42, Parallelism::serial());
        let profiles = pm.profile(Parallelism::serial());
        assert_eq!(profiles.len(), m.layers.len());
        assert!(pm.profiles().is_some());
        // first layer sees the near-dense seed input
        assert!(profiles[0].act_sparsity < 0.1, "{}", profiles[0].act_sparsity);
        // ReLU layers downstream are measurably sparse
        assert!(profiles.iter().skip(1).any(|p| p.act_sparsity > 0.2));
    }
}
