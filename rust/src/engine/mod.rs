//! Prepared-model inference engine: pack operands once, execute many.
//!
//! The paper's whole deployment story (§II-A) is an offline/online split:
//! DBB weights are *encoded offline* and the accelerator *streams* the
//! fixed-rate compressed operand at runtime — encoding cost is paid once
//! per model, never per inference. This module is that split in software.
//! [`PreparedModel::prepare`] lowers each layer of a [`Model`] exactly once
//! into a [`PreparedLayer`]:
//!
//! * a **packed weight operand** ([`PackedOperand`]) — the flattened
//!   `(col_ptr, entries)` CSC stream ([`crate::gemm::DbbPacked`]) that the
//!   DBB row kernels consume, decoded here and never again; or, under
//!   [`PreparedModel::prepare_format`] with [`WeightFormat::Bsr`], the
//!   block-sparse `row_ptr`/`col_idx` stream ([`crate::gemm::BsrPacked`])
//!   the block-scheduler kernels walk (whole `bz×bz` blocks survive
//!   pruning, so sparsity metadata is two coarse index arrays instead of a
//!   per-element bitmask); or a dense `[K, N]` INT8 matrix for layers that
//!   run unpruned;
//! * a **fused-conv descriptor** ([`SampleShape`]) — the sampled window
//!   geometry (same kernel/stride/pad as the full layer) the functional
//!   pass convolves, plus the static profile facts (GEMM `M`, IM2COL
//!   magnification, raw activation bytes) the timing model needs;
//! * a share of the model's **preallocated per-worker scratch arena**
//!   ([`crate::gemm::fused::PatchScratch`]) — the streaming-IM2COL row
//!   buffers every conv layer draws from.
//!
//! [`PreparedModel::execute`] then runs the whole network through the
//! existing [`crate::gemm::fused`] / [`crate::gemm::tiled`] kernels with
//! **zero encode/decode work and zero per-call weight-operand allocation**,
//! bit-exact with the per-call-encoding path it replaced (the shared
//! `dbb_rows_i8`-family inner kernels guarantee it). Those inner kernels in
//! turn dispatch through the [`crate::gemm::micro`] SIMD microkernels —
//! still bit-exact (INT32 accumulation is order-independent), so a prepared
//! model executes identically on every ISA path. Pass
//! `Parallelism::auto().with_pin(true)` to `execute` to additionally pin
//! each conv worker to a core so its `PatchScratch` arena stays cache-hot
//! across steady-state executes.
//! [`PreparedModel::profile`] replays the seeded sampled inference of
//! `sim::accel::profile_model` — same seed, same RNG draw order, same
//! per-layer activation sparsities to the last bit — and records the
//! measured sparsities *into* the prepared model, where the serving
//! coordinator's hardware twin reads them.
//!
//! ## The three-way activation policy: off / gate / encode
//!
//! The measured per-layer sparsities are not just reported — they are *fed
//! back into the kernels*. Every execute resolves a
//! [`crate::gemm::ActPolicy`] per layer (the model-level default is
//! [`ActPolicy::Auto`]; see [`PreparedModel::set_act_policy`] /
//! [`PreparedModel::execute_policy`]):
//!
//! * **Off** — stream the operand raw (dense activations);
//! * **Gate** — the PR-4 zero-skip kernels: fetch everything, skip the
//!   multiplies of zero activations;
//! * **Encode** — DBB-encode the activation operand
//!   ([`crate::gemm::ActDbb`]; conv layers encode each generated patch-row
//!   chunk right after streaming IM2COL) and run the joint A-DBB kernels,
//!   so zeros are never stored, streamed, or multiplied.
//!
//! `Auto` consults the layer's *measured* activation sparsity from the
//! recorded profile (falling back to the zero fraction of the current
//! input operand, which the execute loop measures anyway) and picks the
//! tier the **modeled datapath** pays for: encode at ≥ 50% zeros (the
//! compressed stream's traffic break-even — the software wall-clock
//! trade-off of `Encode` vs `Gate` is host-dependent; see
//! [`crate::gemm::ActPolicy`] and pin `Gate` where execute latency alone
//! matters), gate at ≥ 25%, off below. The
//! same measured values drive the hardware twin's pricing (the
//! `act_sparsity` / `act_encoded` fields of
//! [`crate::sim::accel::LayerProfile`]) — one sparsity source for the
//! priced datapath and the software kernels, and the twin's A-side SRAM
//! traffic distinguishes "skipped the multiply" (gated MACs) from "never
//! fetched the operand" (compressed stream bytes + index overhead). Every
//! policy is bit-exact, so [`Execution::output`] is identical under all of
//! them (`rust/tests/zero_gate.rs`, `rust/tests/act_dbb.rs`); the
//! per-layer decisions are reported in [`Execution::act_policy`] /
//! [`Execution::gate_engaged`]. The legacy two-way [`ZeroGate`] surface
//! ([`PreparedModel::set_zero_gate`] / [`PreparedModel::execute_gated`])
//! is preserved and never encodes.
//!
//! ## Fused epilogues: the i8→i8 layer chain
//!
//! The historical execute loop materializes each layer's whole i32
//! accumulator tensor, then requantizes it ([`crate::gemm::requant_relu`])
//! in a second pass. [`PreparedModel::execute_fused`] fuses that epilogue —
//! requantize, ReLU, and (under [`PreparedModel::set_fused_pool`]) the
//! model's 2×2/stride-2 max-pool — *into the GEMM output walk* via
//! [`crate::gemm::Epilogue`]: each tiled worker converts its freshly
//! accumulated rows to i8 while they are cache-hot, layers chain i8→i8
//! through recycled output backings (the scratch arena's ping-pong pool),
//! and no whole-layer i32 tensor is ever allocated. The shift the epilogue
//! needs up front is frozen offline by [`PreparedModel::calibrate`] (one
//! staged pass over the seed input recording each layer's data-dependent
//! shift — the same offline/online split the DBB weights go through), and
//! [`PreparedModel::execute_staged`] replays the historical staged chain
//! under those frozen shifts as the bit-exactness oracle
//! (`rust/tests/epilogue.rs`). On the seed input, `execute_fused`,
//! `execute_staged`, and plain `execute` all agree bit for bit.
//!
//! ## Persistence and batching: the serving substrate
//!
//! Two extensions turn the prepared model into a serving artifact:
//!
//! * [`PreparedModel::save`] / [`PreparedModel::load`] persist the whole
//!   lowered model — packed DBB streams, dense operands, sampled geometry,
//!   measured sparsities, calibrated shifts (global **and** per-channel) —
//!   as a versioned little-endian flat binary with a trailing checksum
//!   (see [`PERSIST_MAGIC`]; reader/writer in [`crate::util::bin`]). A
//!   restarted coordinator loads and serves with *no* synthesize, prune,
//!   encode, or calibration work; load-vs-prepare bit-exactness is pinned
//!   by `rust/tests/persistence.rs`, and corrupted/truncated streams fail
//!   with a clean `Err`, never a panic.
//! * [`PreparedModel::execute_fused_batch`] folds a whole request batch
//!   into the GEMM `M` dimension (conv kernels take `[b, h, w, c]` maps
//!   natively; FC layers stack row blocks), bit-exact per image with
//!   [`PreparedModel::execute_fused`] — the coordinator's engine-native
//!   serving path ([`crate::coordinator`]) batches through this with zero
//!   steady-state allocation.

use crate::dbb::prune::prune_bsr_i8;
use crate::dbb::DbbMatrix;
use crate::gemm::conv::ConvShape;
use crate::gemm::fused::{self, PatchScratch};
use crate::gemm::tiled;
use crate::gemm::epilogue::{max_pool_2x2, requant_col_shifts, requant_shift, requant_with_shift};
use crate::gemm::{
    requant_relu, ActPolicy, BsrPacked, DbbPacked, Epilogue, PoolGeom, Requant, WeightFormat,
    ZeroGate,
};
use crate::models::{LayerKind, Model};
use crate::sim::accel::LayerProfile;
use crate::sim::analytic::WeightStats;
use crate::sim::im2col::Im2colUnit;
use crate::tensor::TensorI8;
use crate::util::bin::{fnv1a64, BinReader, BinWriter};
use crate::util::error::{bail, Context, Result};
use crate::util::par::map_indexed;
use crate::util::{Parallelism, Rng};
use std::borrow::Cow;
use std::path::Path;
use std::sync::Mutex;

/// Cap on sampled GEMM rows/cols for the functional sparsity measurement
/// (keeps ResNet/VGG preparation fast; sparsity is a statistical mean over
/// ≥64k requantized outputs per layer at these caps — §Perf).
const SAMPLE_ROWS: usize = 256;
const SAMPLE_COLS: usize = 256;
/// Width (in output pixels) of the sampled conv window; the height is then
/// chosen so the window holds at most [`SAMPLE_ROWS`] output pixels.
const SAMPLE_WIN_COLS: usize = 16;

/// Zero fraction of the synthetic input image fed to the first layer:
/// natural images are dense (≈0% zeros after normalization).
const SEED_ACT_SPARSITY: f32 = 0.02;

/// Conv geometry of the sampled sub-window: same kernel/stride/pad as the
/// full layer, input cropped so the output window has ≤ [`SAMPLE_ROWS`]
/// pixels. `c`/`ns` override channels (depthwise samples one channel).
fn sample_shape(s: &ConvShape, c: usize, ns: usize) -> ConvShape {
    let ow_s = s.ow().min(SAMPLE_WIN_COLS).max(1);
    let oh_s = s.oh().min((SAMPLE_ROWS / ow_s).max(1));
    ConvShape {
        h: ((oh_s - 1) * s.stride + s.kh).saturating_sub(2 * s.pad).max(1),
        w: ((ow_s - 1) * s.stride + s.kw).saturating_sub(2 * s.pad).max(1),
        c,
        kh: s.kh,
        kw: s.kw,
        oc: ns,
        stride: s.stride,
        pad: s.pad,
    }
}

/// Fill `out` with `pd` repeated end-to-end (`out[i] = pd[i % pd.len()]`),
/// in whole-slice `copy_from_slice` chunks instead of a per-element modulo.
fn wrap_fill(pd: &[i8], out: &mut [i8]) {
    debug_assert!(!pd.is_empty());
    let n = pd.len();
    let mut done = 0usize;
    while done < out.len() {
        let take = n.min(out.len() - done);
        out[done..done + take].copy_from_slice(&pd[..take]);
        done += take;
    }
}

/// Fit a propagated feature map to a layer's sampled input shape by
/// wrap-around tiling (spatial dims and channels), preserving the measured
/// value/zero structure. An exact-shape match **borrows** the input
/// untouched — the zero-copy identity that keeps [`PreparedModel::profile`]
/// bit-exact (the stored seed input passes through unchanged) and takes
/// every aligned steady-state execute off the copy path entirely. Shape
/// mismatches copy in the widest aligned spans available (whole rows when
/// the widths match, channel runs when only the channel counts do) rather
/// than per-element `at`/`set` calls — this runs on every request, for
/// every layer (§Perf).
fn fit_fmap_from<'p>(p: &'p TensorI8, h: usize, w: usize, c: usize) -> Cow<'p, TensorI8> {
    if p.shape() == [h, w, c] {
        return Cow::Borrowed(p);
    }
    let mut data = vec![0i8; h * w * c];
    fit_fmap_into(p.data(), p.shape(), h, w, c, &mut data);
    Cow::Owned(TensorI8::from_vec(&[h, w, c], data))
}

/// [`fit_fmap_from`]'s copy core on raw parts, writing into a caller slice —
/// the batched executor fits each image of a `[b, ...]` feature map into its
/// slot of a recycled batch buffer without materializing per-image tensors.
/// Byte-identical to `fit_fmap_from(image, h, w, c)` for an image of shape
/// `pshape` backed by `pd`.
fn fit_fmap_into(pd: &[i8], pshape: &[usize], h: usize, w: usize, c: usize, out: &mut [i8]) {
    debug_assert_eq!(out.len(), h * w * c);
    if pshape == [h, w, c] {
        out.copy_from_slice(pd);
        return;
    }
    if pshape.len() != 3 {
        // non-spatial input (matrix / flat vector): wrap the raw data
        wrap_fill(pd, out);
        return;
    }
    let (ph, pw, pc) = (pshape[0], pshape[1], pshape[2]);
    if pc == c {
        for y in 0..h {
            let srow = &pd[(y % ph) * pw * pc..(y % ph + 1) * pw * pc];
            let drow = &mut out[y * w * c..(y + 1) * w * c];
            if pw == w {
                drow.copy_from_slice(srow);
            } else {
                for x in 0..w {
                    let src = (x % pw) * pc;
                    drow[x * c..(x + 1) * c].copy_from_slice(&srow[src..src + c]);
                }
            }
        }
    } else {
        // channel-count mismatch: channels wrap too (rare — FC output fed
        // to a conv sample); per-element fallback on raw slices
        for y in 0..h {
            let sy = (y % ph) * pw * pc;
            for x in 0..w {
                let sx = sy + (x % pw) * pc;
                let dst = (y * w + x) * c;
                for ci in 0..c {
                    out[dst + ci] = pd[sx + ci % pc];
                }
            }
        }
    }
}

/// FC analogue of [`fit_fmap_from`]: wrap the flattened feature map into an
/// `[m, k]` operand sample — borrowing on an exact shape match, chunked
/// `copy_from_slice` otherwise.
fn fit_matrix_from<'p>(p: &'p TensorI8, m: usize, k: usize) -> Cow<'p, TensorI8> {
    if p.shape() == [m, k] {
        return Cow::Borrowed(p);
    }
    let mut data = vec![0i8; m * k];
    wrap_fill(p.data(), &mut data);
    Cow::Owned(TensorI8::from_vec(&[m, k], data))
}

/// The fused-conv descriptor of a prepared layer: what geometry the
/// functional pass runs (the sampled window keeps the full layer's
/// kernel/stride/pad; FC layers sample GEMM rows).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SampleShape {
    /// Sampled conv window (standard or depthwise; depthwise samples one
    /// channel).
    Conv(ConvShape),
    /// Sampled FC GEMM: `m` rows over the layer's full `k`.
    Fc {
        /// Sampled GEMM rows (`min(M, SAMPLE_ROWS)`).
        m: usize,
        /// Reduction dim (the layer's full input features).
        k: usize,
    },
}

/// A weight operand lowered exactly once at prepare time.
#[derive(Debug, Clone)]
pub enum PackedOperand {
    /// DBB-bounded layer: the flattened CSC stream, decoded at prepare.
    Dbb(DbbPacked),
    /// Block-sparse layer ([`WeightFormat::Bsr`]): the `row_ptr`/`col_idx`
    /// indexed stream of dense `bz×bz` blocks the BSR block scheduler
    /// consumes — coarse indices instead of DBB's per-element bitmask.
    Bsr(BsrPacked),
    /// Dense-fallback layer (non-prunable / bound == bz): the `[K, N]`
    /// GEMM right operand.
    Dense(TensorI8),
}

impl PackedOperand {
    /// Host bytes of the packed operand held in steady state.
    pub fn operand_bytes(&self) -> usize {
        match self {
            PackedOperand::Dbb(p) => p.operand_bytes(),
            PackedOperand::Bsr(p) => p.operand_bytes(),
            PackedOperand::Dense(w) => w.len(),
        }
    }

    /// The [`WeightFormat`] this operand was lowered under.
    pub fn format(&self) -> WeightFormat {
        match self {
            PackedOperand::Dbb(_) => WeightFormat::Dbb,
            PackedOperand::Bsr(_) => WeightFormat::Bsr,
            PackedOperand::Dense(_) => WeightFormat::Dense,
        }
    }
}

/// One layer, lowered once: packed operand + sampled geometry + the static
/// profile facts the timing/power models consume.
#[derive(Debug, Clone)]
pub struct PreparedLayer {
    /// Layer name.
    pub name: String,
    /// Full-layer GEMM rows (output pixels × batch 1).
    pub m: usize,
    /// Weight statistics (synthetic-exact for magnitude-pruned weights).
    pub weights: WeightStats,
    /// Sampled execution geometry.
    pub sample: SampleShape,
    /// The weight operand, encoded/decoded exactly once.
    pub operand: PackedOperand,
    /// IM2COL duplication this layer offers (1.0 for FC/1×1).
    pub im2col_magnification: f64,
    /// Raw input bytes (feature map / FC input vector).
    pub raw_act_bytes: u64,
    /// Output elements (for MCU post-processing).
    pub out_elems: u64,
    /// Followed by ReLU?
    pub relu: bool,
}

/// Result of one [`PreparedModel::execute`] pass.
#[derive(Debug, Clone)]
pub struct Execution {
    /// Final layer's requantized INT8 output.
    pub output: TensorI8,
    /// Measured zero fraction of each layer's fitted *input* operand (the
    /// raw feature map / FC matrix as fed to the layer, before any IM2COL
    /// expansion — the same convention as
    /// [`crate::sim::accel::LayerProfile::act_sparsity`]).
    pub act_sparsity: Vec<f64>,
    /// The resolved per-layer activation policy this pass ran under (never
    /// [`ActPolicy::Auto`] — `Auto` resolves before the kernels run).
    pub act_policy: Vec<ActPolicy>,
    /// Whether the activation path engaged for each layer — `true` when the
    /// resolved policy is `Gate` *or* `Encode`. Under the legacy
    /// [`ZeroGate`] surface this is exactly the old meaning: all `false`
    /// under [`ZeroGate::Off`], all `true` under [`ZeroGate::On`], the
    /// per-layer threshold decision under [`ZeroGate::Auto`].
    pub gate_engaged: Vec<bool>,
}

/// What one [`PreparedModel::calibrate`] pass records per layer: the frozen
/// global requantize shift the fused epilogue serves under, plus the
/// per-output-channel shifts derived from the same accumulator's per-column
/// maxima ([`requant_col_shifts`]). The global shift is always the max of
/// the per-channel ones (shift derivation is monotone in the maximum), so
/// both views are frozen by a single staged pass over the seed input.
#[derive(Debug, Default)]
struct CalibRecord {
    shifts: Vec<u32>,
    perch: Vec<Vec<u32>>,
}

/// Where a staged execute pass takes each layer's requantize shift from.
enum ShiftSource<'a> {
    /// Data-dependent per-input shift — the historical `requant_relu`
    /// behavior, derived from the layer's own i32 accumulator.
    Dynamic,
    /// Data-dependent, and additionally recorded per layer — global and
    /// per-channel (the [`PreparedModel::calibrate`] pass).
    Record(&'a mut CalibRecord),
    /// Frozen calibrated shifts — the staged oracle the fused-epilogue
    /// executor is checked against, bit for bit.
    Frozen(&'a [u32]),
}

/// A model lowered once, executable many times: the software twin of the
/// paper's offline-encode / runtime-stream split (§II-A).
#[derive(Debug)]
pub struct PreparedModel {
    name: &'static str,
    nnz: usize,
    bz: usize,
    seed: u64,
    /// Weight format every prunable layer was lowered to
    /// ([`Self::prepare_format`]); non-prunable layers stay dense under
    /// every format.
    format: WeightFormat,
    layers: Vec<PreparedLayer>,
    seed_input: TensorI8,
    /// Recorded by [`Self::profile`]; empty until a functional profile ran.
    measured_act: Vec<f64>,
    /// Model-level default activation policy [`Self::execute`] applies
    /// (default [`ActPolicy::Auto`]).
    act_policy: ActPolicy,
    /// Per-layer requantize shifts frozen by [`Self::calibrate`]; empty
    /// until a calibration pass ran. The fused-epilogue executor needs the
    /// shift *before* the GEMM (the historical path derived it from the
    /// materialized i32 tensor, which the fused path never allocates).
    shifts: Vec<u32>,
    /// Per-layer, per-output-channel requantize shifts recorded by the same
    /// [`Self::calibrate`] pass (from the accumulator's per-column maxima);
    /// empty until calibration ran. `max(perch_shifts[li]) == shifts[li]`
    /// always. The fused serving path requantizes under the global shift;
    /// these feed [`Requant::PerChannel`] epilogues and persist with the
    /// model so a finer-grained epilogue needs no recalibration.
    perch_shifts: Vec<Vec<u32>>,
    /// Fold a 2×2/stride-2 max-pool after every conv layer (applied
    /// uniformly by every execute path, staged and fused, so they stay
    /// comparable). Default `false` — the historical layer chain.
    fused_pool: bool,
    /// Serve-time declaration for the hardware twin: this model executes
    /// through the fused-epilogue path, so [`Self::profiles`] marks every
    /// layer's [`LayerProfile::fused_epilogue`] and the twin prices the
    /// epilogue as array-overlapped work instead of MCU post-processing.
    fused_epilogue: bool,
    /// Opt-in ([`Self::set_per_channel_requant`]): the fused epilogue
    /// requantizes under the calibrated **per-output-channel** shifts
    /// ([`Requant::PerChannel`]) instead of the layer-global maximum.
    /// Default `false` — the global path, bit-exact with the staged oracle.
    per_channel_requant: bool,
    /// Per-worker streaming-IM2COL row buffers, preallocated at prepare and
    /// reused by every [`Self::execute`] (concurrent executes fall back to
    /// a transient arena rather than blocking).
    scratch: Mutex<PatchScratch>,
}

impl PreparedModel {
    /// Lower every layer of `model` exactly once: draw the synthetic
    /// DBB-pruned INT8 weights from `seed` (identical RNG draw order to the
    /// historical per-call path, so measured sparsities reproduce
    /// bit-for-bit), encode + pack each prunable layer's operand on the
    /// `par` worker pool, and preallocate the per-worker scratch arena.
    ///
    /// `nnz` is the model-wide DBB target (paper Table I, e.g. 3/8 for
    /// ResNet-50); non-prunable layers fall back to dense.
    ///
    /// # Example
    ///
    /// ```
    /// use ssta::engine::PreparedModel;
    /// use ssta::util::Parallelism;
    ///
    /// let par = Parallelism::serial();
    /// let model = ssta::models::lenet5();
    /// // one-time lowering at the 2/8 DBB point (paper §II-A offline encode)
    /// let pm = PreparedModel::prepare(&model, 2, 8, 42, par);
    /// assert_eq!(pm.model_name(), "LeNet-5");
    /// assert_eq!(pm.encoding(), (2, 8, 42));
    /// assert_eq!(pm.layers().len(), model.layers.len());
    /// ```
    pub fn prepare(model: &Model, nnz: usize, bz: usize, seed: u64, par: Parallelism) -> Self {
        Self::prepare_format(model, nnz, bz, seed, par, WeightFormat::default())
    }

    /// [`Self::prepare`] with an explicit [`WeightFormat`] for the prunable
    /// layers — the format-polymorphic entry of the weight pipeline:
    ///
    /// * [`WeightFormat::Dbb`] — the historical path: fused top-k prune +
    ///   DBB compress + CSC pack (identical to [`Self::prepare`]);
    /// * [`WeightFormat::Bsr`] — block-structured prune at the **matched
    ///   density** (`nnz/bz` of the `bz×bz` blocks of each block row
    ///   survive) + BSR pack; the engine then streams the block-scheduler
    ///   kernels, paying coarse `row_ptr`/`col_idx` indices instead of
    ///   per-element bitmasks;
    /// * [`WeightFormat::Dense`] — no pruning at all; every layer runs the
    ///   dense oracle kernels.
    ///
    /// Pass 1 (the serial RNG weight + seed-input draw) is format-invariant,
    /// so all three formats of the same `(model, nnz, bz, seed)` start from
    /// byte-identical dense weights and the same seed input.
    pub fn prepare_format(
        model: &Model,
        nnz: usize,
        bz: usize,
        seed: u64,
        par: Parallelism,
        format: WeightFormat,
    ) -> Self {
        let mut rng = Rng::new(seed);
        let nlayers = model.layers.len();

        // Pass 1 (serial): draw the synthetic weights — and, right after the
        // first layer's weights, the seed input — in the exact RNG order the
        // per-call profiler used, so seeded results are unchanged.
        let mut dense = Vec::with_capacity(nlayers);
        let mut samples = Vec::with_capacity(nlayers);
        let mut seed_input: Option<TensorI8> = None;
        for l in &model.layers {
            let (m, k, n) = l.gemm_dims();
            let ns = n.min(SAMPLE_COLS);
            let w_dense = TensorI8::rand(&[k, ns], &mut rng);
            let sample = match l.kind {
                LayerKind::Conv(s) | LayerKind::DepthwiseConv(s) => {
                    let chans = if matches!(l.kind, LayerKind::Conv(_)) { s.c } else { 1 };
                    SampleShape::Conv(sample_shape(&s, chans, ns))
                }
                LayerKind::Fc(..) => SampleShape::Fc { m: m.min(SAMPLE_ROWS), k },
            };
            if seed_input.is_none() {
                seed_input = Some(match sample {
                    SampleShape::Conv(ss) => {
                        TensorI8::rand_sparse(&[ss.h, ss.w, ss.c], SEED_ACT_SPARSITY, &mut rng)
                    }
                    SampleShape::Fc { m, k } => {
                        TensorI8::rand_sparse(&[m, k], SEED_ACT_SPARSITY, &mut rng)
                    }
                });
            }
            dense.push(w_dense);
            samples.push(sample);
        }

        // Pass 2 (worker pool): the one-time encode — format-routed prune +
        // pack per prunable layer. This is the *only* place the engine ever
        // encodes or decodes a weight operand. Dense-fallback layers (and
        // the whole model under `WeightFormat::Dense`) skip the pool
        // entirely: their drawn matrix IS the operand, and it is *moved*
        // into place below — never cloned (the unpruned layers are the
        // largest ones; duplicating them at prepare time doubled their
        // footprint for nothing).
        let packed: Vec<Option<PackedOperand>> = map_indexed(nlayers, par, |li| {
            let l = &model.layers[li];
            let bound = l.dbb_bound(nnz, bz);
            if bound >= bz || matches!(format, WeightFormat::Dense) {
                return None;
            }
            Some(match format {
                WeightFormat::Dbb => PackedOperand::Dbb(
                    DbbMatrix::compress_topk(&dense[li], bz, bound)
                        .expect("valid block size")
                        .pack(),
                ),
                WeightFormat::Bsr => {
                    // matched density: keep nnz/bz of the blocks per block
                    // row, the block-granular analogue of the DBB bound
                    let nbc = dense[li].shape()[1].div_ceil(bz);
                    let keep = (nbc * bound).div_ceil(bz).clamp(1, nbc);
                    let pruned = prune_bsr_i8(&dense[li], bz, bz, keep);
                    PackedOperand::Bsr(BsrPacked::pack(&pruned, bz, bz))
                }
                WeightFormat::Dense => unreachable!("dense handled above"),
            })
        });
        let operands: Vec<PackedOperand> = dense
            .into_iter()
            .zip(packed)
            .map(|(w_dense, p)| p.unwrap_or(PackedOperand::Dense(w_dense)))
            .collect();

        let layers: Vec<PreparedLayer> = model
            .layers
            .iter()
            .zip(samples)
            .zip(operands)
            .enumerate()
            .map(|(li, ((l, sample), operand))| {
                let (m, k, n) = l.gemm_dims();
                let bound = l.dbb_bound(nnz, bz);
                let (im2c, raw) = match l.kind {
                    LayerKind::Conv(s) | LayerKind::DepthwiseConv(s) => (
                        Im2colUnit::default().magnification(&s),
                        (s.h * s.w * s.c) as u64,
                    ),
                    LayerKind::Fc(i, _) => (1.0, i as u64),
                };
                PreparedLayer {
                    name: l.name.clone(),
                    m,
                    weights: WeightStats::synthetic(k, n, bz, bound),
                    sample,
                    operand,
                    im2col_magnification: im2c,
                    raw_act_bytes: raw,
                    out_elems: (m * n) as u64,
                    relu: li + 1 < nlayers,
                }
            })
            .collect();

        let max_k = layers
            .iter()
            .filter_map(|l| match l.sample {
                SampleShape::Conv(ss) => Some(ss.gemm_k()),
                SampleShape::Fc { .. } => None,
            })
            .max()
            .unwrap_or(0);
        PreparedModel {
            name: model.name,
            nnz,
            bz,
            seed,
            format,
            layers,
            seed_input: seed_input.unwrap_or_else(|| TensorI8::zeros(&[1, 1, 1])),
            measured_act: Vec::new(),
            act_policy: ActPolicy::default(),
            shifts: Vec::new(),
            perch_shifts: Vec::new(),
            fused_pool: false,
            fused_epilogue: false,
            per_channel_requant: false,
            scratch: Mutex::new(PatchScratch::preallocate(par.get(), max_k)),
        }
    }

    /// The [`WeightFormat`] the prunable layers were lowered to.
    pub fn weight_format(&self) -> WeightFormat {
        self.format
    }

    /// BSR operands have no joint A-DBB kernel — a resolved `Encode` on a
    /// BSR layer degrades to `Gate` (still bit-exact; [`Self::profiles`]
    /// reports no A-side encode for these layers either, so the twin never
    /// prices a compressed A stream the executor cannot produce).
    fn layer_policy(&self, li: usize, pol: ActPolicy) -> ActPolicy {
        if pol == ActPolicy::Encode && matches!(self.layers[li].operand, PackedOperand::Bsr(_)) {
            ActPolicy::Gate
        } else {
            pol
        }
    }

    /// The requantizer a fused execute hands layer `li`'s epilogue: the
    /// calibrated global shift, or — under [`Self::set_per_channel_requant`]
    /// — that layer's per-output-channel shifts (cloned per call; the
    /// per-channel path trades one small allocation per layer for finer
    /// quantization).
    fn layer_requant(&self, li: usize, shifts: &[u32]) -> Requant {
        if self.per_channel_requant {
            if let Some(per) = self.perch_shifts.get(li) {
                if !per.is_empty() {
                    return Requant::PerChannel(per.clone());
                }
            }
        }
        Requant::Global(shifts[li])
    }

    /// The model-level default [`ActPolicy`] that [`Self::execute`]
    /// applies.
    pub fn act_policy(&self) -> ActPolicy {
        self.act_policy
    }

    /// Override the default activation policy [`Self::execute`] applies.
    /// No policy changes a result bit; this is a performance/traffic knob.
    pub fn set_act_policy(&mut self, policy: ActPolicy) {
        self.act_policy = policy;
    }

    /// The model-level default policy, viewed through the legacy two-way
    /// [`ZeroGate`] surface: `Gate` and `Encode` both read as `On` (the
    /// activation path is engaged), `Off`/`Auto` map to themselves.
    pub fn zero_gate(&self) -> ZeroGate {
        match self.act_policy {
            ActPolicy::Off => ZeroGate::Off,
            ActPolicy::Gate | ActPolicy::Encode => ZeroGate::On,
            ActPolicy::Auto => ZeroGate::Auto,
        }
    }

    /// Set the default policy through the legacy two-way [`ZeroGate`]
    /// surface: `Off` → [`ActPolicy::Off`], `On` → [`ActPolicy::Gate`],
    /// `Auto` → [`ActPolicy::Auto`] (which may resolve to `Encode` on
    /// sufficiently sparse layers — still bit-exact).
    pub fn set_zero_gate(&mut self, gate: ZeroGate) {
        self.act_policy = match gate {
            ZeroGate::Off => ActPolicy::Off,
            ZeroGate::On => ActPolicy::Gate,
            ZeroGate::Auto => ActPolicy::Auto,
        };
    }

    /// The measured per-layer activation sparsities — `Some` once
    /// [`Self::profile`] ran. This is the **one sparsity source** shared by
    /// the software gate (`Auto` consults it per layer) and the hardware
    /// twin's priced A-side gating ([`Self::profiles`] copies the same
    /// values into [`LayerProfile::act_sparsity`]).
    pub fn measured_act_sparsity(&self) -> Option<&[f64]> {
        if self.measured_act.len() != self.layers.len() {
            return None;
        }
        Some(&self.measured_act)
    }

    /// Run the model's scratch arena through `f`: the preallocated arena
    /// when it is free, a reclaimed one after a poisoning panic (the
    /// buffers are fully rewritten before every read, so that is safe), a
    /// transient one when a concurrent execute holds it.
    fn with_scratch<R>(&self, f: impl FnOnce(&mut PatchScratch) -> R) -> R {
        match self.scratch.try_lock() {
            Ok(mut guard) => f(&mut guard),
            Err(std::sync::TryLockError::Poisoned(p)) => f(&mut p.into_inner()),
            Err(std::sync::TryLockError::WouldBlock) => f(&mut PatchScratch::new()),
        }
    }

    /// Run the whole network on `input` (any non-empty feature map /
    /// matrix; it is wrap-fitted to the first layer's sampled shape) with
    /// zero weight encode/decode work: every layer streams its prepared
    /// operand through the fused/tiled kernels, under the model-level
    /// default [`ActPolicy`] ([`ActPolicy::Auto`] unless
    /// [`Self::set_act_policy`] changed it). Repeated calls with the same
    /// input return identical results — the engine holds no mutable state
    /// beyond the scratch buffers, which are fully rewritten before every
    /// read, and no activation policy changes a bit.
    ///
    /// # Example
    ///
    /// ```
    /// use ssta::engine::PreparedModel;
    /// use ssta::util::Parallelism;
    ///
    /// let par = Parallelism::serial();
    /// let pm = PreparedModel::prepare(&ssta::models::lenet5(), 2, 8, 42, par);
    /// // execute many times with zero per-call encode; results are
    /// // deterministic and per-layer activation sparsities come back too
    /// let a = pm.execute(pm.seed_input(), par);
    /// let b = pm.execute(pm.seed_input(), par);
    /// assert_eq!(a.output, b.output);
    /// assert_eq!(a.act_sparsity.len(), pm.layers().len());
    /// ```
    pub fn execute(&self, input: &TensorI8, par: Parallelism) -> Execution {
        self.execute_policy(input, par, self.act_policy)
    }

    /// [`Self::execute`] under an explicit three-way [`ActPolicy`]. `Auto`
    /// resolves per layer against the *measured* activation sparsity the
    /// recorded profile holds for that layer (the same value the hardware
    /// twin prices), falling back to the zero fraction of the layer's
    /// current input operand — which the execute loop measures anyway — on
    /// an unprofiled model. The kernels receive a pre-resolved
    /// `Off`/`Gate`/`Encode`, so no operand is scanned twice.
    pub fn execute_policy(
        &self,
        input: &TensorI8,
        par: Parallelism,
        policy: ActPolicy,
    ) -> Execution {
        self.with_scratch(|scratch| self.execute_policy_with(input, par, policy, scratch))
    }

    /// [`Self::execute`] under an explicit legacy [`ZeroGate`] policy —
    /// the two-way surface: it gates or not, but **never encodes** (`Auto`
    /// here is the PR-4 gate-only auto). Bit-exact with every other path.
    pub fn execute_gated(&self, input: &TensorI8, par: Parallelism, gate: ZeroGate) -> Execution {
        self.with_scratch(|scratch| self.execute_gated_with(input, par, gate, scratch))
    }

    /// [`Self::execute`] on a caller-owned scratch arena (model-level
    /// default activation policy).
    pub fn execute_with(
        &self,
        input: &TensorI8,
        par: Parallelism,
        scratch: &mut PatchScratch,
    ) -> Execution {
        self.execute_policy_with(input, par, self.act_policy, scratch)
    }

    /// [`Self::execute_policy`] on a caller-owned scratch arena.
    pub fn execute_policy_with(
        &self,
        input: &TensorI8,
        par: Parallelism,
        policy: ActPolicy,
        scratch: &mut PatchScratch,
    ) -> Execution {
        self.execute_resolved_with(
            input,
            par,
            |li, in_s| policy.resolved(self.measured_act.get(li).copied().unwrap_or(in_s)),
            scratch,
            ShiftSource::Dynamic,
        )
    }

    /// [`Self::execute_gated`] on a caller-owned scratch arena.
    pub fn execute_gated_with(
        &self,
        input: &TensorI8,
        par: Parallelism,
        gate: ZeroGate,
        scratch: &mut PatchScratch,
    ) -> Execution {
        self.execute_resolved_with(
            input,
            par,
            |li, in_s| {
                if gate.engaged(self.measured_act.get(li).copied().unwrap_or(in_s)) {
                    ActPolicy::Gate
                } else {
                    ActPolicy::Off
                }
            },
            scratch,
            ShiftSource::Dynamic,
        )
    }

    /// The one execute loop every public variant funnels into. `resolve`
    /// maps `(layer index, measured input zero fraction)` to the final
    /// per-layer policy (never `Auto`); the kernels are then dispatched on
    /// `(operand kind, policy)` — `Encode` runs the joint A-DBB kernels
    /// (conv layers encode patch-row chunks inside the fused engine, FC
    /// layers encode the operand once), `Gate`/`Off` run the gated/plain
    /// kernels.
    fn execute_resolved_with(
        &self,
        input: &TensorI8,
        par: Parallelism,
        resolve: impl Fn(usize, f64) -> ActPolicy,
        scratch: &mut PatchScratch,
        mut shifts: ShiftSource<'_>,
    ) -> Execution {
        assert!(!input.is_empty(), "execute input must be non-empty");
        let mut act_sparsity = Vec::with_capacity(self.layers.len());
        let mut act_policy = Vec::with_capacity(self.layers.len());
        let mut gate_engaged = Vec::with_capacity(self.layers.len());
        let mut fmap: Option<TensorI8> = None;
        for (li, l) in self.layers.iter().enumerate() {
            let prev = fmap.as_ref().unwrap_or(input);
            let (acc, in_s, pol) = match l.sample {
                SampleShape::Conv(ss) => {
                    let x = fit_fmap_from(prev, ss.h, ss.w, ss.c);
                    let in_s = x.sparsity();
                    let pol = self.layer_policy(li, resolve(li, in_s));
                    debug_assert_ne!(pol, ActPolicy::Auto, "resolve must not return Auto");
                    let acc = match (&l.operand, pol) {
                        (PackedOperand::Dbb(p), ActPolicy::Encode) => {
                            fused::conv2d_dbb_i8_packed_encoded_with(&x, p, &ss, par, scratch)
                        }
                        (PackedOperand::Dbb(p), _) => fused::conv2d_dbb_i8_packed_gated_with(
                            &x,
                            p,
                            &ss,
                            par,
                            pol.gate(),
                            scratch,
                        ),
                        (PackedOperand::Bsr(p), _) => fused::conv2d_bsr_i8_packed_gated_with(
                            &x,
                            p,
                            &ss,
                            par,
                            pol.gate(),
                            scratch,
                        ),
                        (PackedOperand::Dense(w), ActPolicy::Encode) => {
                            fused::conv2d_i8_encoded_with(&x, w, &ss, par, scratch)
                        }
                        (PackedOperand::Dense(w), _) => {
                            fused::conv2d_i8_gated_with(&x, w, &ss, par, pol.gate(), scratch)
                        }
                    };
                    (acc, in_s, pol)
                }
                SampleShape::Fc { m, k } => {
                    let a = fit_matrix_from(prev, m, k);
                    let in_s = a.sparsity();
                    let pol = self.layer_policy(li, resolve(li, in_s));
                    debug_assert_ne!(pol, ActPolicy::Auto, "resolve must not return Auto");
                    let acc = match (&l.operand, pol) {
                        (PackedOperand::Dbb(p), ActPolicy::Encode) => {
                            tiled::adbb_i8_packed(scratch.act_encode(&a, self.bz), p, par)
                        }
                        (PackedOperand::Dbb(p), _) => {
                            tiled::dbb_i8_packed_gated(&a, p, par, pol.gate())
                        }
                        (PackedOperand::Bsr(p), _) => {
                            tiled::bsr_i8_packed_gated(&a, p, par, pol.gate())
                        }
                        (PackedOperand::Dense(w), ActPolicy::Encode) => {
                            tiled::adbb_dense_i8(scratch.act_encode(&a, self.bz), w, par)
                        }
                        (PackedOperand::Dense(w), _) => {
                            tiled::dense_i8_gated(&a, w, par, pol.gate())
                        }
                    };
                    (acc, in_s, pol)
                }
            };
            act_sparsity.push(in_s);
            act_policy.push(pol);
            gate_engaged.push(pol != ActPolicy::Off);
            // `requant_relu(acc, relu)` is exactly
            // `requant_with_shift(acc, requant_shift(acc.data()), relu)`,
            // so Dynamic and Record are bit-identical (the max of the
            // per-column shifts IS the global shift — monotone derivation).
            let mut out = match &mut shifts {
                ShiftSource::Dynamic => requant_relu(&acc, l.relu),
                ShiftSource::Record(rec) => {
                    let n = *acc.shape().last().unwrap_or(&1);
                    let perch = requant_col_shifts(acc.data(), n.max(1));
                    let sh = perch.iter().copied().max().unwrap_or(0);
                    debug_assert_eq!(sh, requant_shift(acc.data()));
                    rec.shifts.push(sh);
                    rec.perch.push(perch);
                    requant_with_shift(&acc, sh, l.relu)
                }
                ShiftSource::Frozen(sh) => requant_with_shift(&acc, sh[li], l.relu),
            };
            if self.fused_pool {
                if let SampleShape::Conv(ss) = l.sample {
                    let (oh, ow) = (ss.oh(), ss.ow());
                    if oh >= 2 && ow >= 2 {
                        out = max_pool_2x2(&out, oh, ow, ss.oc)
                            .reshape(&[oh / 2, ow / 2, ss.oc]);
                    }
                }
            }
            // propagate: conv outputs keep spatial form, FC outputs become
            // a 1×m×n map
            fmap = Some(if out.shape().len() == 3 {
                out
            } else {
                let (om, on) = (out.shape()[0], out.shape()[1]);
                out.reshape(&[1, om, on])
            });
        }
        Execution {
            output: fmap.unwrap_or_else(|| input.clone()),
            act_sparsity,
            act_policy,
            gate_engaged,
        }
    }

    /// Freeze the per-layer requantize shifts by running one staged pass
    /// over the stored seed input and recording each layer's
    /// data-dependent shift ([`crate::gemm::epilogue::requant_shift`]).
    /// The fused-epilogue executor ([`Self::execute_fused`]) requantizes
    /// rows *while the GEMM walks them*, so it needs the shift up front;
    /// calibration is the offline step that provides it — the same
    /// offline/online split the weights already go through. The shifts are
    /// policy-independent (every activation policy is bit-exact, so the
    /// i32 accumulators — and their shifts — are identical under all of
    /// them). The same pass also records each layer's **per-output-channel**
    /// shifts (from the accumulator's per-column maxima; see
    /// [`Self::calibrated_channel_shifts`]) — the global shift served by the
    /// fused epilogue is their maximum, bit for bit. Returns the recorded
    /// global shifts.
    pub fn calibrate(&mut self, par: Parallelism) -> &[u32] {
        let mut rec = CalibRecord::default();
        self.with_scratch(|scratch| {
            self.execute_resolved_with(
                &self.seed_input,
                par,
                |li, in_s| {
                    self.act_policy.resolved(self.measured_act.get(li).copied().unwrap_or(in_s))
                },
                scratch,
                ShiftSource::Record(&mut rec),
            );
        });
        self.shifts = rec.shifts;
        self.perch_shifts = rec.perch;
        &self.shifts
    }

    /// The per-layer requantize shifts frozen by [`Self::calibrate`] —
    /// `Some` once a calibration pass ran.
    pub fn calibrated_shifts(&self) -> Option<&[u32]> {
        if self.shifts.len() != self.layers.len() {
            return None;
        }
        Some(&self.shifts)
    }

    /// The per-layer, per-output-channel requantize shifts recorded by the
    /// same [`Self::calibrate`] pass — `Some` once calibration ran. Each
    /// layer's entry holds one shift per accumulator column (conv: output
    /// channel; FC: output feature), and its maximum equals the layer's
    /// global calibrated shift ([`Self::calibrated_shifts`]) by the
    /// monotonicity of shift derivation — at uniform per-column maxima a
    /// [`Requant::PerChannel`] epilogue built from these reproduces the
    /// global path bit for bit.
    pub fn calibrated_channel_shifts(&self) -> Option<&[Vec<u32>]> {
        if self.perch_shifts.len() != self.layers.len() {
            return None;
        }
        Some(&self.perch_shifts)
    }

    /// Whether every execute path folds a 2×2/stride-2 max-pool after each
    /// conv layer.
    pub fn fused_pool(&self) -> bool {
        self.fused_pool
    }

    /// Fold a 2×2/stride-2 max-pool after every conv layer (skipped on
    /// conv outputs narrower than 2×2), **uniformly across every execute
    /// path** — [`Self::execute`], [`Self::execute_staged`], and
    /// [`Self::execute_fused`] all apply it, so staged-vs-fused
    /// bit-exactness is preserved. The fused path folds the pool into the
    /// GEMM output walk; the staged paths run it as a separate pass over
    /// the requantized i8 map. Default `false` (the historical chain).
    pub fn set_fused_pool(&mut self, on: bool) {
        self.fused_pool = on;
    }

    /// Whether [`Self::profiles`] declares the fused-epilogue execution
    /// style to the hardware twin.
    pub fn fused_epilogue(&self) -> bool {
        self.fused_epilogue
    }

    /// Declare (for twin pricing) that this model serves through
    /// [`Self::execute_fused`]: [`Self::profiles`] then sets
    /// [`LayerProfile::fused_epilogue`] on every layer, moving the
    /// requant/ReLU/pool cycles out of the MCU post-processing column and
    /// into the array-overlapped epilogue counter. Functional results are
    /// unaffected.
    pub fn set_fused_epilogue(&mut self, on: bool) {
        self.fused_epilogue = on;
    }

    /// Whether fused executes requantize under the calibrated per-channel
    /// shifts instead of the layer-global maximum.
    pub fn per_channel_requant(&self) -> bool {
        self.per_channel_requant
    }

    /// Opt the fused serving paths into **per-output-channel** requantize
    /// shifts ([`Requant::PerChannel`], from the same [`Self::calibrate`]
    /// pass that freezes the global ones). Channels whose calibrated shift
    /// is smaller than the layer maximum keep more low-order bits — finer
    /// quantization at identical kernel cost. With uniform per-channel
    /// shifts this reproduces the global path bit for bit; otherwise the
    /// outputs intentionally differ from the global-shift oracle, so leave
    /// this off where staged-vs-fused bit-exactness is being checked.
    /// Default `false`.
    pub fn set_per_channel_requant(&mut self, on: bool) {
        self.per_channel_requant = on;
    }

    /// The staged oracle for the fused path: the historical
    /// materialize-i32 → `requant_with_shift` → pool chain, but with the
    /// *frozen calibrated* shifts instead of per-input dynamic ones — the
    /// exact computation [`Self::execute_fused`] performs in one streaming
    /// pass. Panics unless [`Self::calibrate`] ran. On the seed input this
    /// is additionally bit-identical to [`Self::execute`] (the recorded
    /// shifts *are* the seed input's dynamic shifts, layer by layer).
    pub fn execute_staged(&self, input: &TensorI8, par: Parallelism) -> Execution {
        let shifts = self.calibrated_shifts().expect("calibrate() before execute_staged");
        self.with_scratch(|scratch| {
            self.execute_resolved_with(
                input,
                par,
                |li, in_s| {
                    self.act_policy.resolved(self.measured_act.get(li).copied().unwrap_or(in_s))
                },
                scratch,
                ShiftSource::Frozen(shifts),
            )
        })
    }

    /// Run the whole network with the layer epilogue **fused into the GEMM
    /// output walk**: each layer's workers requantize (+ ReLU, + pool under
    /// [`Self::set_fused_pool`]) their freshly accumulated rows to i8 while
    /// cache-hot, layers chain i8→i8 through recycled output backings (the
    /// scratch arena's ping-pong pool), and **no whole-layer i32 tensor is
    /// ever allocated**. Bit-exact with [`Self::execute_staged`] on every
    /// input, under every activation policy and ISA
    /// (`rust/tests/epilogue.rs`). Panics unless [`Self::calibrate`] ran.
    pub fn execute_fused(&self, input: &TensorI8, par: Parallelism) -> Execution {
        self.execute_fused_policy(input, par, self.act_policy)
    }

    /// [`Self::execute_fused`] under an explicit [`ActPolicy`].
    pub fn execute_fused_policy(
        &self,
        input: &TensorI8,
        par: Parallelism,
        policy: ActPolicy,
    ) -> Execution {
        self.with_scratch(|scratch| self.execute_fused_policy_with(input, par, policy, scratch))
    }

    /// [`Self::execute_fused_policy`] on a caller-owned scratch arena.
    pub fn execute_fused_policy_with(
        &self,
        input: &TensorI8,
        par: Parallelism,
        policy: ActPolicy,
        scratch: &mut PatchScratch,
    ) -> Execution {
        assert!(!input.is_empty(), "execute input must be non-empty");
        let shifts = self.calibrated_shifts().expect("calibrate() before execute_fused");
        let mut act_sparsity = Vec::with_capacity(self.layers.len());
        let mut act_policy = Vec::with_capacity(self.layers.len());
        let mut gate_engaged = Vec::with_capacity(self.layers.len());
        let mut fmap: Option<TensorI8> = None;
        for (li, l) in self.layers.iter().enumerate() {
            let out = {
                let prev = fmap.as_ref().unwrap_or(input);
                let (out, in_s, pol) = match l.sample {
                    SampleShape::Conv(ss) => {
                        let x = fit_fmap_from(prev, ss.h, ss.w, ss.c);
                        let in_s = x.sparsity();
                        let pol = self
                            .layer_policy(li, policy.resolved(
                                self.measured_act.get(li).copied().unwrap_or(in_s),
                            ));
                        let mut ep = Epilogue::new(self.layer_requant(li, shifts), l.relu);
                        if self.fused_pool && ss.oh() >= 2 && ss.ow() >= 2 {
                            ep = ep.with_pool(PoolGeom { oh: ss.oh(), ow: ss.ow() });
                        }
                        let buf = scratch.take_out_buf();
                        let out = match (&l.operand, pol) {
                            (PackedOperand::Dbb(p), ActPolicy::Encode) => {
                                fused::conv2d_dbb_i8_packed_encoded_ep_with(
                                    &x, p, &ss, par, &ep, scratch, buf,
                                )
                            }
                            (PackedOperand::Dbb(p), _) => fused::conv2d_dbb_i8_packed_ep_with(
                                &x,
                                p,
                                &ss,
                                par,
                                pol.gate(),
                                &ep,
                                scratch,
                                buf,
                            ),
                            (PackedOperand::Bsr(p), _) => fused::conv2d_bsr_i8_packed_ep_with(
                                &x,
                                p,
                                &ss,
                                par,
                                pol.gate(),
                                &ep,
                                scratch,
                                buf,
                            ),
                            (PackedOperand::Dense(w), ActPolicy::Encode) => {
                                fused::conv2d_i8_encoded_ep_with(&x, w, &ss, par, &ep, scratch, buf)
                            }
                            (PackedOperand::Dense(w), _) => fused::conv2d_i8_ep_with(
                                &x,
                                w,
                                &ss,
                                par,
                                pol.gate(),
                                &ep,
                                scratch,
                                buf,
                            ),
                        };
                        (out, in_s, pol)
                    }
                    SampleShape::Fc { m, k } => {
                        let a = fit_matrix_from(prev, m, k);
                        let in_s = a.sparsity();
                        let pol = self
                            .layer_policy(li, policy.resolved(
                                self.measured_act.get(li).copied().unwrap_or(in_s),
                            ));
                        let ep = Epilogue::new(self.layer_requant(li, shifts), l.relu);
                        let buf = scratch.take_out_buf();
                        let out = match (&l.operand, pol) {
                            (PackedOperand::Dbb(p), ActPolicy::Encode) => {
                                tiled::adbb_i8_packed_ep_into(
                                    scratch.act_encode(&a, self.bz),
                                    p,
                                    par,
                                    &ep,
                                    buf,
                                )
                            }
                            (PackedOperand::Dbb(p), _) => {
                                tiled::dbb_i8_packed_ep_into(&a, p, par, pol.gate(), &ep, buf)
                            }
                            (PackedOperand::Bsr(p), _) => {
                                tiled::bsr_i8_packed_ep_into(&a, p, par, pol.gate(), &ep, buf)
                            }
                            (PackedOperand::Dense(w), ActPolicy::Encode) => {
                                tiled::adbb_dense_i8_ep_into(
                                    scratch.act_encode(&a, self.bz),
                                    w,
                                    par,
                                    &ep,
                                    buf,
                                )
                            }
                            (PackedOperand::Dense(w), _) => {
                                tiled::dense_i8_ep_into(&a, w, par, pol.gate(), &ep, buf)
                            }
                        };
                        let (om, on) = (out.shape()[0], out.shape()[1]);
                        (out.reshape(&[1, om, on]), in_s, pol)
                    }
                };
                act_sparsity.push(in_s);
                act_policy.push(pol);
                gate_engaged.push(pol != ActPolicy::Off);
                out
            };
            // ping-pong: the layer that just ran has consumed the previous
            // feature map — recycle its backing for a later layer's output
            if li > 0 {
                if let Some(prev) = fmap.take() {
                    scratch.put_out_buf(prev.into_vec());
                }
            }
            fmap = Some(out);
        }
        Execution {
            output: fmap.unwrap_or_else(|| input.clone()),
            act_sparsity,
            act_policy,
            gate_engaged,
        }
    }

    /// Run a whole **batch** of inputs through the fused-epilogue chain in
    /// one pass per layer: the batch folds into the GEMM `M` dimension (the
    /// conv kernels natively accept `[b, h, w, c]` feature maps, FC layers
    /// stack their row blocks), so `b` requests share every weight-operand
    /// stream, epilogue walk, and worker-pool dispatch instead of paying
    /// them per image. Returns one output tensor per input, **bit-exact**
    /// with `b` independent [`Self::execute_fused`] calls (the kernels
    /// partition work on row boundaries and every row's arithmetic is
    /// independent of its batch neighbors). Steady-state allocation-free:
    /// batch staging buffers and layer outputs all draw from the scratch
    /// arena's ping-pong pool. Panics unless [`Self::calibrate`] ran.
    pub fn execute_fused_batch(&self, inputs: &[TensorI8], par: Parallelism) -> Vec<TensorI8> {
        assert!(!inputs.is_empty(), "batch must be non-empty");
        for x in inputs {
            assert!(!x.is_empty(), "execute input must be non-empty");
        }
        let shifts = self.calibrated_shifts().expect("calibrate() before execute_fused_batch");
        if self.layers.is_empty() {
            return inputs.to_vec();
        }
        let b = inputs.len();
        self.with_scratch(|scratch| {
            // invariant: `fmap` is always `[b, d0, d1, d2]` where
            // `[d0, d1, d2]` is the per-image feature-map shape the
            // single-image chain would propagate (conv: `[oh, ow, oc]`;
            // FC: `[1, m, n]`) — so per-image slices are byte-identical to
            // the single-image path's intermediates.
            let mut fmap: Option<TensorI8> = None;
            for (li, l) in self.layers.iter().enumerate() {
                let (out, staged) = match l.sample {
                    SampleShape::Conv(ss) => {
                        let img = ss.h * ss.w * ss.c;
                        // aligned chain: the previous batched map IS this
                        // layer's [b, h, w, c] input — no copy, mirroring
                        // fit_fmap_from's borrow fast path per image
                        let aligned = matches!(&fmap, Some(prev)
                            if prev.shape()[1..] == [ss.h, ss.w, ss.c]);
                        let mut staged: Option<TensorI8> = None;
                        let x: &TensorI8 = if aligned {
                            fmap.as_ref().unwrap()
                        } else {
                            let mut bx = scratch.take_out_buf();
                            bx.clear();
                            bx.resize(b * img, 0);
                            match &fmap {
                                None => {
                                    for (i, xin) in inputs.iter().enumerate() {
                                        fit_fmap_into(
                                            xin.data(),
                                            xin.shape(),
                                            ss.h,
                                            ss.w,
                                            ss.c,
                                            &mut bx[i * img..(i + 1) * img],
                                        );
                                    }
                                }
                                Some(prev) => {
                                    let ishape = &prev.shape()[1..];
                                    let ilen = prev.len() / b;
                                    for i in 0..b {
                                        fit_fmap_into(
                                            &prev.data()[i * ilen..(i + 1) * ilen],
                                            ishape,
                                            ss.h,
                                            ss.w,
                                            ss.c,
                                            &mut bx[i * img..(i + 1) * img],
                                        );
                                    }
                                }
                            }
                            staged = Some(TensorI8::from_vec(&[b, ss.h, ss.w, ss.c], bx));
                            staged.as_ref().unwrap()
                        };
                        let in_s = x.sparsity();
                        let pol = self.layer_policy(
                            li,
                            self.act_policy
                                .resolved(self.measured_act.get(li).copied().unwrap_or(in_s)),
                        );
                        let mut ep = Epilogue::new(self.layer_requant(li, shifts), l.relu);
                        if self.fused_pool && ss.oh() >= 2 && ss.ow() >= 2 {
                            ep = ep.with_pool(PoolGeom { oh: ss.oh(), ow: ss.ow() });
                        }
                        let buf = scratch.take_out_buf();
                        let out = match (&l.operand, pol) {
                            (PackedOperand::Dbb(p), ActPolicy::Encode) => {
                                fused::conv2d_dbb_i8_packed_encoded_ep_with(
                                    x, p, &ss, par, &ep, scratch, buf,
                                )
                            }
                            (PackedOperand::Dbb(p), _) => fused::conv2d_dbb_i8_packed_ep_with(
                                x,
                                p,
                                &ss,
                                par,
                                pol.gate(),
                                &ep,
                                scratch,
                                buf,
                            ),
                            (PackedOperand::Bsr(p), _) => fused::conv2d_bsr_i8_packed_ep_with(
                                x,
                                p,
                                &ss,
                                par,
                                pol.gate(),
                                &ep,
                                scratch,
                                buf,
                            ),
                            (PackedOperand::Dense(w), ActPolicy::Encode) => {
                                fused::conv2d_i8_encoded_ep_with(x, w, &ss, par, &ep, scratch, buf)
                            }
                            (PackedOperand::Dense(w), _) => fused::conv2d_i8_ep_with(
                                x,
                                w,
                                &ss,
                                par,
                                pol.gate(),
                                &ep,
                                scratch,
                                buf,
                            ),
                        };
                        (out, staged)
                    }
                    SampleShape::Fc { m, k } => {
                        // per image block: exactly fit_matrix_from's bytes
                        // (wrap_fill degenerates to one copy on exact fit)
                        let rows = b * m;
                        let mut ab = scratch.take_out_buf();
                        ab.clear();
                        ab.resize(rows * k, 0);
                        match &fmap {
                            None => {
                                for (i, xin) in inputs.iter().enumerate() {
                                    wrap_fill(xin.data(), &mut ab[i * m * k..(i + 1) * m * k]);
                                }
                            }
                            Some(prev) => {
                                let ilen = prev.len() / b;
                                for i in 0..b {
                                    wrap_fill(
                                        &prev.data()[i * ilen..(i + 1) * ilen],
                                        &mut ab[i * m * k..(i + 1) * m * k],
                                    );
                                }
                            }
                        }
                        let a = TensorI8::from_vec(&[rows, k], ab);
                        let in_s = a.sparsity();
                        let pol = self.layer_policy(
                            li,
                            self.act_policy
                                .resolved(self.measured_act.get(li).copied().unwrap_or(in_s)),
                        );
                        let ep = Epilogue::new(self.layer_requant(li, shifts), l.relu);
                        let buf = scratch.take_out_buf();
                        let out = match (&l.operand, pol) {
                            (PackedOperand::Dbb(p), ActPolicy::Encode) => {
                                tiled::adbb_i8_packed_ep_into(
                                    scratch.act_encode(&a, self.bz),
                                    p,
                                    par,
                                    &ep,
                                    buf,
                                )
                            }
                            (PackedOperand::Dbb(p), _) => {
                                tiled::dbb_i8_packed_ep_into(&a, p, par, pol.gate(), &ep, buf)
                            }
                            (PackedOperand::Bsr(p), _) => {
                                tiled::bsr_i8_packed_ep_into(&a, p, par, pol.gate(), &ep, buf)
                            }
                            (PackedOperand::Dense(w), ActPolicy::Encode) => {
                                tiled::adbb_dense_i8_ep_into(
                                    scratch.act_encode(&a, self.bz),
                                    w,
                                    par,
                                    &ep,
                                    buf,
                                )
                            }
                            (PackedOperand::Dense(w), _) => {
                                tiled::dense_i8_ep_into(&a, w, par, pol.gate(), &ep, buf)
                            }
                        };
                        let on = out.shape()[1];
                        (out.reshape(&[b, 1, m, on]), Some(a))
                    }
                };
                // ping-pong: the layer consumed the previous batched map and
                // any staging copy — recycle both backings
                if let Some(prev) = fmap.take() {
                    scratch.put_out_buf(prev.into_vec());
                }
                if let Some(s) = staged {
                    scratch.put_out_buf(s.into_vec());
                }
                fmap = Some(out);
            }
            let fmap = fmap.expect("at least one layer ran");
            let ishape = fmap.shape()[1..].to_vec();
            let ilen = fmap.len() / b;
            let data = fmap.data();
            (0..b)
                .map(|i| TensorI8::from_vec(&ishape, data[i * ilen..(i + 1) * ilen].to_vec()))
                .collect()
        })
    }

    /// Replay the seeded sampled functional inference (the historical
    /// `profile_model` pass), record the measured per-layer activation
    /// sparsities into the model, and return the layer profiles the
    /// timing/power models consume. Bit-exact with the per-call-encoding
    /// path for the same `(model, nnz, bz, seed)` at any worker-pool width
    /// and under any [`ZeroGate`] policy (gating never changes a bit, so
    /// the recorded sparsities are gating-invariant).
    pub fn profile(&mut self, par: Parallelism) -> Vec<LayerProfile> {
        let rec = self.execute(&self.seed_input, par);
        self.measured_act = rec.act_sparsity;
        self.profiles().expect("profile just ran")
    }

    /// Layer profiles with *measured* activation sparsity — available once
    /// [`Self::profile`] has run, `None` before (the serving twin falls
    /// back to an assumed scalar in that case). Each profile also carries
    /// the layer's resolved A-side *encode* decision
    /// ([`LayerProfile::act_encoded`]): whether this model's
    /// [`Self::act_policy`] would DBB-encode that layer's activations at
    /// serve time, resolved from the same measured sparsity — so the twin
    /// prices compressed A-stream traffic for exactly the layers the
    /// executor compresses.
    pub fn profiles(&self) -> Option<Vec<LayerProfile>> {
        if self.measured_act.len() != self.layers.len() {
            return None;
        }
        Some(
            self.layers
                .iter()
                .zip(&self.measured_act)
                .map(|(l, &act)| LayerProfile {
                    name: l.name.clone(),
                    m: l.m,
                    weights: l.weights,
                    format: l.operand.format(),
                    act_sparsity: act,
                    act_encoded: self.act_policy.resolved(act) == ActPolicy::Encode
                        && !matches!(l.operand, PackedOperand::Bsr(_)),
                    im2col_magnification: l.im2col_magnification,
                    raw_act_bytes: l.raw_act_bytes,
                    out_elems: l.out_elems,
                    relu: l.relu,
                    fused_epilogue: self.fused_epilogue,
                })
                .collect(),
        )
    }

    /// The prepared layers, in execution order.
    pub fn layers(&self) -> &[PreparedLayer] {
        &self.layers
    }

    /// The seeded input sample the profile pass feeds to the first layer.
    pub fn seed_input(&self) -> &TensorI8 {
        &self.seed_input
    }

    /// Model name this was prepared from.
    pub fn model_name(&self) -> &'static str {
        self.name
    }

    /// `(nnz, bz, seed)` the model was prepared with.
    pub fn encoding(&self) -> (usize, usize, u64) {
        (self.nnz, self.bz, self.seed)
    }

    /// Total host bytes of all packed weight operands (steady-state
    /// weight-memory footprint of the executor).
    pub fn operand_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.operand.operand_bytes()).sum()
    }

    /// Serialize the whole prepared model — packed operands, sampled
    /// geometry, profile facts, measured sparsities, calibrated shifts —
    /// into the versioned flat-binary format ([`PERSIST_MAGIC`]). This is
    /// the paper's offline-encode artifact (§II-A) made durable: a restarted
    /// server [`Self::from_bytes`] the stream and serves immediately, with
    /// **no synthesize, no top-k prune, no DBB encode, no calibration** —
    /// the expensive one-time lowering never reruns. The stream is
    /// little-endian, byte-stable across hosts, and ends in an FNV-1a
    /// checksum over everything before it.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut w = BinWriter::new();
        w.bytes(PERSIST_MAGIC);
        w.str(self.name);
        w.usize(self.nnz);
        w.usize(self.bz);
        w.u64(self.seed);
        w.u8(act_policy_to_u8(self.act_policy));
        w.u8(self.fused_pool as u8);
        w.u8(self.fused_epilogue as u8);
        w.u8(self.format.tag());
        w.u8(self.per_channel_requant as u8);
        write_tensor(&mut w, &self.seed_input);
        w.usize(self.measured_act.len());
        for &v in &self.measured_act {
            w.f64(v);
        }
        w.usize(self.shifts.len());
        for &s in &self.shifts {
            w.u32(s);
        }
        w.usize(self.perch_shifts.len());
        for per in &self.perch_shifts {
            w.usize(per.len());
            for &s in per {
                w.u32(s);
            }
        }
        w.usize(self.layers.len());
        for l in &self.layers {
            w.str(&l.name);
            w.usize(l.m);
            w.usize(l.weights.k);
            w.usize(l.weights.n);
            w.usize(l.weights.bz);
            w.usize(l.weights.bound);
            match l.sample {
                SampleShape::Conv(s) => {
                    w.u8(0);
                    for d in [s.h, s.w, s.c, s.kh, s.kw, s.oc, s.stride, s.pad] {
                        w.usize(d);
                    }
                }
                SampleShape::Fc { m, k } => {
                    w.u8(1);
                    w.usize(m);
                    w.usize(k);
                }
            }
            match &l.operand {
                PackedOperand::Dbb(p) => {
                    w.u8(0);
                    w.usize(p.k);
                    w.usize(p.n);
                    w.usize(p.bz);
                    w.usize(p.bound);
                    let col_ptr = p.col_ptr();
                    w.usize(col_ptr.len());
                    for &cp in col_ptr {
                        w.usize(cp);
                    }
                    let entries = p.entries();
                    w.usize(entries.len());
                    for &(ki, v) in entries {
                        w.u32(ki);
                        w.u32(v as u32);
                    }
                }
                PackedOperand::Dense(t) => {
                    w.u8(1);
                    write_tensor(&mut w, t);
                }
                PackedOperand::Bsr(p) => {
                    w.u8(2);
                    w.usize(p.k);
                    w.usize(p.n);
                    w.usize(p.bz_r);
                    w.usize(p.bz_c);
                    let row_ptr = p.row_ptr();
                    w.usize(row_ptr.len());
                    for &v in row_ptr {
                        w.usize(v);
                    }
                    let col_idx = p.col_idx();
                    w.usize(col_idx.len());
                    for &v in col_idx {
                        w.u32(v);
                    }
                    w.i8_slice(p.blocks());
                }
            }
            w.f64(l.im2col_magnification);
            w.u64(l.raw_act_bytes);
            w.u64(l.out_elems);
            w.u8(l.relu as u8);
        }
        let mut bytes = w.into_vec();
        let cs = fnv1a64(&bytes);
        bytes.extend_from_slice(&cs.to_le_bytes());
        bytes
    }

    /// Deserialize a prepared model from [`Self::to_bytes`]' format.
    /// Untrusted input is safe: the trailing checksum is verified **first**,
    /// every length is bounds-checked against the remaining stream before
    /// allocation, and every packed weight stream is revalidated through
    /// [`DbbPacked::from_raw_parts`] / [`BsrPacked::from_raw_parts`] —
    /// truncation or corruption yields a clean `Err`, never a panic.
    /// Accepts both the current [`PERSIST_MAGIC`] (v2) layout and legacy
    /// [`PERSIST_MAGIC_V1`] streams (which predate the BSR datapath and
    /// load as DBB-format models). `par` sizes the preallocated scratch
    /// arena exactly as [`Self::prepare`] would. Bit-exact with the model
    /// that was saved: same outputs, shifts, measured sparsities, operand
    /// bytes (`rust/tests/persistence.rs`).
    pub fn from_bytes(bytes: &[u8], par: Parallelism) -> Result<PreparedModel> {
        if bytes.len() < PERSIST_MAGIC.len() + 8 {
            bail!("prepared-model stream too short ({} bytes)", bytes.len());
        }
        let (body, tail) = bytes.split_at(bytes.len() - 8);
        let stored = u64::from_le_bytes(tail.try_into().expect("8-byte tail"));
        if stored != fnv1a64(body) {
            bail!("prepared-model checksum mismatch (file corrupted or truncated)");
        }
        let mut r = BinReader::new(body);
        let magic = r.bytes(PERSIST_MAGIC.len())?;
        let v2 = magic == PERSIST_MAGIC;
        if !v2 && magic != PERSIST_MAGIC_V1 {
            bail!("not a prepared-model stream (bad magic/version)");
        }
        let name_s = r.str()?.to_string();
        let nnz = r.usize()?;
        let bz = r.usize()?;
        let seed = r.u64()?;
        let act_policy = act_policy_from_u8(r.u8()?)?;
        let fused_pool = r.u8()? != 0;
        let fused_epilogue = r.u8()? != 0;
        // v2 header additions; v1 streams predate both BSR and the
        // per-channel epilogue flag, so Dbb/off are exact, not guesses
        let (format, per_channel_requant) = if v2 {
            let tag = r.u8()?;
            let f = WeightFormat::from_tag(tag)
                .ok_or_else(|| crate::anyhow!("unknown weight-format tag {tag}"))?;
            (f, r.u8()? != 0)
        } else {
            (WeightFormat::Dbb, false)
        };
        let seed_input = read_tensor(&mut r)?;
        let measured_act = r.f64_vec()?;
        let shifts = r.u32_vec()?;
        let nperch = r.len_prefix(8)?;
        let mut perch_shifts = Vec::with_capacity(nperch);
        for _ in 0..nperch {
            perch_shifts.push(r.u32_vec()?);
        }
        let nlayers = r.len_prefix(8)?;
        let mut layers = Vec::with_capacity(nlayers);
        for _ in 0..nlayers {
            let lname = r.str()?.to_string();
            let m = r.usize()?;
            let (wk, wn, wbz, wbound) = (r.usize()?, r.usize()?, r.usize()?, r.usize()?);
            if wbz == 0 || wbz > 16 || wbound == 0 || wbound > wbz {
                bail!("invalid weight stats (bz={wbz}, bound={wbound}) for layer '{lname}'");
            }
            let weights = WeightStats::synthetic(wk, wn, wbz, wbound);
            let sample = match r.u8()? {
                0 => SampleShape::Conv(ConvShape {
                    h: r.usize()?,
                    w: r.usize()?,
                    c: r.usize()?,
                    kh: r.usize()?,
                    kw: r.usize()?,
                    oc: r.usize()?,
                    stride: r.usize()?,
                    pad: r.usize()?,
                }),
                1 => SampleShape::Fc { m: r.usize()?, k: r.usize()? },
                t => bail!("unknown sample-shape tag {t} for layer '{lname}'"),
            };
            if let SampleShape::Conv(s) = &sample {
                if s.stride == 0 || s.kh == 0 || s.kw == 0 || s.c == 0 {
                    bail!("degenerate conv sample for layer '{lname}'");
                }
            }
            let operand = match r.u8()? {
                0 => {
                    let (ok, on, obz, obound) =
                        (r.usize()?, r.usize()?, r.usize()?, r.usize()?);
                    let col_ptr = r.usize_vec()?;
                    let nent = r.len_prefix(8)?;
                    let mut entries = Vec::with_capacity(nent);
                    for _ in 0..nent {
                        let ki = r.u32()?;
                        entries.push((ki, r.u32()? as i32));
                    }
                    PackedOperand::Dbb(
                        DbbPacked::from_raw_parts(ok, on, obz, obound, col_ptr, entries)
                            .with_context(|| format!("packed operand of layer '{lname}'"))?,
                    )
                }
                1 => PackedOperand::Dense(read_tensor(&mut r)?),
                2 if v2 => {
                    let (ok, on) = (r.usize()?, r.usize()?);
                    let (bzr, bzc) = (r.usize()?, r.usize()?);
                    let row_ptr = r.usize_vec()?;
                    let col_idx = r.u32_vec()?;
                    let blocks = r.i8_vec()?;
                    PackedOperand::Bsr(
                        BsrPacked::from_raw_parts(ok, on, bzr, bzc, row_ptr, col_idx, blocks)
                            .with_context(|| format!("BSR operand of layer '{lname}'"))?,
                    )
                }
                t => bail!("unknown operand tag {t} for layer '{lname}'"),
            };
            let im2col_magnification = r.f64()?;
            let raw_act_bytes = r.u64()?;
            let out_elems = r.u64()?;
            let relu = r.u8()? != 0;
            layers.push(PreparedLayer {
                name: lname,
                m,
                weights,
                sample,
                operand,
                im2col_magnification,
                raw_act_bytes,
                out_elems,
                relu,
            });
        }
        if r.remaining() != 0 {
            bail!("{} trailing bytes after prepared-model stream", r.remaining());
        }
        if seed_input.is_empty() {
            bail!("prepared-model seed input is empty");
        }
        for (what, len) in [
            ("measured sparsities", measured_act.len()),
            ("calibrated shifts", shifts.len()),
            ("per-channel shifts", perch_shifts.len()),
        ] {
            if len != 0 && len != layers.len() {
                bail!("{what} count {len} does not match {} layers", layers.len());
            }
        }
        // resolve the name against the serving zoo so a round-tripped model
        // keeps the zoo's 'static name; unknown names (custom models) leak
        // one small allocation per distinct name per process — loads are
        // rare and registry-cached, so this is bounded in practice
        let name: &'static str = crate::models::zoo()
            .iter()
            .find(|m| m.name == name_s)
            .map(|m| m.name)
            .unwrap_or_else(|| Box::leak(name_s.into_boxed_str()));
        let max_k = layers
            .iter()
            .filter_map(|l| match l.sample {
                SampleShape::Conv(ss) => Some(ss.gemm_k()),
                SampleShape::Fc { .. } => None,
            })
            .max()
            .unwrap_or(0);
        Ok(PreparedModel {
            name,
            nnz,
            bz,
            seed,
            format,
            layers,
            seed_input,
            measured_act,
            act_policy,
            shifts,
            perch_shifts,
            fused_pool,
            fused_epilogue,
            per_channel_requant,
            scratch: Mutex::new(PatchScratch::preallocate(par.get(), max_k)),
        })
    }

    /// [`Self::to_bytes`] to a file.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        std::fs::write(path, self.to_bytes())
            .with_context(|| format!("writing prepared model to {}", path.display()))
    }

    /// [`Self::from_bytes`] from a file.
    pub fn load(path: impl AsRef<Path>, par: Parallelism) -> Result<PreparedModel> {
        let path = path.as_ref();
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading prepared model from {}", path.display()))?;
        Self::from_bytes(&bytes, par)
            .with_context(|| format!("loading prepared model from {}", path.display()))
    }
}

/// Magic + version prefix of the prepared-model flat-binary format. Bump
/// the trailing digit on any layout change — old streams then fail the
/// magic check instead of misparsing. v2 adds the weight-format and
/// per-channel-requant header bytes and the BSR operand tag;
/// [`PreparedModel::from_bytes`] still accepts [`PERSIST_MAGIC_V1`]
/// streams (all-DBB/dense payloads written before the BSR datapath).
pub const PERSIST_MAGIC: &[u8; 8] = b"SSTAPM2\0";

/// The v1 magic [`PreparedModel::from_bytes`] remains backward-compatible
/// with: same layout as v2 minus the two header bytes, DBB/dense operand
/// tags only.
pub const PERSIST_MAGIC_V1: &[u8; 8] = b"SSTAPM1\0";

fn act_policy_to_u8(p: ActPolicy) -> u8 {
    match p {
        ActPolicy::Off => 0,
        ActPolicy::Gate => 1,
        ActPolicy::Encode => 2,
        ActPolicy::Auto => 3,
    }
}

fn act_policy_from_u8(v: u8) -> Result<ActPolicy> {
    Ok(match v {
        0 => ActPolicy::Off,
        1 => ActPolicy::Gate,
        2 => ActPolicy::Encode,
        3 => ActPolicy::Auto,
        t => bail!("unknown activation-policy tag {t}"),
    })
}

fn write_tensor(w: &mut BinWriter, t: &TensorI8) {
    w.usize(t.shape().len());
    for &d in t.shape() {
        w.usize(d);
    }
    w.i8_slice(t.data());
}

fn read_tensor(r: &mut BinReader<'_>) -> Result<TensorI8> {
    let nd = r.len_prefix(8)?;
    let mut shape = Vec::with_capacity(nd);
    for _ in 0..nd {
        shape.push(r.usize()?);
    }
    let data = r.i8_vec()?;
    let want = shape
        .iter()
        .try_fold(1usize, |a, &d| a.checked_mul(d))
        .ok_or_else(|| crate::anyhow!("tensor shape {shape:?} overflows"))?;
    if want != data.len() {
        bail!("tensor shape {shape:?} wants {want} elements, stream has {}", data.len());
    }
    Ok(TensorI8::from_vec(&shape, data))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;

    #[test]
    fn prepare_lowers_every_layer_once() {
        let m = models::convnet5();
        let pm = PreparedModel::prepare(&m, 3, 8, 42, Parallelism::serial());
        assert_eq!(pm.layers().len(), m.layers.len());
        assert_eq!(pm.model_name(), m.name);
        assert_eq!(pm.encoding(), (3, 8, 42));
        // prunable layers carry a packed DBB stream, the rest dense
        for (pl, l) in pm.layers().iter().zip(&m.layers) {
            match (&pl.operand, l.prunable) {
                (PackedOperand::Dbb(p), true) => assert!(p.total_nnz() > 0),
                (PackedOperand::Dense(w), false) => assert!(!w.is_empty()),
                (op, prunable) => {
                    panic!("{}: operand {op:?} vs prunable={prunable}", pl.name)
                }
            }
        }
        assert!(pm.operand_bytes() > 0);
        assert!(pm.profiles().is_none(), "no functional profile ran yet");
    }

    #[test]
    fn repeated_execute_is_pure() {
        let m = models::lenet5();
        let pm = PreparedModel::prepare(&m, 2, 8, 9, Parallelism::threads(3));
        let a = pm.execute(pm.seed_input(), Parallelism::threads(3));
        let b = pm.execute(pm.seed_input(), Parallelism::threads(3));
        assert_eq!(a.output, b.output);
        assert_eq!(a.act_sparsity, b.act_sparsity);
    }

    #[test]
    fn fused_epilogue_chain_matches_staged_and_execute() {
        let m = models::convnet5();
        let mut pm = PreparedModel::prepare(&m, 3, 8, 42, Parallelism::threads(3));
        pm.profile(Parallelism::threads(3));
        assert!(pm.calibrated_shifts().is_none(), "no calibration ran yet");
        pm.calibrate(Parallelism::threads(3));
        assert_eq!(pm.calibrated_shifts().unwrap().len(), m.layers.len());
        let par = Parallelism::threads(3);
        let seed = pm.seed_input().clone();
        let plain = pm.execute(&seed, par);
        let staged = pm.execute_staged(&seed, par);
        let fused = pm.execute_fused(&seed, par);
        // on the seed input the frozen shifts ARE the dynamic shifts
        assert_eq!(staged.output, plain.output);
        assert_eq!(fused.output, staged.output, "fused epilogue must be bit-exact");
        assert_eq!(fused.act_policy, staged.act_policy);
        assert_eq!(fused.act_sparsity, staged.act_sparsity);
        // repeated fused executes reuse the ping-pong pool and stay pure
        let fused2 = pm.execute_fused(&seed, par);
        assert_eq!(fused.output, fused2.output);
        // pool folds uniformly across the staged and fused paths
        pm.set_fused_pool(true);
        let pstaged = pm.execute_staged(&seed, par);
        let pfused = pm.execute_fused(&seed, par);
        assert_eq!(pfused.output, pstaged.output, "pooled fused epilogue must be bit-exact");
    }

    #[test]
    fn profiles_carry_the_fused_epilogue_declaration() {
        let m = models::lenet5();
        let mut pm = PreparedModel::prepare(&m, 2, 8, 9, Parallelism::serial());
        pm.profile(Parallelism::serial());
        assert!(pm.profiles().unwrap().iter().all(|p| !p.fused_epilogue));
        pm.set_fused_epilogue(true);
        assert!(pm.fused_epilogue());
        assert!(pm.profiles().unwrap().iter().all(|p| p.fused_epilogue));
    }

    #[test]
    fn execute_accepts_non_spatial_input() {
        // the documented contract: any non-empty input is wrap-fitted,
        // including a 2-D matrix fed to a conv-first model
        let m = models::convnet5();
        let pm = PreparedModel::prepare(&m, 3, 8, 1, Parallelism::serial());
        let mut rng = Rng::new(2);
        let flat = TensorI8::rand(&[10, 27], &mut rng);
        let rec = pm.execute(&flat, Parallelism::serial());
        assert_eq!(rec.act_sparsity.len(), m.layers.len());
        assert!(!rec.output.is_empty());
    }

    #[test]
    fn gate_policies_share_one_output_and_report_decisions() {
        let m = models::lenet5();
        let pm = PreparedModel::prepare(&m, 2, 8, 9, Parallelism::serial());
        let par = Parallelism::serial();
        let off = pm.execute_gated(pm.seed_input(), par, ZeroGate::Off);
        let on = pm.execute_gated(pm.seed_input(), par, ZeroGate::On);
        let auto = pm.execute_gated(pm.seed_input(), par, ZeroGate::Auto);
        assert_eq!(off.output, on.output, "gating must be bit-exact");
        assert_eq!(off.output, auto.output);
        assert_eq!(off.act_sparsity, on.act_sparsity);
        assert!(off.gate_engaged.iter().all(|&g| !g));
        assert!(on.gate_engaged.iter().all(|&g| g));
        // Auto mirrors the per-layer threshold on the measured input
        // sparsities (unprofiled model → current-operand fallback)
        for (li, (&s, &g)) in auto.act_sparsity.iter().zip(&auto.gate_engaged).enumerate() {
            assert_eq!(g, ZeroGate::Auto.engaged(s), "layer {li}: s={s}");
        }
    }

    #[test]
    fn auto_consults_recorded_profile_after_profiling() {
        let m = models::convnet5();
        let mut pm = PreparedModel::prepare(&m, 3, 8, 42, Parallelism::serial());
        assert_eq!(pm.zero_gate(), ZeroGate::Auto, "default policy");
        assert!(pm.measured_act_sparsity().is_none());
        pm.profile(Parallelism::serial());
        let measured = pm.measured_act_sparsity().expect("profile ran").to_vec();
        // same sparsity source as the twin's priced profiles
        let profiles = pm.profiles().unwrap();
        for (p, &s) in profiles.iter().zip(&measured) {
            assert_eq!(p.act_sparsity.to_bits(), s.to_bits(), "{}", p.name);
        }
        // Auto decisions on the seed input now follow the recorded values
        let auto = pm.execute_gated(pm.seed_input(), Parallelism::serial(), ZeroGate::Auto);
        for (li, (&s, &g)) in measured.iter().zip(&auto.gate_engaged).enumerate() {
            assert_eq!(g, ZeroGate::Auto.engaged(s), "layer {li}: measured={s}");
        }
        // the seed input is near-dense (2% zeros): layer 0 must not gate
        assert!(!auto.gate_engaged[0], "near-dense first layer must not gate");
    }

    #[test]
    fn dense_fallback_operand_is_held_once() {
        // the operand footprint must be exactly the sum of the per-layer
        // packed streams and the *moved* dense draws — pass 2 holds no
        // second copy of any dense-fallback matrix, and the dense operand
        // is the drawn [k, min(n, SAMPLE_COLS)] matrix itself
        let m = models::convnet5();
        let pm = PreparedModel::prepare(&m, 3, 8, 42, Parallelism::threads(3));
        let mut want = 0usize;
        let mut dense_seen = 0usize;
        for (pl, l) in pm.layers().iter().zip(&m.layers) {
            let (_, k, n) = l.gemm_dims();
            match &pl.operand {
                PackedOperand::Dense(w) => {
                    assert_eq!(w.shape(), &[k, n.min(SAMPLE_COLS)], "{}", pl.name);
                    want += w.len();
                    dense_seen += 1;
                }
                PackedOperand::Dbb(p) => want += p.operand_bytes(),
            }
        }
        assert!(dense_seen > 0, "convnet5 must have a dense-fallback layer");
        assert_eq!(pm.operand_bytes(), want);
    }

    #[test]
    fn fit_fmap_fast_paths_match_naive_wrap() {
        // the copy_from_slice spans and the borrow fast path must reproduce
        // the historical per-element wrap exactly, for every alignment case
        let mut rng = Rng::new(17);
        let naive = |p: &TensorI8, h: usize, w: usize, c: usize| -> TensorI8 {
            if p.shape().len() != 3 {
                let pd = p.data();
                let data = (0..h * w * c).map(|i| pd[i % pd.len()]).collect();
                return TensorI8::from_vec(&[h, w, c], data);
            }
            let (ph, pw, pc) = (p.shape()[0], p.shape()[1], p.shape()[2]);
            let mut out = TensorI8::zeros(&[h, w, c]);
            for y in 0..h {
                for x in 0..w {
                    for ci in 0..c {
                        out.set(&[y, x, ci], p.at(&[y % ph, x % pw, ci % pc]));
                    }
                }
            }
            out
        };
        // exact match (borrow), row-aligned, channel-aligned, fully ragged,
        // and non-spatial inputs
        let cases: Vec<(Vec<usize>, (usize, usize, usize))> = vec![
            (vec![4, 5, 3], (4, 5, 3)),   // exact → borrow
            (vec![2, 5, 3], (4, 5, 3)),   // rows wrap, pw == w, pc == c
            (vec![3, 2, 3], (4, 5, 3)),   // cols wrap, pc == c
            (vec![3, 2, 2], (4, 5, 3)),   // channels wrap too
            (vec![1, 7, 5], (3, 4, 2)),   // everything ragged
            (vec![6, 11], (3, 4, 2)),     // non-spatial (matrix) input
        ];
        for (pshape, (h, w, c)) in cases {
            let p = TensorI8::rand_sparse(&pshape, 0.4, &mut rng);
            let got = fit_fmap_from(&p, h, w, c);
            let want = naive(&p, h, w, c);
            assert_eq!(got.data(), want.data(), "pshape={pshape:?} -> [{h},{w},{c}]");
            assert_eq!(got.shape(), want.shape());
        }
        // FC fit: exact borrow and wrap
        let p = TensorI8::rand(&[6, 9], &mut rng);
        assert_eq!(fit_matrix_from(&p, 6, 9).data(), p.data());
        let wrapped = fit_matrix_from(&p, 4, 30);
        for (i, &v) in wrapped.data().iter().enumerate() {
            assert_eq!(v, p.data()[i % p.len()], "i={i}");
        }
    }

    #[test]
    fn three_way_policy_bit_exact_and_reported() {
        let m = models::convnet5();
        let mut pm = PreparedModel::prepare(&m, 3, 8, 42, Parallelism::serial());
        assert_eq!(pm.act_policy(), ActPolicy::Auto, "default policy");
        pm.profile(Parallelism::serial());
        let par = Parallelism::serial();
        let off = pm.execute_policy(pm.seed_input(), par, ActPolicy::Off);
        let gate = pm.execute_policy(pm.seed_input(), par, ActPolicy::Gate);
        let enc = pm.execute_policy(pm.seed_input(), par, ActPolicy::Encode);
        let auto = pm.execute_policy(pm.seed_input(), par, ActPolicy::Auto);
        assert_eq!(off.output, gate.output, "gating must be bit-exact");
        assert_eq!(off.output, enc.output, "A-DBB encoding must be bit-exact");
        assert_eq!(off.output, auto.output);
        assert!(off.act_policy.iter().all(|&p| p == ActPolicy::Off));
        assert!(enc.act_policy.iter().all(|&p| p == ActPolicy::Encode));
        assert!(enc.gate_engaged.iter().all(|&g| g));
        // Auto mirrors the recorded profile through the documented tiers
        let measured = pm.measured_act_sparsity().expect("profile ran");
        for (li, (&s, &p)) in measured.iter().zip(&auto.act_policy).enumerate() {
            assert_eq!(p, ActPolicy::Auto.resolved(s), "layer {li}: s={s}");
        }
        // profiles carry the same encode decision the executor makes
        let profiles = pm.profiles().unwrap();
        for (p, &pol) in profiles.iter().zip(&auto.act_policy) {
            assert_eq!(p.act_encoded, pol == ActPolicy::Encode, "{}", p.name);
        }
    }

    #[test]
    fn legacy_zero_gate_surface_maps_onto_policy() {
        let m = models::lenet5();
        let mut pm = PreparedModel::prepare(&m, 2, 8, 9, Parallelism::serial());
        pm.set_zero_gate(ZeroGate::On);
        assert_eq!(pm.act_policy(), ActPolicy::Gate);
        assert_eq!(pm.zero_gate(), ZeroGate::On);
        pm.set_zero_gate(ZeroGate::Off);
        assert_eq!(pm.act_policy(), ActPolicy::Off);
        pm.set_zero_gate(ZeroGate::Auto);
        assert_eq!(pm.zero_gate(), ZeroGate::Auto);
        pm.set_act_policy(ActPolicy::Encode);
        assert_eq!(pm.zero_gate(), ZeroGate::On, "Encode engages the A path");
        // the two-way surface never encodes, even on an all-zero input
        let zero_in = TensorI8::zeros(&[28, 28, 1]);
        let run = pm.execute_gated(&zero_in, Parallelism::serial(), ZeroGate::Auto);
        assert!(run.act_policy.iter().all(|&p| p != ActPolicy::Encode));
        assert!(run.gate_engaged[0], "all-zero input must still gate");
    }

    #[test]
    fn calibrate_records_per_channel_shifts() {
        let m = models::convnet5();
        let mut pm = PreparedModel::prepare(&m, 3, 8, 42, Parallelism::serial());
        assert!(pm.calibrated_channel_shifts().is_none(), "no calibration ran yet");
        pm.calibrate(Parallelism::serial());
        let global = pm.calibrated_shifts().unwrap().to_vec();
        let perch = pm.calibrated_channel_shifts().unwrap();
        assert_eq!(perch.len(), global.len());
        for (li, (per, &g)) in perch.iter().zip(&global).enumerate() {
            assert!(!per.is_empty(), "layer {li}");
            // the global shift is exactly the per-channel maximum
            assert_eq!(per.iter().copied().max().unwrap(), g, "layer {li}");
        }
    }

    #[test]
    fn batched_fused_execute_matches_per_image() {
        let m = models::lenet5();
        let mut pm = PreparedModel::prepare(&m, 2, 8, 9, Parallelism::threads(3));
        pm.profile(Parallelism::threads(3));
        pm.calibrate(Parallelism::threads(3));
        let par = Parallelism::threads(3);
        let mut rng = Rng::new(33);
        // mixed batch: one exact-shape input (borrow fast path per image),
        // the rest wrap-fitted
        let mut inputs = vec![pm.seed_input().clone()];
        inputs.extend((0..3).map(|_| TensorI8::rand_sparse(&[28, 28, 1], 0.3, &mut rng)));
        let batched = pm.execute_fused_batch(&inputs, par);
        assert_eq!(batched.len(), inputs.len());
        for (i, x) in inputs.iter().enumerate() {
            let single = pm.execute_fused(x, par);
            assert_eq!(batched[i], single.output, "image {i}");
        }
        // pooled chain too (shapes shrink between layers)
        pm.set_fused_pool(true);
        let batched = pm.execute_fused_batch(&inputs, par);
        for (i, x) in inputs.iter().enumerate() {
            assert_eq!(batched[i], pm.execute_fused(x, par).output, "pooled image {i}");
        }
    }

    #[test]
    fn persisted_model_roundtrips_bit_exact() {
        let m = models::convnet5();
        let mut pm = PreparedModel::prepare(&m, 3, 8, 42, Parallelism::serial());
        pm.profile(Parallelism::serial());
        pm.calibrate(Parallelism::serial());
        pm.set_fused_epilogue(true);
        let bytes = pm.to_bytes();
        let back = PreparedModel::from_bytes(&bytes, Parallelism::serial()).unwrap();
        assert_eq!(back.model_name(), pm.model_name());
        assert_eq!(back.encoding(), pm.encoding());
        assert_eq!(back.operand_bytes(), pm.operand_bytes());
        assert_eq!(back.calibrated_shifts(), pm.calibrated_shifts());
        assert_eq!(back.calibrated_channel_shifts(), pm.calibrated_channel_shifts());
        assert!(back.fused_epilogue());
        let want = pm.measured_act_sparsity().unwrap();
        let got = back.measured_act_sparsity().unwrap();
        for (a, b) in want.iter().zip(got) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        let a = pm.execute_fused(pm.seed_input(), Parallelism::serial());
        let b = back.execute_fused(back.seed_input(), Parallelism::serial());
        assert_eq!(a.output, b.output, "loaded model must serve bit-exactly");
        // corruption and truncation fail cleanly
        assert!(PreparedModel::from_bytes(&bytes[..bytes.len() - 3], Parallelism::serial())
            .is_err());
        let mut bad = bytes.clone();
        bad[bytes.len() / 2] ^= 0x40;
        assert!(PreparedModel::from_bytes(&bad, Parallelism::serial()).is_err());
    }

    #[test]
    fn profile_records_measured_sparsity() {
        let m = models::convnet5();
        let mut pm = PreparedModel::prepare(&m, 3, 8, 42, Parallelism::serial());
        let profiles = pm.profile(Parallelism::serial());
        assert_eq!(profiles.len(), m.layers.len());
        assert!(pm.profiles().is_some());
        // first layer sees the near-dense seed input
        assert!(profiles[0].act_sparsity < 0.1, "{}", profiles[0].act_sparsity);
        // ReLU layers downstream are measurably sparse
        assert!(profiles.iter().skip(1).any(|p| p.act_sparsity > 0.2));
    }

    /// Swap every BSR operand for its decompressed dense matrix — the
    /// dense-kernel oracle of the same lowered model, sharing seed input,
    /// shifts, and measured sparsities.
    fn densify_bsr(pm: &PreparedModel, par: Parallelism) -> PreparedModel {
        let layers: Vec<PreparedLayer> = pm
            .layers
            .iter()
            .map(|l| {
                let mut l2 = l.clone();
                if let PackedOperand::Bsr(p) = &l.operand {
                    l2.operand = PackedOperand::Dense(p.decompress());
                }
                l2
            })
            .collect();
        PreparedModel {
            name: pm.name,
            nnz: pm.nnz,
            bz: pm.bz,
            seed: pm.seed,
            format: WeightFormat::Dense,
            layers,
            seed_input: pm.seed_input.clone(),
            measured_act: pm.measured_act.clone(),
            act_policy: pm.act_policy,
            shifts: pm.shifts.clone(),
            perch_shifts: pm.perch_shifts.clone(),
            fused_pool: pm.fused_pool,
            fused_epilogue: pm.fused_epilogue,
            per_channel_requant: pm.per_channel_requant,
            scratch: Mutex::new(PatchScratch::preallocate(par.get(), 0)),
        }
    }

    #[test]
    fn bsr_prepare_routes_prunable_layers_and_matches_dense_oracle() {
        let m = models::convnet5();
        let par = Parallelism::threads(3);
        let pm = PreparedModel::prepare_format(&m, 3, 8, 42, par, WeightFormat::Bsr);
        assert_eq!(pm.weight_format(), WeightFormat::Bsr);
        // prunable layers carry a BSR stream with dropped blocks, the rest
        // stay dense — and the coarse index is all the sparsity metadata
        let mut bsr_seen = 0;
        for (pl, l) in pm.layers().iter().zip(&m.layers) {
            match (&pl.operand, l.prunable) {
                (PackedOperand::Bsr(p), true) => {
                    assert!(p.stored_blocks() < p.block_rows() * p.block_cols(), "{}", pl.name);
                    assert!(p.index_bytes() > 0);
                    bsr_seen += 1;
                }
                (PackedOperand::Dense(w), false) => assert!(!w.is_empty()),
                (op, prunable) => {
                    panic!("{}: operand {op:?} vs prunable={prunable}", pl.name)
                }
            }
        }
        assert!(bsr_seen > 0, "convnet5 must have prunable layers");
        // pass 1 is format-invariant: same seed input as the DBB lowering
        let dbb = PreparedModel::prepare(&m, 3, 8, 42, par);
        assert_eq!(pm.seed_input().data(), dbb.seed_input().data());
        // bit-exact with the dense kernels on the decompressed weights,
        // under every activation policy (Encode degrades to Gate on BSR)
        let oracle = densify_bsr(&pm, par);
        let want = oracle.execute_policy(oracle.seed_input(), par, ActPolicy::Off);
        for pol in [ActPolicy::Off, ActPolicy::Gate, ActPolicy::Encode, ActPolicy::Auto] {
            let got = pm.execute_policy(pm.seed_input(), par, pol);
            assert_eq!(got.output, want.output, "policy {pol:?}");
            // no BSR layer ever reports (or runs) Encode
            for (pl, &p) in pm.layers().iter().zip(&got.act_policy) {
                if matches!(pl.operand, PackedOperand::Bsr(_)) {
                    assert_ne!(p, ActPolicy::Encode, "{}", pl.name);
                }
            }
        }
    }

    #[test]
    fn bsr_fused_serving_is_bit_exact_and_batches() {
        let m = models::lenet5();
        let par = Parallelism::threads(3);
        let mut pm = PreparedModel::prepare_format(&m, 2, 8, 9, par, WeightFormat::Bsr);
        pm.profile(par);
        pm.calibrate(par);
        // twin profiles carry the BSR format and never declare A-encode on
        // BSR layers
        for (p, l) in pm.profiles().unwrap().iter().zip(pm.layers()) {
            assert_eq!(p.format, l.operand.format(), "{}", p.name);
            if matches!(l.operand, PackedOperand::Bsr(_)) {
                assert!(!p.act_encoded, "{}", p.name);
            }
        }
        let seed = pm.seed_input().clone();
        let plain = pm.execute(&seed, par);
        let staged = pm.execute_staged(&seed, par);
        let fused = pm.execute_fused(&seed, par);
        assert_eq!(staged.output, plain.output);
        assert_eq!(fused.output, staged.output, "BSR fused epilogue must be bit-exact");
        // batch folds into M, bit-exact per image
        let mut rng = Rng::new(5);
        let mut inputs = vec![seed.clone()];
        inputs.extend((0..2).map(|_| TensorI8::rand_sparse(&[28, 28, 1], 0.3, &mut rng)));
        let batched = pm.execute_fused_batch(&inputs, par);
        for (i, x) in inputs.iter().enumerate() {
            assert_eq!(batched[i], pm.execute_fused(x, par).output, "image {i}");
        }
    }

    #[test]
    fn bsr_model_roundtrips_v2_flat_binary() {
        let m = models::convnet5();
        let mut pm = PreparedModel::prepare_format(&m, 3, 8, 42, Parallelism::serial(), WeightFormat::Bsr);
        pm.profile(Parallelism::serial());
        pm.calibrate(Parallelism::serial());
        pm.set_per_channel_requant(true);
        let bytes = pm.to_bytes();
        assert_eq!(&bytes[..8], PERSIST_MAGIC);
        let back = PreparedModel::from_bytes(&bytes, Parallelism::serial()).unwrap();
        assert_eq!(back.weight_format(), WeightFormat::Bsr);
        assert!(back.per_channel_requant());
        assert_eq!(back.operand_bytes(), pm.operand_bytes());
        let a = pm.execute_fused(pm.seed_input(), Parallelism::serial());
        let b = back.execute_fused(back.seed_input(), Parallelism::serial());
        assert_eq!(a.output, b.output, "loaded BSR model must serve bit-exactly");
        // corruption and truncation still fail cleanly
        assert!(PreparedModel::from_bytes(&bytes[..bytes.len() - 5], Parallelism::serial())
            .is_err());
        let mut bad = bytes.clone();
        bad[bytes.len() / 2] ^= 0x10;
        assert!(PreparedModel::from_bytes(&bad, Parallelism::serial()).is_err());
    }

    #[test]
    fn v1_streams_still_load_as_dbb_models() {
        // synthesize a v1 payload from a v2 one: the v1 layout is exactly
        // the v2 layout minus the two header bytes (format + per-channel
        // flag), under the old magic — see PERSIST_MAGIC_V1
        let m = models::lenet5();
        let mut pm = PreparedModel::prepare(&m, 2, 8, 9, Parallelism::serial());
        pm.profile(Parallelism::serial());
        pm.calibrate(Parallelism::serial());
        let v2 = pm.to_bytes();
        let hdr = 8 + (8 + pm.model_name().len()) + 8 + 8 + 8 + 3;
        assert_eq!(v2[hdr], WeightFormat::Dbb.tag());
        assert_eq!(v2[hdr + 1], 0, "per-channel flag off");
        let mut v1 = Vec::with_capacity(v2.len() - 2);
        v1.extend_from_slice(PERSIST_MAGIC_V1);
        v1.extend_from_slice(&v2[8..hdr]);
        v1.extend_from_slice(&v2[hdr + 2..v2.len() - 8]);
        let cs = fnv1a64(&v1);
        v1.extend_from_slice(&cs.to_le_bytes());
        let back = PreparedModel::from_bytes(&v1, Parallelism::serial()).unwrap();
        assert_eq!(back.weight_format(), WeightFormat::Dbb);
        assert!(!back.per_channel_requant());
        assert_eq!(back.operand_bytes(), pm.operand_bytes());
        let a = pm.execute(pm.seed_input(), Parallelism::serial());
        let b = back.execute(back.seed_input(), Parallelism::serial());
        assert_eq!(a.output, b.output, "v1 payload must serve bit-exactly");
        // a v1 stream claiming a BSR operand tag is rejected, not misparsed
        let mut bsr_pm =
            PreparedModel::prepare_format(&m, 2, 8, 9, Parallelism::serial(), WeightFormat::Bsr);
        bsr_pm.profile(Parallelism::serial());
        let bv2 = bsr_pm.to_bytes();
        let bhdr = 8 + (8 + bsr_pm.model_name().len()) + 8 + 8 + 8 + 3;
        let mut bv1 = Vec::with_capacity(bv2.len() - 2);
        bv1.extend_from_slice(PERSIST_MAGIC_V1);
        bv1.extend_from_slice(&bv2[8..bhdr]);
        bv1.extend_from_slice(&bv2[bhdr + 2..bv2.len() - 8]);
        let cs = fnv1a64(&bv1);
        bv1.extend_from_slice(&cs.to_le_bytes());
        assert!(PreparedModel::from_bytes(&bv1, Parallelism::serial()).is_err());
    }

    #[test]
    fn per_channel_requant_is_opt_in_and_uniform_shifts_match_global() {
        let m = models::convnet5();
        let par = Parallelism::threads(3);
        let mut pm = PreparedModel::prepare(&m, 3, 8, 42, par);
        pm.profile(par);
        pm.calibrate(par);
        assert!(!pm.per_channel_requant(), "global path is the default");
        let seed = pm.seed_input().clone();
        let global = pm.execute_fused(&seed, par);
        pm.set_per_channel_requant(true);
        let perch = pm.execute_fused(&seed, par);
        assert_eq!(perch.output.shape(), global.output.shape());
        // every per-channel shift is at most the layer maximum the global
        // path applies (finer, never coarser, quantization)
        for (per, &g) in pm.perch_shifts.iter().zip(&pm.shifts) {
            assert!(per.iter().all(|&s| s <= g));
        }
        // batched serving agrees with per-image serving under the flag
        let batched = pm.execute_fused_batch(std::slice::from_ref(&seed), par);
        assert_eq!(batched[0], perch.output);
        // uniform per-channel shifts (all pinned to the global maximum)
        // reproduce the global path bit for bit
        pm.perch_shifts = pm
            .shifts
            .iter()
            .zip(&pm.perch_shifts)
            .map(|(&g, per)| vec![g; per.len()])
            .collect();
        let uniform = pm.execute_fused(&seed, par);
        assert_eq!(uniform.output, global.output, "uniform per-channel == global");
    }
}
