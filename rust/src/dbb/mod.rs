//! Density-Bound Block (DBB) / Variable DBB weight-sparsity format — paper
//! §II and Fig. 2.
//!
//! A weight matrix `W[K×N]` (GEMM right operand; `K` is the depth/channel
//! dimension the paper blocks over) is partitioned per column into blocks of
//! `BZ` consecutive elements along `K`. A DBB constraint bounds each block to
//! at most `NNZ` non-zero values. The compressed form stores only the
//! non-zero values plus a `BZ`-bit positional bitmask `M` per block, for
//! `8·NNZ + BZ` bits per block (INT8 words) — paper §II-A.
//!
//! *Variable* DBB (VDBB, paper §III) is simply per-matrix (or per-layer)
//! freedom in `NNZ`: the hardware consumes one non-zero per cycle per block
//! (time unrolling), so any `NNZ ∈ 1..=BZ` runs at full utilization.

pub mod analyze;
pub mod prune;
pub mod variable;

use crate::tensor::TensorI8;
use std::fmt;

/// Errors raised by DBB encode/validate.
#[derive(Debug, PartialEq, Eq)]
pub enum DbbError {
    /// A block exceeded the requested density bound.
    BoundExceeded {
        /// Column of the offending block.
        col: usize,
        /// K-block index of the offending block.
        kblk: usize,
        /// Non-zeros found.
        found: usize,
        /// Requested bound.
        bound: usize,
    },
    /// Unsupported block size.
    BadBlockSize(usize),
}

impl fmt::Display for DbbError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DbbError::BoundExceeded { col, kblk, found, bound } => write!(
                f,
                "block (col {col}, kblk {kblk}) has {found} non-zeros > bound {bound}"
            ),
            DbbError::BadBlockSize(bz) => {
                write!(f, "block size {bz} not supported (must be 1..=16)")
            }
        }
    }
}

impl std::error::Error for DbbError {}

/// One compressed block: the non-zero values (in ascending position order)
/// and the positional bitmask (bit `i` set ⇔ expanded element `i` non-zero).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DbbBlock {
    /// Non-zero values, position-ordered. `vals.len() == mask.count_ones()`.
    pub vals: Vec<i8>,
    /// Positional bitmask (LSB = first element of the block).
    pub mask: u16,
}

impl DbbBlock {
    /// Number of stored non-zeros.
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Expanded positions of the non-zeros (ascending).
    pub fn positions(&self) -> impl Iterator<Item = usize> + '_ {
        let mask = self.mask;
        (0..16usize).filter(move |i| mask & (1 << i) != 0)
    }

    /// Expand back to a dense `bz`-length block.
    pub fn expand(&self, bz: usize) -> Vec<i8> {
        let mut out = vec![0i8; bz];
        for (v, p) in self.vals.iter().zip(self.positions()) {
            out[p] = *v;
        }
        out
    }
}

/// A DBB-compressed `K×N` INT8 weight matrix.
///
/// Blocks are stored column-major by (column, k-block), matching how the
/// STA streams them: each array column consumes the blocks of one output
/// channel in k order.
#[derive(Debug, Clone)]
pub struct DbbMatrix {
    /// Logical rows (depth / reduction dim) of the dense matrix.
    pub k: usize,
    /// Logical columns (output channels).
    pub n: usize,
    /// Block size along `k`.
    pub bz: usize,
    /// Density bound: max non-zeros per block this matrix was encoded with.
    pub bound: usize,
    blocks: Vec<DbbBlock>,
}

impl DbbMatrix {
    /// Number of k-blocks per column (ceil(K/BZ); last block zero-padded).
    pub fn kblocks(&self) -> usize {
        self.k.div_ceil(self.bz)
    }

    /// Block at (column, k-block index).
    pub fn block(&self, col: usize, kblk: usize) -> &DbbBlock {
        &self.blocks[col * self.kblocks() + kblk]
    }

    /// All blocks, column-major.
    pub fn blocks(&self) -> &[DbbBlock] {
        &self.blocks
    }

    /// Total stored non-zero values.
    pub fn total_nnz(&self) -> usize {
        self.blocks.iter().map(|b| b.nnz()).sum()
    }

    /// Maximum non-zeros observed in any block (the *effective* bound).
    pub fn max_block_nnz(&self) -> usize {
        self.blocks.iter().map(|b| b.nnz()).max().unwrap_or(0)
    }

    /// Compressed storage in bits: per block `8·bound + bz` (INT8 values are
    /// padded out to the bound so the stream stays fixed-rate, paper §II-A),
    /// counting every block of the matrix.
    pub fn storage_bits(&self) -> usize {
        self.blocks.len() * (8 * self.bound + self.bz)
    }

    /// Dense storage in bits (8 bits/elem over the padded K).
    pub fn dense_bits(&self) -> usize {
        self.kblocks() * self.bz * self.n * 8
    }

    /// Compression ratio `8·BZ / (8·NNZ + BZ)` — paper §II-A.
    pub fn compression_ratio(&self) -> f64 {
        self.dense_bits() as f64 / self.storage_bits() as f64
    }

    /// Weight density `bound / bz` (paper's NNZ/BZ). Sparsity = 1 − density.
    pub fn density(&self) -> f64 {
        self.bound as f64 / self.bz as f64
    }

    /// Encode a dense matrix, *measuring* the density bound (max block NNZ).
    /// Never fails for valid `bz`; a fully dense matrix gets `bound == bz`.
    pub fn compress(w: &TensorI8, bz: usize) -> Result<Self, DbbError> {
        Self::compress_impl(w, bz, None)
    }

    /// Encode with an explicit bound; returns [`DbbError::BoundExceeded`] if
    /// any block violates it (i.e. the model was not DBB-pruned for this
    /// bound — the hardware would have to fall back to dense).
    pub fn compress_with_bound(w: &TensorI8, bz: usize, bound: usize) -> Result<Self, DbbError> {
        Self::compress_impl(w, bz, Some(bound))
    }

    fn compress_impl(w: &TensorI8, bz: usize, bound: Option<usize>) -> Result<Self, DbbError> {
        if bz == 0 || bz > 16 {
            return Err(DbbError::BadBlockSize(bz));
        }
        let (k, n) = (w.shape()[0], w.shape()[1]);
        let kblocks = k.div_ceil(bz);
        let mut blocks = Vec::with_capacity(n * kblocks);
        let mut max_nnz = 0usize;
        for col in 0..n {
            for kb in 0..kblocks {
                let mut vals = Vec::new();
                let mut mask = 0u16;
                for i in 0..bz {
                    let kk = kb * bz + i;
                    if kk >= k {
                        break; // zero padding of the ragged last block
                    }
                    let v = w.at(&[kk, col]);
                    if v != 0 {
                        vals.push(v);
                        mask |= 1 << i;
                    }
                }
                if let Some(b) = bound {
                    if vals.len() > b {
                        return Err(DbbError::BoundExceeded {
                            col,
                            kblk: kb,
                            found: vals.len(),
                            bound: b,
                        });
                    }
                }
                max_nnz = max_nnz.max(vals.len());
                blocks.push(DbbBlock { vals, mask });
            }
        }
        // A bound of 0 (all-zero matrix) still occupies 1 slot in hardware.
        let eff_bound = bound.unwrap_or(max_nnz).max(1);
        Ok(DbbMatrix {
            k,
            n,
            bz,
            bound: eff_bound,
            blocks,
        })
    }

    /// Fused magnitude-prune + encode: keep the `bound` largest-magnitude
    /// values of every block directly during compression (equivalent to
    /// `prune_i8` followed by `compress_with_bound`, in one pass — the
    /// profiling hot path, §Perf).
    pub fn compress_topk(w: &TensorI8, bz: usize, bound: usize) -> Result<Self, DbbError> {
        if bz == 0 || bz > 16 {
            return Err(DbbError::BadBlockSize(bz));
        }
        let (k, n) = (w.shape()[0], w.shape()[1]);
        let kblocks = k.div_ceil(bz);
        let wd = w.data();
        let mut blocks = Vec::with_capacity(n * kblocks);
        let mut buf: Vec<(i16, usize, i8)> = Vec::with_capacity(bz);
        for col in 0..n {
            for kb in 0..kblocks {
                buf.clear();
                let hi = ((kb + 1) * bz).min(k);
                for kk in kb * bz..hi {
                    let v = wd[kk * n + col];
                    if v != 0 {
                        buf.push((-(v as i16).abs(), kk - kb * bz, v));
                    }
                }
                if buf.len() > bound {
                    buf.select_nth_unstable(bound - 1);
                    buf.truncate(bound);
                }
                buf.sort_unstable_by_key(|&(_, pos, _)| pos);
                let mut vals = Vec::with_capacity(buf.len());
                let mut mask = 0u16;
                for &(_, pos, v) in &buf {
                    vals.push(v);
                    mask |= 1 << pos;
                }
                blocks.push(DbbBlock { vals, mask });
            }
        }
        Ok(DbbMatrix {
            k,
            n,
            bz,
            bound: bound.max(1),
            blocks,
        })
    }

    /// Decode into the flattened per-column CSC stream the GEMM row kernels
    /// consume ([`crate::gemm::DbbPacked`]) — the one-time "compile" step of
    /// the prepare-once/execute-many split: pack here, then every
    /// `*_packed` GEMM/conv reuses the stream with zero decode work.
    pub fn pack(&self) -> crate::gemm::DbbPacked {
        crate::gemm::DbbPacked::pack(self)
    }

    /// Decode back to the dense `K×N` matrix.
    pub fn decompress(&self) -> TensorI8 {
        let mut w = TensorI8::zeros(&[self.k, self.n]);
        for col in 0..self.n {
            for kb in 0..self.kblocks() {
                let blk = self.block(col, kb);
                for (v, p) in blk.vals.iter().zip(blk.positions()) {
                    let kk = kb * self.bz + p;
                    if kk < self.k {
                        w.set(&[kk, col], *v);
                    }
                }
            }
        }
        w
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Config};
    use crate::util::Rng;

    fn random_dbb_dense(
        k: usize,
        n: usize,
        bz: usize,
        nnz: usize,
        rng: &mut Rng,
    ) -> TensorI8 {
        // Build a dense matrix that satisfies an (nnz, bz) DBB constraint.
        let mut w = TensorI8::zeros(&[k, n]);
        for col in 0..n {
            for kb in 0..k.div_ceil(bz) {
                let bz_here = bz.min(k - kb * bz);
                let take = nnz.min(bz_here);
                for p in rng.choose_indices(bz_here, take) {
                    // force non-zero values
                    let mut v = rng.i8_sym();
                    if v == 0 {
                        v = 1;
                    }
                    w.set(&[kb * bz + p, col], v);
                }
            }
        }
        w
    }

    #[test]
    fn roundtrip_exact() {
        let mut rng = Rng::new(1);
        let w = random_dbb_dense(16, 8, 8, 3, &mut rng);
        let c = DbbMatrix::compress(&w, 8).unwrap();
        assert_eq!(c.decompress(), w);
        assert!(c.max_block_nnz() <= 3);
    }

    #[test]
    fn prop_roundtrip_any_shape() {
        check(Config::default().cases(128), |rng| {
            let bz = [2, 4, 8, 16][rng.below(4)];
            let k = rng.below(40) + 1;
            let n = rng.below(12) + 1;
            let nnz = rng.below(bz) + 1;
            let w = random_dbb_dense(k, n, bz, nnz, rng);
            let c = DbbMatrix::compress(&w, bz).unwrap();
            assert_eq!(c.decompress(), w, "k={k} n={n} bz={bz} nnz={nnz}");
        });
    }

    #[test]
    fn bound_enforced() {
        let mut rng = Rng::new(2);
        let w = TensorI8::rand(&[8, 4], &mut rng); // dense: every block 8/8 almost surely
        let err = DbbMatrix::compress_with_bound(&w, 8, 2).unwrap_err();
        assert!(matches!(err, DbbError::BoundExceeded { .. }));
    }

    #[test]
    fn compression_ratio_matches_formula() {
        // 2/8 block: ratio = 8*8 / (8*2 + 8) = 64/24 ≈ 2.67 (paper §II-A)
        let mut rng = Rng::new(3);
        let w = random_dbb_dense(64, 16, 8, 2, &mut rng);
        let c = DbbMatrix::compress_with_bound(&w, 8, 2).unwrap();
        let expect = (8.0 * 8.0) / (8.0 * 2.0 + 8.0);
        assert!((c.compression_ratio() - expect).abs() < 1e-12);
    }

    #[test]
    fn ragged_k_padding() {
        // K=10, BZ=8 -> 2 k-blocks, second covers only 2 rows.
        let mut w = TensorI8::zeros(&[10, 1]);
        w.set(&[9, 0], 5);
        let c = DbbMatrix::compress(&w, 8).unwrap();
        assert_eq!(c.kblocks(), 2);
        assert_eq!(c.block(0, 1).nnz(), 1);
        assert_eq!(c.decompress(), w);
    }

    #[test]
    fn bad_block_size_rejected() {
        let w = TensorI8::zeros(&[8, 1]);
        assert_eq!(
            DbbMatrix::compress(&w, 0).unwrap_err(),
            DbbError::BadBlockSize(0)
        );
        assert_eq!(
            DbbMatrix::compress(&w, 17).unwrap_err(),
            DbbError::BadBlockSize(17)
        );
    }

    #[test]
    fn mask_popcount_invariant() {
        check(Config::default().cases(64), |rng| {
            let w = TensorI8::rand_sparse(&[24, 6], 0.6, rng);
            let c = DbbMatrix::compress(&w, 8).unwrap();
            for b in c.blocks() {
                assert_eq!(b.vals.len(), b.mask.count_ones() as usize);
            }
        });
    }

    #[test]
    fn compress_topk_equals_prune_then_compress() {
        check(Config::default().cases(64), |rng| {
            let k = rng.below(48) + 1;
            let n = rng.below(12) + 1;
            let bz = [4usize, 8, 16][rng.below(3)];
            let nnz = rng.below(bz) + 1;
            let w = TensorI8::rand(&[k, n], rng);
            let fused = DbbMatrix::compress_topk(&w, bz, nnz).unwrap();
            let two_step = DbbMatrix::compress_with_bound(
                &crate::dbb::prune::prune_i8(&w, bz, nnz),
                bz,
                nnz,
            )
            .unwrap();
            // same sparsity structure up to magnitude ties (both keep some
            // top-nnz set); the decompressed matrices must agree wherever
            // magnitudes are untied — compare total nnz and per-block count
            assert_eq!(fused.total_nnz(), two_step.total_nnz(), "k={k} n={n} bz={bz} nnz={nnz}");
            assert!(fused.max_block_nnz() <= nnz);
            // and exact magnitude multiset per block
            for (bf, bt) in fused.blocks().iter().zip(two_step.blocks()) {
                let mut mf: Vec<i32> = bf.vals.iter().map(|v| (*v as i32).abs()).collect();
                let mut mt: Vec<i32> = bt.vals.iter().map(|v| (*v as i32).abs()).collect();
                mf.sort_unstable();
                mt.sort_unstable();
                assert_eq!(mf, mt);
            }
        });
    }

    #[test]
    fn pack_stream_covers_every_nonzero() {
        check(Config::default().cases(32), |rng| {
            let w = TensorI8::rand_sparse(&[24, 6], 0.6, rng);
            let c = DbbMatrix::compress(&w, 8).unwrap();
            let p = c.pack();
            assert_eq!((p.k, p.n, p.bz, p.bound), (c.k, c.n, c.bz, c.bound));
            assert_eq!(p.total_nnz(), c.total_nnz());
            assert_eq!(p.col_ptr().len(), c.n + 1);
            assert_eq!(*p.col_ptr().last().unwrap(), p.entries().len());
            // the stream decodes back to the dense matrix
            let mut dense = TensorI8::zeros(&[c.k, c.n]);
            for col in 0..c.n {
                for &(kk, v) in &p.entries()[p.col_ptr()[col]..p.col_ptr()[col + 1]] {
                    dense.set(&[kk as usize, col], v as i8);
                }
            }
            assert_eq!(dense, c.decompress());
        });
    }

    #[test]
    fn all_zero_matrix() {
        let w = TensorI8::zeros(&[16, 4]);
        let c = DbbMatrix::compress(&w, 8).unwrap();
        assert_eq!(c.total_nnz(), 0);
        assert_eq!(c.bound, 1); // hardware minimum occupancy
        assert_eq!(c.decompress(), w);
    }

    #[test]
    fn dense_matrix_bound_is_bz() {
        let w = TensorI8::from_vec(&[8, 1], vec![1, 2, 3, 4, 5, 6, 7, 8]);
        let c = DbbMatrix::compress(&w, 8).unwrap();
        assert_eq!(c.bound, 8);
        assert_eq!(c.density(), 1.0);
    }
}
