//! Magnitude-based DBB pruning (paper §II-B / §V-A).
//!
//! Given a dense weight tensor and a target `(NNZ, BZ)`, keep the `NNZ`
//! largest-magnitude elements of every block and zero the rest. The training
//! substrate (`crate::train`) applies this progressively over epochs; the
//! one-shot form here is also used to synthesize DBB-conformant weights for
//! the architecture experiments.

use crate::tensor::{TensorF32, TensorI8};

/// One-shot magnitude prune of an f32 `K×N` matrix to a `(nnz, bz)` DBB
/// constraint (blocks run down the K dimension, per column).
pub fn prune_f32(w: &TensorF32, bz: usize, nnz: usize) -> TensorF32 {
    let (k, n) = (w.shape()[0], w.shape()[1]);
    let mut out = w.clone();
    for col in 0..n {
        for kb in 0..k.div_ceil(bz) {
            let lo = kb * bz;
            let hi = (lo + bz).min(k);
            prune_block_f32(&mut out, col, lo, hi, nnz);
        }
    }
    out
}

fn prune_block_f32(w: &mut TensorF32, col: usize, lo: usize, hi: usize, nnz: usize) {
    let len = hi - lo;
    if len <= nnz {
        return;
    }
    // rank positions by |w|, keep top-nnz
    let mut idx: Vec<usize> = (lo..hi).collect();
    idx.sort_by(|&a, &b| {
        w.at(&[b, col])
            .abs()
            .partial_cmp(&w.at(&[a, col]).abs())
            .unwrap()
    });
    for &kk in &idx[nnz..] {
        w.set(&[kk, col], 0.0);
    }
}

/// One-shot magnitude prune of an INT8 `K×N` matrix.
pub fn prune_i8(w: &TensorI8, bz: usize, nnz: usize) -> TensorI8 {
    let (k, n) = (w.shape()[0], w.shape()[1]);
    let mut out = w.clone();
    for col in 0..n {
        for kb in 0..k.div_ceil(bz) {
            let lo = kb * bz;
            let hi = (lo + bz).min(k);
            let len = hi - lo;
            if len <= nnz {
                continue;
            }
            let mut idx: Vec<usize> = (lo..hi).collect();
            idx.sort_by_key(|&a| std::cmp::Reverse((out.at(&[a, col]) as i32).abs()));
            for &kk in &idx[nnz..] {
                out.set(&[kk, col], 0);
            }
        }
    }
    out
}

/// A pruning *mask* (true = keep) for progressive training-time pruning:
/// the mask is recomputed per pruning step and applied after every weight
/// update, mimicking the paper's "progressively prunes small-magnitude
/// weights within each DBB block" over ~20 epochs.
pub fn dbb_mask_f32(w: &TensorF32, bz: usize, nnz: usize) -> Vec<bool> {
    // keep exactly the surviving positions; in particular, positions that
    // are currently zero are *not* kept — otherwise gradient updates would
    // regrow them past the block bound between mask refreshes
    let pruned = prune_f32(w, bz, nnz);
    pruned.data().iter().map(|&p| p != 0.0).collect()
}

/// Apply a keep-mask in place.
pub fn apply_mask_f32(w: &mut TensorF32, mask: &[bool]) {
    for (v, &keep) in w.data_mut().iter_mut().zip(mask) {
        if !keep {
            *v = 0.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dbb::DbbMatrix;
    use crate::util::prop::{check, Config};
    use crate::util::Rng;

    #[test]
    fn pruned_i8_satisfies_bound() {
        check(Config::default().cases(64), |rng| {
            let k = rng.below(64) + 1;
            let n = rng.below(16) + 1;
            let bz = [4usize, 8, 16][rng.below(3)];
            let nnz = rng.below(bz) + 1;
            let w = TensorI8::rand(&[k, n], rng);
            let p = prune_i8(&w, bz, nnz);
            // must now encode under the bound
            let c = DbbMatrix::compress_with_bound(&p, bz, nnz).unwrap();
            assert!(c.max_block_nnz() <= nnz);
        });
    }

    #[test]
    fn prune_keeps_largest_magnitudes() {
        let w = TensorF32::from_vec(&[8, 1], vec![0.1, -0.9, 0.2, 0.8, -0.05, 0.3, 0.0, -0.4]);
        let p = prune_f32(&w, 8, 2);
        // top-2 by |.| are -0.9 and 0.8
        assert_eq!(p.at(&[1, 0]), -0.9);
        assert_eq!(p.at(&[3, 0]), 0.8);
        let kept: usize = p.data().iter().filter(|&&x| x != 0.0).count();
        assert_eq!(kept, 2);
    }

    #[test]
    fn prune_noop_when_block_already_sparse() {
        let w = TensorF32::from_vec(&[4, 1], vec![0.0, 0.5, 0.0, 0.0]);
        let p = prune_f32(&w, 4, 2);
        assert_eq!(p.data(), w.data());
    }

    #[test]
    fn mask_roundtrip() {
        let mut rng = Rng::new(4);
        let w = TensorF32::randn(&[32, 8], 1.0, &mut rng);
        let mask = dbb_mask_f32(&w, 8, 3);
        let mut w2 = w.clone();
        apply_mask_f32(&mut w2, &mask);
        assert_eq!(w2.data(), prune_f32(&w, 8, 3).data());
    }

    #[test]
    fn prune_f32_sparsity_level() {
        let mut rng = Rng::new(5);
        let w = TensorF32::randn(&[64, 64], 1.0, &mut rng);
        let p = prune_f32(&w, 8, 2); // 75% sparsity
        assert!((p.sparsity() - 0.75).abs() < 1e-9);
    }
}
