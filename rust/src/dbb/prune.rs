//! Magnitude-based DBB pruning (paper §II-B / §V-A).
//!
//! Given a dense weight tensor and a target `(NNZ, BZ)`, keep the `NNZ`
//! largest-magnitude elements of every block and zero the rest. The training
//! substrate (`crate::train`) applies this progressively over epochs; the
//! one-shot form here is also used to synthesize DBB-conformant weights for
//! the architecture experiments.

use crate::tensor::{TensorF32, TensorI8};

/// One-shot magnitude prune of an f32 `K×N` matrix to a `(nnz, bz)` DBB
/// constraint (blocks run down the K dimension, per column).
pub fn prune_f32(w: &TensorF32, bz: usize, nnz: usize) -> TensorF32 {
    let (k, n) = (w.shape()[0], w.shape()[1]);
    let mut out = w.clone();
    for col in 0..n {
        for kb in 0..k.div_ceil(bz) {
            let lo = kb * bz;
            let hi = (lo + bz).min(k);
            prune_block_f32(&mut out, col, lo, hi, nnz);
        }
    }
    out
}

fn prune_block_f32(w: &mut TensorF32, col: usize, lo: usize, hi: usize, nnz: usize) {
    let len = hi - lo;
    if len <= nnz {
        return;
    }
    // rank positions by |w|, keep top-nnz
    let mut idx: Vec<usize> = (lo..hi).collect();
    idx.sort_by(|&a, &b| {
        w.at(&[b, col])
            .abs()
            .partial_cmp(&w.at(&[a, col]).abs())
            .unwrap()
    });
    for &kk in &idx[nnz..] {
        w.set(&[kk, col], 0.0);
    }
}

/// One-shot magnitude prune of an INT8 `K×N` matrix.
pub fn prune_i8(w: &TensorI8, bz: usize, nnz: usize) -> TensorI8 {
    let (k, n) = (w.shape()[0], w.shape()[1]);
    let mut out = w.clone();
    for col in 0..n {
        for kb in 0..k.div_ceil(bz) {
            let lo = kb * bz;
            let hi = (lo + bz).min(k);
            let len = hi - lo;
            if len <= nnz {
                continue;
            }
            let mut idx: Vec<usize> = (lo..hi).collect();
            idx.sort_by_key(|&a| std::cmp::Reverse((out.at(&[a, col]) as i32).abs()));
            for &kk in &idx[nnz..] {
                out.set(&[kk, col], 0);
            }
        }
    }
    out
}

/// A pruning *mask* (true = keep) for progressive training-time pruning:
/// the mask is recomputed per pruning step and applied after every weight
/// update, mimicking the paper's "progressively prunes small-magnitude
/// weights within each DBB block" over ~20 epochs.
pub fn dbb_mask_f32(w: &TensorF32, bz: usize, nnz: usize) -> Vec<bool> {
    // keep exactly the surviving positions; in particular, positions that
    // are currently zero are *not* kept — otherwise gradient updates would
    // regrow them past the block bound between mask refreshes
    let pruned = prune_f32(w, bz, nnz);
    pruned.data().iter().map(|&p| p != 0.0).collect()
}

/// Apply a keep-mask in place.
pub fn apply_mask_f32(w: &mut TensorF32, mask: &[bool]) {
    for (v, &keep) in w.data_mut().iter_mut().zip(mask) {
        if !keep {
            *v = 0.0;
        }
    }
}

/// Rank every `bz_r × bz_c` block of a `K×N` matrix by L1 magnitude and
/// return, per block row, the block-column indices of the `keep` largest
/// (SPOTS-style block-structured pruning — the BSR analogue of the
/// per-block top-`nnz` selection above, one granularity coarser). Shared
/// by the f32/i8 pruners and the training-time mask so all three agree
/// on which blocks survive.
fn bsr_survivors<T: Copy, F: Fn(T) -> f64>(
    data: &[T],
    k: usize,
    n: usize,
    bz_r: usize,
    bz_c: usize,
    keep: usize,
    mag: F,
) -> Vec<Vec<usize>> {
    let (nbr, nbc) = (k.div_ceil(bz_r), n.div_ceil(bz_c));
    let mut out = Vec::with_capacity(nbr);
    for br in 0..nbr {
        let r0 = br * bz_r;
        let r1 = (r0 + bz_r).min(k);
        let mut l1: Vec<(f64, usize)> = (0..nbc)
            .map(|bc| {
                let c0 = bc * bz_c;
                let c1 = (c0 + bz_c).min(n);
                let s: f64 = (r0..r1)
                    .flat_map(|r| data[r * n + c0..r * n + c1].iter())
                    .map(|&v| mag(v))
                    .sum();
                (s, bc)
            })
            .collect();
        // stable preference for the leftmost block on ties → deterministic
        l1.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap().then(a.1.cmp(&b.1)));
        let mut kept: Vec<usize> = l1.iter().take(keep.min(nbc)).map(|&(_, bc)| bc).collect();
        kept.sort_unstable();
        out.push(kept);
    }
    out
}

fn zero_non_survivors<T: Copy + Default>(
    data: &mut [T],
    k: usize,
    n: usize,
    bz_r: usize,
    bz_c: usize,
    survivors: &[Vec<usize>],
) {
    let nbc = n.div_ceil(bz_c);
    for (br, kept) in survivors.iter().enumerate() {
        let r0 = br * bz_r;
        let r1 = (r0 + bz_r).min(k);
        for bc in 0..nbc {
            if kept.binary_search(&bc).is_ok() {
                continue;
            }
            let c0 = bc * bz_c;
            let c1 = (c0 + bz_c).min(n);
            for r in r0..r1 {
                for v in &mut data[r * n + c0..r * n + c1] {
                    *v = T::default();
                }
            }
        }
    }
}

/// One-shot block-structured prune of an f32 `K×N` matrix: keep the
/// `keep` largest-L1 `bz_r × bz_c` blocks of every block row, zero whole
/// blocks otherwise. `keep = 0` zeroes the matrix; `keep ≥ block_cols`
/// is a no-op. The result packs losslessly into
/// [`crate::gemm::BsrPacked`] with at most `keep` blocks per block row.
pub fn prune_bsr_f32(w: &TensorF32, bz_r: usize, bz_c: usize, keep: usize) -> TensorF32 {
    let (k, n) = (w.shape()[0], w.shape()[1]);
    let surv = bsr_survivors(w.data(), k, n, bz_r, bz_c, keep, |v: f32| v.abs() as f64);
    let mut out = w.clone();
    zero_non_survivors(out.data_mut(), k, n, bz_r, bz_c, &surv);
    out
}

/// One-shot block-structured prune of an INT8 `K×N` matrix (see
/// [`prune_bsr_f32`]).
pub fn prune_bsr_i8(w: &TensorI8, bz_r: usize, bz_c: usize, keep: usize) -> TensorI8 {
    let (k, n) = (w.shape()[0], w.shape()[1]);
    let surv = bsr_survivors(w.data(), k, n, bz_r, bz_c, keep, |v: i8| (v as i32).abs() as f64);
    let mut out = w.clone();
    zero_non_survivors(out.data_mut(), k, n, bz_r, bz_c, &surv);
    out
}

/// Training-time block keep-mask (true = keep): every position inside a
/// surviving block is kept — including currently-zero positions, because
/// a BSR block is *dense* in the stream, so gradient regrowth inside a
/// surviving block costs the hardware nothing (unlike [`dbb_mask_f32`],
/// which must pin zeros to hold the per-block NNZ bound). Whole
/// non-surviving blocks are masked to zero.
pub fn bsr_mask_f32(w: &TensorF32, bz_r: usize, bz_c: usize, keep: usize) -> Vec<bool> {
    let (k, n) = (w.shape()[0], w.shape()[1]);
    let surv = bsr_survivors(w.data(), k, n, bz_r, bz_c, keep, |v: f32| v.abs() as f64);
    let mut mask = vec![false; k * n];
    for (br, kept) in surv.iter().enumerate() {
        let r0 = br * bz_r;
        let r1 = (r0 + bz_r).min(k);
        for &bc in kept {
            let c0 = bc * bz_c;
            let c1 = (c0 + bz_c).min(n);
            for r in r0..r1 {
                for m in &mut mask[r * n + c0..r * n + c1] {
                    *m = true;
                }
            }
        }
    }
    mask
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dbb::DbbMatrix;
    use crate::util::prop::{check, Config};
    use crate::util::Rng;

    #[test]
    fn pruned_i8_satisfies_bound() {
        check(Config::default().cases(64), |rng| {
            let k = rng.below(64) + 1;
            let n = rng.below(16) + 1;
            let bz = [4usize, 8, 16][rng.below(3)];
            let nnz = rng.below(bz) + 1;
            let w = TensorI8::rand(&[k, n], rng);
            let p = prune_i8(&w, bz, nnz);
            // must now encode under the bound
            let c = DbbMatrix::compress_with_bound(&p, bz, nnz).unwrap();
            assert!(c.max_block_nnz() <= nnz);
        });
    }

    #[test]
    fn prune_keeps_largest_magnitudes() {
        let w = TensorF32::from_vec(&[8, 1], vec![0.1, -0.9, 0.2, 0.8, -0.05, 0.3, 0.0, -0.4]);
        let p = prune_f32(&w, 8, 2);
        // top-2 by |.| are -0.9 and 0.8
        assert_eq!(p.at(&[1, 0]), -0.9);
        assert_eq!(p.at(&[3, 0]), 0.8);
        let kept: usize = p.data().iter().filter(|&&x| x != 0.0).count();
        assert_eq!(kept, 2);
    }

    #[test]
    fn prune_noop_when_block_already_sparse() {
        let w = TensorF32::from_vec(&[4, 1], vec![0.0, 0.5, 0.0, 0.0]);
        let p = prune_f32(&w, 4, 2);
        assert_eq!(p.data(), w.data());
    }

    #[test]
    fn mask_roundtrip() {
        let mut rng = Rng::new(4);
        let w = TensorF32::randn(&[32, 8], 1.0, &mut rng);
        let mask = dbb_mask_f32(&w, 8, 3);
        let mut w2 = w.clone();
        apply_mask_f32(&mut w2, &mask);
        assert_eq!(w2.data(), prune_f32(&w, 8, 3).data());
    }

    #[test]
    fn prune_f32_sparsity_level() {
        let mut rng = Rng::new(5);
        let w = TensorF32::randn(&[64, 64], 1.0, &mut rng);
        let p = prune_f32(&w, 8, 2); // 75% sparsity
        assert!((p.sparsity() - 0.75).abs() < 1e-9);
    }

    #[test]
    fn prune_bsr_respects_block_budget_prop() {
        check(Config::default().cases(64), |rng| {
            let k = rng.below(48) + 1;
            let n = rng.below(32) + 1;
            let bz_r = [4usize, 8, 16][rng.below(3)];
            let bz_c = [4usize, 8, 16][rng.below(3)];
            let keep = rng.below(4);
            let w = TensorI8::rand(&[k, n], rng);
            let p = prune_bsr_i8(&w, bz_r, bz_c, keep);
            let packed = crate::gemm::BsrPacked::pack(&p, bz_r, bz_c);
            let rp = packed.row_ptr();
            for br in 0..packed.block_rows() {
                assert!(rp[br + 1] - rp[br] <= keep, "block row {br} over budget");
            }
            // surviving values are untouched: p is w with whole blocks zeroed
            for (i, (&pv, &wv)) in p.data().iter().zip(w.data()).enumerate() {
                assert!(pv == wv || pv == 0, "elementwise corruption at {i}");
            }
        });
    }

    #[test]
    fn prune_bsr_keeps_largest_l1_blocks() {
        // 8x8 matrix, 4x4 blocks: block (0,1) clearly outweighs (0,0)
        let mut w = TensorF32::zeros(&[4, 8]);
        w.set(&[0, 0], 0.1);
        w.set(&[1, 5], 5.0);
        w.set(&[2, 6], -4.0);
        let p = prune_bsr_f32(&w, 4, 4, 1);
        assert_eq!(p.at(&[0, 0]), 0.0, "small block zeroed whole");
        assert_eq!(p.at(&[1, 5]), 5.0);
        assert_eq!(p.at(&[2, 6]), -4.0);
    }

    #[test]
    fn bsr_mask_keeps_whole_surviving_blocks() {
        let mut rng = Rng::new(6);
        let w = TensorF32::randn(&[16, 16], 1.0, &mut rng);
        let mask = bsr_mask_f32(&w, 8, 8, 1);
        // exactly one 8x8 block kept per block row → half the positions
        assert_eq!(mask.iter().filter(|&&m| m).count(), 2 * 8 * 8);
        // mask application matches the pruner on surviving values
        let mut w2 = w.clone();
        apply_mask_f32(&mut w2, &mask);
        assert_eq!(w2.data(), prune_bsr_f32(&w, 8, 8, 1).data());
    }
}
