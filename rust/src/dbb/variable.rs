//! Per-layer variable density bounds (paper §II-D: "It is also possible to
//! optimize sparsity per-layer or even per-channel to extract the most
//! from the model. Therefore, all of this points towards the need to
//! support a range of structured sparsity ratios natively in the
//! hardware.").
//!
//! The VDBB hardware runs *any* per-layer bound at full utilization, so
//! the software side is free to allocate sparsity where the model can
//! afford it. This module implements the allocation: given per-layer
//! weight statistics, choose each layer's NNZ to meet a global compressed
//! size (or effective-MACs) budget while minimizing the pruning damage
//! proxy — the weight-magnitude energy removed.

use crate::tensor::TensorF32;

/// Per-layer inputs to the allocator.
#[derive(Debug, Clone)]
pub struct LayerInfo {
    /// Layer name.
    pub name: String,
    /// Weight count of the layer.
    pub weights: usize,
    /// For each candidate bound `nnz ∈ 1..=bz`, the fraction of the
    /// layer's magnitude energy (Σw²) *retained* when pruned to that
    /// bound. `retained[nnz-1] ∈ (0, 1]`, monotone non-decreasing.
    pub retained: Vec<f64>,
    /// Whether the layer may be pruned at all (first conv / head stay
    /// dense, paper §V-A).
    pub prunable: bool,
}

impl LayerInfo {
    /// Measure from an f32 GEMM weight matrix: energy retained at every
    /// bound for the given block size.
    pub fn measure(name: &str, w: &TensorF32, bz: usize, prunable: bool) -> LayerInfo {
        let (k, n) = (w.shape()[0], w.shape()[1]);
        let total: f64 = w.data().iter().map(|&v| (v as f64) * (v as f64)).sum();
        let mut retained = vec![0.0f64; bz];
        // per block, sort |w|² descending; the prefix sum at position i is
        // the energy a bound of i+1 retains from this block
        for col in 0..n {
            for kb in 0..k.div_ceil(bz) {
                let lo = kb * bz;
                let hi = (lo + bz).min(k);
                let mut mags: Vec<f64> = (lo..hi)
                    .map(|kk| {
                        let v = w.at(&[kk, col]) as f64;
                        v * v
                    })
                    .collect();
                mags.sort_by(|a, b| b.partial_cmp(a).unwrap());
                let mut prefix = 0.0;
                for (i, r) in retained.iter_mut().enumerate() {
                    if i < mags.len() {
                        prefix += mags[i];
                    }
                    *r += prefix; // bounds past the block length keep all
                }
            }
        }
        let retained: Vec<f64> = retained
            .iter()
            .map(|&r| if total == 0.0 { 1.0 } else { (r / total).min(1.0) })
            .collect();
        LayerInfo {
            name: name.to_string(),
            weights: k * n,
            retained,
            prunable,
        }
    }
}

/// Result of an allocation.
#[derive(Debug, Clone)]
pub struct Allocation {
    /// Chosen bound per layer (bz for non-prunable layers).
    pub bounds: Vec<usize>,
    /// Achieved global density Σ(nnz·weights)/Σ(bz·weights).
    pub density: f64,
    /// Total magnitude energy retained (weighted by layer size).
    pub retained: f64,
}

/// Allocate per-layer bounds under a global density budget.
///
/// Greedy marginal-cost descent: start fully dense, repeatedly decrement
/// the bound of the layer whose next decrement destroys the least energy
/// per weight freed, until the weighted density meets `target_density`.
/// This is the discrete analogue of water-filling on the retained-energy
/// curves and is optimal when the curves are concave (they are, for
/// magnitude pruning: each further slot removed has larger magnitude).
pub fn allocate(layers: &[LayerInfo], bz: usize, target_density: f64) -> Allocation {
    let mut bounds: Vec<usize> = layers.iter().map(|_| bz).collect();
    let total_weights: f64 = layers.iter().map(|l| l.weights as f64).sum();
    let weighted_density = |bounds: &[usize]| -> f64 {
        layers
            .iter()
            .zip(bounds)
            .map(|(l, &b)| l.weights as f64 * b as f64 / bz as f64)
            .sum::<f64>()
            / total_weights
    };

    while weighted_density(&bounds) > target_density {
        // candidate: layer with the cheapest marginal energy loss per
        // density freed
        let mut best: Option<(usize, f64)> = None;
        for (i, l) in layers.iter().enumerate() {
            if !l.prunable || bounds[i] <= 1 {
                continue;
            }
            let b = bounds[i];
            let loss = l.retained[b - 1] - l.retained[b - 2]; // energy lost
            let freed = l.weights as f64 / total_weights / bz as f64;
            let cost = loss / freed.max(1e-12);
            if best.map(|(_, c)| cost < c).unwrap_or(true) {
                best = Some((i, cost));
            }
        }
        match best {
            Some((i, _)) => bounds[i] -= 1,
            None => break, // nothing left to prune
        }
    }

    let retained = layers
        .iter()
        .zip(&bounds)
        .map(|(l, &b)| l.retained[b - 1] * l.weights as f64)
        .sum::<f64>()
        / total_weights;
    Allocation {
        density: weighted_density(&bounds),
        bounds,
        retained,
    }
}

/// Uniform allocation at the same budget (the paper's model-wide bound),
/// for ablation comparisons.
pub fn allocate_uniform(layers: &[LayerInfo], bz: usize, target_density: f64) -> Allocation {
    // smallest uniform bound meeting the budget
    let total_weights: f64 = layers.iter().map(|l| l.weights as f64).sum();
    let mut bounds = vec![bz; layers.len()];
    for nnz in (1..=bz).rev() {
        let b: Vec<usize> = layers
            .iter()
            .map(|l| if l.prunable { nnz } else { bz })
            .collect();
        let d = layers
            .iter()
            .zip(&b)
            .map(|(l, &bb)| l.weights as f64 * bb as f64 / bz as f64)
            .sum::<f64>()
            / total_weights;
        bounds = b;
        if d <= target_density {
            break;
        }
    }
    let density = layers
        .iter()
        .zip(&bounds)
        .map(|(l, &b)| l.weights as f64 * b as f64 / bz as f64)
        .sum::<f64>()
        / total_weights;
    let retained = layers
        .iter()
        .zip(&bounds)
        .map(|(l, &b)| l.retained[b - 1] * l.weights as f64)
        .sum::<f64>()
        / total_weights;
    Allocation {
        bounds,
        density,
        retained,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn measured_layers(seed: u64) -> Vec<LayerInfo> {
        let mut rng = Rng::new(seed);
        // three layers with very different weight distributions: one nearly
        // sparse already (small tail), one dense-energy, one mid
        // energy concentration varies *within the depthwise blocks* (rows
        // of the K dim), which is what per-layer bounds exploit
        let mut l1 = TensorF32::randn(&[64, 32], 1.0, &mut rng);
        for (i, v) in l1.data_mut().iter_mut().enumerate() {
            if (i / 32) % 4 != 0 {
                *v *= 0.05; // most energy in 1/4 of each block
            }
        }
        let l2 = TensorF32::randn(&[64, 32], 1.0, &mut rng); // flat energy
        let mut l3 = TensorF32::randn(&[64, 32], 1.0, &mut rng);
        for (i, v) in l3.data_mut().iter_mut().enumerate() {
            if (i / 32) % 2 != 0 {
                *v *= 0.3;
            }
        }
        vec![
            LayerInfo::measure("peaky", &l1, 8, true),
            LayerInfo::measure("flat", &l2, 8, true),
            LayerInfo::measure("mid", &l3, 8, true),
        ]
    }

    #[test]
    fn retained_curves_are_monotone() {
        for l in measured_layers(1) {
            for i in 1..l.retained.len() {
                assert!(
                    l.retained[i] >= l.retained[i - 1] - 1e-9,
                    "{}: {:?}",
                    l.name,
                    l.retained
                );
            }
            assert!((l.retained[7] - 1.0).abs() < 1e-6, "full bound retains all");
        }
    }

    #[test]
    fn allocation_meets_budget() {
        let layers = measured_layers(2);
        for target in [0.75, 0.5, 0.375, 0.25] {
            let a = allocate(&layers, 8, target);
            assert!(a.density <= target + 1e-9, "density {} > {target}", a.density);
            assert!(a.bounds.iter().all(|&b| (1..=8).contains(&b)));
        }
    }

    #[test]
    fn variable_beats_uniform_on_heterogeneous_layers() {
        // the whole point: per-layer allocation retains more energy than a
        // uniform bound at the same global density
        let layers = measured_layers(3);
        let var = allocate(&layers, 8, 0.5);
        let uni = allocate_uniform(&layers, 8, 0.5);
        assert!(
            var.retained >= uni.retained - 1e-9,
            "variable {} < uniform {}",
            var.retained,
            uni.retained
        );
        // and it actually uses different bounds per layer
        let distinct: std::collections::BTreeSet<usize> = var.bounds.iter().cloned().collect();
        assert!(distinct.len() > 1, "degenerate allocation {:?}", var.bounds);
        // the peaky layer should end up sparser than the flat layer
        assert!(var.bounds[0] < var.bounds[1], "{:?}", var.bounds);
    }

    #[test]
    fn non_prunable_layers_stay_dense() {
        let mut layers = measured_layers(4);
        layers[1].prunable = false;
        let a = allocate(&layers, 8, 0.4);
        assert_eq!(a.bounds[1], 8);
    }

    #[test]
    fn impossible_budget_saturates_at_one() {
        let layers = measured_layers(5);
        let a = allocate(&layers, 8, 0.01);
        assert!(a.bounds.iter().all(|&b| b == 1));
        assert!((a.density - 0.125).abs() < 1e-9);
    }
}
