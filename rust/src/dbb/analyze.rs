//! Sparsity analysis helpers: measure activation sparsity (post-ReLU zeros)
//! and summarize DBB weight statistics per layer — feeds the clock-gating
//! power model and the Table I "Total NNZ / Sparsity" columns.

use super::DbbMatrix;
use crate::tensor::TensorI8;

/// Per-matrix DBB summary (one row of the Table I right-hand side).
#[derive(Debug, Clone, PartialEq)]
pub struct DbbSummary {
    /// Block size used.
    pub bz: usize,
    /// Effective density bound (max block NNZ).
    pub bound: usize,
    /// Total stored non-zeros.
    pub total_nnz: usize,
    /// Dense element count (K×N).
    pub dense_elems: usize,
    /// Block sparsity in percent, `(1 − bound/bz)·100` (paper's "Sparsity").
    pub block_sparsity_pct: f64,
    /// Element-level sparsity in percent (fraction of exact zeros).
    pub elem_sparsity_pct: f64,
    /// Compression ratio of the encoded form.
    pub compression: f64,
}

/// Summarize a compressed matrix.
pub fn summarize(m: &DbbMatrix) -> DbbSummary {
    let dense = m.k * m.n;
    DbbSummary {
        bz: m.bz,
        bound: m.bound,
        total_nnz: m.total_nnz(),
        dense_elems: dense,
        block_sparsity_pct: (1.0 - m.density()) * 100.0,
        elem_sparsity_pct: if dense == 0 {
            0.0
        } else {
            (1.0 - m.total_nnz() as f64 / dense as f64) * 100.0
        },
        compression: m.compression_ratio(),
    }
}

/// Fraction of zero elements in an activation tensor — what the paper's
/// clock-gating scheme exploits ("50% random sparse activations").
pub fn activation_sparsity(a: &TensorI8) -> f64 {
    a.sparsity()
}

/// Histogram of block-NNZ occupancy (how many blocks have 0,1,..,BZ
/// non-zeros) — used by the VDBB occupancy model: cycles per block on the
/// time-unrolled datapath is `max(1, nnz)` when streaming measured blocks.
pub fn block_occupancy_histogram(m: &DbbMatrix) -> Vec<usize> {
    let mut h = vec![0usize; m.bz + 1];
    for b in m.blocks() {
        h[b.nnz()] += 1;
    }
    h
}

/// Mean cycles/block for a VDBB stream of this matrix at fixed bound
/// (hardware streams the padded `bound` slots — paper §III-B: "the number of
/// clock cycles required to compute the block being equal to NNZ", with the
/// *bound* NNZ setting the fixed-rate stream).
pub fn vdbb_cycles_per_block(m: &DbbMatrix) -> usize {
    m.bound.max(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dbb::prune::prune_i8;
    use crate::util::Rng;

    #[test]
    fn summary_of_pruned_matrix() {
        let mut rng = Rng::new(1);
        let w = TensorI8::rand(&[64, 32], &mut rng);
        let p = prune_i8(&w, 8, 2);
        let c = DbbMatrix::compress(&p, 8).unwrap();
        let s = summarize(&c);
        assert_eq!(s.bz, 8);
        assert!(s.bound <= 2);
        assert!((s.block_sparsity_pct - 75.0).abs() < 1e-9);
        // element sparsity >= block sparsity (blocks may have < bound nnz)
        assert!(s.elem_sparsity_pct >= s.block_sparsity_pct - 1e-9);
    }

    #[test]
    fn occupancy_histogram_sums_to_blocks() {
        let mut rng = Rng::new(2);
        let w = TensorI8::rand_sparse(&[40, 10], 0.7, &mut rng);
        let c = DbbMatrix::compress(&w, 8).unwrap();
        let h = block_occupancy_histogram(&c);
        assert_eq!(h.iter().sum::<usize>(), c.blocks().len());
    }

    #[test]
    fn activation_sparsity_matches_tensor() {
        let a = TensorI8::from_vec(&[4], vec![0, 1, 0, 2]);
        assert_eq!(activation_sparsity(&a), 0.5);
    }

    #[test]
    fn vdbb_cycles_is_bound() {
        let mut rng = Rng::new(3);
        let w = prune_i8(&TensorI8::rand(&[16, 4], &mut rng), 8, 3);
        let c = DbbMatrix::compress_with_bound(&w, 8, 3).unwrap();
        assert_eq!(vdbb_cycles_per_block(&c), 3);
    }
}
