//! Layer-3 inference coordinator: the serving loop in front of the
//! accelerator.
//!
//! The leader thread runs the event loop: drain the request channel, let
//! the [`batcher::BatchPolicy`] decide when to flush, execute each planned
//! chunk (batch folded into GEMM `M`, exactly like the hardware folds it
//! into array rows), split the logits back to the callers and account
//! metrics.
//!
//! **The default functional path is engine-native**: requests route by
//! model name through a [`registry::ModelRegistry`] of
//! [`crate::engine::PreparedModel`]s — each model's one-time lowering
//! (synthesize → DBB encode/pack → profile → calibrate) is amortized at
//! startup (or skipped entirely by loading a persisted flat binary from
//! [`Config::persist_dir`]), and every batch runs through
//! [`crate::engine::PreparedModel::execute_fused_batch`]: the fused
//! requant/ReLU/pool epilogue, zero steady-state allocation, no artifact
//! directory and no XLA runtime required. The registry evicts
//! least-recently-used models past a packed-operand byte budget; a request
//! for an evicted model transparently re-loads/re-prepares it. The legacy
//! PJRT/XLA path (the AOT `convnet5_b*` executables, thread-affine
//! [`crate::runtime::Runtime`]) is preserved behind [`Config::use_xla`] for
//! the artifact-replay tests and golden comparisons.
//!
//! Every executed batch is *also* run through the architecture simulator as
//! a **hardware twin** — the same layer profile the power model consumes —
//! so the serving path reports both measured host latency and the simulated
//! accelerator cycles/energy the paper's tables are built from, split per
//! model ([`metrics::Metrics::per_model`]). The twin is the timing path;
//! the engine (or XLA) is the functional path. Python appears in neither.

pub mod batcher;
pub mod metrics;
pub mod registry;
pub mod request;

use std::collections::HashMap;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::arch::Design;
use crate::engine::PreparedModel;
use crate::gemm::ActPolicy;
use crate::power;
use crate::runtime::{HostTensor, Runtime};
use crate::sim::accel::{network_timing_with, profile_model_fixed_act, LayerProfile};
use crate::tensor::TensorI8;
use crate::util::error::{anyhow, bail, Context, Result};
use crate::util::Parallelism;
use batcher::BatchPolicy;
use metrics::Metrics;
use registry::{ModelRegistry, ModelSpec};
use request::{InferRequest, InferResponse};

const IMAGE_ELEMS: usize = 32 * 32 * 3;
const NUM_CLASSES: usize = 10;

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct Config {
    /// Artifact directory (`make artifacts` output).
    pub artifacts_dir: std::path::PathBuf,
    /// Hardware-twin design point for the timing path.
    pub design: Design,
    /// Activation sparsity the twin *assumes* when no functional profile is
    /// available (`measured_sparsity: false`); 0.5 is the paper's typical
    /// operating point. Must lie in `[0, 1]` — validated at
    /// [`Coordinator::start`]. With `measured_sparsity: true` (the
    /// default) the twin instead consumes the per-layer sparsities measured
    /// by the prepared model's functional profile.
    pub act_sparsity: f64,
    /// Batch flush timeout. Must be non-zero — validated at
    /// [`Coordinator::start`] (a zero timeout degenerates every queue
    /// check into an immediate flush, serving nothing but batch-1).
    pub max_wait: Duration,
    /// Worker-pool width for the hardware twin's per-layer timing on the
    /// batched execution path. Defaults to `Parallelism::serial()`: the
    /// served convnet5 twin has 5 µs-scale layers per batch, so pool setup
    /// would cost more latency than it saves. Set `Parallelism::auto()` /
    /// `threads(n)` when serving deeper models.
    pub parallelism: Parallelism,
    /// Build one [`crate::engine::PreparedModel`] of the served network at
    /// startup, run its seeded functional profile once, and feed the twin
    /// *measured* per-layer activation sparsities instead of the
    /// `act_sparsity` scalar. Default `true`.
    pub measured_sparsity: bool,
    /// Three-way activation policy (off / gate / encode) installed on the
    /// prepared model (its functional profile/execute passes). Default
    /// [`ActPolicy::Auto`]: after the startup profile, the engine resolves
    /// the policy per layer from the *same* measured per-layer sparsities
    /// the twin prices — one sparsity source — and the twin prices the
    /// resulting A-side decision too (layers the policy encodes stream
    /// compressed activation traffic in the simulated SRAM counters,
    /// `LayerProfile::act_encoded`). Every policy is bit-exact, so this
    /// knob never changes a served or profiled number, only the simulated
    /// traffic/energy and the engine's own execute cost.
    pub act_policy: ActPolicy,
    /// Serve through the legacy PJRT/XLA artifact path (single compiled
    /// `convnet5` model; requires `make artifacts`) instead of the default
    /// engine-native registry path. Default `false`.
    pub use_xla: bool,
    /// The models the engine-native path registers and serves, each at its
    /// own DBB encoding point. Ignored (and unvalidated) under
    /// [`Self::use_xla`]. Default: ConvNet at the paper's 3/8.
    pub registry: Vec<ModelSpec>,
    /// Byte budget over the registry's resident packed weight operands
    /// ([`crate::engine::PreparedModel::operand_bytes`]); exceeding it
    /// evicts least-recently-used models. Default 256 MiB.
    pub registry_budget_bytes: usize,
    /// Batch sizes the engine-native batch planner chunks to (the engine
    /// has no compiled-shape constraint, but fixed chunk sizes keep the
    /// padding/occupancy accounting — and the twin's batch scaling —
    /// identical to the XLA path). Default `[1, 8]`.
    pub batch_sizes: Vec<usize>,
    /// Directory of persisted prepared-model flat binaries. When set, the
    /// engine-native startup loads `<model>_nnz<n>_bz<b>.ssta` instead of
    /// re-preparing (skipping synthesize/encode/calibrate entirely), and
    /// freshly prepared models are saved there for the next restart.
    pub persist_dir: Option<std::path::PathBuf>,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            artifacts_dir: "artifacts".into(),
            design: Design::paper_optimal(),
            act_sparsity: 0.5,
            max_wait: Duration::from_millis(2),
            parallelism: Parallelism::serial(),
            measured_sparsity: true,
            act_policy: ActPolicy::default(),
            use_xla: false,
            registry: vec![ModelSpec::new("ConvNet", 3, 8)],
            registry_budget_bytes: 256 * 1024 * 1024,
            batch_sizes: vec![1, 8],
            persist_dir: None,
        }
    }
}

impl Config {
    /// Reject configurations that today would be silently accepted and
    /// misbehave at runtime.
    fn validate(&self) -> Result<()> {
        if !(0.0..=1.0).contains(&self.act_sparsity) {
            bail!(
                "coordinator config: act_sparsity must be a fraction in [0, 1], got {}",
                self.act_sparsity
            );
        }
        if self.max_wait == Duration::ZERO {
            bail!(
                "coordinator config: max_wait must be non-zero (a zero batch window \
                 flushes every request alone and defeats batching)"
            );
        }
        if !self.use_xla {
            if self.registry.is_empty() {
                bail!(
                    "coordinator config: engine-native serving needs a non-empty model \
                     registry (or set use_xla for the legacy artifact path)"
                );
            }
            if self.registry_budget_bytes == 0 {
                bail!("coordinator config: registry eviction budget must be non-zero bytes");
            }
            if self.batch_sizes.is_empty() || self.batch_sizes.contains(&0) {
                bail!("coordinator config: batch_sizes must be non-empty and non-zero");
            }
            let zoo = crate::models::zoo();
            let mut seen: Vec<&str> = Vec::new();
            for spec in &self.registry {
                if seen.contains(&spec.model.as_str()) {
                    bail!("coordinator config: duplicate registry entry '{}'", spec.model);
                }
                seen.push(&spec.model);
                if !zoo.iter().any(|m| m.name == spec.model) {
                    bail!(
                        "coordinator config: unknown model '{}' (zoo: {})",
                        spec.model,
                        zoo.iter().map(|m| m.name).collect::<Vec<_>>().join(", ")
                    );
                }
                if spec.nnz == 0 || spec.bz == 0 || spec.bz > 16 || spec.nnz > spec.bz {
                    bail!(
                        "coordinator config: model '{}' needs 1 <= nnz <= bz <= 16, \
                         got nnz={} bz={}",
                        spec.model,
                        spec.nnz,
                        spec.bz
                    );
                }
            }
        }
        Ok(())
    }
}

enum Msg {
    Infer(InferRequest),
    Shutdown,
}

/// Handle to a running coordinator. Cloneable; submit requests from any
/// thread.
#[derive(Clone)]
pub struct Handle {
    tx: mpsc::Sender<Msg>,
    metrics: Arc<Mutex<Metrics>>,
    /// Names the coordinator serves (registry order; the first is the
    /// default route for [`Handle::submit`]).
    models: Arc<Vec<String>>,
}

/// A running coordinator (joined by [`Coordinator::shutdown`] or drop).
pub struct Coordinator {
    handle: Handle,
    worker: Option<JoinHandle<Result<()>>>,
}

impl Coordinator {
    /// Start the leader thread; prepares (or loads) every registered model
    /// and its hardware twin up front — on the XLA path, compiles the model
    /// executables — so the first request pays neither lowering nor compile
    /// latency. Fails fast on an invalid [`Config`].
    pub fn start(cfg: Config) -> Result<Coordinator> {
        cfg.validate()?;
        let models: Arc<Vec<String>> = Arc::new(if cfg.use_xla {
            vec!["ConvNet".to_string()]
        } else {
            cfg.registry.iter().map(|s| s.model.clone()).collect()
        });
        let use_xla = cfg.use_xla;
        let (tx, rx) = mpsc::channel::<Msg>();
        let metrics = Arc::new(Mutex::new(Metrics::default()));
        let metrics2 = metrics.clone();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let worker = std::thread::Builder::new()
            .name("ssta-coordinator".into())
            .spawn(move || {
                if use_xla {
                    leader_loop(cfg, rx, metrics2, ready_tx)
                } else {
                    leader_loop_engine(cfg, rx, metrics2, ready_tx)
                }
            })
            .context("spawning coordinator thread")?;
        // wait for the serving path to come up (or fail fast)
        ready_rx
            .recv()
            .map_err(|_| anyhow!("coordinator thread died during startup"))??;
        Ok(Coordinator {
            handle: Handle { tx, metrics, models },
            worker: Some(worker),
        })
    }

    /// Cloneable submission handle.
    pub fn handle(&self) -> Handle {
        self.handle.clone()
    }

    /// Metrics snapshot.
    pub fn metrics(&self) -> Metrics {
        self.handle.metrics.lock().unwrap().clone()
    }

    /// Stop the leader loop and join the thread.
    pub fn shutdown(mut self) -> Result<()> {
        let _ = self.handle.tx.send(Msg::Shutdown);
        if let Some(w) = self.worker.take() {
            w.join().map_err(|_| anyhow!("coordinator thread panicked"))??;
        }
        Ok(())
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.handle.tx.send(Msg::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Handle {
    /// Submit one image to the default route (the first registered model);
    /// returns the receiver for the response.
    pub fn submit(&self, id: u64, image: Vec<f32>) -> Result<mpsc::Receiver<InferResponse>> {
        if image.len() != IMAGE_ELEMS {
            bail!("image must have {IMAGE_ELEMS} elements, got {}", image.len());
        }
        let model = self
            .models
            .first()
            .cloned()
            .unwrap_or_else(|| "ConvNet".to_string());
        self.submit_routed(model, id, image)
    }

    /// Submit one image routed to a registered model by name. Unknown
    /// names fail here with a typed error — the request never reaches the
    /// leader loop.
    pub fn submit_to(
        &self,
        model: &str,
        id: u64,
        image: Vec<f32>,
    ) -> Result<mpsc::Receiver<InferResponse>> {
        if image.is_empty() {
            bail!("image must be non-empty");
        }
        if !self.models.iter().any(|m| m == model) {
            bail!(
                "unknown model '{model}': this coordinator serves [{}]",
                self.models.join(", ")
            );
        }
        self.submit_routed(model.to_string(), id, image)
    }

    fn submit_routed(
        &self,
        model: String,
        id: u64,
        image: Vec<f32>,
    ) -> Result<mpsc::Receiver<InferResponse>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Msg::Infer(InferRequest {
                id,
                model,
                image,
                enqueued: Instant::now(),
                reply,
            }))
            .map_err(|_| anyhow!("coordinator is down"))?;
        Ok(rx)
    }

    /// Submit and block for the response (default route).
    pub fn infer(&self, id: u64, image: Vec<f32>) -> Result<InferResponse> {
        let rx = self.submit(id, image)?;
        rx.recv().map_err(|_| anyhow!("coordinator dropped the request"))
    }

    /// Submit to a named model and block for the response.
    pub fn infer_to(&self, model: &str, id: u64, image: Vec<f32>) -> Result<InferResponse> {
        let rx = self.submit_to(model, id, image)?;
        rx.recv().map_err(|_| anyhow!("coordinator dropped the request"))
    }

    /// Names this coordinator serves (registry order).
    pub fn models(&self) -> &[String] {
        &self.models
    }

    /// Metrics snapshot.
    pub fn metrics(&self) -> Metrics {
        self.metrics.lock().unwrap().clone()
    }
}

/// Seed for the twin's prepared-model functional profile (fixed so the
/// measured per-layer sparsities are reproducible across restarts).
const TWIN_SEED: u64 = 42;

/// The hardware twin: layer profiles of the served model on the configured
/// design, scaled per executed batch.
struct Twin {
    design: Design,
    profiles_b1: Vec<LayerProfile>,
    par: Parallelism,
}

impl Twin {
    /// Twin with an *assumed* uniform activation sparsity (the
    /// `measured_sparsity: false` path and the Fig-12-style sweeps).
    fn new(design: Design, nnz: usize, act_sparsity: f64, par: Parallelism) -> Twin {
        let model = crate::models::convnet5();
        Twin {
            design,
            profiles_b1: profile_model_fixed_act(&model, nnz, 8, act_sparsity),
            par,
        }
    }

    /// Twin consuming an existing per-layer profile — the coordinator hands
    /// it the *measured* sparsities of the prepared model's functional
    /// profile, so the simulated cycles/energy reflect the layer-by-layer
    /// sparsity variation instead of one assumed scalar.
    fn from_profiles(design: Design, profiles_b1: Vec<LayerProfile>, par: Parallelism) -> Twin {
        Twin {
            design,
            profiles_b1,
            par,
        }
    }

    /// Twin with an *assumed* uniform activation sparsity for an arbitrary
    /// zoo model (the engine-native `measured_sparsity: false` path).
    fn assumed(
        design: Design,
        model: &crate::models::Model,
        nnz: usize,
        bz: usize,
        act_sparsity: f64,
        par: Parallelism,
    ) -> Twin {
        Twin {
            design,
            profiles_b1: profile_model_fixed_act(model, nnz, bz, act_sparsity),
            par,
        }
    }

    /// Simulated (cycles, energy mJ, dense MACs) for one executed batch.
    fn simulate(&self, batch: usize) -> (u64, f64, u64) {
        let profiles: Vec<LayerProfile> = self
            .profiles_b1
            .iter()
            .map(|p| {
                let mut p = p.clone();
                p.m *= batch; // batch folds into GEMM M
                p.out_elems *= batch as u64;
                p
            })
            .collect();
        let t = network_timing_with(&self.design, &profiles, self.par);
        let pw = power::power(&self.design, &t.total);
        let secs = t.total.cycles as f64 / self.design.tech.freq_hz();
        let energy_mj = pw.total_mw() * secs; // mW · s = mJ
        (t.total.cycles, energy_mj, t.dense_macs)
    }
}

/// File name of a model's persisted flat binary under
/// [`Config::persist_dir`].
fn persist_file(spec: &ModelSpec) -> String {
    format!("{}_nnz{}_bz{}.ssta", spec.model, spec.nnz, spec.bz)
}

/// Produce one serving-ready [`PreparedModel`] for `spec`: load the
/// persisted flat binary when [`Config::persist_dir`] holds a matching one
/// (skipping synthesize/encode/profile/calibrate entirely — the restart
/// fast path), otherwise run the full one-time lowering and persist it for
/// the next restart. Either way the returned model is profiled, calibrated,
/// and declared fused-epilogue for twin pricing.
fn prepare_served(cfg: &Config, spec: &ModelSpec) -> Result<PreparedModel> {
    let path = cfg.persist_dir.as_ref().map(|d| d.join(persist_file(spec)));
    if let Some(p) = &path {
        if p.exists() {
            match PreparedModel::load(p, cfg.parallelism) {
                Ok(mut pm)
                    if pm.model_name() == spec.model
                        && pm.encoding() == (spec.nnz, spec.bz, TWIN_SEED)
                        && pm.measured_act_sparsity().is_some()
                        && pm.calibrated_shifts().is_some() =>
                {
                    pm.set_act_policy(cfg.act_policy);
                    pm.set_fused_epilogue(true);
                    return Ok(pm);
                }
                // stale or corrupt artifact: fall through to a fresh
                // prepare, which overwrites it
                Ok(_) | Err(_) => {}
            }
        }
    }
    let model = crate::models::zoo()
        .into_iter()
        .find(|m| m.name == spec.model)
        .ok_or_else(|| anyhow!("unknown model '{}' in registry config", spec.model))?;
    let mut pm = PreparedModel::prepare(&model, spec.nnz, spec.bz, TWIN_SEED, cfg.parallelism);
    pm.set_act_policy(cfg.act_policy);
    pm.set_fused_epilogue(true);
    pm.profile(cfg.parallelism);
    pm.calibrate(cfg.parallelism);
    if let Some(p) = &path {
        if let Some(dir) = p.parent() {
            let _ = std::fs::create_dir_all(dir);
        }
        if let Err(e) = pm.save(p) {
            eprintln!("warning: could not persist prepared model {}: {e}", p.display());
        }
    }
    Ok(pm)
}

/// The engine-native leader loop: registry-served, no PJRT runtime.
fn leader_loop_engine(
    cfg: Config,
    rx: mpsc::Receiver<Msg>,
    metrics: Arc<Mutex<Metrics>>,
    ready: mpsc::Sender<Result<()>>,
) -> Result<()> {
    // ---- startup: prepare/load every registered model and its twin ----
    let startup = (|| -> Result<(ModelRegistry, HashMap<String, Twin>)> {
        let mut registry = ModelRegistry::new(cfg.registry_budget_bytes);
        let mut twins = HashMap::new();
        for spec in &cfg.registry {
            let pm = prepare_served(&cfg, spec)?;
            let twin = if cfg.measured_sparsity {
                let profiles = pm
                    .profiles()
                    .ok_or_else(|| anyhow!("prepared model '{}' has no profile", spec.model))?;
                Twin::from_profiles(cfg.design, profiles, cfg.parallelism)
            } else {
                let model = crate::models::zoo()
                    .into_iter()
                    .find(|m| m.name == spec.model)
                    .ok_or_else(|| anyhow!("unknown model '{}'", spec.model))?;
                Twin::assumed(
                    cfg.design,
                    &model,
                    spec.nnz,
                    spec.bz,
                    cfg.act_sparsity,
                    cfg.parallelism,
                )
            };
            twins.insert(spec.model.clone(), twin);
            let evicted = registry.insert(spec.model.clone(), pm);
            if !evicted.is_empty() {
                metrics.lock().unwrap().evictions += evicted.len() as u64;
            }
        }
        Ok((registry, twins))
    })();
    let (mut registry, twins) = match startup {
        Ok(v) => {
            let _ = ready.send(Ok(()));
            v
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return Ok(());
        }
    };
    let policy = BatchPolicy::new(cfg.batch_sizes.clone(), cfg.max_wait);
    let mut queue: Vec<InferRequest> = Vec::new();

    loop {
        // ---- wait for work (same cadence as the XLA loop) ----
        let msg = if queue.is_empty() {
            match rx.recv() {
                Ok(m) => Some(m),
                Err(_) => return Ok(()), // all senders gone
            }
        } else {
            let oldest = queue[0].enqueued.elapsed();
            let budget = cfg.max_wait.saturating_sub(oldest);
            match rx.recv_timeout(budget) {
                Ok(m) => Some(m),
                Err(mpsc::RecvTimeoutError::Timeout) => None,
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    flush_native(&cfg, &policy, &mut registry, &twins, &mut queue, &metrics)?;
                    return Ok(());
                }
            }
        };
        match msg {
            Some(Msg::Infer(r)) => {
                queue.push(r);
                while queue.len() < policy.max_batch() {
                    match rx.try_recv() {
                        Ok(Msg::Infer(r)) => queue.push(r),
                        Ok(Msg::Shutdown) => {
                            flush_native(
                                &cfg,
                                &policy,
                                &mut registry,
                                &twins,
                                &mut queue,
                                &metrics,
                            )?;
                            return Ok(());
                        }
                        Err(_) => break,
                    }
                }
            }
            Some(Msg::Shutdown) => {
                flush_native(&cfg, &policy, &mut registry, &twins, &mut queue, &metrics)?;
                return Ok(());
            }
            None => {}
        }
        let oldest = queue.first().map(|r| r.enqueued.elapsed()).unwrap_or_default();
        if policy.should_flush(queue.len(), oldest) {
            flush_native(&cfg, &policy, &mut registry, &twins, &mut queue, &metrics)?;
        }
    }
}

/// Quantize a `[0,1]` f32 image to the engine's symmetric INT8 domain.
fn quantize_image(image: &[f32]) -> TensorI8 {
    let data: Vec<i8> = image
        .iter()
        .map(|&v| (v * 127.0).round().clamp(-127.0, 127.0) as i8)
        .collect();
    if data.len() == IMAGE_ELEMS {
        TensorI8::from_vec(&[32, 32, 3], data)
    } else {
        let n = data.len();
        TensorI8::from_vec(&[n], data)
    }
}

/// Execute everything in the queue through the registry-served fused
/// engine: group by model (arrival order preserved), chunk each group by
/// the batch plan, fold each chunk into one
/// [`PreparedModel::execute_fused_batch`] call.
fn flush_native(
    cfg: &Config,
    policy: &BatchPolicy,
    registry: &mut ModelRegistry,
    twins: &HashMap<String, Twin>,
    queue: &mut Vec<InferRequest>,
    metrics: &Arc<Mutex<Metrics>>,
) -> Result<()> {
    if queue.is_empty() {
        return Ok(());
    }
    let mut buckets: Vec<(String, Vec<InferRequest>)> = Vec::new();
    for r in std::mem::take(queue) {
        match buckets.iter_mut().find(|(n, _)| *n == r.model) {
            Some((_, v)) => v.push(r),
            None => {
                let name = r.model.clone();
                buckets.push((name, vec![r]));
            }
        }
    }
    for (name, reqs) in buckets {
        // cold model (evicted under budget pressure): re-load/re-prepare on
        // the miss, evicting whatever the budget demands in turn
        if !registry.contains(&name) {
            let spec = cfg
                .registry
                .iter()
                .find(|s| s.model == name)
                .ok_or_else(|| anyhow!("request for unconfigured model '{name}'"))?;
            let pm = prepare_served(cfg, spec)?;
            let evicted = registry.insert(name.clone(), pm);
            if !evicted.is_empty() {
                metrics.lock().unwrap().evictions += evicted.len() as u64;
            }
        }
        let plan = policy.plan(reqs.len());
        let mut iter = reqs.into_iter();
        for (compiled, real) in plan {
            let chunk: Vec<InferRequest> = iter.by_ref().take(real).collect();
            debug_assert_eq!(chunk.len(), real);

            let mut inputs: Vec<TensorI8> =
                chunk.iter().map(|r| quantize_image(&r.image)).collect();
            // padding rows are zero images whose outputs are dropped
            let pad_shape = inputs[0].shape().to_vec();
            inputs.resize_with(compiled, || TensorI8::zeros(&pad_shape));

            let pm = registry.get(&name).expect("ensured resident above");
            let t0 = Instant::now();
            let outs = pm.execute_fused_batch(&inputs, cfg.parallelism);
            let exec = t0.elapsed();

            let (sim_cycles, sim_energy_mj, dense_macs) = twins
                .get(&name)
                .map(|t| t.simulate(compiled))
                .unwrap_or((0, 0.0, 0));
            {
                let mut m = metrics.lock().unwrap();
                m.record_batch_for(
                    &name,
                    real,
                    compiled,
                    exec,
                    sim_cycles,
                    sim_energy_mj,
                    dense_macs,
                );
            }

            for (i, r) in chunk.into_iter().enumerate() {
                let logits: Vec<f32> =
                    outs[i].data().iter().take(NUM_CLASSES).map(|&v| v as f32).collect();
                let queue_us = (t0 - r.enqueued).as_micros() as u64;
                let resp = InferResponse {
                    id: r.id,
                    logits,
                    batch_size: compiled,
                    queue_us,
                    execute_us: exec.as_micros() as u64,
                    sim_cycles,
                    sim_energy_mj,
                };
                metrics.lock().unwrap().record_latency_for(&name, r.enqueued.elapsed());
                let _ = r.reply.send(resp); // caller may have gone away — fine
            }
        }
    }
    Ok(())
}

fn leader_loop(
    cfg: Config,
    rx: mpsc::Receiver<Msg>,
    metrics: Arc<Mutex<Metrics>>,
    ready: mpsc::Sender<Result<()>>,
) -> Result<()> {
    // ---- startup: open runtime, discover model executables ----
    let startup = (|| -> Result<(Runtime, Vec<usize>, usize)> {
        let mut rt = Runtime::open(&cfg.artifacts_dir)?;
        let names: Vec<String> = rt.artifact_names().iter().map(|s| s.to_string()).collect();
        let mut sizes = Vec::new();
        let mut nnz = 8usize;
        for name in names {
            if let Some(rest) = name.strip_prefix("convnet5_b") {
                if let Ok(b) = rest.parse::<usize>() {
                    sizes.push(b);
                    if let Some(m) = rt.meta(&name) {
                        if let Some(v) = m.raw.get("nnz").and_then(|j| j.as_usize()) {
                            nnz = v;
                        }
                    }
                }
            }
        }
        if sizes.is_empty() {
            bail!("no convnet5_b* artifacts found — run `make artifacts`");
        }
        // pre-compile all batch variants
        for &b in &sizes {
            rt.load(&format!("convnet5_b{b}"))?;
        }
        Ok((rt, sizes, nnz))
    })();
    let (mut rt, sizes, nnz) = match startup {
        Ok(v) => {
            let _ = ready.send(Ok(()));
            v
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return Ok(());
        }
    };
    let policy = BatchPolicy::new(sizes, cfg.max_wait);
    // Prepare-once at startup: the served model is lowered into a
    // PreparedModel (the one-time weight encode/pack) and functionally
    // profiled exactly once; the twin consumes that profile's measured
    // per-layer activation sparsities (paper Fig. 11) for every batch it
    // simulates. Per-batch *functional* execution stays on the XLA
    // runtime — only the profile outlives this block.
    let twin = if cfg.measured_sparsity {
        let model = crate::models::convnet5();
        let mut prepared =
            crate::engine::PreparedModel::prepare(&model, nnz, 8, TWIN_SEED, cfg.parallelism);
        prepared.set_act_policy(cfg.act_policy);
        let profiles = prepared.profile(cfg.parallelism);
        Twin::from_profiles(cfg.design, profiles, cfg.parallelism)
    } else {
        Twin::new(cfg.design, nnz, cfg.act_sparsity, cfg.parallelism)
    };
    let mut queue: Vec<InferRequest> = Vec::new();

    loop {
        // ---- wait for work ----
        let msg = if queue.is_empty() {
            match rx.recv() {
                Ok(m) => Some(m),
                Err(_) => return Ok(()), // all senders gone
            }
        } else {
            let oldest = queue[0].enqueued.elapsed();
            let budget = cfg.max_wait.saturating_sub(oldest);
            match rx.recv_timeout(budget) {
                Ok(m) => Some(m),
                Err(mpsc::RecvTimeoutError::Timeout) => None,
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    flush(&mut rt, &policy, &twin, &mut queue, &metrics)?;
                    return Ok(());
                }
            }
        };
        match msg {
            Some(Msg::Infer(r)) => {
                queue.push(r);
                // greedily drain whatever is already queued in the channel
                // (arrivals during the previous flush) up to a full batch —
                // otherwise a backlog degrades into size-1 flushes
                while queue.len() < policy.max_batch() {
                    match rx.try_recv() {
                        Ok(Msg::Infer(r)) => queue.push(r),
                        Ok(Msg::Shutdown) => {
                            flush(&mut rt, &policy, &twin, &mut queue, &metrics)?;
                            return Ok(());
                        }
                        Err(_) => break,
                    }
                }
            }
            Some(Msg::Shutdown) => {
                flush(&mut rt, &policy, &twin, &mut queue, &metrics)?;
                return Ok(());
            }
            None => {} // timeout → fall through to flush check
        }
        let oldest = queue.first().map(|r| r.enqueued.elapsed()).unwrap_or_default();
        if policy.should_flush(queue.len(), oldest) {
            flush(&mut rt, &policy, &twin, &mut queue, &metrics)?;
        }
    }
}

/// Execute everything in the queue according to the batch plan.
fn flush(
    rt: &mut Runtime,
    policy: &BatchPolicy,
    twin: &Twin,
    queue: &mut Vec<InferRequest>,
    metrics: &Arc<Mutex<Metrics>>,
) -> Result<()> {
    if queue.is_empty() {
        return Ok(());
    }
    let plan = policy.plan(queue.len());
    let mut reqs = std::mem::take(queue).into_iter();
    for (compiled, real) in plan {
        let chunk: Vec<InferRequest> = reqs.by_ref().take(real).collect();
        debug_assert_eq!(chunk.len(), real);

        // pack the batch (padding rows stay zero)
        let mut batch = vec![0f32; compiled * IMAGE_ELEMS];
        for (i, r) in chunk.iter().enumerate() {
            batch[i * IMAGE_ELEMS..(i + 1) * IMAGE_ELEMS].copy_from_slice(&r.image);
        }

        let exe = rt.load(&format!("convnet5_b{compiled}"))?;
        let t0 = Instant::now();
        let outs = exe.run(&[HostTensor::F32(batch)])?;
        let exec = t0.elapsed();
        let logits_all = outs[0].as_f32();

        let (sim_cycles, sim_energy_mj, dense_macs) = twin.simulate(compiled);
        {
            let mut m = metrics.lock().unwrap();
            m.record_batch(real, compiled, exec, sim_cycles, sim_energy_mj, dense_macs);
        }

        for (i, r) in chunk.into_iter().enumerate() {
            let logits = logits_all[i * NUM_CLASSES..(i + 1) * NUM_CLASSES].to_vec();
            let queue_us = (t0 - r.enqueued).as_micros() as u64;
            let resp = InferResponse {
                id: r.id,
                logits,
                batch_size: compiled,
                queue_us,
                execute_us: exec.as_micros() as u64,
                sim_cycles,
                sim_energy_mj,
            };
            metrics.lock().unwrap().record_latency(r.enqueued.elapsed());
            let _ = r.reply.send(resp); // caller may have gone away — fine
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn artifacts_ready() -> bool {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/manifest.json")
            .exists()
    }

    fn test_cfg() -> Config {
        // the artifact-replay tests pin the legacy XLA functional path
        Config {
            artifacts_dir: std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
            use_xla: true,
            ..Config::default()
        }
    }

    fn engine_cfg() -> Config {
        // engine-native serving: no artifacts, no XLA — prepared models only
        Config {
            artifacts_dir: "does-not-exist".into(),
            max_wait: Duration::from_micros(200),
            ..Config::default()
        }
    }

    #[test]
    fn serves_single_request() {
        if !artifacts_ready() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let c = Coordinator::start(test_cfg()).unwrap();
        let mut rng = Rng::new(1);
        let img: Vec<f32> = (0..IMAGE_ELEMS).map(|_| rng.f32()).collect();
        let resp = c.handle().infer(42, img).unwrap();
        assert_eq!(resp.id, 42);
        assert_eq!(resp.logits.len(), NUM_CLASSES);
        assert!(resp.logits.iter().all(|v| v.is_finite()));
        assert!(resp.sim_cycles > 0);
        c.shutdown().unwrap();
    }

    #[test]
    fn batches_concurrent_requests_and_matches_single() {
        if !artifacts_ready() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let c = Coordinator::start(test_cfg()).unwrap();
        let h = c.handle();
        let mut rng = Rng::new(2);
        let imgs: Vec<Vec<f32>> =
            (0..12).map(|_| (0..IMAGE_ELEMS).map(|_| rng.f32()).collect()).collect();

        // singles first (reference answers)
        let singles: Vec<Vec<f32>> = imgs
            .iter()
            .enumerate()
            .map(|(i, im)| h.infer(i as u64, im.clone()).unwrap().logits)
            .collect();

        // now fire concurrently → should batch
        let rxs: Vec<_> = imgs
            .iter()
            .enumerate()
            .map(|(i, im)| h.submit(100 + i as u64, im.clone()).unwrap())
            .collect();
        let batched: Vec<InferResponse> = rxs.into_iter().map(|rx| rx.recv().unwrap()).collect();

        for (i, resp) in batched.iter().enumerate() {
            assert_eq!(resp.id, 100 + i as u64);
            // batching must not change the numbers (row independence)
            for (a, b) in resp.logits.iter().zip(&singles[i]) {
                assert!((a - b).abs() < 1e-4, "req {i}: batched {a} vs single {b}");
            }
        }
        // at least one multi-request batch formed
        let m = c.metrics();
        assert!(m.batches < m.requests, "no batching happened: {}", m.summary());
        c.shutdown().unwrap();
    }

    #[test]
    fn rejects_bad_image_size() {
        if !artifacts_ready() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let c = Coordinator::start(test_cfg()).unwrap();
        assert!(c.handle().submit(0, vec![0.0; 7]).is_err());
        c.shutdown().unwrap();
    }

    #[test]
    fn metrics_accumulate() {
        if !artifacts_ready() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let c = Coordinator::start(test_cfg()).unwrap();
        let h = c.handle();
        let mut rng = Rng::new(3);
        for i in 0..5 {
            let img: Vec<f32> = (0..IMAGE_ELEMS).map(|_| rng.f32()).collect();
            h.infer(i, img).unwrap();
        }
        let m = c.metrics();
        assert_eq!(m.requests, 5);
        assert!(m.sim_cycles > 0);
        assert!(m.sim_energy_mj > 0.0);
        assert!(m.sim_effective_tops(1e9) > 0.0);
        c.shutdown().unwrap();
    }

    #[test]
    fn rejects_invalid_config_before_startup() {
        // validation fires before the runtime opens, so no artifacts needed
        let e = Coordinator::start(Config { act_sparsity: 1.5, ..Config::default() })
            .err()
            .expect("act_sparsity > 1 must be rejected");
        assert!(e.to_string().contains("act_sparsity"), "{e}");
        assert!(Coordinator::start(Config { act_sparsity: -0.1, ..Config::default() }).is_err());
        assert!(
            Coordinator::start(Config { act_sparsity: f64::NAN, ..Config::default() }).is_err()
        );
        let e = Coordinator::start(Config { max_wait: Duration::ZERO, ..Config::default() })
            .err()
            .expect("zero max_wait must be rejected");
        assert!(e.to_string().contains("max_wait"), "{e}");
    }

    #[test]
    fn engine_native_serves_without_artifacts() {
        // the default path: registry-routed execute_fused, no XLA anywhere
        let c = Coordinator::start(engine_cfg()).unwrap();
        let h = c.handle();
        assert_eq!(h.models(), ["ConvNet".to_string()]);
        let mut rng = Rng::new(11);
        let img: Vec<f32> = (0..IMAGE_ELEMS).map(|_| rng.f32()).collect();
        let resp = h.infer(7, img.clone()).unwrap();
        assert_eq!(resp.id, 7);
        assert_eq!(resp.logits.len(), NUM_CLASSES);
        assert!(resp.logits.iter().all(|v| v.is_finite()));
        assert!(resp.sim_cycles > 0, "twin must price engine-served batches");
        // deterministic: the same image serves the same logits
        let again = h.infer_to("ConvNet", 8, img).unwrap();
        assert_eq!(again.logits, resp.logits);
        let m = c.metrics();
        assert_eq!(m.requests, 2);
        let mm = m.model("ConvNet").expect("per-model split populated");
        assert_eq!(mm.requests, 2);
        assert!(mm.latency_pct(50.0) > 0);
        c.shutdown().unwrap();
    }

    #[test]
    fn unknown_model_fails_typed_at_the_handle() {
        let c = Coordinator::start(engine_cfg()).unwrap();
        let h = c.handle();
        let e = h
            .submit_to("NoSuchNet", 1, vec![0.5; IMAGE_ELEMS])
            .err()
            .expect("unknown model must be rejected");
        assert!(e.to_string().contains("unknown model 'NoSuchNet'"), "{e}");
        assert!(h.submit_to("ConvNet", 2, Vec::new()).is_err(), "empty image");
        // the coordinator survives the rejection
        assert!(h.infer(3, vec![0.25; IMAGE_ELEMS]).is_ok());
        c.shutdown().unwrap();
    }

    #[test]
    fn registry_budget_evicts_and_reloads_across_models() {
        // a 1-byte budget can hold only one model at a time: startup keeps
        // the last registered, and each cross-model request re-prepares on
        // the miss, evicting the other — serving still works throughout
        let cfg = Config {
            registry: vec![ModelSpec::new("LeNet-5", 2, 8), ModelSpec::new("ConvNet", 3, 8)],
            registry_budget_bytes: 1,
            ..engine_cfg()
        };
        let c = Coordinator::start(cfg).unwrap();
        let h = c.handle();
        let mut rng = Rng::new(12);
        let img: Vec<f32> = (0..IMAGE_ELEMS).map(|_| rng.f32()).collect();
        let a = h.infer_to("LeNet-5", 1, img.clone()).unwrap();
        let b = h.infer_to("ConvNet", 2, img.clone()).unwrap();
        let a2 = h.infer_to("LeNet-5", 3, img).unwrap();
        assert_eq!(a.logits.len(), NUM_CLASSES);
        assert_eq!(b.logits.len(), NUM_CLASSES);
        assert_eq!(a.logits, a2.logits, "re-prepared model must serve identically");
        let m = c.metrics();
        assert!(m.evictions >= 2, "evictions={}", m.evictions);
        assert_eq!(m.model("LeNet-5").unwrap().requests, 2);
        assert_eq!(m.model("ConvNet").unwrap().requests, 1);
        c.shutdown().unwrap();
    }

    #[test]
    fn engine_config_validation_fails_fast() {
        let e = Coordinator::start(Config { registry: Vec::new(), ..engine_cfg() })
            .err()
            .expect("empty registry must be rejected");
        assert!(e.to_string().contains("registry"), "{e}");
        let e = Coordinator::start(Config { registry_budget_bytes: 0, ..engine_cfg() })
            .err()
            .expect("zero budget must be rejected");
        assert!(e.to_string().contains("budget"), "{e}");
        let e = Coordinator::start(Config {
            registry: vec![ModelSpec::new("NoSuchNet", 3, 8)],
            ..engine_cfg()
        })
        .err()
        .expect("unknown model must be rejected");
        assert!(e.to_string().contains("unknown model"), "{e}");
        assert!(Coordinator::start(Config {
            registry: vec![ModelSpec::new("ConvNet", 9, 8)],
            ..engine_cfg()
        })
        .is_err());
        assert!(Coordinator::start(Config {
            registry: vec![ModelSpec::new("ConvNet", 3, 8), ModelSpec::new("ConvNet", 2, 8)],
            ..engine_cfg()
        })
        .is_err());
        assert!(Coordinator::start(Config { batch_sizes: Vec::new(), ..engine_cfg() }).is_err());
        // the XLA path skips registry validation entirely
        assert!(Config { registry: Vec::new(), use_xla: true, ..Config::default() }
            .validate()
            .is_ok());
    }

    #[test]
    fn persisted_registry_restart_serves_identically() {
        let dir = std::env::temp_dir().join(format!("ssta-persist-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = Config { persist_dir: Some(dir.clone()), ..engine_cfg() };
        let mut rng = Rng::new(13);
        let img: Vec<f32> = (0..IMAGE_ELEMS).map(|_| rng.f32()).collect();
        // first start prepares and persists
        let c = Coordinator::start(cfg.clone()).unwrap();
        let first = c.handle().infer(1, img.clone()).unwrap();
        c.shutdown().unwrap();
        let artifact = dir.join(persist_file(&cfg.registry[0]));
        assert!(artifact.exists(), "prepared model must be persisted");
        // second start loads the flat binary (no re-prepare) and must serve
        // bit-identically
        let c = Coordinator::start(cfg).unwrap();
        let second = c.handle().infer(2, img).unwrap();
        assert_eq!(first.logits, second.logits, "load-vs-prepare must be bit-exact");
        c.shutdown().unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn measured_twin_consumes_per_layer_sparsities() {
        // the startup path's twin: one PreparedModel, profiled once
        let mut pm = crate::engine::PreparedModel::prepare(
            &crate::models::convnet5(),
            4,
            8,
            TWIN_SEED,
            Parallelism::serial(),
        );
        pm.set_act_policy(Config::default().act_policy);
        let measured = pm.profile(Parallelism::serial());
        // one sparsity source: the values the twin prices are the values
        // the engine's ZeroGate::Auto consults
        let engine_side = pm.measured_act_sparsity().expect("profile ran");
        for (p, &s) in measured.iter().zip(engine_side) {
            assert_eq!(p.act_sparsity.to_bits(), s.to_bits(), "{}", p.name);
        }
        let spread: Vec<f64> = measured.iter().map(|p| p.act_sparsity).collect();
        let min = spread.iter().cloned().fold(f64::MAX, f64::min);
        let max = spread.iter().cloned().fold(f64::MIN, f64::max);
        assert!(max > min, "measured sparsity must vary per layer: {spread:?}");
        let twin = Twin::from_profiles(Design::paper_optimal(), measured, Parallelism::serial());
        let (c, e, m) = twin.simulate(4);
        assert!(c > 0 && e > 0.0 && m > 0);
    }

    #[test]
    fn twin_cycles_scale_with_batch() {
        let twin = Twin::new(Design::paper_optimal(), 4, 0.5, Parallelism::auto());
        let (c1, e1, m1) = twin.simulate(1);
        let (c8, e8, m8) = twin.simulate(8);
        assert_eq!(m8, 8 * m1);
        assert!(c8 > 4 * c1, "batch-8 should cost much more than batch-1: {c1} vs {c8}");
        assert!(c8 < 9 * c1, "but less than 9x (better utilization)");
        assert!(e8 > e1);
    }
}
