//! Layer-3 inference coordinator: the serving loop in front of the
//! accelerator.
//!
//! The leader thread owns the PJRT [`crate::runtime::Runtime`] (thread-
//! affine) and runs the event loop: drain the request channel, let the
//! [`batcher::BatchPolicy`] decide when to flush, execute the AOT model
//! executable for each planned chunk (batch folded into GEMM `M`, exactly
//! like the hardware folds it into array rows), split the logits back to
//! the callers and account metrics.
//!
//! Every executed batch is *also* run through the architecture simulator as
//! a **hardware twin** — the same layer profile the power model consumes —
//! so the serving path reports both measured XLA latency and the simulated
//! accelerator cycles/energy the paper's tables are built from. The twin is
//! the timing path; XLA is the functional path. Python appears in neither.

pub mod batcher;
pub mod metrics;
pub mod request;

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::arch::Design;
use crate::gemm::ActPolicy;
use crate::power;
use crate::runtime::{HostTensor, Runtime};
use crate::sim::accel::{network_timing_with, profile_model_fixed_act, LayerProfile};
use crate::util::error::{anyhow, bail, Context, Result};
use crate::util::Parallelism;
use batcher::BatchPolicy;
use metrics::Metrics;
use request::{InferRequest, InferResponse};

const IMAGE_ELEMS: usize = 32 * 32 * 3;
const NUM_CLASSES: usize = 10;

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct Config {
    /// Artifact directory (`make artifacts` output).
    pub artifacts_dir: std::path::PathBuf,
    /// Hardware-twin design point for the timing path.
    pub design: Design,
    /// Activation sparsity the twin *assumes* when no functional profile is
    /// available (`measured_sparsity: false`); 0.5 is the paper's typical
    /// operating point. Must lie in `[0, 1]` — validated at
    /// [`Coordinator::start`]. With `measured_sparsity: true` (the
    /// default) the twin instead consumes the per-layer sparsities measured
    /// by the prepared model's functional profile.
    pub act_sparsity: f64,
    /// Batch flush timeout. Must be non-zero — validated at
    /// [`Coordinator::start`] (a zero timeout degenerates every queue
    /// check into an immediate flush, serving nothing but batch-1).
    pub max_wait: Duration,
    /// Worker-pool width for the hardware twin's per-layer timing on the
    /// batched execution path. Defaults to `Parallelism::serial()`: the
    /// served convnet5 twin has 5 µs-scale layers per batch, so pool setup
    /// would cost more latency than it saves. Set `Parallelism::auto()` /
    /// `threads(n)` when serving deeper models.
    pub parallelism: Parallelism,
    /// Build one [`crate::engine::PreparedModel`] of the served network at
    /// startup, run its seeded functional profile once, and feed the twin
    /// *measured* per-layer activation sparsities instead of the
    /// `act_sparsity` scalar. Default `true`.
    pub measured_sparsity: bool,
    /// Three-way activation policy (off / gate / encode) installed on the
    /// prepared model (its functional profile/execute passes). Default
    /// [`ActPolicy::Auto`]: after the startup profile, the engine resolves
    /// the policy per layer from the *same* measured per-layer sparsities
    /// the twin prices — one sparsity source — and the twin prices the
    /// resulting A-side decision too (layers the policy encodes stream
    /// compressed activation traffic in the simulated SRAM counters,
    /// `LayerProfile::act_encoded`). Every policy is bit-exact, so this
    /// knob never changes a served or profiled number, only the simulated
    /// traffic/energy and the engine's own execute cost.
    pub act_policy: ActPolicy,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            artifacts_dir: "artifacts".into(),
            design: Design::paper_optimal(),
            act_sparsity: 0.5,
            max_wait: Duration::from_millis(2),
            parallelism: Parallelism::serial(),
            measured_sparsity: true,
            act_policy: ActPolicy::default(),
        }
    }
}

impl Config {
    /// Reject configurations that today would be silently accepted and
    /// misbehave at runtime.
    fn validate(&self) -> Result<()> {
        if !(0.0..=1.0).contains(&self.act_sparsity) {
            bail!(
                "coordinator config: act_sparsity must be a fraction in [0, 1], got {}",
                self.act_sparsity
            );
        }
        if self.max_wait == Duration::ZERO {
            bail!(
                "coordinator config: max_wait must be non-zero (a zero batch window \
                 flushes every request alone and defeats batching)"
            );
        }
        Ok(())
    }
}

enum Msg {
    Infer(InferRequest),
    Shutdown,
}

/// Handle to a running coordinator. Cloneable; submit requests from any
/// thread.
#[derive(Clone)]
pub struct Handle {
    tx: mpsc::Sender<Msg>,
    metrics: Arc<Mutex<Metrics>>,
}

/// A running coordinator (joined by [`Coordinator::shutdown`] or drop).
pub struct Coordinator {
    handle: Handle,
    worker: Option<JoinHandle<Result<()>>>,
}

impl Coordinator {
    /// Start the leader thread; compiles the model executables and prepares
    /// the hardware twin's model up front so the first request pays neither
    /// compile nor weight-encode latency. Fails fast on an invalid
    /// [`Config`].
    pub fn start(cfg: Config) -> Result<Coordinator> {
        cfg.validate()?;
        let (tx, rx) = mpsc::channel::<Msg>();
        let metrics = Arc::new(Mutex::new(Metrics::default()));
        let metrics2 = metrics.clone();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let worker = std::thread::Builder::new()
            .name("ssta-coordinator".into())
            .spawn(move || leader_loop(cfg, rx, metrics2, ready_tx))
            .context("spawning coordinator thread")?;
        // wait for the runtime to come up (or fail fast)
        ready_rx
            .recv()
            .map_err(|_| anyhow!("coordinator thread died during startup"))??;
        Ok(Coordinator {
            handle: Handle { tx, metrics },
            worker: Some(worker),
        })
    }

    /// Cloneable submission handle.
    pub fn handle(&self) -> Handle {
        self.handle.clone()
    }

    /// Metrics snapshot.
    pub fn metrics(&self) -> Metrics {
        self.handle.metrics.lock().unwrap().clone()
    }

    /// Stop the leader loop and join the thread.
    pub fn shutdown(mut self) -> Result<()> {
        let _ = self.handle.tx.send(Msg::Shutdown);
        if let Some(w) = self.worker.take() {
            w.join().map_err(|_| anyhow!("coordinator thread panicked"))??;
        }
        Ok(())
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.handle.tx.send(Msg::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Handle {
    /// Submit one image; returns the receiver for the response.
    pub fn submit(&self, id: u64, image: Vec<f32>) -> Result<mpsc::Receiver<InferResponse>> {
        if image.len() != IMAGE_ELEMS {
            bail!("image must have {IMAGE_ELEMS} elements, got {}", image.len());
        }
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Msg::Infer(InferRequest {
                id,
                image,
                enqueued: Instant::now(),
                reply,
            }))
            .map_err(|_| anyhow!("coordinator is down"))?;
        Ok(rx)
    }

    /// Submit and block for the response.
    pub fn infer(&self, id: u64, image: Vec<f32>) -> Result<InferResponse> {
        let rx = self.submit(id, image)?;
        rx.recv().map_err(|_| anyhow!("coordinator dropped the request"))
    }

    /// Metrics snapshot.
    pub fn metrics(&self) -> Metrics {
        self.metrics.lock().unwrap().clone()
    }
}

/// Seed for the twin's prepared-model functional profile (fixed so the
/// measured per-layer sparsities are reproducible across restarts).
const TWIN_SEED: u64 = 42;

/// The hardware twin: layer profiles of the served model on the configured
/// design, scaled per executed batch.
struct Twin {
    design: Design,
    profiles_b1: Vec<LayerProfile>,
    par: Parallelism,
}

impl Twin {
    /// Twin with an *assumed* uniform activation sparsity (the
    /// `measured_sparsity: false` path and the Fig-12-style sweeps).
    fn new(design: Design, nnz: usize, act_sparsity: f64, par: Parallelism) -> Twin {
        let model = crate::models::convnet5();
        Twin {
            design,
            profiles_b1: profile_model_fixed_act(&model, nnz, 8, act_sparsity),
            par,
        }
    }

    /// Twin consuming an existing per-layer profile — the coordinator hands
    /// it the *measured* sparsities of the prepared model's functional
    /// profile, so the simulated cycles/energy reflect the layer-by-layer
    /// sparsity variation instead of one assumed scalar.
    fn from_profiles(design: Design, profiles_b1: Vec<LayerProfile>, par: Parallelism) -> Twin {
        Twin {
            design,
            profiles_b1,
            par,
        }
    }

    /// Simulated (cycles, energy mJ, dense MACs) for one executed batch.
    fn simulate(&self, batch: usize) -> (u64, f64, u64) {
        let profiles: Vec<LayerProfile> = self
            .profiles_b1
            .iter()
            .map(|p| {
                let mut p = p.clone();
                p.m *= batch; // batch folds into GEMM M
                p.out_elems *= batch as u64;
                p
            })
            .collect();
        let t = network_timing_with(&self.design, &profiles, self.par);
        let pw = power::power(&self.design, &t.total);
        let secs = t.total.cycles as f64 / self.design.tech.freq_hz();
        let energy_mj = pw.total_mw() * secs; // mW · s = mJ
        (t.total.cycles, energy_mj, t.dense_macs)
    }
}

fn leader_loop(
    cfg: Config,
    rx: mpsc::Receiver<Msg>,
    metrics: Arc<Mutex<Metrics>>,
    ready: mpsc::Sender<Result<()>>,
) -> Result<()> {
    // ---- startup: open runtime, discover model executables ----
    let startup = (|| -> Result<(Runtime, Vec<usize>, usize)> {
        let mut rt = Runtime::open(&cfg.artifacts_dir)?;
        let names: Vec<String> = rt.artifact_names().iter().map(|s| s.to_string()).collect();
        let mut sizes = Vec::new();
        let mut nnz = 8usize;
        for name in names {
            if let Some(rest) = name.strip_prefix("convnet5_b") {
                if let Ok(b) = rest.parse::<usize>() {
                    sizes.push(b);
                    if let Some(m) = rt.meta(&name) {
                        if let Some(v) = m.raw.get("nnz").and_then(|j| j.as_usize()) {
                            nnz = v;
                        }
                    }
                }
            }
        }
        if sizes.is_empty() {
            bail!("no convnet5_b* artifacts found — run `make artifacts`");
        }
        // pre-compile all batch variants
        for &b in &sizes {
            rt.load(&format!("convnet5_b{b}"))?;
        }
        Ok((rt, sizes, nnz))
    })();
    let (mut rt, sizes, nnz) = match startup {
        Ok(v) => {
            let _ = ready.send(Ok(()));
            v
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return Ok(());
        }
    };
    let policy = BatchPolicy::new(sizes, cfg.max_wait);
    // Prepare-once at startup: the served model is lowered into a
    // PreparedModel (the one-time weight encode/pack) and functionally
    // profiled exactly once; the twin consumes that profile's measured
    // per-layer activation sparsities (paper Fig. 11) for every batch it
    // simulates. Per-batch *functional* execution stays on the XLA
    // runtime — only the profile outlives this block.
    let twin = if cfg.measured_sparsity {
        let model = crate::models::convnet5();
        let mut prepared =
            crate::engine::PreparedModel::prepare(&model, nnz, 8, TWIN_SEED, cfg.parallelism);
        prepared.set_act_policy(cfg.act_policy);
        let profiles = prepared.profile(cfg.parallelism);
        Twin::from_profiles(cfg.design, profiles, cfg.parallelism)
    } else {
        Twin::new(cfg.design, nnz, cfg.act_sparsity, cfg.parallelism)
    };
    let mut queue: Vec<InferRequest> = Vec::new();

    loop {
        // ---- wait for work ----
        let msg = if queue.is_empty() {
            match rx.recv() {
                Ok(m) => Some(m),
                Err(_) => return Ok(()), // all senders gone
            }
        } else {
            let oldest = queue[0].enqueued.elapsed();
            let budget = cfg.max_wait.saturating_sub(oldest);
            match rx.recv_timeout(budget) {
                Ok(m) => Some(m),
                Err(mpsc::RecvTimeoutError::Timeout) => None,
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    flush(&mut rt, &policy, &twin, &mut queue, &metrics)?;
                    return Ok(());
                }
            }
        };
        match msg {
            Some(Msg::Infer(r)) => {
                queue.push(r);
                // greedily drain whatever is already queued in the channel
                // (arrivals during the previous flush) up to a full batch —
                // otherwise a backlog degrades into size-1 flushes
                while queue.len() < policy.max_batch() {
                    match rx.try_recv() {
                        Ok(Msg::Infer(r)) => queue.push(r),
                        Ok(Msg::Shutdown) => {
                            flush(&mut rt, &policy, &twin, &mut queue, &metrics)?;
                            return Ok(());
                        }
                        Err(_) => break,
                    }
                }
            }
            Some(Msg::Shutdown) => {
                flush(&mut rt, &policy, &twin, &mut queue, &metrics)?;
                return Ok(());
            }
            None => {} // timeout → fall through to flush check
        }
        let oldest = queue.first().map(|r| r.enqueued.elapsed()).unwrap_or_default();
        if policy.should_flush(queue.len(), oldest) {
            flush(&mut rt, &policy, &twin, &mut queue, &metrics)?;
        }
    }
}

/// Execute everything in the queue according to the batch plan.
fn flush(
    rt: &mut Runtime,
    policy: &BatchPolicy,
    twin: &Twin,
    queue: &mut Vec<InferRequest>,
    metrics: &Arc<Mutex<Metrics>>,
) -> Result<()> {
    if queue.is_empty() {
        return Ok(());
    }
    let plan = policy.plan(queue.len());
    let mut reqs = std::mem::take(queue).into_iter();
    for (compiled, real) in plan {
        let chunk: Vec<InferRequest> = reqs.by_ref().take(real).collect();
        debug_assert_eq!(chunk.len(), real);

        // pack the batch (padding rows stay zero)
        let mut batch = vec![0f32; compiled * IMAGE_ELEMS];
        for (i, r) in chunk.iter().enumerate() {
            batch[i * IMAGE_ELEMS..(i + 1) * IMAGE_ELEMS].copy_from_slice(&r.image);
        }

        let exe = rt.load(&format!("convnet5_b{compiled}"))?;
        let t0 = Instant::now();
        let outs = exe.run(&[HostTensor::F32(batch)])?;
        let exec = t0.elapsed();
        let logits_all = outs[0].as_f32();

        let (sim_cycles, sim_energy_mj, dense_macs) = twin.simulate(compiled);
        {
            let mut m = metrics.lock().unwrap();
            m.record_batch(real, compiled, exec, sim_cycles, sim_energy_mj, dense_macs);
        }

        for (i, r) in chunk.into_iter().enumerate() {
            let logits = logits_all[i * NUM_CLASSES..(i + 1) * NUM_CLASSES].to_vec();
            let queue_us = (t0 - r.enqueued).as_micros() as u64;
            let resp = InferResponse {
                id: r.id,
                logits,
                batch_size: compiled,
                queue_us,
                execute_us: exec.as_micros() as u64,
                sim_cycles,
                sim_energy_mj,
            };
            metrics.lock().unwrap().record_latency(r.enqueued.elapsed());
            let _ = r.reply.send(resp); // caller may have gone away — fine
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn artifacts_ready() -> bool {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/manifest.json")
            .exists()
    }

    fn test_cfg() -> Config {
        Config {
            artifacts_dir: std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
            ..Config::default()
        }
    }

    #[test]
    fn serves_single_request() {
        if !artifacts_ready() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let c = Coordinator::start(test_cfg()).unwrap();
        let mut rng = Rng::new(1);
        let img: Vec<f32> = (0..IMAGE_ELEMS).map(|_| rng.f32()).collect();
        let resp = c.handle().infer(42, img).unwrap();
        assert_eq!(resp.id, 42);
        assert_eq!(resp.logits.len(), NUM_CLASSES);
        assert!(resp.logits.iter().all(|v| v.is_finite()));
        assert!(resp.sim_cycles > 0);
        c.shutdown().unwrap();
    }

    #[test]
    fn batches_concurrent_requests_and_matches_single() {
        if !artifacts_ready() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let c = Coordinator::start(test_cfg()).unwrap();
        let h = c.handle();
        let mut rng = Rng::new(2);
        let imgs: Vec<Vec<f32>> =
            (0..12).map(|_| (0..IMAGE_ELEMS).map(|_| rng.f32()).collect()).collect();

        // singles first (reference answers)
        let singles: Vec<Vec<f32>> = imgs
            .iter()
            .enumerate()
            .map(|(i, im)| h.infer(i as u64, im.clone()).unwrap().logits)
            .collect();

        // now fire concurrently → should batch
        let rxs: Vec<_> = imgs
            .iter()
            .enumerate()
            .map(|(i, im)| h.submit(100 + i as u64, im.clone()).unwrap())
            .collect();
        let batched: Vec<InferResponse> = rxs.into_iter().map(|rx| rx.recv().unwrap()).collect();

        for (i, resp) in batched.iter().enumerate() {
            assert_eq!(resp.id, 100 + i as u64);
            // batching must not change the numbers (row independence)
            for (a, b) in resp.logits.iter().zip(&singles[i]) {
                assert!((a - b).abs() < 1e-4, "req {i}: batched {a} vs single {b}");
            }
        }
        // at least one multi-request batch formed
        let m = c.metrics();
        assert!(m.batches < m.requests, "no batching happened: {}", m.summary());
        c.shutdown().unwrap();
    }

    #[test]
    fn rejects_bad_image_size() {
        if !artifacts_ready() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let c = Coordinator::start(test_cfg()).unwrap();
        assert!(c.handle().submit(0, vec![0.0; 7]).is_err());
        c.shutdown().unwrap();
    }

    #[test]
    fn metrics_accumulate() {
        if !artifacts_ready() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let c = Coordinator::start(test_cfg()).unwrap();
        let h = c.handle();
        let mut rng = Rng::new(3);
        for i in 0..5 {
            let img: Vec<f32> = (0..IMAGE_ELEMS).map(|_| rng.f32()).collect();
            h.infer(i, img).unwrap();
        }
        let m = c.metrics();
        assert_eq!(m.requests, 5);
        assert!(m.sim_cycles > 0);
        assert!(m.sim_energy_mj > 0.0);
        assert!(m.sim_effective_tops(1e9) > 0.0);
        c.shutdown().unwrap();
    }

    #[test]
    fn rejects_invalid_config_before_startup() {
        // validation fires before the runtime opens, so no artifacts needed
        let e = Coordinator::start(Config { act_sparsity: 1.5, ..Config::default() })
            .err()
            .expect("act_sparsity > 1 must be rejected");
        assert!(e.to_string().contains("act_sparsity"), "{e}");
        assert!(Coordinator::start(Config { act_sparsity: -0.1, ..Config::default() }).is_err());
        assert!(
            Coordinator::start(Config { act_sparsity: f64::NAN, ..Config::default() }).is_err()
        );
        let e = Coordinator::start(Config { max_wait: Duration::ZERO, ..Config::default() })
            .err()
            .expect("zero max_wait must be rejected");
        assert!(e.to_string().contains("max_wait"), "{e}");
    }

    #[test]
    fn measured_twin_consumes_per_layer_sparsities() {
        // the startup path's twin: one PreparedModel, profiled once
        let mut pm = crate::engine::PreparedModel::prepare(
            &crate::models::convnet5(),
            4,
            8,
            TWIN_SEED,
            Parallelism::serial(),
        );
        pm.set_act_policy(Config::default().act_policy);
        let measured = pm.profile(Parallelism::serial());
        // one sparsity source: the values the twin prices are the values
        // the engine's ZeroGate::Auto consults
        let engine_side = pm.measured_act_sparsity().expect("profile ran");
        for (p, &s) in measured.iter().zip(engine_side) {
            assert_eq!(p.act_sparsity.to_bits(), s.to_bits(), "{}", p.name);
        }
        let spread: Vec<f64> = measured.iter().map(|p| p.act_sparsity).collect();
        let min = spread.iter().cloned().fold(f64::MAX, f64::min);
        let max = spread.iter().cloned().fold(f64::MIN, f64::max);
        assert!(max > min, "measured sparsity must vary per layer: {spread:?}");
        let twin = Twin::from_profiles(Design::paper_optimal(), measured, Parallelism::serial());
        let (c, e, m) = twin.simulate(4);
        assert!(c > 0 && e > 0.0 && m > 0);
    }

    #[test]
    fn twin_cycles_scale_with_batch() {
        let twin = Twin::new(Design::paper_optimal(), 4, 0.5, Parallelism::auto());
        let (c1, e1, m1) = twin.simulate(1);
        let (c8, e8, m8) = twin.simulate(8);
        assert_eq!(m8, 8 * m1);
        assert!(c8 > 4 * c1, "batch-8 should cost much more than batch-1: {c1} vs {c8}");
        assert!(c8 < 9 * c1, "but less than 9x (better utilization)");
        assert!(e8 > e1);
    }
}
