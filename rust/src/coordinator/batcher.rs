//! Dynamic batching policy.
//!
//! The accelerator's GEMM datapath folds the batch into the GEMM `M`
//! dimension (Layer-2 does exactly this), so batching multiplies array
//! utilization for free until the activation buffer bound. The AOT model
//! is compiled for a fixed set of batch sizes (`convnet5_b1`, `convnet5_b8`
//! — one executable per shape, there is no dynamic-shape PJRT path), so the
//! batcher's job is:
//!
//! 1. accumulate requests until the largest compiled batch fills, or the
//!    oldest request has waited `max_wait`;
//! 2. split the pending queue into chunks of compiled sizes, padding the
//!    final chunk up to the smallest compiled size that fits (padded rows
//!    are zero images whose outputs are dropped).

use std::time::Duration;

/// Batching policy configuration.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    /// Batch sizes with a compiled executable, ascending (e.g. `[1, 8]`).
    pub sizes: Vec<usize>,
    /// Max time the oldest request may wait before a forced flush.
    pub max_wait: Duration,
}

impl BatchPolicy {
    /// New policy; `sizes` must be non-empty and is sorted ascending.
    pub fn new(mut sizes: Vec<usize>, max_wait: Duration) -> Self {
        assert!(!sizes.is_empty(), "need at least one compiled batch size");
        sizes.sort_unstable();
        sizes.dedup();
        BatchPolicy { sizes, max_wait }
    }

    /// Largest compiled size.
    pub fn max_batch(&self) -> usize {
        *self.sizes.last().unwrap()
    }

    /// Should the queue flush now? (full batch ready, or timeout expired
    /// with anything pending)
    pub fn should_flush(&self, pending: usize, oldest_wait: Duration) -> bool {
        pending >= self.max_batch() || (pending > 0 && oldest_wait >= self.max_wait)
    }

    /// Plan the execution chunks for `pending` requests: returns
    /// `(compiled_size, real_rows)` pairs covering all requests, preferring
    /// large chunks, padding only the tail chunk.
    ///
    /// Invariants (property-tested): Σ real_rows == pending;
    /// real_rows ≤ compiled_size; every compiled_size ∈ sizes.
    pub fn plan(&self, pending: usize) -> Vec<(usize, usize)> {
        let mut chunks = Vec::new();
        let mut left = pending;
        let max = self.max_batch();
        while left >= max {
            chunks.push((max, max));
            left -= max;
        }
        if left > 0 {
            // smallest compiled size that fits the remainder in one chunk,
            // else several of the largest-fitting sizes
            match self.sizes.iter().find(|&&s| s >= left) {
                Some(&s) => chunks.push((s, left)),
                None => unreachable!("max chunk loop guarantees left < max"),
            }
        }
        chunks
    }
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy::new(vec![1, 8], Duration::from_millis(2))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Config};

    #[test]
    fn flush_on_full_batch() {
        let p = BatchPolicy::new(vec![1, 8], Duration::from_millis(5));
        assert!(p.should_flush(8, Duration::ZERO));
        assert!(p.should_flush(9, Duration::ZERO));
        assert!(!p.should_flush(7, Duration::ZERO));
    }

    #[test]
    fn flush_on_timeout() {
        let p = BatchPolicy::new(vec![1, 8], Duration::from_millis(5));
        assert!(p.should_flush(1, Duration::from_millis(5)));
        assert!(!p.should_flush(0, Duration::from_secs(1)));
    }

    #[test]
    fn plan_prefers_big_chunks() {
        let p = BatchPolicy::new(vec![1, 8], Duration::ZERO);
        assert_eq!(p.plan(20), vec![(8, 8), (8, 8), (8, 4)]);
        assert_eq!(p.plan(8), vec![(8, 8)]);
        assert_eq!(p.plan(1), vec![(1, 1)]);
        assert_eq!(p.plan(3), vec![(8, 3)]); // padded tail
    }

    #[test]
    fn plan_exact_small_size() {
        let p = BatchPolicy::new(vec![1, 4, 8], Duration::ZERO);
        assert_eq!(p.plan(4), vec![(4, 4)]);
        assert_eq!(p.plan(5), vec![(8, 5)]);
    }

    #[test]
    fn prop_plan_covers_exactly() {
        check(Config::default().cases(200), |rng| {
            let mut sizes: Vec<usize> = (0..rng.below(3) + 1).map(|_| 1 << rng.below(5)).collect();
            sizes.push(1); // always include 1 so everything is coverable
            let p = BatchPolicy::new(sizes, Duration::ZERO);
            let pending = rng.below(100);
            let plan = p.plan(pending);
            let total: usize = plan.iter().map(|(_, r)| r).sum();
            assert_eq!(total, pending);
            for (s, r) in &plan {
                assert!(p.sizes.contains(s));
                assert!(*r <= *s && *r > 0 || pending == 0);
            }
        });
    }

    #[test]
    fn prop_padding_only_in_tail() {
        check(Config::default().cases(100), |rng| {
            let p = BatchPolicy::new(vec![1, 8], Duration::ZERO);
            let pending = rng.below(64) + 1;
            let plan = p.plan(pending);
            for (i, (s, r)) in plan.iter().enumerate() {
                if i + 1 < plan.len() {
                    assert_eq!(s, r, "only the tail chunk may pad");
                }
            }
        });
    }
}
