//! Request/response types for the inference coordinator.

use std::sync::mpsc;
use std::time::Instant;

/// One inference request: a flattened f32 image in `[0,1]`, routed to a
/// registered model by name.
#[derive(Debug)]
pub struct InferRequest {
    /// Caller-assigned id (echoed in the response).
    pub id: u64,
    /// Registry name of the model to serve this request
    /// ([`crate::coordinator::registry::ModelSpec::model`]); the legacy
    /// single-model XLA path ignores it.
    pub model: String,
    /// Flattened image (`32*32*3` floats on the default route; other
    /// lengths are wrap-fitted by the engine path).
    pub image: Vec<f32>,
    /// Enqueue timestamp (set by the handle).
    pub enqueued: Instant,
    /// Response channel.
    pub reply: mpsc::Sender<InferResponse>,
}

/// Response to one request.
#[derive(Debug, Clone)]
pub struct InferResponse {
    /// Echoed request id.
    pub id: u64,
    /// Class logits (10 classes).
    pub logits: Vec<f32>,
    /// Batch this request was served in.
    pub batch_size: usize,
    /// Queue wait in microseconds.
    pub queue_us: u64,
    /// XLA execute time for the whole batch, microseconds.
    pub execute_us: u64,
    /// Simulated accelerator cycles for this batch on the hardware twin.
    pub sim_cycles: u64,
    /// Simulated accelerator energy for this batch (millijoules).
    pub sim_energy_mj: f64,
}

/// Argmax helper for callers that want a class id.
pub fn argmax(logits: &[f32]) -> usize {
    logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basics() {
        assert_eq!(argmax(&[1.0, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[-5.0, -1.0]), 1);
        assert_eq!(argmax(&[]), 0);
    }
}
