//! Coordinator metrics: request/batch counters, latency distribution and
//! the hardware twin's aggregate (cycles, energy, effective TOPS).

use std::time::Duration;

use crate::util::stats;

/// Aggregated serving metrics (snapshot-able).
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    /// Requests completed.
    pub requests: u64,
    /// Batches executed.
    pub batches: u64,
    /// Rows executed including padding.
    pub padded_rows: u64,
    /// Per-request end-to-end latency samples (µs).
    pub latency_us: Vec<u64>,
    /// Per-batch XLA execute time samples (µs).
    pub execute_us: Vec<u64>,
    /// Simulated accelerator cycles over all batches.
    pub sim_cycles: u64,
    /// Simulated accelerator energy over all batches (mJ).
    pub sim_energy_mj: f64,
    /// Dense-equivalent MACs served (for effective-TOPS accounting).
    pub dense_macs: u64,
}

impl Metrics {
    /// Record one completed batch.
    pub fn record_batch(
        &mut self,
        real_rows: usize,
        compiled_rows: usize,
        execute: Duration,
        sim_cycles: u64,
        sim_energy_mj: f64,
        dense_macs: u64,
    ) {
        self.batches += 1;
        self.requests += real_rows as u64;
        self.padded_rows += (compiled_rows - real_rows) as u64;
        self.execute_us.push(execute.as_micros() as u64);
        self.sim_cycles += sim_cycles;
        self.sim_energy_mj += sim_energy_mj;
        self.dense_macs += dense_macs;
    }

    /// Record one request's end-to-end latency.
    pub fn record_latency(&mut self, l: Duration) {
        self.latency_us.push(l.as_micros() as u64);
    }

    /// Mean batch occupancy (real rows per executed row).
    pub fn occupancy(&self) -> f64 {
        let total = self.requests + self.padded_rows;
        if total == 0 {
            return 0.0;
        }
        self.requests as f64 / total as f64
    }

    /// Latency percentile in µs.
    pub fn latency_pct(&self, p: f64) -> u64 {
        let v: Vec<f64> = self.latency_us.iter().map(|&x| x as f64).collect();
        if v.is_empty() {
            return 0;
        }
        stats::percentile(&v, p) as u64
    }

    /// Simulated effective TOPS of the hardware twin at `freq_hz`.
    pub fn sim_effective_tops(&self, freq_hz: f64) -> f64 {
        if self.sim_cycles == 0 {
            return 0.0;
        }
        let secs = self.sim_cycles as f64 / freq_hz;
        2.0 * self.dense_macs as f64 / secs / 1e12
    }

    /// Simulated average power of the twin (W) at `freq_hz`.
    pub fn sim_avg_power_w(&self, freq_hz: f64) -> f64 {
        if self.sim_cycles == 0 {
            return 0.0;
        }
        let secs = self.sim_cycles as f64 / freq_hz;
        self.sim_energy_mj / 1e3 / secs
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "requests={} batches={} occupancy={:.2} p50={}us p95={}us sim_cycles={} \
             sim_energy={:.2}mJ",
            self.requests,
            self.batches,
            self.occupancy(),
            self.latency_pct(50.0),
            self.latency_pct(95.0),
            self.sim_cycles,
            self.sim_energy_mj,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn occupancy_counts_padding() {
        let mut m = Metrics::default();
        m.record_batch(3, 8, Duration::from_micros(100), 1000, 0.5, 1_000_000);
        assert!((m.occupancy() - 3.0 / 8.0).abs() < 1e-12);
        m.record_batch(8, 8, Duration::from_micros(100), 1000, 0.5, 1_000_000);
        assert!((m.occupancy() - 11.0 / 16.0).abs() < 1e-12);
    }

    #[test]
    fn effective_tops_accounting() {
        let mut m = Metrics::default();
        // 1e9 dense MACs in 1e6 cycles at 1 GHz = 1 ms → 2e9*1e3 ops/s = 2 TOPS
        m.record_batch(8, 8, Duration::from_micros(10), 1_000_000, 1.0, 1_000_000_000);
        assert!((m.sim_effective_tops(1e9) - 2.0).abs() < 1e-9);
        // 1 mJ over 1 ms = 1 W
        assert!((m.sim_avg_power_w(1e9) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn latency_percentiles() {
        let mut m = Metrics::default();
        for i in 1..=100u64 {
            m.record_latency(Duration::from_micros(i));
        }
        assert!(m.latency_pct(50.0) >= 49 && m.latency_pct(50.0) <= 51);
        assert!(m.latency_pct(95.0) >= 94);
    }

    #[test]
    fn empty_metrics_are_zero() {
        let m = Metrics::default();
        assert_eq!(m.occupancy(), 0.0);
        assert_eq!(m.latency_pct(50.0), 0);
        assert_eq!(m.sim_effective_tops(1e9), 0.0);
    }
}
