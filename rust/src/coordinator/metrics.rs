//! Coordinator metrics: request/batch counters, latency percentiles over a
//! fixed-size sample reservoir, and the hardware twin's aggregate (cycles,
//! energy, effective TOPS). With the engine-native registry path serving
//! several models from one process, every counter and reservoir is *also*
//! split per model ([`Metrics::per_model`]) so each model's SLO percentiles
//! and twin numbers are separable from the aggregate.

use std::collections::BTreeMap;
use std::time::Duration;

use crate::util::{stats, Rng};

/// Samples held by a [`Reservoir`] — enough for stable p99 estimates while
/// keeping a long-running coordinator's memory bounded.
const RESERVOIR_CAP: usize = 1024;

/// Fixed-size uniform sample reservoir (Vitter's Algorithm R with the
/// in-tree deterministic [`Rng`]): the first `RESERVOIR_CAP` (1024) values
/// are kept verbatim; afterwards the `i`-th value replaces a random held
/// sample with probability `cap / i`, so every value seen has equal
/// probability of being in the sample. Memory stays O(cap) no matter how
/// many requests a serving process handles.
#[derive(Debug, Clone)]
pub struct Reservoir {
    samples: Vec<u64>,
    seen: u64,
    rng: Rng,
}

impl Default for Reservoir {
    fn default() -> Self {
        Reservoir {
            samples: Vec::new(),
            seen: 0,
            rng: Rng::new(0x5eed_5a3b),
        }
    }
}

impl Reservoir {
    /// Offer one value to the reservoir.
    pub fn push(&mut self, v: u64) {
        self.seen += 1;
        if self.samples.len() < RESERVOIR_CAP {
            self.samples.push(v);
        } else {
            let j = self.rng.below(self.seen as usize);
            if j < RESERVOIR_CAP {
                self.samples[j] = v;
            }
        }
    }

    /// Total values offered (not the held sample count).
    pub fn seen(&self) -> u64 {
        self.seen
    }

    /// Currently held samples.
    pub fn samples(&self) -> &[u64] {
        &self.samples
    }

    /// p-th percentile (0..=100) over the held sample; 0 when empty.
    pub fn percentile(&self, p: f64) -> u64 {
        if self.samples.is_empty() {
            return 0;
        }
        let v: Vec<f64> = self.samples.iter().map(|&x| x as f64).collect();
        stats::percentile(&v, p) as u64
    }
}

/// Aggregated serving metrics (snapshot-able).
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    /// Requests completed.
    pub requests: u64,
    /// Batches executed.
    pub batches: u64,
    /// Rows executed including padding.
    pub padded_rows: u64,
    /// Per-request end-to-end latency reservoir (µs).
    pub latency_us: Reservoir,
    /// Per-batch XLA execute time reservoir (µs).
    pub execute_us: Reservoir,
    /// Simulated accelerator cycles over all batches.
    pub sim_cycles: u64,
    /// Simulated accelerator energy over all batches (mJ).
    pub sim_energy_mj: f64,
    /// Dense-equivalent MACs served (for effective-TOPS accounting).
    pub dense_macs: u64,
    /// The same counters/reservoirs split per served model (engine-native
    /// registry path; empty under the legacy single-model XLA path).
    pub per_model: BTreeMap<String, ModelMetrics>,
    /// Prepared models evicted from the registry under byte-budget pressure
    /// (each later request for one pays a re-prepare/re-load on the miss).
    pub evictions: u64,
}

/// Per-model slice of the serving metrics (see [`Metrics::per_model`]).
#[derive(Debug, Clone, Default)]
pub struct ModelMetrics {
    /// Requests completed for this model.
    pub requests: u64,
    /// Batches executed for this model.
    pub batches: u64,
    /// Rows executed including padding.
    pub padded_rows: u64,
    /// Per-request end-to-end latency reservoir (µs).
    pub latency_us: Reservoir,
    /// Per-batch engine execute time reservoir (µs).
    pub execute_us: Reservoir,
    /// Simulated accelerator cycles over this model's batches.
    pub sim_cycles: u64,
    /// Simulated accelerator energy over this model's batches (mJ).
    pub sim_energy_mj: f64,
    /// Dense-equivalent MACs served for this model.
    pub dense_macs: u64,
}

impl ModelMetrics {
    /// Mean batch occupancy (real rows per executed row).
    pub fn occupancy(&self) -> f64 {
        let total = self.requests + self.padded_rows;
        if total == 0 {
            return 0.0;
        }
        self.requests as f64 / total as f64
    }

    /// Latency percentile in µs (over this model's sample reservoir).
    pub fn latency_pct(&self, p: f64) -> u64 {
        self.latency_us.percentile(p)
    }

    /// Simulated effective TOPS of the hardware twin at `freq_hz`.
    pub fn sim_effective_tops(&self, freq_hz: f64) -> f64 {
        if self.sim_cycles == 0 {
            return 0.0;
        }
        let secs = self.sim_cycles as f64 / freq_hz;
        2.0 * self.dense_macs as f64 / secs / 1e12
    }

    /// Simulated average power of the twin (W) at `freq_hz`.
    pub fn sim_avg_power_w(&self, freq_hz: f64) -> f64 {
        if self.sim_cycles == 0 {
            return 0.0;
        }
        let secs = self.sim_cycles as f64 / freq_hz;
        self.sim_energy_mj / 1e3 / secs
    }
}

impl Metrics {
    /// Record one completed batch.
    pub fn record_batch(
        &mut self,
        real_rows: usize,
        compiled_rows: usize,
        execute: Duration,
        sim_cycles: u64,
        sim_energy_mj: f64,
        dense_macs: u64,
    ) {
        self.batches += 1;
        self.requests += real_rows as u64;
        self.padded_rows += (compiled_rows - real_rows) as u64;
        self.execute_us.push(execute.as_micros() as u64);
        self.sim_cycles += sim_cycles;
        self.sim_energy_mj += sim_energy_mj;
        self.dense_macs += dense_macs;
    }

    /// Record one completed batch against the aggregate *and* `model`'s
    /// per-model slice.
    pub fn record_batch_for(
        &mut self,
        model: &str,
        real_rows: usize,
        compiled_rows: usize,
        execute: Duration,
        sim_cycles: u64,
        sim_energy_mj: f64,
        dense_macs: u64,
    ) {
        self.record_batch(real_rows, compiled_rows, execute, sim_cycles, sim_energy_mj, dense_macs);
        let mm = self.per_model.entry(model.to_string()).or_default();
        mm.batches += 1;
        mm.requests += real_rows as u64;
        mm.padded_rows += (compiled_rows - real_rows) as u64;
        mm.execute_us.push(execute.as_micros() as u64);
        mm.sim_cycles += sim_cycles;
        mm.sim_energy_mj += sim_energy_mj;
        mm.dense_macs += dense_macs;
    }

    /// Record one request's end-to-end latency.
    pub fn record_latency(&mut self, l: Duration) {
        self.latency_us.push(l.as_micros() as u64);
    }

    /// Record one request's end-to-end latency against the aggregate *and*
    /// `model`'s per-model slice.
    pub fn record_latency_for(&mut self, model: &str, l: Duration) {
        self.record_latency(l);
        self.per_model.entry(model.to_string()).or_default().latency_us.push(l.as_micros() as u64);
    }

    /// `model`'s metrics slice, if it served anything.
    pub fn model(&self, model: &str) -> Option<&ModelMetrics> {
        self.per_model.get(model)
    }

    /// Mean batch occupancy (real rows per executed row).
    pub fn occupancy(&self) -> f64 {
        let total = self.requests + self.padded_rows;
        if total == 0 {
            return 0.0;
        }
        self.requests as f64 / total as f64
    }

    /// Latency percentile in µs (over the sample reservoir).
    pub fn latency_pct(&self, p: f64) -> u64 {
        self.latency_us.percentile(p)
    }

    /// Simulated effective TOPS of the hardware twin at `freq_hz`.
    pub fn sim_effective_tops(&self, freq_hz: f64) -> f64 {
        if self.sim_cycles == 0 {
            return 0.0;
        }
        let secs = self.sim_cycles as f64 / freq_hz;
        2.0 * self.dense_macs as f64 / secs / 1e12
    }

    /// Simulated average power of the twin (W) at `freq_hz`.
    pub fn sim_avg_power_w(&self, freq_hz: f64) -> f64 {
        if self.sim_cycles == 0 {
            return 0.0;
        }
        let secs = self.sim_cycles as f64 / freq_hz;
        self.sim_energy_mj / 1e3 / secs
    }

    /// One-line human summary — plus one indented line per served model
    /// (and the eviction count) when the registry path populated the
    /// per-model split.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "requests={} batches={} occupancy={:.2} p50={}us p95={}us p99={}us \
             sim_cycles={} sim_energy={:.2}mJ",
            self.requests,
            self.batches,
            self.occupancy(),
            self.latency_pct(50.0),
            self.latency_pct(95.0),
            self.latency_pct(99.0),
            self.sim_cycles,
            self.sim_energy_mj,
        );
        if self.evictions > 0 {
            s.push_str(&format!(" evictions={}", self.evictions));
        }
        for (name, mm) in &self.per_model {
            s.push_str(&format!(
                "\n  {name}: requests={} batches={} occupancy={:.2} p50={}us p95={}us \
                 p99={}us sim_cycles={} sim_energy={:.2}mJ",
                mm.requests,
                mm.batches,
                mm.occupancy(),
                mm.latency_pct(50.0),
                mm.latency_pct(95.0),
                mm.latency_pct(99.0),
                mm.sim_cycles,
                mm.sim_energy_mj,
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn occupancy_counts_padding() {
        let mut m = Metrics::default();
        m.record_batch(3, 8, Duration::from_micros(100), 1000, 0.5, 1_000_000);
        assert!((m.occupancy() - 3.0 / 8.0).abs() < 1e-12);
        m.record_batch(8, 8, Duration::from_micros(100), 1000, 0.5, 1_000_000);
        assert!((m.occupancy() - 11.0 / 16.0).abs() < 1e-12);
    }

    #[test]
    fn effective_tops_accounting() {
        let mut m = Metrics::default();
        // 1e9 dense MACs in 1e6 cycles at 1 GHz = 1 ms → 2e9*1e3 ops/s = 2 TOPS
        m.record_batch(8, 8, Duration::from_micros(10), 1_000_000, 1.0, 1_000_000_000);
        assert!((m.sim_effective_tops(1e9) - 2.0).abs() < 1e-9);
        // 1 mJ over 1 ms = 1 W
        assert!((m.sim_avg_power_w(1e9) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn latency_percentiles() {
        let mut m = Metrics::default();
        for i in 1..=100u64 {
            m.record_latency(Duration::from_micros(i));
        }
        assert!(m.latency_pct(50.0) >= 49 && m.latency_pct(50.0) <= 51);
        assert!(m.latency_pct(95.0) >= 94);
        assert!(m.latency_pct(99.0) >= m.latency_pct(95.0));
    }

    #[test]
    fn reservoir_bounds_memory_and_stays_representative() {
        let mut r = Reservoir::default();
        // 100k values uniform over 0..10_000 µs
        for i in 0..100_000u64 {
            r.push(i % 10_000);
        }
        assert_eq!(r.seen(), 100_000);
        assert_eq!(r.samples().len(), RESERVOIR_CAP, "memory stays bounded");
        // sampled percentiles track the true distribution within a loose band
        let p50 = r.percentile(50.0);
        let p99 = r.percentile(99.0);
        assert!((4_000..=6_000).contains(&p50), "p50={p50}");
        assert!(p99 >= 9_000, "p99={p99}");
    }

    #[test]
    fn reservoir_below_cap_is_exact() {
        let mut r = Reservoir::default();
        for i in 1..=100u64 {
            r.push(i);
        }
        assert_eq!(r.samples().len(), 100);
        assert_eq!(r.percentile(100.0), 100);
        assert_eq!(r.percentile(0.0), 1);
    }

    #[test]
    fn empty_metrics_are_zero() {
        let m = Metrics::default();
        assert_eq!(m.occupancy(), 0.0);
        assert_eq!(m.latency_pct(50.0), 0);
        assert_eq!(m.sim_effective_tops(1e9), 0.0);
        assert!(m.per_model.is_empty());
        assert_eq!(m.evictions, 0);
    }

    #[test]
    fn per_model_split_tracks_each_model() {
        let mut m = Metrics::default();
        m.record_batch_for("a", 3, 8, Duration::from_micros(100), 1000, 0.5, 1_000_000);
        m.record_batch_for("b", 8, 8, Duration::from_micros(50), 2000, 1.0, 2_000_000);
        m.record_batch_for("a", 2, 2, Duration::from_micros(80), 500, 0.25, 500_000);
        m.record_latency_for("a", Duration::from_micros(300));
        m.record_latency_for("b", Duration::from_micros(700));
        // aggregate view sums across models (existing invariants intact)
        assert_eq!(m.requests, 13);
        assert_eq!(m.batches, 3);
        assert_eq!(m.sim_cycles, 3500);
        assert_eq!(m.latency_us.seen(), 2);
        // per-model slices separate cleanly
        let a = m.model("a").unwrap();
        assert_eq!(a.requests, 5);
        assert_eq!(a.batches, 2);
        assert_eq!(a.padded_rows, 5);
        assert_eq!(a.sim_cycles, 1500);
        assert!((a.occupancy() - 5.0 / 10.0).abs() < 1e-12);
        assert_eq!(a.latency_pct(50.0), 300);
        let b = m.model("b").unwrap();
        assert_eq!(b.requests, 8);
        assert_eq!(b.padded_rows, 0);
        assert_eq!(b.latency_pct(50.0), 700);
        assert!(b.sim_effective_tops(1e9) > 0.0);
        assert!(m.model("c").is_none());
        // the per-model table rides on the summary line
        m.evictions = 2;
        let s = m.summary();
        assert!(s.contains("evictions=2"), "{s}");
        assert!(s.contains("\n  a: requests=5"), "{s}");
        assert!(s.contains("\n  b: requests=8"), "{s}");
    }
}
