//! Prepared-model registry: the coordinator's named, byte-budgeted cache of
//! lowered models.
//!
//! Serving more than one network means paying more than one one-time
//! lowering ([`PreparedModel::prepare`] + profile + calibrate) — the
//! registry amortizes each exactly once per model and routes requests by
//! name. Residency is bounded by a **byte budget** over the models' packed
//! weight operands ([`PreparedModel::operand_bytes`] — the same accounting
//! the paper's Table-III SRAM sizing uses): inserting past the budget
//! evicts least-recently-used models until the resident set fits again, and
//! a later request for an evicted model transparently re-prepares (or
//! re-loads the persisted flat binary — see [`PreparedModel::load`]) on the
//! miss path. A single model larger than the whole budget is kept anyway:
//! an empty registry serves nothing, which is strictly worse than an
//! over-budget one.

use crate::engine::PreparedModel;

/// One served model's identity: zoo name plus the DBB encoding point it is
/// prepared at (paper Table I's `nnz/bz`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelSpec {
    /// Serving-zoo name (see [`crate::models::zoo`]).
    pub model: String,
    /// Retained weights per DBB block.
    pub nnz: usize,
    /// DBB block size.
    pub bz: usize,
}

impl ModelSpec {
    /// Spec for `model` at `nnz`/`bz`.
    pub fn new(model: &str, nnz: usize, bz: usize) -> ModelSpec {
        ModelSpec { model: model.to_string(), nnz, bz }
    }
}

struct Entry {
    name: String,
    bytes: usize,
    last_used: u64,
    model: PreparedModel,
}

/// LRU byte-budgeted cache of [`PreparedModel`]s, keyed by model name.
///
/// # Example
///
/// ```
/// use ssta::coordinator::registry::ModelRegistry;
/// use ssta::engine::PreparedModel;
/// use ssta::util::Parallelism;
///
/// let par = Parallelism::serial();
/// let pm = PreparedModel::prepare(&ssta::models::lenet5(), 2, 8, 42, par);
/// let mut reg = ModelRegistry::new(pm.operand_bytes()); // room for exactly one
/// let evicted = reg.insert("LeNet-5", pm);
/// assert!(evicted.is_empty());
/// assert_eq!(reg.names(), ["LeNet-5"]);
/// // `get` marks the entry used and hands out the lowered model
/// let served = reg.get("LeNet-5").unwrap();
/// let out = served.execute(served.seed_input(), par);
/// assert!(!out.output.data().is_empty());
/// ```
pub struct ModelRegistry {
    budget_bytes: usize,
    entries: Vec<Entry>,
    tick: u64,
}

impl ModelRegistry {
    /// Empty registry with an eviction budget over packed-operand bytes.
    pub fn new(budget_bytes: usize) -> ModelRegistry {
        ModelRegistry { budget_bytes, entries: Vec::new(), tick: 0 }
    }

    /// Insert (or replace) `name`, then evict least-recently-used entries
    /// until the resident operand bytes fit the budget again — never the
    /// entry just inserted, and never the last one standing. Returns the
    /// evicted names, oldest first.
    pub fn insert(&mut self, name: impl Into<String>, model: PreparedModel) -> Vec<String> {
        let name = name.into();
        self.entries.retain(|e| e.name != name);
        self.tick += 1;
        self.entries.push(Entry {
            name,
            bytes: model.operand_bytes(),
            last_used: self.tick,
            model,
        });
        let mut evicted = Vec::new();
        while self.resident_bytes() > self.budget_bytes && self.entries.len() > 1 {
            let lru = self
                .entries
                .iter()
                .enumerate()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(i, _)| i)
                .expect("len > 1");
            evicted.push(self.entries.remove(lru).name);
        }
        evicted
    }

    /// The prepared model under `name`, bumping its recency; `None` if it
    /// was never inserted or has been evicted (the caller re-prepares or
    /// re-loads, then [`Self::insert`]s).
    pub fn get(&mut self, name: &str) -> Option<&PreparedModel> {
        self.tick += 1;
        let tick = self.tick;
        self.entries.iter_mut().find(|e| e.name == name).map(|e| {
            e.last_used = tick;
            &e.model
        })
    }

    /// Is `name` resident right now?
    pub fn contains(&self, name: &str) -> bool {
        self.entries.iter().any(|e| e.name == name)
    }

    /// Remove and return `name`'s model, if resident.
    pub fn remove(&mut self, name: &str) -> Option<PreparedModel> {
        let i = self.entries.iter().position(|e| e.name == name)?;
        Some(self.entries.remove(i).model)
    }

    /// Resident model names, least-recently-used first.
    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&Entry> = self.entries.iter().collect();
        v.sort_by_key(|e| e.last_used);
        v.into_iter().map(|e| e.name.as_str()).collect()
    }

    /// Total packed-operand bytes resident right now.
    pub fn resident_bytes(&self) -> usize {
        self.entries.iter().map(|e| e.bytes).sum()
    }

    /// The configured eviction budget (bytes).
    pub fn budget_bytes(&self) -> usize {
        self.budget_bytes
    }

    /// Resident model count.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// No models resident?
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{Layer, LayerKind, Model};
    use crate::util::Parallelism;

    fn tiny(name: &'static str, k: usize) -> PreparedModel {
        let m = Model {
            name,
            dataset: "synthetic",
            layers: vec![Layer {
                name: "fc".into(),
                kind: LayerKind::Fc(k, 8),
                prunable: true,
            }],
        };
        PreparedModel::prepare(&m, 2, 4, 7, Parallelism::serial())
    }

    #[test]
    fn insert_get_and_recency() {
        let mut reg = ModelRegistry::new(usize::MAX);
        assert!(reg.is_empty());
        let a = tiny("reg-a", 16);
        let bytes_a = a.operand_bytes();
        assert!(reg.insert("reg-a", a).is_empty());
        assert!(reg.insert("reg-b", tiny("reg-b", 32)).is_empty());
        assert_eq!(reg.len(), 2);
        assert!(reg.resident_bytes() >= bytes_a);
        // touching a makes b the LRU
        assert!(reg.get("reg-a").is_some());
        assert_eq!(reg.names(), vec!["reg-b", "reg-a"]);
        assert!(reg.get("reg-missing").is_none());
    }

    #[test]
    fn over_budget_inserts_evict_lru() {
        let a = tiny("reg-a", 16);
        let b = tiny("reg-b", 16);
        let c = tiny("reg-c", 16);
        // budget holds exactly two of the (identically sized) models
        let budget = a.operand_bytes() + b.operand_bytes();
        let mut reg = ModelRegistry::new(budget);
        assert!(reg.insert("reg-a", a).is_empty());
        assert!(reg.insert("reg-b", b).is_empty());
        // a is LRU → inserting c evicts it
        assert_eq!(reg.insert("reg-c", c), vec!["reg-a".to_string()]);
        assert!(!reg.contains("reg-a"));
        assert!(reg.contains("reg-b") && reg.contains("reg-c"));
        // touch b, insert a again → c is now the LRU and goes
        assert!(reg.get("reg-b").is_some());
        assert_eq!(reg.insert("reg-a", tiny("reg-a", 16)), vec!["reg-c".to_string()]);
    }

    #[test]
    fn one_over_budget_model_is_kept() {
        // an empty registry serves nothing: a single model larger than the
        // whole budget stays resident
        let mut reg = ModelRegistry::new(1);
        assert!(reg.insert("reg-a", tiny("reg-a", 64)).is_empty());
        assert_eq!(reg.len(), 1);
        assert!(reg.resident_bytes() > reg.budget_bytes());
        // a second insert evicts the first, not the new one
        assert_eq!(reg.insert("reg-b", tiny("reg-b", 64)), vec!["reg-a".to_string()]);
        assert_eq!(reg.names(), vec!["reg-b"]);
    }

    #[test]
    fn replace_same_name_keeps_one_entry() {
        let mut reg = ModelRegistry::new(usize::MAX);
        reg.insert("reg-a", tiny("reg-a", 16));
        let replaced = tiny("reg-a", 32);
        let want = replaced.operand_bytes();
        assert!(reg.insert("reg-a", replaced).is_empty());
        assert_eq!(reg.len(), 1);
        assert_eq!(reg.resident_bytes(), want);
        assert_eq!(reg.remove("reg-a").map(|m| m.operand_bytes()), Some(want));
        assert!(reg.remove("reg-a").is_none());
    }
}
