//! Detailed per-MAC-slot simulator — ground truth for the analytic engine.
//!
//! Walks the exact output-stationary schedule of §IV (same tiling, skew and
//! occupancy as [`super::analytic`]), issuing every physical MAC slot with
//! its real operand data: it computes the functional GEMM result (checked
//! against `crate::gemm` golden in tests), counts every switching event from
//! the data (not from sparsity fractions), and accounts cycles from the
//! deterministic schedule. Slow (O(MAC slots)) — use on small/medium GEMMs;
//! the property tests cross-validate [`super::analytic`] against this.

use super::analytic::{occupancy, sched_blocks, steady_cycles_per_pass, WeightStats};
use super::{EventCounts, GemmTiming};
use crate::arch::{Datapath, Design};
use crate::dbb::DbbMatrix;
use crate::tensor::{TensorI32, TensorI8};

/// Result of a detailed simulation: functional output + timing.
#[derive(Debug, Clone)]
pub struct DetailedResult {
    /// The computed GEMM output (INT32).
    pub output: TensorI32,
    /// Timing/event summary.
    pub timing: GemmTiming,
}

/// Simulate `C = A · W` on the design's array, per MAC slot.
///
/// `im2col_magnification` only scales the activation SRAM traffic (the
/// datapath behaviour is unchanged), mirroring the analytic engine.
pub fn simulate_gemm(
    design: &Design,
    a: &TensorI8,
    w: &DbbMatrix,
    im2col_magnification: f64,
) -> DetailedResult {
    design.validate().expect("valid design");
    let d = design.dims;
    let (mg, k) = (a.shape()[0], a.shape()[1]);
    assert_eq!(k, w.k, "GEMM inner dim");
    let ng = w.n;
    assert!(
        !matches!(design.datapath, Datapath::Bsr),
        "the BSR datapath runs on its own operand: use simulate_gemm_bsr"
    );
    if !matches!(design.datapath, Datapath::Dense) {
        assert_eq!(d.b, w.bz, "sparse datapath block size must match encoding");
    }

    let stats = WeightStats::of(w);
    let o = occupancy(design, &stats);
    let tsteps = sched_blocks(design, &stats);
    let (tile_rows, tile_cols) = (d.a * d.m, d.c * d.n);
    let row_tiles = mg.div_ceil(tile_rows);
    let col_tiles = ng.div_ceil(tile_cols);

    // dense view needed for the dense datapath / fixed-DBB fallback streams
    let dense_w = w.decompress();

    let mut out = TensorI32::zeros(&[mg, ng]);
    let mut ev = EventCounts::default();

    for rt in 0..row_tiles {
        for ct in 0..col_tiles {
            // ---- one output-tile pass ----
            for t in 0..tsteps {
                // every TPE (i,j) processes step t (at skewed cycles; the
                // schedule is deterministic so we only account the counts)
                for ti in 0..d.m {
                    for tj in 0..d.n {
                        for ai in 0..d.a {
                            let row = rt * tile_rows + ti * d.a + ai;
                            for cj in 0..d.c {
                                let col = ct * tile_cols + tj * d.c + cj;
                                if row >= mg || col >= ng {
                                    continue; // idle (counted via slot balance)
                                }
                                issue_block(
                                    design, a, w, &dense_w, row, col, t, o, &mut out, &mut ev,
                                );
                            }
                        }
                    }
                }
            }
            ev.cycles += steady_cycles_per_pass(design, &stats);
        }
    }
    // one pipeline fill (skew) + one final accumulator drain for the whole
    // back-to-back pass stream (matches `analytic::gemm_cycles`)
    ev.cycles += (d.m + d.n - 2) as u64 * occupancy(design, &stats) as u64
        + (d.a * d.c) as u64;

    // idle slots = total slots − issued
    let slots = design.physical_macs() as u64 * ev.cycles;
    ev.macs_idle = slots - (ev.macs_active + ev.macs_gated);

    // ---- SRAM traffic (counted, not computed from formulas) ----
    let kb = tsteps as u64;
    let wbytes_per_col: u64 = match design.datapath {
        Datapath::Dense => kb * d.b as u64,
        // + one index byte per block
        Datapath::FixedDbb { b } => kb * (o as u64 * b as u64) + (w.kblocks() as u64),
        Datapath::Vdbb => kb * o as u64 + w.kblocks() as u64,
        Datapath::Bsr => unreachable!("guarded at entry"),
    };
    ev.weight_sram_bytes = wbytes_per_col * ng as u64 * row_tiles as u64;
    ev.act_edge_bytes = (mg as u64 * kb * d.b as u64) * col_tiles as u64;
    ev.act_sram_bytes = (ev.act_edge_bytes as f64 / im2col_magnification.max(1.0)) as u64;
    ev.out_sram_bytes = mg as u64 * ng as u64; // INT8 post-requant write-back
    ev.mux_selects = match design.datapath {
        Datapath::Dense | Datapath::Bsr => 0,
        _ => ev.macs_active + ev.macs_gated,
    };

    DetailedResult {
        output: out,
        timing: GemmTiming {
            events: ev,
            dense_macs: mg as u64 * k as u64 * ng as u64,
        },
    }
}

/// Issue all MAC slots of one (row, col, block-step) triple.
#[allow(clippy::too_many_arguments)]
fn issue_block(
    design: &Design,
    a: &TensorI8,
    w: &DbbMatrix,
    dense_w: &TensorI8,
    row: usize,
    col: usize,
    t: usize,
    o: usize,
    out: &mut TensorI32,
    ev: &mut EventCounts,
) {
    let d = design.dims;
    let k = a.shape()[1];
    let mut mac = |av: i8, wv: i8| {
        if av != 0 && wv != 0 {
            ev.macs_active += 1;
        } else {
            ev.macs_gated += 1;
        }
        if av != 0 && wv != 0 {
            let cur = out.at(&[row, col]);
            out.set(&[row, col], cur + av as i32 * wv as i32);
        }
    };

    match design.datapath {
        Datapath::Dense => {
            // step t covers k ∈ [t·B, t·B+B)
            for s in 0..d.b {
                let kk = t * d.b + s;
                let (av, wv) = if kk < k {
                    (a.at(&[row, kk]), dense_w.at(&[kk, col]))
                } else {
                    (0, 0) // K padding streams zeros
                };
                mac(av, wv);
            }
        }
        Datapath::FixedDbb { b } => {
            let blk = w.block(col, t);
            if w.bound <= b {
                // sparse mode: one cycle, b slots, compressed weights
                let positions: Vec<usize> = blk.positions().collect();
                for s in 0..b {
                    if s < blk.vals.len() {
                        let kk = t * d.b + positions[s];
                        mac(a.at(&[row, kk]), blk.vals[s]);
                    } else {
                        mac(0, 0); // encoded padding slot
                    }
                }
            } else {
                // dense fallback: stream the expanded block in o·b slots
                let expanded = blk.expand(d.b);
                for s in 0..(o * b) {
                    if s < d.b {
                        let kk = t * d.b + s;
                        let av = if kk < k { a.at(&[row, kk]) } else { 0 };
                        mac(av, expanded[s]);
                    } else {
                        mac(0, 0);
                    }
                }
            }
        }
        Datapath::Vdbb => {
            // time unrolled: o = bound slots, one non-zero per cycle
            let blk = w.block(col, t);
            let positions: Vec<usize> = blk.positions().collect();
            for s in 0..o {
                if s < blk.vals.len() {
                    let kk = t * d.b + positions[s];
                    mac(a.at(&[row, kk]), blk.vals[s]);
                } else {
                    mac(0, 0); // block had fewer non-zeros than the bound
                }
            }
        }
        Datapath::Bsr => unreachable!("BSR blocks are issued by simulate_gemm_bsr"),
    }
}

/// Simulate `C = A · W` for a BSR operand on a [`Datapath::Bsr`] design,
/// per MAC slot. The scheduler walks the real `row_ptr`/`col_idx`
/// structure: a block-column only ever issues its *surviving* blocks. The
/// systolic wavefront stays in lockstep across an output tile, so a pass
/// streams the **maximum** surviving-block count over the block-columns it
/// covers (shorter columns idle for the remainder; the analytic twin
/// prices the average — equal whenever the pruner keeps a uniform block
/// count per column, which matched-sparsity budgets do).
pub fn simulate_gemm_bsr(
    design: &Design,
    a: &TensorI8,
    w: &crate::gemm::BsrPacked,
    im2col_magnification: f64,
) -> DetailedResult {
    design.validate().expect("valid design");
    assert!(
        matches!(design.datapath, Datapath::Bsr),
        "simulate_gemm_bsr is the BSR-datapath entry"
    );
    let d = design.dims;
    let (mg, k) = (a.shape()[0], a.shape()[1]);
    assert_eq!(k, w.k, "GEMM inner dim");
    assert_eq!(d.b, w.bz_r, "BSR block rows must match the datapath B");
    assert_eq!(d.b, w.bz_c, "BSR block cols must match the datapath B");
    let ng = w.n;
    let bz = d.b;
    let (tile_rows, tile_cols) = (d.a * d.m, d.c * d.n);
    let row_tiles = mg.div_ceil(tile_rows);
    let col_tiles = ng.div_ceil(tile_cols);

    // per-block-column surviving (block_row, storage_index) lists, in
    // ascending K order (canonical col_idx order guarantees it)
    let nbc = w.block_cols();
    let mut col_blocks: Vec<Vec<(usize, usize)>> = vec![Vec::new(); nbc];
    for br in 0..w.block_rows() {
        for idx in w.row_ptr()[br]..w.row_ptr()[br + 1] {
            col_blocks[w.col_idx()[idx] as usize].push((br, idx));
        }
    }
    // wavefront length per column tile = max survivors over covered bcs
    let stream_len: Vec<usize> = (0..col_tiles)
        .map(|ct| {
            let lo = ct * tile_cols / bz;
            let hi = ((ct + 1) * tile_cols).min(ng).div_ceil(bz);
            (lo..hi).map(|bc| col_blocks[bc].len()).max().unwrap_or(0)
        })
        .collect();

    let mut out = TensorI32::zeros(&[mg, ng]);
    let mut ev = EventCounts::default();
    for rt in 0..row_tiles {
        for (ct, &tsteps) in stream_len.iter().enumerate() {
            for ti in 0..d.m {
                for tj in 0..d.n {
                    for ai in 0..d.a {
                        let row = rt * tile_rows + ti * d.a + ai;
                        for cj in 0..d.c {
                            let col = ct * tile_cols + tj * d.c + cj;
                            if row >= mg || col >= ng {
                                continue; // idle (counted via slot balance)
                            }
                            // a column shorter than the tile wavefront
                            // idles after its own blocks run out — those
                            // slots land in the idle balance below
                            for &(br, idx) in &col_blocks[col / bz] {
                                issue_bsr_block(a, w, br, idx, row, col, &mut out, &mut ev);
                            }
                        }
                    }
                }
            }
            ev.cycles += tsteps as u64; // occupancy 1 per surviving block
        }
    }
    // pipeline fill + final accumulator drain, occupancy 1
    ev.cycles += (d.m + d.n - 2) as u64 + (d.a * d.c) as u64;
    let slots = design.physical_macs() as u64 * ev.cycles;
    ev.macs_idle = slots - (ev.macs_active + ev.macs_gated);

    // ---- SRAM traffic (counted from the real structure) ----
    // values: each output column re-reads its surviving blocks' B-value
    // column slices once per row-tile pass; index: row_ptr + col_idx are
    // walked once per row-tile pass. No per-element bitmask exists.
    let value_bytes: u64 = (0..ng)
        .map(|c| col_blocks[c / bz].len() as u64 * bz as u64)
        .sum();
    ev.weight_sram_bytes = (value_bytes + w.index_bytes() as u64) * row_tiles as u64;
    let stream_total: u64 = stream_len.iter().map(|&t| t as u64).sum();
    ev.act_edge_bytes = mg as u64 * bz as u64 * stream_total;
    ev.act_sram_bytes = (ev.act_edge_bytes as f64 / im2col_magnification.max(1.0)) as u64;
    ev.out_sram_bytes = mg as u64 * ng as u64; // INT8 post-requant write-back
    ev.mux_selects = 0; // skip lives in the scheduler, not the operand path

    DetailedResult {
        output: out,
        timing: GemmTiming {
            events: ev,
            dense_macs: mg as u64 * k as u64 * ng as u64,
        },
    }
}

/// Issue the B MAC slots of one surviving BSR block for one output element.
#[allow(clippy::too_many_arguments)]
fn issue_bsr_block(
    a: &TensorI8,
    w: &crate::gemm::BsrPacked,
    br: usize,
    idx: usize,
    row: usize,
    col: usize,
    out: &mut TensorI32,
    ev: &mut EventCounts,
) {
    let (bz_r, bz_c) = (w.bz_r, w.bz_c);
    let block = &w.blocks()[idx * bz_r * bz_c..(idx + 1) * bz_r * bz_c];
    let jc = col % bz_c;
    for s in 0..bz_r {
        let kk = br * bz_r + s;
        // K-edge padding inside the block is stored as literal zeros, so
        // the padded slots stream (and gate) exactly like dense K padding
        let (av, wv) = if kk < w.k {
            (a.at(&[row, kk]), block[s * bz_c + jc])
        } else {
            (0, 0)
        };
        if av != 0 && wv != 0 {
            ev.macs_active += 1;
            let cur = out.at(&[row, col]);
            out.set(&[row, col], cur + av as i32 * wv as i32);
        } else {
            ev.macs_gated += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{ArrayDims, Tech};
    use crate::dbb::prune::prune_i8;
    use crate::gemm;
    use crate::sim::analytic;
    use crate::util::prop::{check, Config};
    use crate::util::Rng;

    fn designs_under_test() -> Vec<Design> {
        let mk = |a, b, c, m, n, dp| Design {
            dims: ArrayDims { a, b, c, m, n },
            datapath: dp,
            im2col: false,
            act_cg: true,
            tech: Tech::N16,
        };
        vec![
            mk(1, 1, 1, 2, 4, Datapath::Dense),            // classic SA
            mk(2, 8, 2, 2, 2, Datapath::Dense),            // dense STA
            mk(2, 8, 2, 2, 2, Datapath::FixedDbb { b: 4 }), // STA-DBB 4/8
            mk(2, 8, 2, 2, 2, Datapath::FixedDbb { b: 2 }), // STA-DBB 2/8
            mk(2, 8, 4, 2, 2, Datapath::Vdbb),             // STA-VDBB
            mk(4, 8, 8, 2, 2, Datapath::Vdbb),             // bigger VDBB TPE
        ]
    }

    #[test]
    fn functional_output_matches_golden() {
        check(Config::default().cases(40), |rng| {
            let designs = designs_under_test();
            let design = &designs[rng.below(designs.len())];
            let mg = rng.below(20) + 1;
            let k = rng.below(40) + 1;
            let ng = rng.below(20) + 1;
            let nnz = rng.below(8) + 1;
            let a = TensorI8::rand_sparse(&[mg, k], 0.4, rng);
            let wd = prune_i8(&TensorI8::rand(&[k, ng], rng), 8, nnz);
            let w = DbbMatrix::compress(&wd, 8).unwrap();
            let r = simulate_gemm(design, &a, &w, 1.0);
            let golden = gemm::dense_i8(&a, &wd);
            assert_eq!(
                r.output.data(),
                golden.data(),
                "design={} mg={mg} k={k} ng={ng} nnz={nnz}",
                design.label()
            );
        });
    }

    #[test]
    fn cycles_match_analytic_exactly() {
        check(Config::default().cases(40), |rng| {
            let designs = designs_under_test();
            let design = &designs[rng.below(designs.len())];
            let mg = rng.below(30) + 1;
            let k = rng.below(50) + 1;
            let ng = rng.below(30) + 1;
            let nnz = rng.below(8) + 1;
            let a = TensorI8::rand(&[mg, k], rng);
            let wd = prune_i8(&TensorI8::rand(&[k, ng], rng), 8, nnz);
            let w = DbbMatrix::compress(&wd, 8).unwrap();
            let det = simulate_gemm(design, &a, &w, 1.0);
            let ana = analytic::gemm_timing_exact(design, &a, &w, 1.0);
            assert_eq!(
                det.timing.events.cycles,
                ana.events.cycles,
                "design={}",
                design.label()
            );
            assert_eq!(det.timing.events.mac_slots(), ana.events.mac_slots());
        });
    }

    #[test]
    fn issued_slots_match_analytic_exactly() {
        check(Config::default().cases(30), |rng| {
            let designs = designs_under_test();
            let design = &designs[rng.below(designs.len())];
            let mg = rng.below(24) + 1;
            let k = rng.below(48) + 1;
            let ng = rng.below(24) + 1;
            let nnz = rng.below(8) + 1;
            let a = TensorI8::rand(&[mg, k], rng);
            let wd = prune_i8(&TensorI8::rand(&[k, ng], rng), 8, nnz);
            let w = DbbMatrix::compress(&wd, 8).unwrap();
            let det = simulate_gemm(design, &a, &w, 1.0).timing.events;
            let ana = analytic::gemm_timing_exact(design, &a, &w, 1.0).events;
            let det_issued = det.macs_active + det.macs_gated;
            let ana_issued = ana.macs_active + ana.macs_gated;
            assert_eq!(det_issued, ana_issued, "design={}", design.label());
            assert_eq!(det.macs_idle, ana.macs_idle);
        });
    }

    #[test]
    fn active_counts_match_analytic_when_acts_dense() {
        // with no activation zeros the analytic fraction model is exact
        check(Config::default().cases(30), |rng| {
            let designs = designs_under_test();
            let design = &designs[rng.below(designs.len())];
            let mg = rng.below(16) + 1;
            let k = rng.below(32) + 1;
            let ng = rng.below(16) + 1;
            let nnz = rng.below(8) + 1;
            // all-nonzero activations
            let mut a = TensorI8::rand(&[mg, k], rng);
            for v in a.data_mut() {
                if *v == 0 {
                    *v = 1;
                }
            }
            let wd = prune_i8(&TensorI8::rand(&[k, ng], rng), 8, nnz);
            let w = DbbMatrix::compress(&wd, 8).unwrap();
            let det = simulate_gemm(design, &a, &w, 1.0).timing.events;
            let ana = analytic::gemm_timing_exact(design, &a, &w, 1.0).events;
            assert_eq!(det.macs_active, ana.macs_active, "design={}", design.label());
        });
    }

    #[test]
    fn active_counts_close_to_analytic_with_sparse_acts() {
        let mut rng = Rng::new(77);
        let design = &designs_under_test()[4]; // VDBB
        let a = TensorI8::rand_sparse(&[32, 64], 0.5, &mut rng);
        let wd = prune_i8(&TensorI8::rand(&[64, 32], &mut rng), 8, 3);
        let w = DbbMatrix::compress(&wd, 8).unwrap();
        let det = simulate_gemm(design, &a, &w, 1.0).timing.events;
        let ana = analytic::gemm_timing_exact(design, &a, &w, 1.0).events;
        let rel = (det.macs_active as f64 - ana.macs_active as f64).abs()
            / det.macs_active.max(1) as f64;
        assert!(rel < 0.02, "rel={rel}");
    }

    #[test]
    fn sram_traffic_matches_analytic() {
        check(Config::default().cases(30), |rng| {
            let designs = designs_under_test();
            let design = &designs[rng.below(designs.len())];
            let mg = rng.below(24) + 1;
            let k = rng.below(48) + 1;
            let ng = rng.below(24) + 1;
            let nnz = rng.below(8) + 1;
            let a = TensorI8::rand(&[mg, k], rng);
            let wd = prune_i8(&TensorI8::rand(&[k, ng], rng), 8, nnz);
            let w = DbbMatrix::compress(&wd, 8).unwrap();
            let det = simulate_gemm(design, &a, &w, 1.0).timing.events;
            let ana = analytic::gemm_timing_exact(design, &a, &w, 1.0).events;
            assert_eq!(det.act_edge_bytes, ana.act_edge_bytes, "{}", design.label());
            assert_eq!(det.out_sram_bytes, ana.out_sram_bytes);
            // weight bytes: same formula base; allow the index-byte rounding
            let diff = det.weight_sram_bytes as i64 - ana.weight_sram_bytes as i64;
            assert!(
                diff.unsigned_abs() <= (w.kblocks() * ng) as u64,
                "det={} ana={} design={}",
                det.weight_sram_bytes,
                ana.weight_sram_bytes,
                design.label()
            );
        });
    }

    #[test]
    fn bsr_functional_matches_golden() {
        use crate::dbb::prune::prune_bsr_i8;
        use crate::gemm::BsrPacked;
        let design = Design {
            dims: ArrayDims { a: 2, b: 8, c: 2, m: 2, n: 2 },
            datapath: Datapath::Bsr,
            im2col: false,
            act_cg: true,
            tech: Tech::N16,
        };
        check(Config::default().cases(30), |rng| {
            let mg = rng.below(20) + 1;
            let k = rng.below(40) + 1;
            let ng = rng.below(20) + 1;
            let keep = rng.below(ng.div_ceil(8)) + 1;
            let a = TensorI8::rand_sparse(&[mg, k], 0.4, rng);
            let wd = prune_bsr_i8(&TensorI8::rand(&[k, ng], rng), 8, 8, keep);
            let w = BsrPacked::pack(&wd, 8, 8);
            let r = simulate_gemm_bsr(&design, &a, &w, 1.0);
            let golden = gemm::dense_i8(&a, &wd);
            assert_eq!(
                r.output.data(),
                golden.data(),
                "mg={mg} k={k} ng={ng} keep={keep}"
            );
            assert_eq!(r.timing.events.mux_selects, 0);
            // slot balance holds exactly
            assert_eq!(
                r.timing.events.mac_slots(),
                design.physical_macs() as u64 * r.timing.events.cycles
            );
        });
    }

    #[test]
    fn bsr_uniform_survival_matches_analytic_exactly() {
        // a checkerboard block pattern gives every block-column exactly
        // half its blocks, so the detailed per-tile max equals the
        // analytic per-column average: cycles and traffic agree exactly
        use crate::gemm::BsrPacked;
        let design = Design {
            dims: ArrayDims { a: 2, b: 8, c: 2, m: 2, n: 2 },
            datapath: Datapath::Bsr,
            im2col: false,
            act_cg: true,
            tech: Tech::N16,
        };
        let (k, ng) = (64, 64);
        let mut rng = Rng::new(9);
        let mut wd = TensorI8::rand(&[k, ng], &mut rng);
        for v in wd.data_mut() {
            if *v == 0 {
                *v = 1; // no accidental all-zero blocks
            }
        }
        for r in 0..k {
            for c in 0..ng {
                if ((r / 8) + (c / 8)) % 2 == 1 {
                    wd.set(&[r, c], 0);
                }
            }
        }
        let w = BsrPacked::pack(&wd, 8, 8);
        assert_eq!(w.stored_blocks(), 32);
        let a = TensorI8::rand(&[24, k], &mut rng);
        let det = simulate_gemm_bsr(&design, &a, &w, 1.0).timing.events;
        let stats = analytic::WeightStats::of_bsr(&w);
        assert_eq!(stats.bound, 4); // 50% block density on the 1/8 grid
        let ana = analytic::gemm_timing_stats(&design, 24, &stats, a.sparsity(), 1.0).events;
        assert_eq!(det.cycles, ana.cycles);
        assert_eq!(det.act_edge_bytes, ana.act_edge_bytes);
        assert_eq!(det.weight_sram_bytes, ana.weight_sram_bytes);
        assert_eq!(det.macs_active + det.macs_gated, ana.macs_active + ana.macs_gated);
        assert_eq!(det.mux_selects, 0);
    }

    #[test]
    fn vdbb_sparser_weights_fewer_cycles() {
        let mut rng = Rng::new(5);
        let design = &designs_under_test()[4];
        let a = TensorI8::rand(&[32, 64], &mut rng);
        let w2 = DbbMatrix::compress_with_bound(
            &prune_i8(&TensorI8::rand(&[64, 32], &mut rng), 8, 2),
            8,
            2,
        )
        .unwrap();
        let w6 = DbbMatrix::compress_with_bound(
            &prune_i8(&TensorI8::rand(&[64, 32], &mut rng), 8, 6),
            8,
            6,
        )
        .unwrap();
        let c2 = simulate_gemm(design, &a, &w2, 1.0).timing.events.cycles;
        let c6 = simulate_gemm(design, &a, &w6, 1.0).timing.events.cycles;
        assert!(c6 > 2 * c2, "c2={c2} c6={c6}"); // ≈3x
    }
}
